"""Paper Section 5.2 reproduction: convex softmax regression with R=15
workers, batch 8, Top_k with k=40 coordinates, lr = c/(lambda (a+t)),
synchronous (Algorithm 1) and asynchronous (Algorithm 2) operation.

Run:  PYTHONPATH=src python examples/mnist_convex.py [--steps 400]
"""

import argparse

import jax

from repro.core.operators import (
    Identity, QSGDQuantizer, QuantizedSparsifier, Sign, SignSparsifier, TopK,
)
from repro.data import mnist_like, worker_batches
from repro.models import softmax
from repro.optim import inverse_time, sgd
from repro.train import RunConfig, train

R, B = 15, 8
K = 40 / 7850.0


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--steps", type=int, default=400)
    ap.add_argument("--target", type=float, default=1.0)
    args = ap.parse_args()
    T = args.steps

    x, y = mnist_like(6000, seed=0)
    cfg = softmax.SoftmaxConfig(l2=1.0 / len(x))
    params = softmax.init_params(jax.random.PRNGKey(0), cfg)

    def grad_fn(p, batch):
        return jax.value_and_grad(
            lambda pp: softmax.loss_fn(pp, batch, cfg)[0])(p)

    lr = inverse_time(xi=60.0, a=100.0)

    methods = [
        ("vanilla SGD", Identity(), 1, False),
        ("TopK-SGD [SCJ18]", TopK(k=K), 1, False),
        ("EF-SIGNSGD [KRSJ19]", Sign(), 1, False),
        ("EF-QSGD [WHHZ18]", QSGDQuantizer(s=15), 1, False),
        ("QTopK (Lemma 1)", QuantizedSparsifier(k=K, s=15), 1, False),
        ("SignTopK (Lemma 3)", SignSparsifier(k=K, m=1), 1, False),
        ("local SGD H=8 [Sti19]", Identity(), 8, False),
        ("Qsparse-local QTopK H=8", QuantizedSparsifier(k=K, s=15), 8, False),
        ("Qsparse-local SignTopK H=8", SignSparsifier(k=K, m=1), 8, False),
        ("async SignTopK H=8 (Alg 2)", SignSparsifier(k=K, m=1), 8, True),
    ]
    print(f"{'method':30s} {'loss':>7s} {'Mbits':>10s} "
          f"{'bits->target':>14s} {'rounds':>7s}")
    base_bits = None
    for name, op, H, asy in methods:
        run = RunConfig(total_steps=T, R=R, H=H, asynchronous=asy,
                        log_every=50, target_loss=args.target)
        state, hist = train(grad_fn, params, sgd(), op, lr,
                            worker_batches(x, y, R, B, T, seed=1), run)
        btt = hist.bits_to_target
        if name == "vanilla SGD":
            base_bits = btt
        rel = (f"{base_bits / btt:7.0f}x less" if btt and base_bits else "")
        print(f"{name:30s} {hist.loss[-1]:7.3f} "
              f"{hist.bits[-1] / 1e6:10.2f} "
              f"{(f'{btt:.3g}' if btt else 'n/a'):>14s} "
              f"{hist.rounds[-1]:7d}  {rel}")


if __name__ == "__main__":
    main()

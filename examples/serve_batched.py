"""Batched serving example: prefill a batch of prompts through any
assigned architecture's smoke config, then greedy-decode continuation
tokens with the family's KV cache / recurrent-state decode step.

Run:  PYTHONPATH=src python examples/serve_batched.py --arch gemma3-1b
"""

import argparse
import time

import jax
import jax.numpy as jnp

from repro.configs import ARCHS, get_config
from repro.models import get_model


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", choices=sorted(ARCHS), default="gemma3-1b")
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--prompt-len", type=int, default=32)
    ap.add_argument("--new-tokens", type=int, default=32)
    args = ap.parse_args()

    cfg = get_config(args.arch, smoke=True)
    model = get_model(cfg)
    params = model.init_params(jax.random.PRNGKey(0), cfg)
    B, S = args.batch, args.prompt_len
    prompts = jax.random.randint(jax.random.PRNGKey(1), (B, S), 0, cfg.vocab)
    batch = {"tokens": prompts}
    if cfg.modality:
        batch["prefix_embeds"] = 0.02 * jax.random.normal(
            jax.random.PRNGKey(2), (B, cfg.n_frontend_tokens, cfg.d_model))

    max_len = S + args.new_tokens + (cfg.n_frontend_tokens if cfg.modality else 0)
    t0 = time.time()
    logits, cache, n = model.prefill(params, batch, cfg, max_len=max_len)
    logits = logits.reshape(B, -1)[:, :cfg.vocab]
    t_prefill = time.time() - t0

    decode = jax.jit(
        lambda p, c, tok, pos: model.decode_step(p, c, tok, pos, cfg),
        static_argnames=(),
    ) if False else (lambda p, c, tok, pos: model.decode_step(p, c, tok, pos, cfg))

    out_tokens = []
    tok = jnp.argmax(logits, -1).astype(jnp.int32)
    pos0 = S + (cfg.n_frontend_tokens if cfg.modality else 0)
    t0 = time.time()
    for i in range(args.new_tokens):
        out_tokens.append(tok)
        lg, cache = decode(params, cache, tok, pos0 + i)
        tok = jnp.argmax(lg, -1).astype(jnp.int32)
    jax.block_until_ready(tok)
    t_decode = time.time() - t0

    gen = jnp.stack(out_tokens, axis=1)
    print(f"arch={args.arch} ({cfg.family})  batch={B}")
    print(f"prefill {S} tokens: {t_prefill * 1e3:.1f} ms   "
          f"decode {args.new_tokens} tokens: "
          f"{t_decode / args.new_tokens * 1e3:.1f} ms/token")
    for b in range(min(B, 2)):
        print(f"  seq {b}: prompt tail {list(map(int, prompts[b, -6:]))} -> "
              f"generated {list(map(int, gen[b, :10]))}...")


if __name__ == "__main__":
    main()

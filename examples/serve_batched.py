"""Continuous-batching serving example (DESIGN.md §11).

Initializes the dense smoke transformer, compresses it with its arch
policy preset, round-trips the compressed tree through a compact
checkpoint, and drives the ServeEngine on a burst of mixed-length
requests — printing the per-request metrics table (queue wait, TTFT,
tokens/s) and the zero-densify counter.

Run:  PYTHONPATH=src python examples/serve_batched.py --arch yi-6b
"""

import argparse
import tempfile

import jax
import numpy as np

from repro.configs import ARCHS, get_config
from repro.configs.policies import get_policy_preset
from repro.models import get_model
from repro.serve import ServeEngine, compressed as sc
from repro.train import checkpoint as ckpt


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", choices=sorted(ARCHS), default="yi-6b")
    ap.add_argument("--requests", type=int, default=8)
    ap.add_argument("--max-batch", type=int, default=4)
    ap.add_argument("--prompt-len", type=int, default=12)
    ap.add_argument("--new-tokens", type=int, default=8)
    ap.add_argument("--scheduler", choices=("continuous", "static"),
                    default="continuous")
    ap.add_argument("--dense", action="store_true",
                    help="skip compression (dense baseline)")
    args = ap.parse_args()

    cfg = get_config(args.arch, smoke=True)
    if cfg.family != "dense":
        raise SystemExit(f"{args.arch} is family={cfg.family!r}; the "
                         f"serving engine drives the dense family")
    model = get_model(cfg)
    params = model.init_params(jax.random.PRNGKey(0), cfg)

    if not args.dense:
        policy = get_policy_preset("arch", args.arch)
        comp = sc.compress_tree(params, policy)
        # compact checkpoint round-trip: what lands on disk is the
        # compressed buffers; loading never builds the dense weights
        with tempfile.TemporaryDirectory() as d:
            ckpt.save_compact(d, comp, step=0)
            assert ckpt.is_compact(d)
            params = ckpt.load_compact(d)
        sizes = sc.tree_bytes(params)
        print(f"compressed: {sizes['compressed'] / 1e6:.2f} MB resident "
              f"(dense {sizes['dense'] / 1e6:.2f} MB)")
    sc.reset_stats()

    eng = ServeEngine(params, cfg, max_batch=args.max_batch,
                      max_len=args.prompt_len + args.new_tokens + 4,
                      prompt_pad=args.prompt_len,
                      scheduler=args.scheduler)
    rng = np.random.RandomState(0)
    for _ in range(args.requests):
        plen = int(rng.randint(max(2, args.prompt_len // 2),
                               args.prompt_len + 1))
        eng.submit(rng.randint(0, cfg.vocab, plen).tolist(),
                   max_new_tokens=args.new_tokens)
    res = eng.run()

    print(f"arch={args.arch} scheduler={args.scheduler} "
          f"slots={args.max_batch} requests={len(res['metrics'])}")
    print(" rid  plen  new   wait_ms   ttft_ms    tok/s")
    for m in sorted(res["metrics"].values(), key=lambda m: m.rid):
        print(f"{m.rid:4d} {m.prompt_len:5d} {m.new_tokens:4d} "
              f"{m.queue_wait_s * 1e3:9.1f} {m.ttft_s * 1e3:9.1f} "
              f"{m.tokens_per_s:8.1f}")
    print(f"aggregate: {res['requests_per_s']:.2f} req/s, "
          f"{res['tokens_per_s']:.1f} tok/s over {res['steps']} engine "
          f"steps; peak occupancy {max(eng.occupancy)}/{args.max_batch}")
    print(f"serve stats: {sc.STATS} (densify must stay 0)")
    rid0 = min(res["outputs"])
    print(f"sample (rid {rid0}):", res["outputs"][rid0][:10])


if __name__ == "__main__":
    main()

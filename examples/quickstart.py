"""Quickstart: Qsparse-local-SGD in ~60 lines.

Trains the paper's convex objective (softmax regression on MNIST-shaped
data) with 8 workers, comparing vanilla distributed SGD against
Qsparse-local-SGD (SignTop_k + error feedback + H=4 local steps), and
prints the bits transmitted to reach the same loss.

Run:  PYTHONPATH=src python examples/quickstart.py
"""

import jax

from repro.core.operators import Identity, SignSparsifier
from repro.data import mnist_like, worker_batches
from repro.models import softmax
from repro.optim import inverse_time, sgd
from repro.train import RunConfig, train


def main():
    R, b, T = 8, 8, 300
    x, y = mnist_like(4000, seed=0)
    cfg = softmax.SoftmaxConfig(l2=1.0 / len(x))
    params = softmax.init_params(jax.random.PRNGKey(0), cfg)

    def grad_fn(p, batch):
        return jax.value_and_grad(
            lambda pp: softmax.loss_fn(pp, batch, cfg)[0])(p)

    lr = inverse_time(xi=60.0, a=100.0)
    print(f"{'method':24s} {'loss':>8s} {'Mbits':>10s} {'rounds':>7s}")
    results = {}
    for name, op, H in [
        ("vanilla SGD", Identity(), 1),
        ("Qsparse-local (SignTopK)", SignSparsifier(k=0.01, m=1), 4),
    ]:
        run = RunConfig(total_steps=T, R=R, H=H, log_every=50,
                        target_loss=1.0)
        state, hist = train(
            grad_fn, params, sgd(), op, lr,
            worker_batches(x, y, R, b, T, seed=1), run)
        results[name] = hist
        print(f"{name:24s} {hist.loss[-1]:8.3f} "
              f"{hist.bits[-1] / 1e6:10.2f} {hist.rounds[-1]:7d}")
    v = results["vanilla SGD"]
    q = results["Qsparse-local (SignTopK)"]
    if v.bits_to_target and q.bits_to_target:
        print(f"\nbits to reach loss 1.0:  vanilla {v.bits_to_target:.3g}  "
              f"qsparse {q.bits_to_target:.3g}  "
              f"(saving {v.bits_to_target / q.bits_to_target:.0f}x)")


if __name__ == "__main__":
    main()

"""End-to-end LM training driver: a transformer trained with
Qsparse-local-SGD on the synthetic Markov token stream, with eval,
bits ledger and checkpointing.

Default is a ~5M-parameter model sized to finish a few hundred steps on
this CPU container in minutes.  ``--preset 100m`` selects a ~100M
config (the deliverable-scale run; expect hours on CPU, minutes on a
real accelerator).

Run:  PYTHONPATH=src python examples/train_lm.py --steps 200
"""

import argparse
import time

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.core.operators import SignSparsifier
from repro.data import LMTokenStream
from repro.models import get_model
from repro.optim import momentum_sgd, warmup_piecewise
from repro.train import RunConfig, train

PRESETS = {
    "5m": ModelConfig(
        name="lm5m", family="dense", n_layers=4, d_model=256, n_heads=8,
        n_kv_heads=2, d_ff=1024, vocab=2048, max_seq_len=512,
        param_dtype="float32", act_dtype="float32", q_chunk=64),
    "100m": ModelConfig(
        name="lm100m", family="dense", n_layers=12, d_model=768, n_heads=12,
        n_kv_heads=4, d_ff=3072, vocab=8192, max_seq_len=1024,
        param_dtype="float32", act_dtype="float32", q_chunk=128),
}


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--preset", choices=sorted(PRESETS), default="5m")
    ap.add_argument("--steps", type=int, default=200)
    ap.add_argument("--workers", type=int, default=4)
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--seq", type=int, default=128)
    ap.add_argument("--H", type=int, default=4)
    ap.add_argument("--k", type=float, default=0.01)
    ap.add_argument("--ckpt", default="artifacts/lm_ckpt")
    args = ap.parse_args()

    cfg = PRESETS[args.preset]
    model = get_model(cfg)
    params = model.init_params(jax.random.PRNGKey(0), cfg)
    n = sum(x.size for x in jax.tree_util.tree_leaves(params))
    print(f"model {cfg.name}: {n / 1e6:.1f}M params, R={args.workers}, "
          f"H={args.H}, SignTopK k={args.k}")

    def grad_fn(p, batch):
        def loss(pp):
            l, _ = model.loss_fn(pp, batch, cfg)
            return l
        return jax.value_and_grad(loss)(p)

    stream = LMTokenStream(vocab=cfg.vocab, R=args.workers, order=64, seed=0)
    eval_batch = next(stream.batches(8, args.seq, 1, seed=999))
    eval_tokens = jnp.asarray(eval_batch["tokens"].reshape(-1, args.seq + 1))

    @jax.jit
    def eval_loss(p):
        l, _ = model.loss_fn(p, {"tokens": eval_tokens}, cfg)
        return l

    lr = warmup_piecewise(0.3, 20, [int(args.steps * 0.7)])
    op = SignSparsifier(k=args.k, m=1)
    run = RunConfig(total_steps=args.steps, R=args.workers, H=args.H,
                    log_every=20, ckpt_dir=args.ckpt,
                    ckpt_every=max(50, args.steps // 4),
                    eval_every=max(20, args.steps // 10))
    t0 = time.time()
    state, hist = train(
        grad_fn, params, momentum_sgd(0.9), op, lr,
        stream.batches(args.batch, args.seq, args.steps, seed=1), run,
        eval_fn=lambda p: {"eval_loss": eval_loss(p)},
    )
    dt = time.time() - t0
    print(f"\nsteps/s: {args.steps / dt:.2f}   total bits: "
          f"{hist.bits[-1]:.3g}  sync rounds: {hist.rounds[-1]}")
    print("train loss trace:", [round(l, 3) for l in hist.loss])
    print("eval:", hist.eval_metrics)
    import math
    uniform = math.log(cfg.vocab)
    assert hist.loss[-1] < uniform - 0.5, "did not learn structure"
    print(f"final loss {hist.loss[-1]:.3f} << uniform {uniform:.3f}  "
          f"(checkpoints in {args.ckpt})")


if __name__ == "__main__":
    main()

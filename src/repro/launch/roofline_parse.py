"""HLO-text collective parsing (importable without touching jax device
state — dryrun.py re-exports it)."""

from __future__ import annotations

import re

_COLLECTIVE_RE = re.compile(
    r"=\s*([a-z0-9]+)\[([\d,]*)\][^=]*?\s"
    r"(all-reduce|all-gather|reduce-scatter|all-to-all|collective-permute)\("
)

_DTYPE_BYTES = {
    "f64": 8, "f32": 4, "bf16": 2, "f16": 2, "s64": 8, "u64": 8,
    "s32": 4, "u32": 4, "s16": 2, "u16": 2, "s8": 1, "u8": 1, "pred": 1,
    "f8e4m3fn": 1, "f8e5m2": 1,
}


def collective_bytes(hlo_text: str) -> dict:
    """Sum output-tensor bytes per collective kind from (per-device
    partitioned) HLO text."""
    out: dict = {}
    for m in _COLLECTIVE_RE.finditer(hlo_text):
        dtype, dims, kind = m.groups()
        nbytes = _DTYPE_BYTES.get(dtype, 4)
        if dims:
            for d in dims.split(","):
                nbytes *= int(d)
        out[kind] = out.get(kind, 0) + nbytes
    out["total"] = sum(v for k, v in out.items() if k != "total")
    return out

"""Production training launcher.

Drives the distributed Qsparse-local-SGD engine (core/distributed.py)
for any assigned architecture on a jax mesh.  On real TPU hardware this
is the per-host entry point (jax.distributed handles multi-host); on
this CPU container it runs with forced host devices for integration
testing:

  XLA_FLAGS=--xla_force_host_platform_device_count=8 \
  PYTHONPATH=src python -m repro.launch.train --arch yi-6b --smoke \
      --mesh 8x1 --steps 20 --H 4

On 0.4.x jax use a TP=1 mesh (e.g. 8x1): a >1 tensor-parallel auto
axis cannot partition the scanned layer stacks inside the partial-
manual region there (see repro/compat.py).  Modern jax takes any mesh.
"""

from __future__ import annotations

import argparse
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.compat import set_mesh
from repro.configs import ARCHS, get_config, get_policy_preset
from repro.core import policy as pol
from repro.core.distributed import (ShardCompressor, make_dist_round,
                                    make_dist_steps)
from repro.core.engine import stack_block
from repro.data import LMTokenStream
from repro.launch.mesh import data_axes, worker_count
from repro.models import get_model
from repro.optim import momentum_sgd, warmup_piecewise
from repro.sharding.specs import activation_policy, param_specs, sanitize_spec
from repro.train import checkpoint


def resolve_policy_arg(args) -> pol.ChannelSpec:
    """One ChannelSpec from the CLI surface (DESIGN.md §6).

    ``--policy`` takes an inline DSL string, ``@file.json`` (a
    ``to_dict()`` serialization) or ``preset:<name>`` /``preset:arch``
    (configs/policies.py).  The legacy ``--compressor``/``--downlink``
    flags map onto the equivalent catch-all policy behind a one-time
    deprecation warning; every name goes through the operator registry,
    so an unknown compressor or downlink fails loudly instead of
    silently meaning identity.
    """
    legacy = (args.compressor is not None or args.downlink is not None
              or args.downlink_k_frac is not None)
    if args.policy is not None:
        if legacy:
            raise SystemExit(
                "--policy conflicts with the deprecated --compressor/"
                "--downlink/--downlink-k-frac flags; put both directions "
                "in the policy ('uplink >> downlink')")
        if args.policy.startswith("preset:"):
            spec = get_policy_preset(args.policy[len("preset:"):],
                                     arch=args.arch)
        else:
            spec = pol.load(args.policy)
        return pol.as_channel_spec(spec)
    if legacy:
        pol.warn_once(
            "launch-legacy-flags",
            "--compressor/--downlink/--downlink-k-frac are deprecated; "
            "use --policy (e.g. --policy 'topk:k=0.01 >> topk:k=0.05')",
            stacklevel=2)
    up_name = args.compressor or "topk"
    up = (pol.PolicySpec.catch_all("identity") if up_name == "none"
          else pol.PolicySpec.catch_all(
              pol.OpSpec(up_name, (("k", args.k_frac),))
              if pol.OpSpec.parse(up_name).takes("k")
              else pol.OpSpec.parse(up_name)))
    down = None
    if args.downlink is not None and args.downlink != "identity":
        dk = (args.downlink_k_frac if args.downlink_k_frac is not None
              else args.k_frac)
        dspec = (pol.OpSpec(args.downlink, (("k", dk),))
                 if pol.OpSpec.parse(args.downlink).takes("k")
                 else pol.OpSpec.parse(args.downlink))
        down = pol.PolicySpec.catch_all(dspec)
    return pol.ChannelSpec(up, down)


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", choices=sorted(ARCHS), required=True)
    ap.add_argument("--smoke", action="store_true",
                    help="reduced config (CPU-sized)")
    ap.add_argument("--mesh", default="4x2",
                    help="DxM or PxDxM device mesh, e.g. 16x16 or 2x16x16")
    ap.add_argument("--steps", type=int, default=20)
    ap.add_argument("--batch", type=int, default=2, help="per-worker batch")
    ap.add_argument("--seq", type=int, default=64)
    ap.add_argument("--H", type=int, default=4)
    ap.add_argument("--k-frac", type=float, default=0.01)
    ap.add_argument("--policy", default=None,
                    help="compression policy (DESIGN.md §6): inline DSL "
                         "('norm->identity;.*->topk:k=0.01', uplink '>>' "
                         "downlink), @file.json, or preset:<name>|"
                         "preset:arch (configs/policies.py)")
    ap.add_argument("--compressor", default=None,
                    choices=["topk", "signtopk", "none"],
                    help="DEPRECATED: use --policy")
    ap.add_argument("--dispatch", default="auto",
                    choices=["auto", "kernel", "reference"],
                    help="compression kernel routing (kernels/dispatch.py): "
                         "auto = fused Pallas Top_k on TPU, reference "
                         "elsewhere")
    ap.add_argument("--aggregate", default="mean_R",
                    choices=["mean_R", "mean_S", "support_weighted",
                             "dense_psum", "sparse_allgather"],
                    help="master division rule over the syncing subset "
                         "(DESIGN.md §8): mean_R (the paper's Σ/R), "
                         "mean_S (Σ/|S|), or support_weighted (per-"
                         "coordinate survivor count).  The legacy wire "
                         "values dense_psum|sparse_allgather are shimmed "
                         "onto --wire with a one-time warning")
    ap.add_argument("--wire", default="dense_psum",
                    choices=["dense_psum", "sparse_allgather"],
                    help="sync transport: dense psum, or compact "
                         "(idx, val) allgather (the sparse wire format)")
    ap.add_argument("--scenario", default=None,
                    help="fleet scenario (core/scenarios.py, DESIGN.md "
                         "§8): 'preset:<name>' (e.g. preset:flaky_fleet) "
                         "or 'k=v,...' (participation=0.8,"
                         "straggler_frac=0.1,seed=3) — generates the "
                         "[T, R] per-worker sync mask; --H is the base "
                         "sync period")
    ap.add_argument("--runtime", default="round",
                    choices=["round", "step"],
                    help="execution runtime (DESIGN.md §7): 'round' "
                         "compiles each sync round (H local steps + "
                         "sync) into one scanned, donated program; "
                         "'step' keeps per-step dispatch.  Identical "
                         "trajectories; 0.4.x TP>1 meshes auto-fall "
                         "back to per-step with a warning")
    ap.add_argument("--overlap", action="store_true",
                    help="overlapped round driver (DESIGN.md §10): "
                         "dispatch windows of consecutive equal-length "
                         "rounds as one scanned multi-round program, "
                         "pipelining each round's sync collective "
                         "against the next round's local compute.  "
                         "Bit-for-bit trajectories; the wire-bits log "
                         "coarsens to window granularity.  Requires "
                         "--runtime round; unsupported with --faults")
    ap.add_argument("--overlap-window", type=int, default=8,
                    help="max rounds per overlapped window "
                         "(power-of-2 chunks)")
    ap.add_argument("--tune", action="store_true",
                    help="autotune the run's compression-kernel launch "
                         "signatures (kernels/autotune.py) before "
                         "training and persist the winning block "
                         "geometry to the per-device tuning table "
                         "(artifacts/tuning/<device>.json); already-"
                         "tuned signatures are reused")
    ap.add_argument("--retune", action="store_true",
                    help="with --tune: re-measure signatures already "
                         "in the tuning table")
    ap.add_argument("--faults", default=None,
                    help="fault-injection spec (core/scenarios.py "
                         "FaultSpec, DESIGN.md §9): 'preset:<name>' "
                         "(e.g. preset:chaos) or 'k=v,...' "
                         "(max_delay=3,drop=0.1,crash_rate=0.02,seed=5) "
                         "— executed staleness: payloads computed at t "
                         "land on the master at t+τ out of per-worker "
                         "in-flight queues, with crash/recover and "
                         "payload drop")
    ap.add_argument("--fault-seed", type=int, default=None,
                    help="override the fault spec's PRNG seed (its own "
                         "stream — never perturbs batch construction)")
    ap.add_argument("--staleness-weight", default="uniform",
                    choices=["uniform", "damped"],
                    help="weighting of delayed payloads at apply time: "
                         "uniform (as computed) or damped (1/(1+τ))")
    ap.add_argument("--downlink", default=None,
                    help="DEPRECATED: use --policy 'up >> down'.  "
                         "Registry operator name for the server→worker "
                         "channel (identity = exact dense broadcast)")
    ap.add_argument("--downlink-k-frac", type=float, default=None,
                    help="DEPRECATED: survivor fraction of the downlink "
                         "channel (default: --k-frac)")
    ap.add_argument("--zero1", action="store_true")
    ap.add_argument("--lr", type=float, default=0.1)
    ap.add_argument("--ckpt", default=None)
    args = ap.parse_args()

    dims = [int(x) for x in args.mesh.split("x")]
    names = ("pod", "data", "model")[-len(dims):]
    mesh = jax.make_mesh(tuple(dims), names)
    daxes = data_axes(mesh)
    R = worker_count(mesh)
    cfg = get_config(args.arch, smoke=args.smoke)
    model = get_model(cfg)
    policy = activation_policy(cfg, for_serving=False, data_axes=daxes)
    specs = param_specs(cfg)

    def grad_fn(params, batch):
        def loss(p):
            l, _ = model.loss_fn(p, batch, cfg, policy)
            return l
        return jax.value_and_grad(loss)(params)

    # params first: the policy resolves per leaf against their paths
    params = model.init_params(jax.random.PRNGKey(0), cfg)
    channel_spec = resolve_policy_arg(args)
    print("policy:", channel_spec.to_string(), flush=True)
    if args.tune:
        from repro.kernels import autotune
        from repro.kernels.dispatch import DispatchConfig
        up_tree, down_tree = channel_spec.resolve(params)
        fresh = autotune.tune_for_run(
            up_tree, params, DispatchConfig(mode=args.dispatch),
            downlink=down_tree, retune=args.retune)
        print(f"tune: {len(fresh)} measured, "
              f"{autotune.tune.last_cached} cached -> "
              f"{autotune.table_path()}", flush=True)
    uplink = ShardCompressor.from_spec(
        channel_spec.uplink, params, dispatch=args.dispatch)
    downlink = None
    if channel_spec.downlink is not None:
        downlink = ShardCompressor.from_spec(
            channel_spec.downlink, params, dispatch=args.dispatch)
    engine_args = (
        grad_fn, momentum_sgd(0.9),
        uplink if uplink is not None
        else ShardCompressor("none", dispatch=args.dispatch),
        warmup_piecewise(args.lr, 5, [int(args.steps * 0.8)]),
        mesh, daxes, specs,
    )
    scenario_mask = None
    if args.scenario is not None:
        from repro.core import scenarios as scn
        scenario = scn.parse(args.scenario)
        scenario_mask = scenario.mask(args.steps, R, H=args.H)
        scn.warn_if_biased(scenario_mask, args.aggregate)
        print(f"scenario: {scenario.to_string() or 'lossless'} "
              f"(participation {scn.participation_of(scenario_mask):.2f}, "
              f"{int(scenario_mask.any(axis=1).sum())} sync steps)",
              flush=True)
    fault_spec = fault_rows = fault_events = None
    if args.faults is not None:
        import dataclasses as _dc

        from repro.core import engine as engine_mod
        from repro.core import scenarios as scn
        if args.zero1:
            raise SystemExit("--faults does not support --zero1 (the "
                             "recover phase needs the full master)")
        if downlink is not None:
            raise SystemExit("--faults does not support a compressed "
                             "downlink on the mesh engine; drop the "
                             "'>> down' half of the policy")
        fault_spec = scn.parse_faults(args.faults)
        if args.fault_seed is not None:
            fault_spec = _dc.replace(fault_spec, seed=int(args.fault_seed))
        base_mask = (scenario_mask if scenario_mask is not None
                     else np.array([(t + 1) % args.H == 0
                                    or t == args.steps - 1
                                    for t in range(args.steps)]))
        fault_tables = fault_spec.tables(args.steps, R)
        fault_rows = engine_mod.fault_rows(base_mask, fault_tables, R)
        _, fault_arrivals, fault_events = scn.fault_replay(
            fault_rows.sync, fault_tables)
        print(f"faults: {fault_spec.to_string() or 'none'} "
              f"(queue depth {fault_spec.depth}, "
              f"{int(fault_arrivals.sum())} arrivals, "
              f"{int((~fault_tables.alive).sum())} crashed worker-steps, "
              f"weighting {args.staleness_weight})", flush=True)
    engine_kw = dict(zero1=args.zero1, aggregate=args.aggregate,
                     downlink=downlink, wire=args.wire,
                     partial=scenario_mask is not None)
    if args.overlap:
        if args.runtime != "round":
            raise SystemExit("--overlap requires --runtime round")
        if args.faults is not None:
            raise SystemExit(
                "--overlap is unsupported with --faults: arrival "
                "events segment rounds dynamically")
    if fault_spec is not None:
        from repro.core.distributed import (make_dist_fault_round,
                                            make_dist_fault_steps)
        fault_kw = dict(queue_depth=fault_spec.depth,
                        aggregate=args.aggregate, wire=args.wire,
                        staleness_weight=args.staleness_weight)
        if args.runtime == "round":
            init_fn, round_fn, fused = make_dist_fault_round(
                *engine_args, **fault_kw)
            print(f"runtime: fault round "
                  f"({'fused' if fused else 'per-step fallback'})",
                  flush=True)
        else:
            init_fn, local_step, sync_step = make_dist_fault_steps(
                *engine_args, **fault_kw)
    elif args.runtime == "round" and args.overlap:
        from repro.core.distributed import make_dist_multiround
        init_fn, multi_fn, fused = make_dist_multiround(
            *engine_args, **engine_kw)
        print(f"runtime: round overlap "
              f"({'fused' if fused else 'per-round fallback'}), "
              f"window {args.overlap_window}", flush=True)
    elif args.runtime == "round":
        init_fn, round_fn, fused = make_dist_round(*engine_args, **engine_kw)
        print(f"runtime: round ({'fused' if fused else 'per-step fallback'})",
              flush=True)
    else:
        init_fn, local_step, sync_step = make_dist_steps(*engine_args,
                                                         **engine_kw)
    from jax.sharding import NamedSharding
    put_specs = jax.tree_util.tree_map(
        lambda leaf, sp: NamedSharding(
            mesh, sanitize_spec(sp, leaf.shape, mesh)),
        params, specs,
        is_leaf=lambda z: hasattr(z, "shape") and not isinstance(z, dict),
    )
    from repro.kernels.dispatch import LAUNCHES, reset_launches

    def make_batch(batch, sub):
        b = {"tokens": jnp.asarray(batch["tokens"])}
        if cfg.modality:
            b["prefix_embeds"] = 0.02 * jax.random.normal(
                sub, (R, args.batch, cfg.n_frontend_tokens, cfg.d_model))
        return b

    def launch_note_once():
        return " ".join(f"{k}={v}" for k, v in LAUNCHES.items() if v) or "none"

    def log_step(t, kind, loss, up, down, note=""):
        print(f"step {t + 1:4d} [{kind}] loss {loss:.4f} "
              f"bits up {up:.3g} down {down:.3g}{note}", flush=True)

    with set_mesh(mesh):
        params = jax.device_put(params, put_specs)
        state = init_fn(params)
        stream = LMTokenStream(vocab=cfg.vocab, R=R, order=64, seed=0)
        key = jax.random.PRNGKey(1)
        t0 = time.time()
        # kernel launches are counted at trace time (launch_stats.py):
        # snapshot after the first sync step traces — with megabuffer
        # packing this shows one launch per operator family per
        # direction per sync round, regardless of leaf count
        reset_launches()
        launch_note = None

        def is_sync_step(t):
            """Scenario runs sync where any worker's mask row fires; the
            fixed schedule keeps the historical every-H + final step.
            Fault runs close at *event* steps — any scheduled sync row
            or any queued-payload arrival (scenarios.fault_replay)."""
            if fault_events is not None:
                return bool(fault_events[t])
            if scenario_mask is not None:
                return bool(scenario_mask[t].any())
            return (t + 1) % args.H == 0 or t == args.steps - 1

        if args.runtime == "round" and args.overlap:
            # overlapped round runtime (DESIGN.md §10): windows of
            # consecutive equal-length rounds run as ONE scanned
            # multi-round program — the sync collective of round w
            # pipelines against round w+1's local compute.  Same key
            # threading as the per-round loop below, so trajectories
            # match; wire-bit logging coarsens to window granularity
            # (interior tail steps show the pre-window totals).
            from repro.core import rounds as rnd_mod
            plans, s0 = [], 0
            for t in range(args.steps):
                if is_sync_step(t) or t == args.steps - 1:
                    tail = (scenario_mask[t] if scenario_mask is not None
                            else np.asarray(is_sync_step(t)))
                    plans.append(rnd_mod.RoundPlan(
                        s0, t - s0 + 1, np.asarray(tail)))
                    s0 = t + 1
            windows = rnd_mod.window_rounds(
                plans, max_window=args.overlap_window)
            batch_iter = stream.batches(args.batch, args.seq, args.steps,
                                        seed=1)
            mirror = key
            for win in windows:
                W, L = len(win), win[0].length
                pending = []
                for _ in range(W * L):
                    mirror, sub = jax.random.split(mirror)
                    pending.append(make_batch(next(batch_iter), sub))
                blocks = jax.tree_util.tree_map(
                    lambda x: x.reshape((W, L) + x.shape[1:]),
                    stack_block(pending))
                prev_up = float(state.bits)
                prev_down = float(state.bits_down)
                if scenario_mask is not None:
                    masks_arr = jnp.asarray(
                        np.stack([np.asarray(p.mask) for p in win]))
                    state, losses, key = multi_fn(state, blocks,
                                                  masks_arr, key)
                else:
                    state, losses, key = multi_fn(state, blocks, key)
                mirror = key
                if launch_note is None:
                    launch_note = launch_note_once()
                losses = np.asarray(losses)
                for wi, plan in enumerate(win):
                    for i in range(L):
                        tail = i == L - 1
                        final = tail and wi == W - 1
                        last_loss = float(losses[wi, i])
                        log_step(
                            plan.start + i,
                            "sync " if tail and is_sync_step(plan.stop - 1)
                            else "local",
                            last_loss,
                            float(state.bits) if final else prev_up,
                            float(state.bits_down) if final else prev_down,
                            f" launches/round [{launch_note}]"
                            if final else "")
        elif args.runtime == "round":
            # round runtime (DESIGN.md §7): accumulate steps until the
            # schedule's next sync, run the block as one program.  The
            # round program splits the PRNG key in-program with the
            # same per-step sequence this host mirror uses for batch
            # construction, so trajectories match --runtime step.
            pending, block_start, mirror = [], 0, key
            for t, batch in enumerate(
                    stream.batches(args.batch, args.seq, args.steps,
                                   seed=1)):
                mirror, sub = jax.random.split(mirror)
                pending.append(make_batch(batch, sub))
                # scenario runs close rounds at any-worker-sync steps
                # (an all-False final flush is legal: the masked tail
                # sync is exactly a local step on every worker)
                if not (is_sync_step(t) or t == args.steps - 1):
                    continue
                block = stack_block(pending)
                prev_up, prev_down = float(state.bits), float(state.bits_down)
                if fault_rows is not None:
                    from repro.core.engine import index_rows
                    rblock = index_rows(fault_rows,
                                        slice(block_start, t + 1))
                    state, losses, key = round_fn(state, block, rblock, key)
                elif scenario_mask is not None:
                    state, losses, key = round_fn(
                        state, block, jnp.asarray(scenario_mask[t]), key)
                else:
                    state, losses, key = round_fn(state, block, key)
                mirror = key
                if launch_note is None:
                    launch_note = launch_note_once()
                losses = np.asarray(losses)
                for i in range(len(pending)):
                    tail = i == len(pending) - 1
                    last_loss = float(losses[i])
                    log_step(
                        block_start + i,
                        "sync " if tail and is_sync_step(t) else "local",
                        last_loss,
                        float(state.bits) if tail else prev_up,
                        float(state.bits_down) if tail else prev_down,
                        f" launches/round [{launch_note}]" if tail else "")
                pending, block_start = [], t + 1
        else:
            ls, ss = jax.jit(local_step), jax.jit(sync_step)
            for t, batch in enumerate(
                    stream.batches(args.batch, args.seq, args.steps,
                                   seed=1)):
                key, sub = jax.random.split(key)
                b = make_batch(batch, sub)
                if is_sync_step(t):
                    if fault_rows is not None:
                        from repro.core.engine import index_rows
                        state, loss = ss(state, b, index_rows(fault_rows, t),
                                         sub)
                    elif scenario_mask is not None:
                        state, loss = ss(state, b, sub,
                                         jnp.asarray(scenario_mask[t]))
                    else:
                        state, loss = ss(state, b, sub)
                    kind = "sync "
                    if launch_note is None:
                        launch_note = launch_note_once()
                    note = f" launches/round [{launch_note}]"
                else:
                    if fault_rows is not None:
                        from repro.core.engine import index_rows
                        state, loss = ls(state, b, index_rows(fault_rows, t),
                                         sub)
                    else:
                        state, loss = ls(state, b, sub)
                    kind = "local"
                    note = ""
                last_loss = float(loss)
                log_step(t, kind, last_loss, float(state.bits),
                         float(state.bits_down), note)
        dt = time.time() - t0
    total = float(state.bits) + float(state.bits_down)
    print(f"\n{args.steps} steps in {dt:.1f}s ({args.steps / dt:.2f} it/s); "
          f"R={R} workers, {int(state.rounds)} sync rounds, "
          f"{float(state.bits):.3g} uplink + {float(state.bits_down):.3g} "
          f"downlink = {total:.3g} wire bits")
    assert np.isfinite(last_loss)
    if args.ckpt:
        # persist the policy spec so a resume reproduces the exact
        # per-leaf operators (and hence the bits trajectories)
        checkpoint.save(args.ckpt, state.master, step=args.steps,
                        policy=channel_spec.to_dict())
        print("checkpoint saved to", args.ckpt)


if __name__ == "__main__":
    main()

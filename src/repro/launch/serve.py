"""Production serving launcher: batched prefill + decode on a mesh.

  XLA_FLAGS=--xla_force_host_platform_device_count=8 \
  PYTHONPATH=src python -m repro.launch.serve --arch qwen3-moe-30b-a3b \
      --smoke --mesh 4x2 --batch 8 --prompt-len 32 --new-tokens 16
"""

from __future__ import annotations

import argparse
import time

import jax
import jax.numpy as jnp

from repro.compat import set_mesh
from repro.configs import ARCHS, get_config
from repro.launch.mesh import data_axes
from repro.models import get_model
from repro.sharding.specs import activation_policy, param_specs, sanitize_spec


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", choices=sorted(ARCHS), required=True)
    ap.add_argument("--smoke", action="store_true")
    ap.add_argument("--mesh", default="4x2")
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--prompt-len", type=int, default=32)
    ap.add_argument("--new-tokens", type=int, default=16)
    args = ap.parse_args()

    dims = [int(x) for x in args.mesh.split("x")]
    names = ("pod", "data", "model")[-len(dims):]
    mesh = jax.make_mesh(tuple(dims), names)
    daxes = data_axes(mesh)
    cfg = get_config(args.arch, smoke=args.smoke)
    model = get_model(cfg)
    policy = activation_policy(cfg, for_serving=True, data_axes=daxes)

    from jax.sharding import NamedSharding
    params = model.init_params(jax.random.PRNGKey(0), cfg)
    specs = param_specs(cfg)
    put = jax.tree_util.tree_map(
        lambda leaf, sp: NamedSharding(mesh,
                                       sanitize_spec(sp, leaf.shape, mesh)),
        params, specs,
        is_leaf=lambda z: hasattr(z, "shape") and not isinstance(z, dict),
    )
    B, S = args.batch, args.prompt_len
    prompts = jax.random.randint(jax.random.PRNGKey(1), (B, S), 0, cfg.vocab)
    batch = {"tokens": prompts}
    if cfg.modality:
        batch["prefix_embeds"] = 0.02 * jax.random.normal(
            jax.random.PRNGKey(2), (B, cfg.n_frontend_tokens, cfg.d_model))
    max_len = S + args.new_tokens + (cfg.n_frontend_tokens if cfg.modality else 0)

    with set_mesh(mesh):
        params = jax.device_put(params, put)
        t0 = time.time()
        logits, cache, n = jax.jit(
            lambda p, b: model.prefill(p, b, cfg, policy, max_len=max_len)
        )(params, batch)
        jax.block_until_ready(logits)
        t_prefill = time.time() - t0
        decode = jax.jit(
            lambda p, c, tok, pos: model.decode_step(p, c, tok, pos, cfg,
                                                     policy))
        tok = jnp.argmax(logits.reshape(B, -1)[:, :cfg.vocab], -1) \
            .astype(jnp.int32)
        pos0 = S + (cfg.n_frontend_tokens if cfg.modality else 0)
        outs = []
        t0 = time.time()
        for i in range(args.new_tokens):
            outs.append(tok)
            lg, cache = decode(params, cache, tok, pos0 + i)
            tok = jnp.argmax(lg, -1).astype(jnp.int32)
        jax.block_until_ready(tok)
        t_decode = time.time() - t0
    print(f"arch={args.arch} mesh={args.mesh} batch={B}")
    print(f"prefill {S}tok: {t_prefill * 1e3:.0f} ms; decode: "
          f"{t_decode / args.new_tokens * 1e3:.1f} ms/tok")
    gen = jnp.stack(outs, 1)
    print("sample:", list(map(int, gen[0, :10])))


if __name__ == "__main__":
    main()

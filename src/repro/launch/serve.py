"""Serving launcher: compressed checkpoints through the
continuous-batching engine (DESIGN.md §11).

Loads real trained checkpoints — a compact serving checkpoint
(``checkpoint.save_compact``) is consumed directly in compressed form;
a dense training checkpoint is restored and, under ``--compressed``,
compressed once at load time with the policy spec persisted in its own
manifest (``--policy`` overrides).  The request runtime is
``serve.engine.ServeEngine``: admission queue, prefill/decode
interleave, slot reuse, per-request metrics.

  # train then serve the smoke model compressed:
  PYTHONPATH=src python -m repro.launch.train --arch yi-6b --smoke \
      --steps 4 --ckpt /tmp/ck
  PYTHONPATH=src python -m repro.launch.serve --arch yi-6b --smoke \
      --checkpoint /tmp/ck --compressed --scheduler continuous
"""

from __future__ import annotations

import argparse
import json

import jax
import numpy as np

from repro.configs import ARCHS, get_config
from repro.configs.policies import get_policy_preset
from repro.models import get_model
from repro.serve import compressed as sc
from repro.serve.engine import ServeEngine
from repro.train import checkpoint as ckpt


def resolve_policy(arg: str | None, checkpoint_path: str | None,
                   arch: str | None):
    """Policy used for --compressed: explicit --policy (DSL string,
    @file.json, or preset:<name>|preset:arch) wins; otherwise the spec
    persisted in the checkpoint manifest; otherwise the arch preset."""
    from repro.core import policy as pol
    if arg:
        if arg.startswith("preset:"):
            return get_policy_preset(arg[len("preset:"):], arch)
        return pol.load(arg)
    if checkpoint_path:
        spec = ckpt.load_policy(checkpoint_path)
        if spec is not None:
            return spec
    return get_policy_preset("arch", arch)


def load_params(args, cfg, model):
    """(params, source) — compact checkpoints stay compressed; dense
    checkpoints restore into the model structure and optionally
    compress once at load."""
    if args.checkpoint and ckpt.is_compact(args.checkpoint):
        return ckpt.load_compact(args.checkpoint), "compact checkpoint"
    if args.checkpoint:
        like = model.init_params(jax.random.PRNGKey(0), cfg)
        params = ckpt.restore(args.checkpoint, like)
        src = "dense checkpoint"
    else:
        params = model.init_params(jax.random.PRNGKey(0), cfg)
        src = "random init (no --checkpoint)"
    if args.compressed:
        if cfg.family != "dense":
            raise SystemExit(
                f"--compressed serves the dense transformer family only "
                f"(arch {args.arch} is family={cfg.family!r})")
        policy = resolve_policy(args.policy, args.checkpoint, args.arch)
        params = sc.compress_tree(params, policy)
        src += " -> compressed at load"
    return params, src


def main(argv=None):
    ap = argparse.ArgumentParser(
        description="serve a (compressed) checkpoint with continuous "
                    "batching")
    ap.add_argument("--arch", choices=sorted(ARCHS), required=True)
    ap.add_argument("--smoke", action="store_true")
    ap.add_argument("--checkpoint", default=None,
                    help="dense (train --ckpt) or compact "
                         "(save_compact) checkpoint directory")
    ap.add_argument("--compressed", action="store_true",
                    help="serve from compressed weights (policy-guided "
                         "one-shot compression for dense checkpoints)")
    ap.add_argument("--policy", default=None,
                    help="compression policy override: DSL string, "
                         "@file.json, preset:<name> or preset:arch "
                         "(default: the checkpoint's persisted spec)")
    ap.add_argument("--scheduler", choices=("continuous", "static"),
                    default="continuous")
    ap.add_argument("--max-batch", type=int, default=4,
                    help="decode slots (continuous-batching width)")
    ap.add_argument("--max-len", type=int, default=64)
    ap.add_argument("--prompt-len", type=int, default=16,
                    help="synthetic prompt length cap (also the static "
                         "prefill pad)")
    ap.add_argument("--new-tokens", type=int, default=8)
    ap.add_argument("--requests", type=int, default=8,
                    help="synthetic request count")
    ap.add_argument("--flash", action="store_true",
                    help="route decode attention through the Pallas "
                         "flash-decode kernel (paged: the paged kernel)")
    ap.add_argument("--paged", action="store_true",
                    help="shared KV page pool + block tables instead of "
                         "per-slot contiguous caches (DESIGN.md §12)")
    ap.add_argument("--page-size", type=int, default=16,
                    help="tokens per KV page (--paged)")
    ap.add_argument("--kv-quant", action="store_true",
                    help="int8 pages + per-token-slot f32 scales "
                         "(--paged; 4x KV HBM at f32)")
    ap.add_argument("--kv-pool-pages", type=int, default=None,
                    help="total pool pages (default: max-batch * "
                         "ceil(max-len / page-size), the contiguous "
                         "layout's HBM equivalent)")
    ap.add_argument("--dispatch", choices=("auto", "kernel", "reference"),
                    default="auto",
                    help="compressed-GEMM dispatch mode (kernel uses "
                         "interpret off-TPU)")
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--json", default=None,
                    help="write the metrics summary to this file")
    args = ap.parse_args(argv)

    cfg = get_config(args.arch, smoke=args.smoke)
    if args.flash:
        import dataclasses
        cfg = dataclasses.replace(cfg, use_pallas=True)
    if cfg.family != "dense":
        raise SystemExit(
            f"the serving engine drives the dense transformer family "
            f"(arch {args.arch} is family={cfg.family!r})")
    model = get_model(cfg)
    from repro.kernels.dispatch import DispatchConfig
    sc.set_dispatch(DispatchConfig(mode=args.dispatch))

    params, source = load_params(args, cfg, model)
    sc.reset_stats()
    sizes = sc.tree_bytes(params)
    print(f"arch={args.arch} source: {source}")
    print(f"resident params: {sizes['compressed'] / 1e6:.2f} MB "
          f"(dense equivalent {sizes['dense'] / 1e6:.2f} MB, "
          f"{sizes['leaves']} leaves)")

    if args.kv_quant and not args.paged:
        raise SystemExit("--kv-quant requires --paged")
    eng = ServeEngine(params, cfg, max_batch=args.max_batch,
                      max_len=args.max_len, prompt_pad=args.prompt_len,
                      scheduler=args.scheduler, paged=args.paged,
                      page_size=args.page_size, kv_quant=args.kv_quant,
                      kv_pool_pages=args.kv_pool_pages)
    rng = np.random.RandomState(args.seed)
    for _ in range(args.requests):
        plen = int(rng.randint(max(2, args.prompt_len // 2),
                               args.prompt_len + 1))
        eng.submit(rng.randint(0, cfg.vocab, plen).tolist(),
                   max_new_tokens=args.new_tokens)
    res = eng.run()

    mets = sorted(res["metrics"].values(), key=lambda m: m.rid)
    print(f"\nscheduler={args.scheduler} slots={args.max_batch} "
          f"requests={len(mets)} steps={res['steps']}")
    print(" rid  plen  new   wait_ms   ttft_ms    tok/s")
    for m in mets:
        print(f"{m.rid:4d} {m.prompt_len:5d} {m.new_tokens:4d} "
              f"{m.queue_wait_s * 1e3:9.1f} {m.ttft_s * 1e3:9.1f} "
              f"{m.tokens_per_s:8.1f}")
    print(f"\naggregate: {res['requests_per_s']:.2f} req/s, "
          f"{res['tokens_per_s']:.1f} tok/s, "
          f"wall {res['wall_s']:.2f}s, peak occupancy "
          f"{max(eng.occupancy) if eng.occupancy else 0}/{args.max_batch}")
    if args.paged:
        pool = res["pool"]
        print(f"page pool: {pool['peak_pages_used']}/{pool['n_pages']} "
              f"peak pages ({pool['page_size']} tok/page, "
              f"{'int8' if pool['kv_quant'] else 'fp'} layout), "
              f"{pool['pages_used']} in use at exit")
        print(f"  preemptions={pool['preemptions']} "
              f"admission_stalls={pool['admission_stalls']} "
              f"fragmentation={pool['fragmentation']:.4f}")
    print(f"serve stats: {sc.STATS}")
    if args.compressed and sc.STATS["densify"]:
        raise SystemExit("zero-densify violated: the serving path "
                         f"densified {sc.STATS['densify']} leaves")
    if args.json:
        with open(args.json, "w") as f:
            payload = {
                "requests_per_s": res["requests_per_s"],
                "tokens_per_s": res["tokens_per_s"],
                "steps": res["steps"],
                "densify": sc.STATS["densify"],
            }
            if args.paged:
                payload["pool"] = res["pool"]
            json.dump(payload, f, indent=2)
    sample = res["outputs"].get(0, [])[:10]
    print("sample:", sample)


if __name__ == "__main__":
    main()

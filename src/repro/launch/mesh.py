"""Production mesh construction.

A FUNCTION, not a module-level constant — importing this module never
touches jax device state (required for the smoke tests, which must see
one CPU device, vs the dry-run, which forces 512 host devices *before*
jax init).
"""

from __future__ import annotations

import jax


def make_production_mesh(*, multi_pod: bool = False):
    """v5e production mesh: 16x16 = 256 chips per pod; 2 pods = 512."""
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(shape, axes)


def make_debug_mesh(data: int = 2, model: int = 2):
    """Small mesh for CPU integration tests (requires
    XLA_FLAGS=--xla_force_host_platform_device_count>=data*model)."""
    return jax.make_mesh((data, model), ("data", "model"))


def data_axes(mesh) -> tuple[str, ...]:
    return tuple(a for a in mesh.axis_names if a in ("pod", "data"))


def worker_count(mesh) -> int:
    out = 1
    for a in data_axes(mesh):
        out *= mesh.shape[a]
    return out

import os
os.environ["XLA_FLAGS"] = (
    "--xla_force_host_platform_device_count=512 "
    + os.environ.get("XLA_FLAGS", "")
)

"""Multi-pod dry-run: prove every (architecture x input shape x mesh)
combination lowers and compiles for the production mesh, and extract the
roofline terms from the compiled artifact.

The two lines above MUST run before any other import (jax locks the
device count at first init).

Usage:
    PYTHONPATH=src python -m repro.launch.dryrun --arch yi-6b --shape train_4k
    PYTHONPATH=src python -m repro.launch.dryrun --all [--multi-pod]
    PYTHONPATH=src python -m repro.launch.dryrun --all --both-meshes

Artifacts: artifacts/dryrun/<arch>__<shape>__<mesh>.json with
memory_analysis, cost_analysis, per-collective byte totals and the
derived roofline terms (see benchmarks/roofline.py for the report).
"""

import argparse
import json
import time
import traceback

import jax
import jax.numpy as jnp
import numpy as np

from repro.compat import set_mesh
from jax.sharding import NamedSharding, PartitionSpec as P

from repro.configs import (
    ARCHS,
    INPUT_SHAPES,
    get_config,
    shape_supported,
)
from repro.core.distributed import ShardCompressor, make_dist_steps
from repro.launch.mesh import data_axes, make_production_mesh, worker_count
from repro.models import get_model
from repro.optim import constant, momentum_sgd
from repro.sharding.specs import (activation_policy, param_specs,
                                  sanitize_spec)

ART_DIR = "artifacts/dryrun"

# v5e hardware constants (roofline denominators)
PEAK_FLOPS = 197e12          # bf16 per chip
HBM_BW = 819e9               # bytes/s per chip
ICI_BW = 50e9                # bytes/s per link

from repro.launch.roofline_parse import collective_bytes  # noqa: E402


# ---------------------------------------------------------------------------
# abstract inputs
# ---------------------------------------------------------------------------


def _sds(shape, dtype, mesh=None, spec=None):
    if mesh is None:
        return jax.ShapeDtypeStruct(shape, dtype)
    return jax.ShapeDtypeStruct(shape, dtype,
                                sharding=NamedSharding(mesh, spec or P()))


def _combine_first(spec, daxes):
    """Prefix a spec's axis-0 entry with the data axes (ZeRO/worker dim)."""
    entries = tuple(spec) if spec is not None else ()
    first = entries[0] if entries else None
    rest = entries[1:] if entries else ()
    if first is None:
        return P(tuple(daxes), *rest)
    firsts = first if isinstance(first, tuple) else (first,)
    return P(tuple(daxes) + tuple(firsts), *rest)


def abstract_params(cfg, mesh, model):
    """ShapeDtypeStructs for params with their NamedShardings."""
    sds = jax.eval_shape(lambda: model.init_params(jax.random.PRNGKey(0), cfg))
    specs = param_specs(cfg)
    is_spec = lambda z: isinstance(z, P) or z is None

    def attach(s, spec):
        return _sds(s.shape, s.dtype, mesh, sanitize_spec(spec, s.shape, mesh))

    return jax.tree_util.tree_map(attach, sds, specs, is_leaf=lambda z: False), specs


def input_specs(cfg, shape_name: str, mesh, *, for_train: bool):
    """Abstract inputs for the given shape.  Training batches carry a
    leading worker axis [R, b, ...]; serving batches are [B, ...]."""
    sh = INPUT_SHAPES[shape_name]
    daxes = data_axes(mesh)
    if for_train:
        R = worker_count(mesh)
        b = max(1, sh.global_batch // R)
        batch = {"tokens": _sds((R, b, sh.seq_len + 1), jnp.int32, mesh,
                                P(tuple(daxes)))}
        if cfg.modality:
            batch["prefix_embeds"] = _sds(
                (R, b, cfg.n_frontend_tokens, cfg.d_model), cfg.adtype,
                mesh, P(tuple(daxes)))
        return batch
    B = sh.global_batch
    bspec = tuple(daxes) if B % max(worker_count(mesh), 1) == 0 else None
    batch = {"tokens": _sds((B, sh.seq_len), jnp.int32, mesh,
                            P(bspec))}
    if cfg.modality:
        batch["prefix_embeds"] = _sds(
            (B, cfg.n_frontend_tokens, cfg.d_model), cfg.adtype, mesh,
            P(bspec))
    return batch


def cache_shardings(cfg, cache_sds, mesh, batch_size: int):
    """Heuristic NamedShardings for decode caches: batch dim over the
    data axes (when divisible), largest model-divisible dim over 'model'."""
    daxes = data_axes(mesh)
    n_data = worker_count(mesh)
    n_model = mesh.shape["model"]

    def leaf(s):
        entries = [None] * len(s.shape)
        used_batch = used_model = False
        for ax, n in enumerate(s.shape):
            if not used_batch and n == batch_size and batch_size % n_data == 0:
                entries[ax] = tuple(daxes)
                used_batch = True
                break
        # biggest remaining axis divisible by model size
        best, best_ax = 0, None
        for ax, n in enumerate(s.shape):
            if entries[ax] is None and n % n_model == 0 and n > best and n >= n_model:
                best, best_ax = n, ax
        if best_ax is not None:
            entries[best_ax] = "model"
        return _sds(s.shape, s.dtype, mesh, P(*entries))

    return jax.tree_util.tree_map(leaf, cache_sds)


# ---------------------------------------------------------------------------
# lowering paths
# ---------------------------------------------------------------------------


def lower_train(cfg, mesh, *, zero1: bool = False, compressor_mode: str = "topk",
                k_frac: float = 0.01, seq_shard: bool = True,
                aggregate: str = "dense_psum"):
    """Lower + compile the Qsparse sync_step (the communication-bearing
    step) and the local step."""
    daxes = data_axes(mesh)
    model = get_model(cfg)
    policy = activation_policy(cfg, for_serving=False, data_axes=daxes,
                               seq_shard=seq_shard)

    def grad_fn(params, batch):
        def loss(p):
            l, _ = model.loss_fn(p, batch, cfg, policy)
            return l
        return jax.value_and_grad(loss)(params)

    specs = param_specs(cfg)
    init_fn, local_step, sync_step = make_dist_steps(
        grad_fn, momentum_sgd(0.9), ShardCompressor(compressor_mode, k_frac),
        constant(1e-3), mesh, daxes, specs, zero1=zero1,
        wire=aggregate,
    )
    params_sds, _ = abstract_params(cfg, mesh, model)
    state_sds = jax.eval_shape(init_fn, params_sds)
    # attach shardings to the state tree
    is_spec = lambda z: isinstance(z, P) or z is None

    def master_shard(s, spec):
        from repro.core.distributed import _zero1_axis
        spec = sanitize_spec(spec, s.shape, mesh)
        if zero1:
            R = worker_count(mesh)
            ax = _zero1_axis(s.shape, spec, R)
            if ax is not None:
                entries = list(spec) + [None] * (len(s.shape) - len(tuple(spec)))
                entries[ax] = tuple(daxes)
                spec = P(*entries)
        return _sds(s.shape, s.dtype, mesh, spec)

    def worker_shard(s, spec):
        entries = tuple(sanitize_spec(spec, s.shape[1:], mesh))
        return _sds(s.shape, s.dtype, mesh, P(tuple(daxes), *entries))

    def tmap(fn, tree, specs_tree):
        flat_s, treedef = jax.tree_util.tree_flatten(tree)
        flat_spec = jax.tree_util.tree_leaves(specs_tree, is_leaf=is_spec)
        if len(flat_spec) != len(flat_s):
            # inner-opt state may nest params-like trees (e.g. momentum "mu")
            reps = len(flat_s) // len(flat_spec)
            flat_spec = flat_spec * reps
        return jax.tree_util.tree_unflatten(
            treedef, [fn(s, sp) for s, sp in zip(flat_s, flat_spec)]
        )

    from repro.core.distributed import DistQsparseState
    state_sharded = DistQsparseState(
        master=tmap(master_shard, state_sds.master, specs),
        local=tmap(worker_shard, state_sds.local, specs),
        memory=tmap(worker_shard, state_sds.memory, specs),
        inner=tmap(worker_shard, state_sds.inner, specs),
        step=_sds((), jnp.int32, mesh, P()),
        bits=_sds((), jnp.float32, mesh, P()),
        rounds=_sds((), jnp.int32, mesh, P()),
    )
    batch_sds = input_specs(cfg, _CUR_SHAPE[0], mesh, for_train=True)
    key_sds = _sds((2,), jnp.uint32, mesh, P())
    results = {}
    for name, fn in (("sync_step", sync_step), ("local_step", local_step)):
        with set_mesh(mesh):
            # donate the state: steady-state training aliases the Qsparse
            # state buffers in place (alias_bytes in memory_analysis)
            lowered = jax.jit(fn, donate_argnums=(0,)).lower(
                state_sharded, batch_sds, key_sds)
            results[name] = lowered
    return results


def lower_serve(cfg, mesh, shape_name: str):
    """Lower + compile prefill (prefill_32k) or one decode step
    (decode_32k / long_500k)."""
    sh = INPUT_SHAPES[shape_name]
    daxes = data_axes(mesh)
    model = get_model(cfg)
    policy = activation_policy(cfg, for_serving=True, data_axes=daxes)
    params_sds, _ = abstract_params(cfg, mesh, model)
    results = {}
    if sh.kind == "prefill":
        batch_sds = input_specs(cfg, shape_name, mesh, for_train=False)

        def prefill_fn(params, batch):
            return model.prefill(params, batch, cfg, policy,
                                 max_len=sh.seq_len)

        with set_mesh(mesh):
            results["prefill"] = jax.jit(prefill_fn).lower(params_sds, batch_sds)
        return results
    # decode: one new token against a seq_len cache
    B = sh.global_batch
    cache_sds = jax.eval_shape(
        lambda: model.init_cache(cfg, B, sh.seq_len))
    cache_sharded = cache_shardings(cfg, cache_sds, mesh, B)
    bspec = tuple(daxes) if B % worker_count(mesh) == 0 else None
    token_sds = _sds((B,), jnp.int32, mesh, P(bspec))

    def decode_fn(params, cache, token):
        return model.decode_step(params, cache, token, sh.seq_len - 1, cfg,
                                 policy)

    with set_mesh(mesh):
        results["decode"] = jax.jit(decode_fn).lower(
            params_sds, cache_sharded, token_sds)
    return results


# ---------------------------------------------------------------------------
# driver
# ---------------------------------------------------------------------------

_CUR_SHAPE = ["train_4k"]


def run_one(arch: str, shape_name: str, *, multi_pod: bool = False,
            zero1: bool = False, compressor: str = "topk",
            seq_shard: bool = True, tag: str = "",
            smoke: bool = False, mesh=None, shape_override=None,
            aggregate: str = "dense_psum", cfg_overrides=None) -> dict:
    ok, reason = shape_supported(arch, shape_name)
    mesh_name = "pod2x16x16" if multi_pod else "pod16x16"
    record = {
        "arch": arch, "shape": shape_name, "mesh": mesh_name,
        "zero1": zero1, "compressor": compressor, "seq_shard": seq_shard,
        "aggregate": aggregate, "tag": tag,
        "status": "skipped", "reason": reason,
    }
    if not ok:
        return record
    _CUR_SHAPE[0] = shape_name
    sh = shape_override or INPUT_SHAPES[shape_name]
    if shape_override is not None:
        INPUT_SHAPES[shape_name] = shape_override
    kw = {}
    if arch == "zamba2-7b" and shape_name == "long_500k" and not smoke:
        kw["long_context"] = True
    cfg = get_config(arch, smoke=smoke, **kw)
    if smoke and arch == "zamba2-7b" and shape_name == "long_500k":
        cfg = __import__("dataclasses").replace(cfg, swa_pattern=(64,))
    if cfg_overrides:
        cfg = __import__("dataclasses").replace(cfg, **cfg_overrides)
    if mesh is None:
        mesh = make_production_mesh(multi_pod=multi_pod)
    t0 = time.time()
    try:
        if sh.kind == "train":
            lowered = lower_train(cfg, mesh, zero1=zero1,
                                  compressor_mode=compressor,
                                  seq_shard=seq_shard, aggregate=aggregate)
        else:
            lowered = lower_serve(cfg, mesh, shape_name)
        record["lower_s"] = round(time.time() - t0, 1)
        record["steps"] = {}
        for name, low in lowered.items():
            t1 = time.time()
            compiled = low.compile()
            mem = compiled.memory_analysis()
            ca = compiled.cost_analysis() or {}
            if isinstance(ca, (list, tuple)):  # 0.4.x: list of one dict
                ca = ca[0] if ca else {}
            coll = collective_bytes(compiled.as_text())
            record["steps"][name] = {
                "compile_s": round(time.time() - t1, 1),
                "memory": {
                    "argument_bytes": int(mem.argument_size_in_bytes),
                    "output_bytes": int(mem.output_size_in_bytes),
                    "temp_bytes": int(mem.temp_size_in_bytes),
                    "alias_bytes": int(mem.alias_size_in_bytes),
                    "code_bytes": int(mem.generated_code_size_in_bytes),
                },
                "flops": float(ca.get("flops", -1)),
                "bytes_accessed": float(ca.get("bytes accessed", -1)),
                "collectives": coll,
            }
        record["status"] = "ok"
        record["params"] = cfg.param_count()
        record["active_params"] = cfg.active_param_count()
        record["n_devices"] = int(np.prod(list(mesh.shape.values())))
        record["model_axis"] = mesh.shape["model"]
        record["n_workers"] = worker_count(mesh)
        record["seq_len"] = sh.seq_len
        record["global_batch"] = sh.global_batch
        record["kind"] = sh.kind
    except Exception as e:  # noqa: BLE001 - report every failure mode
        record["status"] = "error"
        record["error"] = f"{type(e).__name__}: {e}"
        record["traceback"] = traceback.format_exc()[-4000:]
    return record


def save_record(record: dict, tag: str = "") -> str:
    os.makedirs(ART_DIR, exist_ok=True)
    suffix = f"__{tag}" if tag else ""
    fn = (f"{ART_DIR}/{record['arch']}__{record['shape']}"
          f"__{record['mesh']}{suffix}.json")
    with open(fn, "w") as f:
        json.dump(record, f, indent=2)
    return fn


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", choices=sorted(ARCHS), default=None)
    ap.add_argument("--shape", choices=sorted(INPUT_SHAPES), default=None)
    ap.add_argument("--all", action="store_true")
    ap.add_argument("--multi-pod", action="store_true")
    ap.add_argument("--both-meshes", action="store_true")
    ap.add_argument("--zero1", action="store_true")
    ap.add_argument("--compressor", default="topk",
                    choices=["topk", "signtopk", "none"])
    ap.add_argument("--no-seq-shard", action="store_true")
    ap.add_argument("--aggregate", default="dense_psum",
                    choices=["dense_psum", "sparse_allgather"])
    ap.add_argument("--tag", default="")
    ap.add_argument("--smoke", action="store_true",
                    help="reduced configs through the same lowering path")
    args = ap.parse_args()

    combos = []
    archs = sorted(ARCHS) if (args.all or not args.arch) else [args.arch]
    shapes = sorted(INPUT_SHAPES) if (args.all or not args.shape) else [args.shape]
    meshes = [False, True] if args.both_meshes else [args.multi_pod]
    for a in archs:
        for s in shapes:
            for mp in meshes:
                combos.append((a, s, mp))

    failures = 0
    for a, s, mp in combos:
        rec = run_one(a, s, multi_pod=mp, zero1=args.zero1,
                      compressor=args.compressor,
                      seq_shard=not args.no_seq_shard, tag=args.tag,
                      smoke=args.smoke, aggregate=args.aggregate)
        fn = save_record(rec, tag=args.tag)
        status = rec["status"]
        extra = ""
        if status == "ok":
            st = next(iter(rec["steps"].values()))
            extra = (f"flops={st['flops']:.3g} "
                     f"temp={st['memory']['temp_bytes']/2**30:.2f}GiB "
                     f"coll={st['collectives']['total']/2**20:.1f}MiB")
        elif status == "error":
            failures += 1
            extra = rec["error"][:160]
        print(f"[{status:7s}] {a} x {s} x "
              f"{'2x16x16' if mp else '16x16'}  {extra}", flush=True)
    if failures:
        raise SystemExit(f"{failures} dry-run failures")


if __name__ == "__main__":
    main()

"""Operator → Pallas-kernel dispatch for the unified Qsparse engine.

The engine (core/engine.py) compresses the error-compensated
accumulator ``m + x - x̂`` once per sync round; on production shapes
that is the per-round hot spot.  This module maps ``CompressionOp``
instances to the fused Pallas kernels when shape/dtype/platform allow,
and falls back *transparently* to the dense reference operators in
``core/operators.py`` otherwise — same dense output, same wire-bit
accounting, so callers never see which path ran (except through
:func:`would_dispatch`, used by tests and benchmarks).

Three entry layers (see DESIGN.md §3.2-§3.4):

  * :func:`compress_tree` / :func:`channel_compress_tree` — the
    engine's per-round entries.  With ``pack=True`` (default)
    same-operator leaves are packed into one padded ``[rows, n]``
    megabuffer per (row length, k, sign) bucket — lane-aligned,
    zero-padded — so a whole pytree costs **one kernel launch per
    operator family** instead of one per leaf.  The kernels are
    row-independent, so packing is output-identical to the leaf-by-leaf
    path.  The channel form (uplink *and* downlink, DESIGN.md §5)
    additionally returns the updated error memory — fused from the
    kernel for Top_k leaves, ``acc − q`` elsewhere.
  * :func:`compress_leaf` / :func:`compact_compress` — per-leaf dense /
    compact form.  The compact form returns ``(idx, val)`` survivor
    buffers plus the fused error memory (the sparse wire format of
    ``aggregate="sparse_allgather"``, DESIGN.md §3.3).
  * :func:`topk_rows` / :func:`compact_rows` — raw pre-shaped row
    entries for the distributed shard compressor.

Dispatch rules (see DESIGN.md §3.2):

  ========================  =======================================
  operator                  kernel
  ========================  =======================================
  ``TopK``                  ``topk_compress`` on a single padded row
  ``RowTopK``               ``topk_compress``, one row per block-row
  ``SignSparsifier`` (top,  ``topk_compress(sign=True)`` single row
  m=2)
  ``RowSignTopK`` (m=2)     ``topk_compress(sign=True)`` per row
  ``QSGDQuantizer``         ``qsgd`` single bucket, external uniforms
  ========================  =======================================

The Top_k family additionally supports the compact emission mode
(``topk_compact``) with the scatter-free jnp oracle as its transparent
reference fallback.  Everything else (RandK, Sign, k-level, the
composed quantized sparsifiers, SignTopK with the L1 scale) runs the
reference operator.

Eligibility (``mode="auto"``): the backend is TPU (off-TPU the kernels
only exist in interpret mode, which is for validation, not speed), the
leaf has at least ``min_size`` elements, rows are lane-aligned (128)
and a row fits the VMEM budget (``max_row``).  ``mode="kernel"``
forces the kernel path (interpret off-TPU) for parity tests and
benchmarks; ``mode="reference"`` disables dispatch entirely.
"""

from __future__ import annotations

import dataclasses
import functools
from typing import Callable, NamedTuple, Optional

import jax
import jax.numpy as jnp

from repro.core import bits as bitlib
from repro.core.operators import (
    CompressionOp,
    QSGDQuantizer,
    RowSignTopK,
    RowTopK,
    SignSparsifier,
    TopK,
    ops_for_leaves,
    resolve_k,
)
from repro.kernels import qsgd as _qsgd
from repro.kernels import sparse_gemm as _sgemm
from repro.kernels import topk_compress as _topk
from repro.kernels.launch_stats import (  # noqa: F401 — re-exported
    LAUNCHES, reset_launches, total_launches,
)

LANES = 128  # TPU vector lane width: kernel rows are padded to this

#: the historical fixed grid geometry — the fallback when a shape has
#: no tuning-table entry and ``block_rows`` is on auto (None)
DEFAULT_BLOCK_ROWS = 8
DEFAULT_CHUNK = 128


@dataclasses.dataclass(frozen=True)
class DispatchConfig:
    """Where and when compression runs through the Pallas kernels.

    mode: "auto"      — kernels on TPU, references elsewhere (default)
          "kernel"    — force the kernel path (interpret mode off-TPU);
                        bypasses min_size but not structural limits
          "reference" — never dispatch (pure core/operators.py)
    min_size: smallest leaf (elements) worth a kernel launch in "auto"
    max_row:  longest kernel row (elements); bounds VMEM residency —
              3 f32 blocks of (block_rows, max_row) must fit in ~16 MB
    max_cap:  largest compact survivor capacity (elements per row) the
              compact kernel accepts; bounds the (block_rows, chunk,
              kcap) one-hot intermediate of the slot scatter
    block_rows: grid block height handed to the kernels.  ``None``
          (default) resolves per launch signature through the autotune
          table (kernels/autotune.py; LRU → persisted per-device table
          → ``DEFAULT_BLOCK_ROWS``), so untuned shapes behave exactly
          like the historical fixed geometry; an explicit int always
          wins over the table.  The kernels are row-independent, so the
          choice changes timing only — outputs are bit-identical.
    pack: megabuffer-pack same-bucket leaves in compress_tree (one
          kernel launch per operator family per sync round)
    interpret: None — auto (interpret off-TPU); bool to force
    """

    mode: str = "auto"
    min_size: int = 1 << 16
    max_row: int = 1 << 19
    max_cap: int = 1 << 11
    block_rows: Optional[int] = None
    pack: bool = True
    interpret: Optional[bool] = None

    def __post_init__(self):
        if self.mode not in ("auto", "kernel", "reference"):
            raise ValueError(f"unknown dispatch mode {self.mode!r}")

    def kernels_enabled(self) -> bool:
        if self.mode == "reference":
            return False
        if self.mode == "kernel":
            return True
        return jax.default_backend() == "tpu"

    def _interpret(self) -> bool:
        if self.interpret is not None:
            return self.interpret
        return jax.default_backend() != "tpu"


DEFAULT = DispatchConfig()


def _resolve(cfg: Optional[DispatchConfig]) -> DispatchConfig:
    return cfg if cfg is not None else DEFAULT


def _block_rows(cfg: DispatchConfig, kernel: str, rows: int, row_len: int,
                k: int, sign: bool) -> int:
    """Resolve one launch's grid height: an explicit
    ``cfg.block_rows`` wins, then the autotune table (hit/miss counters
    in ``launch_stats.TUNE_CACHE``), then the historical heuristic."""
    if cfg.block_rows is not None:
        return cfg.block_rows
    from repro.kernels import autotune
    ent = autotune.lookup(kernel, rows, row_len, k, sign)
    return ent.block_rows if ent is not None else DEFAULT_BLOCK_ROWS


def _compact_geometry(cfg: DispatchConfig, rows: int, row_len: int,
                      k: int, sign: bool) -> tuple[int, int]:
    """(block_rows, chunk) for a ``topk_compact`` launch — same
    resolution order; an explicit ``block_rows=`` pins the chunk to the
    default too (geometry is tuned as a pair)."""
    if cfg.block_rows is not None:
        return cfg.block_rows, DEFAULT_CHUNK
    from repro.kernels import autotune
    ent = autotune.lookup("topk_compact", rows, row_len, k, sign)
    if ent is not None:
        return ent.block_rows, ent.chunk or DEFAULT_CHUNK
    return DEFAULT_BLOCK_ROWS, DEFAULT_CHUNK


# ---------------------------------------------------------------------------
# shape plumbing
# ---------------------------------------------------------------------------


def _pad_to(flat: jnp.ndarray, multiple: int) -> jnp.ndarray:
    pad = (-flat.shape[0]) % multiple
    return jnp.pad(flat, (0, pad)) if pad else flat


def _as_single_row(x: jnp.ndarray) -> jnp.ndarray:
    """Flatten + zero-pad to a lane-aligned [1, n] row.  Zero padding is
    select-safe: |0| never beats a real survivor, and a zero survivor
    contributes zero to the dense output either way."""
    flat = _pad_to(x.reshape(-1).astype(jnp.float32), LANES)
    return flat[None, :]


def _as_rows(x: jnp.ndarray, row_len: int) -> jnp.ndarray:
    flat = _pad_to(x.reshape(-1).astype(jnp.float32), row_len)
    return flat.reshape(-1, row_len)


def _restore(out2d: jnp.ndarray, x: jnp.ndarray) -> jnp.ndarray:
    return out2d.reshape(-1)[: x.size].reshape(x.shape).astype(x.dtype)


def _padded_len(d: int, multiple: int) -> int:
    return d + ((-d) % multiple)


def capacity(k: int, n: int) -> int:
    """Lane-aligned compact survivor-buffer capacity for (k, row n)."""
    return min(_padded_len(max(k, 1), LANES), _padded_len(n, LANES))


# ---------------------------------------------------------------------------
# kernel rules
# ---------------------------------------------------------------------------


@dataclasses.dataclass(frozen=True)
class KernelRule:
    """One operator-family → kernel mapping."""

    name: str
    matches: Callable[[CompressionOp], bool]
    eligible: Callable[[CompressionOp, tuple, "DispatchConfig"], bool]
    run: Callable  # (op, key, x, cfg) -> (dense_out, wire_bits)


def _size(shape: tuple) -> int:
    n = 1
    for s in shape:
        n *= int(s)
    return n


def _global_row_ok(shape, cfg) -> bool:
    return _padded_len(_size(shape), LANES) <= cfg.max_row


def _row_len_of(op, shape) -> int:
    return min(op.row_len, _size(shape))


def _rows_ok(op, shape, cfg) -> bool:
    row = _row_len_of(op, shape)
    return row % LANES == 0 and row <= cfg.max_row


TOPK_FAMILY = ("topk_global", "row_topk", "signtopk_global", "row_signtopk")


def _plan_topk(rule_name: str, op, x):
    """Per-leaf Top_k-family launch plan: the pre-shaped [rows, n] f32
    buffer, static k, sign flag, and the counted-bits ledger closure.
    Shared by the per-leaf runners, megabuffer packing, and the compact
    emission path, so every route charges identical bits."""
    d = x.size
    if rule_name in ("topk_global", "signtopk_global"):
        sign = rule_name == "signtopk_global"
        k = resolve_k(op.k, d)
        rows = _as_single_row(x)
        if sign:
            bits_of = lambda c: bitlib.bits_signtopk_counted(d, c)
        else:
            bits_of = lambda c: bitlib.bits_topk_counted(d, c, op.value_bits)
    else:
        sign = rule_name == "row_signtopk"
        row = _row_len_of(op, x.shape)
        k = resolve_k(op.k, row)
        rows = _as_rows(x, row)
        nrows = rows.shape[0]
        # one 32-bit length/scale field per compression row; the counted
        # helpers already include one, hence the -32
        if sign:
            bits_of = lambda c: (jnp.float32(32 * nrows)
                                 + bitlib.bits_signtopk_counted(row, c)
                                 - jnp.float32(32))
        else:
            bits_of = lambda c: (jnp.float32(32 * nrows)
                                 + bitlib.bits_topk_counted(
                                     row, c, op.value_bits)
                                 - jnp.float32(32))
    return rows, k, sign, bits_of


def _run_topk_family(rule_name: str, op, key, x, cfg):
    rows, k, sign, bits_of = _plan_topk(rule_name, op, x)
    br = _block_rows(cfg, "topk_compress", rows.shape[0], rows.shape[1],
                     k, sign)
    sel, _mem, cnt = _topk.topk_compress(
        rows, k, sign=sign, block_rows=br, interpret=cfg._interpret())
    return _restore(sel, x), bits_of(jnp.sum(cnt))


def _run_qsgd(op: QSGDQuantizer, key, x, cfg):
    d = x.size
    flat = x.reshape(-1).astype(jnp.float32)
    # uniforms drawn exactly like the reference operator (same key, same
    # flat shape) keep the stochastic rounding bit-identical
    u = jax.random.uniform(key, flat.shape)
    row = _pad_to(flat, LANES)[None, :]
    br = _block_rows(cfg, "qsgd", 1, row.shape[1], op.s, False)
    out = _qsgd.qsgd_quantize(
        row, _pad_to(u, LANES)[None, :], op.s,
        block_rows=br, interpret=cfg._interpret())
    out = _restore(out, x)
    nz = jnp.sum(out != 0.0)
    return out, bitlib.bits_qsgd(d, op.s, nz)


RULES: tuple[KernelRule, ...] = (
    KernelRule(
        "topk_global",
        lambda op: type(op) is TopK,
        lambda op, shape, cfg: _global_row_ok(shape, cfg),
        functools.partial(_run_topk_family, "topk_global"),
    ),
    KernelRule(
        "row_topk",
        lambda op: type(op) is RowTopK,
        lambda op, shape, cfg: _rows_ok(op, shape, cfg),
        functools.partial(_run_topk_family, "row_topk"),
    ),
    KernelRule(
        "signtopk_global",
        lambda op: (type(op) is SignSparsifier and op.sparsifier == "top"
                    and op.m == 2),
        lambda op, shape, cfg: _global_row_ok(shape, cfg),
        functools.partial(_run_topk_family, "signtopk_global"),
    ),
    KernelRule(
        "row_signtopk",
        lambda op: type(op) is RowSignTopK and op.m == 2,
        lambda op, shape, cfg: _rows_ok(op, shape, cfg),
        functools.partial(_run_topk_family, "row_signtopk"),
    ),
    KernelRule(
        "qsgd_global",
        lambda op: type(op) is QSGDQuantizer,
        lambda op, shape, cfg: _global_row_ok(shape, cfg),
        _run_qsgd,
    ),
)


def select_rule(op: CompressionOp, shape: tuple,
                dtype=jnp.float32,
                cfg: Optional[DispatchConfig] = None) -> Optional[KernelRule]:
    """The kernel rule that would serve this (op, leaf), or None."""
    cfg = _resolve(cfg)
    if not cfg.kernels_enabled():
        return None
    if not jnp.issubdtype(jnp.dtype(dtype), jnp.floating):
        return None
    if cfg.mode == "auto" and _size(shape) < cfg.min_size:
        return None
    for rule in RULES:
        if rule.matches(op) and rule.eligible(op, shape, cfg):
            return rule
    return None


def would_dispatch(op: CompressionOp, shape: tuple, dtype=jnp.float32,
                   cfg: Optional[DispatchConfig] = None) -> bool:
    """Introspection probe: True iff compress_leaf would use a kernel."""
    return select_rule(op, shape, dtype, cfg) is not None


# ---------------------------------------------------------------------------
# raw row-kernel entries (shard-local compressors in core/distributed.py)
# ---------------------------------------------------------------------------


def rows_eligible(row_len: int, cfg: Optional[DispatchConfig] = None,
                  leaf_size: Optional[int] = None) -> bool:
    """Can [rows, row_len] blocks go through the Top_k kernel?

    Mirrors select_rule's auto-mode policy: pass ``leaf_size`` so tiny
    leaves (below min_size) stay on the reference path instead of
    paying a kernel launch; mode="kernel" bypasses the floor.
    """
    cfg = _resolve(cfg)
    if not (cfg.kernels_enabled() and row_len % LANES == 0
            and row_len <= cfg.max_row):
        return False
    if (cfg.mode == "auto" and leaf_size is not None
            and leaf_size < cfg.min_size):
        return False
    return True


def compact_rows_eligible(row_len: int, kcap: int,
                          cfg: Optional[DispatchConfig] = None,
                          leaf_size: Optional[int] = None) -> bool:
    """Can [rows, row_len] blocks go through the *compact* kernel?
    The dense row policy plus the survivor-capacity VMEM bound."""
    cfg = _resolve(cfg)
    return rows_eligible(row_len, cfg, leaf_size) and kcap <= cfg.max_cap


def topk_rows(rows: jnp.ndarray, k: int, *, sign: bool = False,
              cfg: Optional[DispatchConfig] = None):
    """Kernel Top_k/SignTop_k over pre-shaped [rows, n] blocks.

    Returns (selected, new_memory, count_per_row) — the fused kernel
    outputs.  Callers are responsible for :func:`rows_eligible`.
    """
    cfg = _resolve(cfg)
    br = _block_rows(cfg, "topk_compress", rows.shape[0], rows.shape[1],
                     k, sign)
    return _topk.topk_compress(
        rows, k, sign=sign, block_rows=br, interpret=cfg._interpret())


def compact_rows(rows: jnp.ndarray, k: int, kcap: int, *,
                 sign: bool = False,
                 cfg: Optional[DispatchConfig] = None,
                 leaf_size: Optional[int] = None):
    """Compact Top_k/SignTop_k over pre-shaped [rows, n] blocks.

    Kernel when :func:`compact_rows_eligible`, else the scatter-free
    jnp oracle (``ref.topk_compact_ref``) — identical outputs either
    way, and both forms are sort-free (they trace without ``lax.top_k``,
    which the 0.4.x SPMD partitioner cannot partition inside
    partial-manual shard_map regions).

    Returns (idx [rows, kcap] int32, val [rows, kcap] f32,
    new_mem [rows, n] f32, cnt [rows] int32); empty slots carry the
    out-of-row sentinel (idx = n, val = 0) — see DESIGN.md §3.3.
    """
    cfg = _resolve(cfg)
    n = rows.shape[1]
    if compact_rows_eligible(n, kcap, cfg, leaf_size=leaf_size):
        br, chunk = _compact_geometry(cfg, rows.shape[0], n, k, sign)
        return _topk.topk_compact(
            rows, k, kcap, sign=sign, block_rows=br, chunk=chunk,
            interpret=cfg._interpret())
    from repro.kernels.ref import topk_compact_ref
    return topk_compact_ref(rows.astype(jnp.float32), k, kcap, sign=sign)


# ---------------------------------------------------------------------------
# compact leaf compression (the sparse wire format)
# ---------------------------------------------------------------------------


class CompactLeaf(NamedTuple):
    """One leaf in compact wire form (DESIGN.md §3.3).

    idx/val are [rows, kcap] survivor buffers (rows = 1 for the global
    operators); slot j of row r holds the j-th surviving coordinate of
    that compression row in ascending index order, indices row-local.
    Slots past the row's survivor count hold (idx = row_len, val = 0) —
    the out-of-row sentinel a scatter-add decoder drops, so fixed-size
    buffers allgather without a decoded length.  ``mem`` is the fused
    error memory (leaf shape, f32) and ``bits`` the counted wire cost.
    """

    idx: jnp.ndarray
    val: jnp.ndarray
    mem: jnp.ndarray
    bits: jnp.ndarray
    row_len: int
    kcap: int


def decode_rows(idx: jnp.ndarray, val: jnp.ndarray,
                row_len: int) -> jnp.ndarray:
    """THE compact-buffer decoder: per-row scatter-add of [rows, kcap]
    (idx, val) into dense [rows, row_len] f32.  Out-of-row sentinel
    indices (empty slots, §3.3) drop; every consumer of the wire format
    decodes through here so the convention lives in one place."""
    out = jnp.zeros((idx.shape[0], row_len), jnp.float32)
    return jax.vmap(lambda o, i, v: o.at[i].add(v, mode="drop"))(
        out, idx, val)


def densify_compact(leaf: CompactLeaf, shape, dtype=jnp.float32):
    """Dense decode of a CompactLeaf: scatter-add rows, unpad, reshape
    to the original leaf shape."""
    out = decode_rows(leaf.idx, leaf.val, leaf.row_len)
    return out.reshape(-1)[: _size(tuple(shape))].reshape(shape).astype(dtype)


def would_compact(op: CompressionOp, shape: tuple, dtype=jnp.float32,
                  cfg: Optional[DispatchConfig] = None) -> bool:
    """True iff compact_compress would use the compact *kernel* (the
    fallback oracle produces the same wire form either way)."""
    cfg = _resolve(cfg)
    rule = next((r for r in RULES
                 if r.name in TOPK_FAMILY and r.matches(op)), None)
    if rule is None or not jnp.issubdtype(jnp.dtype(dtype), jnp.floating):
        return False
    d = _size(shape)
    if rule.name in ("topk_global", "signtopk_global"):
        n = _padded_len(d, LANES)
        k = resolve_k(op.k, d)
    else:
        n = _row_len_of(op, shape)
        k = resolve_k(op.k, n)
    return compact_rows_eligible(n, capacity(k, n), cfg, leaf_size=d)


def compact_compress(op: CompressionOp, key, x: jnp.ndarray,
                     cfg: Optional[DispatchConfig] = None
                     ) -> tuple[CompactLeaf, bool]:
    """Compact-form counterpart of :func:`compress_leaf` for the Top_k
    family (TopK / SignTopK(m=2) / RowTopK / RowSignTopK).

    Returns (CompactLeaf, used_kernel).  The fallback is the
    scatter-free reference oracle, not a dense compress: callers always
    get the compact wire form.  Ops outside the family raise TypeError
    (they have no sparse wire format — use compress_leaf).
    """
    cfg = _resolve(cfg)
    rule = next((r for r in RULES
                 if r.name in TOPK_FAMILY and r.matches(op)), None)
    if rule is None:
        raise TypeError(
            f"{type(op).__name__} has no compact wire form; "
            "compact_compress serves the Top_k family only")
    rows, k, sign, bits_of = _plan_topk(rule.name, op, x)
    n = rows.shape[1]
    kcap = capacity(k, n)
    # route on would_compact so the probe and the execution agree
    # (its dtype guard included — compact_rows alone never sees x.dtype)
    used = would_compact(op, x.shape, x.dtype, cfg)
    if used:
        br, chunk = _compact_geometry(cfg, rows.shape[0], n, k, sign)
        idx, val, mem, cnt = _topk.topk_compact(
            rows, k, kcap, sign=sign, block_rows=br, chunk=chunk,
            interpret=cfg._interpret())
    else:
        from repro.kernels.ref import topk_compact_ref
        idx, val, mem, cnt = topk_compact_ref(rows, k, kcap, sign=sign)
    mem_leaf = mem.reshape(-1)[: x.size].reshape(x.shape)
    bits = jnp.asarray(bits_of(jnp.sum(cnt)), jnp.float32)
    return CompactLeaf(idx, val, mem_leaf, bits, n, kcap), used


# ---------------------------------------------------------------------------
# compressed-weight serving GEMMs (DESIGN.md §11)
# ---------------------------------------------------------------------------


def _gemm_geometry(cfg: DispatchConfig, kernel: str, rows: int,
                   row_len: int, k: int) -> tuple[int, int]:
    """(block_rows, chunk) for a serving-GEMM launch — the same
    resolution order as the compression kernels: explicit
    ``cfg.block_rows``, then the autotune table, then the defaults."""
    if cfg.block_rows is not None:
        return cfg.block_rows, DEFAULT_CHUNK
    from repro.kernels import autotune
    ent = autotune.lookup(kernel, rows, row_len, k, False)
    if ent is not None:
        return ent.block_rows, ent.chunk or DEFAULT_CHUNK
    return DEFAULT_BLOCK_ROWS, DEFAULT_CHUNK


def paged_geometry(cfg: Optional[DispatchConfig], pages: int,
                   page_size: int, head_dim: int, quant: bool) -> int:
    """pages-per-block for a ``paged_decode`` launch — same resolution
    order as the GEMMs: explicit ``cfg.block_rows`` (reused as the page
    count per grid step), then the autotune table (signature = table
    width × page size × head_dim, sign bit = int8 pages), then the
    default; always clamped to the table width."""
    cfg = _resolve(cfg)
    if cfg.block_rows is not None:
        return max(1, min(cfg.block_rows, pages))
    from repro.kernels import autotune
    from repro.kernels.paged_attention import DEFAULT_PAGES_PER_BLOCK
    ent = autotune.lookup("paged_decode", pages, page_size, head_dim, quant)
    pb = ent.block_rows if ent is not None else DEFAULT_PAGES_PER_BLOCK
    return max(1, min(pb, pages))


def paged_decode(x: jnp.ndarray, kp: jnp.ndarray, vp: jnp.ndarray,
                 kscale: jnp.ndarray, vscale: jnp.ndarray,
                 tables: jnp.ndarray, lengths: jnp.ndarray,
                 cfg: Optional[DispatchConfig] = None) -> jnp.ndarray:
    """Serving entry for paged flash-decode attention.

    x: [B, 1, H, hd] rope'd queries; kp/vp/kscale/vscale: the KV page
    pool (see ``kernels/paged_attention.py``); tables: [B, P] block
    tables; lengths: [B].  Kernel when ``cfg.kernels_enabled()`` (pages
    gathered into VMEM via scalar-prefetch block tables, int8 dequant
    fused into the attention dot), the gather oracle otherwise;
    [B, 1, H, hd] either way.
    """
    cfg = _resolve(cfg)
    if cfg.kernels_enabled():
        pb = paged_geometry(cfg, tables.shape[-1], kp.shape[-3],
                            kp.shape[-1], kp.dtype == jnp.int8)
        from repro.kernels import paged_attention as _pa
        return _pa.paged_decode_fwd(x, kp, vp, kscale, vscale, tables,
                                    lengths, pages_per_block=pb,
                                    interpret=cfg._interpret())
    from repro.kernels.ref import paged_decode_ref
    return paged_decode_ref(x, kp, vp, kscale, vscale, tables, lengths)


def sparse_gemm(x: jnp.ndarray, idx: jnp.ndarray, val: jnp.ndarray,
                row_len: int, cfg: Optional[DispatchConfig] = None
                ) -> jnp.ndarray:
    """Serving entry for the sparse-weight × dense-activation GEMM.

    x: [M, row_len] activations; idx/val: [R, kcap] compact survivor
    buffers (rows enumerate output features).  Kernel when
    ``cfg.kernels_enabled()`` (the weight tile is decoded block-by-block
    in VMEM — the dense weight never exists in HBM), the
    densify-then-matmul oracle otherwise; [M, R] f32 either way.
    """
    cfg = _resolve(cfg)
    if cfg.kernels_enabled():
        br, chunk = _gemm_geometry(cfg, "sparse_gemm", idx.shape[0],
                                   row_len, idx.shape[1])
        return _sgemm.sparse_gemm(x, idx, val, row_len, block_rows=br,
                                  chunk=chunk, interpret=cfg._interpret())
    from repro.kernels.ref import sparse_gemm_ref
    return sparse_gemm_ref(x, idx, val, row_len)


def qdq_gemm(x: jnp.ndarray, levels: jnp.ndarray, scale: jnp.ndarray,
             cfg: Optional[DispatchConfig] = None) -> jnp.ndarray:
    """Serving entry for the QSGD-dequantize-fused GEMM.

    x: [M, n]; levels: [R, n] integer levels; scale: [R, 1] f32 per-row
    scales.  Kernel (dequantize fused into the matmul's VMEM residency)
    or the dequantize-then-matmul oracle; [M, R] f32 either way.
    """
    cfg = _resolve(cfg)
    if cfg.kernels_enabled():
        br, _ = _gemm_geometry(cfg, "qdq_gemm", levels.shape[0],
                               levels.shape[1], 0)
        return _sgemm.qdq_gemm(x, levels, scale, block_rows=br,
                               interpret=cfg._interpret())
    from repro.kernels.ref import qdq_gemm_ref
    return qdq_gemm_ref(x, levels, scale)


# ---------------------------------------------------------------------------
# public compression entry points (engine-facing)
# ---------------------------------------------------------------------------


def compress_leaf(op: CompressionOp, key, x: jnp.ndarray,
                  cfg: Optional[DispatchConfig] = None):
    """Compress one leaf: (dense_out, wire_bits, used_kernel).

    Kernel path when a rule matches and the leaf is eligible; otherwise
    the reference operator — identical output contract either way.
    """
    cfg = _resolve(cfg)
    rule = select_rule(op, x.shape, x.dtype, cfg)
    if rule is None:
        out, bits = op(key, x)
        return out, jnp.asarray(bits, jnp.float32), False
    out, bits = rule.run(op, key, x, cfg)
    return out, jnp.asarray(bits, jnp.float32), True


def _compress_leaves_packed(ops, keys, leaves, cfg, want_mem: bool = False):
    """Megabuffer-packed leaf compression (DESIGN.md §3.4).

    Kernel-eligible leaves are bucketed by launch signature —
    (row length, k, sign) for the Top_k family, (row length, s) for
    QSGD — and each bucket's pre-shaped rows are concatenated into one
    padded megabuffer for a single kernel launch.  The kernels are
    row-independent, so per-leaf outputs, error memories and counted
    bits are identical to the leaf-by-leaf path; only the launch count
    changes (one per populated bucket instead of one per leaf).

    With ``want_mem`` (the channel path, :func:`channel_compress_tree`)
    the third return carries per-leaf error memories: the kernel's
    *fused* ``acc − selected`` for Top_k-family leaves (no extra
    subtract outside the kernel), None for leaves whose memory the
    caller derives as ``acc − out``.
    """
    n = len(leaves)
    outs: list = [None] * n
    bit_terms: list = [None] * n
    mems: list = [None] * n
    topk_buckets: dict = {}
    qsgd_buckets: dict = {}
    for i, (op, key, x) in enumerate(zip(ops, keys, leaves)):
        rule = select_rule(op, x.shape, x.dtype, cfg)
        if rule is None:
            out, bits = op(key, x)
            outs[i] = out
            bit_terms[i] = jnp.asarray(bits, jnp.float32)
        elif rule.name == "qsgd_global":
            flat = x.reshape(-1).astype(jnp.float32)
            u = jax.random.uniform(key, flat.shape)
            row = _pad_to(flat, LANES)[None, :]
            urow = _pad_to(u, LANES)[None, :]
            qsgd_buckets.setdefault((row.shape[1], op.s), []).append(
                (i, row, urow, op, x))
        else:
            rows, k, sign, bits_of = _plan_topk(rule.name, op, x)
            topk_buckets.setdefault((rows.shape[1], k, sign), []).append(
                (i, rows, bits_of, x))
    for (_, k, sign), entries in topk_buckets.items():
        mega = (entries[0][1] if len(entries) == 1
                else jnp.concatenate([e[1] for e in entries], axis=0))
        br = _block_rows(cfg, "topk_compress", mega.shape[0], mega.shape[1],
                         k, sign)
        sel, mem, cnt = _topk.topk_compress(
            mega, k, sign=sign, block_rows=br,
            interpret=cfg._interpret())
        off = 0
        for i, rows, bits_of, x in entries:
            r = rows.shape[0]
            outs[i] = _restore(sel[off:off + r], x)
            if want_mem:
                mems[i] = _restore(mem[off:off + r], x)
            bit_terms[i] = jnp.asarray(
                bits_of(jnp.sum(cnt[off:off + r])), jnp.float32)
            off += r
    for (_, s), entries in qsgd_buckets.items():
        mega = (entries[0][1] if len(entries) == 1
                else jnp.concatenate([e[1] for e in entries], axis=0))
        megau = (entries[0][2] if len(entries) == 1
                 else jnp.concatenate([e[2] for e in entries], axis=0))
        br = _block_rows(cfg, "qsgd", mega.shape[0], mega.shape[1], s, False)
        out = _qsgd.qsgd_quantize(mega, megau, s, block_rows=br,
                                  interpret=cfg._interpret())
        for off, (i, _row, _urow, op, x) in enumerate(entries):
            o = _restore(out[off:off + 1], x)
            outs[i] = o
            bit_terms[i] = jnp.asarray(
                bitlib.bits_qsgd(x.size, op.s, jnp.sum(o != 0.0)),
                jnp.float32)
    return outs, bit_terms, mems


def compress_tree(op_tree, key, grads,
                  cfg: Optional[DispatchConfig] = None):
    """Kernel-aware counterpart of ``operators.compress_tree``: same
    operator-broadcast, key-splitting and bits-summing semantics, with
    each leaf routed through the kernels (megabuffer-packed per
    operator family when ``cfg.pack``) or :func:`compress_leaf`."""
    cfg = _resolve(cfg)
    leaves, treedef = jax.tree_util.tree_flatten(grads)
    ops = ops_for_leaves(op_tree, len(leaves))
    if key is not None:
        keys = jax.random.split(key, len(leaves))
    else:
        keys = [None] * len(leaves)
    if cfg.pack and cfg.kernels_enabled():
        outs, bit_terms, _ = _compress_leaves_packed(ops, keys, leaves, cfg)
    else:
        outs, bit_terms = [], []
        for op, k, g in zip(ops, keys, leaves):
            o, b, _ = compress_leaf(op, k, g, cfg)
            outs.append(o)
            bit_terms.append(b)
    total = jnp.sum(jnp.stack(bit_terms)) if bit_terms else jnp.float32(0)
    return jax.tree_util.tree_unflatten(treedef, outs), total


def channel_compress_tree(op_tree, key, acc,
                          cfg: Optional[DispatchConfig] = None,
                          *, want_leaf_bits: bool = False):
    """Channel-aware tree compression (DESIGN.md §5): compress the
    error-compensated accumulator ``acc`` and hand back the updated
    error memory alongside.

    Returns ``(q_tree, mem_tree, total_bits)`` with the invariant
    ``q + mem == acc`` per leaf.  Uplink and downlink both enter here
    (``core.channel.Channel.apply``), so downlink leaves join the same
    megabuffer packing buckets and trace-time launch counters as the
    uplink — one kernel launch per operator family per direction per
    sync round.  Top_k-family kernel leaves return the kernel's *fused*
    error memory (computed in the same VMEM residency, §3.3); every
    other leaf derives it as ``acc − q`` — bit-identical either way,
    both are the same f32 elementwise subtract.

    ``want_leaf_bits``: additionally return the per-leaf wire bits (a
    list of f32 scalars in flatten order — the per-leaf ledger of
    DESIGN.md §6) as a fourth element.  The total is always the sum of
    that list, so the aggregate ledger is unchanged either way.
    """
    cfg = _resolve(cfg)
    leaves, treedef = jax.tree_util.tree_flatten(acc)
    ops = ops_for_leaves(op_tree, len(leaves))
    if key is not None:
        keys = jax.random.split(key, len(leaves))
    else:
        keys = [None] * len(leaves)
    if cfg.pack and cfg.kernels_enabled():
        outs, bit_terms, mems = _compress_leaves_packed(
            ops, keys, leaves, cfg, want_mem=True)
    else:
        outs, bit_terms, mems = [], [], []
        for op, k, g in zip(ops, keys, leaves):
            o, b, _ = compress_leaf(op, k, g, cfg)
            outs.append(o)
            bit_terms.append(b)
            mems.append(None)
    mems = [m if m is not None else a - o
            for m, a, o in zip(mems, leaves, outs)]
    total = jnp.sum(jnp.stack(bit_terms)) if bit_terms else jnp.float32(0)
    out = (jax.tree_util.tree_unflatten(treedef, outs),
           jax.tree_util.tree_unflatten(treedef, mems),
           total)
    if want_leaf_bits:
        return out + (list(bit_terms),)
    return out


# ---------------------------------------------------------------------------
# launch-plan introspection (the autotuner's work list)
# ---------------------------------------------------------------------------


def _plan_topk_shape(rule_name: str, op, shape) -> tuple[int, int, int, bool]:
    """Shape-only twin of :func:`_plan_topk`: (rows, row_len, k, sign)
    of the pre-shaped kernel buffer, without building arrays."""
    d = _size(shape)
    if rule_name in ("topk_global", "signtopk_global"):
        return (1, _padded_len(d, LANES), resolve_k(op.k, d),
                rule_name == "signtopk_global")
    row = _row_len_of(op, shape)
    return (_padded_len(d, row) // row, row, resolve_k(op.k, row),
            rule_name == "row_signtopk")


def launch_plans(op_tree, tree, cfg: Optional[DispatchConfig] = None,
                 *, compact: bool = False) -> list:
    """The static kernel-launch signatures :func:`compress_tree` /
    :func:`channel_compress_tree` would dispatch for this (op_tree,
    params-like tree) — mirroring the megabuffer bucketing under
    ``cfg.pack`` — as ``autotune.ShapeKey`` rows.  This is exactly the
    autotuner's work list (``autotune.tune_for_run``): tune these keys
    and every launch of the run resolves through the table.

    ``compact=True`` maps Top_k-family plans onto the compact-emission
    kernel (``topk_compact``) instead — the sparse-allgather wire of
    the distributed engine."""
    from repro.kernels.autotune import ShapeKey
    cfg = _resolve(cfg)
    plans: list = []
    if not cfg.kernels_enabled():
        return plans
    leaves = jax.tree_util.tree_leaves(tree)
    ops = ops_for_leaves(op_tree, len(leaves))
    topk_name = "topk_compact" if compact else "topk_compress"
    topk_buckets: dict = {}
    qsgd_buckets: dict = {}
    for op, x in zip(ops, leaves):
        rule = select_rule(op, x.shape, x.dtype, cfg)
        if rule is None:
            continue
        if rule.name == "qsgd_global":
            n = _padded_len(_size(x.shape), LANES)
            qsgd_buckets[(n, op.s)] = qsgd_buckets.get((n, op.s), 0) + 1
        else:
            rows, n, k, sign = _plan_topk_shape(rule.name, op, x.shape)
            topk_buckets[(n, k, sign)] = (
                topk_buckets.get((n, k, sign), 0) + rows)
            if not cfg.pack:
                key = ShapeKey(topk_name, rows, n, k, sign)
                if key not in plans:
                    plans.append(key)
    if cfg.pack:
        for (n, k, sign), rows in topk_buckets.items():
            plans.append(ShapeKey(topk_name, rows, n, k, sign))
        for (n, s), rows in qsgd_buckets.items():
            plans.append(ShapeKey("qsgd", rows, n, s, False))
    else:
        for (n, s), count in qsgd_buckets.items():
            key = ShapeKey("qsgd", 1, n, s, False)
            if key not in plans:
                plans.append(key)
    return plans

"""Operator → Pallas-kernel dispatch for the unified Qsparse engine.

The engine (core/engine.py) compresses the error-compensated
accumulator ``m + x - x̂`` once per sync round; on production shapes
that is the per-round hot spot.  This module maps ``CompressionOp``
instances to the fused Pallas kernels when shape/dtype/platform allow,
and falls back *transparently* to the dense reference operators in
``core/operators.py`` otherwise — same dense output, same wire-bit
accounting, so callers never see which path ran (except through
:func:`would_dispatch`, used by tests and benchmarks).

Dispatch rules (see DESIGN.md §3.2):

  ========================  =======================================
  operator                  kernel
  ========================  =======================================
  ``TopK``                  ``topk_compress`` on a single padded row
  ``RowTopK``               ``topk_compress``, one row per block-row
  ``SignSparsifier`` (top,  ``topk_compress(sign=True)`` single row
  m=2)
  ``RowSignTopK`` (m=2)     ``topk_compress(sign=True)`` per row
  ``QSGDQuantizer``         ``qsgd`` single bucket, external uniforms
  ========================  =======================================

Everything else (RandK, Sign, k-level, the composed quantized
sparsifiers, SignTopK with the L1 scale) runs the reference operator.

Eligibility (``mode="auto"``): the backend is TPU (off-TPU the kernels
only exist in interpret mode, which is for validation, not speed), the
leaf has at least ``min_size`` elements, rows are lane-aligned (128)
and a row fits the VMEM budget (``max_row``).  ``mode="kernel"``
forces the kernel path (interpret off-TPU) for parity tests and
benchmarks; ``mode="reference"`` disables dispatch entirely.
"""

from __future__ import annotations

import dataclasses
from typing import Callable, Optional

import jax
import jax.numpy as jnp

from repro.core import bits as bitlib
from repro.core.operators import (
    CompressionOp,
    QSGDQuantizer,
    RowSignTopK,
    RowTopK,
    SignSparsifier,
    TopK,
    ops_for_leaves,
    resolve_k,
)
from repro.kernels import qsgd as _qsgd
from repro.kernels import topk_compress as _topk

LANES = 128  # TPU vector lane width: kernel rows are padded to this


@dataclasses.dataclass(frozen=True)
class DispatchConfig:
    """Where and when compression runs through the Pallas kernels.

    mode: "auto"      — kernels on TPU, references elsewhere (default)
          "kernel"    — force the kernel path (interpret mode off-TPU);
                        bypasses min_size but not structural limits
          "reference" — never dispatch (pure core/operators.py)
    min_size: smallest leaf (elements) worth a kernel launch in "auto"
    max_row:  longest kernel row (elements); bounds VMEM residency —
              3 f32 blocks of (block_rows, max_row) must fit in ~16 MB
    block_rows: grid block height handed to the kernels
    interpret: None — auto (interpret off-TPU); bool to force
    """

    mode: str = "auto"
    min_size: int = 1 << 16
    max_row: int = 1 << 19
    block_rows: int = 8
    interpret: Optional[bool] = None

    def __post_init__(self):
        if self.mode not in ("auto", "kernel", "reference"):
            raise ValueError(f"unknown dispatch mode {self.mode!r}")

    def kernels_enabled(self) -> bool:
        if self.mode == "reference":
            return False
        if self.mode == "kernel":
            return True
        return jax.default_backend() == "tpu"

    def _interpret(self) -> bool:
        if self.interpret is not None:
            return self.interpret
        return jax.default_backend() != "tpu"


DEFAULT = DispatchConfig()


def _resolve(cfg: Optional[DispatchConfig]) -> DispatchConfig:
    return cfg if cfg is not None else DEFAULT


# ---------------------------------------------------------------------------
# shape plumbing
# ---------------------------------------------------------------------------


def _pad_to(flat: jnp.ndarray, multiple: int) -> jnp.ndarray:
    pad = (-flat.shape[0]) % multiple
    return jnp.pad(flat, (0, pad)) if pad else flat


def _as_single_row(x: jnp.ndarray) -> jnp.ndarray:
    """Flatten + zero-pad to a lane-aligned [1, n] row.  Zero padding is
    select-safe: |0| never beats a real survivor, and a zero survivor
    contributes zero to the dense output either way."""
    flat = _pad_to(x.reshape(-1).astype(jnp.float32), LANES)
    return flat[None, :]


def _as_rows(x: jnp.ndarray, row_len: int) -> jnp.ndarray:
    flat = _pad_to(x.reshape(-1).astype(jnp.float32), row_len)
    return flat.reshape(-1, row_len)


def _restore(out2d: jnp.ndarray, x: jnp.ndarray) -> jnp.ndarray:
    return out2d.reshape(-1)[: x.size].reshape(x.shape).astype(x.dtype)


def _padded_len(d: int, multiple: int) -> int:
    return d + ((-d) % multiple)


# ---------------------------------------------------------------------------
# kernel rules
# ---------------------------------------------------------------------------


@dataclasses.dataclass(frozen=True)
class KernelRule:
    """One operator-family → kernel mapping."""

    name: str
    matches: Callable[[CompressionOp], bool]
    eligible: Callable[[CompressionOp, tuple, DispatchConfig], bool]
    run: Callable  # (op, key, x, cfg) -> (dense_out, wire_bits)


def _size(shape: tuple) -> int:
    n = 1
    for s in shape:
        n *= int(s)
    return n


def _global_row_ok(shape, cfg) -> bool:
    return _padded_len(_size(shape), LANES) <= cfg.max_row


def _row_len_of(op, shape) -> int:
    return min(op.row_len, _size(shape))


def _rows_ok(op, shape, cfg) -> bool:
    row = _row_len_of(op, shape)
    return row % LANES == 0 and row <= cfg.max_row


def _run_topk_global(op: TopK, key, x, cfg):
    d = x.size
    k = resolve_k(op.k, d)
    sel, _mem, cnt = _topk.topk_compress(
        _as_single_row(x), k, block_rows=cfg.block_rows,
        interpret=cfg._interpret())
    bits = bitlib.bits_topk_counted(d, jnp.sum(cnt), op.value_bits)
    return _restore(sel, x), bits


def _run_signtopk_global(op: SignSparsifier, key, x, cfg):
    d = x.size
    k = resolve_k(op.k, d)
    sel, _mem, cnt = _topk.topk_compress(
        _as_single_row(x), k, sign=True, block_rows=cfg.block_rows,
        interpret=cfg._interpret())
    bits = bitlib.bits_signtopk_counted(d, jnp.sum(cnt))
    return _restore(sel, x), bits


def _run_row_topk(op: RowTopK, key, x, cfg):
    d = x.size
    row = _row_len_of(op, x.shape)
    k = resolve_k(op.k, row)
    acc = _as_rows(x, row)
    sel, _mem, cnt = _topk.topk_compress(
        acc, k, block_rows=cfg.block_rows, interpret=cfg._interpret())
    nrows = acc.shape[0]
    bits = (jnp.float32(32 * nrows)
            + bitlib.bits_topk_counted(row, jnp.sum(cnt), op.value_bits)
            - jnp.float32(32))
    return _restore(sel, x), bits


def _run_row_signtopk(op: RowSignTopK, key, x, cfg):
    d = x.size
    row = _row_len_of(op, x.shape)
    k = resolve_k(op.k, row)
    acc = _as_rows(x, row)
    sel, _mem, cnt = _topk.topk_compress(
        acc, k, sign=True, block_rows=cfg.block_rows,
        interpret=cfg._interpret())
    nrows = acc.shape[0]
    bits = (jnp.float32(32 * nrows)
            + bitlib.bits_signtopk_counted(row, jnp.sum(cnt))
            - jnp.float32(32))
    return _restore(sel, x), bits


def _run_qsgd(op: QSGDQuantizer, key, x, cfg):
    d = x.size
    flat = x.reshape(-1).astype(jnp.float32)
    # uniforms drawn exactly like the reference operator (same key, same
    # flat shape) keep the stochastic rounding bit-identical
    u = jax.random.uniform(key, flat.shape)
    out = _qsgd.qsgd_quantize(
        _pad_to(flat, LANES)[None, :], _pad_to(u, LANES)[None, :], op.s,
        block_rows=cfg.block_rows, interpret=cfg._interpret())
    out = _restore(out, x)
    nz = jnp.sum(out != 0.0)
    return out, bitlib.bits_qsgd(d, op.s, nz)


RULES: tuple[KernelRule, ...] = (
    KernelRule(
        "topk_global",
        lambda op: type(op) is TopK,
        lambda op, shape, cfg: _global_row_ok(shape, cfg),
        _run_topk_global,
    ),
    KernelRule(
        "row_topk",
        lambda op: type(op) is RowTopK,
        lambda op, shape, cfg: _rows_ok(op, shape, cfg),
        _run_row_topk,
    ),
    KernelRule(
        "signtopk_global",
        lambda op: (type(op) is SignSparsifier and op.sparsifier == "top"
                    and op.m == 2),
        lambda op, shape, cfg: _global_row_ok(shape, cfg),
        _run_signtopk_global,
    ),
    KernelRule(
        "row_signtopk",
        lambda op: type(op) is RowSignTopK and op.m == 2,
        lambda op, shape, cfg: _rows_ok(op, shape, cfg),
        _run_row_signtopk,
    ),
    KernelRule(
        "qsgd_global",
        lambda op: type(op) is QSGDQuantizer,
        lambda op, shape, cfg: _global_row_ok(shape, cfg),
        _run_qsgd,
    ),
)


def select_rule(op: CompressionOp, shape: tuple,
                dtype=jnp.float32,
                cfg: Optional[DispatchConfig] = None) -> Optional[KernelRule]:
    """The kernel rule that would serve this (op, leaf), or None."""
    cfg = _resolve(cfg)
    if not cfg.kernels_enabled():
        return None
    if not jnp.issubdtype(jnp.dtype(dtype), jnp.floating):
        return None
    if cfg.mode == "auto" and _size(shape) < cfg.min_size:
        return None
    for rule in RULES:
        if rule.matches(op) and rule.eligible(op, shape, cfg):
            return rule
    return None


def would_dispatch(op: CompressionOp, shape: tuple, dtype=jnp.float32,
                   cfg: Optional[DispatchConfig] = None) -> bool:
    """Introspection probe: True iff compress_leaf would use a kernel."""
    return select_rule(op, shape, dtype, cfg) is not None


# ---------------------------------------------------------------------------
# raw row-kernel entry (shard-local compressors in core/distributed.py)
# ---------------------------------------------------------------------------


def rows_eligible(row_len: int, cfg: Optional[DispatchConfig] = None,
                  leaf_size: Optional[int] = None) -> bool:
    """Can [rows, row_len] blocks go through the Top_k kernel?

    Mirrors select_rule's auto-mode policy: pass ``leaf_size`` so tiny
    leaves (below min_size) stay on the reference path instead of
    paying a kernel launch; mode="kernel" bypasses the floor.
    """
    cfg = _resolve(cfg)
    if not (cfg.kernels_enabled() and row_len % LANES == 0
            and row_len <= cfg.max_row):
        return False
    if (cfg.mode == "auto" and leaf_size is not None
            and leaf_size < cfg.min_size):
        return False
    return True


def topk_rows(rows: jnp.ndarray, k: int, *, sign: bool = False,
              cfg: Optional[DispatchConfig] = None):
    """Kernel Top_k/SignTop_k over pre-shaped [rows, n] blocks.

    Returns (selected, new_memory, count_per_row) — the fused kernel
    outputs.  Callers are responsible for :func:`rows_eligible`.
    """
    cfg = _resolve(cfg)
    return _topk.topk_compress(
        rows, k, sign=sign, block_rows=cfg.block_rows,
        interpret=cfg._interpret())


# ---------------------------------------------------------------------------
# public compression entry points (engine-facing)
# ---------------------------------------------------------------------------


def compress_leaf(op: CompressionOp, key, x: jnp.ndarray,
                  cfg: Optional[DispatchConfig] = None):
    """Compress one leaf: (dense_out, wire_bits, used_kernel).

    Kernel path when a rule matches and the leaf is eligible; otherwise
    the reference operator — identical output contract either way.
    """
    cfg = _resolve(cfg)
    rule = select_rule(op, x.shape, x.dtype, cfg)
    if rule is None:
        out, bits = op(key, x)
        return out, jnp.asarray(bits, jnp.float32), False
    out, bits = rule.run(op, key, x, cfg)
    return out, jnp.asarray(bits, jnp.float32), True


def compress_tree(op_tree, key, grads,
                  cfg: Optional[DispatchConfig] = None):
    """Kernel-aware counterpart of ``operators.compress_tree``: same
    operator-broadcast, key-splitting and bits-summing semantics, with
    each leaf routed through :func:`compress_leaf`."""
    cfg = _resolve(cfg)
    leaves, treedef = jax.tree_util.tree_flatten(grads)
    ops = ops_for_leaves(op_tree, len(leaves))
    if key is not None:
        keys = jax.random.split(key, len(leaves))
    else:
        keys = [None] * len(leaves)
    outs, bit_terms = [], []
    for op, k, g in zip(ops, keys, leaves):
        o, b, _ = compress_leaf(op, k, g, cfg)
        outs.append(o)
        bit_terms.append(b)
    total = jnp.sum(jnp.stack(bit_terms)) if bit_terms else jnp.float32(0)
    return jax.tree_util.tree_unflatten(treedef, outs), total

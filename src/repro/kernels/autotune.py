"""Empirical block-geometry autotuner for the Pallas compression kernels.

The kernels in this package (``topk_compress``, ``topk_compact``,
``qsgd``) are row-independent: per-row threshold bisection and
quantization never read across block-row boundaries, so the grid
geometry — ``block_rows`` for all three, plus the scatter ``chunk`` for
the compact kernel — changes *timing only*, never outputs.  That makes
block geometry safely tunable: this module measures each candidate on
the live backend (warmup + ``block_until_ready``, best of N) and
records the winner in a per-device tuning table that
``kernels/dispatch.py`` resolves through transparently whenever a
``DispatchConfig`` leaves ``block_rows`` on auto (``None``).

Resolution order (DESIGN.md §10):

  1. an explicit ``DispatchConfig(block_rows=...)`` always wins;
  2. otherwise the tuning table, via an in-memory LRU keyed on the
     trace-time launch signature ``(kernel, dtype, rows, row_len, k,
     sign)`` — hit/miss counters surface in
     ``launch_stats.TUNE_CACHE``;
  3. untuned shapes fall back to the historical heuristic
     (``dispatch.DEFAULT_BLOCK_ROWS`` = 8, chunk 128) — so behaviour
     without a table, off-TPU and in interpret mode, is exactly the
     pre-autotune dispatch.

The table persists to ``artifacts/tuning/<device_kind>.json`` (one file
per accelerator kind; load/merge/save, so repeated tune runs extend the
table instead of clobbering it).  Corrupt, stale-schema or
foreign-device files never break dispatch: they load as an empty table
with a once-per-reason warning.  ``--retune`` (CLI and
``RunConfig.retune``) re-measures entries that already exist.

CLI (the CI tune-smoke lane)::

    PYTHONPATH=src python -m repro.kernels.autotune --smoke [--retune]

tunes a tiny fixed shape budget, prints one line per entry and a
``table: <path> (tuned N, cached M)`` summary — a second run reports
``tuned 0`` (every entry cache-hits).
"""

from __future__ import annotations

import argparse
import functools
import json
import os
import time
import warnings
from collections import OrderedDict
from typing import NamedTuple, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.kernels import qsgd as _qsgd
from repro.kernels import sparse_gemm as _sgemm
from repro.kernels import topk_compress as _topk
from repro.kernels.launch_stats import TUNE_CACHE

TABLE_VERSION = 1
DEFAULT_TABLE_DIR = os.path.join("artifacts", "tuning")

#: dense kernels hold 3 f32 blocks of (block_rows, row_len) in VMEM;
#: candidates stay inside the envelope the historical defaults implied
#: (block_rows 8 at max_row 2^19)
VMEM_DENSE_BYTES = 3 * 8 * (1 << 19) * 4
#: the compact kernel's (block_rows, chunk, kcap) one-hot scatter
#: intermediate, at the historical default geometry (8, 128, max_cap)
VMEM_COMPACT_BYTES = 8 * 128 * (1 << 11) * 4

KERNELS = ("topk_compress", "topk_compact", "qsgd",
           "sparse_gemm", "qdq_gemm", "paged_decode")

#: fixed activation-row count for serving-GEMM measurement — the tuned
#: geometry tiles the *weight* rows; activation batch only scales every
#: candidate uniformly, so one representative M suffices
GEMM_MEASURE_M = 8

_LRU_MAX = 512
_lru: OrderedDict = OrderedDict()
_table: Optional[dict] = None   # lazily loaded persisted entries
_table_dir: str = DEFAULT_TABLE_DIR
_warned: set = set()


class TunedEntry(NamedTuple):
    """One tuning-table row: the winning geometry and its measured time."""

    block_rows: int
    chunk: Optional[int] = None   # topk_compact only
    us: float = float("nan")


class ShapeKey(NamedTuple):
    """A trace-time kernel launch signature — the tuning-table key."""

    kernel: str
    rows: int
    row_len: int
    k: int          # survivor count (Top_k family) or level count s (qsgd)
    sign: bool
    dtype: str = "f32"   # kernels compute in f32 today; keyed for later

    def as_str(self) -> str:
        return (f"{self.kernel}|{self.dtype}|{self.rows}|{self.row_len}"
                f"|{self.k}|{int(self.sign)}")


def _warn_once(tag: str, msg: str) -> None:
    if tag not in _warned:
        _warned.add(tag)
        warnings.warn(msg, stacklevel=3)


def device_kind() -> str:
    """Normalized accelerator kind — the per-device table filename."""
    kind = jax.devices()[0].device_kind
    return "".join(c if c.isalnum() else "_" for c in kind.lower())


def table_path(table_dir: Optional[str] = None) -> str:
    return os.path.join(table_dir or _table_dir, f"{device_kind()}.json")


def configure(table_dir: Optional[str] = None) -> None:
    """Point the module at a different table directory (tests, CLI) and
    drop the in-memory state so the next lookup reloads from it."""
    global _table_dir
    if table_dir is not None:
        _table_dir = table_dir
    clear_cache()


def clear_cache() -> None:
    """Drop the LRU, the loaded table and the warn-once registry (the
    persisted file is untouched)."""
    global _table
    _lru.clear()
    _table = None
    _warned.clear()


def _parse_key(s: str) -> Optional[ShapeKey]:
    parts = s.split("|")
    if len(parts) != 6 or parts[0] not in KERNELS:
        return None
    try:
        return ShapeKey(parts[0], int(parts[2]), int(parts[3]),
                        int(parts[4]), bool(int(parts[5])), parts[1])
    except ValueError:
        return None


def _valid_entry(key: ShapeKey, ent: dict) -> bool:
    br = ent.get("block_rows")
    if not isinstance(br, int) or br < 1:
        return False
    chunk = ent.get("chunk")
    if chunk is not None:
        if not isinstance(chunk, int) or chunk < 1:
            return False
        if key.row_len % chunk != 0:
            return False
    return True


def load_table(path: Optional[str] = None) -> dict:
    """Load a persisted tuning table → {key_str: TunedEntry}.

    Never raises on bad input: a missing file is an empty table; corrupt
    JSON, a stale schema version or a foreign-device file fall back to
    empty with a once-per-reason warning; malformed entries are skipped
    individually."""
    path = path or table_path()
    if not os.path.exists(path):
        return {}
    try:
        with open(path) as f:
            raw = json.load(f)
    except (json.JSONDecodeError, OSError, UnicodeDecodeError):
        _warn_once(f"corrupt:{path}",
                   f"ignoring corrupt tuning table {path}")
        return {}
    if not isinstance(raw, dict) or raw.get("version") != TABLE_VERSION:
        _warn_once(f"stale:{path}",
                   f"ignoring stale tuning table {path} (version "
                   f"{raw.get('version') if isinstance(raw, dict) else '?'}, "
                   f"want {TABLE_VERSION})")
        return {}
    if raw.get("device_kind") != device_kind():
        _warn_once(f"foreign:{path}",
                   f"ignoring tuning table {path} tuned for device kind "
                   f"{raw.get('device_kind')!r} (this backend: "
                   f"{device_kind()!r})")
        return {}
    out = {}
    entries = raw.get("entries")
    if not isinstance(entries, dict):
        _warn_once(f"stale:{path}",
                   f"ignoring tuning table {path}: no entries mapping")
        return {}
    for ks, ent in entries.items():
        key = _parse_key(ks)
        if key is None or not isinstance(ent, dict) \
                or not _valid_entry(key, ent):
            _warn_once(f"entry:{path}",
                       f"skipping malformed entries in tuning table {path}")
            continue
        out[ks] = TunedEntry(int(ent["block_rows"]),
                             ent.get("chunk"),
                             float(ent.get("us", float("nan"))))
    return out


def save_table(entries: dict, path: Optional[str] = None) -> str:
    """Merge ``entries`` ({key_str: TunedEntry}) into the on-disk table
    (new keys win) and write it back.  Returns the path written."""
    path = path or table_path()
    merged = dict(load_table(path))
    merged.update(entries)
    os.makedirs(os.path.dirname(path) or ".", exist_ok=True)
    payload = {
        "version": TABLE_VERSION,
        "device_kind": device_kind(),
        "entries": {
            ks: {"block_rows": e.block_rows, "chunk": e.chunk, "us": e.us}
            for ks, e in sorted(merged.items())
        },
    }
    tmp = path + ".tmp"
    with open(tmp, "w") as f:
        json.dump(payload, f, indent=1, sort_keys=True)
    os.replace(tmp, path)
    return path


def lookup(kernel: str, rows: int, row_len: int, k: int, sign: bool,
           dtype: str = "f32") -> Optional[TunedEntry]:
    """Trace-time table resolution: LRU first (``TUNE_CACHE['hit']``),
    then the lazily-loaded persisted table (``'miss'``; negative results
    are cached too, so untuned shapes cost one dict probe per trace)."""
    global _table
    ks = ShapeKey(kernel, rows, row_len, k, sign, dtype).as_str()
    if ks in _lru:
        _lru.move_to_end(ks)
        TUNE_CACHE["hit"] += 1
        return _lru[ks]
    TUNE_CACHE["miss"] += 1
    if _table is None:
        _table = load_table()
    ent = _table.get(ks)
    _lru[ks] = ent
    if len(_lru) > _LRU_MAX:
        _lru.popitem(last=False)
    return ent


# ---------------------------------------------------------------------------
# measurement
# ---------------------------------------------------------------------------


def _time_us(fn, *args, iters: int = 3) -> float:
    """Best-of-N wall time in µs, after one warmup (compile) call; every
    call is ``block_until_ready`` so async dispatch can't undercount."""
    jax.block_until_ready(fn(*args))
    best = float("inf")
    for _ in range(max(1, iters)):
        t0 = time.perf_counter()
        jax.block_until_ready(fn(*args))
        best = min(best, time.perf_counter() - t0)
    return best * 1e6


def block_row_candidates(rows: int, row_len: int) -> list:
    """Powers of two up to the row count (clamped), inside the dense
    VMEM envelope."""
    cands = set()
    p = 1
    while p < max(rows, 1):
        cands.add(p)
        p *= 2
    cands.add(rows)
    out = sorted(c for c in cands if 3 * c * row_len * 4 <= VMEM_DENSE_BYTES)
    return out or [min(rows, 8)]


def chunk_candidates(row_len: int) -> list:
    out = [c for c in (128, 256, 512, 1024) if row_len % c == 0]
    return out or [row_len]


def page_block_candidates(pages: int) -> list:
    """Pages-per-block candidates for ``paged_decode``: powers of two up
    to the block-table width, plus the width itself (single-block)."""
    cands = set()
    p = 1
    while p < max(pages, 1):
        cands.add(p)
        p *= 2
    cands.add(max(pages, 1))
    return sorted(cands)


def _interpret_default(interpret: Optional[bool]) -> bool:
    if interpret is not None:
        return interpret
    return jax.default_backend() != "tpu"


def measure_entry(key: ShapeKey, *, iters: int = 3,
                  interpret: Optional[bool] = None) -> TunedEntry:
    """Measure every candidate geometry for one launch signature and
    return the winner."""
    interp = _interpret_default(interpret)
    rng = np.random.RandomState(0)
    x = jnp.asarray(rng.randn(key.rows, key.row_len).astype(np.float32))
    best: Optional[TunedEntry] = None
    if key.kernel == "topk_compress":
        for br in block_row_candidates(key.rows, key.row_len):
            fn = jax.jit(functools.partial(
                _topk.topk_compress, k=key.k, sign=key.sign,
                block_rows=br, interpret=interp))
            us = _time_us(fn, x, iters=iters)
            if best is None or us < best.us:
                best = TunedEntry(br, None, us)
    elif key.kernel == "qsgd":
        u = jnp.asarray(rng.rand(key.rows, key.row_len).astype(np.float32))
        for br in block_row_candidates(key.rows, key.row_len):
            fn = jax.jit(functools.partial(
                _qsgd.qsgd_quantize, s=key.k, block_rows=br,
                interpret=interp))
            us = _time_us(fn, x, u, iters=iters)
            if best is None or us < best.us:
                best = TunedEntry(br, None, us)
    elif key.kernel == "topk_compact":
        from repro.kernels.dispatch import capacity
        kcap = capacity(key.k, key.row_len)
        for br in block_row_candidates(key.rows, key.row_len):
            for chunk in chunk_candidates(key.row_len):
                if br * chunk * kcap * 4 > VMEM_COMPACT_BYTES:
                    continue
                fn = jax.jit(functools.partial(
                    _topk.topk_compact, k=key.k, kcap=kcap, sign=key.sign,
                    block_rows=br, chunk=chunk, interpret=interp))
                us = _time_us(fn, x, iters=iters)
                if best is None or us < best.us:
                    best = TunedEntry(br, chunk, us)
        if best is None:   # every pair over budget: keep the default
            best = TunedEntry(min(key.rows, 8), 128, float("nan"))
    elif key.kernel == "sparse_gemm":
        # key.k is the compact capacity kcap; rows/row_len describe the
        # weight in its serving orientation (rows = output features)
        xact = jnp.asarray(
            rng.randn(GEMM_MEASURE_M, key.row_len).astype(np.float32))
        idx = jnp.asarray(rng.randint(
            0, key.row_len, (key.rows, key.k)).astype(np.int32))
        val = jnp.asarray(rng.randn(key.rows, key.k).astype(np.float32))
        for br in block_row_candidates(key.rows, key.row_len):
            for chunk in chunk_candidates(key.row_len):
                if br * chunk * key.k * 4 > VMEM_COMPACT_BYTES:
                    continue
                fn = jax.jit(functools.partial(
                    _sgemm.sparse_gemm, row_len=key.row_len,
                    block_rows=br, chunk=chunk, interpret=interp))
                us = _time_us(fn, xact, idx, val, iters=iters)
                if best is None or us < best.us:
                    best = TunedEntry(br, chunk, us)
        if best is None:
            best = TunedEntry(min(key.rows, 8), 128, float("nan"))
    elif key.kernel == "qdq_gemm":
        xact = jnp.asarray(
            rng.randn(GEMM_MEASURE_M, key.row_len).astype(np.float32))
        levels = jnp.asarray(rng.randint(
            -key.k, key.k + 1, (key.rows, key.row_len)).astype(np.int8))
        scale = jnp.asarray(
            rng.rand(key.rows, 1).astype(np.float32))
        for br in block_row_candidates(key.rows, key.row_len):
            fn = jax.jit(functools.partial(
                _sgemm.qdq_gemm, block_rows=br, interpret=interp))
            us = _time_us(fn, xact, levels, scale, iters=iters)
            if best is None or us < best.us:
                best = TunedEntry(br, None, us)
    elif key.kernel == "paged_decode":
        # signature: rows = block-table width (max pages per request),
        # row_len = page size, k = head_dim, sign = int8 page layout;
        # block_rows stores the winning pages-per-block
        from repro.kernels import paged_attention as _pa
        P, ps, hd = key.rows, key.row_len, key.k
        B, KV, G = 4, 1, 8
        n_pages = B * P
        q = jnp.asarray(rng.randn(B, 1, KV * G, hd).astype(np.float32))
        if key.sign:
            kp = jnp.asarray(rng.randint(
                -127, 128, (n_pages, ps, KV, hd)).astype(np.int8))
            vp = jnp.asarray(rng.randint(
                -127, 128, (n_pages, ps, KV, hd)).astype(np.int8))
        else:
            kp = jnp.asarray(
                rng.randn(n_pages, ps, KV, hd).astype(np.float32))
            vp = jnp.asarray(
                rng.randn(n_pages, ps, KV, hd).astype(np.float32))
        ks_ = jnp.asarray(rng.rand(n_pages, ps).astype(np.float32))
        vs_ = jnp.asarray(rng.rand(n_pages, ps).astype(np.float32))
        tbl = jnp.asarray(
            np.arange(n_pages).reshape(B, P).astype(np.int32))
        lens = jnp.asarray(np.full(B, P * ps, np.int32))
        for pb in page_block_candidates(P):
            fn = jax.jit(functools.partial(
                _pa.paged_decode_fwd, pages_per_block=pb,
                interpret=interp))
            us = _time_us(fn, q, kp, vp, ks_, vs_, tbl, lens, iters=iters)
            if best is None or us < best.us:
                best = TunedEntry(pb, None, us)
    else:
        raise ValueError(f"unknown kernel {key.kernel!r}; "
                         f"expected one of {KERNELS}")
    return best


def tune(keys, *, iters: int = 3, retune: bool = False, save: bool = True,
         interpret: Optional[bool] = None, verbose: bool = False) -> dict:
    """Tune every ShapeKey in ``keys`` that isn't already in the table
    (all of them with ``retune``), persist the merged table, and return
    {key_str: TunedEntry} for the keys measured this call."""
    global _table
    if _table is None:
        _table = load_table()
    fresh = {}
    cached = 0
    for key in keys:
        ks = key.as_str() if isinstance(key, ShapeKey) else str(key)
        if not retune and ks in _table:
            cached += 1
            if verbose:
                print(f"  cached {ks} -> {_table[ks]}")
            continue
        ent = measure_entry(key, iters=iters, interpret=interpret)
        fresh[ks] = ent
        if verbose:
            print(f"  tuned  {ks} -> block_rows={ent.block_rows}"
                  + (f" chunk={ent.chunk}" if ent.chunk else "")
                  + f" ({ent.us:.1f} us)")
    if fresh:
        _table.update(fresh)
        if save:
            save_table(fresh)
        _lru.clear()   # resolutions cached before this tune are stale
    tune.last_cached = cached   # introspection for the CLI/tests
    return fresh


def tune_for_run(op_tree, params, cfg=None, *, downlink=None,
                 iters: int = 3, retune: bool = False,
                 compact: bool = False, verbose: bool = False) -> dict:
    """Tune exactly the launch signatures a training run's compression
    would dispatch (``dispatch.launch_plans`` over the uplink — and
    downlink — operator trees against the per-worker param shapes)."""
    from repro.kernels import dispatch as dsp
    keys = list(dsp.launch_plans(op_tree, params, cfg, compact=compact))
    if downlink is not None:
        for key in dsp.launch_plans(downlink, params, cfg, compact=compact):
            if key not in keys:
                keys.append(key)
    return tune(keys, iters=iters, retune=retune, verbose=verbose)


# ---------------------------------------------------------------------------
# CLI — the CI tune-smoke lane
# ---------------------------------------------------------------------------

#: tiny interpret-friendly budget: one signature per kernel family
SMOKE_KEYS = (
    ShapeKey("topk_compress", 4, 256, 8, False),
    ShapeKey("topk_compress", 1, 1024, 16, True),
    ShapeKey("topk_compact", 4, 256, 8, False),
    ShapeKey("qsgd", 1, 1024, 15, False),
    ShapeKey("sparse_gemm", 8, 256, 16, False),
    ShapeKey("qdq_gemm", 8, 256, 15, False),
    ShapeKey("paged_decode", 4, 16, 32, False),
    ShapeKey("paged_decode", 4, 16, 32, True),
)


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(
        description="autotune Pallas compression-kernel block geometry")
    ap.add_argument("--smoke", action="store_true",
                    help="tune the tiny fixed smoke shape budget")
    ap.add_argument("--retune", action="store_true",
                    help="re-measure entries already in the table")
    ap.add_argument("--dir", default=None, help="tuning-table directory "
                    f"(default {DEFAULT_TABLE_DIR})")
    ap.add_argument("--iters", type=int, default=3)
    ap.add_argument("--kernel", choices=KERNELS)
    ap.add_argument("--rows", type=int)
    ap.add_argument("--row-len", type=int)
    ap.add_argument("--k", type=int)
    ap.add_argument("--sign", action="store_true")
    args = ap.parse_args(argv)
    if args.dir:
        configure(args.dir)
    if args.smoke:
        keys = list(SMOKE_KEYS)
    elif args.kernel:
        if not (args.rows and args.row_len and args.k):
            ap.error("--kernel needs --rows, --row-len and --k")
        keys = [ShapeKey(args.kernel, args.rows, args.row_len, args.k,
                         args.sign)]
    else:
        ap.error("pass --smoke or an explicit --kernel shape")
    fresh = tune(keys, iters=args.iters, retune=args.retune, verbose=True)
    path = table_path()
    print(f"table: {path} (tuned {len(fresh)}, cached {tune.last_cached})")
    return 0 if os.path.exists(path) or not fresh else 1


if __name__ == "__main__":
    raise SystemExit(main())

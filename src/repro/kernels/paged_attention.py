"""Pallas TPU kernel: paged flash-decode attention over a shared KV
page pool (DESIGN.md §12).

The serving engine stores KV state in a fixed arena of
``[n_pages, page_size, KV, hd]`` blocks; each request owns a
*block table* — the ordered list of physical page ids holding its
tokens.  This kernel computes single-token GQA decode attention
directly against that layout: the block table rides in as a
scalar-prefetch operand (``pltpu.PrefetchScalarGridSpec``), so the
``BlockSpec`` index maps gather each request's pages straight from the
pool — the contiguous per-request KV tensor never exists.

Grid: ``(batch, kv_head, page_blocks)`` with the page dimension
innermost.  One grid step fetches ``pages_per_block`` pages (the pool
operand is passed that many times, each copy with its own
table-indexed index map — the tunable geometry), and the
online-softmax state (running max m, normalizer l, f32 accumulator o)
is carried across page blocks in VMEM scratch, exactly like the
prefill flash kernel.

Quantized pages: when the pool dtype is int8 the per-page scale
vectors (``[n_pages, page_size]`` f32 — one scale per token slot, the
wire format mirroring ``qdq_gemm``'s per-row scale) ride along through
the same table-indexed gather and the dequantize multiply is fused
into the attention dot's VMEM residency.  Note the int8 native tile on
real TPUs is (32, 128); the smoke geometries here (page_size 8-16,
hd 32) validate in interpret mode — production TPU pools want
page_size ≥ 32.

Masking needs no position bookkeeping in the pool: pages are dense in
logical token order, so slot ``t`` of logical page ``j`` holds global
position ``j*page_size + t`` and validity is simply ``position <
length``.  Sentinel table entries (-1, unallocated) only ever cover
positions ≥ length, so clamping them to page 0 is safe.  A fully
masked request (length 0 — a free engine slot) returns exact zeros.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.kernels.launch_stats import LAUNCHES

NEG_INF = -1e30

#: untuned fallback geometry (kernels/autotune.py tunes per shape)
DEFAULT_PAGES_PER_BLOCK = 4


def _paged_kernel(tbl_ref, len_ref, q_ref, *rest, nblk: int, pb: int,
                  ps: int, quant: bool, scale: float):
    if quant:
        k_refs, v_refs = rest[:pb], rest[pb:2 * pb]
        ks_refs, vs_refs = rest[2 * pb:3 * pb], rest[3 * pb:4 * pb]
        o_ref, o_acc, m_acc, l_acc = rest[4 * pb:]
    else:
        k_refs, v_refs = rest[:pb], rest[pb:2 * pb]
        ks_refs = vs_refs = ()
        o_ref, o_acc, m_acc, l_acc = rest[2 * pb:]
    b = pl.program_id(0)
    j = pl.program_id(2)

    @pl.when(j == 0)
    def _init():
        o_acc[...] = jnp.zeros_like(o_acc)
        m_acc[...] = jnp.full_like(m_acc, NEG_INF)
        l_acc[...] = jnp.zeros_like(l_acc)

    q = q_ref[0, 0].astype(jnp.float32) * scale          # [G, hd]
    length = len_ref[b]
    for i in range(pb):
        k = k_refs[i][0, :, 0, :].astype(jnp.float32)    # [ps, hd]
        v = v_refs[i][0, :, 0, :].astype(jnp.float32)
        if quant:
            k = k * ks_refs[i][0, :][:, None]
            v = v * vs_refs[i][0, :][:, None]
        kpos = (j * pb + i) * ps + jax.lax.iota(jnp.int32, ps)
        live = kpos < length
        s = jax.lax.dot_general(q, k, (((1,), (1,)), ((), ())),
                                preferred_element_type=jnp.float32)  # [G, ps]
        s = jnp.where(live[None, :], s, NEG_INF)
        m_prev = m_acc[...]
        m_new = jnp.maximum(m_prev, jnp.max(s, axis=1))
        # explicit mask on p (not just on s): with every slot dead the
        # m subtraction would otherwise turn NEG_INF scores into
        # exp(0) = 1 and a free engine slot would emit garbage mass
        p = jnp.where(live[None, :], jnp.exp(s - m_new[:, None]), 0.0)
        alpha = jnp.exp(m_prev - m_new)
        l_acc[...] = l_acc[...] * alpha + jnp.sum(p, axis=1)
        o_acc[...] = o_acc[...] * alpha[:, None] + jax.lax.dot_general(
            p, v, (((1,), (0,)), ((), ())),
            preferred_element_type=jnp.float32)
        m_acc[...] = m_new

    @pl.when(j == nblk - 1)
    def _finish():
        o = o_acc[...] / jnp.maximum(l_acc[...], 1e-30)[:, None]
        o_ref[0, 0] = o.astype(o_ref.dtype)


def paged_decode_fwd(q, kp, vp, kscale, vscale, tables, lengths, *,
                     pages_per_block: int = DEFAULT_PAGES_PER_BLOCK,
                     interpret: bool = False):
    """Single-token decode attention against a KV page pool, GQA-aware.

    q: [B, 1, H, hd] (rope'd at each slot's position); kp/vp:
    [n_pages, page_size, KV, hd] pool arenas (f32/bf16, or int8 levels);
    kscale/vscale: [n_pages, page_size] f32 per-token-slot dequant
    scales (ignored for fp pools); tables: [B, max_pages] int32 block
    tables (-1 = unallocated); lengths: [B] int32 valid-token counts
    (0 = inactive slot → exact-zero output).  Returns [B, 1, H, hd].
    """
    B, _, H, hd = q.shape
    n_pages, ps, KV, _ = kp.shape
    G = H // KV
    P = tables.shape[1]
    quant = kp.dtype == jnp.int8
    pb = max(1, min(int(pages_per_block), P))
    pad = (-P) % pb
    # sentinel/-1 entries clamp to page 0: they only cover positions
    # beyond `lengths`, which the kernel masks by position anyway
    tbl = jnp.clip(tables, 0, n_pages - 1).astype(jnp.int32)
    if pad:
        tbl = jnp.pad(tbl, ((0, 0), (0, pad)))
    nblk = (P + pad) // pb
    LAUNCHES["paged_decode"] += 1
    q4 = q.reshape(B, 1, KV, G, hd)[:, 0]                # [B, KV, G, hd]

    def page_spec(i):
        return pl.BlockSpec(
            (1, ps, 1, hd),
            lambda b, h, j, tbl, lens, i=i: (tbl[b, j * pb + i], 0, h, 0))

    def scale_spec(i):
        return pl.BlockSpec(
            (1, ps),
            lambda b, h, j, tbl, lens, i=i: (tbl[b, j * pb + i], 0))

    in_specs = [pl.BlockSpec((1, 1, G, hd),
                             lambda b, h, j, tbl, lens: (b, h, 0, 0))]
    inputs = [q4]
    in_specs += [page_spec(i) for i in range(pb)]
    inputs += [kp] * pb
    in_specs += [page_spec(i) for i in range(pb)]
    inputs += [vp] * pb
    if quant:
        in_specs += [scale_spec(i) for i in range(pb)]
        inputs += [kscale] * pb
        in_specs += [scale_spec(i) for i in range(pb)]
        inputs += [vscale] * pb
    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=2,
        grid=(B, KV, nblk),
        in_specs=in_specs,
        out_specs=pl.BlockSpec((1, 1, G, hd),
                               lambda b, h, j, tbl, lens: (b, h, 0, 0)),
        scratch_shapes=[
            pltpu.VMEM((G, hd), jnp.float32),
            pltpu.VMEM((G,), jnp.float32),
            pltpu.VMEM((G,), jnp.float32),
        ],
    )
    kern = functools.partial(_paged_kernel, nblk=nblk, pb=pb, ps=ps,
                             quant=quant, scale=hd ** -0.5)
    out = pl.pallas_call(
        kern,
        grid_spec=grid_spec,
        out_shape=jax.ShapeDtypeStruct((B, KV, G, hd), q.dtype),
        interpret=interpret,
    )(tbl, jnp.asarray(lengths, jnp.int32), *inputs)
    return out.reshape(B, 1, H, hd)

"""Trace-time kernel-launch accounting, shared by all kernel families.

Each python-level kernel-wrapper call is one ``pallas_call`` site in
the traced program (vmap/grid batching does not multiply it), so
benchmarks measure launches-per-sync-round by resetting, tracing, and
reading.  Kept in its own module so kernel families don't import each
other just to count.
"""

from __future__ import annotations

LAUNCHES = {"topk_compress": 0, "topk_compact": 0, "qsgd": 0,
            "sparse_gemm": 0, "qdq_gemm": 0, "flash_decode": 0,
            "paged_decode": 0}

#: serving page-pool gauges, refreshed by ``ServeEngine.step()`` when
#: the paged KV runtime is active (DESIGN.md §12): pages used/free and
#: peak, internal fragmentation (1 - live_tokens / (used_pages *
#: page_size)), preemptions (recompute-from-start evictions) and
#: admission stalls (queue head blocked on pages, not slots).
PAGE_POOL = {"pages_used": 0, "pages_free": 0, "peak_pages_used": 0,
             "fragmentation": 0.0, "preemptions": 0, "admission_stalls": 0}

#: trace-time tuning-table resolution counters (kernels/autotune.py):
#: ``hit`` — the LRU already held the shape's resolution, ``miss`` — the
#: persisted table (or the heuristic fallback) had to be consulted.
#: Incremented only when a DispatchConfig leaves ``block_rows`` on auto.
TUNE_CACHE = {"hit": 0, "miss": 0}


def reset_launches() -> None:
    for k in LAUNCHES:
        LAUNCHES[k] = 0


def reset_tune_cache() -> None:
    for k in TUNE_CACHE:
        TUNE_CACHE[k] = 0


def reset_page_pool() -> None:
    for k in PAGE_POOL:
        PAGE_POOL[k] = 0.0 if k == "fragmentation" else 0


def total_launches() -> int:
    return sum(LAUNCHES.values())

"""Pure-jnp oracles for every Pallas kernel.

These define the exact semantics the kernels must reproduce; the tests
sweep shapes/dtypes and assert allclose between kernel (interpret=True
on CPU) and these references.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp


# ---------------------------------------------------------------------------
# topk_compress: bisection-threshold top-k select + fused error update
# ---------------------------------------------------------------------------


def topk_compress_ref(acc: jnp.ndarray, k: int, *, iters: int = 24,
                      sign: bool = False):
    """acc: [rows, n] error-compensated accumulator (m + x - x̂).

    Per row: bisect (``iters`` rounds) for the magnitude threshold of the
    k-th largest entry of |acc|; select the survivors (full precision, or
    sign * ||sel||_2/count when ``sign``); the fused error update is
    m' = acc - selected.

    Selection is *exactly* k generically: the bisection invariant is
    cnt(a >= lo) > k >= cnt(a >= hi), so the hi threshold keeps exactly
    k entries once the interval is narrower than the k-th/(k+1)-th
    magnitude gap.  Under ties or an exhausted iteration budget it falls
    back to the lo threshold (>= k survivors, a strictly better
    sparsifier; the error memory absorbs the difference either way).

    Returns (selected, new_memory, count_per_row).
    """
    a = jnp.abs(acc.astype(jnp.float32))
    hi = jnp.max(a, axis=1, keepdims=True)
    lo = jnp.zeros_like(hi)

    def body(_, lohi):
        lo, hi = lohi
        mid = 0.5 * (lo + hi)
        cnt = jnp.sum(a >= mid, axis=1, keepdims=True)
        # too many kept -> raise threshold; too few -> lower it
        lo = jnp.where(cnt > k, mid, lo)
        hi = jnp.where(cnt > k, hi, mid)
        return lo, hi

    lo, hi = jax.lax.fori_loop(0, iters, body, (lo, hi))
    c_hi = jnp.sum(a >= hi, axis=1, keepdims=True)
    thr = jnp.where(c_hi >= k, hi, lo)
    # exact zeros are never survivors: an all-zero (or zero-padded) row
    # must not count toward the wire-bits ledger
    mask = (a >= thr) & (a > 0.0)
    cnt = jnp.sum(mask, axis=1)
    sel = jnp.where(mask, acc.astype(jnp.float32), 0.0)
    if sign:
        norm = jnp.sqrt(jnp.sum(jnp.square(sel), axis=1, keepdims=True))
        denom = jnp.maximum(cnt[:, None].astype(jnp.float32), 1.0)
        sel = jnp.where(mask, jnp.sign(acc) * norm / denom, 0.0)
    new_mem = acc.astype(jnp.float32) - sel
    return sel, new_mem, cnt


def topk_compact_ref(acc: jnp.ndarray, k: int, kcap: int, *,
                     iters: int = 24, sign: bool = False, chunk: int = 256):
    """Oracle for the compact-emitting kernel (``topk_compact``).

    Same threshold selection as :func:`topk_compress_ref`; survivors are
    then compacted into ``(idx, val)`` buffers of capacity ``kcap`` per
    row, slots filled in ascending index order, empty slots carrying the
    out-of-row sentinel ``(idx=n, val=0)`` that a scatter-add decoder
    drops.  Survivors past ``kcap`` (heavy ties only) stay in the error
    memory instead of crossing the wire.

    Deliberately sort- and scatter-free (prefix-sum slots + chunked
    one-hot contraction): this is also the *fallback* compact path
    inside 0.4.x partial-manual shard_map regions, where ``lax.top_k``
    and scatters crash the SPMD partitioner (DESIGN.md §4.1).

    Returns (idx [rows, kcap] int32, val [rows, kcap] f32,
    new_mem [rows, n] f32, cnt [rows] int32).
    """
    acc = acc.astype(jnp.float32)
    rows, n = acc.shape
    a = jnp.abs(acc)
    hi = jnp.max(a, axis=1, keepdims=True)
    lo = jnp.zeros_like(hi)

    def body(_, lohi):
        lo, hi = lohi
        mid = 0.5 * (lo + hi)
        cnt = jnp.sum(a >= mid, axis=1, keepdims=True)
        lo = jnp.where(cnt > k, mid, lo)
        hi = jnp.where(cnt > k, hi, mid)
        return lo, hi

    lo, hi = jax.lax.fori_loop(0, iters, body, (lo, hi))
    c_hi = jnp.sum(a >= hi, axis=1, keepdims=True)
    thr = jnp.where(c_hi >= k, hi, lo)
    mask = (a >= thr) & (a > 0.0)
    pos = jnp.cumsum(mask.astype(jnp.int32), axis=1) - 1
    emit = mask & (pos < kcap)
    cnt = jnp.sum(emit, axis=1).astype(jnp.int32)
    sel = jnp.where(emit, acc, 0.0)
    if sign:
        norm = jnp.sqrt(jnp.sum(jnp.square(sel), axis=1, keepdims=True))
        denom = jnp.maximum(cnt[:, None].astype(jnp.float32), 1.0)
        sel = jnp.where(emit, jnp.sign(acc) * norm / denom, 0.0)
    new_mem = acc - sel
    # chunked one-hot contraction bounds the [rows, chunk, kcap]
    # intermediate; rows are zero-padded to a chunk multiple (padding
    # never emits).
    pad = (-n) % chunk
    if pad:
        pos = jnp.pad(pos, ((0, 0), (0, pad)))
        emit = jnp.pad(emit, ((0, 0), (0, pad)))
        sel_p = jnp.pad(sel, ((0, 0), (0, pad)))
    else:
        sel_p = sel
    cols = jnp.arange(kcap)[None, None, :]
    lane = jnp.arange(chunk)[None, :]

    def cbody(g, carry):
        idx_acc, val_acc = carry
        p = jax.lax.dynamic_slice(pos, (0, g * chunk), (rows, chunk))
        e = jax.lax.dynamic_slice(emit, (0, g * chunk), (rows, chunk))
        v = jax.lax.dynamic_slice(sel_p, (0, g * chunk), (rows, chunk))
        oh = ((p[:, :, None] == cols) & e[:, :, None]).astype(jnp.float32)
        gidx = jnp.broadcast_to((g * chunk + lane).astype(jnp.float32),
                                (rows, chunk))
        val_acc = val_acc + jnp.einsum("rc,rcj->rj", v, oh)
        idx_acc = idx_acc + jnp.einsum("rc,rcj->rj", gidx, oh)
        return idx_acc, val_acc

    zeros = jnp.zeros((rows, kcap), jnp.float32)
    idx_acc, val_acc = jax.lax.fori_loop(0, (n + pad) // chunk, cbody,
                                         (zeros, zeros))
    slot = jnp.arange(kcap)[None, :]
    idx = jnp.where(slot < cnt[:, None], idx_acc.astype(jnp.int32), n)
    return idx, val_acc, new_mem, cnt


# ---------------------------------------------------------------------------
# compressed-weight serving GEMMs (kernels/sparse_gemm.py)
# ---------------------------------------------------------------------------


def sparse_gemm_ref(x: jnp.ndarray, idx: jnp.ndarray, val: jnp.ndarray,
                    row_len: int) -> jnp.ndarray:
    """Densify-then-matmul oracle for ``sparse_gemm``.

    x: [M, row_len]; idx/val: [R, kcap] compact survivor buffers
    (row-local indices, out-of-row sentinel idx = row_len, val = 0).
    Decodes the [R, row_len] weight through the canonical scatter-add
    decoder semantics and contracts: ``y = x @ W.T`` in f32.
    """
    w = jnp.zeros((idx.shape[0], row_len), jnp.float32)
    w = jax.vmap(lambda o, i, v: o.at[i].add(v, mode="drop"))(
        w, idx, val.astype(jnp.float32))
    return x.astype(jnp.float32) @ w.T


def qdq_gemm_ref(x: jnp.ndarray, levels: jnp.ndarray,
                 scale: jnp.ndarray) -> jnp.ndarray:
    """Dequantize-then-matmul oracle for ``qdq_gemm``: per-row integer
    levels times the [R, 1] f32 scale, contracted in f32."""
    w = levels.astype(jnp.float32) * scale.astype(jnp.float32).reshape(-1, 1)
    return x.astype(jnp.float32) @ w.T


# ---------------------------------------------------------------------------
# flash attention (causal, optional sliding window), GQA
# ---------------------------------------------------------------------------


def flash_attention_ref(q, k, v, *, window: int = -1):
    """q: [B, S, H, D]; k, v: [B, S, KV, D].  Causal; window > 0 limits
    attention to the last ``window`` positions.  f32 accumulation."""
    B, S, H, D = q.shape
    KV = k.shape[2]
    G = H // KV
    qf = q.astype(jnp.float32).reshape(B, S, KV, G, D) * (D ** -0.5)
    s = jnp.einsum("bqkgh,bskh->bkgqs", qf, k.astype(jnp.float32))
    pos = jnp.arange(S)
    mask = pos[None, :] <= pos[:, None]
    if window > 0:
        mask &= pos[None, :] > pos[:, None] - window
    s = jnp.where(mask[None, None, None], s, -1e30)
    p = jax.nn.softmax(s, axis=-1)
    o = jnp.einsum("bkgqs,bskh->bqkgh", p, v.astype(jnp.float32))
    return o.reshape(B, S, H, D).astype(q.dtype)


def flash_decode_ref(q, k, v, valid):
    """Oracle for ``flash_decode_fwd``: single-token GQA attention over
    ring-cache contents under a precomputed slot-validity mask.

    q: [B, 1, H, D]; k, v: [B, C, KV, D]; valid: [C] bool."""
    B, _, H, D = q.shape
    KV = k.shape[2]
    G = H // KV
    qf = q.astype(jnp.float32).reshape(B, 1, KV, G, D) * (D ** -0.5)
    s = jnp.einsum("bqkgh,bskh->bkgqs", qf, k.astype(jnp.float32))
    s = jnp.where(valid[None, None, None, None, :], s, -1e30)
    p = jax.nn.softmax(s, axis=-1)
    o = jnp.einsum("bkgqs,bskh->bqkgh", p, v.astype(jnp.float32))
    return o.reshape(B, 1, H, D).astype(q.dtype)


def paged_decode_ref(q, kp, vp, kscale, vscale, tables, lengths):
    """Gather-then-attend oracle for ``paged_decode_fwd``.

    q: [B, 1, H, D]; kp/vp: [n_pages, page_size, KV, D] pool arenas
    (fp, or int8 levels with the [n_pages, page_size] f32 per-token-slot
    scales); tables: [B, P] int32 block tables (-1 = unallocated);
    lengths: [B] int32.  Materializes each request's logical KV view
    through the table, dequantizes, and attends with explicit masked
    normalization — a length-0 row (free engine slot) yields exact
    zeros, matching the kernel, where ``jax.nn.softmax`` would emit a
    uniform distribution over garbage.
    """
    B, _, H, D = q.shape
    n_pages, ps, KV, _ = kp.shape
    P = tables.shape[1]
    G = H // KV
    tbl = jnp.clip(tables, 0, n_pages - 1)
    k = kp[tbl].astype(jnp.float32)                  # [B, P, ps, KV, D]
    v = vp[tbl].astype(jnp.float32)
    if kp.dtype == jnp.int8:
        k = k * kscale[tbl].astype(jnp.float32)[..., None, None]
        v = v * vscale[tbl].astype(jnp.float32)[..., None, None]
    k = k.reshape(B, P * ps, KV, D)
    v = v.reshape(B, P * ps, KV, D)
    live = jnp.arange(P * ps)[None, :] < lengths[:, None]        # [B, C]
    qf = q.astype(jnp.float32).reshape(B, 1, KV, G, D) * (D ** -0.5)
    s = jnp.einsum("bqkgh,bskh->bkgqs", qf, k)
    s = jnp.where(live[:, None, None, None, :], s, -1e30)
    m = jnp.max(s, axis=-1, keepdims=True)
    p = jnp.where(live[:, None, None, None, :], jnp.exp(s - m), 0.0)
    denom = jnp.moveaxis(jnp.sum(p, axis=-1), -1, 1)[..., None]  # [B,1,KV,G,1]
    o = jnp.einsum("bkgqs,bskh->bqkgh", p, v) / jnp.maximum(denom, 1e-30)
    return o.reshape(B, 1, H, D).astype(q.dtype)


# ---------------------------------------------------------------------------
# bucketed QSGD stochastic quantization
# ---------------------------------------------------------------------------


def qsgd_bucketed_ref(x: jnp.ndarray, u: jnp.ndarray, s: int):
    """x: [buckets, n]; u: uniform [buckets, n] in [0,1).  Per-bucket l2
    norm; levels xi stochastically rounded.  Returns quantized [buckets, n]."""
    xf = x.astype(jnp.float32)
    norm = jnp.sqrt(jnp.sum(jnp.square(xf), axis=1, keepdims=True))
    safe = jnp.where(norm > 0, norm, 1.0)
    level = jnp.abs(xf) / safe * s
    low = jnp.floor(level)
    xi = low + (u < (level - low)).astype(jnp.float32)
    q = norm * jnp.sign(xf) * xi / s
    return jnp.where(norm > 0, q, jnp.zeros_like(xf))

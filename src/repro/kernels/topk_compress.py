"""Pallas TPU kernel: fused blockwise top-k compression + error update.

The paper's per-sync hot spot: compressing a ~25M-element accumulator
(m + x - x̂) with Top_k.  A GPU implementation radix-selects; on TPU we
instead run a **bisection threshold search** — 24 rounds of
compare-and-count, pure VPU (8x128 lanes) work with no sorting network
and no MXU involvement — then a masked select, the optional 1-bit
Sign quantization of the survivors (SignTop_k, Lemma 3), and the fused
error-memory update ``m' = acc - selected``, all in one VMEM residency
of the block.  See DESIGN.md §3 (hardware adaptation).

Grid: one program per row-block.  Block shape (ROWS, n) where n is the
row length (the shard-local compression row, typically 1-8k) — (8, 512)
multiples keep the VPU lanes full.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _kernel(acc_ref, sel_ref, mem_ref, cnt_ref, *, k: int, iters: int,
            sign: bool):
    acc = acc_ref[...].astype(jnp.float32)        # [ROWS, N]
    a = jnp.abs(acc)
    hi = jnp.max(a, axis=1, keepdims=True)
    lo = jnp.zeros_like(hi)

    def body(_, lohi):
        lo, hi = lohi
        mid = 0.5 * (lo + hi)
        cnt = jnp.sum((a >= mid).astype(jnp.int32), axis=1, keepdims=True)
        keep_hi = cnt > k
        lo = jnp.where(keep_hi, mid, lo)
        hi = jnp.where(keep_hi, hi, mid)
        return lo, hi

    lo, hi = jax.lax.fori_loop(0, iters, body, (lo, hi))
    # Exact-k selection: the bisection invariant is cnt(a >= lo) > k and
    # cnt(a >= hi) <= k, so generically (distinct magnitudes, interval
    # narrower than the k-th/k+1-th gap) the hi threshold keeps exactly k
    # entries.  If ties or the iteration budget leave cnt(a >= hi) < k,
    # fall back to lo, which keeps >= k (a strictly better sparsifier).
    c_hi = jnp.sum((a >= hi).astype(jnp.int32), axis=1, keepdims=True)
    thr = jnp.where(c_hi >= k, hi, lo)
    # exact zeros are never survivors (zero-padded / all-zero rows must
    # not count toward the wire-bits ledger)
    mask = (a >= thr) & (a > 0.0)
    cnt = jnp.sum(mask.astype(jnp.int32), axis=1)
    sel = jnp.where(mask, acc, 0.0)
    if sign:
        norm = jnp.sqrt(jnp.sum(sel * sel, axis=1, keepdims=True))
        denom = jnp.maximum(cnt[:, None].astype(jnp.float32), 1.0)
        sel = jnp.where(mask, jnp.sign(acc) * norm / denom, 0.0)
    sel_ref[...] = sel.astype(sel_ref.dtype)
    mem_ref[...] = (acc - sel).astype(mem_ref.dtype)
    cnt_ref[...] = cnt.astype(jnp.int32)


def topk_compress(acc: jax.Array, k: int, *, iters: int = 24,
                  sign: bool = False, block_rows: int = 8,
                  interpret: bool = False):
    """acc: [rows, n] -> (selected [rows, n], new_mem [rows, n], cnt [rows]).

    VMEM per program: 3 blocks of (block_rows, n) f32 — for n = 8192 and
    block_rows = 8 that is ~0.8 MB, comfortably inside the ~16 MB VMEM
    budget with double buffering.
    """
    rows, n = acc.shape
    br = min(block_rows, rows)
    pad = (-rows) % br
    if pad:
        acc = jnp.pad(acc, ((0, pad), (0, 0)))
    grid = (acc.shape[0] // br,)
    kern = functools.partial(_kernel, k=k, iters=iters, sign=sign)
    sel, mem, cnt = pl.pallas_call(
        kern,
        grid=grid,
        in_specs=[pl.BlockSpec((br, n), lambda i: (i, 0))],
        out_specs=[
            pl.BlockSpec((br, n), lambda i: (i, 0)),
            pl.BlockSpec((br, n), lambda i: (i, 0)),
            pl.BlockSpec((br,), lambda i: (i,)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct(acc.shape, jnp.float32),
            jax.ShapeDtypeStruct(acc.shape, jnp.float32),
            jax.ShapeDtypeStruct((acc.shape[0],), jnp.int32),
        ],
        interpret=interpret,
    )(acc)
    if pad:
        sel, mem, cnt = sel[:rows], mem[:rows], cnt[:rows]
    return sel, mem, cnt

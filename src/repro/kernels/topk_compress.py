"""Pallas TPU kernels: fused blockwise top-k compression + error update.

The paper's per-sync hot spot: compressing a ~25M-element accumulator
(m + x - x̂) with Top_k.  A GPU implementation radix-selects; on TPU we
instead run a **bisection threshold search** — 24 rounds of
compare-and-count, pure VPU (8x128 lanes) work with no sorting network
and no MXU involvement — then a masked select, the optional 1-bit
Sign quantization of the survivors (SignTop_k, Lemma 3), and the fused
error-memory update ``m' = acc - selected``, all in one VMEM residency
of the block.  See DESIGN.md §3 (hardware adaptation).

Two emission modes share the threshold search:

  * :func:`topk_compress` — *dense* survivors (zeros elsewhere), the
    input to a dense psum/pmean aggregation;
  * :func:`topk_compact` — *compact* ``(idx int32, val f32)`` survivor
    buffers of capacity ``kcap`` per row, written directly via an
    in-kernel prefix-sum compaction (cumsum of the survivor mask gives
    each survivor its output slot; a chunked one-hot matmul performs
    the slot scatter on the MXU — TPUs have no vector scatter).  This
    is the wire form of ``aggregate="sparse_allgather"`` and is sort-
    free, so it also partitions under the 0.4.x SPMD partitioner where
    ``lax.top_k`` hard-crashes (DESIGN.md §3.3, §4.1).

Grid: one program per row-block.  Block shape (ROWS, n) where n is the
row length (the shard-local compression row, typically 1-8k) — (8, 512)
multiples keep the VPU lanes full.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from repro.kernels.launch_stats import LAUNCHES


def _bisect_threshold(a: jnp.ndarray, k: int, iters: int) -> jnp.ndarray:
    """Per-row magnitude threshold keeping ~k entries of ``a`` (= |acc|).

    Maintains cnt(a >= lo) > k >= cnt(a >= hi); generically (distinct
    magnitudes, interval narrower than the k-th/k+1-th gap) the hi bound
    keeps exactly k.  If ties or the iteration budget leave
    cnt(a >= hi) < k, fall back to lo, which keeps >= k (a strictly
    better sparsifier).
    """
    hi = jnp.max(a, axis=1, keepdims=True)
    lo = jnp.zeros_like(hi)

    def body(_, lohi):
        lo, hi = lohi
        mid = 0.5 * (lo + hi)
        cnt = jnp.sum((a >= mid).astype(jnp.int32), axis=1, keepdims=True)
        keep_hi = cnt > k
        lo = jnp.where(keep_hi, mid, lo)
        hi = jnp.where(keep_hi, hi, mid)
        return lo, hi

    lo, hi = jax.lax.fori_loop(0, iters, body, (lo, hi))
    c_hi = jnp.sum((a >= hi).astype(jnp.int32), axis=1, keepdims=True)
    return jnp.where(c_hi >= k, hi, lo)


def _kernel(acc_ref, sel_ref, mem_ref, cnt_ref, *, k: int, iters: int,
            sign: bool):
    acc = acc_ref[...].astype(jnp.float32)        # [ROWS, N]
    a = jnp.abs(acc)
    thr = _bisect_threshold(a, k, iters)
    # exact zeros are never survivors (zero-padded / all-zero rows must
    # not count toward the wire-bits ledger)
    mask = (a >= thr) & (a > 0.0)
    cnt = jnp.sum(mask.astype(jnp.int32), axis=1)
    sel = jnp.where(mask, acc, 0.0)
    if sign:
        norm = jnp.sqrt(jnp.sum(sel * sel, axis=1, keepdims=True))
        denom = jnp.maximum(cnt[:, None].astype(jnp.float32), 1.0)
        sel = jnp.where(mask, jnp.sign(acc) * norm / denom, 0.0)
    sel_ref[...] = sel.astype(sel_ref.dtype)
    mem_ref[...] = (acc - sel).astype(mem_ref.dtype)
    cnt_ref[...] = cnt.astype(jnp.int32)


def topk_compress(acc: jax.Array, k: int, *, iters: int = 24,
                  sign: bool = False, block_rows: int = 8,
                  interpret: bool = False):
    """acc: [rows, n] -> (selected [rows, n], new_mem [rows, n], cnt [rows]).

    VMEM per program: 3 blocks of (block_rows, n) f32 — for n = 8192 and
    block_rows = 8 that is ~0.8 MB, comfortably inside the ~16 MB VMEM
    budget with double buffering.
    """
    LAUNCHES["topk_compress"] += 1
    rows, n = acc.shape
    br = min(block_rows, rows)
    pad = (-rows) % br
    if pad:
        acc = jnp.pad(acc, ((0, pad), (0, 0)))
    grid = (acc.shape[0] // br,)
    kern = functools.partial(_kernel, k=k, iters=iters, sign=sign)
    sel, mem, cnt = pl.pallas_call(
        kern,
        grid=grid,
        in_specs=[pl.BlockSpec((br, n), lambda i: (i, 0))],
        out_specs=[
            pl.BlockSpec((br, n), lambda i: (i, 0)),
            pl.BlockSpec((br, n), lambda i: (i, 0)),
            pl.BlockSpec((br,), lambda i: (i,)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct(acc.shape, jnp.float32),
            jax.ShapeDtypeStruct(acc.shape, jnp.float32),
            jax.ShapeDtypeStruct((acc.shape[0],), jnp.int32),
        ],
        interpret=interpret,
    )(acc)
    if pad:
        sel, mem, cnt = sel[:rows], mem[:rows], cnt[:rows]
    return sel, mem, cnt


# ---------------------------------------------------------------------------
# compact emission (the sparse wire format)
# ---------------------------------------------------------------------------


def _compact_kernel(acc_ref, idx_ref, val_ref, mem_ref, cnt_ref, *, k: int,
                    kcap: int, iters: int, sign: bool, chunk: int):
    acc = acc_ref[...].astype(jnp.float32)        # [ROWS, N]
    rows, n = acc.shape
    a = jnp.abs(acc)
    thr = _bisect_threshold(a, k, iters)
    mask = (a >= thr) & (a > 0.0)
    # prefix-sum compaction: each survivor's output slot is the count of
    # survivors strictly before it in the row.  Survivors past the
    # buffer capacity (only possible under heavy ties) are dropped from
    # the wire; the fused memory update below absorbs them.
    pos = jnp.cumsum(mask.astype(jnp.int32), axis=1) - 1
    emit = mask & (pos < kcap)
    cnt = jnp.sum(emit.astype(jnp.int32), axis=1)
    sel = jnp.where(emit, acc, 0.0)
    if sign:
        norm = jnp.sqrt(jnp.sum(sel * sel, axis=1, keepdims=True))
        denom = jnp.maximum(cnt[:, None].astype(jnp.float32), 1.0)
        sel = jnp.where(emit, jnp.sign(acc) * norm / denom, 0.0)
    mem_ref[...] = (acc - sel).astype(mem_ref.dtype)
    cnt_ref[...] = cnt.astype(jnp.int32)
    # slot scatter as a chunked one-hot matmul: TPUs have no vector
    # scatter, but onehot[r, c, j] = [pos == j & emit] contracted
    # against the values (and against the global indices) on the MXU
    # writes every chunk's survivors to their slots.  f32 holds indices
    # exactly up to 2^24 >> max_row.
    cols = jax.lax.broadcasted_iota(jnp.int32, (1, 1, kcap), 2)
    lane = jax.lax.broadcasted_iota(jnp.int32, (1, chunk), 1)

    def body(g, carry):
        idx_acc, val_acc = carry
        p = jax.lax.dynamic_slice(pos, (0, g * chunk), (rows, chunk))
        e = jax.lax.dynamic_slice(emit, (0, g * chunk), (rows, chunk))
        v = jax.lax.dynamic_slice(sel, (0, g * chunk), (rows, chunk))
        oh = ((p[:, :, None] == cols) & e[:, :, None]).astype(jnp.float32)
        gidx = jnp.broadcast_to((g * chunk + lane).astype(jnp.float32),
                                (rows, chunk))
        val_acc = val_acc + jnp.einsum(
            "rc,rcj->rj", v, oh, preferred_element_type=jnp.float32)
        idx_acc = idx_acc + jnp.einsum(
            "rc,rcj->rj", gidx, oh, preferred_element_type=jnp.float32)
        return idx_acc, val_acc

    zeros = jnp.zeros((rows, kcap), jnp.float32)
    idx_acc, val_acc = jax.lax.fori_loop(0, n // chunk, body, (zeros, zeros))
    # empty slots carry the sentinel index n (one past the row): the
    # decoder's scatter-add drops out-of-bounds writes, so a gathered
    # buffer never needs its count to be decoded.
    slot = jax.lax.broadcasted_iota(jnp.int32, (rows, kcap), 1)
    idx_ref[...] = jnp.where(slot < cnt[:, None],
                             idx_acc.astype(jnp.int32), n)
    val_ref[...] = val_acc.astype(val_ref.dtype)


def topk_compact(acc: jax.Array, k: int, kcap: int, *, iters: int = 24,
                 sign: bool = False, block_rows: int = 8, chunk: int = 128,
                 interpret: bool = False):
    """Compact Top_k: [rows, n] -> (idx [rows, kcap] int32,
    val [rows, kcap] f32, new_mem [rows, n] f32, cnt [rows] int32).

    Survivor slots are filled in ascending index order; slots past
    ``cnt[r]`` hold ``(idx=n, val=0)`` — the out-of-row sentinel that a
    scatter-add decoder drops.  ``n`` must be a multiple of ``chunk``
    (the dispatch layer lane-aligns rows).  VMEM per program adds the
    (block_rows, chunk, kcap) one-hot to the dense-kernel budget —
    ~4 MB at (8, 128, 1024) f32, so dispatch caps kcap (``max_cap``).
    """
    LAUNCHES["topk_compact"] += 1
    rows, n = acc.shape
    if n % chunk:
        raise ValueError(f"row length {n} not a multiple of chunk {chunk}")
    br = min(block_rows, rows)
    pad = (-rows) % br
    if pad:
        acc = jnp.pad(acc, ((0, pad), (0, 0)))
    grid = (acc.shape[0] // br,)
    kern = functools.partial(_compact_kernel, k=k, kcap=kcap, iters=iters,
                             sign=sign, chunk=chunk)
    idx, val, mem, cnt = pl.pallas_call(
        kern,
        grid=grid,
        in_specs=[pl.BlockSpec((br, n), lambda i: (i, 0))],
        out_specs=[
            pl.BlockSpec((br, kcap), lambda i: (i, 0)),
            pl.BlockSpec((br, kcap), lambda i: (i, 0)),
            pl.BlockSpec((br, n), lambda i: (i, 0)),
            pl.BlockSpec((br,), lambda i: (i,)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((acc.shape[0], kcap), jnp.int32),
            jax.ShapeDtypeStruct((acc.shape[0], kcap), jnp.float32),
            jax.ShapeDtypeStruct(acc.shape, jnp.float32),
            jax.ShapeDtypeStruct((acc.shape[0],), jnp.int32),
        ],
        interpret=interpret,
    )(acc)
    if pad:
        idx, val, mem, cnt = idx[:rows], val[:rows], mem[:rows], cnt[:rows]
    return idx, val, mem, cnt

"""Pallas TPU kernels for the paper's compute hot spots.

  * topk_compress — fused blockwise Top_k select (bisection threshold)
    + optional Sign quantize + error-memory update (the per-sync
    compression of ~25M-element accumulators).
  * topk_compact — same selection, compact (idx, val) survivor-buffer
    emission via in-kernel prefix-sum compaction (the sparse wire
    format of aggregate="sparse_allgather") + the fused error memory.
  * flash_attention — causal/sliding-window online-softmax attention
    used by the transformer substrate.
  * qsgd — bucketed stochastic s-level quantization.

Each has a pure-jnp oracle in ``ref.py`` and a jit'd wrapper in
``ops.py``; interpret=True executes the kernel body on CPU for the
correctness sweeps in tests/test_kernels.py.
"""

from repro.kernels import ops, ref

__all__ = ["ops", "ref"]

"""Pallas TPU kernel: causal (optionally sliding-window) flash
attention forward, GQA-aware.

Canonical TPU formulation: grid (batch, q_head, q_blocks, kv_blocks)
with the kv dimension innermost; the online-softmax state (running max
m, normalizer l, f32 accumulator o) lives in VMEM scratch and is carried
across the kv grid steps.  Each program touches exactly one
(q_block x D) query tile and one (kv_block x D) kv tile — VMEM per
program is ~(q_block*D*4 + 2*kv_block*D*2 + q_block*D*4) bytes
(~0.4 MB at 128x128), leaving room for double buffering.

Causality/window: kv tiles that are fully masked for this q tile skip
their compute under ``pl.when`` (on TPU the grid still visits them, but
the MXU work is gated off).
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.kernels.launch_stats import LAUNCHES

NEG_INF = -1e30


def _kernel(q_ref, k_ref, v_ref, o_ref, o_acc, m_acc, l_acc, *,
            nk: int, q_block: int, kv_block: int, window: int, scale: float):
    qi = pl.program_id(2)
    j = pl.program_id(3)

    @pl.when(j == 0)
    def _init():
        o_acc[...] = jnp.zeros_like(o_acc)
        m_acc[...] = jnp.full_like(m_acc, NEG_INF)
        l_acc[...] = jnp.zeros_like(l_acc)

    q_start = qi * q_block
    k_start = j * kv_block
    # tile-level relevance: any (q, k) pair with k <= q and (window)
    relevant = k_start <= q_start + q_block - 1
    if window > 0:
        relevant &= (k_start + kv_block - 1) > (q_start - window)

    @pl.when(relevant)
    def _compute():
        q = q_ref[0, 0].astype(jnp.float32) * scale       # [qb, D]
        k = k_ref[0, 0].astype(jnp.float32)               # [kb, D]
        v = v_ref[0, 0].astype(jnp.float32)
        qpos = q_start + jax.lax.iota(jnp.int32, q_block)
        kpos = k_start + jax.lax.iota(jnp.int32, kv_block)
        s = q @ k.T
        mask = kpos[None, :] <= qpos[:, None]
        if window > 0:
            mask &= kpos[None, :] > qpos[:, None] - window
        s = jnp.where(mask, s, NEG_INF)
        m_prev = m_acc[...]
        m_new = jnp.maximum(m_prev, jnp.max(s, axis=1))
        p = jnp.exp(s - m_new[:, None])
        alpha = jnp.exp(m_prev - m_new)
        l_acc[...] = l_acc[...] * alpha + jnp.sum(p, axis=1)
        o_acc[...] = o_acc[...] * alpha[:, None] + p @ v
        m_acc[...] = m_new

    @pl.when(j == nk - 1)
    def _finish():
        o = o_acc[...] / jnp.maximum(l_acc[...], 1e-30)[:, None]
        o_ref[0, 0] = o.astype(o_ref.dtype)


def flash_attention_fwd(q, k, v, *, window: int = -1, q_block: int = 128,
                        kv_block: int = 128, interpret: bool = False):
    """q: [B, S, H, D]; k, v: [B, S, KV, D] -> [B, S, H, D]."""
    B, S, H, D = q.shape
    KV = k.shape[2]
    G = H // KV
    qb = min(q_block, S)
    kb = min(kv_block, S)
    pad_q = (-S) % qb
    pad_k = (-S) % kb
    Sq, Sk = S + pad_q, S + pad_k
    qt = jnp.moveaxis(q, 2, 1)                        # [B, H, S, D]
    kt = jnp.moveaxis(k, 2, 1)
    vt = jnp.moveaxis(v, 2, 1)
    if pad_q:
        qt = jnp.pad(qt, ((0, 0), (0, 0), (0, pad_q), (0, 0)))
    if pad_k:
        kt = jnp.pad(kt, ((0, 0), (0, 0), (0, pad_k), (0, 0)))
        vt = jnp.pad(vt, ((0, 0), (0, 0), (0, pad_k), (0, 0)))
    nq, nk = Sq // qb, Sk // kb
    grid = (B, H, nq, nk)
    kern = functools.partial(
        _kernel, nk=nk, q_block=qb, kv_block=kb, window=window,
        scale=D ** -0.5,
    )
    out = pl.pallas_call(
        kern,
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, 1, qb, D), lambda b, h, i, j: (b, h, i, 0)),
            pl.BlockSpec((1, 1, kb, D), lambda b, h, i, j: (b, h // G, j, 0)),
            pl.BlockSpec((1, 1, kb, D), lambda b, h, i, j: (b, h // G, j, 0)),
        ],
        out_specs=pl.BlockSpec((1, 1, qb, D), lambda b, h, i, j: (b, h, i, 0)),
        out_shape=jax.ShapeDtypeStruct((B, H, Sq, D), q.dtype),
        scratch_shapes=[
            pltpu.VMEM((qb, D), jnp.float32),
            pltpu.VMEM((qb,), jnp.float32),
            pltpu.VMEM((qb,), jnp.float32),
        ],
        interpret=interpret,
    )(qt, kt, vt)
    out = out[:, :, :S]
    return jnp.moveaxis(out, 1, 2)


# ---------------------------------------------------------------------------
# single-token decode attention against a ring KV cache
# ---------------------------------------------------------------------------


def _decode_kernel(q_ref, k_ref, v_ref, m_ref, o_ref, *, scale: float):
    """One (batch, kv_head) program: the [G, C] score tile fits VMEM
    whole (C is the ring-cache length, bounded by max_len), so a plain
    masked softmax suffices — no online accumulation."""
    q = q_ref[0, 0].astype(jnp.float32) * scale      # [G, hd]
    k = k_ref[0, 0].astype(jnp.float32)              # [C, hd]
    s = jax.lax.dot_general(q, k, (((1,), (1,)), ((), ())),
                            preferred_element_type=jnp.float32)  # [G, C]
    s = jnp.where(m_ref[...] > 0, s, NEG_INF)
    m = jnp.max(s, axis=1, keepdims=True)
    p = jnp.exp(s - m)
    l = jnp.sum(p, axis=1, keepdims=True)
    v = v_ref[0, 0].astype(jnp.float32)              # [C, hd]
    o = jax.lax.dot_general(p, v, (((1,), (0,)), ((), ())),
                            preferred_element_type=jnp.float32)
    o_ref[0, 0] = (o / jnp.maximum(l, 1e-30)).astype(o_ref.dtype)


def flash_decode_fwd(q, k, v, valid, *, interpret: bool = False):
    """Single-token decode attention, GQA-aware.

    q: [B, 1, H, hd] (rope'd at the current position); k, v:
    [B, C, KV, hd] ring-cache contents; valid: [C] slot-validity mask
    (position occupied, causal, inside the window — computed by the
    caller with jnp, so traced windows/positions are fine).  Returns
    [B, 1, H, hd].  Masking by a precomputed slot mask keeps the kernel
    free of position arithmetic: ring order never matters to softmax.
    """
    B, _, H, hd = q.shape
    C, KV = k.shape[1], k.shape[2]
    G = H // KV
    LAUNCHES["flash_decode"] += 1
    q4 = q.reshape(B, 1, KV, G, hd)[:, 0]            # [B, KV, G, hd]
    kt = jnp.moveaxis(k, 2, 1)                       # [B, KV, C, hd]
    vt = jnp.moveaxis(v, 2, 1)
    mask = valid.astype(jnp.float32).reshape(1, C)
    out = pl.pallas_call(
        functools.partial(_decode_kernel, scale=hd ** -0.5),
        grid=(B, KV),
        in_specs=[
            pl.BlockSpec((1, 1, G, hd), lambda b, h: (b, h, 0, 0)),
            pl.BlockSpec((1, 1, C, hd), lambda b, h: (b, h, 0, 0)),
            pl.BlockSpec((1, 1, C, hd), lambda b, h: (b, h, 0, 0)),
            pl.BlockSpec((1, C), lambda b, h: (0, 0)),
        ],
        out_specs=pl.BlockSpec((1, 1, G, hd), lambda b, h: (b, h, 0, 0)),
        out_shape=jax.ShapeDtypeStruct((B, KV, G, hd), q.dtype),
        interpret=interpret,
    )(q4, kt, vt, mask)
    return out.reshape(B, 1, H, hd)

"""Jit'd public wrappers for the Pallas kernels.

``interpret`` defaults to auto: True off-TPU (this container is
CPU-only; interpret mode executes the kernel body in Python/XLA for
validation), False on real TPU backends.
"""

from __future__ import annotations

from functools import partial

import jax

from repro.kernels import flash_attention as _fa
from repro.kernels import qsgd as _qsgd
from repro.kernels import topk_compress as _topk


def _auto_interpret(interpret):
    if interpret is not None:
        return interpret
    return jax.default_backend() != "tpu"


@partial(jax.jit, static_argnames=("k", "iters", "sign", "interpret"))
def topk_compress(acc, k: int, *, iters: int = 24, sign: bool = False,
                  interpret: bool | None = None):
    return _topk.topk_compress(acc, k, iters=iters, sign=sign,
                               interpret=_auto_interpret(interpret))


@partial(jax.jit, static_argnames=("k", "kcap", "iters", "sign",
                                   "interpret"))
def topk_compact(acc, k: int, kcap: int, *, iters: int = 24,
                 sign: bool = False, interpret: bool | None = None):
    return _topk.topk_compact(acc, k, kcap, iters=iters, sign=sign,
                              interpret=_auto_interpret(interpret))


@partial(jax.jit, static_argnames=("window", "q_block", "kv_block",
                                   "interpret"))
def flash_attention(q, k, v, *, window: int = -1, q_block: int = 128,
                    kv_block: int = 128, interpret: bool | None = None):
    return _fa.flash_attention_fwd(
        q, k, v, window=window, q_block=q_block, kv_block=kv_block,
        interpret=_auto_interpret(interpret),
    )


@partial(jax.jit, static_argnames=("s", "interpret"))
def qsgd_quantize(x, u, s: int, *, interpret: bool | None = None):
    return _qsgd.qsgd_quantize(x, u, s, interpret=_auto_interpret(interpret))

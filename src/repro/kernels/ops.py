"""Jit'd public wrappers for the Pallas kernels.

``interpret`` defaults to auto: True off-TPU (this container is
CPU-only; interpret mode executes the kernel body in Python/XLA for
validation), False on real TPU backends.
"""

from __future__ import annotations

from functools import partial

import jax
import jax.numpy as jnp

from repro.kernels import flash_attention as _fa
from repro.kernels import paged_attention as _pa
from repro.kernels import qsgd as _qsgd
from repro.kernels import sparse_gemm as _sg
from repro.kernels import topk_compress as _topk


def _auto_interpret(interpret):
    if interpret is not None:
        return interpret
    return jax.default_backend() != "tpu"


@partial(jax.jit, static_argnames=("k", "iters", "sign", "interpret"))
def topk_compress(acc, k: int, *, iters: int = 24, sign: bool = False,
                  interpret: bool | None = None):
    return _topk.topk_compress(acc, k, iters=iters, sign=sign,
                               interpret=_auto_interpret(interpret))


@partial(jax.jit, static_argnames=("k", "kcap", "iters", "sign",
                                   "interpret"))
def topk_compact(acc, k: int, kcap: int, *, iters: int = 24,
                 sign: bool = False, interpret: bool | None = None):
    return _topk.topk_compact(acc, k, kcap, iters=iters, sign=sign,
                              interpret=_auto_interpret(interpret))


@partial(jax.jit, static_argnames=("window", "q_block", "kv_block",
                                   "interpret"))
def flash_attention(q, k, v, *, window: int = -1, q_block: int = 128,
                    kv_block: int = 128, interpret: bool | None = None):
    return _fa.flash_attention_fwd(
        q, k, v, window=window, q_block=q_block, kv_block=kv_block,
        interpret=_auto_interpret(interpret),
    )


@partial(jax.jit, static_argnames=("s", "interpret"))
def qsgd_quantize(x, u, s: int, *, interpret: bool | None = None):
    return _qsgd.qsgd_quantize(x, u, s, interpret=_auto_interpret(interpret))


@partial(jax.jit, static_argnames=("interpret",))
def flash_decode(q, k, v, valid, *, interpret: bool | None = None):
    return _fa.flash_decode_fwd(q, k, v, valid,
                                interpret=_auto_interpret(interpret))


@partial(jax.jit, static_argnames=("pages_per_block", "interpret"))
def _paged_decode_jit(q, kp, vp, kscale, vscale, tables, lengths, *,
                      pages_per_block: int, interpret: bool):
    return _pa.paged_decode_fwd(q, kp, vp, kscale, vscale, tables, lengths,
                                pages_per_block=pages_per_block,
                                interpret=interpret)


def paged_decode(q, kp, vp, kscale, vscale, tables, lengths, *,
                 pages_per_block: int | None = None,
                 interpret: bool | None = None):
    """Paged flash-decode over a KV page pool (kernels/paged_attention).

    ``pages_per_block`` (the kernel geometry, static) defaults to the
    autotuned table resolution for this (table width, page size,
    head_dim, quantized) signature — resolved *before* the jit so a
    tuned geometry never triggers a retrace inside a serving step.
    """
    if pages_per_block is None:
        from repro.kernels import dispatch as _dsp
        pages_per_block = _dsp.paged_geometry(
            None, tables.shape[-1], kp.shape[-3], kp.shape[-1],
            kp.dtype == jnp.int8)
    return _paged_decode_jit(q, kp, vp, kscale, vscale, tables, lengths,
                             pages_per_block=pages_per_block,
                             interpret=_auto_interpret(interpret))


@partial(jax.jit, static_argnames=("row_len", "block_m", "block_rows",
                                   "chunk", "interpret"))
def sparse_gemm(x, idx, val, row_len: int, *, block_m: int = 128,
                block_rows: int = 8, chunk: int = 128,
                interpret: bool | None = None):
    return _sg.sparse_gemm(x, idx, val, row_len, block_m=block_m,
                           block_rows=block_rows, chunk=chunk,
                           interpret=_auto_interpret(interpret))


@partial(jax.jit, static_argnames=("block_m", "block_rows", "interpret"))
def qdq_gemm(x, levels, scale, *, block_m: int = 128, block_rows: int = 8,
             interpret: bool | None = None):
    return _sg.qdq_gemm(x, levels, scale, block_m=block_m,
                        block_rows=block_rows,
                        interpret=_auto_interpret(interpret))

"""Pallas TPU kernel: bucketed QSGD stochastic quantization.

Standard production QSGD buckets the vector (norm per bucket) so the
kernel is single-pass: each program loads one row-block, computes the
per-row (bucket) l2 norm, stochastically rounds |x|/norm into s levels
using externally supplied uniform randoms (keeps the oracle bit-exact
and the kernel deterministic given its operands), and writes the
dequantized values.

Block shape (ROWS, bucket) — VPU elementwise + one row reduction; no MXU.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from repro.kernels.launch_stats import LAUNCHES


def _kernel(x_ref, u_ref, o_ref, *, s: int):
    x = x_ref[...].astype(jnp.float32)
    u = u_ref[...].astype(jnp.float32)
    norm = jnp.sqrt(jnp.sum(x * x, axis=1, keepdims=True))
    safe = jnp.where(norm > 0, norm, 1.0)
    level = jnp.abs(x) / safe * s
    low = jnp.floor(level)
    xi = low + (u < (level - low)).astype(jnp.float32)
    q = norm * jnp.sign(x) * xi / s
    o_ref[...] = jnp.where(norm > 0, q, 0.0).astype(o_ref.dtype)


def qsgd_quantize(x: jax.Array, u: jax.Array, s: int, *,
                  block_rows: int = 8, interpret: bool = False):
    """x, u: [buckets, n] -> dequantized [buckets, n] (f32)."""
    LAUNCHES["qsgd"] += 1
    rows, n = x.shape
    br = min(block_rows, rows)
    pad = (-rows) % br
    if pad:
        x = jnp.pad(x, ((0, pad), (0, 0)))
        u = jnp.pad(u, ((0, pad), (0, 0)))
    grid = (x.shape[0] // br,)
    out = pl.pallas_call(
        functools.partial(_kernel, s=s),
        grid=grid,
        in_specs=[
            pl.BlockSpec((br, n), lambda i: (i, 0)),
            pl.BlockSpec((br, n), lambda i: (i, 0)),
        ],
        out_specs=pl.BlockSpec((br, n), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct(x.shape, jnp.float32),
        interpret=interpret,
    )(x, u)
    return out[:rows]

"""Pallas TPU kernels for the compressed-weight serving forward
(DESIGN.md §11).

Two GEMM families, both consuming the storage layouts that
``serve/compressed.py`` builds from a trained checkpoint's persisted
PolicySpec:

  * :func:`sparse_gemm` — sparse-weight × dense-activation product over
    the compact ``(idx, val)`` survivor buffers of DESIGN.md §3.3
    (rows enumerate the *output* features, indices are row-local input
    coordinates, empty slots carry the ``idx = row_len, val = 0``
    sentinel).  Each grid program decodes one ``(block_rows, chunk)``
    weight tile from its survivor slots via the same chunked one-hot
    contraction the compact compressor uses — the tile lives only in
    VMEM registers, the dense weight never exists in HBM — and feeds it
    straight to the MXU against the resident activation block.

  * :func:`qdq_gemm` — QSGD-dequantize-fused product over per-row
    integer levels + f32 scales: ``y = x @ (levels * scale).T`` with the
    dequantize folded into the same VMEM residency as the matmul.

Both are tiled over (activation rows, weight rows); geometry
(``block_rows`` height of the weight tile, decode ``chunk``) is
autotunable (kernels/autotune.py) and changes timing only — outputs are
bit-identical across geometries.  Oracles live in ``kernels/ref.py``.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from repro.kernels.launch_stats import LAUNCHES

DEFAULT_BLOCK_M = 128


def _pad_dim(x: jnp.ndarray, axis: int, multiple: int,
             value=0) -> jnp.ndarray:
    pad = (-x.shape[axis]) % multiple
    if not pad:
        return x
    widths = [(0, 0)] * x.ndim
    widths[axis] = (0, pad)
    return jnp.pad(x, widths, constant_values=value)


# ---------------------------------------------------------------------------
# sparse (idx, val) GEMM
# ---------------------------------------------------------------------------


def _sparse_kernel(x_ref, idx_ref, val_ref, o_ref, *, chunk: int):
    """One (block_m, block_rows) output tile.

    x_ref: [block_m, n] activations; idx_ref/val_ref: [block_rows, kcap]
    survivor buffers; o_ref: [block_m, block_rows].  The weight tile is
    decoded chunk-by-chunk with a one-hot contraction (MXU-friendly, no
    scatter) and immediately contracted against the matching activation
    columns; sentinel slots (val = 0) contribute nothing.
    """
    n = x_ref.shape[1]
    bm = x_ref.shape[0]
    br = idx_ref.shape[0]
    idx = idx_ref[...]
    val = val_ref[...].astype(jnp.float32)

    def body(j, acc):
        base = j * chunk
        cols = base + jax.lax.broadcasted_iota(jnp.int32, (1, 1, chunk), 2)
        # [br, kcap, chunk] one-hot of each survivor against this chunk
        oh = (idx[:, :, None] == cols).astype(jnp.float32)
        # decode the (br, chunk) weight tile: w[r, c] = sum_s val[r,s]*oh
        w = jax.lax.dot_general(
            val, oh, (((1,), (1,)), ((0,), (0,))),
            preferred_element_type=jnp.float32)
        xc = x_ref[:, pl.dslice(base, chunk)].astype(jnp.float32)
        return acc + jax.lax.dot_general(
            xc, w, (((1,), (1,)), ((), ())),
            preferred_element_type=jnp.float32)

    acc = jax.lax.fori_loop(0, n // chunk, body,
                            jnp.zeros((bm, br), jnp.float32))
    o_ref[...] = acc.astype(o_ref.dtype)


def sparse_gemm(x: jnp.ndarray, idx: jnp.ndarray, val: jnp.ndarray,
                row_len: int, *, block_m: int = DEFAULT_BLOCK_M,
                block_rows: int = 8, chunk: int = 128,
                interpret: bool = False) -> jnp.ndarray:
    """``y[m, r] = sum_s val[r, s] * x[m, idx[r, s]]``.

    x: [M, row_len] dense activations (any float dtype; f32 compute).
    idx/val: [R, kcap] compact survivor buffers (row-local indices,
    out-of-row sentinel ``idx = row_len, val = 0``).  Returns [M, R] f32.
    """
    M, n = x.shape
    R, kcap = idx.shape
    if n != row_len:
        raise ValueError(f"x has {n} features, buffers expect {row_len}")
    LAUNCHES["sparse_gemm"] += 1
    xp = _pad_dim(x.astype(jnp.float32), 1, chunk)
    xp = _pad_dim(xp, 0, min(block_m, max(M, 1)))
    bm = min(block_m, xp.shape[0])
    xp = _pad_dim(xp, 0, bm)
    br = min(block_rows, R)
    # sentinel-pad extra rows: idx = row_len never matches a real column
    # and val = 0 kills the padded-column match
    idxp = _pad_dim(idx, 0, br, value=row_len)
    valp = _pad_dim(val, 0, br)
    n_p = xp.shape[1]
    grid = (xp.shape[0] // bm, idxp.shape[0] // br)
    out = pl.pallas_call(
        functools.partial(_sparse_kernel, chunk=chunk),
        grid=grid,
        in_specs=[
            pl.BlockSpec((bm, n_p), lambda m, r: (m, 0)),
            pl.BlockSpec((br, kcap), lambda m, r: (r, 0)),
            pl.BlockSpec((br, kcap), lambda m, r: (r, 0)),
        ],
        out_specs=pl.BlockSpec((bm, br), lambda m, r: (m, r)),
        out_shape=jax.ShapeDtypeStruct((xp.shape[0], idxp.shape[0]),
                                       jnp.float32),
        interpret=interpret,
    )(xp, idxp, valp)
    return out[:M, :R]


# ---------------------------------------------------------------------------
# QSGD-dequantize-fused GEMM
# ---------------------------------------------------------------------------


def _qdq_kernel(x_ref, lv_ref, scale_ref, o_ref):
    """One (block_m, block_rows) output tile: dequantize the integer
    weight tile in VMEM (levels * per-row scale) and contract."""
    w = lv_ref[...].astype(jnp.float32) * scale_ref[...].astype(jnp.float32)
    o_ref[...] = jax.lax.dot_general(
        x_ref[...].astype(jnp.float32), w, (((1,), (1,)), ((), ())),
        preferred_element_type=jnp.float32).astype(o_ref.dtype)


def qdq_gemm(x: jnp.ndarray, levels: jnp.ndarray, scale: jnp.ndarray,
             *, block_m: int = DEFAULT_BLOCK_M, block_rows: int = 8,
             interpret: bool = False) -> jnp.ndarray:
    """``y = x @ (levels * scale).T`` with the dequantize fused.

    x: [M, n]; levels: [R, n] integer QSGD levels (sign * xi); scale:
    [R, 1] f32 per-row scale (||w_row|| / s).  Returns [M, R] f32.
    """
    M, n = x.shape
    R = levels.shape[0]
    if levels.shape[1] != n:
        raise ValueError(
            f"x has {n} features, levels rows have {levels.shape[1]}")
    LAUNCHES["qdq_gemm"] += 1
    xp = _pad_dim(x.astype(jnp.float32), 0, min(block_m, max(M, 1)))
    bm = min(block_m, xp.shape[0])
    xp = _pad_dim(xp, 0, bm)
    br = min(block_rows, R)
    lvp = _pad_dim(levels, 0, br)
    scp = _pad_dim(scale.astype(jnp.float32).reshape(R, 1), 0, br)
    grid = (xp.shape[0] // bm, lvp.shape[0] // br)
    out = pl.pallas_call(
        _qdq_kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((bm, n), lambda m, r: (m, 0)),
            pl.BlockSpec((br, n), lambda m, r: (r, 0)),
            pl.BlockSpec((br, 1), lambda m, r: (r, 0)),
        ],
        out_specs=pl.BlockSpec((bm, br), lambda m, r: (m, r)),
        out_shape=jax.ShapeDtypeStruct((xp.shape[0], lvp.shape[0]),
                                       jnp.float32),
        interpret=interpret,
    )(xp, lvp, scp)
    return out[:M, :R]

"""rwkv6-3b "Finch" — attention-free, data-dependent decay
[arXiv:2404.05892]."""
from repro.configs.base import ModelConfig


def full() -> ModelConfig:
    return ModelConfig(
        name="rwkv6-3b", family="rwkv6",
        n_layers=32, d_model=2560, d_ff=8960, vocab=65536,
        ssm_head_dim=64, max_seq_len=1 << 20,
        source="arXiv:2404.05892",
    )


def smoke() -> ModelConfig:
    return ModelConfig(
        name="rwkv6-3b-smoke", family="rwkv6",
        n_layers=2, d_model=128, d_ff=448, vocab=512,
        ssm_head_dim=16, max_seq_len=256,
        param_dtype="float32", act_dtype="float32",
        source="arXiv:2404.05892",
    )

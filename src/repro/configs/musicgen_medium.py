"""musicgen-medium — decoder-only over EnCodec tokens; the EnCodec
conv codec frontend is the allowed stub (precomputed conditioning frame
embeddings are prepended) [arXiv:2306.05284]."""
from repro.configs.base import ModelConfig


def full() -> ModelConfig:
    return ModelConfig(
        name="musicgen-medium", family="dense",
        n_layers=48, d_model=1536, n_heads=24, n_kv_heads=24, head_dim=64,
        d_ff=6144, vocab=2048, rope_theta=1e4, max_seq_len=32768,
        modality="audio", n_frontend_tokens=64,
        source="arXiv:2306.05284",
    )


def smoke() -> ModelConfig:
    return ModelConfig(
        name="musicgen-medium-smoke", family="dense",
        n_layers=2, d_model=192, n_heads=6, n_kv_heads=6, head_dim=32,
        d_ff=384, vocab=512, max_seq_len=256,
        modality="audio", n_frontend_tokens=8,
        param_dtype="float32", act_dtype="float32", q_chunk=32,
        source="arXiv:2306.05284",
    )

"""qwen3-moe-30b-a3b — 128 experts, top-8, per-expert ff 768
[hf:Qwen/Qwen3-30B-A3B]."""
from repro.configs.base import ModelConfig


def full() -> ModelConfig:
    return ModelConfig(
        name="qwen3-moe-30b-a3b", family="moe",
        n_layers=48, d_model=2048, n_heads=32, n_kv_heads=4, head_dim=128,
        d_ff=768, vocab=151936, rope_theta=1e6, max_seq_len=32768,
        n_experts=128, moe_top_k=8, moe_interleave=1,
        capacity_factor=1.25,
        source="hf:Qwen/Qwen3-30B-A3B",
    )


def smoke() -> ModelConfig:
    return ModelConfig(
        name="qwen3-moe-smoke", family="moe",
        n_layers=2, d_model=128, n_heads=4, n_kv_heads=2, head_dim=32,
        d_ff=64, vocab=512, max_seq_len=256,
        n_experts=4, moe_top_k=2, moe_interleave=1, capacity_factor=4.0,
        param_dtype="float32", act_dtype="float32", q_chunk=32,
        source="hf:Qwen/Qwen3-30B-A3B",
    )

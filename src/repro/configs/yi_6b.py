"""yi-6b — llama-arch dense GQA [arXiv:2403.04652]."""
from repro.configs.base import ModelConfig


def full() -> ModelConfig:
    return ModelConfig(
        name="yi-6b", family="dense",
        n_layers=32, d_model=4096, n_heads=32, n_kv_heads=4, head_dim=128,
        d_ff=11008, vocab=64000, rope_theta=5e6, max_seq_len=32768,
        source="arXiv:2403.04652",
    )


def smoke() -> ModelConfig:
    return ModelConfig(
        name="yi-6b-smoke", family="dense",
        n_layers=2, d_model=256, n_heads=8, n_kv_heads=1, head_dim=32,
        d_ff=688, vocab=512, rope_theta=5e6, max_seq_len=256,
        param_dtype="float32", act_dtype="float32", q_chunk=32,
        source="arXiv:2403.04652",
    )

"""gemma3-1b — 5:1 local:global attention (window 1024), kv=1,
262k vocab, 128k rope [hf:google/gemma-3-1b-pt]."""
from repro.configs.base import ModelConfig

_PATTERN = (1024, 1024, 1024, 1024, 1024, -1)


def full() -> ModelConfig:
    return ModelConfig(
        name="gemma3-1b", family="dense",
        n_layers=26, d_model=1152, n_heads=4, n_kv_heads=1, head_dim=256,
        d_ff=6912, vocab=262144, rope_theta=1e6, max_seq_len=131072,
        swa_pattern=_PATTERN, tie_embeddings=True,
        source="hf:google/gemma-3-1b-pt",
    )


def smoke() -> ModelConfig:
    return ModelConfig(
        name="gemma3-1b-smoke", family="dense",
        n_layers=2, d_model=128, n_heads=4, n_kv_heads=1, head_dim=32,
        d_ff=432, vocab=512, max_seq_len=256,
        swa_pattern=(16, -1), tie_embeddings=True,
        param_dtype="float32", act_dtype="float32", q_chunk=32,
        source="hf:google/gemma-3-1b-pt",
    )

"""yi-34b — llama-arch dense GQA [arXiv:2403.04652]."""
from repro.configs.base import ModelConfig


def full() -> ModelConfig:
    return ModelConfig(
        name="yi-34b", family="dense",
        n_layers=60, d_model=7168, n_heads=56, n_kv_heads=8, head_dim=128,
        d_ff=20480, vocab=64000, rope_theta=5e6, max_seq_len=32768,
        q_chunk=128,
        source="arXiv:2403.04652",
    )


def smoke() -> ModelConfig:
    return ModelConfig(
        name="yi-34b-smoke", family="dense",
        n_layers=2, d_model=256, n_heads=8, n_kv_heads=2, head_dim=32,
        d_ff=640, vocab=512, max_seq_len=256,
        param_dtype="float32", act_dtype="float32", q_chunk=32,
        source="arXiv:2403.04652",
    )

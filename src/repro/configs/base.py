"""Model/run configuration schema.

Every assigned architecture gets a module ``configs/<id>.py`` exporting
``full()`` (the exact published config) and ``smoke()`` (a reduced
variant of the same family: <=2 layers, d_model<=512, <=4 experts) —
the full config is exercised only through the ShapeDtypeStruct dry-run.
"""

from __future__ import annotations

import dataclasses
from typing import Optional, Tuple

import jax.numpy as jnp
from jax.sharding import PartitionSpec as P


@dataclasses.dataclass(frozen=True)
class ShardingPolicy:
    """Optional activation sharding constraints (None => no constraint).

    Only 'model'-axis entries are legal inside the shard_map training
    engine (data axes are manual there); the serving path may use full
    specs including batch axes.
    """

    act: Optional[P] = None          # [B, S, D] boundaries between layers
    logits: Optional[P] = None       # [B, S, V]
    kv_cache: Optional[P] = None     # [B, S, KV, HD]
    ssm_state: Optional[P] = None    # [B, H, K, V] recurrent states
    ep_axis: Optional[str] = None    # mesh axis for explicit expert parallelism
    vary_axes: Tuple[str, ...] = ()  # manual axes the model code runs under
                                     # (shard_map training engine); scan init
                                     # carries must be pvary'd over these


NO_SHARDING = ShardingPolicy()


@dataclasses.dataclass(frozen=True)
class ModelConfig:
    name: str = "model"
    family: str = "dense"            # dense | moe | rwkv6 | zamba2 | softmax | resnet
    # transformer common
    n_layers: int = 2
    d_model: int = 256
    n_heads: int = 4
    n_kv_heads: int = 4
    head_dim: Optional[int] = None   # default d_model // n_heads
    d_ff: int = 1024
    vocab: int = 1000
    rope_theta: float = 1e4
    max_seq_len: int = 8192
    tie_embeddings: bool = False
    # attention pattern: window size per layer; -1 = global full attention.
    # ``swa_pattern=(w, w, w, w, w, -1)`` means 5 local : 1 global (gemma3).
    swa_pattern: Optional[Tuple[int, ...]] = None
    # MoE
    n_experts: int = 0
    moe_top_k: int = 1
    moe_interleave: int = 1          # every Nth layer is MoE (1 = all)
    shared_expert: bool = False
    capacity_factor: float = 1.25
    router_aux_weight: float = 0.01
    dense_ff: Optional[int] = None   # FFN width of non-MoE interleaved layers
                                     # and the shared expert (default d_ff)
    # SSM (mamba2 / rwkv6)
    ssm_state: int = 64
    ssm_head_dim: int = 64
    ssm_expand: int = 2
    ssm_conv: int = 4
    attn_every: int = 6              # zamba2: shared attn block cadence
    # multimodal stubs
    modality: Optional[str] = None   # None | "audio" | "vision"
    n_frontend_tokens: int = 256     # patches / frames prepended
    # numerics / execution
    param_dtype: str = "bfloat16"
    act_dtype: str = "bfloat16"
    q_chunk: int = 512               # chunked attention query block
    remat: bool = True
    scan_layers: bool = True
    use_pallas: bool = False         # route attention through the Pallas kernel
    # citation for the assigned config
    source: str = ""

    # -- derived -----------------------------------------------------------
    @property
    def hd(self) -> int:
        return self.head_dim if self.head_dim is not None else self.d_model // self.n_heads

    @property
    def pdtype(self):
        return jnp.dtype(self.param_dtype)

    @property
    def adtype(self):
        return jnp.dtype(self.act_dtype)

    def layer_windows(self) -> Tuple[int, ...]:
        """Per-layer attention window (-1 = full)."""
        if self.swa_pattern is None:
            return tuple([-1] * self.n_layers)
        pat = self.swa_pattern
        return tuple(pat[i % len(pat)] for i in range(self.n_layers))

    def param_count(self) -> int:
        """Analytic parameter count (dense embedding + stack)."""
        d, ff, V, L = self.d_model, self.d_ff, self.vocab, self.n_layers
        hd = self.hd
        if self.family == "softmax":
            return (self.d_model + 1) * self.vocab
        n = V * d  # embed
        if not self.tie_embeddings:
            n += d * V
        n += d  # final norm
        if self.family == "rwkv6":
            att = d * (4 * d) + 6 * d  # r,k,v,o + decays/mixes (approx lora'd)
            ffn = d * ff + ff * d
            n += L * (att + ffn + 2 * d)
            return n
        if self.family == "zamba2":
            din = self.ssm_expand * d
            mamba = d * (2 * din) + din * d + din * (2 * self.ssm_state) + din
            n += L * (mamba + 2 * d)
            # shared attention+mlp block (counted once)
            n += 4 * d * self.n_heads * hd + 3 * d * ff
            return n
        attn = d * self.n_heads * hd + 2 * d * self.n_kv_heads * hd \
            + self.n_heads * hd * d
        dense_ffn = 3 * d * ff
        if self.family == "moe":
            dff = self.dense_ff or ff
            moe_layers = sum(
                1 for i in range(L) if (i + 1) % self.moe_interleave == 0
            )
            dense_layers = L - moe_layers
            expert_ffn = self.n_experts * 3 * d * ff + d * self.n_experts
            if self.shared_expert:
                expert_ffn += 3 * d * dff
            n += L * (attn + 2 * d) + dense_layers * 3 * d * dff \
                + moe_layers * expert_ffn
        else:
            n += L * (attn + dense_ffn + 2 * d)
        return n

    def active_param_count(self) -> int:
        """Active params per token (MoE: only routed experts)."""
        if self.family != "moe":
            return self.param_count()
        d, ff, V, L = self.d_model, self.d_ff, self.vocab, self.n_layers
        hd = self.hd
        attn = d * self.n_heads * hd + 2 * d * self.n_kv_heads * hd \
            + self.n_heads * hd * d
        dff = self.dense_ff or ff
        moe_layers = sum(1 for i in range(L) if (i + 1) % self.moe_interleave == 0)
        dense_layers = L - moe_layers
        act_ffn = self.moe_top_k * 3 * d * ff + (3 * d * dff if self.shared_expert else 0)
        n = 2 * V * d + d + L * (attn + 2 * d) \
            + dense_layers * 3 * d * dff + moe_layers * act_ffn
        return n


# ---------------------------------------------------------------------------
# assigned input shapes
# ---------------------------------------------------------------------------


@dataclasses.dataclass(frozen=True)
class InputShape:
    name: str
    seq_len: int
    global_batch: int
    kind: str  # "train" | "prefill" | "decode"


INPUT_SHAPES = {
    "train_4k": InputShape("train_4k", 4_096, 256, "train"),
    "prefill_32k": InputShape("prefill_32k", 32_768, 32, "prefill"),
    "decode_32k": InputShape("decode_32k", 32_768, 128, "decode"),
    "long_500k": InputShape("long_500k", 524_288, 1, "decode"),
}

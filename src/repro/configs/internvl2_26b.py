"""internvl2-26b — InternLM2-20B language backbone consuming InternViT
patch embeddings; the ViT+projector frontend is the allowed stub
[arXiv:2404.16821]."""
from repro.configs.base import ModelConfig


def full() -> ModelConfig:
    return ModelConfig(
        name="internvl2-26b", family="dense",
        n_layers=48, d_model=6144, n_heads=48, n_kv_heads=8, head_dim=128,
        d_ff=16384, vocab=92553, rope_theta=1e6, max_seq_len=32768,
        modality="vision", n_frontend_tokens=256,
        source="arXiv:2404.16821",
    )


def smoke() -> ModelConfig:
    return ModelConfig(
        name="internvl2-26b-smoke", family="dense",
        n_layers=2, d_model=192, n_heads=6, n_kv_heads=2, head_dim=32,
        d_ff=512, vocab=512, max_seq_len=256,
        modality="vision", n_frontend_tokens=16,
        param_dtype="float32", act_dtype="float32", q_chunk=32,
        source="arXiv:2404.16821",
    )

"""Config registry: ``--arch <id>`` resolution for every assigned
architecture (plus the paper's own experiment models, which live in
models/resnet.py and models/softmax.py)."""

from __future__ import annotations

import importlib

from repro.configs.base import INPUT_SHAPES, InputShape, ModelConfig
from repro.configs.policies import (
    ARCH_POLICIES,
    POLICY_PRESETS,
    get_policy_preset,
)

ARCHS = {
    "yi-6b": "yi_6b",
    "stablelm-3b": "stablelm_3b",
    "llama4-maverick-400b-a17b": "llama4_maverick_400b_a17b",
    "gemma3-1b": "gemma3_1b",
    "rwkv6-3b": "rwkv6_3b",
    "musicgen-medium": "musicgen_medium",
    "qwen3-moe-30b-a3b": "qwen3_moe_30b_a3b",
    "yi-34b": "yi_34b",
    "zamba2-7b": "zamba2_7b",
    "internvl2-26b": "internvl2_26b",
}

#: archs with a sub-quadratic long-context path => run long_500k
LONG_CONTEXT_ARCHS = {"rwkv6-3b", "zamba2-7b", "gemma3-1b"}


def _module(arch: str):
    if arch not in ARCHS:
        raise KeyError(f"unknown arch {arch!r}; have {sorted(ARCHS)}")
    return importlib.import_module(f"repro.configs.{ARCHS[arch]}")


def get_config(arch: str, *, smoke: bool = False, **kw) -> ModelConfig:
    mod = _module(arch)
    return mod.smoke() if smoke else mod.full(**kw)


def shape_supported(arch: str, shape: str) -> tuple[bool, str]:
    """(supported, reason) for the 10x4 dry-run matrix."""
    sh = INPUT_SHAPES[shape]
    if shape == "long_500k" and arch not in LONG_CONTEXT_ARCHS:
        return False, "full-attention arch; no sub-quadratic variant (DESIGN.md)"
    return True, ""


__all__ = [
    "ARCHS",
    "ARCH_POLICIES",
    "LONG_CONTEXT_ARCHS",
    "INPUT_SHAPES",
    "InputShape",
    "ModelConfig",
    "POLICY_PRESETS",
    "get_config",
    "get_policy_preset",
    "shape_supported",
]

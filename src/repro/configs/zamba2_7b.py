"""zamba2-7b — Mamba2 backbone + shared attention blocks
[arXiv:2411.15242].  swa_pattern supplies the shared block's window at
long context (long_500k); -1 (full) elsewhere."""
from repro.configs.base import ModelConfig


def full(long_context: bool = False) -> ModelConfig:
    return ModelConfig(
        name="zamba2-7b", family="zamba2",
        n_layers=81, d_model=3584, n_heads=32, n_kv_heads=32, head_dim=112,
        d_ff=14336, vocab=32000, max_seq_len=1 << 20,
        ssm_state=64, ssm_head_dim=64, ssm_expand=2, ssm_conv=4,
        attn_every=6,
        swa_pattern=(4096,) if long_context else None,
        source="arXiv:2411.15242",
    )


def smoke() -> ModelConfig:
    return ModelConfig(
        name="zamba2-7b-smoke", family="zamba2",
        n_layers=4, d_model=128, n_heads=4, n_kv_heads=4, head_dim=32,
        d_ff=256, vocab=512, max_seq_len=256,
        ssm_state=16, ssm_head_dim=16, ssm_expand=2, ssm_conv=4,
        attn_every=2,
        param_dtype="float32", act_dtype="float32", q_chunk=32,
        source="arXiv:2411.15242",
    )

"""stablelm-3b — dense MHA (kv = heads) [hf:stabilityai/stablelm-2-1_6b]."""
from repro.configs.base import ModelConfig


def full() -> ModelConfig:
    return ModelConfig(
        name="stablelm-3b", family="dense",
        n_layers=32, d_model=2560, n_heads=32, n_kv_heads=32, head_dim=80,
        d_ff=6912, vocab=50304, rope_theta=1e4, max_seq_len=16384,
        source="hf:stabilityai/stablelm-2-1_6b",
    )


def smoke() -> ModelConfig:
    return ModelConfig(
        name="stablelm-3b-smoke", family="dense",
        n_layers=2, d_model=256, n_heads=8, n_kv_heads=8, head_dim=32,
        d_ff=432, vocab=512, max_seq_len=256,
        param_dtype="float32", act_dtype="float32", q_chunk=32,
        source="hf:stabilityai/stablelm-2-1_6b",
    )

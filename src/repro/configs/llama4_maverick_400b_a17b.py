"""llama4-maverick-400b-a17b — interleaved MoE (every 2nd layer MoE,
128 experts top-1, shared expert), early-fusion multimodal backbone
[hf:meta-llama/Llama-4-Scout-17B-16E].

Param budget check (ModelConfig.param_count): 24 MoE layers x 128
experts x 3*5120*8192 ~= 386B + dense/attn/embed ~= 400B total,
~17B active.
"""
from repro.configs.base import ModelConfig


def full() -> ModelConfig:
    return ModelConfig(
        name="llama4-maverick-400b-a17b", family="moe",
        n_layers=48, d_model=5120, n_heads=40, n_kv_heads=8, head_dim=128,
        d_ff=8192, dense_ff=16384, vocab=202048, rope_theta=5e5,
        max_seq_len=32768,
        n_experts=128, moe_top_k=1, moe_interleave=2, shared_expert=True,
        capacity_factor=1.25,
        source="hf:meta-llama/Llama-4-Scout-17B-16E",
    )


def smoke() -> ModelConfig:
    return ModelConfig(
        name="llama4-maverick-smoke", family="moe",
        n_layers=2, d_model=128, n_heads=4, n_kv_heads=2, head_dim=32,
        d_ff=96, dense_ff=192, vocab=512, max_seq_len=256,
        n_experts=4, moe_top_k=1, moe_interleave=2, shared_expert=True,
        capacity_factor=4.0,
        param_dtype="float32", act_dtype="float32", q_chunk=32,
        source="hf:meta-llama/Llama-4-Scout-17B-16E",
    )

"""Per-architecture compression-policy presets (DESIGN.md §6).

Named ``core.policy`` DSL strings for ``launch/train.py --policy
preset:<name>`` (or ``preset:arch`` to pick by ``--arch``).  The
heterogeneous presets follow the paper's layer-wise Top_k setup plus
Wangni et al.'s observation that *where* the sparsity budget lands
matters: aggressive Top_k on the big matmuls, QSGD on the embedding /
head tables, dense (identity) on the norms, biases and other small
glue the error-feedback memory should not be spent on.

Pattern vocabulary (leaf paths are '/'-joined, e.g. ``layers/attn/wq``;
see ``core.policy.tree_paths``): transformer stacks expose
``embed|head|final_norm|layers/attn/*|layers/mlp/*|layers/ln*``; the
SSM families expose their own mixer names, matched by the family
presets below.
"""

from __future__ import annotations

from repro.core import policy as pol

#: named presets (DSL strings — parse with ``core.policy.parse``)
POLICY_PRESETS: dict[str, str] = {
    # the historical homogeneous default (catch-all Top_k 1%)
    "uniform_topk": "topk:k=0.01",
    # heterogeneous: dense norms/biases, QSGD embeddings/head, Top_k
    # on everything big — the ResNet-50-style layer-wise setup
    "lm_hetero": ("ln|norm|bias|scale|gate_bias->identity;"
                  "embed|head->qsgd:s=15;"
                  ".*->topk:k=0.01"),
    # bidirectional: same uplink + an error-compensated Top_k downlink
    "lm_hetero_bidir": ("ln|norm|bias|scale|gate_bias->identity;"
                        "embed|head->qsgd:s=15;"
                        ".*->topk:k=0.01"
                        " >> ln|norm|bias->identity;.*->topk:k=0.05"),
    # one global survivor budget (1% of the matched dims) spent
    # proportional to leaf size across the matmul leaves
    "budget_1pct": ("budget=0.01;"
                    "attn|mlp|ffn|expert|proj|mixer->topk;"
                    ".*->identity"),
    # 1-bit wire: SignTop_k everywhere it pays, dense glue
    "sign_hetero": ("ln|norm|bias|scale->identity;"
                    ".*->signtopk:k=0.01,m=2"),
}

#: default preset per assigned architecture (``preset:arch``)
ARCH_POLICIES: dict[str, str] = {
    "yi-6b": "lm_hetero",
    "yi-34b": "lm_hetero",
    "stablelm-3b": "lm_hetero",
    "gemma3-1b": "lm_hetero",
    "llama4-maverick-400b-a17b": "budget_1pct",
    "qwen3-moe-30b-a3b": "budget_1pct",
    "musicgen-medium": "lm_hetero",
    "internvl2-26b": "lm_hetero",
    "rwkv6-3b": "sign_hetero",
    "zamba2-7b": "sign_hetero",
}


def get_policy_preset(name: str, arch: str | None = None):
    """Resolve ``preset:<name>`` (or ``preset:arch``) to a parsed
    ``PolicySpec``/``ChannelSpec``.  Unknown names fail loudly."""
    if name == "arch":
        if arch is None or arch not in ARCH_POLICIES:
            raise KeyError(
                f"no per-arch policy preset for {arch!r}; have "
                f"{sorted(ARCH_POLICIES)}")
        name = ARCH_POLICIES[arch]
    if name not in POLICY_PRESETS:
        raise KeyError(
            f"unknown policy preset {name!r}; have {sorted(POLICY_PRESETS)}")
    return pol.parse(POLICY_PRESETS[name])

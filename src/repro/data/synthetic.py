"""Deterministic synthetic data pipelines.

The paper's setting is *distributed* data: worker r draws from its own
local dataset D_r.  Every generator here is seeded per worker so the
R-worker batch [R, b, ...] is reproducible, and supports a ``non_iid``
knob that skews each worker's distribution (class subsets / distinct
Markov chains), which is where local-SGD/error-feedback effects bite.

No downloads: MNIST-shaped classification data comes from a fixed
random teacher model (so it is genuinely learnable and loss floors are
meaningful); LM tokens come from per-worker Markov chains over the
vocabulary (so next-token prediction has learnable structure).
"""

from __future__ import annotations

import dataclasses
from typing import Iterator

import numpy as np


# ---------------------------------------------------------------------------
# classification (paper's convex experiments; ResNet images)
# ---------------------------------------------------------------------------


def make_classification_data(
    n: int,
    dim: int = 784,
    classes: int = 10,
    seed: int = 0,
    label_noise: float = 0.05,
):
    """Teacher-model data: x ~ N(0, I) (sparse-ish positive like pixel
    data), y = argmax(W* x + b* + noise)."""
    rng = np.random.RandomState(seed)
    W = rng.randn(dim, classes).astype(np.float32) / np.sqrt(dim)
    b = rng.randn(classes).astype(np.float32) * 0.1
    x = np.abs(rng.randn(n, dim)).astype(np.float32)
    x *= (rng.rand(n, dim) < 0.25)  # sparse activations, MNIST-ish
    logits = x @ W + b + label_noise * rng.randn(n, classes).astype(np.float32)
    y = np.argmax(logits, axis=1).astype(np.int32)
    return x, y


def mnist_like(n: int = 12000, seed: int = 0):
    return make_classification_data(n, dim=784, classes=10, seed=seed)


def make_image_data(n: int, hw: int = 16, channels: int = 3,
                    classes: int = 10, seed: int = 0):
    """CIFAR-shaped teacher data for the ResNet reproduction: class
    templates + noise."""
    rng = np.random.RandomState(seed)
    templates = rng.randn(classes, hw, hw, channels).astype(np.float32)
    y = rng.randint(0, classes, size=n).astype(np.int32)
    x = templates[y] + 1.5 * rng.randn(n, hw, hw, channels).astype(np.float32)
    return x, y


def worker_batches(
    x: np.ndarray,
    y: np.ndarray,
    R: int,
    batch: int,
    steps: int,
    seed: int = 0,
    non_iid: bool = False,
    feature_key: str = "features",
) -> Iterator[dict]:
    """Yields ``steps`` batches shaped [R, batch, ...].

    iid: the pool is split uniformly into R local datasets D_r.
    non_iid: worker r is biased toward classes r mod C (80/20 mix).
    """
    n = len(x)
    rng = np.random.RandomState(seed)
    if non_iid:
        classes = int(y.max()) + 1
        by_class = [np.where(y == c)[0] for c in range(classes)]
        shards = []
        for r in range(R):
            own = by_class[r % classes]
            other = np.concatenate(
                [by_class[c] for c in range(classes) if c != r % classes]
            )
            shards.append((own, other))
    else:
        perm = rng.permutation(n)
        shards = np.array_split(perm, R)
    for _ in range(steps):
        xs, ys = [], []
        for r in range(R):
            if non_iid:
                own, other = shards[r]
                n_own = int(0.8 * batch)
                idx = np.concatenate([
                    rng.choice(own, n_own),
                    rng.choice(other, batch - n_own),
                ])
            else:
                idx = rng.choice(shards[r], batch)
            xs.append(x[idx])
            ys.append(y[idx])
        yield {feature_key: np.stack(xs), "labels": np.stack(ys)}


# ---------------------------------------------------------------------------
# LM token streams
# ---------------------------------------------------------------------------


@dataclasses.dataclass
class LMTokenStream:
    """Per-worker Markov-chain token streams.

    Each worker gets its own transition matrix (non_iid) or a shared one
    (iid), over an effective alphabet of ``order`` states hashed into
    the full vocab, so cross-entropy has a real floor below log(vocab).
    """

    vocab: int
    R: int = 1
    order: int = 64
    seed: int = 0
    non_iid: bool = False

    def __post_init__(self):
        rng = np.random.RandomState(self.seed)
        k = min(self.order, self.vocab)
        n_chains = self.R if self.non_iid else 1
        self.trans = []
        for _ in range(n_chains):
            t = rng.rand(k, k).astype(np.float64) ** 4  # peaky
            t /= t.sum(axis=1, keepdims=True)
            self.trans.append(t)
        self.state_to_token = rng.permutation(self.vocab)[:k]
        self.k = k

    def batches(self, batch: int, seq_len: int, steps: int,
                seed: int = 1) -> Iterator[dict]:
        """Yields {"tokens": [R, batch, seq_len + 1]} int32 batches."""
        rng = np.random.RandomState(seed)
        for _ in range(steps):
            out = np.zeros((self.R, batch, seq_len + 1), np.int32)
            for r in range(self.R):
                t = self.trans[r % len(self.trans)]
                s = rng.randint(0, self.k, size=batch)
                for j in range(seq_len + 1):
                    out[r, :, j] = self.state_to_token[s]
                    u = rng.rand(batch, 1)
                    s = (u > np.cumsum(t[s], axis=1)).sum(axis=1)
                    s = np.clip(s, 0, self.k - 1)
            yield {"tokens": out}

from repro.data.synthetic import (
    LMTokenStream,
    make_classification_data,
    make_image_data,
    mnist_like,
    worker_batches,
)

__all__ = [
    "LMTokenStream",
    "make_classification_data",
    "make_image_data",
    "mnist_like",
    "worker_batches",
]

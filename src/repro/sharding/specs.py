"""Per-architecture PartitionSpec trees (tensor-parallel 'model' axis).

Conventions (megatron-style):
  * column-parallel:  out-features sharded ('model' on the last dim)
  * row-parallel:     in-features sharded  ('model' on the contraction dim)
  * embeddings sharded on vocab; heads sharded where divisible.

The specs only mention the 'model' axis — data-parallel placement is
the engines' job (replicated masters, worker-axis locals, batch over
('pod','data')).  Leaves whose natural shard axis does not divide by
the mesh's model size are replicated (None) — correctness first, noted
for the roofline.
"""

from __future__ import annotations

from jax.sharding import PartitionSpec as P

from repro.configs.base import ModelConfig, ShardingPolicy


def _dense_layer_specs(cfg: ModelConfig, L: bool = True):
    pre = (None,) if L else ()
    return {
        "ln1": P(*pre, None),
        "ln2": P(*pre, None),
        "attn": {
            "wq": P(*pre, None, "model"),
            "wk": P(*pre, None, "model"),
            "wv": P(*pre, None, "model"),
            "wo": P(*pre, "model", None),
        },
        "mlp": {
            "w1": P(*pre, None, "model"),
            "w3": P(*pre, None, "model"),
            "w2": P(*pre, "model", None),
        },
    }


def _moe_specs(cfg: ModelConfig):
    nper_pre = (None,)
    lay = {
        "ln1": P(None, None),
        "ln2": P(None, None),
        "attn": {
            "wq": P(None, None, "model"),
            "wk": P(None, None, "model"),
            "wv": P(None, None, "model"),
            "wo": P(None, "model", None),
        },
        "moe": {
            "router": P(None, None, None),
            "w1": P(None, "model", None, None),   # experts over 'model'
            "w3": P(None, "model", None, None),
            "w2": P(None, "model", None, None),
        },
    }
    if cfg.shared_expert:
        lay["moe"]["shared"] = {
            "w1": P(None, None, "model"),
            "w3": P(None, None, "model"),
            "w2": P(None, "model", None),
        }
    if cfg.moe_interleave > 1:
        lay["dense_mlp"] = {
            "w1": P(None, None, "model"),
            "w3": P(None, None, "model"),
            "w2": P(None, "model", None),
        }
    return lay


def _rwkv_specs(cfg: ModelConfig):
    v = {
        "ln1": P(None, None), "ln2": P(None, None),
        "mix_r": P(None, None), "mix_k": P(None, None),
        "mix_v": P(None, None), "mix_w": P(None, None),
        "mix_g": P(None, None),
        "wr": P(None, None, "model"),
        "wk": P(None, None, "model"),
        "wv": P(None, None, "model"),
        "wg": P(None, None, "model"),
        "wo": P(None, "model", None),
        "w0": P(None, None),
        "wA": P(None, None, None),
        "wB": P(None, None, None),
        "bonus": P(None, "model", None),   # heads over model
        "ln_x": P(None, None),
        "cmix_k": P(None, None), "cmix_r": P(None, None),
        "ck": P(None, None, "model"),
        "cv": P(None, "model", None),
        "cr": P(None, None, "model"),
    }
    return v


def _zamba_specs(cfg: ModelConfig):
    lay = {
        "ln": P(None, None),
        "w_z": P(None, None, "model"),
        "w_x": P(None, None, "model"),
        "w_B": P(None, None, None),
        "w_C": P(None, None, None),
        "w_dt": P(None, None, "model"),
        "conv_x": P(None, None, "model"),
        "conv_B": P(None, None, None),
        "conv_C": P(None, None, None),
        "conv_bx": P(None, "model"),
        "conv_bB": P(None, None),
        "conv_bC": P(None, None),
        "dt_bias": P(None, "model"),
        "A_log": P(None, "model"),
        "D": P(None, "model"),
        "ln_y": P(None, "model"),
        "w_out": P(None, "model", None),
    }
    shared = {
        "w_cat": P(None, "model"),
        "ln1": P(None),
        "attn": {
            "wq": P(None, "model"), "wk": P(None, "model"),
            "wv": P(None, "model"), "wo": P("model", None),
        },
        "ln2": P(None),
        "mlp": {
            "w1": P(None, "model"), "w3": P(None, "model"),
            "w2": P("model", None),
        },
        "w_back": P("model", None),
    }
    return lay, shared


def param_specs(cfg: ModelConfig):
    """PartitionSpec tree matching the family's init_params structure."""
    if cfg.family == "dense":
        specs = {
            "embed": P("model", None),
            "layers": _dense_layer_specs(cfg),
            "final_norm": P(None),
        }
        if not cfg.tie_embeddings:
            specs["head"] = P(None, "model")
        return specs
    if cfg.family == "moe":
        return {
            "embed": P("model", None),
            "layers": _moe_specs(cfg),
            "final_norm": P(None),
            "head": P(None, "model"),
        }
    if cfg.family == "rwkv6":
        return {
            "embed": P("model", None),
            "layers": _rwkv_specs(cfg),
            "final_norm": P(None),
            "head": P(None, "model"),
        }
    if cfg.family == "zamba2":
        lay, shared = _zamba_specs(cfg)
        out = {
            "embed": P("model", None),
            "layers": lay,
            "final_norm": P(None),
            "head": P(None, "model"),
        }
        if cfg.attn_every > 0:
            out["shared"] = shared
        return out
    raise KeyError(cfg.family)


def activation_policy(cfg: ModelConfig, *, for_serving: bool,
                      data_axes=("data",), seq_shard: bool = False,
                      ep: bool = True) -> ShardingPolicy:
    """Activation constraints.

    Training runs inside a manual-(pod,data) shard_map, so constraints
    may reference only 'model'.  Serving runs under plain jit, so batch
    dims carry the data axes.
    """
    da = tuple(data_axes)
    if for_serving:
        return ShardingPolicy(
            act=P(da, None, None),
            logits=None,  # ranks differ between prefill/decode; leave to XLA
            kv_cache=P(da, None, "model", None),
            ep_axis="model" if (ep and cfg.family == "moe") else None,
        )
    # NOTE: the explicit expert-parallel shard_map cannot nest inside the
    # manual-(pod,data) training region in current JAX (mixed Manual/Auto
    # PartitionSpec rejection); training delegates expert sharding to XLA
    # auto over the expert axis instead.  Serving keeps explicit EP.
    return ShardingPolicy(
        act=P(None, "model", None) if seq_shard else None,
        logits=P(None, None, "model"),
        # EP inside the manual-(pod,data) region works through the
        # custom_vjp expert apply (models/moe.py) — plain AD through a
        # nested shard_map is unsupported in current JAX.
        ep_axis="model" if (ep and cfg.family == "moe") else None,
        vary_axes=tuple(data_axes),
    )


def sanitize_spec(spec, shape, mesh) -> P:
    """Drop sharding entries whose axis size does not divide the dim
    (e.g. internvl2's 92553 vocab, rwkv6's 40 heads on a 16-way model
    axis) — replicate those dims instead.  Keeps lowering legal; the
    divisibility loss is reported via head_divisibility_note."""
    if spec is None:
        return P()
    entries = list(spec) + [None] * (len(shape) - len(spec))
    out = []
    for dim, e in zip(shape, entries):
        if e is None:
            out.append(None)
            continue
        axes = e if isinstance(e, tuple) else (e,)
        size = 1
        for a in axes:
            size *= mesh.shape[a]
        out.append(e if dim % size == 0 else None)
    return P(*out)


def batch_specs(kind: str, data_axes=("data",)):
    da = tuple(data_axes)
    if kind == "train":
        return {"tokens": P(da, None, None)}
    return {"tokens": P(da, None)}


def head_divisibility_note(cfg: ModelConfig, model_size: int) -> str:
    """Roofline annotation: which shardings are limited by divisibility."""
    notes = []
    if cfg.family in ("dense", "moe"):
        if (cfg.n_heads * cfg.hd) % model_size:
            notes.append(f"attn out dim {cfg.n_heads * cfg.hd} !% {model_size}")
        if (cfg.n_kv_heads * cfg.hd) % model_size:
            notes.append(
                f"kv dim {cfg.n_kv_heads * cfg.hd} !% {model_size} (replicated)"
            )
    return "; ".join(notes) or "clean"

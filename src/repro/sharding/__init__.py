from repro.sharding.specs import (
    activation_policy,
    batch_specs,
    param_specs,
)

__all__ = ["activation_policy", "batch_specs", "param_specs"]

"""jax version-compatibility shims (mesh context + shard_map).

The repo targets the modern jax surface — ``jax.shard_map`` with
``axis_names``/``check_vma`` and the ``jax.set_mesh`` context — while
still running on jax 0.4.x, where those live in
``jax.experimental.shard_map`` (``check_rep``/``auto``) and the legacy
``Mesh`` context manager.  Import from here instead of from jax.
"""

from __future__ import annotations

import contextlib

import jax

#: modern jax surface (jax.shard_map & friends).  On 0.4.x partial-manual
#: shard_map regions additionally cannot lower axis_index, all_gather or
#: all_to_all (psum/pmean/psum_scatter are fine) — callers with such
#: collectives must restructure when this is False.
MODERN = hasattr(jax, "shard_map")

if MODERN:

    def shard_map(f, *, mesh, in_specs, out_specs, axis_names=None,
                  check_vma: bool = True):
        kw = dict(mesh=mesh, in_specs=in_specs, out_specs=out_specs,
                  check_vma=check_vma)
        if axis_names is not None:
            kw["axis_names"] = set(axis_names)
        return jax.shard_map(f, **kw)

else:
    from jax.experimental.shard_map import shard_map as _shard_map

    def shard_map(f, *, mesh, in_specs, out_specs, axis_names=None,
                  check_vma: bool = True):
        manual = (frozenset(axis_names) if axis_names is not None
                  else frozenset(mesh.axis_names))
        auto = frozenset(mesh.axis_names) - manual
        # 0.4.x replication checking does not understand auto axes
        return _shard_map(f, mesh=mesh, in_specs=in_specs,
                          out_specs=out_specs,
                          check_rep=bool(check_vma) and not auto, auto=auto)


def round_scan_supported(mesh, data_axes) -> bool:
    """Can ``lax.scan`` with xs wrap this mesh's partial-manual
    shard_map steps — the fused round-program runtime (DESIGN.md §7)?

    Modern jax: always.  0.4.x: only when every non-data (auto /
    tensor-parallel) axis has size 1 — the legacy SPMD partitioner
    CHECK-crashes partitioning scan-with-xs across a >1 auto axis of a
    partial-manual program (the same limitation that skips the TP>1
    dry-run compile; see ROADMAP).  Callers fall back to the per-step
    path when this is False.
    """
    if MODERN:
        return True
    daxes = set(data_axes)
    return all(mesh.shape[a] == 1 for a in mesh.axis_names
               if a not in daxes)


def sharding_constraints_usable() -> bool:
    """Can with_sharding_constraint be emitted *here*?  Modern jax: always.
    0.4.x: not while tracing inside a shard_map/pmap body — a constraint
    naming auto axes inside a partial-manual region crashes the SPMD
    partitioner, so constraint helpers should no-op there (the pins are
    perf hints, not correctness)."""
    if MODERN:
        return True
    try:
        return not jax.core.nonempty_axis_env_DO_NOT_USE()
    except Exception:
        return True


if hasattr(jax.lax, "axis_size"):
    axis_size = jax.lax.axis_size
else:

    def axis_size(name):
        # constant-folds to the static axis size under shard_map
        return jax.lax.psum(1, name)


if hasattr(jax, "set_mesh"):
    set_mesh = jax.set_mesh
else:

    @contextlib.contextmanager
    def set_mesh(mesh):
        with mesh:
            yield mesh

"""Checkpointing: flat-npz + JSON manifest of the pytree structure.

Sharding-aware restore: arrays are saved from host memory (gathered);
``restore(..., shardings=tree)`` device_puts each leaf back onto its
NamedSharding.  No external deps (no orbax in this container).
"""

from __future__ import annotations

import json
import os
from typing import Any, Optional

import jax
import ml_dtypes
import numpy as np

# numpy can't serialize bf16/f8 natively; store as uint16/uint8 views
_EXOTIC = {
    "bfloat16": ("uint16", ml_dtypes.bfloat16),
    "float8_e4m3fn": ("uint8", ml_dtypes.float8_e4m3fn),
    "float8_e5m2": ("uint8", ml_dtypes.float8_e5m2),
}


def _flatten_with_paths(tree):
    flat, treedef = jax.tree_util.tree_flatten_with_path(tree)
    items = []
    for path, leaf in flat:
        key = "/".join(str(getattr(p, "key", getattr(p, "idx", p))) for p in path)
        items.append((key, leaf))
    return items, treedef


def save(path: str, tree: Any, step: Optional[int] = None,
         policy: Optional[dict] = None) -> None:
    """``policy``: the run's serialized compression spec
    (``core.policy`` ``to_dict()`` form) — persisted into the manifest
    so a resume reproduces the exact per-leaf operators and hence the
    bits trajectories (read it back with :func:`load_policy`)."""
    os.makedirs(path, exist_ok=True)
    items, _ = _flatten_with_paths(tree)
    arrays = {}
    manifest = {"keys": [], "step": step}
    if policy is not None:
        manifest["policy"] = policy
    for i, (key, leaf) in enumerate(items):
        name = f"a{i}"
        arr = np.asarray(jax.device_get(leaf))
        dtype = str(arr.dtype)
        if dtype in _EXOTIC:
            arr = arr.view(_EXOTIC[dtype][0])
        arrays[name] = arr
        manifest["keys"].append({"name": name, "path": key, "dtype": dtype})
    np.savez(os.path.join(path, "arrays.npz"), **arrays)
    with open(os.path.join(path, "manifest.json"), "w") as f:
        json.dump(manifest, f, indent=2)


def restore(path: str, like: Any, shardings: Any = None) -> Any:
    """``like``: a pytree with the target structure (e.g. abstract or
    freshly-initialized params)."""
    with open(os.path.join(path, "manifest.json")) as f:
        manifest = json.load(f)
    data = np.load(os.path.join(path, "arrays.npz"))
    leaves_like, treedef = jax.tree_util.tree_flatten(like)
    if len(manifest["keys"]) != len(leaves_like):
        raise ValueError(
            f"checkpoint has {len(manifest['keys'])} leaves, target "
            f"structure has {len(leaves_like)}"
        )
    out = []
    for e in manifest["keys"]:
        arr = np.asarray(data[e["name"]])
        if e["dtype"] in _EXOTIC:
            arr = arr.view(_EXOTIC[e["dtype"]][1])
        out.append(arr)
    tree = jax.tree_util.tree_unflatten(treedef, out)
    if shardings is not None:
        tree = jax.device_put(tree, shardings)
    else:
        tree = jax.tree_util.tree_map(
            lambda a, l: jax.numpy.asarray(a, getattr(l, "dtype", None)),
            tree, like,
        )
    return tree


def load_policy(path: str):
    """The compression spec this checkpoint was trained with, as a
    ``core.policy`` spec object (ChannelSpec/PolicySpec/OpSpec), or
    None for pre-policy checkpoints."""
    with open(os.path.join(path, "manifest.json")) as f:
        manifest = json.load(f)
    d = manifest.get("policy")
    if d is None:
        return None
    from repro.core import policy as pol
    return pol.from_dict(d)


# ---------------------------------------------------------------------------
# crash-consistent full train-state checkpoints (DESIGN.md §9)
# ---------------------------------------------------------------------------


def save_train_state(path: str, state: Any, *, key: Any, cursor: int,
                     policy: Optional[dict] = None,
                     faults: Optional[str] = None,
                     staleness_weight: Optional[str] = None) -> None:
    """Persist the FULL training state — master, per-worker locals,
    uplink/downlink error memories, the in-flight payload queue
    (values, arrival steps, staleness tags), every ledger, and the PRNG
    key — plus the fault cursor (the next global step to execute), so a
    mid-round restart reproduces the exact trajectory.

    ``faults``/``staleness_weight`` record the run's fault spec string
    (``FaultSpec.to_string()``) and weighting mode; :func:`restore_train_state`
    hands them back so a resume can assert it re-derived the same
    deterministic fault tables.
    """
    save(path, {"state": state, "key": key}, step=cursor, policy=policy)
    mpath = os.path.join(path, "manifest.json")
    with open(mpath) as f:
        manifest = json.load(f)
    manifest["train_state"] = {
        "cursor": int(cursor),
        "faults": faults,
        "staleness_weight": staleness_weight,
    }
    with open(mpath, "w") as f:
        json.dump(manifest, f, indent=2)


def restore_train_state(path: str, like_state: Any, like_key: Any
                        ) -> tuple[Any, Any, dict]:
    """Inverse of :func:`save_train_state`: ``(state, key, info)`` with
    ``info`` the ``{"cursor", "faults", "staleness_weight"}`` record.
    ``like_state``/``like_key`` give the target structure (a freshly
    initialized state of the same RunConfig)."""
    mpath = os.path.join(path, "manifest.json")
    with open(mpath) as f:
        manifest = json.load(f)
    info = manifest.get("train_state")
    if info is None:
        raise ValueError(
            f"{path} is a master-only checkpoint, not a full train-state "
            f"snapshot (no train_state record in the manifest)")
    tree = restore(path, {"state": like_state, "key": like_key})
    return tree["state"], tree["key"], dict(info)


def latest_full(root: str) -> Optional[int]:
    """Latest ``full_step_<N>`` train-state snapshot under ``root``."""
    if not os.path.isdir(root):
        return None
    steps = []
    for d in os.listdir(root):
        if d.startswith("full_step_"):
            try:
                steps.append(int(d.rsplit("_", 1)[1]))
            except ValueError:
                pass
    return max(steps) if steps else None


def latest_step(root: str) -> Optional[int]:
    if not os.path.isdir(root):
        return None
    steps = []
    for d in os.listdir(root):
        if d.startswith("step_"):
            try:
                steps.append(int(d.split("_")[1]))
            except ValueError:
                pass
    return max(steps) if steps else None

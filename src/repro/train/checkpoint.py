"""Checkpointing: flat-npz + JSON manifest of the pytree structure.

Sharding-aware restore: arrays are saved from host memory (gathered);
``restore(..., shardings=tree)`` device_puts each leaf back onto its
NamedSharding.  No external deps (no orbax in this container).
"""

from __future__ import annotations

import json
import os
from typing import Any, Optional

import jax
import ml_dtypes
import numpy as np

# numpy can't serialize bf16/f8 natively; store as uint16/uint8 views
_EXOTIC = {
    "bfloat16": ("uint16", ml_dtypes.bfloat16),
    "float8_e4m3fn": ("uint8", ml_dtypes.float8_e4m3fn),
    "float8_e5m2": ("uint8", ml_dtypes.float8_e5m2),
}


def _flatten_with_paths(tree):
    flat, treedef = jax.tree_util.tree_flatten_with_path(tree)
    items = []
    for path, leaf in flat:
        key = "/".join(str(getattr(p, "key", getattr(p, "idx", p))) for p in path)
        items.append((key, leaf))
    return items, treedef


def save(path: str, tree: Any, step: Optional[int] = None,
         policy: Optional[dict] = None) -> None:
    """``policy``: the run's serialized compression spec
    (``core.policy`` ``to_dict()`` form) — persisted into the manifest
    so a resume reproduces the exact per-leaf operators and hence the
    bits trajectories (read it back with :func:`load_policy`)."""
    os.makedirs(path, exist_ok=True)
    items, _ = _flatten_with_paths(tree)
    arrays = {}
    manifest = {"keys": [], "step": step}
    if policy is not None:
        manifest["policy"] = policy
    for i, (key, leaf) in enumerate(items):
        name = f"a{i}"
        arr = np.asarray(jax.device_get(leaf))
        dtype = str(arr.dtype)
        if dtype in _EXOTIC:
            arr = arr.view(_EXOTIC[dtype][0])
        arrays[name] = arr
        manifest["keys"].append({"name": name, "path": key, "dtype": dtype})
    np.savez(os.path.join(path, "arrays.npz"), **arrays)
    with open(os.path.join(path, "manifest.json"), "w") as f:
        json.dump(manifest, f, indent=2)


def restore(path: str, like: Any, shardings: Any = None) -> Any:
    """``like``: a pytree with the target structure (e.g. abstract or
    freshly-initialized params)."""
    with open(os.path.join(path, "manifest.json")) as f:
        manifest = json.load(f)
    data = np.load(os.path.join(path, "arrays.npz"))
    leaves_like, treedef = jax.tree_util.tree_flatten(like)
    if len(manifest["keys"]) != len(leaves_like):
        raise ValueError(
            f"checkpoint has {len(manifest['keys'])} leaves, target "
            f"structure has {len(leaves_like)}"
        )
    out = []
    for e in manifest["keys"]:
        arr = np.asarray(data[e["name"]])
        if e["dtype"] in _EXOTIC:
            arr = arr.view(_EXOTIC[e["dtype"]][1])
        out.append(arr)
    tree = jax.tree_util.tree_unflatten(treedef, out)
    if shardings is not None:
        tree = jax.device_put(tree, shardings)
    else:
        tree = jax.tree_util.tree_map(
            lambda a, l: jax.numpy.asarray(a, getattr(l, "dtype", None)),
            tree, like,
        )
    return tree


def load_policy(path: str):
    """The compression spec this checkpoint was trained with, as a
    ``core.policy`` spec object (ChannelSpec/PolicySpec/OpSpec), or
    None for pre-policy checkpoints."""
    with open(os.path.join(path, "manifest.json")) as f:
        manifest = json.load(f)
    d = manifest.get("policy")
    if d is None:
        return None
    from repro.core import policy as pol
    return pol.from_dict(d)


# ---------------------------------------------------------------------------
# compact serving checkpoints (DESIGN.md §11.2)
# ---------------------------------------------------------------------------

COMPACT_FORMAT = "compact-v1"


def _nest_paths(items):
    """Rebuild a nested pytree from ('/'-joined path, value) pairs.
    All-numeric key levels become lists (tree_flatten_with_path emits
    list indices as numeric components)."""
    if len(items) == 1 and items[0][0] == "":
        return items[0][1]
    root: dict = {}
    for key, v in items:
        parts = key.split("/")
        d = root
        for p in parts[:-1]:
            d = d.setdefault(p, {})
        d[parts[-1]] = v

    def conv(d):
        if not isinstance(d, dict):
            return d
        if d and all(k.isdigit() for k in d):
            return [conv(d[k]) for k in sorted(d, key=int)]
        return {k: conv(v) for k, v in d.items()}

    return conv(root)


def is_compact(path: str) -> bool:
    """True when ``path`` holds a compact-format serving checkpoint."""
    mpath = os.path.join(path, "manifest.json")
    if not os.path.exists(mpath):
        return False
    with open(mpath) as f:
        return json.load(f).get("format") == COMPACT_FORMAT


def save_compact(path: str, tree: Any, step: Optional[int] = None,
                 policy: Optional[dict] = None) -> None:
    """Persist a serving tree (``serve.compressed.compress_tree``
    output) in compact form: compressed leaves keep their ``(idx, val)``
    / ``(levels, scale)`` buffers plus layout metadata; dense leaves
    save as-is.  :func:`load_compact` rebuilds the tree without a
    ``like`` structure and without ever densifying."""
    from repro.serve.compressed import CompressedTensor
    os.makedirs(path, exist_ok=True)
    flat, _ = jax.tree_util.tree_flatten_with_path(
        tree, is_leaf=lambda x: isinstance(x, CompressedTensor))
    arrays = {}
    manifest = {"format": COMPACT_FORMAT, "keys": [], "step": step}
    if policy is not None:
        manifest["policy"] = policy
    for i, (p, leaf) in enumerate(flat):
        key = "/".join(str(getattr(q, "key", getattr(q, "idx", q)))
                       for q in p)
        name = f"a{i}"
        if isinstance(leaf, CompressedTensor):
            arrays[name + "_a"] = np.asarray(jax.device_get(leaf.a))
            arrays[name + "_b"] = np.asarray(jax.device_get(leaf.b))
            manifest["keys"].append({
                "name": name, "path": key, "kind": leaf.kind,
                "row_len": leaf.row_len, "shape": list(leaf.shape),
                "out_axis": leaf.out_axis, "dtype": leaf.dtype,
                "op": leaf.op,
            })
        else:
            arr = np.asarray(jax.device_get(leaf))
            dtype = str(arr.dtype)
            if dtype in _EXOTIC:
                arr = arr.view(_EXOTIC[dtype][0])
            arrays[name] = arr
            manifest["keys"].append({"name": name, "path": key,
                                     "kind": "dense", "dtype": dtype})
    np.savez(os.path.join(path, "arrays.npz"), **arrays)
    with open(os.path.join(path, "manifest.json"), "w") as f:
        json.dump(manifest, f, indent=2)


def load_compact(path: str) -> Any:
    """Rebuild the serving tree from a compact checkpoint.  Compressed
    leaves come back as ``CompressedTensor`` holding exactly the stored
    buffers — no dense materialization happens here (the zero-densify
    counter stays untouched)."""
    from repro.serve.compressed import CompressedTensor
    with open(os.path.join(path, "manifest.json")) as f:
        manifest = json.load(f)
    if manifest.get("format") != COMPACT_FORMAT:
        raise ValueError(f"{path} is not a {COMPACT_FORMAT} checkpoint")
    data = np.load(os.path.join(path, "arrays.npz"))
    items = []
    for e in manifest["keys"]:
        if e["kind"] == "dense":
            arr = np.asarray(data[e["name"]])
            if e["dtype"] in _EXOTIC:
                arr = arr.view(_EXOTIC[e["dtype"]][1])
            items.append((e["path"], jax.numpy.asarray(arr)))
        else:
            leaf = CompressedTensor(
                e["kind"], jax.numpy.asarray(data[e["name"] + "_a"]),
                jax.numpy.asarray(data[e["name"] + "_b"]),
                e["row_len"], tuple(e["shape"]), e["out_axis"],
                e["dtype"], e.get("op", ""))
            items.append((e["path"], leaf))
    return _nest_paths(items)


# ---------------------------------------------------------------------------
# crash-consistent full train-state checkpoints (DESIGN.md §9)
# ---------------------------------------------------------------------------


def save_train_state(path: str, state: Any, *, key: Any, cursor: int,
                     policy: Optional[dict] = None,
                     faults: Optional[str] = None,
                     staleness_weight: Optional[str] = None) -> None:
    """Persist the FULL training state — master, per-worker locals,
    uplink/downlink error memories, the in-flight payload queue
    (values, arrival steps, staleness tags), every ledger, and the PRNG
    key — plus the fault cursor (the next global step to execute), so a
    mid-round restart reproduces the exact trajectory.

    ``faults``/``staleness_weight`` record the run's fault spec string
    (``FaultSpec.to_string()``) and weighting mode; :func:`restore_train_state`
    hands them back so a resume can assert it re-derived the same
    deterministic fault tables.
    """
    save(path, {"state": state, "key": key}, step=cursor, policy=policy)
    mpath = os.path.join(path, "manifest.json")
    with open(mpath) as f:
        manifest = json.load(f)
    manifest["train_state"] = {
        "cursor": int(cursor),
        "faults": faults,
        "staleness_weight": staleness_weight,
    }
    with open(mpath, "w") as f:
        json.dump(manifest, f, indent=2)


def restore_train_state(path: str, like_state: Any, like_key: Any
                        ) -> tuple[Any, Any, dict]:
    """Inverse of :func:`save_train_state`: ``(state, key, info)`` with
    ``info`` the ``{"cursor", "faults", "staleness_weight"}`` record.
    ``like_state``/``like_key`` give the target structure (a freshly
    initialized state of the same RunConfig)."""
    mpath = os.path.join(path, "manifest.json")
    with open(mpath) as f:
        manifest = json.load(f)
    info = manifest.get("train_state")
    if info is None:
        raise ValueError(
            f"{path} is a master-only checkpoint, not a full train-state "
            f"snapshot (no train_state record in the manifest)")
    tree = restore(path, {"state": like_state, "key": like_key})
    return tree["state"], tree["key"], dict(info)


def latest_full(root: str) -> Optional[int]:
    """Latest ``full_step_<N>`` train-state snapshot under ``root``."""
    if not os.path.isdir(root):
        return None
    steps = []
    for d in os.listdir(root):
        if d.startswith("full_step_"):
            try:
                steps.append(int(d.rsplit("_", 1)[1]))
            except ValueError:
                pass
    return max(steps) if steps else None


def latest_step(root: str) -> Optional[int]:
    if not os.path.isdir(root):
        return None
    steps = []
    for d in os.listdir(root):
        if d.startswith("step_"):
            try:
                steps.append(int(d.split("_")[1]))
            except ValueError:
                pass
    return max(steps) if steps else None

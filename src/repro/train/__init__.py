from repro.train import checkpoint
from repro.train.trainer import History, RunConfig, train

__all__ = ["checkpoint", "History", "RunConfig", "train"]

"""Training loop driving the unified Qsparse-local-SGD engine.

Both paper algorithms run through ``core/engine.py``: the synchronous
schedule (Algorithm 1) is a [T] mask broadcast to all workers, the
asynchronous one (Algorithm 2) a [T, R] per-worker mask.  Compression
dispatches to the Pallas kernels per ``RunConfig.dispatch``
("auto" | "kernel" | "reference"; see kernels/dispatch.py), with
same-operator leaves megabuffer-packed into one kernel launch per
family per sync round (``RunConfig.pack``, DESIGN.md §3.4).

Handles: sync/async schedules, LR schedules, the bits ledger (the
paper's evaluation axis), periodic eval, target-loss early stats (bits
to reach target), and checkpointing.
"""

from __future__ import annotations

import dataclasses
import time
from typing import Any, Callable, Iterable, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import engine, schedule as sched
from repro.core.operators import CompressionOp
from repro.kernels.dispatch import DispatchConfig
from repro.optim.transforms import GradientTransform
from repro.train import checkpoint as ckpt


@dataclasses.dataclass
class RunConfig:
    total_steps: int
    R: int
    H: int = 1
    asynchronous: bool = False
    seed: int = 0
    log_every: int = 50
    eval_every: int = 0
    ckpt_dir: Optional[str] = None
    ckpt_every: int = 0
    target_loss: Optional[float] = None
    dispatch: str = "auto"  # "auto" | "kernel" | "reference"
    pack: bool = True       # megabuffer-pack same-operator leaves per round
    # server→worker compression channel (DESIGN.md §5): an operator (or
    # tree) applied to each syncing worker's master delta with a
    # server-side error memory.  None/Identity = exact dense broadcast
    # (historical trajectories bit-for-bit), charged to the downlink
    # ledger.
    downlink_op: Optional[Any] = None


@dataclasses.dataclass
class History:
    steps: list = dataclasses.field(default_factory=list)
    loss: list = dataclasses.field(default_factory=list)
    bits: list = dataclasses.field(default_factory=list)
    bits_down: list = dataclasses.field(default_factory=list)
    rounds: list = dataclasses.field(default_factory=list)
    eval_steps: list = dataclasses.field(default_factory=list)
    eval_metrics: list = dataclasses.field(default_factory=list)
    bits_to_target: Optional[float] = None
    steps_to_target: Optional[int] = None
    wall_time: float = 0.0

    def summary(self) -> dict:
        return {
            "final_loss": self.loss[-1] if self.loss else None,
            "total_bits": self.bits[-1] if self.bits else 0.0,
            "total_bits_down": self.bits_down[-1] if self.bits_down else 0.0,
            "rounds": self.rounds[-1] if self.rounds else 0,
            "bits_to_target": self.bits_to_target,
            "steps_to_target": self.steps_to_target,
            "wall_time": self.wall_time,
        }


def make_mask(run: RunConfig) -> np.ndarray:
    """The engine's [T, R] sync mask for this run's schedule."""
    if run.asynchronous:
        return sched.async_schedule(run.total_steps, run.R, run.H,
                                    seed=run.seed)
    fixed = sched.fixed_schedule(run.total_steps, run.H)
    return np.broadcast_to(fixed[:, None], (run.total_steps, run.R)).copy()


def train(
    grad_fn: Callable,                       # (params, batch)->(loss, grads)
    params: Any,
    inner_opt: GradientTransform,
    operator: CompressionOp | Any,
    lr_schedule: Callable,
    batches: Iterable,
    run: RunConfig,
    eval_fn: Optional[Callable] = None,      # (master_params) -> metrics dict
    smooth: int = 20,
) -> tuple[Any, History]:
    """Runs Algorithm 1 (or Algorithm 2 when run.asynchronous) via the
    unified engine."""
    key = jax.random.PRNGKey(run.seed)
    hist = History()
    t0 = time.time()
    dispatch = DispatchConfig(mode=run.dispatch, pack=run.pack)
    state = engine.init(params, inner_opt, run.R, downlink=run.downlink_op)
    step_fn = jax.jit(engine.make_step(
        grad_fn, inner_opt, operator, lr_schedule, run.R,
        dispatch=dispatch, global_rounds=not run.asynchronous,
        downlink=run.downlink_op))
    mask = make_mask(run)

    recent = []
    for t, batch in enumerate(batches):
        if t >= run.total_steps:
            break
        key, sub = jax.random.split(key)
        batch = jax.tree_util.tree_map(jnp.asarray, batch)
        state, loss = step_fn(state, batch, jnp.asarray(mask[t]), sub)
        lossf = float(loss)
        recent.append(lossf)
        if len(recent) > smooth:
            recent.pop(0)
        sm = float(np.mean(recent))
        if (t + 1) % run.log_every == 0 or t == run.total_steps - 1:
            hist.steps.append(t + 1)
            hist.loss.append(sm)
            hist.bits.append(float(state.bits))
            hist.bits_down.append(float(state.bits_down))
            hist.rounds.append(int(state.rounds))
        if (run.target_loss is not None and hist.bits_to_target is None
                and sm <= run.target_loss and len(recent) == smooth):
            hist.bits_to_target = float(state.bits)
            hist.steps_to_target = t + 1
        if eval_fn and run.eval_every and (t + 1) % run.eval_every == 0:
            hist.eval_steps.append(t + 1)
            hist.eval_metrics.append(
                {k: float(v) for k, v in eval_fn(state.master).items()}
            )
        if run.ckpt_dir and run.ckpt_every and (t + 1) % run.ckpt_every == 0:
            ckpt.save(f"{run.ckpt_dir}/step_{t + 1}", state.master, step=t + 1)
    hist.wall_time = time.time() - t0
    if run.ckpt_dir:
        ckpt.save(f"{run.ckpt_dir}/final", state.master,
                  step=run.total_steps)
    return state, hist

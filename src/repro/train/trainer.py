"""Training loop driving the unified Qsparse-local-SGD engine.

Both paper algorithms run through ``core/engine.py``: the synchronous
schedule (Algorithm 1) is a [T] mask broadcast to all workers, the
asynchronous one (Algorithm 2) a [T, R] per-worker mask.  Compression
dispatches to the Pallas kernels per ``RunConfig.dispatch``
("auto" | "kernel" | "reference"; see kernels/dispatch.py), with
same-operator leaves megabuffer-packed into one kernel launch per
family per sync round (``RunConfig.pack``, DESIGN.md §3.4).

Two runtimes drive the schedule (``RunConfig.runtime``, DESIGN.md §7):

* ``"round"`` (default) — the schedule is segmented into round plans
  (``core/rounds.py``) and each round (H local steps + sync) runs as
  ONE compiled, donated program (``engine.make_superstep``): per-step
  losses come back as one array per round, ledger scalars are fetched
  once per round, and the next round's batch block is assembled while
  the device executes the current one.  Trajectories — states and
  every bits ledger — are bit-for-bit the per-step path's.
* ``"step"``  — the historical per-step host loop (one jitted, donated
  step per iteration).

Handles: sync/async schedules, LR schedules, the bits ledger (the
paper's evaluation axis), periodic eval, target-loss early stats (bits
to reach target), and checkpointing — with identical per-step History
semantics under both runtimes (mid-round log points read the ledger of
the last completed round, which is exactly the per-step value, since
bits/rounds/master only change at sync steps).
"""

from __future__ import annotations

import dataclasses
import time
from typing import Any, Callable, Iterable, Optional, Union

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import engine, policy as pol, rounds as rnd, \
    scenarios as scn, schedule as sched
from repro.core.operators import CompressionOp
from repro.kernels.dispatch import DispatchConfig
from repro.optim.transforms import GradientTransform
from repro.train import checkpoint as ckpt


@dataclasses.dataclass
class RunConfig:
    total_steps: int
    R: int
    H: int = 1
    asynchronous: bool = False
    seed: int = 0
    log_every: int = 50
    eval_every: int = 0
    ckpt_dir: Optional[str] = None
    ckpt_every: int = 0
    target_loss: Optional[float] = None
    dispatch: str = "auto"  # "auto" | "kernel" | "reference"
    pack: bool = True       # megabuffer-pack same-operator leaves per round
    # execution runtime (DESIGN.md §7): "round" compiles each sync
    # round (H local steps + sync) into one scanned, donated program;
    # "step" keeps the per-step host loop.  Bit-for-bit identical
    # trajectories and History either way.
    runtime: str = "round"  # "round" | "step"
    # overlapped round driver (DESIGN.md §10): dispatch consecutive
    # equal-length rounds as ONE scanned multi-round window, so round
    # r+1's local phase is in the device queue before round r's sync
    # collective is consumed.  Bit-for-bit the serialized driver on
    # states, both bits ledgers, losses and History (rounds containing
    # eval/ckpt points run as singleton windows).  Requires the round
    # runtime; unsupported with fault injection.
    overlap: bool = False
    overlap_window: int = 8   # max rounds per window (power-of-2 chunks)
    # kernel autotuning (kernels/autotune.py, DESIGN.md §10): before
    # training, time the run's exact compression launch signatures over
    # the block-geometry candidates and persist the winners to the
    # per-device tuning table — DispatchConfig then resolves block_rows
    # through the table transparently.  Tuning changes timing only,
    # never outputs (block geometry is scheduling, not math).
    tune: bool = False
    retune: bool = False      # re-measure signatures already tabled
    # THE compression-configuration surface (DESIGN.md §6): a
    # ``core.policy`` spec — PolicySpec / ChannelSpec / OpSpec, the DSL
    # string form ("topk:k=0.01", "norm->identity;.*->topk:k=0.01",
    # uplink ">>" downlink), or a plain CompressionOp.  Resolved
    # against the params at train() time into the per-leaf operator
    # trees for both wire directions.  When set, the legacy
    # ``operator`` argument and ``downlink_op`` field must be left
    # unset.
    policy: Optional[Union[str, pol.PolicySpec, pol.ChannelSpec,
                           pol.OpSpec, CompressionOp]] = None
    # per-top-level-leaf-group wire-bit ledger (History.leaf_bits /
    # leaf_bits_down) — compare heterogeneous policies on the paper's
    # x-axis per layer group.  Pure accounting; trajectories unchanged.
    leaf_ledger: bool = False
    # fleet scenario (core/scenarios.py, DESIGN.md §8): a Scenario, a
    # "k=v,..." spec string, or "preset:<name>" — compiled into the
    # engine's [T, R] mask (partial participation, stragglers, dropout,
    # heterogeneous H).  Mutually exclusive with ``asynchronous``.
    scenario: Optional[Union[str, scn.Scenario]] = None
    # the master's division rule over the syncing subset (DESIGN.md §8):
    # "mean_R" (the paper's Σ/R), "mean_S", or "support_weighted".
    # With a partial-participation scenario and the default mean_R a
    # one-time bias warning is emitted (scenarios.warn_if_biased).
    aggregate: str = "mean_R"
    # DEPRECATED (PR 4): the pre-policy downlink knob.  Use
    # ``policy="<uplink> >> <downlink>"`` (or a ChannelSpec) instead;
    # kept as a shim with a one-time warning.
    downlink_op: Optional[Union[CompressionOp, str]] = None
    # fault injection (core/scenarios.py FaultSpec, DESIGN.md §9): a
    # FaultSpec, "k=v,..." string, or "preset:<name>".  When set the
    # run executes through the engine's staleness-first fault runtime —
    # payloads computed at t applied at t+τ out of the in-flight queue,
    # worker crash/recover, payload drops — deterministically expanded
    # from the *fault* PRNG seed (never the data/model key stream).
    # ``--faults preset:none`` runs the fault runtime with trivial
    # tables: bit-for-bit the fault-free trajectories.
    faults: Optional[Union[str, scn.FaultSpec]] = None
    # overrides the fault spec's own seed when set (``--fault-seed``)
    fault_seed: Optional[int] = None
    # how arriving stale payloads are weighted (DESIGN.md §9):
    # "uniform" applies them exactly as computed, "damped" scales each
    # by 1/(1+τ)
    staleness_weight: str = "uniform"
    # crash-consistent resume: restore the latest full train-state
    # snapshot under ckpt_dir (queues, error memories, fault cursor,
    # PRNG key) and continue the exact trajectory.  Full snapshots are
    # written at ckpt_every points (round runtime: at the round
    # boundaries containing them).  The batch iterable must be
    # deterministic from the start — the resumed run skips the first
    # ``cursor`` batches.
    resume: bool = False


def _deprecated(name: str, instead: str):
    pol.warn_once(name, f"{name} is deprecated; use {instead} instead")


def resolve_run_channels(operator, run: RunConfig, params):
    """Normalize the (operator, run.policy, run.downlink_op) surfaces
    into resolved (uplink_tree, downlink_tree_or_None, channel_spec).

    ``run.policy`` is the one true path; the legacy ``operator`` +
    ``downlink_op`` pair keeps working behind a deprecation warning
    (exactly the old semantics — bit-for-bit trajectories).
    ``channel_spec`` is the serializable ChannelSpec persisted into
    checkpoints when the policy surface was used (None for raw
    operator objects, which have no canonical spec form).
    """
    if run.policy is not None:
        if operator is not None:
            raise ValueError(
                "pass the compression through RunConfig.policy OR the "
                "operator argument, not both")
        if run.downlink_op is not None:
            raise ValueError(
                "RunConfig.downlink_op conflicts with RunConfig.policy; "
                "put the downlink in the policy ('uplink >> downlink')")
        spec = pol.as_channel_spec(run.policy)
        up, down = spec.resolve(params)
        return up, down, spec
    if operator is None:
        raise ValueError("no compression configured: set RunConfig.policy "
                         "or pass an operator")
    downlink = run.downlink_op
    if downlink is not None:
        _deprecated("RunConfig.downlink_op",
                    "RunConfig.policy ('uplink >> downlink')")
        if isinstance(downlink, str):
            # registry-validated: unknown names raise KeyError here
            # instead of silently meaning Identity
            downlink = pol.resolve(downlink, params)
    if isinstance(operator, (str, pol.OpSpec, pol.PolicySpec)):
        operator = pol.resolve(operator, params)
    return operator, downlink, None


@dataclasses.dataclass
class History:
    steps: list = dataclasses.field(default_factory=list)
    loss: list = dataclasses.field(default_factory=list)
    bits: list = dataclasses.field(default_factory=list)
    bits_down: list = dataclasses.field(default_factory=list)
    rounds: list = dataclasses.field(default_factory=list)
    eval_steps: list = dataclasses.field(default_factory=list)
    eval_metrics: list = dataclasses.field(default_factory=list)
    bits_to_target: Optional[float] = None
    steps_to_target: Optional[int] = None
    wall_time: float = 0.0
    # per-leaf-group ledger (RunConfig.leaf_ledger): group names plus,
    # per log point, the cumulative [G] bits vector per direction
    leaf_groups: list = dataclasses.field(default_factory=list)
    leaf_bits: list = dataclasses.field(default_factory=list)
    leaf_bits_down: list = dataclasses.field(default_factory=list)
    # round runtime (DESIGN.md §7): one (start_step, length, n_synced)
    # tuple per executed round program.  The per-round loss blocks
    # flatten into the per-step ``loss``/``steps`` view above, so the
    # per-step History is identical under both runtimes.
    round_blocks: list = dataclasses.field(default_factory=list)

    def summary(self) -> dict:
        out = {
            "final_loss": self.loss[-1] if self.loss else None,
            "total_bits": self.bits[-1] if self.bits else 0.0,
            "total_bits_down": self.bits_down[-1] if self.bits_down else 0.0,
            "rounds": self.rounds[-1] if self.rounds else 0,
            "bits_to_target": self.bits_to_target,
            "steps_to_target": self.steps_to_target,
            "wall_time": self.wall_time,
        }
        if self.leaf_groups and self.leaf_bits:
            out["leaf_bits"] = dict(zip(self.leaf_groups,
                                        self.leaf_bits[-1]))
            out["leaf_bits_down"] = dict(zip(self.leaf_groups,
                                             self.leaf_bits_down[-1]))
        return out


def make_mask(run: RunConfig) -> np.ndarray:
    """The engine's [T, R] sync mask for this run's schedule."""
    if run.scenario is not None:
        if run.asynchronous:
            raise ValueError(
                "RunConfig.scenario and RunConfig.asynchronous are "
                "mutually exclusive: a scenario already generates the "
                "per-worker mask (use hetero_H for staggered workers)")
        return scn.parse(run.scenario).mask(run.total_steps, run.R,
                                            H=run.H)
    if run.asynchronous:
        return sched.async_schedule(run.total_steps, run.R, run.H,
                                    seed=run.seed)
    fixed = sched.fixed_schedule(run.total_steps, run.H)
    return np.broadcast_to(fixed[:, None], (run.total_steps, run.R)).copy()


def train(
    grad_fn: Callable,                       # (params, batch)->(loss, grads)
    params: Any,
    inner_opt: GradientTransform,
    operator: CompressionOp | Any = None,    # legacy; prefer run.policy
    lr_schedule: Callable = None,
    batches: Iterable = None,
    run: RunConfig = None,
    eval_fn: Optional[Callable] = None,      # (master_params) -> metrics dict
    smooth: int = 20,
) -> tuple[Any, History]:
    """Runs Algorithm 1 (or Algorithm 2 when run.asynchronous) via the
    unified engine.  Compression comes from ``run.policy`` (a
    ``core.policy`` spec resolved per leaf against ``params``) or the
    legacy ``operator`` argument; the schedule executes as round
    programs (``run.runtime == "round"``, the default) or the per-step
    host loop — identical math and History either way."""
    if run.runtime not in ("round", "step"):
        raise ValueError(
            f"RunConfig.runtime must be 'round' or 'step', "
            f"got {run.runtime!r}")
    if run.overlap:
        if run.runtime != "round":
            raise ValueError(
                "RunConfig.overlap requires the round runtime "
                "(runtime='round'); the per-step loop has no rounds "
                "to window")
        if run.faults is not None:
            raise ValueError(
                "RunConfig.overlap is unsupported with fault injection: "
                "the fault runtime's arrival events segment rounds "
                "dynamically (run with overlap=False)")
    key = jax.random.PRNGKey(run.seed)
    hist = History()
    t0 = time.time()
    dispatch = DispatchConfig(mode=run.dispatch, pack=run.pack)
    operator, downlink, channel_spec = resolve_run_channels(
        operator, run, params)
    if run.tune:
        from repro.kernels import autotune
        autotune.tune_for_run(operator, params, dispatch,
                              downlink=downlink, retune=run.retune)
    scn.validate_staleness_weight(run.staleness_weight)
    fault_spec = None
    tables = None
    if run.faults is not None:
        fault_spec = scn.parse_faults(run.faults)
        if run.fault_seed is not None:
            fault_spec = dataclasses.replace(fault_spec,
                                             seed=int(run.fault_seed))
    state = engine.init(params, inner_opt, run.R, downlink=downlink,
                        leaf_ledger=run.leaf_ledger,
                        queue_depth=(fault_spec.depth if fault_spec
                                     else None))
    mask = make_mask(run)
    if fault_spec is not None:
        # the fault tables expand from the dedicated fault seed — a
        # PRNG stream fully separate from the jax key stream above, so
        # enabling faults never perturbs batches or compression draws
        tables = fault_spec.tables(run.total_steps, run.R)
    if run.scenario is not None:
        scn.warn_if_biased(mask, run.aggregate)
    ckpt_policy = None if channel_spec is None else channel_spec.to_dict()
    if run.leaf_ledger:
        hist.leaf_groups = list(engine.leaf_group_names(params))

    # ---- crash-consistent resume (DESIGN.md §9) ---------------------
    start = 0
    if run.resume:
        if not run.ckpt_dir:
            raise ValueError("RunConfig.resume needs ckpt_dir")
        full = ckpt.latest_full(run.ckpt_dir)
        if full is not None:
            state, key, info = ckpt.restore_train_state(
                f"{run.ckpt_dir}/full_step_{full}", state, key)
            start = int(info["cursor"])
            want = fault_spec.to_string() if fault_spec else None
            if info.get("faults") != want:
                raise ValueError(
                    f"resume fault spec mismatch: checkpoint recorded "
                    f"{info.get('faults')!r}, this run derives {want!r}")
            it0 = iter(batches)
            for _ in range(start):   # the batch stream replays from 0
                next(it0, None)
            batches = it0

    def save_full(t_next: int, st, kk):
        ckpt.save_train_state(
            f"{run.ckpt_dir}/full_step_{t_next}", st, key=kk,
            cursor=t_next, policy=ckpt_policy,
            faults=fault_spec.to_string() if fault_spec else None,
            staleness_weight=run.staleness_weight)

    # ---- per-step bookkeeping, shared by both runtimes --------------
    # ``led`` carries the ledger scalars the step's state would hold;
    # in the round runtime mid-round steps read the previous round's
    # snapshot (bits/rounds only change at sync steps, so the values
    # are exactly the per-step path's).
    recent = []

    def snapshot_ledger(st) -> dict:
        led = {
            "bits": float(st.bits),
            "bits_down": float(st.bits_down),
            "rounds": int(st.rounds),
        }
        if run.leaf_ledger:
            led["leaf_bits"] = [float(b) for b in np.asarray(st.leaf_bits)]
            led["leaf_bits_down"] = [
                float(b) for b in np.asarray(st.leaf_bits_down)]
        return led

    def bookkeep_loss(t: int, lossf: float, led: dict):
        recent.append(lossf)
        if len(recent) > smooth:
            recent.pop(0)
        sm = float(np.mean(recent))
        if (t + 1) % run.log_every == 0 or t == run.total_steps - 1:
            hist.steps.append(t + 1)
            hist.loss.append(sm)
            hist.bits.append(led["bits"])
            hist.bits_down.append(led["bits_down"])
            hist.rounds.append(led["rounds"])
            if run.leaf_ledger:
                hist.leaf_bits.append(list(led["leaf_bits"]))
                hist.leaf_bits_down.append(list(led["leaf_bits_down"]))
        if (run.target_loss is not None and hist.bits_to_target is None
                and sm <= run.target_loss and len(recent) == smooth):
            hist.bits_to_target = led["bits"]
            hist.steps_to_target = t + 1

    def maybe_eval_ckpt(t: int, master):
        """Eval/checkpoint side effects of step t (reads ``master``,
        which in the round runtime must be the master the per-step path
        would hold after step t — mid-round that is the previous
        round's, materialized *before* the round program donates it)."""
        if eval_fn and run.eval_every and (t + 1) % run.eval_every == 0:
            hist.eval_steps.append(t + 1)
            hist.eval_metrics.append(
                {k: float(v) for k, v in eval_fn(master).items()})
        if run.ckpt_dir and run.ckpt_every and (t + 1) % run.ckpt_every == 0:
            ckpt.save(f"{run.ckpt_dir}/step_{t + 1}", master,
                      step=t + 1, policy=ckpt_policy)

    if fault_spec is not None:
        rows = engine.fault_rows(mask[:run.total_steps], tables, run.R)
        if run.runtime == "round":
            superstep = engine.make_fault_superstep(
                grad_fn, inner_opt, operator, lr_schedule, run.R,
                queue_depth=fault_spec.depth, dispatch=dispatch,
                global_rounds=not run.asynchronous, downlink=downlink,
                leaf_ledger=run.leaf_ledger, aggregate=run.aggregate,
                staleness_weight=run.staleness_weight)
            state, key = _drive_fault_rounds(
                state, superstep, batches, rows, tables, key, run, hist,
                snapshot_ledger, bookkeep_loss, maybe_eval_ckpt,
                save_full, start=start)
        else:
            step_fn = engine.donated_jit(engine.make_fault_step(
                grad_fn, inner_opt, operator, lr_schedule, run.R,
                queue_depth=fault_spec.depth, dispatch=dispatch,
                global_rounds=not run.asynchronous, downlink=downlink,
                leaf_ledger=run.leaf_ledger, aggregate=run.aggregate,
                staleness_weight=run.staleness_weight))
            for t, batch in enumerate(batches, start=start):
                if t >= run.total_steps:
                    break
                key, sub = jax.random.split(key)
                batch = jax.tree_util.tree_map(jnp.asarray, batch)
                state, loss = step_fn(state, batch,
                                      engine.index_rows(rows, t), sub)
                bookkeep_loss(t, float(loss), snapshot_ledger(state))
                maybe_eval_ckpt(t, state.master)
                if (run.ckpt_dir and run.ckpt_every
                        and (t + 1) % run.ckpt_every == 0):
                    save_full(t + 1, state, key)
    elif run.runtime == "round":
        superstep = engine.make_superstep(
            grad_fn, inner_opt, operator, lr_schedule, run.R,
            dispatch=dispatch, global_rounds=not run.asynchronous,
            downlink=downlink, leaf_ledger=run.leaf_ledger,
            aggregate=run.aggregate)
        drive = _drive_rounds_overlap if run.overlap else _drive_rounds
        state, key = drive(
            state, superstep, batches, mask, key, run, hist,
            snapshot_ledger, bookkeep_loss, maybe_eval_ckpt,
            save_full, start=start)
    else:
        step_fn = engine.donated_jit(engine.make_step(
            grad_fn, inner_opt, operator, lr_schedule, run.R,
            dispatch=dispatch, global_rounds=not run.asynchronous,
            downlink=downlink, leaf_ledger=run.leaf_ledger,
            aggregate=run.aggregate))
        for t, batch in enumerate(batches, start=start):
            if t >= run.total_steps:
                break
            key, sub = jax.random.split(key)
            batch = jax.tree_util.tree_map(jnp.asarray, batch)
            state, loss = step_fn(state, batch, jnp.asarray(mask[t]), sub)
            bookkeep_loss(t, float(loss), snapshot_ledger(state))
            maybe_eval_ckpt(t, state.master)
            if (run.ckpt_dir and run.ckpt_every
                    and (t + 1) % run.ckpt_every == 0):
                save_full(t + 1, state, key)
    hist.wall_time = time.time() - t0
    if run.ckpt_dir:
        ckpt.save(f"{run.ckpt_dir}/final", state.master,
                  step=run.total_steps, policy=ckpt_policy)
    return state, hist


def _drive_rounds(state, superstep, batches, mask, key, run: RunConfig,
                  hist: History, snapshot_ledger, bookkeep_loss,
                  maybe_eval_ckpt, save_full=None, start: int = 0):
    """The round-runtime drive loop (DESIGN.md §7): one donated program
    per round, next block assembled while the device runs the current
    round, ledger scalars + the [L] loss array fetched once per round.

    Donation discipline: every read of a state (ledger snapshot, eval,
    checkpoint) happens before the *next* round program consumes its
    buffers — mid-round eval/ckpt points (whose per-step semantics
    freeze the previous sync's master) run before the round is
    dispatched, tail points after.

    ``start``: global step of the window's first step (a resumed run
    re-segments the remaining schedule; ``mask`` must already be the
    ``[start:total]`` suffix is NOT assumed — it is sliced here).
    """
    plans = rnd.compile_rounds(mask[start:run.total_steps])
    fn = engine.donated_jit(superstep)
    it = iter(batches)

    def take(n: int) -> list:
        out = []
        for _ in range(n):
            try:
                out.append(next(it))
            except StopIteration:
                break
        return out

    led = snapshot_ledger(state)
    block_steps = take(plans[0].length) if plans else []
    for pi, plan in enumerate(plans):
        if not block_steps:
            break  # batch stream exhausted mid-schedule
        L = len(block_steps)
        g0 = start + plan.start   # global step of the round's first step
        # a truncated block never reaches the plan's tail step, whose
        # mask row is the only one that can sync — so its tail is the
        # (all-False) mask row of the last step it does reach
        tail_mask = (plan.mask if L == plan.length
                     else np.zeros_like(plan.mask))
        # mid-round eval/ckpt points read the pre-round master (it only
        # changes at sync): run them before the program donates it
        for i in range(L - 1):
            maybe_eval_ckpt(g0 + i, state.master)
        block = engine.stack_block(block_steps)
        state, losses_dev, key = fn(state, block,
                                    jnp.asarray(tail_mask), key)
        # prefetch: assemble the next round's batches while the device
        # executes this round (dispatch above is async)
        block_steps = (take(plans[pi + 1].length)
                       if pi + 1 < len(plans) else [])
        losses = np.asarray(losses_dev)   # one fetch per round
        new_led = snapshot_ledger(state)
        for i in range(L):
            bookkeep_loss(g0 + i, float(losses[i]),
                          new_led if i == L - 1 else led)
        maybe_eval_ckpt(g0 + L - 1, state.master)
        hist.round_blocks.append((g0, L, int(np.sum(tail_mask))))
        led = new_led
        if (save_full is not None and run.ckpt_dir and run.ckpt_every
                and (g0 + L) // run.ckpt_every > g0 // run.ckpt_every):
            # the first state boundary at/after each ckpt point: full
            # snapshots land on round boundaries in the round runtime
            save_full(g0 + L, state, key)
    return state, key


def _drive_rounds_overlap(state, superstep, batches, mask, key,
                          run: RunConfig, hist: History, snapshot_ledger,
                          bookkeep_loss, maybe_eval_ckpt, save_full=None,
                          start: int = 0):
    """The overlapped round-runtime drive loop (DESIGN.md §10):
    consecutive equal-length rounds execute as ONE scanned multi-round
    window (``rounds.window_rounds`` → ``engine.make_multiround``), so
    the device queue holds round r+1's local phase while round r's sync
    collective completes and the host pays one dispatch per window.

    History contract: identical to :func:`_drive_rounds` — the
    multi-round program emits per-round ledger stacks, so every round
    boundary's bits/rounds snapshot (and the per-step loss view built
    from them) is exactly the serialized driver's without materializing
    mid-window states.  Rounds containing eval/ckpt trigger steps are
    forced into singleton windows (``boundary_steps``), where the
    serialized body below preserves the donation discipline: mid-round
    reads happen before the round program consumes the state.
    """
    T = run.total_steps
    plans = rnd.compile_rounds(mask[start:T])
    bounds = set()
    if run.eval_every:
        bounds.update(t - start for t in range(start, T)
                      if (t + 1) % run.eval_every == 0)
    if run.ckpt_dir and run.ckpt_every:
        bounds.update(t - start for t in range(start, T)
                      if (t + 1) % run.ckpt_every == 0)
    windows = rnd.window_rounds(plans, max_window=run.overlap_window,
                                boundary_steps=sorted(bounds))
    serial_fn = engine.donated_jit(superstep)
    multi_fn = engine.donated_jit(engine._multiround_for(superstep))
    it = iter(batches)

    def take(n: int) -> list:
        out = []
        for _ in range(n):
            try:
                out.append(next(it))
            except StopIteration:
                break
        return out

    led = snapshot_ledger(state)
    for win in windows:
        W, L = len(win), win[0].length
        steps = take(W * L)
        if W > 1 and len(steps) == W * L:
            # ---- overlapped window: one dispatch, W scanned rounds --
            g0 = start + win[0].start
            blocks = engine.stack_window(steps, W, L)
            masks_arr = jnp.asarray(
                np.stack([np.asarray(p.mask) for p in win]))
            state, losses_dev, leds_dev, key = multi_fn(
                state, blocks, masks_arr, key)
            losses = np.asarray(losses_dev)              # [W, L]
            leds = {k: np.asarray(v) for k, v in leds_dev.items()}
            for wi, plan in enumerate(win):
                r0 = g0 + wi * L
                round_led = {
                    "bits": float(leds["bits"][wi]),
                    "bits_down": float(leds["bits_down"][wi]),
                    "rounds": int(leds["rounds"][wi]),
                }
                if run.leaf_ledger:
                    round_led["leaf_bits"] = [
                        float(b) for b in leds["leaf_bits"][wi]]
                    round_led["leaf_bits_down"] = [
                        float(b) for b in leds["leaf_bits_down"][wi]]
                for i in range(L):
                    bookkeep_loss(r0 + i, float(losses[wi, i]),
                                  round_led if i == L - 1 else led)
                hist.round_blocks.append(
                    (r0, L, int(np.sum(np.asarray(plan.mask)))))
                led = round_led
            # no eval/ckpt/full-snapshot points can fall inside a
            # multi-round window: those rounds are singletons above
            continue
        # ---- singleton window / truncated stream: serialized body ---
        exhausted = False
        for wi, plan in enumerate(win):
            seg = steps[wi * L:(wi + 1) * L]
            if not seg:
                exhausted = True
                break
            Ls = len(seg)
            g0 = start + plan.start
            tail_mask = (plan.mask if Ls == plan.length
                         else np.zeros_like(plan.mask))
            for i in range(Ls - 1):
                maybe_eval_ckpt(g0 + i, state.master)
            state, losses_dev, key = serial_fn(
                state, engine.stack_block(seg), jnp.asarray(tail_mask),
                key)
            losses = np.asarray(losses_dev)
            new_led = snapshot_ledger(state)
            for i in range(Ls):
                bookkeep_loss(g0 + i, float(losses[i]),
                              new_led if i == Ls - 1 else led)
            maybe_eval_ckpt(g0 + Ls - 1, state.master)
            hist.round_blocks.append((g0, Ls, int(np.sum(tail_mask))))
            led = new_led
            if (save_full is not None and run.ckpt_dir and run.ckpt_every
                    and (g0 + Ls) // run.ckpt_every > g0 // run.ckpt_every):
                save_full(g0 + Ls, state, key)
            if Ls < plan.length:
                exhausted = True
                break
        if exhausted:
            break
    return state, key


def _drive_fault_rounds(state, superstep, batches, rows, tables, key,
                        run: RunConfig, hist: History, snapshot_ledger,
                        bookkeep_loss, maybe_eval_ckpt, save_full=None,
                        start: int = 0):
    """Round-runtime drive loop for the fault runtime (DESIGN.md §9).

    Rounds close at *event* steps — scheduled syncs (even all-crashed
    ones: the empty round still gets its History entry with zero
    arrivals and zero bits) and payload arrivals — so mid-round ledger
    snapshots stay exactly the per-step path's.  On resume
    (``start > 0``) the restored in-flight queue's pending arrival
    steps are added as extra round boundaries.
    """
    T = run.total_steps
    win = engine.index_rows(rows, slice(start, T))
    win_tables = scn.FaultTables(*(np.asarray(x)[start:T]
                                   for x in tables))
    extra = None
    if start > 0:
        pending = np.asarray(state.arrive_at)
        extra = [int(a) - start for a in np.unique(pending)
                 if a >= start]
    plans = rnd.compile_fault_rounds(win.sync, win_tables,
                                     extra_events=extra)
    _, arrivals, _ = scn.fault_replay(win.sync, win_tables)
    fn = engine.donated_jit(superstep)
    it = iter(batches)

    def take(n: int) -> list:
        out = []
        for _ in range(n):
            try:
                out.append(next(it))
            except StopIteration:
                break
        return out

    led = snapshot_ledger(state)
    block_steps = take(plans[0].length) if plans else []
    for pi, plan in enumerate(plans):
        if not block_steps:
            break
        L = len(block_steps)
        g0 = start + plan.start
        block_rows = engine.index_rows(win, slice(plan.start,
                                                  plan.start + L))
        if L < plan.length:
            # truncated block: the steps reached are event-free heads
            block_rows = block_rows._replace(
                sync=np.zeros_like(block_rows.sync))
        for i in range(L - 1):
            maybe_eval_ckpt(g0 + i, state.master)
        block = engine.stack_block(block_steps)
        state, losses_dev, key = fn(state, block, block_rows, key)
        block_steps = (take(plans[pi + 1].length)
                       if pi + 1 < len(plans) else [])
        losses = np.asarray(losses_dev)
        new_led = snapshot_ledger(state)
        for i in range(L):
            bookkeep_loss(g0 + i, float(losses[i]),
                          new_led if i == L - 1 else led)
        maybe_eval_ckpt(g0 + L - 1, state.master)
        # n_synced for a fault round = payloads APPLIED at the tail
        # (the new semantics; an all-crashed scheduled sync records 0)
        n_applied = (int(arrivals[plan.start + L - 1].sum())
                     if L == plan.length else 0)
        hist.round_blocks.append((g0, L, n_applied))
        led = new_led
        if (save_full is not None and run.ckpt_dir and run.ckpt_every
                and (g0 + L) // run.ckpt_every > g0 // run.ckpt_every):
            save_full(g0 + L, state, key)
    return state, key

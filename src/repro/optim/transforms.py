"""Minimal optax-style gradient transforms (built from scratch; the
container has no optax).

The Qsparse engines need the *local* inner optimizer to expose the
update as a pure function so each worker can be vmapped/shard_mapped.

``update(grads, state, params, lr) -> (updates, new_state)`` where
``updates`` is the quantity to *subtract* scaled by +1, i.e. the new
params are ``params - updates`` (so updates already include the learning
rate).  This matches the paper's bookkeeping where
``x_t - x̂_{t+1/2}`` accumulates ``sum_j eta_j * d_j``.
"""

from __future__ import annotations

from typing import Any, Callable, NamedTuple

import jax
import jax.numpy as jnp

OptState = Any


class GradientTransform(NamedTuple):
    init: Callable[[Any], OptState]
    update: Callable[[Any, OptState, Any, jnp.ndarray], tuple[Any, OptState]]


def _zeros_like(tree):
    return jax.tree_util.tree_map(jnp.zeros_like, tree)


def sgd(weight_decay: float = 0.0) -> GradientTransform:
    def init(params):
        return ()

    def update(grads, state, params, lr):
        if weight_decay:
            grads = jax.tree_util.tree_map(
                lambda g, p: g + weight_decay * p, grads, params
            )
        updates = jax.tree_util.tree_map(lambda g: lr * g, grads)
        return updates, state

    return GradientTransform(init, update)


def momentum_sgd(
    momentum: float = 0.9, nesterov: bool = False, weight_decay: float = 0.0
) -> GradientTransform:
    """SGD with (heavy-ball) momentum, applied on local iterations as in
    the paper's ResNet-50 experiments (momentum 0.9)."""

    def init(params):
        return {"mu": _zeros_like(params)}

    def update(grads, state, params, lr):
        if weight_decay:
            grads = jax.tree_util.tree_map(
                lambda g, p: g + weight_decay * p, grads, params
            )
        mu = jax.tree_util.tree_map(
            lambda m, g: momentum * m + g, state["mu"], grads
        )
        if nesterov:
            eff = jax.tree_util.tree_map(
                lambda m, g: momentum * m + g, mu, grads
            )
        else:
            eff = mu
        updates = jax.tree_util.tree_map(lambda e: lr * e, eff)
        return updates, {"mu": mu}

    return GradientTransform(init, update)


def adam(
    b1: float = 0.9,
    b2: float = 0.999,
    eps: float = 1e-8,
    weight_decay: float = 0.0,
) -> GradientTransform:
    def init(params):
        return {
            "m": _zeros_like(params),
            "v": _zeros_like(params),
            "count": jnp.zeros((), jnp.int32),
        }

    def update(grads, state, params, lr):
        count = state["count"] + 1
        m = jax.tree_util.tree_map(
            lambda m_, g: b1 * m_ + (1 - b1) * g, state["m"], grads
        )
        v = jax.tree_util.tree_map(
            lambda v_, g: b2 * v_ + (1 - b2) * jnp.square(g), state["v"], grads
        )
        c = count.astype(jnp.float32)
        bc1 = 1 - b1**c
        bc2 = 1 - b2**c

        def upd(m_, v_, p):
            u = (m_ / bc1) / (jnp.sqrt(v_ / bc2) + eps)
            if weight_decay:
                u = u + weight_decay * p
            return lr * u

        updates = jax.tree_util.tree_map(upd, m, v, params)
        return updates, {"m": m, "v": v, "count": count}

    return GradientTransform(init, update)


def apply_updates(params, updates):
    """params - updates (updates already carry the learning rate)."""
    return jax.tree_util.tree_map(
        lambda p, u: (p - u).astype(p.dtype), params, updates
    )


def make_optimizer(name: str, **kw) -> GradientTransform:
    table = {"sgd": sgd, "momentum": momentum_sgd, "adam": adam}
    if name not in table:
        raise KeyError(f"unknown optimizer {name!r}; have {sorted(table)}")
    return table[name](**kw)

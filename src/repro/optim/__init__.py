from repro.optim.transforms import (
    GradientTransform,
    OptState,
    adam,
    momentum_sgd,
    sgd,
    apply_updates,
    make_optimizer,
)
from repro.optim.schedules import (
    constant,
    inverse_time,
    paper_convex_lr,
    piecewise_decay,
    warmup_cosine,
    warmup_piecewise,
)

__all__ = [
    "GradientTransform",
    "OptState",
    "adam",
    "momentum_sgd",
    "sgd",
    "apply_updates",
    "make_optimizer",
    "constant",
    "inverse_time",
    "paper_convex_lr",
    "piecewise_decay",
    "warmup_cosine",
    "warmup_piecewise",
]

"""Learning-rate schedules.

Includes the paper's schedules:
  * fixed eta = C/sqrt(T)                          (Theorem 1 / 4)
  * decaying eta_t = xi / (a + t)                  (Theorems 2, 3, 5, 6)
  * the convex-experiment schedule c / (lambda (a + t)) with a = d*H/k
  * ResNet-style warmup + piecewise decay          (Section 5.1)

All schedules are ``step -> lr`` callables usable under jit (step traced).
"""

from __future__ import annotations

import jax.numpy as jnp


def constant(lr: float):
    def fn(step):
        return jnp.asarray(lr, jnp.float32)

    return fn


def inverse_time(xi: float, a: float):
    """eta_t = xi / (a + t)  (paper Lemma 4 / Theorem 2/3 form)."""

    def fn(step):
        return jnp.asarray(xi, jnp.float32) / (a + step.astype(jnp.float32))

    return fn


def paper_convex_lr(c: float, lam: float, d: int, H: int, k: int):
    """Section 5.2.2: lr = c / (lambda (a + t)), a = d H / k."""
    a = float(d) * H / max(k, 1)
    return inverse_time(c / lam, a)


def piecewise_decay(base_lr: float, boundaries, factor: float = 0.1):
    bnds = jnp.asarray(list(boundaries), jnp.int32)

    def fn(step):
        n = jnp.sum(step >= bnds)
        return base_lr * factor ** n.astype(jnp.float32)

    return fn


def warmup_piecewise(base_lr: float, warmup_steps: int, boundaries,
                     factor: float = 0.1):
    """Linear warmup then piecewise decay (paper's ResNet-50 schedule)."""
    pw = piecewise_decay(base_lr, boundaries, factor)

    def fn(step):
        s = step.astype(jnp.float32)
        warm = base_lr * (s + 1.0) / max(warmup_steps, 1)
        return jnp.where(step < warmup_steps, warm, pw(step))

    return fn


def warmup_cosine(base_lr: float, warmup_steps: int, total_steps: int,
                  min_ratio: float = 0.1):
    def fn(step):
        s = step.astype(jnp.float32)
        warm = base_lr * (s + 1.0) / max(warmup_steps, 1)
        prog = jnp.clip(
            (s - warmup_steps) / max(total_steps - warmup_steps, 1), 0.0, 1.0
        )
        cos = base_lr * (min_ratio + (1 - min_ratio) * 0.5 * (1 + jnp.cos(jnp.pi * prog)))
        return jnp.where(step < warmup_steps, warm, cos)

    return fn

"""Family dispatcher: uniform access to every architecture family.

Each family exposes:
    init_params(key, cfg)
    loss_fn(params, batch, cfg, policy=...) -> (loss, aux)
    prefill(params, batch, cfg, policy=..., max_len=...) -> (logits, cache, n)
    decode_step(params, cache, token, pos, cfg, policy=...) -> (logits, cache)
    init_cache(cfg, batch, max_len)   (families with a decode path)
"""

from __future__ import annotations

from types import SimpleNamespace

from repro.configs.base import ModelConfig
from repro.models import moe, rwkv6, transformer, zamba2


def get_model(cfg: ModelConfig) -> SimpleNamespace:
    fam = cfg.family
    if fam == "dense":
        m = transformer
    elif fam == "moe":
        m = moe
    elif fam == "rwkv6":
        m = rwkv6
    elif fam == "zamba2":
        m = zamba2
    else:
        raise KeyError(f"unknown family {fam!r}")
    return SimpleNamespace(
        init_params=m.init_params,
        loss_fn=m.loss_fn,
        prefill=m.prefill,
        decode_step=m.decode_step,
        init_cache=getattr(m, "init_cache", None),
        forward=getattr(m, "forward", None),
        module=m,
    )

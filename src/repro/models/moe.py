"""Mixture-of-Experts decoder (llama4-maverick, qwen3-moe).

Expert parallelism: experts are sharded over the 'model' mesh axis.  The
MoE FFN is computed inside a nested ``shard_map`` manual over that axis:
each shard routes the (replicated) token activations to its *local*
experts through fixed-capacity buffers (sort-based position assignment,
overflow drops counted), runs a grouped dense einsum over local experts,
and the shards' partial outputs are combined with one ``psum`` — the
same wire class as a tensor-parallel MLP, with no flop-polluting
one-hot dispatch einsum (see DESIGN.md §4).

llama4-maverick: interleaved FFN (every ``moe_interleave``-th layer is
MoE, others dense) + a shared expert added to the routed output, top-1
routing.  qwen3: every layer MoE, top-8.
"""

from __future__ import annotations

import math

import numpy as np
from typing import Optional

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

from repro.compat import shard_map
from repro.configs.base import ModelConfig, NO_SHARDING, ShardingPolicy
from repro.models.layers import (
    attn_block_decode,
    attn_block_train,
    attn_params,
    cache_prefill,
    dense_init,
    embed,
    init_kv_cache,
    maybe_shard,
    mlp_params,
    norm_params,
    rmsnorm,
    swiglu,
)


# ---------------------------------------------------------------------------
# params
# ---------------------------------------------------------------------------


def _expert_params(key, cfg: ModelConfig, stacked: int | None):
    d, ff, E = cfg.d_model, cfg.d_ff, cfg.n_experts
    pre = (stacked,) if stacked else ()
    ks = jax.random.split(key, 5)
    p = {
        "router": dense_init(ks[0], pre + (d, E), jnp.float32),
        "w1": dense_init(ks[1], pre + (E, d, ff), cfg.pdtype),
        "w3": dense_init(ks[2], pre + (E, d, ff), cfg.pdtype),
        "w2": dense_init(ks[3], pre + (E, ff, d), cfg.pdtype),
    }
    if cfg.shared_expert:
        p["shared"] = mlp_params(ks[4], cfg, stacked, d_ff=cfg.dense_ff)
    return p


def _is_moe_layer(i: int, cfg: ModelConfig) -> bool:
    return (i + 1) % cfg.moe_interleave == 0


def init_params(key: jax.Array, cfg: ModelConfig):
    L, P_ = cfg.n_layers, cfg.moe_interleave
    assert L % P_ == 0, "n_layers must divide by moe_interleave"
    nper = L // P_
    ks = jax.random.split(key, 8)
    layers = {
        # all-layer stacks, reshaped to [nper, P_, ...] at scan time
        "ln1": norm_params(cfg, L),
        "attn": attn_params(ks[0], cfg, L),
        "ln2": norm_params(cfg, L),
        "moe": _expert_params(ks[1], cfg, nper),
    }
    if P_ > 1:
        layers["dense_mlp"] = mlp_params(ks[2], cfg, L - nper,
                                         d_ff=cfg.dense_ff)
    params = {
        "embed": dense_init(ks[3], (cfg.vocab, cfg.d_model), cfg.pdtype, scale=1.0),
        "layers": layers,
        "final_norm": norm_params(cfg, None),
        "head": dense_init(ks[4], (cfg.d_model, cfg.vocab), cfg.pdtype),
    }
    return params


# ---------------------------------------------------------------------------
# routed FFN
# ---------------------------------------------------------------------------


def _capacity(n_assign: int, E: int, cf: float) -> int:
    return max(4, int(math.ceil(n_assign / E * cf)))


def _route(x2d: jnp.ndarray, router: jnp.ndarray, cfg: ModelConfig):
    """x2d: [T, d].  Returns (eids [T,K], weights [T,K], aux_loss)."""
    logits = (x2d.astype(jnp.float32) @ router).astype(jnp.float32)
    probs = jax.nn.softmax(logits, axis=-1)
    w, eids = jax.lax.top_k(probs, cfg.moe_top_k)
    w = w / jnp.clip(jnp.sum(w, axis=-1, keepdims=True), 1e-9)
    # switch-style load-balance aux
    E = router.shape[-1]
    frac = jnp.mean(
        jnp.sum(jax.nn.one_hot(eids, E, dtype=jnp.float32), axis=1), axis=0
    )
    mean_p = jnp.mean(probs, axis=0)
    aux = E * jnp.sum(frac * mean_p)
    return eids, w.astype(jnp.float32), aux


def _assignments(eids, e_start, E_loc: int, C: int):
    """Shared routing bookkeeping: per-assignment local-expert id,
    capacity position, keep mask (sort-based position assignment)."""
    T, K = eids.shape
    A = T * K
    flat_e = eids.reshape(A)
    tok_of = jnp.repeat(jnp.arange(T), K)
    local = (flat_e >= e_start) & (flat_e < e_start + E_loc)
    le = jnp.where(local, flat_e - e_start, E_loc)  # E_loc = overflow bucket
    order = jnp.argsort(le, stable=True)
    le_sorted = le[order]
    start_of = jnp.searchsorted(le_sorted, jnp.arange(E_loc + 1))
    pos_sorted = jnp.arange(A) - start_of[le_sorted]
    pos = jnp.zeros(A, jnp.int32).at[order].set(pos_sorted.astype(jnp.int32))
    keep = local & (pos < C)
    le_c = jnp.where(keep, le, 0).astype(jnp.int32)
    pos_c = jnp.where(keep, pos, 0).astype(jnp.int32)
    return tok_of, local, keep, le_c, pos_c


def _expert_compute_local(
    x2d: jnp.ndarray,              # [T, d] tokens (replicated across EP shards)
    eids: jnp.ndarray,             # [T, K]
    weights: jnp.ndarray,          # [T, K]
    w1, w3, w2,                    # local expert stacks [E_loc, ...]
    e_start, E_loc: int, C: int,
    shard_axis: str | None = None,
):
    """Contribution of experts [e_start, e_start+E_loc) to every token.
    Returns ([T, d] partial output, dropped_assignments).

    ``shard_axis``: when running in XLA-auto mode (no nested shard_map),
    constrain the [E, C, *] buffers to shard over that mesh axis so the
    grouped einsums stay expert-parallel instead of replicating 100GB+
    expert stacks.
    """
    T, K = eids.shape
    d = x2d.shape[-1]
    A = T * K
    flat_w = weights.reshape(A)
    tok_of, local, keep, le, pos = _assignments(eids, e_start, E_loc, C)

    def eshard(t):
        if shard_axis is None:
            return t
        return maybe_shard(t, P(shard_axis, *([None] * (t.ndim - 1))))

    buf = jnp.zeros((E_loc, C, d), x2d.dtype)
    buf = buf.at[le, pos].add(
        jnp.where(keep[:, None], x2d[tok_of], 0))
    buf = eshard(buf)

    h = jnp.einsum("ecd,edf->ecf", buf, w1, preferred_element_type=jnp.float32)
    g = jnp.einsum("ecd,edf->ecf", buf, w3, preferred_element_type=jnp.float32)
    h = eshard((jax.nn.silu(h) * g).astype(x2d.dtype))
    o = jnp.einsum("ecf,efd->ecd", h, w2, preferred_element_type=jnp.float32)
    o = eshard(o)

    contrib = o[le, pos] * jnp.where(keep, flat_w, 0.0)[:, None]
    out = jnp.zeros((T, d), jnp.float32).at[tok_of].add(contrib)
    dropped = jnp.sum(local & ~keep)
    return out, dropped


def _expert_bwd_local(x2d, eids, weights, w1, w3, w2, e_start, E_loc, C,
                      dout):
    """Hand-written VJP of ``_expert_compute_local`` (this shard's
    contribution).  Recomputes the forward residuals from the inputs so
    nothing is checkpointed across the shard boundary.

    Returns (dx2d_partial, dweights_partial, dw1, dw3, dw2)."""
    T, K = eids.shape
    d = x2d.shape[-1]
    A = T * K
    flat_w = weights.reshape(A)
    tok_of, local, keep, le, pos = _assignments(eids, e_start, E_loc, C)

    # ---- recompute forward intermediates
    buf = jnp.zeros((E_loc, C, d), x2d.dtype)
    buf = buf.at[le, pos].add(jnp.where(keep[:, None], x2d[tok_of], 0))
    pre1 = jnp.einsum("ecd,edf->ecf", buf, w1,
                      preferred_element_type=jnp.float32)
    pre3 = jnp.einsum("ecd,edf->ecf", buf, w3,
                      preferred_element_type=jnp.float32)
    sig = jax.nn.sigmoid(pre1)
    silu1 = pre1 * sig
    h = (silu1 * pre3).astype(x2d.dtype)
    o = jnp.einsum("ecf,efd->ecd", h, w2,
                   preferred_element_type=jnp.float32)

    # ---- backward
    dcontrib = dout[tok_of]                                   # [A, d]
    wk = jnp.where(keep, flat_w, 0.0)
    # d(weights): contrib = o[le, pos] * w  =>  dw = <dout, o[le, pos]>
    dflat_w = jnp.sum(dcontrib * o[le, pos], axis=-1) * keep
    dweights = dflat_w.reshape(T, K)
    # d(o): scatter dout * w into slots
    do = jnp.zeros((E_loc, C, d), jnp.float32)
    do = do.at[le, pos].add(dcontrib.astype(jnp.float32) * wk[:, None])
    dh = jnp.einsum("ecd,efd->ecf", do, w2.astype(jnp.float32))
    dw2 = jnp.einsum("ecf,ecd->efd", h.astype(jnp.float32), do)
    dsilu1 = dh * pre3
    dpre3 = dh * silu1
    dpre1 = dsilu1 * (sig * (1 + pre1 * (1 - sig)))
    dbuf = (jnp.einsum("ecf,edf->ecd", dpre1, w1.astype(jnp.float32))
            + jnp.einsum("ecf,edf->ecd", dpre3, w3.astype(jnp.float32)))
    bw = buf.astype(jnp.float32)
    dw1 = jnp.einsum("ecd,ecf->edf", bw, dpre1)
    dw3 = jnp.einsum("ecd,ecf->edf", bw, dpre3)
    # d(x2d): gather dbuf back through the scatter
    dx_assign = dbuf[le, pos] * keep[:, None]
    dx2d = jnp.zeros((T, d), jnp.float32).at[tok_of].add(dx_assign)
    return dx2d, dweights, dw1, dw3, dw2


import functools


@functools.lru_cache(maxsize=64)
def _make_ep_apply(axis: str, E: int, C: int, nshards: int):
    """Expert-parallel apply with a hand-written VJP: both the forward
    and the backward run inside a nested shard_map manual over ``axis``
    (experts sharded), sidestepping JAX's unsupported AD-through-nested-
    shard_map path.  The expert-id offset comes from an arange operand
    ``er`` (no axis_index => no ambiguous PartitionId in SPMD lowering).

    Cached at module level with no traced closures (tracer-leak safe);
    the mesh is taken from the ambient context at call time.
    """
    E_loc = E // nshards

    def fwd_shard(x2d, eids, wts, w1, w3, w2, er):
        out, dropped = _expert_compute_local(
            x2d, eids, wts, w1, w3, w2, er[0], E_loc, C)
        return jax.lax.psum(out, axis), jax.lax.psum(dropped, axis)

    def bwd_shard(x2d, eids, wts, w1, w3, w2, er, dout):
        dx, dwts, dw1, dw3, dw2 = _expert_bwd_local(
            x2d, eids, wts, w1, w3, w2, er[0], E_loc, C, dout)
        return (jax.lax.psum(dx, axis), jax.lax.psum(dwts, axis),
                dw1, dw3, dw2)

    def _fwd_mapped(x2d, eids, wts, w1, w3, w2, er):
        mesh = jax.sharding.get_abstract_mesh()
        return shard_map(
            fwd_shard, mesh=mesh,
            in_specs=(P(), P(), P(), P(axis), P(axis), P(axis), P(axis)),
            out_specs=(P(), P()), axis_names={axis}, check_vma=True,
        )(x2d, eids, wts, w1, w3, w2, er)

    def _bwd_mapped(x2d, eids, wts, w1, w3, w2, er, dout):
        mesh = jax.sharding.get_abstract_mesh()
        return shard_map(
            bwd_shard, mesh=mesh,
            in_specs=(P(), P(), P(), P(axis), P(axis), P(axis), P(axis),
                      P()),
            out_specs=(P(), P(), P(axis), P(axis), P(axis)),
            axis_names={axis}, check_vma=True,
        )(x2d, eids, wts, w1, w3, w2, er, dout)

    @jax.custom_vjp
    def apply(x2d, eids, wts, w1, w3, w2, er):
        return _fwd_mapped(x2d, eids, wts, w1, w3, w2, er)

    def apply_fwd(x2d, eids, wts, w1, w3, w2, er):
        out = _fwd_mapped(x2d, eids, wts, w1, w3, w2, er)
        return out, (x2d, eids, wts, w1, w3, w2, er)

    def apply_bwd(res, cts):
        x2d, eids, wts, w1, w3, w2, er = res
        dout, _ = cts  # the dropped-count output carries no cotangent
        dx, dwts, dw1, dw3, dw2 = _bwd_mapped(
            x2d, eids, wts, w1, w3, w2, er, jnp.asarray(dout, jnp.float32))
        f0 = lambda a: np.zeros(a.shape, jax.dtypes.float0)
        return (dx.astype(x2d.dtype), f0(eids), dwts.astype(wts.dtype),
                dw1.astype(w1.dtype), dw3.astype(w3.dtype),
                dw2.astype(w2.dtype), f0(er))

    apply.defvjp(apply_fwd, apply_bwd)
    return apply


def moe_ffn(x: jnp.ndarray, mp, cfg: ModelConfig,
            policy: ShardingPolicy = NO_SHARDING):
    """x: [B, S, d] -> ([B, S, d], aux_metrics dict)."""
    B, S, d = x.shape
    if policy.ep_axis is not None:
        # tokens must be replicated across the EP axis at the shard_map
        # boundary (seq-sharded activations would force an illegal
        # Manual/Auto mixed spec); this is the EP all-gather.
        x = maybe_shard(x, P(None, None, None))
    x2d = x.reshape(B * S, d)
    eids, w, aux = _route(x2d, mp["router"], cfg)
    T = B * S
    E = cfg.n_experts
    C = _capacity(T * cfg.moe_top_k, E, cfg.capacity_factor)

    if policy.ep_axis is not None and not policy.vary_axes:
        # serving path (plain jit): explicit EP via nested shard_map
        nshards = jax.sharding.get_abstract_mesh().shape[policy.ep_axis]
        apply = _make_ep_apply(policy.ep_axis, E, C, nshards)
        out, dropped = apply(x2d, eids, w, mp["w1"], mp["w3"], mp["w2"],
                             jnp.arange(E))
    else:
        # training path (inside the manual-(pod,data) region): XLA-auto
        # expert parallelism with explicit [E, C, *] buffer constraints
        # (AD through a nested shard_map is unsupported in current JAX;
        # see DESIGN.md §4 and the custom_vjp note above).
        out, dropped = _expert_compute_local(
            x2d, eids, w, mp["w1"], mp["w3"], mp["w2"], 0, E, C,
            shard_axis=policy.ep_axis,
        )

    out = out.astype(x.dtype)
    if cfg.shared_expert:
        out = out + swiglu(x2d, mp["shared"])
    metrics = {"aux_loss": aux, "dropped": dropped.astype(jnp.float32)}
    return out.reshape(B, S, d), metrics


# ---------------------------------------------------------------------------
# stack
# ---------------------------------------------------------------------------


def _reshape_period(tree, nper: int, P_: int):
    return jax.tree_util.tree_map(
        lambda x: x.reshape((nper, P_) + x.shape[1:]), tree
    )


def apply_stack(params, h, positions, cfg: ModelConfig,
                policy: ShardingPolicy, collect_kv: bool = False):
    """Scan over periods of ``moe_interleave`` layers (last layer of each
    period is MoE; the preceding ones use the dense FFN stack)."""
    L, P_ = cfg.n_layers, cfg.moe_interleave
    nper = L // P_
    lay = params["layers"]
    windows = jnp.asarray(cfg.layer_windows(), jnp.int32).reshape(nper, P_)
    attn = _reshape_period(lay["attn"], nper, P_)
    ln1 = lay["ln1"].reshape(nper, P_, -1)
    ln2 = lay["ln2"].reshape(nper, P_, -1)
    if P_ > 1:
        dense_mlp = _reshape_period(lay["dense_mlp"], nper, P_ - 1)
    moe_p = lay["moe"]

    def body(carry, xs):
        h = carry
        attn_p, l1, l2, wins, moe_lp = xs[:5]
        dense_lp = xs[5] if P_ > 1 else None
        kvs = []
        for j in range(P_):
            lp_attn = jax.tree_util.tree_map(lambda x: x[j], attn_p)
            a, kv = attn_block_train(rmsnorm(h, l1[j]), lp_attn, cfg,
                                     wins[j], positions, policy)
            h = h + a
            hn = rmsnorm(h, l2[j])
            if j == P_ - 1:
                f, metrics = moe_ffn(hn, moe_lp, cfg, policy)
            else:
                lp_mlp = jax.tree_util.tree_map(lambda x: x[j], dense_lp)
                f = swiglu(hn, lp_mlp)
                metrics = None
            h = h + f
            h = maybe_shard(h, policy.act)
            kvs.append(kv)
        aux = metrics["aux_loss"]
        dropped = metrics["dropped"]
        ys = (kvs if collect_kv else None, aux, dropped)
        return h, ys

    body_fn = jax.checkpoint(body) if cfg.remat else body
    xs = (attn, ln1, ln2, windows, moe_p)
    if P_ > 1:
        xs = xs + (dense_mlp,)
    h, (kvs, aux, dropped) = jax.lax.scan(body_fn, h, xs)
    metrics = {"aux_loss": jnp.mean(aux), "dropped": jnp.sum(dropped)}
    return h, kvs, metrics


def loss_fn(params, batch, cfg: ModelConfig,
            policy: ShardingPolicy = NO_SHARDING, loss_chunk: int = 1024):
    tokens = batch["tokens"]
    inp, labels = tokens[:, :-1], tokens[:, 1:]
    h = embed(inp, params["embed"]).astype(cfg.adtype)
    B, S, _ = h.shape
    positions = jnp.broadcast_to(jnp.arange(S), (B, S))
    h, _, metrics = apply_stack(params, h, positions, cfg, policy)
    h = rmsnorm(h, params["final_norm"])
    W = params["head"]
    c = min(loss_chunk, S)
    pad = (-S) % c
    hp = jnp.pad(h, ((0, 0), (0, pad), (0, 0)))
    lp = jnp.pad(labels, ((0, 0), (0, pad)))
    msk = jnp.pad(jnp.ones((B, S), jnp.float32), ((0, 0), (0, pad)))
    n = hp.shape[1] // c
    hp = hp.reshape(B, n, c, -1).swapaxes(0, 1)
    lp = lp.reshape(B, n, c).swapaxes(0, 1)
    msk = msk.reshape(B, n, c).swapaxes(0, 1)

    def chunk_loss(carry, xs):
        hc, lc, mc = xs
        logits = (hc @ W).astype(jnp.float32)
        logits = maybe_shard(logits, policy.logits)
        lse = jax.nn.logsumexp(logits, axis=-1)
        gold = jnp.take_along_axis(logits, lc[..., None], axis=-1)[..., 0]
        return carry + jnp.sum((lse - gold) * mc), None

    from repro.models.layers import pvary
    total, _ = jax.lax.scan(chunk_loss,
                            pvary(jnp.zeros((), jnp.float32),
                                  policy.vary_axes), (hp, lp, msk))
    loss = total / (B * S) + cfg.router_aux_weight * metrics["aux_loss"]
    return loss, {"aux_loss": metrics["aux_loss"], "dropped": metrics["dropped"]}


# ---------------------------------------------------------------------------
# decode
# ---------------------------------------------------------------------------


def init_cache(cfg: ModelConfig, batch: int, max_len: int):
    wins = cfg.layer_windows()
    return init_kv_cache(cfg, batch, wins[0], max_len, stacked=cfg.n_layers)


def prefill(params, batch, cfg: ModelConfig,
            policy: ShardingPolicy = NO_SHARDING, max_len: Optional[int] = None):
    tokens = batch["tokens"]
    h = embed(tokens, params["embed"]).astype(cfg.adtype)
    B, S, _ = h.shape
    max_len = max_len or max(cfg.max_seq_len, S)
    positions = jnp.broadcast_to(jnp.arange(S), (B, S))
    h, kvs, _ = apply_stack(params, h, positions, cfg, policy, collect_kv=True)
    hl = rmsnorm(h[:, -1:], params["final_norm"])
    logits = (hl @ params["head"]).astype(jnp.float32)
    # kvs: list (per j in period) of (k, v) stacked [nper, B, S, KV, hd]
    L, P_ = cfg.n_layers, cfg.moe_interleave
    nper = L // P_
    k_all = jnp.stack([kvs[j][0] for j in range(P_)], axis=1).reshape(
        (L,) + kvs[0][0].shape[1:]
    )
    v_all = jnp.stack([kvs[j][1] for j in range(P_)], axis=1).reshape(
        (L,) + kvs[0][1].shape[1:]
    )
    cache = init_cache(cfg, B, max_len)
    cache = jax.vmap(lambda cc, k, v: cache_prefill(cc, k, v, S))(cache, k_all, v_all)
    return logits, cache, S


def decode_step(params, cache, token, pos, cfg: ModelConfig,
                policy: ShardingPolicy = NO_SHARDING):
    h = embed(token[:, None], params["embed"]).astype(cfg.adtype)
    L, P_ = cfg.n_layers, cfg.moe_interleave
    nper = L // P_
    lay = params["layers"]
    wins = cfg.layer_windows()

    def get(tree, i):
        return jax.tree_util.tree_map(lambda x: x[i], tree)

    dense_idx = 0
    new_cache_layers = []
    cache_list = [get(cache, i) for i in range(L)]
    for i in range(L):
        lp_attn = get(lay["attn"], i)
        a, c = attn_block_decode(rmsnorm(h, lay["ln1"][i]), lp_attn, cfg,
                                 cache_list[i], pos, wins[i])
        h = h + a
        hn = rmsnorm(h, lay["ln2"][i])
        if _is_moe_layer(i, cfg):
            f, _ = moe_ffn(hn, get(lay["moe"], i // P_), cfg, policy)
        else:
            f = swiglu(hn, get(lay["dense_mlp"], dense_idx))
            dense_idx += 1
        h = h + f
        new_cache_layers.append(c)
    new_cache = jax.tree_util.tree_map(
        lambda *xs: jnp.stack(xs), *new_cache_layers
    )
    h = rmsnorm(h, params["final_norm"])
    logits = (h[:, 0] @ params["head"]).astype(jnp.float32)
    return maybe_shard(logits, policy.logits), new_cache

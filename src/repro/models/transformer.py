"""Dense decoder-only transformer (yi, stablelm, gemma3, musicgen,
internvl2 backbones).

Training/prefill scan over a stacked [L, ...] parameter pytree; the
per-layer attention window rides along as a traced [L] array so mixed
local/global patterns (gemma3 5:1) still scan.  Decode unrolls only when
cache shapes are heterogeneous (mixed windows => per-layer ring-cache
lengths differ).

Multimodal backbones (musicgen audio / internvl2 vision) consume
precomputed frontend embeddings prepended to the token embeddings — the
frontend itself is the one allowed stub (see DESIGN.md).
"""

from __future__ import annotations

from typing import Optional

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig, NO_SHARDING, ShardingPolicy
from repro.models.layers import (
    attn_block_decode,
    attn_block_decode_paged,
    attn_block_train,
    attn_params,
    cache_prefill,
    dense_init,
    embed,
    init_kv_cache,
    maybe_shard,
    mlp_params,
    norm_params,
    rmsnorm,
    swiglu,
)


# ---------------------------------------------------------------------------
# params
# ---------------------------------------------------------------------------


def init_params(key: jax.Array, cfg: ModelConfig):
    L = cfg.n_layers
    ks = jax.random.split(key, 6)
    stacked = L if cfg.scan_layers else None
    if cfg.scan_layers:
        layers = {
            "ln1": norm_params(cfg, L),
            "attn": attn_params(ks[0], cfg, L),
            "ln2": norm_params(cfg, L),
            "mlp": mlp_params(ks[1], cfg, L),
        }
    else:
        layers = []
        lk = jax.random.split(ks[0], L)
        for i in range(L):
            k1, k2 = jax.random.split(lk[i])
            layers.append({
                "ln1": norm_params(cfg, None),
                "attn": attn_params(k1, cfg, None),
                "ln2": norm_params(cfg, None),
                "mlp": mlp_params(k2, cfg, None),
            })
    params = {
        "embed": dense_init(ks[2], (cfg.vocab, cfg.d_model), cfg.pdtype, scale=1.0),
        "layers": layers,
        "final_norm": norm_params(cfg, None),
    }
    if not cfg.tie_embeddings:
        params["head"] = dense_init(ks[3], (cfg.d_model, cfg.vocab), cfg.pdtype)
    return params


def _apply_head(h, params, cfg: ModelConfig):
    """LM head application: ``h @ head`` (or ``h @ embed.T`` when tied).
    Duck-typed on ``.matmul`` so a compressed serving table — whose rows
    enumerate the vocab either way — serves both variants without the
    transpose that a compact tensor cannot express."""
    w = params["embed"] if cfg.tie_embeddings else params["head"]
    if hasattr(w, "matmul"):
        return w.matmul(h)
    return h @ (w.T if cfg.tie_embeddings else w)


# ---------------------------------------------------------------------------
# forward (training / prefill)
# ---------------------------------------------------------------------------


def _layer_train(h, lp, window, positions, cfg, policy):
    a, kv = attn_block_train(rmsnorm(h, lp["ln1"]), lp["attn"], cfg, window,
                             positions, policy)
    h = h + a
    h = h + swiglu(rmsnorm(h, lp["ln2"]), lp["mlp"])
    h = maybe_shard(h, policy.act)
    return h, kv


def apply_stack(params, h, positions, cfg: ModelConfig,
                policy: ShardingPolicy, collect_kv: bool = False):
    """Runs all layers.  Returns (h, kv_stack|None).  kv_stack leaves are
    [L, B, S, KV, hd]."""
    windows = jnp.asarray(cfg.layer_windows(), jnp.int32)
    if cfg.scan_layers:
        def body(carry, xs):
            lp, w = xs
            hh, kv = _layer_train(carry, lp, w, positions, cfg, policy)
            return hh, (kv if collect_kv else None)

        body_fn = jax.checkpoint(body) if cfg.remat else body
        h, kvs = jax.lax.scan(body_fn, h, (params["layers"], windows))
        return h, kvs
    kvs = []
    wins = cfg.layer_windows()
    for i, lp in enumerate(params["layers"]):
        h, kv = _layer_train(h, lp, int(wins[i]), positions, cfg, policy)
        if collect_kv:
            kvs.append(kv)
    return h, (kvs if collect_kv else None)


def embed_inputs(params, batch: dict, cfg: ModelConfig):
    """Returns (h, n_prefix): token embeddings with optional multimodal
    prefix embeddings prepended."""
    tokens = batch["tokens"]
    h = embed(tokens, params["embed"]).astype(cfg.adtype)
    if "prefix_embeds" in batch:
        pre = batch["prefix_embeds"].astype(cfg.adtype)
        h = jnp.concatenate([pre, h], axis=1)
        return h, pre.shape[1]
    return h, 0


def forward(params, batch: dict, cfg: ModelConfig,
            policy: ShardingPolicy = NO_SHARDING):
    """Full-sequence logits [B, S_total, V]."""
    h, _ = embed_inputs(params, batch, cfg)
    B, S, _ = h.shape
    positions = jnp.broadcast_to(jnp.arange(S), (B, S))
    h, _ = apply_stack(params, h, positions, cfg, policy)
    h = rmsnorm(h, params["final_norm"])
    logits = _apply_head(h, params, cfg)
    return maybe_shard(logits.astype(jnp.float32), policy.logits)


def loss_fn(params, batch: dict, cfg: ModelConfig,
            policy: ShardingPolicy = NO_SHARDING,
            loss_chunk: int = 1024):
    """Next-token CE over the token segment (prefix embeddings are
    context only).  The LM head is applied in sequence chunks so the
    [B, S, V] f32 logits tensor is never fully materialized."""
    tokens = batch["tokens"]            # [B, S+1]
    inp = {**batch, "tokens": tokens[:, :-1]}
    labels = tokens[:, 1:]
    h, n_prefix = embed_inputs(params, inp, cfg)
    B, S, _ = h.shape
    positions = jnp.broadcast_to(jnp.arange(S), (B, S))
    h, _ = apply_stack(params, h, positions, cfg, policy)
    h = rmsnorm(h, params["final_norm"])
    if n_prefix:
        h = h[:, n_prefix:]
    Stok = h.shape[1]
    c = min(loss_chunk, Stok)
    pad = (-Stok) % c
    hp = jnp.pad(h, ((0, 0), (0, pad), (0, 0)))
    lp = jnp.pad(labels, ((0, 0), (0, pad)))
    msk = jnp.pad(jnp.ones((B, Stok), jnp.float32), ((0, 0), (0, pad)))
    n = hp.shape[1] // c
    hp = hp.reshape(B, n, c, -1).swapaxes(0, 1)
    lp = lp.reshape(B, n, c).swapaxes(0, 1)
    msk = msk.reshape(B, n, c).swapaxes(0, 1)

    def chunk_loss(carry, xs):
        hc, lc, mc = xs
        logits = _apply_head(hc, params, cfg).astype(jnp.float32)
        logits = maybe_shard(logits, policy.logits)
        lse = jax.nn.logsumexp(logits, axis=-1)
        gold = jnp.take_along_axis(logits, lc[..., None], axis=-1)[..., 0]
        nll = (lse - gold) * mc
        return carry + jnp.sum(nll), None

    from repro.models.layers import pvary
    total, _ = jax.lax.scan(chunk_loss,
                            pvary(jnp.zeros((), jnp.float32),
                                  policy.vary_axes),
                            (hp, lp, msk))
    loss = total / (B * Stok)
    return loss, {"ntokens": B * Stok}


# ---------------------------------------------------------------------------
# decode path
# ---------------------------------------------------------------------------


def uniform_windows(cfg: ModelConfig) -> bool:
    return len(set(cfg.layer_windows())) == 1


def init_cache(cfg: ModelConfig, batch: int, max_len: int):
    wins = cfg.layer_windows()
    if uniform_windows(cfg) and cfg.scan_layers:
        return init_kv_cache(cfg, batch, wins[0], max_len, stacked=cfg.n_layers)
    return [init_kv_cache(cfg, batch, w, max_len) for w in wins]


def prefill(params, batch: dict, cfg: ModelConfig,
            policy: ShardingPolicy = NO_SHARDING, max_len: Optional[int] = None):
    """Consume the prompt; return (last_token_logits, cache, n_consumed)."""
    h, n_prefix = embed_inputs(params, batch, cfg)
    B, S, _ = h.shape
    max_len = max_len or max(cfg.max_seq_len, S)
    positions = jnp.broadcast_to(jnp.arange(S), (B, S))
    h, kvs = apply_stack(params, h, positions, cfg, policy, collect_kv=True)
    hl = rmsnorm(h[:, -1:], params["final_norm"])
    logits = _apply_head(hl, params, cfg).astype(jnp.float32)
    wins = cfg.layer_windows()
    if uniform_windows(cfg) and cfg.scan_layers:
        cache = init_kv_cache(cfg, B, wins[0], max_len, stacked=cfg.n_layers)
        cache = jax.vmap(lambda c, k, v: cache_prefill(c, k, v, S))(
            cache, kvs[0], kvs[1]
        )
    else:
        cache = []
        for i, w in enumerate(wins):
            c = init_kv_cache(cfg, B, w, max_len)
            if cfg.scan_layers:  # scan stacked the kv on a leading L axis
                k, v = kvs[0][i], kvs[1][i]
            else:
                k, v = kvs[i]
            cache.append(cache_prefill(c, k, v, S))
    return logits, cache, S


def decode_step(params, cache, token: jax.Array, pos, cfg: ModelConfig,
                policy: ShardingPolicy = NO_SHARDING):
    """One decode step.  token: [B] int32; pos: scalar global position.
    Returns (logits [B, V], new_cache)."""
    h = embed(token[:, None], params["embed"]).astype(cfg.adtype)
    windows = jnp.asarray(cfg.layer_windows(), jnp.int32)

    def layer(h, lp, cache_l, w):
        a, new_c = attn_block_decode(rmsnorm(h, lp["ln1"]), lp["attn"], cfg,
                                     cache_l, pos, w)
        h = h + a
        h = h + swiglu(rmsnorm(h, lp["ln2"]), lp["mlp"])
        return h, new_c

    if uniform_windows(cfg) and cfg.scan_layers:
        def body(carry, xs):
            lp, c, w = xs
            hh, new_c = layer(carry, lp, c, w)
            return hh, new_c

        h, new_cache = jax.lax.scan(body, h, (params["layers"], cache, windows))
    else:
        wins = cfg.layer_windows()
        new_cache = []
        layer_params = (
            params["layers"] if not cfg.scan_layers
            else [jax.tree_util.tree_map(lambda x: x[i], params["layers"])
                  for i in range(cfg.n_layers)]
        )
        for i, lp in enumerate(layer_params):
            h, c = layer(h, lp, cache[i], wins[i])
            new_cache.append(c)
    h = rmsnorm(h, params["final_norm"])
    logits = _apply_head(h[:, 0], params, cfg).astype(jnp.float32)
    return maybe_shard(logits, policy.logits), new_cache


def decode_step_paged(params, pool, tables, tokens: jax.Array,
                      positions: jax.Array, active: jax.Array,
                      cfg: ModelConfig,
                      policy: ShardingPolicy = NO_SHARDING):
    """One decode step over the shared KV page pool, whole slot batch at
    once.  tokens/positions/active: [B] (per-slot token, position and
    liveness); pool: ``PagedKVCache`` stacked [L, ...]; tables: [B, P]
    block tables shared by every layer.  Returns (logits [B, V],
    new_pool).  Paged serving is gated to uniform-window scanned stacks
    (full attention) — the engine enforces it; this asserts it."""
    if not (uniform_windows(cfg) and cfg.scan_layers):
        raise ValueError("paged decode requires uniform windows and "
                         "scanned layers")
    h = embed(tokens[:, None], params["embed"]).astype(cfg.adtype)

    def body(carry, xs):
        lp, pool_l = xs
        a, new_pool = attn_block_decode_paged(
            rmsnorm(carry, lp["ln1"]), lp["attn"], cfg, pool_l, tables,
            positions, active)
        hh = carry + a
        hh = hh + swiglu(rmsnorm(hh, lp["ln2"]), lp["mlp"])
        return hh, new_pool

    h, new_pool = jax.lax.scan(body, h, (params["layers"], pool))
    h = rmsnorm(h, params["final_norm"])
    logits = _apply_head(h[:, 0], params, cfg).astype(jnp.float32)
    return maybe_shard(logits, policy.logits), new_pool

"""ResNet (He et al. 2016) — the paper's non-convex experiment model
family (ResNet-50 on ImageNet in Section 5.1).

Pure-functional JAX; normalization is GroupNorm (a documented
substitution for BatchNorm to keep the model stateless under
vmap-over-workers — local BN statistics would leak across Qsparse
workers otherwise and GN is batch-size independent, which matters at
per-worker batch sizes).
"""

from __future__ import annotations

import dataclasses
from typing import Sequence

import jax
import jax.numpy as jnp

from repro.models.layers import dense_init


@dataclasses.dataclass(frozen=True)
class ResNetConfig:
    name: str = "resnet"
    stage_sizes: Sequence[int] = (2, 2, 2, 2)   # resnet18
    bottleneck: bool = False                    # True => resnet50-style
    width: int = 64
    num_classes: int = 10
    in_channels: int = 3
    groups: int = 8
    param_dtype: str = "float32"
    stem_stride: int = 1                        # 1 for CIFAR-size inputs

    @property
    def pdtype(self):
        return jnp.dtype(self.param_dtype)


def resnet50_config(num_classes: int = 1000) -> ResNetConfig:
    return ResNetConfig(name="resnet50", stage_sizes=(3, 4, 6, 3),
                        bottleneck=True, num_classes=num_classes,
                        stem_stride=2)


def resnet8_config(num_classes: int = 10) -> ResNetConfig:
    """Small CIFAR-scale variant for the reproduction experiments."""
    return ResNetConfig(name="resnet8", stage_sizes=(1, 1, 1),
                        bottleneck=False, width=16, num_classes=num_classes)


def _conv_init(key, k, cin, cout, dtype):
    fan = k * k * cin
    return (jax.random.normal(key, (k, k, cin, cout), jnp.float32)
            * (2.0 / fan) ** 0.5).astype(dtype)


def _gn_params(c, dtype):
    return {"scale": jnp.ones((c,), dtype), "bias": jnp.zeros((c,), dtype)}


def init_params(key, cfg: ResNetConfig):
    ks = iter(jax.random.split(key, 4 + sum(cfg.stage_sizes) * 4 + len(cfg.stage_sizes)))
    w = cfg.width
    params = {
        "stem": {"conv": _conv_init(next(ks), 3, cfg.in_channels, w, cfg.pdtype),
                 "gn": _gn_params(w, cfg.pdtype)},
        "stages": [],
    }
    cin = w
    for si, n in enumerate(cfg.stage_sizes):
        cout = w * (2 ** si)
        blocks = []
        for bi in range(n):
            stride = 2 if (bi == 0 and si > 0) else 1
            if cfg.bottleneck:
                mid = cout
                cexp = cout * 4
                blk = {
                    "conv1": _conv_init(next(ks), 1, cin, mid, cfg.pdtype),
                    "gn1": _gn_params(mid, cfg.pdtype),
                    "conv2": _conv_init(next(ks), 3, mid, mid, cfg.pdtype),
                    "gn2": _gn_params(mid, cfg.pdtype),
                    "conv3": _conv_init(next(ks), 1, mid, cexp, cfg.pdtype),
                    "gn3": _gn_params(cexp, cfg.pdtype),
                }
                if cin != cexp or stride != 1:
                    blk["proj"] = _conv_init(next(ks), 1, cin, cexp, cfg.pdtype)
                cin = cexp
            else:
                blk = {
                    "conv1": _conv_init(next(ks), 3, cin, cout, cfg.pdtype),
                    "gn1": _gn_params(cout, cfg.pdtype),
                    "conv2": _conv_init(next(ks), 3, cout, cout, cfg.pdtype),
                    "gn2": _gn_params(cout, cfg.pdtype),
                }
                if cin != cout or stride != 1:
                    blk["proj"] = _conv_init(next(ks), 1, cin, cout, cfg.pdtype)
                cin = cout
            blocks.append(blk)
        params["stages"].append(blocks)
    params["head"] = dense_init(next(ks), (cin, cfg.num_classes), cfg.pdtype)
    params["head_b"] = jnp.zeros((cfg.num_classes,), cfg.pdtype)
    return params


def _conv(x, w, stride=1):
    return jax.lax.conv_general_dilated(
        x, w, (stride, stride), "SAME",
        dimension_numbers=("NHWC", "HWIO", "NHWC"),
    )


def _gn(x, p, groups):
    c = x.shape[-1]
    g = min(groups, c)
    xg = x.reshape(x.shape[:-1] + (g, c // g)).astype(jnp.float32)
    mean = jnp.mean(xg, axis=(1, 2, 4), keepdims=True)
    var = jnp.var(xg, axis=(1, 2, 4), keepdims=True)
    xg = (xg - mean) * jax.lax.rsqrt(var + 1e-5)
    out = xg.reshape(x.shape) * p["scale"] + p["bias"]
    return out.astype(x.dtype)


def _block(x, blk, cfg: ResNetConfig, stride: int):
    r = x
    if cfg.bottleneck:
        y = jax.nn.relu(_gn(_conv(x, blk["conv1"]), blk["gn1"], cfg.groups))
        y = jax.nn.relu(_gn(_conv(y, blk["conv2"], stride), blk["gn2"], cfg.groups))
        y = _gn(_conv(y, blk["conv3"]), blk["gn3"], cfg.groups)
    else:
        y = jax.nn.relu(_gn(_conv(x, blk["conv1"], stride), blk["gn1"], cfg.groups))
        y = _gn(_conv(y, blk["conv2"]), blk["gn2"], cfg.groups)
    if "proj" in blk:
        r = _conv(x, blk["proj"], stride)
    return jax.nn.relu(y + r)


def forward(params, images, cfg: ResNetConfig):
    """images: [B, H, W, C] -> logits [B, num_classes]."""
    x = _conv(images.astype(cfg.pdtype), params["stem"]["conv"], cfg.stem_stride)
    x = jax.nn.relu(_gn(x, params["stem"]["gn"], cfg.groups))
    for si, blocks in enumerate(params["stages"]):
        for bi, blk in enumerate(blocks):
            stride = 2 if (bi == 0 and si > 0) else 1
            x = _block(x, blk, cfg, stride)
    x = jnp.mean(x, axis=(1, 2))
    return (x @ params["head"] + params["head_b"]).astype(jnp.float32)


def loss_fn(params, batch, cfg: ResNetConfig):
    logits = forward(params, batch["images"], cfg)
    labels = batch["labels"]
    lse = jax.nn.logsumexp(logits, axis=-1)
    gold = jnp.take_along_axis(logits, labels[:, None], axis=-1)[:, 0]
    loss = jnp.mean(lse - gold)
    acc = jnp.mean((jnp.argmax(logits, -1) == labels).astype(jnp.float32))
    return loss, {"accuracy": acc}

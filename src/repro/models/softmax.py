"""Softmax (multinomial logistic) regression with an l2 regularizer —
the paper's convex objective (Section 5.2):

    -(1/n) sum_i sum_j 1{b_i = j} log h_{x,z}(a_i) + (lambda/2) ||x||^2

Parameters: weight columns x_j in R^d per class plus biases z.  For
MNIST-shaped data (d=784, L=10) this is exactly the paper's d=7850
parameter problem.
"""

from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp


@dataclasses.dataclass(frozen=True)
class SoftmaxConfig:
    name: str = "mnist_softmax"
    input_dim: int = 784
    num_classes: int = 10
    l2: float = 1e-4          # lambda; paper uses 1/n


def init_params(key, cfg: SoftmaxConfig):
    return {
        "x": jnp.zeros((cfg.input_dim, cfg.num_classes), jnp.float32),
        "z": jnp.zeros((cfg.num_classes,), jnp.float32),
    }


def forward(params, feats):
    return feats @ params["x"] + params["z"]


def loss_fn(params, batch, cfg: SoftmaxConfig):
    logits = forward(params, batch["features"])
    labels = batch["labels"]
    lse = jax.nn.logsumexp(logits, axis=-1)
    gold = jnp.take_along_axis(logits, labels[:, None], axis=-1)[:, 0]
    nll = jnp.mean(lse - gold)
    reg = 0.5 * cfg.l2 * jnp.sum(jnp.square(params["x"]))
    acc = jnp.mean((jnp.argmax(logits, -1) == labels).astype(jnp.float32))
    return nll + reg, {"accuracy": acc, "nll": nll}


def strong_convexity(cfg: SoftmaxConfig) -> float:
    """mu >= lambda (the regularizer's contribution)."""
    return cfg.l2

"""RWKV-6 "Finch" (arXiv:2404.05892): attention-free, data-dependent
per-channel decay linear recurrence.

Per head (head size N), with receptance r_t, key k_t, value v_t, decay
w_t in (0,1)^N (data dependent) and bonus u in R^N:

    out_t = r_t @ (S_{t-1} + diag(u) k_t v_t^T)
    S_t   = diag(w_t) S_{t-1} + k_t v_t^T

Training runs a *chunked* form: within a chunk of length c the
recurrence is unrolled into a masked quadratic form with per-channel
decay factors accumulated in log space (numerically safe because
w = exp(-exp(x)) < 1), and the [N, N] state is carried across chunks by
a scan — O(S*c) memory instead of O(S^2) or a length-S sequential scan.
A naive sequential reference (`wkv6_ref`) backs the correctness tests.

Decode is the O(1) recurrence — this is why rwkv6 runs the ``long_500k``
shape natively.

Simplifications vs the released checkpoints (documented in DESIGN.md):
static token-shift mixing coefficients (v6 makes them data-dependent via
tiny LoRAs) and a single LoRA for the decay only.
"""

from __future__ import annotations

from typing import NamedTuple, Optional

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig, NO_SHARDING, ShardingPolicy
from repro.models.layers import dense_init, embed, maybe_shard, rmsnorm


# ---------------------------------------------------------------------------
# params
# ---------------------------------------------------------------------------


def init_params(key: jax.Array, cfg: ModelConfig):
    d, L, ff = cfg.d_model, cfg.n_layers, cfg.d_ff
    N = cfg.ssm_head_dim
    H = d // N
    ks = jax.random.split(key, 16)
    lora = max(32, N)
    layers = {
        "ln1": jnp.zeros((L, d), cfg.pdtype),
        "ln2": jnp.zeros((L, d), cfg.pdtype),
        # time-mix
        "mix_r": jnp.full((L, d), 0.5, cfg.pdtype),
        "mix_k": jnp.full((L, d), 0.5, cfg.pdtype),
        "mix_v": jnp.full((L, d), 0.5, cfg.pdtype),
        "mix_w": jnp.full((L, d), 0.5, cfg.pdtype),
        "mix_g": jnp.full((L, d), 0.5, cfg.pdtype),
        "wr": dense_init(ks[0], (L, d, d), cfg.pdtype),
        "wk": dense_init(ks[1], (L, d, d), cfg.pdtype),
        "wv": dense_init(ks[2], (L, d, d), cfg.pdtype),
        "wg": dense_init(ks[3], (L, d, d), cfg.pdtype),
        "wo": dense_init(ks[4], (L, d, d), cfg.pdtype),
        # data-dependent decay: w = exp(-exp(w0 + tanh(x A) B))
        "w0": jnp.full((L, d), -2.0, jnp.float32),
        "wA": dense_init(ks[5], (L, d, lora), cfg.pdtype),
        "wB": dense_init(ks[6], (L, lora, d), cfg.pdtype, scale=0.01),
        "bonus": jnp.zeros((L, H, N), jnp.float32),
        "ln_x": jnp.zeros((L, d), cfg.pdtype),  # per-head groupnorm gain
        # channel-mix
        "cmix_k": jnp.full((L, d), 0.5, cfg.pdtype),
        "cmix_r": jnp.full((L, d), 0.5, cfg.pdtype),
        "ck": dense_init(ks[7], (L, d, ff), cfg.pdtype),
        "cv": dense_init(ks[8], (L, ff, d), cfg.pdtype),
        "cr": dense_init(ks[9], (L, d, d), cfg.pdtype),
    }
    return {
        "embed": dense_init(ks[10], (cfg.vocab, d), cfg.pdtype, scale=1.0),
        "layers": layers,
        "final_norm": jnp.zeros((d,), cfg.pdtype),
        "head": dense_init(ks[11], (d, cfg.vocab), cfg.pdtype),
    }


# ---------------------------------------------------------------------------
# wkv6 core
# ---------------------------------------------------------------------------


def wkv6_ref(r, k, v, w, u):
    """Sequential oracle.  r,k,v,w: [B, S, H, N]; u: [H, N].
    Returns out [B, S, H, N]."""
    B, S, H, N = r.shape

    def step(S_state, xs):
        r_t, k_t, v_t, w_t = xs  # [B, H, N]
        kv = jnp.einsum("bhk,bhv->bhkv", k_t, v_t)
        # diag(u) applies on the key index of k_t v_t^T
        out = jnp.einsum(
            "bhk,bhkv->bhv", r_t,
            S_state + jnp.einsum("hk,bhkv->bhkv", u, kv),
        )
        S_new = w_t[..., None] * S_state + kv
        return S_new, out

    S0 = jnp.zeros((B, H, N, N), jnp.float32)
    xs = tuple(jnp.moveaxis(t.astype(jnp.float32), 1, 0) for t in (r, k, v, w))
    _, outs = jax.lax.scan(step, S0, xs)
    return jnp.moveaxis(outs, 0, 1)


def wkv6_chunked(r, k, v, w, u, chunk: int = 32, return_state: bool = False,
                 vary_axes=()):
    """Chunked parallel form.  Same signature as wkv6_ref."""
    B, S, H, N = r.shape
    c = min(chunk, S)
    pad = (-S) % c
    if pad:
        padf = lambda t, val=0.0: jnp.pad(
            t, ((0, 0), (0, pad), (0, 0), (0, 0)), constant_values=val
        )
        r, k, v = padf(r), padf(k), padf(v)
        w = padf(w, 1.0)  # decay 1 on padding keeps state unchanged
    Sp = r.shape[1]
    nch = Sp // c

    def reshape(t):
        return t.astype(jnp.float32).reshape(B, nch, c, H, N).transpose(1, 0, 3, 2, 4)

    rr, kk, vv, ww = map(reshape, (r, k, v, w))  # [nch, B, H, c, N]
    logw = jnp.log(jnp.clip(ww, 1e-38))          # <= 0
    la = jnp.cumsum(logw, axis=-2)               # logA_t (inclusive)

    def chunk_step(S_state, xs):
        rc, kc, vc, lac, logwc = xs              # [B, H, c, N]
        la_prev = lac - logwc                    # logA_{t-1}
        # inter-chunk: r_t ⊙ A_{t-1} @ S
        r_dec = rc * jnp.exp(la_prev)
        inter = jnp.einsum("bhck,bhkv->bhcv", r_dec, S_state)
        # intra-chunk: sum_{j<t} (r_t ⊙ A_{t-1}/A_j) · k_j  v_j  (+ u diag)
        # decay[t, j, :] = exp(la_prev[t] - la[j]);  strict lower triangle
        dec = jnp.exp(
            jnp.clip(la_prev[:, :, :, None, :] - lac[:, :, None, :, :], -60.0, 0.0)
        )  # [B, H, c(t), c(j), N]
        tri = jnp.tril(jnp.ones((c, c), bool), k=-1)
        scores = jnp.einsum("bhtk,bhtjk,bhjk->bhtj", rc, dec, kc)
        scores = jnp.where(tri[None, None], scores, 0.0)
        intra = jnp.einsum("bhtj,bhjv->bhtv", scores, vc)
        diag = jnp.einsum("bhtk,hk,bhtk->bht", rc, u, kc)
        intra = intra + diag[..., None] * vc
        out_c = inter + intra
        # state update: S' = diag(A_c) S + sum_j (A_c / A_j) ⊙ k_j v_j^T
        a_c = lac[:, :, -1, :]                   # [B, H, N]
        k_dec = kc * jnp.exp(
            jnp.clip(a_c[:, :, None, :] - lac, -60.0, 0.0)
        )
        S_new = jnp.exp(a_c)[..., None] * S_state + jnp.einsum(
            "bhck,bhcv->bhkv", k_dec, vc
        )
        return S_new, out_c

    from repro.models.layers import pvary
    S0 = pvary(jnp.zeros((B, H, N, N), jnp.float32), vary_axes)
    S_final, outs = jax.lax.scan(chunk_step, S0, (rr, kk, vv, la, logw))
    out = outs.transpose(1, 0, 3, 2, 4).reshape(B, Sp, H, N)
    if return_state:
        return out[:, :S], S_final
    return out[:, :S]


def wkv6_decode(S_state, r_t, k_t, v_t, w_t, u):
    """One step.  S_state: [B, H, N, N]; r/k/v/w: [B, H, N]."""
    kv = jnp.einsum("bhk,bhv->bhkv", k_t, v_t)
    out = jnp.einsum(
        "bhk,bhkv->bhv", r_t, S_state + jnp.einsum("hk,bhkv->bhkv", u, kv)
    )
    S_new = w_t[..., None] * S_state + kv
    return S_new, out


# ---------------------------------------------------------------------------
# blocks
# ---------------------------------------------------------------------------


def _shift(x, x_prev):
    """x: [B, S, d] -> previous-token tensor with x_prev as t=-1."""
    return jnp.concatenate([x_prev[:, None], x[:, :-1]], axis=1)


def _time_mix(h, lp, cfg: ModelConfig, x_prev, return_state: bool = False,
              vary_axes=()):
    B, S, d = h.shape
    N = cfg.ssm_head_dim
    H = d // N
    sh = _shift(h, x_prev)

    def mx(m):
        return h + (sh - h) * lp[m].astype(h.dtype)

    r = (mx("mix_r") @ lp["wr"]).reshape(B, S, H, N)
    k = (mx("mix_k") @ lp["wk"]).reshape(B, S, H, N)
    v = (mx("mix_v") @ lp["wv"]).reshape(B, S, H, N)
    g = jax.nn.silu(mx("mix_g") @ lp["wg"])
    xw = mx("mix_w").astype(jnp.float32)
    dd = jnp.tanh(xw @ lp["wA"].astype(jnp.float32)) @ lp["wB"].astype(jnp.float32)
    w = jnp.exp(-jnp.exp(lp["w0"] + dd))        # (0, 1), data-dependent
    w = w.reshape(B, S, H, N)
    u = lp["bonus"]
    if return_state:
        out, S_final = wkv6_chunked(r, k, v, w, u, return_state=True,
                                    vary_axes=vary_axes)
    else:
        out = wkv6_chunked(r, k, v, w, u, vary_axes=vary_axes)
        S_final = None
    # per-head groupnorm
    mean = jnp.mean(out, axis=-1, keepdims=True)
    var = jnp.var(out, axis=-1, keepdims=True)
    out = (out - mean) * jax.lax.rsqrt(var + 1e-5)
    out = out.reshape(B, S, d) * (1.0 + lp["ln_x"].astype(jnp.float32))
    out = out.astype(h.dtype) * g
    return out @ lp["wo"], h[:, -1], S_final


def _channel_mix(h, lp, x_prev):
    sh = _shift(h, x_prev)
    xk = h + (sh - h) * lp["cmix_k"].astype(h.dtype)
    xr = h + (sh - h) * lp["cmix_r"].astype(h.dtype)
    kk = jnp.square(jax.nn.relu(xk @ lp["ck"]))
    out = jax.nn.sigmoid(xr @ lp["cr"]) * (kk @ lp["cv"])
    return out, h[:, -1]


def _layer(h, lp, cfg, policy, shift_tm, shift_cm, return_state=False):
    a, new_tm, S_final = _time_mix(rmsnorm(h, lp["ln1"]), lp, cfg, shift_tm,
                                   return_state, vary_axes=policy.vary_axes)
    h = h + a
    b, new_cm = _channel_mix(rmsnorm(h, lp["ln2"]), lp, shift_cm)
    h = h + b
    h = maybe_shard(h, policy.act)
    return h, new_tm, new_cm, S_final


def apply_stack(params, h, cfg: ModelConfig, policy: ShardingPolicy):
    B, S, d = h.shape
    z = jnp.zeros((B, d), h.dtype)

    def body(carry, lp):
        out, _, _, _ = _layer(carry, lp, cfg, policy, z, z)
        return out, None

    if cfg.remat:
        body = jax.checkpoint(body)
    h, _ = jax.lax.scan(body, h, params["layers"])
    return h


def prefill(params, batch, cfg: ModelConfig,
            policy: ShardingPolicy = NO_SHARDING, max_len: Optional[int] = None):
    """Consume the prompt, return (last_logits, RWKVCache, n_consumed)."""
    tokens = batch["tokens"]
    h = embed(tokens, params["embed"]).astype(cfg.adtype)
    B, S, d = h.shape
    z = jnp.zeros((B, d), h.dtype)

    def body(carry, lp):
        hh = carry
        out, tm, cm, S_final = _layer(hh, lp, cfg, policy, z, z,
                                      return_state=True)
        return out, (S_final, tm, cm)

    h, (wkv, tm, cm) = jax.lax.scan(body, h, params["layers"])
    cache = RWKVCache(wkv=wkv, shift_tm=tm, shift_cm=cm)
    hl = rmsnorm(h[:, -1:], params["final_norm"])
    logits = (hl[:, 0] @ params["head"]).astype(jnp.float32)
    return logits, cache, S


def loss_fn(params, batch, cfg: ModelConfig,
            policy: ShardingPolicy = NO_SHARDING, loss_chunk: int = 1024):
    tokens = batch["tokens"]
    inp, labels = tokens[:, :-1], tokens[:, 1:]
    h = embed(inp, params["embed"]).astype(cfg.adtype)
    h = apply_stack(params, h, cfg, policy)
    h = rmsnorm(h, params["final_norm"])
    return _chunked_ce(h, params["head"], labels, policy, loss_chunk)


def _chunked_ce(h, W, labels, policy, loss_chunk):  # noqa: used by zamba2 too
    B, S, _ = h.shape
    c = min(loss_chunk, S)
    pad = (-S) % c
    hp = jnp.pad(h, ((0, 0), (0, pad), (0, 0)))
    lp = jnp.pad(labels, ((0, 0), (0, pad)))
    msk = jnp.pad(jnp.ones((B, S), jnp.float32), ((0, 0), (0, pad)))
    n = hp.shape[1] // c
    hp = hp.reshape(B, n, c, -1).swapaxes(0, 1)
    lp = lp.reshape(B, n, c).swapaxes(0, 1)
    msk = msk.reshape(B, n, c).swapaxes(0, 1)

    def chunk_loss(carry, xs):
        hc, lc, mc = xs
        logits = (hc @ W).astype(jnp.float32)
        logits = maybe_shard(logits, policy.logits)
        lse = jax.nn.logsumexp(logits, axis=-1)
        gold = jnp.take_along_axis(logits, lc[..., None], axis=-1)[..., 0]
        return carry + jnp.sum((lse - gold) * mc), None

    from repro.models.layers import pvary
    total, _ = jax.lax.scan(chunk_loss,
                            pvary(jnp.zeros((), jnp.float32), policy.vary_axes),
                            (hp, lp, msk))
    return total / (B * S), {}


# ---------------------------------------------------------------------------
# decode
# ---------------------------------------------------------------------------


class RWKVCache(NamedTuple):
    wkv: jax.Array      # [L, B, H, N, N]
    shift_tm: jax.Array  # [L, B, d]
    shift_cm: jax.Array  # [L, B, d]


def init_cache(cfg: ModelConfig, batch: int, max_len: int = 0) -> RWKVCache:
    d, L = cfg.d_model, cfg.n_layers
    N = cfg.ssm_head_dim
    H = d // N
    return RWKVCache(
        wkv=jnp.zeros((L, batch, H, N, N), jnp.float32),
        shift_tm=jnp.zeros((L, batch, d), cfg.adtype),
        shift_cm=jnp.zeros((L, batch, d), cfg.adtype),
    )


def decode_step(params, cache: RWKVCache, token, pos, cfg: ModelConfig,
                policy: ShardingPolicy = NO_SHARDING):
    h = embed(token[:, None], params["embed"]).astype(cfg.adtype)
    B, _, d = h.shape
    N = cfg.ssm_head_dim
    H = d // N

    def body(carry, xs):
        hh = carry
        lp, S_state, st_tm, st_cm = xs
        x = rmsnorm(hh, lp["ln1"])
        x1 = x[:, 0]

        def mx(m):
            return x1 + (st_tm - x1) * lp[m].astype(x1.dtype)

        r = (mx("mix_r") @ lp["wr"]).reshape(B, H, N).astype(jnp.float32)
        k = (mx("mix_k") @ lp["wk"]).reshape(B, H, N).astype(jnp.float32)
        v = (mx("mix_v") @ lp["wv"]).reshape(B, H, N).astype(jnp.float32)
        g = jax.nn.silu(mx("mix_g") @ lp["wg"])
        xw = mx("mix_w").astype(jnp.float32)
        dd = jnp.tanh(xw @ lp["wA"].astype(jnp.float32)) @ lp["wB"].astype(jnp.float32)
        w = jnp.exp(-jnp.exp(lp["w0"] + dd)).reshape(B, H, N)
        S_new, out = wkv6_decode(S_state, r, k, v, w, lp["bonus"])
        mean = jnp.mean(out, axis=-1, keepdims=True)
        var = jnp.var(out, axis=-1, keepdims=True)
        out = (out - mean) * jax.lax.rsqrt(var + 1e-5)
        out = out.reshape(B, d) * (1.0 + lp["ln_x"].astype(jnp.float32))
        out = out.astype(hh.dtype) * g
        hh = hh + (out @ lp["wo"])[:, None]
        x2 = rmsnorm(hh, lp["ln2"])[:, 0]
        xk = x2 + (st_cm - x2) * lp["cmix_k"].astype(x2.dtype)
        xr = x2 + (st_cm - x2) * lp["cmix_r"].astype(x2.dtype)
        kk = jnp.square(jax.nn.relu(xk @ lp["ck"]))
        cm = jax.nn.sigmoid(xr @ lp["cr"]) * (kk @ lp["cv"])
        hh = hh + cm[:, None]
        return hh, (S_new, x1, x2)

    h, (wkv, s1, s2) = jax.lax.scan(
        body, h, (params["layers"], cache.wkv, cache.shift_tm, cache.shift_cm)
    )
    new_cache = RWKVCache(wkv=wkv, shift_tm=s1, shift_cm=s2)
    h = rmsnorm(h, params["final_norm"])
    logits = (h[:, 0] @ params["head"]).astype(jnp.float32)
    return maybe_shard(logits, policy.logits), new_cache

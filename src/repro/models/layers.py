"""Shared transformer building blocks.

Everything is a pure function over explicit parameter pytrees; stacks
are scanned (params stacked on a leading layer axis) where the layer
structure is uniform, unrolled otherwise (e.g. gemma3's mixed
local/global attention with per-kind cache shapes).

Attention is memory-efficient by construction: query-chunked online
softmax (flash-style) so an S x S score matrix is never materialized.
KV caches are ring buffers of length min(window, max_len) with an
explicit slot->position array, which makes full, sliding-window and
long-context decode masks uniform.
"""

from __future__ import annotations

from typing import NamedTuple, Optional

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import PartitionSpec as P

from repro.compat import sharding_constraints_usable
from repro.configs.base import ModelConfig, ShardingPolicy

Array = jax.Array


def maybe_shard(x: Array, spec: Optional[P]) -> Array:
    if spec is None or not sharding_constraints_usable():
        return x
    return jax.lax.with_sharding_constraint(x, spec)


def pvary(x, axes):
    """Mark ``x`` as varying over the manual axes ``axes`` (vma typing
    for scan carries created inside a shard_map region).  No-op on
    0.4.x jax, whose shard_map has no vma typing to satisfy."""
    if not axes or not hasattr(jax.lax, "pvary"):
        return x
    return jax.tree_util.tree_map(lambda t: jax.lax.pvary(t, tuple(axes)), x)


# ---------------------------------------------------------------------------
# norms / embeddings / rope
# ---------------------------------------------------------------------------


def rmsnorm(x: Array, w: Array, eps: float = 1e-6) -> Array:
    xf = x.astype(jnp.float32)
    var = jnp.mean(jnp.square(xf), axis=-1, keepdims=True)
    out = xf * jax.lax.rsqrt(var + eps) * (1.0 + w.astype(jnp.float32))
    return out.astype(x.dtype)


def rope(x: Array, positions: Array, theta: float) -> Array:
    """x: [..., S, H, hd]; positions: [..., S] (int)."""
    hd = x.shape[-1]
    half = hd // 2
    freqs = theta ** (-jnp.arange(0, half, dtype=jnp.float32) / half)
    ang = positions[..., :, None].astype(jnp.float32)[..., None, :] * 1.0
    # ang: [..., S, 1, 1] broadcasting against freqs [half]
    ang = positions.astype(jnp.float32)[..., None, None] * freqs  # [..., S, 1, half]
    cos, sin = jnp.cos(ang), jnp.sin(ang)
    x1, x2 = x[..., :half], x[..., half:]
    out = jnp.concatenate(
        [x1 * cos - x2 * sin, x2 * cos + x1 * sin], axis=-1
    )
    return out.astype(x.dtype)


def embed(tokens: Array, table: Array) -> Array:
    if hasattr(table, "take_rows"):   # compressed serving table
        return table.take_rows(tokens)
    return jnp.take(table, tokens, axis=0)


def matmul(x: Array, w) -> Array:
    """``x @ w`` with a duck-typed hook for compressed serving weights
    (``serve.compressed.CompressedTensor``): anything exposing
    ``.matmul`` routes the contraction itself (sparse/quantized Pallas
    GEMMs), so models never import the serving layer."""
    if hasattr(w, "matmul"):
        return w.matmul(x)
    return x @ w


# ---------------------------------------------------------------------------
# parameter init
# ---------------------------------------------------------------------------


def dense_init(key, shape, dtype, scale: Optional[float] = None):
    fan_in = shape[-2] if len(shape) >= 2 else shape[-1]
    s = scale if scale is not None else fan_in ** -0.5
    return (jax.random.normal(key, shape, jnp.float32) * s).astype(dtype)


def attn_params(key, cfg: ModelConfig, stacked: int | None):
    """Per-layer (or [L]-stacked) GQA projection weights."""
    d, H, KV, hd = cfg.d_model, cfg.n_heads, cfg.n_kv_heads, cfg.hd
    pre = (stacked,) if stacked else ()
    ks = jax.random.split(key, 4)
    return {
        "wq": dense_init(ks[0], pre + (d, H * hd), cfg.pdtype),
        "wk": dense_init(ks[1], pre + (d, KV * hd), cfg.pdtype),
        "wv": dense_init(ks[2], pre + (d, KV * hd), cfg.pdtype),
        "wo": dense_init(ks[3], pre + (H * hd, d), cfg.pdtype),
    }


def mlp_params(key, cfg: ModelConfig, stacked: int | None, d_ff=None):
    d, ff = cfg.d_model, d_ff or cfg.d_ff
    pre = (stacked,) if stacked else ()
    ks = jax.random.split(key, 3)
    return {
        "w1": dense_init(ks[0], pre + (d, ff), cfg.pdtype),
        "w3": dense_init(ks[1], pre + (d, ff), cfg.pdtype),
        "w2": dense_init(ks[2], pre + (ff, d), cfg.pdtype),
    }


def norm_params(cfg: ModelConfig, stacked: int | None):
    pre = (stacked,) if stacked else ()
    return jnp.zeros(pre + (cfg.d_model,), cfg.pdtype)


# ---------------------------------------------------------------------------
# attention core
# ---------------------------------------------------------------------------


NEG_INF = -1e30


def _gqa_scores(q: Array, k: Array) -> Array:
    """q: [B, Sq, KV, G, hd], k: [B, Sk, KV, hd] -> [B, KV, G, Sq, Sk]."""
    return jnp.einsum("bqkgh,bskh->bkgqs", q, k, preferred_element_type=jnp.float32)


def _gqa_out(p: Array, v: Array) -> Array:
    """p: [B, KV, G, Sq, Sk], v: [B, Sk, KV, hd] -> [B, Sq, KV, G, hd]."""
    return jnp.einsum("bkgqs,bskh->bqkgh", p, v.astype(p.dtype))


def chunked_attention(
    q: Array,                # [B, S, H, hd] (already rope'd)
    k: Array,                # [B, S, KV, hd]
    v: Array,                # [B, S, KV, hd]
    *,
    window: int,             # -1 = full causal
    q_chunk: int,
    q_offset: Array | int = 0,  # global position of q[0] (prefill continuation)
) -> Array:
    """Causal (optionally sliding-window) attention, scanned over query
    chunks so peak score memory is O(q_chunk * S)."""
    B, S, H, hd = q.shape
    KV = k.shape[2]
    G = H // KV
    scale = hd ** -0.5
    qc = min(q_chunk, S)
    pad = (-S) % qc
    nchunk = (S + pad) // qc

    qr = jnp.pad(q, ((0, 0), (0, pad), (0, 0), (0, 0)))
    qr = qr.reshape(B, nchunk, qc, KV, G, hd)
    kpos = jnp.arange(S)

    def one_chunk(ci, qchunk):
        # qchunk: [B, qc, KV, G, hd]; local (same-array) positions suffice
        # for causality since q and k index the same S tokens.
        qpos = ci * qc + jnp.arange(qc)
        s = _gqa_scores(qchunk.astype(jnp.float32) * scale, k.astype(jnp.float32))
        mask = kpos[None, :] <= qpos[:, None]
        # window may be a static int or a traced per-layer scalar (scan)
        if window is None:
            pass
        elif isinstance(window, (int, np.integer)):
            if window > 0:
                mask &= kpos[None, :] > qpos[:, None] - window
        else:
            w = jnp.asarray(window)
            mask &= jnp.where(w > 0, kpos[None, :] > qpos[:, None] - w, True)
        s = jnp.where(mask[None, None, None], s, NEG_INF)
        p = jax.nn.softmax(s, axis=-1)
        return _gqa_out(p, v)

    if nchunk == 1:
        out = one_chunk(0, qr[:, 0])[:, None]
    else:
        out = jax.lax.map(
            lambda args: one_chunk(args[0], args[1]),
            (jnp.arange(nchunk), jnp.moveaxis(qr, 1, 0)),
        )
        out = jnp.moveaxis(out, 0, 1)
    out = out.reshape(B, nchunk * qc, H, hd)[:, :S]
    return out.astype(q.dtype)


# ---------------------------------------------------------------------------
# KV cache (ring buffer with slot->position map)
# ---------------------------------------------------------------------------


class KVCache(NamedTuple):
    k: Array      # [B, C, KV, hd]  (possibly [L, ...] stacked outside)
    v: Array      # [B, C, KV, hd]
    pos: Array    # [C] int32, -1 = empty; global position held by the slot


def init_kv_cache(cfg: ModelConfig, batch: int, window: int, max_len: int,
                  stacked: int | None = None, dtype=None) -> KVCache:
    C = max_len if window is None or window <= 0 else min(window, max_len)
    pre = (stacked,) if stacked else ()
    dt = dtype or cfg.adtype
    return KVCache(
        k=jnp.zeros(pre + (batch, C, cfg.n_kv_heads, cfg.hd), dt),
        v=jnp.zeros(pre + (batch, C, cfg.n_kv_heads, cfg.hd), dt),
        pos=jnp.full(pre + (C,), -1, jnp.int32),
    )


def cache_write(cache: KVCache, k_new: Array, v_new: Array, pos) -> KVCache:
    """Write one token (k_new/v_new: [B, 1, KV, hd]) at global ``pos``."""
    C = cache.k.shape[1]
    slot = jnp.mod(pos, C)
    k = jax.lax.dynamic_update_slice_in_dim(cache.k, k_new.astype(cache.k.dtype), slot, axis=1)
    v = jax.lax.dynamic_update_slice_in_dim(cache.v, v_new.astype(cache.v.dtype), slot, axis=1)
    p = jax.lax.dynamic_update_slice_in_dim(
        cache.pos, jnp.asarray(pos, jnp.int32)[None], slot, axis=0
    )
    return KVCache(k, v, p)


def cache_prefill(cache: KVCache, k_all: Array, v_all: Array, S: int) -> KVCache:
    """Bulk-write positions [0, S) (S static).  For ring caches keep the
    last C positions."""
    C = cache.k.shape[1]
    if S <= C:
        k = jax.lax.dynamic_update_slice_in_dim(cache.k, k_all.astype(cache.k.dtype), 0, axis=1)
        v = jax.lax.dynamic_update_slice_in_dim(cache.v, v_all.astype(cache.v.dtype), 0, axis=1)
        p = jax.lax.dynamic_update_slice_in_dim(
            cache.pos, jnp.arange(S, dtype=jnp.int32), 0, axis=0
        )
        return KVCache(k, v, p)
    # keep last C tokens, ring-aligned so slot = pos % C stays true
    start = S - C
    kk = k_all[:, start:]
    vv = v_all[:, start:]
    pp = jnp.arange(start, S, dtype=jnp.int32)
    roll = jnp.mod(start, C)
    kk = jnp.roll(kk, roll, axis=1)
    vv = jnp.roll(vv, roll, axis=1)
    pp = jnp.roll(pp, roll, axis=0)
    return KVCache(kk.astype(cache.k.dtype), vv.astype(cache.v.dtype), pp)


def decode_attention(
    q: Array,                # [B, 1, H, hd] (rope'd at cur_pos)
    cache: KVCache,
    cur_pos,                 # scalar int (traced ok)
    window: int,
    use_pallas: bool = False,
) -> Array:
    B, _, H, hd = q.shape
    KV = cache.k.shape[2]
    G = H // KV
    valid = (cache.pos >= 0) & (cache.pos <= cur_pos)
    if window is None:
        pass
    elif isinstance(window, (int, np.integer)):
        if window > 0:
            valid &= cache.pos > cur_pos - window
    else:
        w = jnp.asarray(window)
        valid &= jnp.where(w > 0, cache.pos > cur_pos - w, True)
    if use_pallas:
        # slot validity is plain jnp, so unlike the prefill flash path
        # this works under scanned (traced) per-layer windows too
        from repro.kernels import ops as kops
        return kops.flash_decode(q, cache.k, cache.v, valid)
    scale = hd ** -0.5
    qr = q.reshape(B, 1, KV, G, hd).astype(jnp.float32) * scale
    s = _gqa_scores(qr, cache.k.astype(jnp.float32))  # [B, KV, G, 1, C]
    s = jnp.where(valid[None, None, None, None, :], s, NEG_INF)
    p = jax.nn.softmax(s, axis=-1)
    out = _gqa_out(p, cache.v)
    return out.reshape(B, 1, H, hd).astype(q.dtype)


# ---------------------------------------------------------------------------
# paged KV cache (shared page pool + per-request block tables)
# ---------------------------------------------------------------------------


class PagedKVCache(NamedTuple):
    """Shared KV page pool for one layer ([L, ...]-stacked outside).

    ``k``/``v``: [n_pages, page_size, KV, hd] arenas — the activation
    dtype, or int8 levels when the pool is quantized (inferred from the
    dtype; no flag field so the pytree structure is layout-independent).
    ``kscale``/``vscale``: [n_pages, page_size] f32 per-token-slot
    dequantization scales, zeros (and unread) for fp pools.

    Block tables and lengths live *outside* the pytree (one table per
    request, shared by every layer) — see ``serve/engine.py``.
    """

    k: Array
    v: Array
    kscale: Array
    vscale: Array


def init_paged_pool(cfg: ModelConfig, n_pages: int, page_size: int,
                    stacked: int | None = None, quant: bool = False,
                    dtype=None) -> PagedKVCache:
    pre = (stacked,) if stacked else ()
    dt = jnp.int8 if quant else (dtype or cfg.adtype)
    shape = pre + (n_pages, page_size, cfg.n_kv_heads, cfg.hd)
    return PagedKVCache(
        k=jnp.zeros(shape, dt),
        v=jnp.zeros(shape, dt),
        kscale=jnp.zeros(pre + (n_pages, page_size), jnp.float32),
        vscale=jnp.zeros(pre + (n_pages, page_size), jnp.float32),
    )


def quantize_kv(x: Array) -> tuple[Array, Array]:
    """Deterministic symmetric int8 over the trailing [KV, hd] axes:
    scale = amax/127 per token slot (round-to-nearest, clip ±127), the
    same wire scheme as ``serve/compressed.py``'s QSGD levels — so a
    requantized identical token is bit-identical (admission re-feeds the
    last prompt token; idempotency keeps that step exact)."""
    xf = x.astype(jnp.float32)
    amax = jnp.max(jnp.abs(xf), axis=(-2, -1))
    scale = amax / 127.0
    safe = jnp.where(scale > 0, scale, 1.0)[..., None, None]
    levels = jnp.clip(jnp.round(xf / safe), -127, 127).astype(jnp.int8)
    return levels, scale


def paged_write(pool: PagedKVCache, k_new: Array, v_new: Array,
                tables: Array, positions: Array,
                active: Array) -> PagedKVCache:
    """Write one token per slot (k_new/v_new: [B, 1, KV, hd]) into each
    slot's table-mapped page at ``positions`` ([B]).  Inactive slots
    (``active`` False — free engine slots still traced by the batched
    step) and unallocated (-1) table entries scatter to an out-of-pool
    sentinel and are dropped."""
    n_pages, ps = pool.k.shape[0], pool.k.shape[1]
    P = tables.shape[1]
    pidx = jnp.clip(positions // ps, 0, P - 1)
    pids = jnp.take_along_axis(tables, pidx[:, None], axis=1)[:, 0]
    pids = jnp.where(active & (pids >= 0), pids, n_pages)
    offs = jnp.mod(positions, ps)
    kv_k, kv_v = k_new[:, 0], v_new[:, 0]            # [B, KV, hd]
    if pool.k.dtype == jnp.int8:
        lk, sk = quantize_kv(kv_k)
        lv, sv = quantize_kv(kv_v)
        return pool._replace(
            k=pool.k.at[pids, offs].set(lk, mode="drop"),
            v=pool.v.at[pids, offs].set(lv, mode="drop"),
            kscale=pool.kscale.at[pids, offs].set(sk, mode="drop"),
            vscale=pool.vscale.at[pids, offs].set(sv, mode="drop"),
        )
    return pool._replace(
        k=pool.k.at[pids, offs].set(kv_k.astype(pool.k.dtype), mode="drop"),
        v=pool.v.at[pids, offs].set(kv_v.astype(pool.v.dtype), mode="drop"),
    )


def paged_prefill_insert(pool: PagedKVCache, k_all: Array, v_all: Array,
                         page_ids: Array) -> PagedKVCache:
    """Scatter one request's prefilled KV into the pool.

    k_all/v_all: [L, Cp, KV, hd] (Cp a page multiple); page_ids:
    [Cp/page_size] physical destinations in logical page order, with
    the ``n_pages`` sentinel marking unallocated tail pages (dropped).
    Pool is [L, ...]-stacked; quantization applied per token slot."""
    L, Cp, KV, hd = k_all.shape
    ps = pool.k.shape[2]
    n_adm = Cp // ps
    kp = k_all.reshape(L, n_adm, ps, KV, hd)
    vp = v_all.reshape(L, n_adm, ps, KV, hd)
    if pool.k.dtype == jnp.int8:
        lk, sk = quantize_kv(kp)
        lv, sv = quantize_kv(vp)
        return pool._replace(
            k=pool.k.at[:, page_ids].set(lk, mode="drop"),
            v=pool.v.at[:, page_ids].set(lv, mode="drop"),
            kscale=pool.kscale.at[:, page_ids].set(sk, mode="drop"),
            vscale=pool.vscale.at[:, page_ids].set(sv, mode="drop"),
        )
    return pool._replace(
        k=pool.k.at[:, page_ids].set(kp.astype(pool.k.dtype), mode="drop"),
        v=pool.v.at[:, page_ids].set(vp.astype(pool.v.dtype), mode="drop"),
    )


def paged_decode_attention(q: Array, pool: PagedKVCache, tables: Array,
                           lengths: Array, use_pallas: bool = False) -> Array:
    """Single-token attention against the page pool (full causal — the
    engine gates paged serving to uniform full-window configs).  Kernel
    or gather-oracle path by ``use_pallas``; a length-0 slot yields
    zeros either way."""
    if use_pallas:
        from repro.kernels import ops as kops
        return kops.paged_decode(q, pool.k, pool.v, pool.kscale,
                                 pool.vscale, tables, lengths)
    from repro.kernels.ref import paged_decode_ref
    return paged_decode_ref(q, pool.k, pool.v, pool.kscale, pool.vscale,
                            tables, lengths)


# ---------------------------------------------------------------------------
# blocks
# ---------------------------------------------------------------------------


def swiglu(x: Array, p) -> Array:
    h = jax.nn.silu(matmul(x, p["w1"])) * matmul(x, p["w3"])
    return matmul(h, p["w2"])


def gqa_project(x: Array, p, cfg: ModelConfig):
    B, S, _ = x.shape
    H, KV, hd = cfg.n_heads, cfg.n_kv_heads, cfg.hd
    q = matmul(x, p["wq"]).reshape(B, S, H, hd)
    k = matmul(x, p["wk"]).reshape(B, S, KV, hd)
    v = matmul(x, p["wv"]).reshape(B, S, KV, hd)
    return q, k, v


def attn_block_train(x, p, cfg: ModelConfig, window: int, positions,
                     policy: ShardingPolicy):
    """Full-sequence attention block (training / prefill). Returns
    (out, (k, v)) so prefill can populate the cache."""
    q, k, v = gqa_project(x, p, cfg)
    q = rope(q, positions, cfg.rope_theta)
    k = rope(k, positions, cfg.rope_theta)
    # the Pallas kernel needs a static window (it shapes the kv loop);
    # traced per-layer windows (scanned mixed-pattern stacks) fall back
    # to the chunked-jnp path.
    if cfg.use_pallas and isinstance(window, (int, np.integer)):
        from repro.kernels import ops as kops
        o = kops.flash_attention(q, k, v, window=int(window))
    else:
        o = chunked_attention(q, k, v, window=window, q_chunk=cfg.q_chunk)
    B, S = x.shape[:2]
    out = matmul(o.reshape(B, S, cfg.n_heads * cfg.hd), p["wo"])
    return out, (k, v)


def attn_block_decode(x, p, cfg: ModelConfig, cache: KVCache, pos, window: int):
    q, k, v = gqa_project(x, p, cfg)
    posv = jnp.asarray(pos)[None]
    q = rope(q, jnp.broadcast_to(posv, (x.shape[0], 1)), cfg.rope_theta)
    k = rope(k, jnp.broadcast_to(posv, (x.shape[0], 1)), cfg.rope_theta)
    cache = cache_write(cache, k, v, pos)
    o = decode_attention(q, cache, pos, window, use_pallas=cfg.use_pallas)
    out = matmul(o.reshape(x.shape[0], 1, cfg.n_heads * cfg.hd), p["wo"])
    return out, cache


def attn_block_decode_paged(x, p, cfg: ModelConfig, pool: PagedKVCache,
                            tables: Array, positions: Array, active: Array):
    """Decode attention block over the shared page pool.  Unlike the
    contiguous block (one scalar ``pos``, vmapped per slot), this runs
    the whole slot batch at once: ``positions`` is [B] (per-slot rope
    phase) and ``active`` gates pool writes for free slots."""
    q, k, v = gqa_project(x, p, cfg)
    q = rope(q, positions[:, None], cfg.rope_theta)
    k = rope(k, positions[:, None], cfg.rope_theta)
    pool = paged_write(pool, k, v, tables, positions, active)
    lengths = jnp.where(active, positions + 1, 0).astype(jnp.int32)
    o = paged_decode_attention(q, pool, tables, lengths,
                               use_pallas=cfg.use_pallas)
    out = matmul(o.reshape(x.shape[0], 1, cfg.n_heads * cfg.hd), p["wo"])
    return out, pool

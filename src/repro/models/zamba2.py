"""Mamba2 (SSD) blocks + Zamba2 hybrid stack (arXiv:2411.15242).

Mamba2 selective-state-space block with scalar-per-head decay:

    a_t = exp(dt_t * A_h)           (A_h < 0, dt_t = softplus(...))
    h_t = a_t h_{t-1} + (dt_t x_t) ⊗ B_t        h in R^{P x N} per head
    y_t = h_t C_t + D_h x_t

Training uses the SSD chunked form (intra-chunk masked quadratic +
inter-chunk state carry) — O(S·c) memory; a naive sequential reference
(`ssd_ref`) backs the tests.  Decode is the O(1) recurrence, so zamba2
runs ``long_500k``.

Zamba2 hybrid: a stack of Mamba2 blocks with a *shared* attention+MLP
block (one parameter set) applied every ``attn_every`` layers on
concat(hidden, original embedding) — following Zamba2's shared-block
design; the 2d->d input projection is our documented simplification.
At long context the shared block's KV cache is a sliding-window ring
(config ``swa_pattern``), the documented TPU adaptation for long_500k.
"""

from __future__ import annotations

from typing import NamedTuple, Optional

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig, NO_SHARDING, ShardingPolicy
from repro.models.layers import (
    KVCache,
    attn_block_decode,
    attn_block_train,
    attn_params,
    cache_prefill,
    dense_init,
    embed,
    init_kv_cache,
    maybe_shard,
    mlp_params,
    norm_params,
    rmsnorm,
    swiglu,
)


# ---------------------------------------------------------------------------
# SSD core
# ---------------------------------------------------------------------------


def ssd_ref(x, dt, A, B, C, D):
    """Sequential oracle.
    x: [Bt, S, H, P]; dt: [Bt, S, H]; A: [H] (<0); B, C: [Bt, S, N]; D: [H].
    Returns y: [Bt, S, H, P]."""
    Bt, S, H, P = x.shape
    N = B.shape[-1]

    def step(h, xs):
        x_t, dt_t, B_t, C_t = xs
        a_t = jnp.exp(dt_t * A)                      # [Bt, H]
        upd = jnp.einsum("bhp,bn->bhpn", x_t * dt_t[..., None], B_t)
        h = a_t[..., None, None] * h + upd
        y = jnp.einsum("bhpn,bn->bhp", h, C_t) + D[None, :, None] * x_t
        return h, y

    h0 = jnp.zeros((Bt, H, P, N), jnp.float32)
    xs = (
        jnp.moveaxis(x.astype(jnp.float32), 1, 0),
        jnp.moveaxis(dt.astype(jnp.float32), 1, 0),
        jnp.moveaxis(B.astype(jnp.float32), 1, 0),
        jnp.moveaxis(C.astype(jnp.float32), 1, 0),
    )
    _, ys = jax.lax.scan(step, h0, xs)
    return jnp.moveaxis(ys, 0, 1)


def ssd_chunked(x, dt, A, B, C, D, chunk: int = 64, return_state: bool = False,
                vary_axes=()):
    """Chunked SSD.  Same signature/semantics as ssd_ref."""
    Bt, S, H, P = x.shape
    N = B.shape[-1]
    c = min(chunk, S)
    pad = (-S) % c
    if pad:
        x = jnp.pad(x, ((0, 0), (0, pad), (0, 0), (0, 0)))
        dt = jnp.pad(dt, ((0, 0), (0, pad), (0, 0)))
        B = jnp.pad(B, ((0, 0), (0, pad), (0, 0)))
        C = jnp.pad(C, ((0, 0), (0, pad), (0, 0)))
    Sp = x.shape[1]
    nch = Sp // c

    xr = x.astype(jnp.float32).reshape(Bt, nch, c, H, P).transpose(1, 0, 3, 2, 4)
    dtr = dt.astype(jnp.float32).reshape(Bt, nch, c, H).transpose(1, 0, 3, 2)
    Br = B.astype(jnp.float32).reshape(Bt, nch, c, N).transpose(1, 0, 2, 3)
    Cr = C.astype(jnp.float32).reshape(Bt, nch, c, N).transpose(1, 0, 2, 3)
    # xr: [nch, Bt, H, c, P]; dtr: [nch, Bt, H, c]; Br/Cr: [nch, Bt, c, N]
    loga = dtr * A[None, None, :, None]             # [nch, Bt, H, c], <= 0
    la = jnp.cumsum(loga, axis=-1)                  # inclusive cumsum

    def chunk_step(h, xs):
        xc, dtc, Bc, Cc, lac = xs
        # inter: y_t += exp(la_t) * C_t h0
        CB_h0 = jnp.einsum("bcn,bhpn->bhcp", Cc, h)
        inter = jnp.exp(lac)[..., None] * CB_h0
        # intra: scores[t, j] = (C_t . B_j) exp(la_t - la_j) dt_j, j<=t
        dec = jnp.exp(jnp.clip(lac[..., :, None] - lac[..., None, :], -60.0, 0.0))
        cb = jnp.einsum("btn,bjn->btj", Cc, Bc)     # [Bt, c, c]
        scores = cb[:, None] * dec * dtc[..., None, :]  # [Bt, H, t, j]
        tri = jnp.tril(jnp.ones((c, c), bool))
        scores = jnp.where(tri[None, None], scores, 0.0)
        intra = jnp.einsum("bhtj,bhjp->bhtp", scores, xc)
        y = inter + intra + D[None, :, None, None] * xc
        # state: h' = exp(la_c) h + sum_j exp(la_c - la_j) dt_j x_j ⊗ B_j
        la_c = lac[..., -1]
        wdec = jnp.exp(jnp.clip(la_c[..., None] - lac, -60.0, 0.0)) * dtc
        upd = jnp.einsum("bhcp,bhc,bcn->bhpn", xc, wdec, Bc)
        h_new = jnp.exp(la_c)[..., None, None] * h + upd
        return h_new, y

    from repro.models.layers import pvary
    h0 = pvary(jnp.zeros((Bt, H, P, N), jnp.float32), vary_axes)
    h_final, ys = jax.lax.scan(chunk_step, h0, (xr, dtr, Br, Cr, la))
    y = ys.transpose(1, 0, 3, 2, 4).reshape(Bt, Sp, H, P)[:, :S]
    if return_state:
        return y, h_final
    return y


def ssd_decode(h, x_t, dt_t, A, B_t, C_t, D):
    """One step.  h: [Bt, H, P, N]; x_t: [Bt, H, P]; dt_t: [Bt, H];
    B_t, C_t: [Bt, N]."""
    a_t = jnp.exp(dt_t * A)
    upd = jnp.einsum("bhp,bn->bhpn", x_t * dt_t[..., None], B_t)
    h = a_t[..., None, None] * h + upd
    y = jnp.einsum("bhpn,bn->bhp", h, C_t) + D[None, :, None] * x_t
    return h, y


# ---------------------------------------------------------------------------
# mamba2 block
# ---------------------------------------------------------------------------


def mamba_params(key, cfg: ModelConfig, stacked: int | None):
    """Projections are kept *unpacked* (separate z/x/B/C/dt weights and
    per-part conv filters) so each leaf carries a clean TP sharding: the
    head-major x/z/dt dims shard over 'model'; the head-shared B/C
    projections stay replicated."""
    d = cfg.d_model
    din = cfg.ssm_expand * d
    N = cfg.ssm_state
    H = din // cfg.ssm_head_dim
    pre = (stacked,) if stacked else ()
    ks = jax.random.split(key, 8)
    return {
        "ln": jnp.zeros(pre + (d,), cfg.pdtype),
        "w_z": dense_init(ks[0], pre + (d, din), cfg.pdtype),
        "w_x": dense_init(ks[1], pre + (d, din), cfg.pdtype),
        "w_B": dense_init(ks[2], pre + (d, N), cfg.pdtype),
        "w_C": dense_init(ks[3], pre + (d, N), cfg.pdtype),
        "w_dt": dense_init(ks[4], pre + (d, H), cfg.pdtype),
        "conv_x": dense_init(ks[5], pre + (cfg.ssm_conv, din), cfg.pdtype,
                             scale=0.5),
        "conv_B": dense_init(ks[6], pre + (cfg.ssm_conv, N), cfg.pdtype,
                             scale=0.5),
        "conv_C": dense_init(ks[7], pre + (cfg.ssm_conv, N), cfg.pdtype,
                             scale=0.5),
        "conv_bx": jnp.zeros(pre + (din,), cfg.pdtype),
        "conv_bB": jnp.zeros(pre + (N,), cfg.pdtype),
        "conv_bC": jnp.zeros(pre + (N,), cfg.pdtype),
        "dt_bias": jnp.zeros(pre + (H,), jnp.float32),
        "A_log": jnp.zeros(pre + (H,), jnp.float32),      # A = -exp(A_log)
        "D": jnp.ones(pre + (H,), jnp.float32),
        "ln_y": jnp.zeros(pre + (din,), cfg.pdtype),      # gated norm
        "w_out": dense_init(ks[2], pre + (din, d), cfg.pdtype),
    }


def _causal_conv(x, w, b):
    """Depthwise causal conv.  x: [B, S, C]; w: [K, C]."""
    K = w.shape[0]
    xp = jnp.pad(x, ((0, 0), (K - 1, 0), (0, 0)))
    y = sum(xp[:, i:i + x.shape[1]] * w[i] for i in range(K))
    return jax.nn.silu(y + b)





def mamba_block(h, mp, cfg: ModelConfig, return_state: bool = False,
                vary_axes=()):
    """Full-sequence Mamba2 block.  h: [Bt, S, d]."""
    Bt, S, d = h.shape
    din = cfg.ssm_expand * d
    N = cfg.ssm_state
    P = cfg.ssm_head_dim
    H = din // P
    hn = rmsnorm(h, mp["ln"])
    z = hn @ mp["w_z"]
    x_raw = hn @ mp["w_x"]
    B_raw = hn @ mp["w_B"]
    C_raw = hn @ mp["w_C"]
    dt = hn @ mp["w_dt"]
    xs = _causal_conv(x_raw, mp["conv_x"], mp["conv_bx"]).reshape(Bt, S, H, P)
    Bm = _causal_conv(B_raw, mp["conv_B"], mp["conv_bB"])
    Cm = _causal_conv(C_raw, mp["conv_C"], mp["conv_bC"])
    dt = jax.nn.softplus(dt.astype(jnp.float32) + mp["dt_bias"])
    A = -jnp.exp(mp["A_log"])
    out = ssd_chunked(xs, dt, A, Bm, Cm, mp["D"], return_state=return_state,
                      vary_axes=vary_axes)
    if return_state:
        y, ssm_state = out
    else:
        y, ssm_state = out, None
    y = y.reshape(Bt, S, din).astype(h.dtype)
    y = rmsnorm(y * jax.nn.silu(z), mp["ln_y"])
    y = y @ mp["w_out"]
    if return_state:
        # conv state: last (K-1) pre-activation channels of [x|B|C]
        K = cfg.ssm_conv
        raw = jnp.concatenate([x_raw, B_raw, C_raw], axis=-1)
        conv_state = raw[:, -(K - 1):, :]
        return y, ssm_state, conv_state
    return y


def mamba_decode(h1, mp, cfg: ModelConfig, ssm_state, conv_state):
    """One-token Mamba2.  h1: [Bt, 1, d]; conv_state: [Bt, K-1, C]."""
    Bt = h1.shape[0]
    din = cfg.ssm_expand * cfg.d_model
    N = cfg.ssm_state
    P = cfg.ssm_head_dim
    H = din // P
    K = cfg.ssm_conv
    hn = rmsnorm(h1, mp["ln"])[:, 0]
    z = hn @ mp["w_z"]
    x_raw = hn @ mp["w_x"]
    B_raw = hn @ mp["w_B"]
    C_raw = hn @ mp["w_C"]
    dt = hn @ mp["w_dt"]
    xbc = jnp.concatenate([x_raw, B_raw, C_raw], axis=-1)
    window = jnp.concatenate([conv_state, xbc[:, None]], axis=1)  # [Bt, K, C]
    conv_w = jnp.concatenate([mp["conv_x"], mp["conv_B"], mp["conv_C"]], axis=-1)
    conv_b = jnp.concatenate([mp["conv_bx"], mp["conv_bB"], mp["conv_bC"]])
    y_conv = jnp.einsum("bkc,kc->bc", window.astype(jnp.float32),
                        conv_w.astype(jnp.float32)) + conv_b
    y_conv = jax.nn.silu(y_conv).astype(h1.dtype)
    xs = y_conv[..., :din].reshape(Bt, H, P)
    Bm = y_conv[..., din:din + N]
    Cm = y_conv[..., din + N:]
    dtv = jax.nn.softplus(dt.astype(jnp.float32) + mp["dt_bias"])
    A = -jnp.exp(mp["A_log"])
    ssm_state, y = ssd_decode(
        ssm_state, xs.astype(jnp.float32), dtv, A,
        Bm.astype(jnp.float32), Cm.astype(jnp.float32), mp["D"],
    )
    y = y.reshape(Bt, din).astype(h1.dtype)
    y = rmsnorm(y * jax.nn.silu(z), mp["ln_y"])
    y = (y @ mp["w_out"])[:, None]
    new_conv = window[:, 1:]
    return y, ssm_state, new_conv


# ---------------------------------------------------------------------------
# zamba2 hybrid stack
# ---------------------------------------------------------------------------


def _n_attn_apps(cfg: ModelConfig) -> int:
    return len([i for i in range(cfg.n_layers) if i % cfg.attn_every == 0])


def init_params(key: jax.Array, cfg: ModelConfig):
    d, L = cfg.d_model, cfg.n_layers
    ks = jax.random.split(key, 8)
    params = {
        "embed": dense_init(ks[0], (cfg.vocab, d), cfg.pdtype, scale=1.0),
        "layers": mamba_params(ks[1], cfg, L),
        "final_norm": jnp.zeros((d,), cfg.pdtype),
        "head": dense_init(ks[2], (d, cfg.vocab), cfg.pdtype),
    }
    if cfg.attn_every > 0:
        params["shared"] = {
            "w_cat": dense_init(ks[3], (2 * d, d), cfg.pdtype),
            "ln1": norm_params(cfg, None),
            "attn": attn_params(ks[4], cfg, None),
            "ln2": norm_params(cfg, None),
            "mlp": mlp_params(ks[5], cfg, None),
            "w_back": dense_init(ks[6], (d, d), cfg.pdtype),
        }
    return params


def _shared_window(cfg: ModelConfig) -> int:
    return cfg.swa_pattern[0] if cfg.swa_pattern else -1


def _shared_block_train(h, h0, sp, cfg, positions, policy):
    x = jnp.concatenate([h, h0], axis=-1) @ sp["w_cat"]
    a, kv = attn_block_train(rmsnorm(x, sp["ln1"]), sp["attn"], cfg,
                             _shared_window(cfg), positions, policy)
    x = x + a
    x = x + swiglu(rmsnorm(x, sp["ln2"]), sp["mlp"])
    return h + x @ sp["w_back"], kv


def apply_stack(params, h, positions, cfg: ModelConfig,
                policy: ShardingPolicy, collect_kv: bool = False):
    """Mamba scan with shared attention applied at i % attn_every == 0.

    The shared block is *unrolled* (it has a single parameter set and a
    handful of applications), interleaved with scanned mamba segments.
    """
    L, E = cfg.n_layers, cfg.attn_every
    lay = params["layers"]
    kvs = []

    def seg_scan(h, lo, hi):
        if hi <= lo:
            return h
        seg = jax.tree_util.tree_map(lambda x: x[lo:hi], lay)

        def body(carry, mp):
            out = mamba_block(carry, mp, cfg, vary_axes=policy.vary_axes)
            return carry + out, None

        body_fn = jax.checkpoint(body) if cfg.remat else body
        h, _ = jax.lax.scan(body_fn, h, seg)
        return h

    h0 = h
    apps = list(range(0, L, E)) if E > 0 else []
    prev = 0
    for i in apps:
        h = seg_scan(h, prev, i)
        h, kv = _shared_block_train(h, h0, params["shared"], cfg, positions,
                                    policy)
        kvs.append(kv)
        prev = i
    h = seg_scan(h, prev, L)
    return h, (kvs if collect_kv else None)


def loss_fn(params, batch, cfg: ModelConfig,
            policy: ShardingPolicy = NO_SHARDING, loss_chunk: int = 1024):
    from repro.models.rwkv6 import _chunked_ce
    tokens = batch["tokens"]
    inp, labels = tokens[:, :-1], tokens[:, 1:]
    h = embed(inp, params["embed"]).astype(cfg.adtype)
    B, S, _ = h.shape
    positions = jnp.broadcast_to(jnp.arange(S), (B, S))
    h, _ = apply_stack(params, h, positions, cfg, policy)
    h = rmsnorm(h, params["final_norm"])
    return _chunked_ce(h, params["head"], labels, policy, loss_chunk)


# ---------------------------------------------------------------------------
# decode
# ---------------------------------------------------------------------------


class ZambaCache(NamedTuple):
    ssm: jax.Array        # [L, Bt, H, P, N]
    conv: jax.Array       # [L, Bt, K-1, C]
    attn: Optional[KVCache]  # stacked [n_apps, ...] or None


def init_cache(cfg: ModelConfig, batch: int, max_len: int) -> ZambaCache:
    d = cfg.d_model
    din = cfg.ssm_expand * d
    N, P, K = cfg.ssm_state, cfg.ssm_head_dim, cfg.ssm_conv
    H = din // P
    conv_ch = din + 2 * N
    attn = None
    if cfg.attn_every > 0:
        attn = init_kv_cache(cfg, batch, _shared_window(cfg), max_len,
                             stacked=_n_attn_apps(cfg))
    return ZambaCache(
        ssm=jnp.zeros((cfg.n_layers, batch, H, P, N), jnp.float32),
        conv=jnp.zeros((cfg.n_layers, batch, K - 1, conv_ch), cfg.adtype),
        attn=attn,
    )


def prefill(params, batch, cfg: ModelConfig,
            policy: ShardingPolicy = NO_SHARDING, max_len: Optional[int] = None):
    tokens = batch["tokens"]
    h = embed(tokens, params["embed"]).astype(cfg.adtype)
    B, S, _ = h.shape
    max_len = max_len or max(cfg.max_seq_len, S)
    positions = jnp.broadcast_to(jnp.arange(S), (B, S))
    L, E = cfg.n_layers, cfg.attn_every
    lay = params["layers"]
    h0 = h
    ssm_states, conv_states, kvs = [], [], []
    apps = list(range(0, L, E)) if E > 0 else []
    for i in range(L):
        if i in apps:
            h, kv = _shared_block_train(h, h0, params["shared"], cfg,
                                        positions, policy)
            kvs.append(kv)
        mp = jax.tree_util.tree_map(lambda x: x[i], lay)
        y, ssm_s, conv_s = mamba_block(h, mp, cfg, return_state=True)
        h = h + y
        ssm_states.append(ssm_s)
        conv_states.append(conv_s)
    h = rmsnorm(h[:, -1:], params["final_norm"])
    logits = (h[:, 0] @ params["head"]).astype(jnp.float32)
    attn_cache = None
    if apps:
        attn_cache = init_kv_cache(cfg, B, _shared_window(cfg), max_len,
                                   stacked=len(apps))
        k_all = jnp.stack([kv[0] for kv in kvs])
        v_all = jnp.stack([kv[1] for kv in kvs])
        attn_cache = jax.vmap(lambda c, k, v: cache_prefill(c, k, v, S))(
            attn_cache, k_all, v_all
        )
    cache = ZambaCache(
        ssm=jnp.stack(ssm_states),
        conv=jnp.stack(conv_states).astype(cfg.adtype),
        attn=attn_cache,
    )
    return logits, cache, S


def decode_step(params, cache: ZambaCache, token, pos, cfg: ModelConfig,
                policy: ShardingPolicy = NO_SHARDING):
    h = embed(token[:, None], params["embed"]).astype(cfg.adtype)
    h0 = h
    L, E = cfg.n_layers, cfg.attn_every
    lay = params["layers"]
    apps = list(range(0, L, E)) if E > 0 else []
    new_ssm, new_conv, new_attn = [], [], []
    app_idx = 0
    for i in range(L):
        if i in apps:
            sp = params["shared"]
            x = jnp.concatenate([h, h0], axis=-1) @ sp["w_cat"]
            c_i = jax.tree_util.tree_map(lambda t: t[app_idx], cache.attn)
            a, c_new = attn_block_decode(rmsnorm(x, sp["ln1"]), sp["attn"],
                                         cfg, c_i, pos, _shared_window(cfg))
            x = x + a
            x = x + swiglu(rmsnorm(x, sp["ln2"]), sp["mlp"])
            h = h + x @ sp["w_back"]
            new_attn.append(c_new)
            app_idx += 1
        mp = jax.tree_util.tree_map(lambda x: x[i], lay)
        y, s_new, c_new2 = mamba_decode(h, mp, cfg, cache.ssm[i], cache.conv[i])
        h = h + y
        new_ssm.append(s_new)
        new_conv.append(c_new2)
    attn_cache = None
    if apps:
        attn_cache = jax.tree_util.tree_map(lambda *xs: jnp.stack(xs), *new_attn)
    new_cache = ZambaCache(
        ssm=jnp.stack(new_ssm),
        conv=jnp.stack(new_conv).astype(cfg.adtype),
        attn=attn_cache,
    )
    h = rmsnorm(h, params["final_norm"])
    logits = (h[:, 0] @ params["head"]).astype(jnp.float32)
    return maybe_shard(logits, policy.logits), new_cache

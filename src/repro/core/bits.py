"""Exact wire-bit accounting for every operator (the paper's x-axis).

Conventions (conservative, matching the paper's setup):
  * a dense float update costs ``value_bits`` per coordinate (32 default);
  * a sparse update sends (index, value) pairs: ceil(log2(d)) bits per
    index plus value bits per coordinate, plus one 32-bit length field;
  * Rand_k indices are derivable from a shared seed, so only a 32-bit
    seed + k values cross the wire;
  * QSGD sends the 32-bit norm, one sign bit and ceil(log2(s+1)) level
    bits per *non-zero* coordinate plus a bitmap-free index for zeros via
    the same sparse encoding (we charge the index only for non-zeros,
    matching QSGD's Elias-coded sparsity gains qualitatively while staying
    an exact, implementable format);
  * SignTop_k sends a 32-bit scale, k indices, k sign bits.

Everything returns float (bits can be data dependent through the
non-zero count for stochastic quantizers => returned as a traced scalar).

The ledger is **per direction** (DESIGN.md §5): the engines keep
separate uplink (worker→server, ``state.bits``) and downlink
(server→worker, ``state.bits_down``) totals — both directions charge
per transmitting/receiving worker (unicast accounting), and downlink
Top_k/QSGD entries use the same counted-survivor forms as the uplink.
``core.channel.wire_ledger(state)`` bundles the pair with a combined
total.
"""

from __future__ import annotations

import math

import jax
import jax.numpy as jnp


def _idx_bits(d: int) -> int:
    return max(1, math.ceil(math.log2(max(d, 2))))


def _level_bits(s: int) -> int:
    return max(1, math.ceil(math.log2(s + 1)))


def bits_dense(d: int, value_bits: int = 32) -> float:
    return float(d * value_bits)


def bits_dense_tree(tree, value_bits: int = 32) -> float:
    """Dense wire cost of transmitting a whole pytree exactly — the
    per-receiver charge of an uncompressed (Identity) broadcast.  Leaf
    sizes are static, so this is a python float usable at trace time."""
    return float(sum(bits_dense(leaf.size, value_bits)
                     for leaf in jax.tree_util.tree_leaves(tree)))


def bits_topk(d: int, k: int, value_bits: int = 32) -> float:
    return float(32 + k * (_idx_bits(d) + value_bits))


def bits_randk(d: int, k: int, value_bits: int = 32) -> float:
    # indices recoverable from a shared 32-bit seed
    return float(32 + 32 + k * value_bits)


def bits_sign(d: int) -> float:
    # 32-bit scale + one bit per coordinate
    return float(32 + d)


def bits_signtopk(d: int, k: int) -> float:
    return float(32 + k * (_idx_bits(d) + 1))


def bits_klevel(d: int, s: int) -> float:
    # lo & hi 32-bit floats + level bits per coordinate
    return float(64 + d * _level_bits(s))


def bits_qsgd(d: int, s: int, nnz) -> jnp.ndarray:
    """norm + per-nonzero (index + sign + level).  nnz may be traced."""
    per = _idx_bits(d) + 1 + _level_bits(s)
    return jnp.asarray(32 + 32, jnp.float32) + jnp.asarray(nnz, jnp.float32) * per


def bits_topk_counted(d: int, nnz, value_bits: int = 32) -> jnp.ndarray:
    """Top_k wire cost from the *actual* survivor count (traced).

    Identical to :func:`bits_topk` when nnz == k; the threshold-select
    kernels report their true count, which can exceed k under ties.
    """
    per = _idx_bits(d) + value_bits
    return jnp.asarray(32, jnp.float32) + jnp.asarray(nnz, jnp.float32) * per


def bits_signtopk_counted(d: int, nnz) -> jnp.ndarray:
    """SignTop_k wire cost from the actual survivor count (traced)."""
    per = _idx_bits(d) + 1
    return jnp.asarray(32, jnp.float32) + jnp.asarray(nnz, jnp.float32) * per


def bits_qtopk(d: int, k: int, s: int, nnz) -> jnp.ndarray:
    """TopK then QSGD on the k survivors: indices for k, levels only for
    the quantizer's non-zeros (QSGD may zero some survivors)."""
    per_idx = _idx_bits(d)
    per_val = 1 + _level_bits(s)
    return (
        jnp.asarray(32 + 32 + k * per_idx, jnp.float32)
        + jnp.asarray(nnz, jnp.float32) * per_val
    )


def bits_qrandk(d: int, k: int, s: int, nnz) -> jnp.ndarray:
    per_val = 1 + _level_bits(s)
    return (
        jnp.asarray(32 + 32 + 32, jnp.float32)
        + jnp.asarray(nnz, jnp.float32) * per_val
    )

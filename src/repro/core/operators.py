"""Communication-efficient compression operators (paper Section 2).

Every operator maps a flat (or arbitrary-shaped) array to a *dense*
array of the same shape containing the decompressed update (the value the
master will apply), plus an exact count of bits that would cross the wire
for that update.  The dense representation keeps the algorithm math
identical to the paper while the bits ledger accounts the true wire cost.

Operators satisfy (or are tested against) Definition 3:

    E ||x - C(x)||^2 <= (1 - gamma) ||x||^2,   gamma in (0, 1].

Implemented (with the paper's lemma references):
  * ``Identity``                 -- gamma = 1 (vanilla SGD / local-SGD)
  * ``TopK`` / ``RandK``         -- gamma = k/d                     [SCJ18]
  * ``QSGDQuantizer``            -- Definition 1, beta = min(d/s^2, sqrt(d)/s)
  * ``StochasticKLevel``         -- Definition 1, beta = d/(2 s^2)
  * ``Sign``                     -- Definition 2 (biased 1-bit)
  * ``QuantizedSparsifier``      -- Lemma 1 (unscaled) / Lemma 2 (scaled)
  * ``SignSparsifier``           -- Lemma 3 (Sign o Comp_k, ||.||_m / k scale)
  * ``RowTopK``                  -- per-row top-k: the TP-shard-local variant
                                    (Corollary 1 piecewise compression)

All operators are stateless pytrees (dataclass + tree_util registration)
so they can be closed over inside jit/shard_map without retracing hazards.
"""

from __future__ import annotations

import dataclasses
import math
from functools import partial
from typing import Optional, Tuple

import jax
import jax.numpy as jnp

from repro.core import bits as bitlib

Array = jax.Array


# ---------------------------------------------------------------------------
# helpers
# ---------------------------------------------------------------------------


def _flat(x: Array) -> Array:
    return x.reshape(-1)


def _static_size(x: Array) -> int:
    return int(x.size)


def resolve_k(k: int | float, d: int) -> int:
    """k may be an absolute count or a fraction of d."""
    if isinstance(k, float) and 0.0 < k < 1.0:
        kk = max(1, int(round(k * d)))
    else:
        kk = int(k)
    return max(1, min(kk, d))


def _rand_subset(key, d: int, k: int) -> Array:
    """Uniform k-subset of [0, d) without replacement: the indices of
    the k smallest keyed uniforms (a threshold selection / Gumbel-top-k
    with the identity weight).  Replaces
    ``jax.random.choice(replace=False)``, whose full permutation is an
    O(d log d) argsort per call — the op/randk_1pct pathology — with
    one ``lax.top_k`` partial selection; the draw is exactly as uniform
    and the wire-bit accounting (``bits_randk``: d, k and the seed
    cross the wire, never the indices) is unchanged."""
    if k >= d:
        return jnp.arange(d)
    u = jax.random.uniform(key, (d,))
    _, idx = jax.lax.top_k(-u, k)
    return idx


# ---------------------------------------------------------------------------
# base
# ---------------------------------------------------------------------------


class CompressionOp:
    """Base class.  Subclasses implement ``_compress_flat``."""

    #: True if the operator consumes randomness.
    stochastic: bool = False

    def __call__(self, key: Optional[Array], x: Array) -> Tuple[Array, Array]:
        """Returns ``(x_hat, bits)``: dense decompressed update + wire bits."""
        flat = _flat(x)
        out, bits = self._compress_flat(key, flat)
        return out.reshape(x.shape).astype(x.dtype), bits

    def _compress_flat(self, key, x):  # pragma: no cover - interface
        raise NotImplementedError

    def gamma(self, d: int) -> float:
        """Compression coefficient from the paper (for theory checks)."""
        raise NotImplementedError


def _register(cls):
    """Register a dataclass operator as a static pytree (no leaves)."""
    cls = dataclasses.dataclass(frozen=True)(cls)
    jax.tree_util.register_pytree_node(
        cls,
        lambda op: ((), dataclasses.astuple(op)),
        lambda aux, _: cls(*aux),
    )
    return cls


# ---------------------------------------------------------------------------
# identity / sparsifiers
# ---------------------------------------------------------------------------


@_register
class Identity(CompressionOp):
    """No compression; full-precision dense update (vanilla / local SGD)."""

    value_bits: int = 32

    def _compress_flat(self, key, x):
        return x, jnp.asarray(bitlib.bits_dense(x.size, self.value_bits), jnp.float64
                              if jax.config.read("jax_enable_x64") else jnp.float32)

    def gamma(self, d):
        return 1.0


@_register
class TopK(CompressionOp):
    """Keep the k largest-magnitude coordinates at full precision."""

    k: float = 0.01  # int count or fraction
    value_bits: int = 32

    def _compress_flat(self, key, x):
        d = _static_size(x)
        k = resolve_k(self.k, d)
        xf = x.astype(jnp.float32)
        vals, idx = jax.lax.top_k(jnp.abs(xf), k)
        out = jnp.zeros_like(xf).at[idx].set(xf[idx])
        bits = bitlib.bits_topk(d, k, self.value_bits)
        return out, jnp.asarray(bits, jnp.float32)

    def gamma(self, d):
        return resolve_k(self.k, d) / d


@_register
class RandK(CompressionOp):
    """Keep k uniformly random coordinates at full precision."""

    k: float = 0.01
    value_bits: int = 32
    stochastic = True

    def _compress_flat(self, key, x):
        d = _static_size(x)
        k = resolve_k(self.k, d)
        xf = x.astype(jnp.float32)
        idx = _rand_subset(key, d, k)
        out = jnp.zeros_like(xf).at[idx].set(xf[idx])
        # Rand_k indices can be seeded: only the seed + values cross the wire.
        bits = bitlib.bits_randk(d, k, self.value_bits)
        return out, jnp.asarray(bits, jnp.float32)

    def gamma(self, d):
        return resolve_k(self.k, d) / d


@_register
class RowTopK(CompressionOp):
    """Top-k per row of a 2D-reshaped tensor (blockwise Top_k).

    This is the TP-friendly variant: applied per model shard it never
    crosses shard boundaries, and by Corollary 1 (piecewise compression)
    the composition over rows/shards is a compression operator with
    gamma = k_row / row_len.

    ``row_len`` rows are formed from the flattened tensor (padding with
    zeros if needed); ``k`` is per-row.
    """

    k: float = 0.01
    row_len: int = 4096
    value_bits: int = 32

    def _compress_flat(self, key, x):
        d = _static_size(x)
        row = min(self.row_len, d)
        k = resolve_k(self.k, row)
        pad = (-d) % row
        xf = jnp.pad(x.astype(jnp.float32), (0, pad)).reshape(-1, row)
        vals, idx = jax.lax.top_k(jnp.abs(xf), k)
        out = jnp.zeros_like(xf)
        out = jax.vmap(lambda o, i, v: o.at[i].set(v))(
            out, idx, jnp.take_along_axis(xf, idx, axis=1)
        )
        out = out.reshape(-1)[:d]
        nrows = (d + pad) // row
        bits = nrows * bitlib.bits_topk(row, k, self.value_bits)
        return out, jnp.asarray(bits, jnp.float32)

    def gamma(self, d):
        row = min(self.row_len, d)
        return resolve_k(self.k, row) / row


# ---------------------------------------------------------------------------
# quantizers (Definition 1 / Definition 2)
# ---------------------------------------------------------------------------


@_register
class QSGDQuantizer(CompressionOp):
    """QSGD [AGL+17]: q_i = ||x||_2 * sign(x_i) * xi_i / s.

    xi_i stochastically rounds s*|x_i|/||x|| to an adjacent integer level.
    Unbiased; E||Q(x)||^2 <= (1 + beta) ||x||^2 with
    beta = min(d/s^2, sqrt(d)/s).
    """

    s: int = 15  # number of levels (4-bit quantizer => s = 2^4 - 1)
    stochastic = True

    def _compress_flat(self, key, x):
        xf = x.astype(jnp.float32)
        out = qsgd_quantize(key, xf, self.s)
        d = _static_size(x)
        nz = jnp.sum(out != 0.0)
        bits = bitlib.bits_qsgd(d, self.s, nz)
        return out, bits

    def beta(self, d: int) -> float:
        return min(d / self.s**2, math.sqrt(d) / self.s)

    def gamma(self, d):
        b = self.beta(d)
        if b >= 1.0:
            return 0.0  # outside Lemma-1 operating regime
        return 1.0 - b


def qsgd_quantize(key: Array, x: Array, s: int) -> Array:
    """Core QSGD map (shared with the kernel oracle)."""
    norm = jnp.linalg.norm(x)
    safe = jnp.where(norm > 0, norm, 1.0)
    level = jnp.abs(x) / safe * s           # in [0, s]
    low = jnp.floor(level)
    prob = level - low
    u = jax.random.uniform(key, x.shape)
    xi = low + (u < prob).astype(jnp.float32)
    q = norm * jnp.sign(x) * xi / s
    return jnp.where(norm > 0, q, jnp.zeros_like(x))


@_register
class StochasticKLevel(CompressionOp):
    """Stochastic s-level quantization between min_i x_i and max_i x_i
    [SYKM17, ZDJW13]; beta = d / (2 s^2)."""

    s: int = 15
    stochastic = True

    def _compress_flat(self, key, x):
        xf = x.astype(jnp.float32)
        lo, hi = jnp.min(xf), jnp.max(xf)
        span = jnp.where(hi > lo, hi - lo, 1.0)
        level = (xf - lo) / span * self.s
        low = jnp.floor(level)
        prob = level - low
        u = jax.random.uniform(key, xf.shape)
        xi = low + (u < prob).astype(jnp.float32)
        out = lo + xi / self.s * span
        out = jnp.where(hi > lo, out, xf)
        d = _static_size(x)
        bits = jnp.asarray(bitlib.bits_klevel(d, self.s), jnp.float32)
        return out, bits

    def beta(self, d: int) -> float:
        return d / (2.0 * self.s**2)

    def gamma(self, d):
        b = self.beta(d)
        return max(0.0, 1.0 - b)


@_register
class Sign(CompressionOp):
    """Deterministic 1-bit sign quantizer, scaled by ||x||_1 / d so that it
    is a compression operator (Lemma 3 with k = d, m = 1)."""

    def _compress_flat(self, key, x):
        xf = x.astype(jnp.float32)
        d = _static_size(x)
        scale = jnp.sum(jnp.abs(xf)) / d
        sg = jnp.where(xf >= 0, 1.0, -1.0)
        out = scale * sg
        bits = jnp.asarray(bitlib.bits_sign(d), jnp.float32)
        return out, bits

    def gamma(self, d):
        return 1.0 / d  # worst case (Lemma 3, m = 1 lower term)


# ---------------------------------------------------------------------------
# compositions (Lemmas 1-3)
# ---------------------------------------------------------------------------


@_register
class QuantizedSparsifier(CompressionOp):
    """``Q_s ∘ Comp_k``: QSGD (or k-level) applied to the k surviving
    coordinates of Top_k/Rand_k.

    scaled=False -> Lemma 1 (requires beta_{k,s} < 1; gamma=(1-beta)k/d)
    scaled=True  -> Lemma 2 (always compression; gamma = k/(d(1+beta)))
    """

    k: float = 0.01
    s: int = 15
    scaled: bool = False
    sparsifier: str = "top"  # "top" | "rand"
    quantizer: str = "qsgd"  # "qsgd" | "klevel"
    stochastic = True

    def _compress_flat(self, key, x):
        d = _static_size(x)
        k = resolve_k(self.k, d)
        xf = x.astype(jnp.float32)
        k_key, q_key = jax.random.split(key)
        if self.sparsifier == "top":
            _, idx = jax.lax.top_k(jnp.abs(xf), k)
        else:
            idx = _rand_subset(k_key, d, k)
        sel = xf[idx]  # compact k-vector: quantize it as a k-dim vector
        if self.quantizer == "qsgd":
            qsel = qsgd_quantize(q_key, sel, self.s)
            beta = min(k / self.s**2, math.sqrt(k) / self.s)
        else:
            lo, hi = jnp.min(sel), jnp.max(sel)
            span = jnp.where(hi > lo, hi - lo, 1.0)
            level = (sel - lo) / span * self.s
            low = jnp.floor(level)
            u = jax.random.uniform(q_key, sel.shape)
            xi = low + (u < (level - low)).astype(jnp.float32)
            qsel = jnp.where(hi > lo, lo + xi / self.s * span, sel)
            beta = k / (2.0 * self.s**2)
        if self.scaled:
            qsel = qsel / (1.0 + beta)
        out = jnp.zeros_like(xf).at[idx].set(qsel)
        nz = jnp.sum(qsel != 0.0)
        if self.sparsifier == "top":
            bits = bitlib.bits_qtopk(d, k, self.s, nz)
        else:
            bits = bitlib.bits_qrandk(d, k, self.s, nz)
        return out, bits

    def beta(self, d: int) -> float:
        k = resolve_k(self.k, d)
        if self.quantizer == "qsgd":
            return min(k / self.s**2, math.sqrt(k) / self.s)
        return k / (2.0 * self.s**2)

    def gamma(self, d):
        k = resolve_k(self.k, d)
        b = self.beta(d)
        if self.scaled:
            return k / (d * (1.0 + b))
        return max(0.0, (1.0 - b) * k / d)


@_register
class SignSparsifier(CompressionOp):
    """``SignComp_k`` (Lemma 3): 1-bit sign of the k selected coordinates,
    scaled by ||Comp_k(x)||_m / k.  m=1 or 2 supported."""

    k: float = 0.01
    m: int = 1
    sparsifier: str = "top"
    stochastic = True  # only when sparsifier == "rand"

    def _compress_flat(self, key, x):
        d = _static_size(x)
        k = resolve_k(self.k, d)
        xf = x.astype(jnp.float32)
        if self.sparsifier == "top":
            _, idx = jax.lax.top_k(jnp.abs(xf), k)
        else:
            idx = _rand_subset(key, d, k)
        sel = xf[idx]
        if self.m == 1:
            norm = jnp.sum(jnp.abs(sel))
        else:
            norm = jnp.linalg.norm(sel)
        sg = jnp.where(sel >= 0, 1.0, -1.0)
        out = jnp.zeros_like(xf).at[idx].set(norm / k * sg)
        bits = jnp.asarray(bitlib.bits_signtopk(d, k), jnp.float32)
        return out, bits

    def gamma(self, d):
        k = resolve_k(self.k, d)
        if self.m == 1:
            return 1.0 / d  # conservative lower bound from Lemma 3
        return k ** (2.0 / self.m - 1.0) / d


@_register
class RowSignTopK(CompressionOp):
    """SignTopK applied per row (TP-shard/block-local SignComp_k)."""

    k: float = 0.01
    row_len: int = 4096
    m: int = 2

    def _compress_flat(self, key, x):
        d = _static_size(x)
        row = min(self.row_len, d)
        k = resolve_k(self.k, row)
        pad = (-d) % row
        xf = jnp.pad(x.astype(jnp.float32), (0, pad)).reshape(-1, row)
        _, idx = jax.lax.top_k(jnp.abs(xf), k)
        sel = jnp.take_along_axis(xf, idx, axis=1)
        if self.m == 1:
            norm = jnp.sum(jnp.abs(sel), axis=1, keepdims=True)
        else:
            norm = jnp.linalg.norm(sel, axis=1, keepdims=True)
        sg = jnp.where(sel >= 0, 1.0, -1.0)
        out = jnp.zeros_like(xf)
        out = jax.vmap(lambda o, i, v: o.at[i].set(v))(out, idx, norm / k * sg)
        out = out.reshape(-1)[:d]
        nrows = (d + pad) // row
        bits = jnp.asarray(nrows * bitlib.bits_signtopk(row, k), jnp.float32)
        return out, bits

    def gamma(self, d):
        row = min(self.row_len, d)
        k = resolve_k(self.k, row)
        return k ** (2.0 / self.m - 1.0) / row


# ---------------------------------------------------------------------------
# piecewise application over pytrees (Corollary 1)
# ---------------------------------------------------------------------------


def ops_for_leaves(op_tree, n_leaves: int) -> list:
    """Resolve a single op (broadcast) or a pytree-prefix of ops to one
    operator per gradient leaf (shared by the reference and the
    kernel-dispatch compression paths)."""
    if isinstance(op_tree, CompressionOp):
        return [op_tree] * n_leaves
    ops = jax.tree_util.tree_leaves(
        op_tree, is_leaf=lambda z: isinstance(z, CompressionOp)
    )
    if len(ops) != n_leaves:
        raise ValueError(
            f"operator tree has {len(ops)} leaves, grads have {n_leaves}"
        )
    return ops


def compress_tree(op_tree, key: Optional[Array], grads):
    """Apply a (tree of) compression operator(s) leafwise.

    ``op_tree`` is a single CompressionOp (broadcast to all leaves) or a
    pytree-prefix of operators.  Returns (compressed_tree, total_bits).
    By Corollary 1 the leafwise application is itself a compression
    operator with gamma = min_i gamma_i.
    """
    leaves, treedef = jax.tree_util.tree_flatten(grads)
    ops = ops_for_leaves(op_tree, len(leaves))
    if key is not None:
        keys = jax.random.split(key, len(leaves))
    else:
        keys = [None] * len(leaves)
    outs, bit_terms = [], []
    for op, k, g in zip(ops, keys, leaves):
        o, b = op(k, g)
        outs.append(o)
        bit_terms.append(jnp.asarray(b, jnp.float32))
    total_bits = jnp.sum(jnp.stack(bit_terms)) if bit_terms else jnp.float32(0)
    return jax.tree_util.tree_unflatten(treedef, outs), total_bits


def tree_gamma(op_tree, grads) -> float:
    """min_i gamma_i over leaves (Corollary 1)."""
    leaves = jax.tree_util.tree_leaves(grads)
    if isinstance(op_tree, CompressionOp):
        ops = [op_tree] * len(leaves)
    else:
        ops = jax.tree_util.tree_leaves(
            op_tree, is_leaf=lambda z: isinstance(z, CompressionOp)
        )
    return min(op.gamma(int(l.size)) for op, l in zip(ops, leaves))


# operator registry (config/spec-driven construction) ---------------------
#
# Every operator family is registered under a stable wire name; aliases
# (qtopk/qrandk/...) pin constructor kwargs of a shared class.  The
# registry is the single source of truth for ``core.policy`` spec
# parsing/serialization and for every CLI/config surface — an unknown
# name fails loudly here instead of silently falling back to Identity.


@dataclasses.dataclass(frozen=True)
class RegisteredOp:
    """One registry entry: a name bound to a class + pinned kwargs."""

    name: str
    cls: type
    fixed: Tuple[Tuple[str, object], ...]  # kwargs the alias pins
    summary: str = ""

    def fields(self) -> dict:
        """Configurable constructor fields (name -> default), with the
        alias-pinned ones removed."""
        pinned = {k for k, _ in self.fixed}
        return {f.name: f.default for f in dataclasses.fields(self.cls)
                if f.name not in pinned}


OP_REGISTRY: dict[str, RegisteredOp] = {}


def register_op(name: str, summary: str = "", **fixed):
    """Class decorator (also callable on an existing class) registering
    a ``CompressionOp`` under ``name``.  ``fixed`` kwargs are pinned by
    the alias and cannot be overridden through the spec surface."""

    def deco(cls):
        if name in OP_REGISTRY:
            raise ValueError(f"operator name {name!r} already registered")
        for k in fixed:
            if k not in {f.name for f in dataclasses.fields(cls)}:
                raise TypeError(
                    f"register_op({name!r}): {cls.__name__} has no "
                    f"field {k!r}")
        OP_REGISTRY[name] = RegisteredOp(
            name, cls, tuple(sorted(fixed.items())), summary)
        return cls

    return deco


register_op("identity", "no compression (vanilla / local SGD)")(Identity)
register_op("topk", "Top_k sparsifier [SCJ18]")(TopK)
register_op("randk", "Rand_k sparsifier [SCJ18]")(RandK)
register_op("row_topk", "per-row Top_k (TP-shard-local, Cor. 1)")(RowTopK)
register_op("qsgd", "QSGD quantizer [AGL+17], Definition 1")(QSGDQuantizer)
register_op("klevel", "stochastic s-level quantizer [SYKM17]")(
    StochasticKLevel)
register_op("sign", "scaled 1-bit sign, Definition 2")(Sign)
register_op("qtopk", "QSGD o Top_k (Lemmas 1-2)",
            sparsifier="top")(QuantizedSparsifier)
register_op("qrandk", "QSGD o Rand_k (Lemmas 1-2)",
            sparsifier="rand")(QuantizedSparsifier)
register_op("signtopk", "Sign o Top_k (Lemma 3)",
            sparsifier="top")(SignSparsifier)
register_op("signrandk", "Sign o Rand_k (Lemma 3)",
            sparsifier="rand")(SignSparsifier)
register_op("row_signtopk", "per-row SignTop_k (TP-shard-local)")(
    RowSignTopK)


class _OperatorsView(dict):
    """Backward-compat ``OPERATORS`` mapping: name -> constructor."""

    def __getitem__(self, name):
        entry = super().__getitem__(name)
        return partial(entry.cls, **dict(entry.fixed)) if entry.fixed \
            else entry.cls


OPERATORS = _OperatorsView(OP_REGISTRY)


def make_operator(name: str, **kw) -> CompressionOp:
    """Construct a registered operator; loud errors for unknown names
    and unknown/pinned kwargs (the registry's validation choke point)."""
    if name not in OP_REGISTRY:
        raise KeyError(
            f"unknown operator {name!r}; registered: {sorted(OP_REGISTRY)}")
    entry = OP_REGISTRY[name]
    pinned = dict(entry.fixed)
    clash = sorted(set(kw) & set(pinned))
    if clash:
        raise TypeError(
            f"operator {name!r} pins {clash}; use a different registry "
            f"name instead of overriding")
    valid = entry.fields()
    unknown = sorted(set(kw) - set(valid))
    if unknown:
        raise TypeError(
            f"operator {name!r} has no parameter(s) {unknown}; "
            f"valid: {sorted(valid)}")
    return entry.cls(**pinned, **kw)


def spec_name_of(op: CompressionOp) -> str:
    """The registry name serializing this operator instance — the entry
    of ``type(op)`` whose pinned kwargs match (most-pinned wins, so
    ``QuantizedSparsifier(sparsifier='top')`` maps to ``qtopk``)."""
    best = None
    for entry in OP_REGISTRY.values():
        if entry.cls is not type(op):
            continue
        if all(getattr(op, k) == v for k, v in entry.fixed):
            if best is None or len(entry.fixed) > len(best.fixed):
                best = entry
    if best is None:
        raise KeyError(
            f"{type(op).__name__}({op!r}) matches no registered operator "
            f"name; register it with register_op")
    return best.name

"""Fleet scenario simulator (DESIGN.md §8): declarative partial-
participation / straggler / dropout specs compiled to the engine's
``[T, R]`` per-worker sync mask.

The paper's convergence theory (Theorems 1-4) assumes every worker
contributes to every sync round; a production fleet does not.  The
engine already executes *arbitrary* per-worker masks (the generalized
``s ∈ {0,1}^R`` of ``core/engine.py``), so fleet behaviour is purely a
mask-generation problem plus an aggregation-rule question:

  * **participation** — each scheduled sync event survives i.i.d. with
    probability p (a worker that misses its sync keeps training locally
    against its lagging view; its error memory keeps accumulating).
  * **mid-round dropout** — a second, independent thinning applied to
    the survivors: the worker reached the round but its payload was
    lost (network partition, preemption) — statistically identical to
    non-participation at the mask layer, kept as a separate knob so
    specs document *why* a sync is missing and failure-injection tests
    can target it.
  * **stragglers** — a fixed fraction of workers sync k× less often
    (they only land every ``straggler_stale_rounds``-th of their
    scheduled syncs), modelling persistently slow hosts whose
    contributions are k rounds stale.
  * **heterogeneous H** — per-worker local-step periods drawn uniformly
    from ``hetero_H=(lo, hi)`` instead of the shared ``H``.

Masks are plain numpy bool arrays, deterministic in ``seed``; with all
knobs at their defaults ``Scenario().mask(T, R, H)`` is bit-for-bit the
trainer's synchronous fixed schedule, so a scenario run degenerates
exactly to the paper's Algorithm 1/2.

The matching aggregation rules (``aggregate=`` in ``core/engine.py`` /
``core/distributed.py``) are:

  * ``mean_R`` — the paper's Σ/R (divide by the *fleet* size).  Under
    partial participation this silently scales the update by |S|/R;
    :func:`warn_if_biased` emits a one-time warning for such runs.
  * ``mean_S`` — divide by the syncing-subset size |S|; equals mean_R
    bit-for-bit when every worker participates.
  * ``support_weighted`` — FedDropoutAvg-style per-coordinate mean:
    each coordinate is divided by its *survivor count* (the number of
    syncing workers whose compressed payload carried that coordinate),
    with zero-support coordinates falling back to the master value
    (the numerator is exactly zero there, so the guard is the
    ``max(count, 1)`` denominator).  With Identity compression every
    syncing worker supports every coordinate, so it equals mean_S
    bit-for-bit.
"""

from __future__ import annotations

import dataclasses
from typing import Optional

import numpy as np

from repro.core import policy as pol, schedule as sched

#: aggregation rules understood by the engines (see module docstring)
AGGREGATES = ("mean_R", "mean_S", "support_weighted")


def validate_aggregate(aggregate: str) -> str:
    if aggregate not in AGGREGATES:
        raise ValueError(
            f"unknown aggregate {aggregate!r}; expected one of "
            f"{AGGREGATES} (wire formats moved to wire=, see "
            f"core/distributed.py)")
    return aggregate


@dataclasses.dataclass(frozen=True)
class Scenario:
    """Declarative fleet-behaviour spec; ``mask(T, R, H)`` compiles it.

    All knobs default to the lossless fleet: ``Scenario().mask(T, R, H)``
    is exactly the synchronous fixed schedule broadcast to R workers.
    """

    participation: float = 1.0        # P(scheduled sync survives)
    dropout_mid_round: float = 0.0    # P(survivor drops mid-round)
    straggler_frac: float = 0.0       # fraction of persistently slow workers
    straggler_stale_rounds: int = 4   # stragglers land every k-th sync only
    hetero_H: Optional[tuple] = None  # per-worker H ~ U{lo..hi}; None = shared
    seed: int = 0

    def __post_init__(self):
        if not (0.0 <= self.participation <= 1.0):
            raise ValueError(f"participation must be in [0, 1], "
                             f"got {self.participation}")
        if not (0.0 <= self.dropout_mid_round <= 1.0):
            raise ValueError(f"dropout_mid_round must be in [0, 1], "
                             f"got {self.dropout_mid_round}")
        if not (0.0 <= self.straggler_frac <= 1.0):
            raise ValueError(f"straggler_frac must be in [0, 1], "
                             f"got {self.straggler_frac}")
        if self.straggler_stale_rounds < 1:
            raise ValueError("straggler_stale_rounds must be >= 1")
        if self.hetero_H is not None:
            lo, hi = self.hetero_H
            if not (1 <= int(lo) <= int(hi)):
                raise ValueError(f"hetero_H must be (lo, hi) with "
                                 f"1 <= lo <= hi, got {self.hetero_H}")

    # ---- mask compilation ------------------------------------------------

    def mask(self, T: int, R: int, H: int = 1) -> np.ndarray:
        """The ``[T, R]`` bool sync mask of this scenario.

        Worker r's base schedule is ``fixed_schedule(T, H_r)`` (H_r = H,
        or drawn from ``hetero_H``); stragglers then keep only every
        ``straggler_stale_rounds``-th of their scheduled syncs, and each
        remaining sync event survives participation and mid-round
        dropout independently.  Deterministic in ``seed`` (one
        ``RandomState`` consumed in worker-major order); all-False rows
        and columns are legal engine inputs (pure-local steps / workers
        that never sync).
        """
        if T < 1 or R < 1:
            raise ValueError(f"need T >= 1 and R >= 1, got T={T}, R={R}")
        rng = np.random.RandomState(self.seed)
        if self.hetero_H is not None:
            lo, hi = int(self.hetero_H[0]), int(self.hetero_H[1])
            Hs = rng.randint(lo, hi + 1, size=R)
        else:
            Hs = np.full(R, int(H))
        n_strag = int(round(self.straggler_frac * R))
        stragglers = set(
            rng.choice(R, size=n_strag, replace=False)) if n_strag else set()
        mask = np.zeros((T, R), bool)
        for r in range(R):
            col = sched.fixed_schedule(T, int(Hs[r]))
            events = np.flatnonzero(col)
            if r in stragglers:
                # keep every k-th scheduled sync (1-indexed events), so a
                # straggler's contribution is always ~k rounds stale
                keep = (np.arange(1, len(events) + 1)
                        % self.straggler_stale_rounds) == 0
                events = events[keep]
            if self.participation < 1.0 and len(events):
                events = events[rng.rand(len(events)) < self.participation]
            if self.dropout_mid_round > 0.0 and len(events):
                events = events[
                    rng.rand(len(events)) >= self.dropout_mid_round]
            mask[events, r] = True
        return mask

    # ---- spec string surface --------------------------------------------

    def to_string(self) -> str:
        """Canonical ``k=v,...`` spec string (round-trips via parse)."""
        parts = []
        for f in dataclasses.fields(self):
            v = getattr(self, f.name)
            if v == f.default:
                continue
            if f.name == "hetero_H":
                parts.append(f"hetero_H={int(v[0])}-{int(v[1])}")
            else:
                parts.append(f"{f.name}={v}")
        return ",".join(parts)


#: named fleet presets (``--scenario preset:<name>``)
PRESETS = {
    # the lossless fleet: pure Algorithm-1 schedule
    "lossless": Scenario(),
    # the CI failure-injection profile: partial participation,
    # mid-round payload loss, a slow eighth of the fleet, and
    # heterogeneous local-step periods — every knob nonzero
    "flaky_fleet": Scenario(participation=0.85, dropout_mid_round=0.05,
                            straggler_frac=0.125, straggler_stale_rounds=3,
                            hetero_H=(1, 8), seed=7),
    # isolate one failure mode each
    "dropout": Scenario(participation=0.7, seed=11),
    "stragglers": Scenario(straggler_frac=0.25, straggler_stale_rounds=4,
                           seed=13),
    "hetero": Scenario(hetero_H=(1, 16), seed=17),
}


def parse(spec) -> Scenario:
    """A Scenario from a spec string, preset name, or Scenario.

    Accepts ``"preset:<name>"`` (see :data:`PRESETS`), a ``k=v,...``
    string (``"participation=0.8,straggler_frac=0.1,seed=3"``, with
    ``hetero_H=lo-hi``), or an existing :class:`Scenario` (returned
    as-is).  Unknown keys and presets raise.
    """
    if isinstance(spec, Scenario):
        return spec
    if not isinstance(spec, str):
        raise TypeError(f"scenario spec must be a Scenario or str, "
                        f"got {type(spec).__name__}")
    s = spec.strip()
    if s.startswith("preset:"):
        name = s[len("preset:"):]
        try:
            return PRESETS[name]
        except KeyError:
            raise KeyError(
                f"unknown scenario preset {name!r}; available: "
                f"{sorted(PRESETS)}") from None
    kwargs = {}
    fields = {f.name: f for f in dataclasses.fields(Scenario)}
    for item in filter(None, (p.strip() for p in s.split(","))):
        if "=" not in item:
            raise ValueError(f"bad scenario item {item!r}: expected k=v")
        k, v = (x.strip() for x in item.split("=", 1))
        if k not in fields:
            raise KeyError(f"unknown scenario field {k!r}; available: "
                           f"{sorted(fields)}")
        if k == "hetero_H":
            lo, _, hi = v.partition("-")
            kwargs[k] = (int(lo), int(hi or lo))
        elif k in ("straggler_stale_rounds", "seed"):
            kwargs[k] = int(v)
        else:
            kwargs[k] = float(v)
    return Scenario(**kwargs)


# ---------------------------------------------------------------------------
# mask diagnostics
# ---------------------------------------------------------------------------


def is_partial(mask) -> bool:
    """Does any sync step have a strict subset of workers syncing?
    (the regime where mean_R's Σ/R silently downscales the update)"""
    m = np.asarray(mask, bool)
    if m.ndim == 1:
        return False
    rows = m.sum(axis=1)
    return bool(np.any((rows > 0) & (rows < m.shape[1])))


def participation_of(mask) -> float:
    """Mean fraction of workers syncing over the steps where anyone
    does (1.0 for an all-agree schedule; 0.0 when nothing syncs)."""
    m = np.asarray(mask, bool)
    if m.ndim == 1:
        m = m[:, None]
    any_rows = m.any(axis=1)
    if not any_rows.any():
        return 0.0
    return float(m[any_rows].mean())


def warn_if_biased(mask, aggregate: str) -> bool:
    """One-time warning for the silent Σ/R bias: under partial
    participation ``mean_R`` scales every update down by |S|/R (the
    paper-faithful default, but rarely what a fleet operator means).
    Returns whether the warning condition held."""
    biased = aggregate == "mean_R" and is_partial(mask)
    if biased:
        pol.warn_once(
            "scenario-mean_R-partial",
            "scenario has partial participation (mean fraction "
            f"{participation_of(mask):.2f}) with aggregate='mean_R': "
            "the paper's Σ/R divides by the full fleet size, scaling "
            "each update down by |S|/R. Pass aggregate='mean_S' or "
            "'support_weighted' for unbiased partial-participation "
            "averaging.")
    return biased


# ---------------------------------------------------------------------------
# failure injection (the differential-test surface)
# ---------------------------------------------------------------------------


def inject_dropout(mask, worker: int, step: int) -> np.ndarray:
    """Mask-layer failure: remove worker's sync at ``step`` entirely
    (its payload never arrives; the master round proceeds without it)."""
    m = np.array(mask, bool, copy=True)
    if not m[step, worker]:
        raise ValueError(f"worker {worker} does not sync at step {step}")
    m[step, worker] = False
    return m


def defer_sync(mask, worker: int, step: int, later: int) -> np.ndarray:
    """Stale-arrival failure: worker's sync at ``step`` lands at
    ``later`` instead (the payload survived but arrived rounds late —
    the async regime of ``core/async_qsparse.py``)."""
    if later <= step:
        raise ValueError(f"deferred step {later} must follow {step}")
    m = inject_dropout(mask, worker, step)
    m[later, worker] = True
    return m

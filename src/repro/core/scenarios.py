"""Fleet scenario simulator (DESIGN.md §8): declarative partial-
participation / straggler / dropout specs compiled to the engine's
``[T, R]`` per-worker sync mask.

The paper's convergence theory (Theorems 1-4) assumes every worker
contributes to every sync round; a production fleet does not.  The
engine already executes *arbitrary* per-worker masks (the generalized
``s ∈ {0,1}^R`` of ``core/engine.py``), so fleet behaviour is purely a
mask-generation problem plus an aggregation-rule question:

  * **participation** — each scheduled sync event survives i.i.d. with
    probability p (a worker that misses its sync keeps training locally
    against its lagging view; its error memory keeps accumulating).
  * **mid-round dropout** — a second, independent thinning applied to
    the survivors: the worker reached the round but its payload was
    lost (network partition, preemption) — statistically identical to
    non-participation at the mask layer, kept as a separate knob so
    specs document *why* a sync is missing and failure-injection tests
    can target it.
  * **stragglers** — a fixed fraction of workers sync k× less often
    (they only land every ``straggler_stale_rounds``-th of their
    scheduled syncs), modelling persistently slow hosts whose
    contributions are k rounds stale.
  * **heterogeneous H** — per-worker local-step periods drawn uniformly
    from ``hetero_H=(lo, hi)`` instead of the shared ``H``.

Masks are plain numpy bool arrays, deterministic in ``seed``; with all
knobs at their defaults ``Scenario().mask(T, R, H)`` is bit-for-bit the
trainer's synchronous fixed schedule, so a scenario run degenerates
exactly to the paper's Algorithm 1/2.

The matching aggregation rules (``aggregate=`` in ``core/engine.py`` /
``core/distributed.py``) are:

  * ``mean_R`` — the paper's Σ/R (divide by the *fleet* size).  Under
    partial participation this silently scales the update by |S|/R;
    :func:`warn_if_biased` emits a one-time warning for such runs.
  * ``mean_S`` — divide by the syncing-subset size |S|; equals mean_R
    bit-for-bit when every worker participates.
  * ``support_weighted`` — FedDropoutAvg-style per-coordinate mean:
    each coordinate is divided by its *survivor count* (the number of
    syncing workers whose compressed payload carried that coordinate),
    with zero-support coordinates falling back to the master value
    (the numerator is exactly zero there, so the guard is the
    ``max(count, 1)`` denominator).  With Identity compression every
    syncing worker supports every coordinate, so it equals mean_S
    bit-for-bit.
"""

from __future__ import annotations

import dataclasses
from typing import NamedTuple, Optional

import numpy as np

from repro.core import policy as pol, schedule as sched

#: aggregation rules understood by the engines (see module docstring)
AGGREGATES = ("mean_R", "mean_S", "support_weighted")


def validate_aggregate(aggregate: str) -> str:
    if aggregate not in AGGREGATES:
        raise ValueError(
            f"unknown aggregate {aggregate!r}; expected one of "
            f"{AGGREGATES} (wire formats moved to wire=, see "
            f"core/distributed.py)")
    return aggregate


@dataclasses.dataclass(frozen=True)
class Scenario:
    """Declarative fleet-behaviour spec; ``mask(T, R, H)`` compiles it.

    All knobs default to the lossless fleet: ``Scenario().mask(T, R, H)``
    is exactly the synchronous fixed schedule broadcast to R workers.
    """

    participation: float = 1.0        # P(scheduled sync survives)
    dropout_mid_round: float = 0.0    # P(survivor drops mid-round)
    straggler_frac: float = 0.0       # fraction of persistently slow workers
    straggler_stale_rounds: int = 4   # stragglers land every k-th sync only
    hetero_H: Optional[tuple] = None  # per-worker H ~ U{lo..hi}; None = shared
    seed: int = 0

    def __post_init__(self):
        if not (0.0 <= self.participation <= 1.0):
            raise ValueError(f"participation must be in [0, 1], "
                             f"got {self.participation}")
        if not (0.0 <= self.dropout_mid_round <= 1.0):
            raise ValueError(f"dropout_mid_round must be in [0, 1], "
                             f"got {self.dropout_mid_round}")
        if not (0.0 <= self.straggler_frac <= 1.0):
            raise ValueError(f"straggler_frac must be in [0, 1], "
                             f"got {self.straggler_frac}")
        if self.straggler_stale_rounds < 1:
            raise ValueError("straggler_stale_rounds must be >= 1")
        if self.hetero_H is not None:
            lo, hi = self.hetero_H
            if not (1 <= int(lo) <= int(hi)):
                raise ValueError(f"hetero_H must be (lo, hi) with "
                                 f"1 <= lo <= hi, got {self.hetero_H}")

    # ---- mask compilation ------------------------------------------------

    def mask(self, T: int, R: int, H: int = 1) -> np.ndarray:
        """The ``[T, R]`` bool sync mask of this scenario.

        Worker r's base schedule is ``fixed_schedule(T, H_r)`` (H_r = H,
        or drawn from ``hetero_H``); stragglers then keep only every
        ``straggler_stale_rounds``-th of their scheduled syncs, and each
        remaining sync event survives participation and mid-round
        dropout independently.  Deterministic in ``seed`` (one
        ``RandomState`` consumed in worker-major order); all-False rows
        and columns are legal engine inputs (pure-local steps / workers
        that never sync).
        """
        if T < 1 or R < 1:
            raise ValueError(f"need T >= 1 and R >= 1, got T={T}, R={R}")
        rng = np.random.RandomState(self.seed)
        if self.hetero_H is not None:
            lo, hi = int(self.hetero_H[0]), int(self.hetero_H[1])
            Hs = rng.randint(lo, hi + 1, size=R)
        else:
            Hs = np.full(R, int(H))
        n_strag = int(round(self.straggler_frac * R))
        stragglers = set(
            rng.choice(R, size=n_strag, replace=False)) if n_strag else set()
        mask = np.zeros((T, R), bool)
        for r in range(R):
            col = sched.fixed_schedule(T, int(Hs[r]))
            events = np.flatnonzero(col)
            if r in stragglers:
                # keep every k-th scheduled sync (1-indexed events), so a
                # straggler's contribution is always ~k rounds stale
                keep = (np.arange(1, len(events) + 1)
                        % self.straggler_stale_rounds) == 0
                events = events[keep]
            if self.participation < 1.0 and len(events):
                events = events[rng.rand(len(events)) < self.participation]
            if self.dropout_mid_round > 0.0 and len(events):
                events = events[
                    rng.rand(len(events)) >= self.dropout_mid_round]
            mask[events, r] = True
        return mask

    # ---- spec string surface --------------------------------------------

    def to_string(self) -> str:
        """Canonical ``k=v,...`` spec string (round-trips via parse)."""
        parts = []
        for f in dataclasses.fields(self):
            v = getattr(self, f.name)
            if v == f.default:
                continue
            if f.name == "hetero_H":
                parts.append(f"hetero_H={int(v[0])}-{int(v[1])}")
            else:
                parts.append(f"{f.name}={v}")
        return ",".join(parts)


#: named fleet presets (``--scenario preset:<name>``)
PRESETS = {
    # the lossless fleet: pure Algorithm-1 schedule
    "lossless": Scenario(),
    # the CI failure-injection profile: partial participation,
    # mid-round payload loss, a slow eighth of the fleet, and
    # heterogeneous local-step periods — every knob nonzero
    "flaky_fleet": Scenario(participation=0.85, dropout_mid_round=0.05,
                            straggler_frac=0.125, straggler_stale_rounds=3,
                            hetero_H=(1, 8), seed=7),
    # isolate one failure mode each
    "dropout": Scenario(participation=0.7, seed=11),
    "stragglers": Scenario(straggler_frac=0.25, straggler_stale_rounds=4,
                           seed=13),
    "hetero": Scenario(hetero_H=(1, 16), seed=17),
}


def parse(spec) -> Scenario:
    """A Scenario from a spec string, preset name, or Scenario.

    Accepts ``"preset:<name>"`` (see :data:`PRESETS`), a ``k=v,...``
    string (``"participation=0.8,straggler_frac=0.1,seed=3"``, with
    ``hetero_H=lo-hi``), or an existing :class:`Scenario` (returned
    as-is).  Unknown keys and presets raise.
    """
    if isinstance(spec, Scenario):
        return spec
    if not isinstance(spec, str):
        raise TypeError(f"scenario spec must be a Scenario or str, "
                        f"got {type(spec).__name__}")
    s = spec.strip()
    if s.startswith("preset:"):
        name = s[len("preset:"):]
        try:
            return PRESETS[name]
        except KeyError:
            raise KeyError(
                f"unknown scenario preset {name!r}; available: "
                f"{sorted(PRESETS)}") from None
    kwargs = {}
    fields = {f.name: f for f in dataclasses.fields(Scenario)}
    for item in filter(None, (p.strip() for p in s.split(","))):
        if "=" not in item:
            raise ValueError(f"bad scenario item {item!r}: expected k=v")
        k, v = (x.strip() for x in item.split("=", 1))
        if k not in fields:
            raise KeyError(f"unknown scenario field {k!r}; available: "
                           f"{sorted(fields)}")
        if k == "hetero_H":
            lo, _, hi = v.partition("-")
            kwargs[k] = (int(lo), int(hi or lo))
        elif k in ("straggler_stale_rounds", "seed"):
            kwargs[k] = int(v)
        else:
            kwargs[k] = float(v)
    return Scenario(**kwargs)


# ---------------------------------------------------------------------------
# mask diagnostics
# ---------------------------------------------------------------------------


def is_partial(mask) -> bool:
    """Does any sync step have a strict subset of workers syncing?
    (the regime where mean_R's Σ/R silently downscales the update)"""
    m = np.asarray(mask, bool)
    if m.ndim == 1:
        return False
    rows = m.sum(axis=1)
    return bool(np.any((rows > 0) & (rows < m.shape[1])))


def participation_of(mask) -> float:
    """Mean fraction of workers syncing over the steps where anyone
    does (1.0 for an all-agree schedule; 0.0 when nothing syncs)."""
    m = np.asarray(mask, bool)
    if m.ndim == 1:
        m = m[:, None]
    any_rows = m.any(axis=1)
    if not any_rows.any():
        return 0.0
    return float(m[any_rows].mean())


def warn_if_biased(mask, aggregate: str) -> bool:
    """One-time warning for the silent Σ/R bias: under partial
    participation ``mean_R`` scales every update down by |S|/R (the
    paper-faithful default, but rarely what a fleet operator means).
    Returns whether the warning condition held."""
    biased = aggregate == "mean_R" and is_partial(mask)
    if biased:
        pol.warn_once(
            "scenario-mean_R-partial",
            "scenario has partial participation (mean fraction "
            f"{participation_of(mask):.2f}) with aggregate='mean_R': "
            "the paper's Σ/R divides by the full fleet size, scaling "
            "each update down by |S|/R. Pass aggregate='mean_S' or "
            "'support_weighted' for unbiased partial-participation "
            "averaging.")
    return biased


# ---------------------------------------------------------------------------
# failure injection (the differential-test surface)
# ---------------------------------------------------------------------------


def inject_dropout(mask, worker: int, step: int) -> np.ndarray:
    """Mask-layer failure: remove worker's sync at ``step`` entirely
    (its payload never arrives; the master round proceeds without it)."""
    m = np.array(mask, bool, copy=True)
    if not m[step, worker]:
        raise ValueError(f"worker {worker} does not sync at step {step}")
    m[step, worker] = False
    return m


def defer_sync(mask, worker: int, step: int, later: int) -> np.ndarray:
    """Stale-arrival failure: worker's sync at ``step`` lands at
    ``later`` instead — the *modelled* form of staleness (the whole
    sync event moves, so the payload is computed late too).  For the
    paper-faithful *executed* form — payload computed at ``step``,
    applied at ``step + τ`` — use :class:`FaultSpec` delays, which keep
    the compute time (and hence the error-feedback algebra) intact."""
    if later <= step:
        raise ValueError(f"deferred step {later} must follow {step}")
    m = inject_dropout(mask, worker, step)
    m[later, worker] = True
    return m


# ---------------------------------------------------------------------------
# fault specs (DESIGN.md §9): executed staleness, crash/recover, drops
# ---------------------------------------------------------------------------


class FaultTables(NamedTuple):
    """Per-step ``[T, R]`` expansion of a :class:`FaultSpec`.

    * ``delay``   — int32, payload computed at t arrives at t+delay[t,r];
    * ``alive``   — bool, worker r is up at step t (a dead worker takes
      no local step, computes no payload, and receives no broadcast);
    * ``recover`` — bool, step t is worker r's first alive step after an
      outage (error memory is lost; local/view re-init from the master);
    * ``drop``    — bool, the payload computed at (t, r) is lost in
      flight (memory was already updated at compute time — the
      error-feedback algebra absorbs the loss over later rounds).

    All tables are deterministic in the spec's ``seed`` (a dedicated
    ``np.random.RandomState`` — a PRNG stream fully separate from the
    jax data/model key stream, so enabling faults never perturbs batch
    construction or compression randomness).
    """

    delay: np.ndarray     # int32 [T, R]
    alive: np.ndarray     # bool  [T, R]
    recover: np.ndarray   # bool  [T, R]
    drop: np.ndarray      # bool  [T, R]

    @property
    def depth(self) -> int:
        """In-flight queue depth the engine must allocate: one slot per
        possible outstanding delay (``max observed delay + 1``)."""
        return int(self.delay.max()) + 1 if self.delay.size else 1

    @property
    def trivial(self) -> bool:
        """No faults at all — the tables of ``FaultSpec()``."""
        return (not self.delay.any() and bool(self.alive.all())
                and not self.drop.any())


@dataclasses.dataclass(frozen=True)
class FaultSpec:
    """Declarative fault-injection spec; ``tables(T, R)`` expands it.

    All knobs default to the fault-free fleet: ``FaultSpec().tables(T,
    R)`` yields trivial tables (zero delay, everyone alive, no drops),
    under which the fault runtime is bit-for-bit the fault-free one.

    * ``min_delay``/``max_delay`` — payload staleness τ drawn uniformly
      from ``{min_delay..max_delay}`` per computed payload: computed at
      t, applied to the master at t+τ.
    * ``drop`` — probability a computed payload is lost in flight
      (never applied; the uplink error memory was already updated at
      compute time, so the loss is absorbed by error feedback).
    * ``crash`` — deterministic outage windows
      ``((worker, crash_step, recover_step), ...)``: worker is dead for
      steps ``crash_step <= t < recover_step``.  On recovery the worker
      re-initializes from the current master and its error memory is
      lost (zeroed).
    * ``crash_rate``/``mean_outage`` — additionally, each alive worker
      crashes i.i.d. per step with probability ``crash_rate`` for a
      geometric outage of mean ``mean_outage`` steps.
    * ``seed`` — the dedicated fault PRNG seed (``--fault-seed``).
    """

    max_delay: int = 0
    min_delay: int = 0
    drop: float = 0.0
    crash: tuple = ()           # ((worker, crash_step, recover_step), ...)
    crash_rate: float = 0.0
    mean_outage: float = 8.0
    seed: int = 0

    def __post_init__(self):
        if not (0 <= self.min_delay <= self.max_delay):
            raise ValueError(
                f"need 0 <= min_delay <= max_delay, got "
                f"[{self.min_delay}, {self.max_delay}]")
        if not (0.0 <= self.drop <= 1.0):
            raise ValueError(f"drop must be in [0, 1], got {self.drop}")
        if not (0.0 <= self.crash_rate <= 1.0):
            raise ValueError(
                f"crash_rate must be in [0, 1], got {self.crash_rate}")
        if self.mean_outage < 1.0:
            raise ValueError(
                f"mean_outage must be >= 1, got {self.mean_outage}")
        for w in self.crash:
            if len(w) != 3:
                raise ValueError(
                    f"crash window must be (worker, crash, recover), "
                    f"got {w!r}")
            r, c, rec = (int(x) for x in w)
            if r < 0 or c < 0 or rec <= c:
                raise ValueError(
                    f"bad crash window {w!r}: need worker >= 0 and "
                    f"0 <= crash_step < recover_step")

    @property
    def depth(self) -> int:
        """Static queue depth (independent of T/R, so jitted programs
        are reusable across runs of the same spec)."""
        return int(self.max_delay) + 1

    # ---- table expansion -------------------------------------------------

    def tables(self, T: int, R: int) -> FaultTables:
        """Expand into per-step ``[T, R]`` tables (see FaultTables)."""
        if T < 1 or R < 1:
            raise ValueError(f"need T >= 1 and R >= 1, got T={T}, R={R}")
        rng = np.random.RandomState(self.seed)
        if self.max_delay > self.min_delay:
            delay = rng.randint(self.min_delay, self.max_delay + 1,
                                size=(T, R)).astype(np.int32)
        else:
            delay = np.full((T, R), self.min_delay, np.int32)
        drop = (rng.rand(T, R) < self.drop if self.drop > 0.0
                else np.zeros((T, R), bool))
        alive = np.ones((T, R), bool)
        if self.crash_rate > 0.0:
            # per-worker markov outages: crash i.i.d. per alive step,
            # outage length 1 + geometric(1/mean_outage)
            p_crash = rng.rand(T, R)
            p_len = rng.rand(T, R)
            for r in range(R):
                t = 0
                while t < T:
                    if p_crash[t, r] < self.crash_rate:
                        u = max(p_len[t, r], 1e-12)
                        length = 1 + int(np.floor(
                            np.log(u) / np.log(1.0 - 1.0 /
                                               max(self.mean_outage, 1.0))
                        )) if self.mean_outage > 1.0 else 1
                        alive[t:t + length, r] = False
                        t += length
                    else:
                        t += 1
        for w, c, rec in ((int(a), int(b), int(d)) for a, b, d in self.crash):
            if w < R:
                alive[min(c, T):min(rec, T), w] = False
        recover = np.zeros((T, R), bool)
        recover[1:] = alive[1:] & ~alive[:-1]
        return FaultTables(delay=delay, alive=alive, recover=recover,
                           drop=drop)

    # ---- spec string surface --------------------------------------------

    def to_string(self) -> str:
        """Canonical ``k=v,...`` spec string (round-trips via
        :func:`parse_faults`)."""
        parts = []
        for f in dataclasses.fields(self):
            v = getattr(self, f.name)
            if v == f.default:
                continue
            if f.name == "crash":
                parts.append("crash=" + "+".join(
                    f"{int(r)}@{int(c)}-{int(rec)}" for r, c, rec in v))
            else:
                parts.append(f"{f.name}={v}")
        return ",".join(parts)


#: named fault presets (``--faults preset:<name>``)
FAULT_PRESETS = {
    # the fault-free harness: trivial tables, pins the bit-exactness of
    # the fault runtime against the fault-free one (satellite S1)
    "none": FaultSpec(),
    # staleness only: every payload 0-3 steps late
    "delayed": FaultSpec(max_delay=3, seed=1),
    # staleness + in-flight loss
    "lossy": FaultSpec(max_delay=2, drop=0.1, seed=2),
    # random crash/recover churn on top of delays
    "crashy": FaultSpec(max_delay=2, crash_rate=0.02, mean_outage=6.0,
                        seed=3),
    # the CI fault-smoke profile: one deterministic crash/recover window
    # plus random delays and drops — every fault class exercised
    "chaos": FaultSpec(max_delay=3, drop=0.05,
                       crash=((1, 2, 5),), crash_rate=0.01,
                       mean_outage=4.0, seed=5),
}


def parse_faults(spec) -> FaultSpec:
    """A FaultSpec from a spec string, preset name, or FaultSpec.

    Accepts ``"preset:<name>"`` (see :data:`FAULT_PRESETS`), a
    ``k=v,...`` string (``"max_delay=3,drop=0.1,seed=2"``, with crash
    windows as ``crash=r@c-rec+r2@c2-rec2``), or an existing
    :class:`FaultSpec` (returned as-is).
    """
    if isinstance(spec, FaultSpec):
        return spec
    if not isinstance(spec, str):
        raise TypeError(f"fault spec must be a FaultSpec or str, "
                        f"got {type(spec).__name__}")
    s = spec.strip()
    if s.startswith("preset:"):
        name = s[len("preset:"):]
        try:
            return FAULT_PRESETS[name]
        except KeyError:
            raise KeyError(
                f"unknown fault preset {name!r}; available: "
                f"{sorted(FAULT_PRESETS)}") from None
    kwargs = {}
    fields = {f.name: f for f in dataclasses.fields(FaultSpec)}
    for item in filter(None, (p.strip() for p in s.split(","))):
        if "=" not in item:
            raise ValueError(f"bad fault item {item!r}: expected k=v")
        k, v = (x.strip() for x in item.split("=", 1))
        if k not in fields:
            raise KeyError(f"unknown fault field {k!r}; available: "
                           f"{sorted(fields)}")
        if k == "crash":
            windows = []
            for win in filter(None, v.split("+")):
                r, _, span = win.partition("@")
                c, _, rec = span.partition("-")
                windows.append((int(r), int(c), int(rec)))
            kwargs[k] = tuple(windows)
        elif k in ("max_delay", "min_delay", "seed"):
            kwargs[k] = int(v)
        else:
            kwargs[k] = float(v)
    return FaultSpec(**kwargs)


#: staleness weighting modes for arriving payloads (``--staleness-weight``)
STALENESS_WEIGHTS = ("uniform", "damped")


def validate_staleness_weight(mode: str) -> str:
    if mode not in STALENESS_WEIGHTS:
        raise ValueError(
            f"unknown staleness weight {mode!r}; expected one of "
            f"{STALENESS_WEIGHTS}")
    return mode


def fault_replay(mask, tables: FaultTables):
    """Host-side replay of the fault schedule's *event structure*.

    Returns ``(computed, arrivals, events)``:

    * ``computed [T, R]`` — worker r computes a payload at t
      (scheduled sync AND alive);
    * ``arrivals [T, R]`` — int32 count of payloads *from* worker r
      applied to the master at t (computed at some t' <= t with
      t' + delay == t, not dropped; two payloads from one worker can
      land on the same step; payloads whose arrival lands past T-1
      stay in flight);
    * ``events [T]`` — steps where master/ledger state can change or a
      scheduled sync fires: any scheduled sync row (even with every
      worker crashed — the empty round stays a History round) or any
      arrival.  The round program must close rounds exactly at these
      steps (``rounds.compile_fault_rounds``).
    """
    m = np.asarray(mask, bool)
    if m.ndim == 1:
        m = np.broadcast_to(m[:, None], (m.shape[0], tables.alive.shape[1]))
    T, R = m.shape
    computed = m & tables.alive[:T]
    arrivals = np.zeros((T, R), np.int32)
    src = computed & ~tables.drop[:T]
    for t, r in zip(*np.nonzero(src)):
        a = t + int(tables.delay[t, r])
        if a < T:
            arrivals[a, r] += 1
    events = m.any(axis=1) | (arrivals > 0).any(axis=1)
    return computed, arrivals, events

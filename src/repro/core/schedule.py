"""Synchronization schedules: the paper's index sets I_T and gap(I_T).

Synchronous (Algorithm 1): a single I_T shared by all workers.
Asynchronous (Algorithm 2): per-worker I_T^{(r)} with gap(I_T^{(r)}) <= H;
the paper's experiments draw each worker's next sync offset uniformly
from [1, H] after every sync -- we reproduce exactly that.

Schedules are materialized as boolean masks so they can be consumed
inside jit (via indexing with the step counter) and inspected by tests.
"""

from __future__ import annotations

import numpy as np


def gap(indices) -> int:
    """gap(I_T) = max difference between consecutive sync indices
    (Definition 4).  ``indices`` are 1-based step indices t with t in I_T."""
    idx = sorted(int(i) for i in indices)
    if not idx:
        return 0
    prev = 0
    g = 0
    for t in idx:
        g = max(g, t - prev)
        prev = t
    return g


def fixed_schedule(T: int, H: int) -> np.ndarray:
    """Synchronous: sync at t+1 in {H, 2H, ...} union {T}.

    Returns a bool mask of length T: mask[t] == True iff (t+1) in I_T.
    """
    mask = np.zeros(T, dtype=bool)
    for t in range(T):
        if (t + 1) % H == 0:
            mask[t] = True
    mask[T - 1] = True  # paper requires T in I_T
    return mask


def schedule_from_indices(T: int, indices) -> np.ndarray:
    mask = np.zeros(T, dtype=bool)
    for i in indices:
        if 1 <= i <= T:
            mask[i - 1] = True
    mask[T - 1] = True
    return mask


def async_schedule(T: int, R: int, H: int, seed: int = 0) -> np.ndarray:
    """Asynchronous: per-worker masks, next sync drawn U[1, H] after each
    sync (paper Section 5.2.3).  Returns bool mask [T, R]."""
    rng = np.random.RandomState(seed)
    mask = np.zeros((T, R), dtype=bool)
    for r in range(R):
        t = 0
        while True:
            step = int(rng.randint(1, H + 1))
            t += step
            if t > T:
                break
            mask[t - 1, r] = True
        mask[T - 1, r] = True
    return mask


def worker_gaps(mask: np.ndarray) -> list[int]:
    """gap(I_T^{(r)}) per worker for an async [T, R] mask."""
    T, R = mask.shape
    out = []
    for r in range(R):
        idx = [t + 1 for t in range(T) if mask[t, r]]
        out.append(gap(idx))
    return out

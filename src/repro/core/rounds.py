"""Schedule compiler for the round-program runtime (DESIGN.md §7).

Local computation is the headline of Qsparse-local-SGD: between
error-compensated syncs every worker takes H uncommunicated steps, yet
a per-step host loop pays one dispatch (plus a loss transfer) for each
of them — the cheapest phase of the algorithm carries the most host
overhead.  The round runtime inverts that: a *round* is a maximal run
of steps none of which syncs, closed by the first step where any
worker's sync mask fires (or by the end of the schedule), and each
round executes as ONE compiled program — ``lax.scan`` over the local
phase with the batch block as xs, the sync phase once at the tail
(``engine.make_superstep``).

This module is the pure-host half: it segments any sync schedule —
shared ``[T]`` masks (Algorithm 1), per-worker ``[T, R]`` masks
(Algorithm 2), staggered round-robin, arbitrary mixtures — into
:class:`RoundPlan`\\ s.  The segmentation is exactly invertible
(:func:`expand_rounds`), which the property tests pin: concatenating
the plans reproduces the original mask bit for bit, including trailing
partial rounds that never sync.

Plan format
-----------
``RoundPlan(start, length, mask)``:

* ``start``  — 0-based global step index of the round's first step;
* ``length`` — number of steps in the round (≥ 1).  Steps
  ``start .. start+length-2`` are pure-local (their mask rows are all
  False by construction); step ``start+length-1`` is the tail;
* ``mask``   — the tail step's sync row, shape ``[R]`` (or the scalar
  the caller's ``[T]`` mask carried).  All-False for a trailing
  partial round, in which case the tail is a pure-local step too and
  the compiled program's ``lax.cond`` skips the sync phase — no
  separate compilation.

Rounds of equal ``length`` share one XLA executable (the tail mask is
data, not structure), so a fixed-H schedule compiles at most twice
(H and the trailing partial length) and a random async schedule at
most ``max gap`` times.
"""

from __future__ import annotations

from typing import NamedTuple, Sequence

import numpy as np


class RoundPlan(NamedTuple):
    start: int          # global step index of the round's first step
    length: int         # steps in the round (head locals + tail)
    mask: np.ndarray    # tail-step sync row, bool[R] (or scalar bool)

    @property
    def syncs(self) -> bool:
        """Does any worker sync at this round's tail step?"""
        return bool(np.any(self.mask))

    @property
    def stop(self) -> int:
        """One past the round's last global step index."""
        return self.start + self.length


def _as_rows(mask) -> tuple[np.ndarray, bool]:
    """Normalize a [T] or [T, R] mask to [T, R'] rows + whether the
    caller's rows were scalar (shared/Algorithm-1 form)."""
    m = np.asarray(mask, dtype=bool)
    if m.ndim == 1:
        return m[:, None], True
    if m.ndim != 2:
        raise ValueError(
            f"sync mask must be [T] or [T, R], got shape {m.shape}")
    return m, False


def compile_rounds(mask) -> list[RoundPlan]:
    """Segment a sync schedule into round plans.

    ``mask`` is bool ``[T]`` (shared I_T) or ``[T, R]`` (per-worker
    I_T^{(r)}).  A round closes at every step where *any* worker syncs
    — the engine's sync phase runs whenever ``any(s)`` — so by
    construction every non-tail row of every plan is all-False.  Steps
    after the schedule's last sync form one trailing partial round
    whose tail mask is all-False.
    """
    rows, scalar = _as_rows(mask)
    T = rows.shape[0]
    plans: list[RoundPlan] = []
    start = 0
    any_sync = rows.any(axis=1)
    for t in range(T):
        if any_sync[t]:
            tail = rows[t, 0] if scalar else rows[t].copy()
            plans.append(RoundPlan(start, t - start + 1, np.asarray(tail)))
            start = t + 1
    if start < T:  # trailing partial round: never syncs
        tail = (np.zeros((), bool) if scalar
                else np.zeros(rows.shape[1], bool))
        plans.append(RoundPlan(start, T - start, tail))
    return plans


def expand_rounds(plans: Sequence[RoundPlan], R: int | None = None
                  ) -> np.ndarray:
    """Inverse of :func:`compile_rounds`: rebuild the full [T] / [T, R]
    mask the plans were compiled from (the property the tests pin).

    ``R`` overrides the worker count when the plans carry scalar tail
    masks but the caller wants the broadcast [T, R] form.
    """
    if not plans:
        shape = (0,) if R is None else (0, R)
        return np.zeros(shape, bool)
    T = plans[-1].stop
    tail0 = np.asarray(plans[0].mask)
    if tail0.ndim == 0 and R is None:
        out = np.zeros(T, bool)
    else:
        Rr = tail0.shape[0] if tail0.ndim else R
        out = np.zeros((T, Rr), bool)
    pos = 0
    for p in plans:
        if p.start != pos:
            raise ValueError(
                f"plans are not contiguous: expected start {pos}, "
                f"got {p.start}")
        if p.length < 1:
            raise ValueError(f"round of length {p.length} at step {p.start}")
        out[p.stop - 1] = np.asarray(p.mask)
        pos = p.stop
    return out


def compile_fault_rounds(mask, tables, extra_events=None) -> list[RoundPlan]:
    """Segment a sync schedule *under faults* into round plans.

    With a :class:`~repro.core.scenarios.FaultSpec` active, master and
    ledger state can change at steps beyond the scheduled syncs: a
    payload computed at t lands at t+τ, so its *arrival* step is an
    event even when no worker syncs there.  Rounds must close at every
    event step — any scheduled sync row (even one where every worker is
    crashed: the empty round still gets its History entry) or any
    payload arrival — so the round program's heads stay pure-local and
    the trainer's per-round ledger snapshots stay exact.

    ``mask`` is the bool ``[T]``/``[T, R]`` sync schedule and ``tables``
    the expanded :class:`~repro.core.scenarios.FaultTables`.  The
    returned plans carry the *original* tail sync rows (the engine's
    fault superstep takes the full per-step fault rows separately);
    trailing no-event steps form the usual partial round.  With trivial
    tables the segmentation is exactly :func:`compile_rounds`.

    ``extra_events``: additional step indices to close rounds at —
    arrival steps of payloads already in flight when this schedule
    window starts (a crash-consistent resume mid-trajectory restores a
    non-empty queue whose arrivals the window's own replay can't see).
    """
    from repro.core import scenarios as scn  # local: avoid import cycle

    rows, scalar = _as_rows(mask)
    _, _, events = scn.fault_replay(rows, tables)
    if extra_events is not None:
        events = events.copy()
        for e in extra_events:
            if 0 <= int(e) < events.shape[0]:
                events[int(e)] = True
    T = rows.shape[0]
    plans: list[RoundPlan] = []
    start = 0
    for t in range(T):
        if events[t]:
            tail = rows[t, 0] if scalar else rows[t].copy()
            plans.append(RoundPlan(start, t - start + 1, np.asarray(tail)))
            start = t + 1
    if start < T:
        tail = (np.zeros((), bool) if scalar
                else np.zeros(rows.shape[1], bool))
        plans.append(RoundPlan(start, T - start, tail))
    return plans


def round_lengths(plans: Sequence[RoundPlan]) -> list[int]:
    """Distinct round lengths, in first-appearance order — one XLA
    compilation of the superstep per entry."""
    seen: list[int] = []
    for p in plans:
        if p.length not in seen:
            seen.append(p.length)
    return seen


def window_rounds(plans: Sequence[RoundPlan], max_window: int = 8,
                  boundary_steps: Sequence[int] = ()
                  ) -> list[list[RoundPlan]]:
    """Group consecutive round plans into dispatch windows for the
    overlapped round driver (``engine.run_rounds_overlap``, DESIGN.md
    §10): each window executes as ONE scanned multi-round program, so
    the device queue always holds the next round's local phase while
    the current round's sync collective completes, and the host pays
    one dispatch per window instead of one per round.

    Window rules — these are what keep the overlapped trajectories
    bit-for-bit the serialized driver's:

    * only consecutive plans of equal ``length`` share a window (the
      stacked batch blocks and tail masks must be rectangular; the key
      stream threads through the scan exactly as through back-to-back
      superstep calls either way);
    * a plan containing any step in ``boundary_steps`` (0-based, in the
      plans' own index space) is a singleton window — the caller needs
      the materialized state at that point (eval / checkpoint / full
      snapshot reads), so the window must not scan past it;
    * runs chunk greedily into power-of-two sizes ≤ ``max_window``, so
      each distinct (window, length) pair costs at most one XLA
      compilation and a run of W equal rounds compiles O(log W)
      executables, not O(W).

    Returns a list of windows (each a non-empty list of contiguous
    plans); concatenating them reproduces ``plans`` exactly.
    """
    if max_window < 1:
        raise ValueError(f"max_window must be >= 1, got {max_window}")
    bounds = sorted(set(int(b) for b in boundary_steps))

    def has_boundary(p: RoundPlan) -> bool:
        return any(p.start <= b < p.stop for b in bounds)

    windows: list[list[RoundPlan]] = []
    run: list[RoundPlan] = []

    def flush():
        nonlocal run
        i = 0
        while i < len(run):
            w = 1
            while w * 2 <= min(max_window, len(run) - i):
                w *= 2
            windows.append(run[i:i + w])
            i += w
        run = []

    for p in plans:
        if has_boundary(p):
            flush()
            windows.append([p])
            continue
        if run and run[-1].length != p.length:
            flush()
        run.append(p)
    flush()
    return windows

"""Unified Qsparse-local-SGD engine (paper Algorithms 1 and 2 as one
state machine; see DESIGN.md §1).

The paper presents a synchronous algorithm (one shared sync index set
I_T) and an asynchronous one (per-worker I_T^{(r)}); the repo used to
implement them twice.  This engine keeps ONE step function over the
generalized per-worker sync mask

    s ∈ {0,1}^R,   s_r = [t+1 ∈ I_T^{(r)}],

with per-worker master *views* x_t^{(r)} (the last broadcast worker r
received).  Algorithm 1 is the special case where all s_r agree — then
every view equals the true master at all times and the masked update
reduces exactly to the shared-I_T math.  Algorithm 2 is the general
case.  Per step t:

  x̂_{t+1/2}^{(r)} = x̂_t^{(r)} - eta_t d_t^{(r)}            (local phase)
  r with s_r = 0:  keep (x^{(r)}, m^{(r)});  x̂_{t+1}^{(r)} = x̂_{t+1/2}^{(r)}
  r with s_r = 1:  g_t^{(r)} = QComp_k(m_t^{(r)} + x_t^{(r)} - x̂_{t+1/2}^{(r)})
                   m_{t+1}^{(r)} = m_t^{(r)} + x_t^{(r)} - x̂_{t+1/2}^{(r)} - g
  master:          x̄_{t+1} = x̄_t - (1/R) Σ_{r: s_r} g_t^{(r)}
  r with s_r = 1:  x_{t+1}^{(r)} = x̂_{t+1}^{(r)} = x̄_{t+1}       (broadcast)

Both directions of the wire are first-class *channels* (DESIGN.md §5,
``core/channel.py``): the uplink above, and an optional **compressed
downlink** — instead of broadcasting x̄_{t+1} dense, the server
compresses the per-worker master delta with its own error memory
md^{(r)} (Double Quantization / error-compensated broadcast):

  r with s_r = 1:  q_t^{(r)}  = DComp(md_t^{(r)} + x̄_{t+1} - x_t^{(r)})
                   md_{t+1}^{(r)} = md_t^{(r)} + x̄_{t+1} - x_t^{(r)} - q
                   x_{t+1}^{(r)} = x̂_{t+1}^{(r)} = x_t^{(r)} + q_t^{(r)}

With ``downlink=None`` (or Identity) the broadcast stays the exact
assignment above — bit-for-bit the historical trajectories — and the
downlink ledger charges the dense broadcast cost the uplink-only
ledger used to omit.  ``state.bits`` stays uplink-only; the downlink
accumulates in ``state.bits_down`` (``channel.wire_ledger`` totals).

Compression routes through ``kernels.dispatch``: eligible (operator,
leaf) pairs execute the fused Pallas kernels — megabuffer-packed so a
sync round costs one kernel launch per operator family *per
direction*, not one per leaf (DESIGN.md §3.4) — everything else the
dense reference operators; same outputs, same wire-bit ledger either
way.

When no worker syncs (any(s) == False) the whole sync phase is skipped
via ``lax.cond``, so pure-local steps never pay for compression.

``core/qsparse.py`` and ``core/async_qsparse.py`` are thin wrappers
over this engine preserving their historical APIs; ``train/trainer.py``
drives it directly with a [T, R] mask.
"""

from __future__ import annotations

import warnings
from typing import Any, Callable, NamedTuple, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import channel as chn
from repro.core.operators import CompressionOp
from repro.kernels import dispatch as dsp
from repro.optim.transforms import GradientTransform, apply_updates



class EngineState(NamedTuple):
    master: Any           # x̄_t — the true master parameters
    master_view: Any      # x_t^{(r)}: last master copy worker r received [R]
    local: Any            # x̂_t^{(r)} [R]
    memory: Any           # m_t^{(r)} uplink error-feedback memory [R]
    inner: Any            # inner-optimizer state per worker [R]
    step: jnp.ndarray     # int32 global clock t
    bits: jnp.ndarray     # float32 cumulative UPLINK wire bits (Σ workers)
    rounds: jnp.ndarray   # int32 — see ``global_rounds`` in make_step
    # downlink channel state (DESIGN.md §5); down_memory is the
    # server-side per-worker error memory md^{(r)} [R] — None unless a
    # compressed downlink is configured (init(..., downlink=op))
    down_memory: Any = None
    bits_down: Any = None  # float32 cumulative DOWNLINK wire bits
    # per-leaf-group ledger (DESIGN.md §6): cumulative wire bits per
    # top-level parameter group, [G] f32 per direction — None unless
    # init/make_step were built with leaf_ledger=True.  Group names
    # come from ``leaf_group_names(params)``.
    leaf_bits: Any = None
    leaf_bits_down: Any = None


def replicate(tree, R: int):
    """Broadcast a pytree to a leading worker axis of size R."""
    return jax.tree_util.tree_map(
        lambda x: jnp.broadcast_to(x[None], (R,) + x.shape), tree
    )


def leaf_group_names(params) -> tuple:
    """Top-level parameter-group names of the per-leaf ledger, in the
    order ``state.leaf_bits``/``leaf_bits_down`` index them."""
    from repro.core.policy import leaf_groups
    return leaf_groups(params)[0]


def init(params, inner_opt: GradientTransform, R: int,
         downlink=None, leaf_ledger: bool = False) -> EngineState:
    """``downlink``: the server→worker compression operator (or
    Channel) this state will be stepped with — needed here only to
    allocate the server-side error memory; None/Identity allocates
    nothing (the exact-broadcast path is memoryless).

    ``leaf_ledger``: allocate the optional per-top-level-leaf-group
    wire-bit ledgers ([G] f32 per direction, G = number of top-level
    parameter groups) — pass the same flag to :func:`make_step`.
    """
    local = replicate(params, R)
    down = chn.as_channel(downlink, "downlink")
    G = len(leaf_group_names(params)) if leaf_ledger else 0
    return EngineState(
        # own copies: the state is donated by engine.run/run_rounds, so
        # master may not alias the caller's params and master_view may
        # not alias local (one buffer cannot fill two donated slots)
        master=jax.tree_util.tree_map(jnp.copy, params),
        master_view=jax.tree_util.tree_map(jnp.copy, local),
        local=local,
        memory=jax.tree_util.tree_map(jnp.zeros_like, local),
        inner=jax.vmap(inner_opt.init)(local),
        step=jnp.zeros((), jnp.int32),
        bits=jnp.zeros((), jnp.float32),
        rounds=jnp.zeros((), jnp.int32),
        down_memory=(None if down.is_identity()
                     else down.init_memory(local)),
        bits_down=jnp.zeros((), jnp.float32),
        leaf_bits=jnp.zeros((G,), jnp.float32) if leaf_ledger else None,
        leaf_bits_down=(jnp.zeros((G,), jnp.float32) if leaf_ledger
                        else None),
    )


def _make_local_phase(grad_fn: Callable, inner_opt: GradientTransform,
                      lr_schedule: Callable):
    """The per-step local phase (Algorithm 1/2 lines 5-7), shared by the
    per-step ``make_step`` and the scanned ``make_superstep``."""

    def local_phase(state: EngineState, batch):
        lr = lr_schedule(state.step)

        def one(params, inner, data):
            loss, grads = grad_fn(params, data)
            updates, inner = inner_opt.update(grads, inner, params, lr)
            return apply_updates(params, updates), inner, loss

        return jax.vmap(one)(state.local, state.inner, batch)

    return local_phase


def make_step(
    grad_fn: Callable,               # (params, batch) -> (loss, grads)
    inner_opt: GradientTransform,
    operator: CompressionOp | Any,   # op or tree-of-ops (Corollary 1)
    lr_schedule: Callable,
    R: int,
    *,
    dispatch: Optional[dsp.DispatchConfig] = None,
    global_rounds: bool = False,
    downlink=None,
    leaf_ledger: bool = False,
    aggregate: str = "mean_R",
):
    """Build the jittable unified step.

    grad_fn must accept per-worker params and a per-worker batch and
    return (loss, grads) — it is vmapped over the R axis.

    The built step takes ``(state, batch, sync_mask, key)`` where
    ``sync_mask`` is bool[R] (a scalar broadcasts): which workers hit a
    sync index at t+1.

    global_rounds: what ``state.rounds`` counts — True: master rounds
    (+1 whenever any worker syncs; Algorithm-1 bookkeeping), False:
    worker sync events (+Σ s_r; Algorithm-2 bookkeeping).

    aggregate: how the master divides the syncing subset's payload sum
    (DESIGN.md §8) — "mean_R" is the paper's Σ/R (bit-for-bit the
    historical trajectories; under partial participation it scales
    updates down by |S|/R — see ``scenarios.warn_if_biased``),
    "mean_S" divides by the syncing-subset size |S| (≡ mean_R when all
    R workers sync), "support_weighted" divides each coordinate by its
    survivor count — the number of syncing workers whose compressed
    payload carried that coordinate — so sparse payloads don't dilute
    each other; zero-support coordinates keep the master value (the
    payload sum is exactly 0 there and the ``max(count, 1)`` guard
    makes the quotient 0).  With Identity compression every syncing
    worker supports every coordinate, so support_weighted ≡ mean_S.

    downlink: server→worker compression — an operator (or tree, or
    ``channel.Channel``) applied to the per-worker master delta with a
    server-side error memory (state.down_memory; pass the same
    ``downlink`` to :func:`init`).  None/Identity keeps the exact
    dense broadcast (bit-for-bit historical trajectories) and charges
    its dense cost to ``state.bits_down``.

    leaf_ledger: accumulate the per-top-level-leaf-group wire-bit
    ledgers (``state.leaf_bits`` / ``state.leaf_bits_down``, indexed by
    ``leaf_group_names``) so heterogeneous policies can be compared on
    the paper's bits x-axis per layer group, not just in aggregate.
    Pure accounting: trajectories are unchanged.
    """
    from repro.core.scenarios import validate_aggregate
    validate_aggregate(aggregate)
    up_ch = (operator if isinstance(operator, chn.Channel)
             else chn.Channel(operator, "uplink", dispatch))
    down_ch = chn.as_channel(downlink, "downlink", dispatch)
    compressed_down = not down_ch.is_identity()

    local_phase = _make_local_phase(grad_fn, inner_opt, lr_schedule)

    def sync_phase(state: EngineState, half, inner, sync_mask, key):
        """Masked compress-and-aggregate (Algorithm 1/2 lines 8-20)."""
        if leaf_ledger:
            from repro.core.policy import leaf_groups
            _gnames, gidx = leaf_groups(state.master)
            seg = jnp.asarray(gidx, jnp.int32)
            G = len(_gnames)

        def group_bits(per_leaf_bits, s_r):
            """Per-leaf bits (flatten order) → masked [G] group vector."""
            vec = jax.ops.segment_sum(
                jnp.stack([jnp.asarray(b, jnp.float32)
                           for b in per_leaf_bits]),
                seg, num_segments=G)
            return jnp.where(s_r, vec, jnp.zeros_like(vec))

        def worker_update(m_r, view_r, half_r, key_r, s_r):
            acc = jax.tree_util.tree_map(
                lambda m, x, h: m + x.astype(jnp.float32)
                - h.astype(jnp.float32),
                m_r, view_r, half_r,
            )
            if leaf_ledger:
                g, m_out, bits, lb = up_ch.apply(key_r, acc, per_leaf=True)
                gvec = group_bits(lb, s_r)
            else:
                g, m_out, bits = up_ch.apply(key_r, acc)
                gvec = jnp.zeros((0,), jnp.float32)
            # masked: non-syncing workers transmit nothing and keep state
            g = jax.tree_util.tree_map(
                lambda gg: jnp.where(s_r, gg, jnp.zeros_like(gg)), g
            )
            new_m = jax.tree_util.tree_map(
                lambda m, mm: jnp.where(s_r, mm, m), m_r, m_out
            )
            return g, new_m, jnp.where(s_r, bits, 0.0), gvec

        keys = jax.random.split(key, R)
        g_all, new_mem, bits_all, gvec_all = jax.vmap(worker_update)(
            state.memory, state.master_view, half, keys, sync_mask
        )
        new_leaf_bits = (state.leaf_bits + jnp.sum(gvec_all, axis=0)
                         if leaf_ledger else state.leaf_bits)
        # master divides the syncing subset's payload sum per
        # ``aggregate`` (module docstring / DESIGN.md §8)
        if aggregate == "mean_R":
            # the paper's (1/R) Σ over S — the exact historical
            # expression, kept verbatim for bit-for-bit trajectories
            g_sum = jax.tree_util.tree_map(
                lambda g: jnp.sum(g, axis=0) / R, g_all
            )
        elif aggregate == "mean_S":
            # |S| ≥ 1 here: the sync phase only runs when any(s)
            n_sync = jnp.maximum(
                jnp.sum(sync_mask.astype(jnp.float32)), 1.0)
            g_sum = jax.tree_util.tree_map(
                lambda g: jnp.sum(g, axis=0) / n_sync, g_all
            )
        else:  # support_weighted: per-coordinate survivor count
            # (g is already zero-masked for non-syncing workers, so the
            # count only sees syncing payloads; where it is 0 the
            # numerator is exactly 0 too — master keeps its value)
            g_sum = jax.tree_util.tree_map(
                lambda g: jnp.sum(g, axis=0) / jnp.maximum(
                    jnp.sum((g != 0).astype(jnp.float32), axis=0), 1.0),
                g_all
            )
        new_master = jax.tree_util.tree_map(
            lambda x, g: (x.astype(jnp.float32) - g).astype(x.dtype),
            state.master, g_sum,
        )

        def sel(new, old):
            shape = (R,) + (1,) * (new.ndim - 1)
            return jnp.where(sync_mask.reshape(shape), new, old)

        if compressed_down:
            # downlink channel: the server compresses each syncing
            # worker's master delta against its per-worker error memory
            # md^{(r)}; only q crosses the wire, so the worker's view
            # (and local iterate) advances by the *decompressed* delta
            def down_update(dm_r, view_r, half_r, key_r, s_r):
                acc = jax.tree_util.tree_map(
                    lambda dm, v, nm: dm + nm.astype(jnp.float32)
                    - v.astype(jnp.float32),
                    dm_r, view_r, new_master,
                )
                if leaf_ledger:
                    q, dm_out, dbits, dlb = down_ch.apply(
                        key_r, acc, per_leaf=True)
                    dgvec = group_bits(dlb, s_r)
                else:
                    q, dm_out, dbits = down_ch.apply(key_r, acc)
                    dgvec = jnp.zeros((0,), jnp.float32)
                new_v = jax.tree_util.tree_map(
                    lambda v, qq: jnp.where(
                        s_r, (v.astype(jnp.float32) + qq).astype(v.dtype),
                        v),
                    view_r, q,
                )
                new_dm = jax.tree_util.tree_map(
                    lambda dm, mm: jnp.where(s_r, mm, dm), dm_r, dm_out
                )
                new_l = jax.tree_util.tree_map(
                    lambda nv, h: jnp.where(s_r, nv.astype(h.dtype), h),
                    new_v, half_r,
                )
                return (new_v, new_dm, new_l, jnp.where(s_r, dbits, 0.0),
                        dgvec)

            # uplink keys stay exactly jax.random.split(key, R) (bit
            # compat); downlink draws an independent stream per worker
            down_keys = jax.vmap(
                lambda kk: jax.random.fold_in(kk, 0x0d0b))(keys)
            (new_view, new_down_mem, new_local, dbits_all,
             dgvec_all) = jax.vmap(down_update)(
                state.down_memory, state.master_view, half, down_keys,
                sync_mask)
            down_bits = state.bits_down + jnp.sum(dbits_all)
            new_leaf_down = (
                state.leaf_bits_down + jnp.sum(dgvec_all, axis=0)
                if leaf_ledger else state.leaf_bits_down)
        else:
            # exact broadcast (historical path, bit-for-bit): workers in
            # S receive x̄_{t+1} verbatim; the ledger still charges the
            # dense per-receiver cost the wire would carry
            bcast = replicate(new_master, R)
            new_view = jax.tree_util.tree_map(sel, bcast,
                                              state.master_view)
            new_local = jax.tree_util.tree_map(sel, bcast, half)
            new_down_mem = state.down_memory
            n_sync = jnp.sum(sync_mask.astype(jnp.float32))
            down_bits = state.bits_down + (
                n_sync * down_ch.dense_bits(state.master))
            if leaf_ledger:
                # static per-group dense broadcast cost (per receiver)
                dense_vec = jnp.zeros((G,), jnp.float32).at[seg].add(
                    jnp.asarray(
                        [32.0 * l.size for l in
                         jax.tree_util.tree_leaves(state.master)],
                        jnp.float32))
                new_leaf_down = state.leaf_bits_down + n_sync * dense_vec
            else:
                new_leaf_down = state.leaf_bits_down

        inc = (jnp.any(sync_mask).astype(jnp.int32) if global_rounds
               else jnp.sum(sync_mask.astype(jnp.int32)))
        return EngineState(
            master=new_master,
            master_view=new_view,
            local=new_local,
            memory=new_mem,
            inner=inner,
            step=state.step + 1,
            bits=state.bits + jnp.sum(bits_all),
            rounds=state.rounds + inc,
            down_memory=new_down_mem,
            bits_down=down_bits,
            leaf_bits=new_leaf_bits,
            leaf_bits_down=new_leaf_down,
        )

    def step_fn(state: EngineState, batch, sync_mask, key):
        if compressed_down and state.down_memory is None:
            raise ValueError(
                "compressed downlink needs server-side error memory: "
                "initialize with engine.init(..., downlink=<op>)")
        if not compressed_down and state.down_memory is not None:
            raise ValueError(
                "state carries downlink error memory but this step was "
                "built without downlink=: pass the same downlink to "
                "make_step and init (or re-init without one)")
        if state.bits_down is None:  # states minted before the ledger split
            state = state._replace(bits_down=jnp.zeros((), jnp.float32))
        if leaf_ledger and state.leaf_bits is None:
            raise ValueError(
                "per-leaf ledger needs state fields: initialize with "
                "engine.init(..., leaf_ledger=True)")
        sync_mask = jnp.broadcast_to(
            jnp.asarray(sync_mask, bool).reshape(-1), (R,)
        )
        half, inner, losses = local_phase(state, batch)

        def no_sync(_):
            return EngineState(
                master=state.master,
                master_view=state.master_view,
                local=half,
                memory=state.memory,
                inner=inner,
                step=state.step + 1,
                bits=state.bits,
                rounds=state.rounds,
                down_memory=state.down_memory,
                bits_down=state.bits_down,
                leaf_bits=state.leaf_bits,
                leaf_bits_down=state.leaf_bits_down,
            )

        new_state = jax.lax.cond(
            jnp.any(sync_mask),
            lambda _: sync_phase(state, half, inner, sync_mask, key),
            no_sync,
            operand=None,
        )
        return new_state, jnp.mean(losses)

    return step_fn


def make_superstep(
    grad_fn: Callable,               # (params, batch) -> (loss, grads)
    inner_opt: GradientTransform,
    operator: CompressionOp | Any,
    lr_schedule: Callable,
    R: int,
    *,
    dispatch: Optional[dsp.DispatchConfig] = None,
    global_rounds: bool = False,
    downlink=None,
    leaf_ledger: bool = False,
    aggregate: str = "mean_R",
):
    """Build the round program (DESIGN.md §7): one compiled function per
    sync round — ``lax.scan`` over the local phase with the round's
    batch block as xs, the sync phase once at the tail.

    The built superstep takes ``(state, batch_block, tail_mask, key)``
    where ``batch_block`` stacks the round's L per-step batches on a new
    leading axis ([L, R, ...] leaves) and ``tail_mask`` is the tail
    step's sync row (bool[R]; a scalar broadcasts; all-False for a
    trailing partial round — the sync phase is then skipped by the same
    ``lax.cond`` the per-step path uses).  It returns
    ``(new_state, losses, key)`` with ``losses`` the [L] per-step mean
    losses (one device→host fetch per round) and ``key`` the advanced
    PRNG key.

    Bit-for-bit contract: the key is split *inside* the program with
    exactly the per-step host loop's sequence (one split per step, the
    subkey consumed only by the sync phase), and the scanned local body
    is the no-sync branch of the per-step ``lax.cond`` verbatim — so
    superstep trajectories equal per-step trajectories on every state
    leaf and every ledger, for any schedule.  Jit with the state
    donated (``donate_argnums=0``) to update the EngineState buffers in
    place; :func:`run_rounds` does both.
    """
    step_fn = make_step(
        grad_fn, inner_opt, operator, lr_schedule, R, dispatch=dispatch,
        global_rounds=global_rounds, downlink=downlink,
        leaf_ledger=leaf_ledger, aggregate=aggregate)
    local_phase = _make_local_phase(grad_fn, inner_opt, lr_schedule)

    def superstep(state: EngineState, batch_block, tail_mask, key):
        if state.bits_down is None:  # states minted before the ledger split
            state = state._replace(bits_down=jnp.zeros((), jnp.float32))

        def body(carry, batch):
            state, key = carry
            # same stream as the host loop: split per step, subkey
            # unused on pure-local steps (the sync phase is the only
            # consumer), carried key advances identically
            key, _sub = jax.random.split(key)
            half, inner, losses = local_phase(state, batch)
            state = state._replace(local=half, inner=inner,
                                   step=state.step + 1)
            return (state, key), jnp.mean(losses)

        head = jax.tree_util.tree_map(lambda x: x[:-1], batch_block)
        tail = jax.tree_util.tree_map(lambda x: x[-1], batch_block)
        (state, key), head_losses = jax.lax.scan(body, (state, key), head)
        key, sub = jax.random.split(key)
        state, tail_loss = step_fn(state, tail, tail_mask, sub)
        return state, jnp.concatenate([head_losses, tail_loss[None]]), key

    return superstep


def donated_jit(fn):
    """``jax.jit`` with the first argument (the state) donated.

    On backends without buffer aliasing, donation degrades to copies
    and jax warns per executable; the suppression here is scoped to
    *these* calls (not a process-global filter), so unrelated donated
    jits elsewhere keep their diagnostic.  The raw jitted function is
    exposed as ``.jitted``.
    """
    jfn = jax.jit(fn, donate_argnums=(0,))
    if _donation_supported():
        try:
            jfn.jitted = jfn  # uniform surface with the filtered wrapper
            return jfn
        except AttributeError:
            pass  # non-writable jit object: fall through to the wrapper

    def call(*args, **kwargs):
        with warnings.catch_warnings():
            warnings.filterwarnings(
                "ignore", message="Some donated buffers were not usable")
            return jfn(*args, **kwargs)

    call.jitted = jfn
    return call


_DONATION_OK: Optional[bool] = None


def _donation_supported() -> bool:
    """Does this backend alias donated buffers (no per-compile 'not
    usable' warning)?  Probed once per process with a scalar jit, so
    the steady-state donated dispatch path carries no warnings-context
    overhead when — as on TPU and current CPU jaxlibs — donation
    simply works."""
    global _DONATION_OK
    if _DONATION_OK is None:
        with warnings.catch_warnings(record=True) as wlog:
            warnings.simplefilter("always")
            jax.jit(lambda x: x + 1, donate_argnums=(0,))(
                jnp.zeros(())).block_until_ready()
        _DONATION_OK = not any(
            "donated buffers were not usable" in str(w.message)
            for w in wlog)
    return _DONATION_OK


def _donated(fn, attr: str = "_donated_jit"):
    """One :func:`donated_jit` per step function, cached on the
    function itself so repeated ``run``/``run_rounds`` calls over the
    same step reuse one executable instead of re-tracing (and
    re-allocating) every call."""
    cached = getattr(fn, attr, None)
    if cached is None:
        cached = donated_jit(fn)
        try:
            setattr(fn, attr, cached)
        except AttributeError:  # non-writable callables: still jitted
            pass
    return cached


def run(
    state: EngineState,
    step_fn,
    batches,                      # iterable of [R, ...] batches
    sync_mask,                    # bool[T] (all-agree) or bool[T, R]
    key,
    jit: bool = True,
) -> tuple[EngineState, list[float]]:
    """Drive T steps (per-step host loop).

    The step is jitted once per ``step_fn`` with the EngineState
    donated — buffers update in place across steps on backends with
    aliasing — and per-step losses stay on device until the loop ends
    (one deferred fetch, not T synchronizing transfers).  ``jit=False``
    runs the identical loop and loss accounting eagerly.  The state
    argument is consumed: don't reuse the passed-in buffers afterwards.
    """
    fn = _donated(step_fn) if jit else step_fn
    losses = []
    for t, batch in enumerate(batches):
        key, sub = jax.random.split(key)
        state, loss = fn(state, batch, jnp.asarray(sync_mask[t]), sub)
        losses.append(loss)
    return state, [float(l) for l in losses]


def stack_block(step_batches):
    """Stack a round's per-step batches into one [L, ...] block."""
    return jax.tree_util.tree_map(
        lambda *xs: jnp.stack([jnp.asarray(x) for x in xs]), *step_batches)


def run_rounds(
    state: EngineState,
    superstep,                    # from make_superstep
    batches,                      # iterable of [R, ...] batches
    sync_mask,                    # bool[T] (all-agree) or bool[T, R]
    key,
    jit: bool = True,
) -> tuple[EngineState, list[float]]:
    """Drive a whole schedule as compiled round programs (DESIGN.md §7).

    Segments ``sync_mask`` into round plans (``core/rounds.py``), stacks
    each round's batches into one block, and runs each round as a single
    donated program.  Rounds of equal length share one executable; the
    per-step losses come back as one array per round and are fetched
    once at the end, and block assembly for round i+1 overlaps round i's
    device execution (async dispatch = free host-side prefetch).
    Trajectories are bit-for-bit the per-step path's (see
    :func:`make_superstep`).  The state argument is consumed.
    """
    from repro.core import rounds as rnd
    plans = rnd.compile_rounds(sync_mask)
    fn = _donated(superstep) if jit else superstep
    losses = []
    it = iter(batches)
    for plan in plans:
        steps = []
        for _ in range(plan.length):
            try:
                steps.append(next(it))
            except StopIteration:
                break
        if not steps:
            break
        # a truncated block (batch stream shorter than the schedule,
        # matching run()'s graceful stop) never reaches the plan's tail
        # step — the last step it does reach is mid-round, i.e. no-sync
        tail = (plan.mask if len(steps) == plan.length
                else np.zeros_like(plan.mask))
        state, ls, key = fn(state, stack_block(steps), jnp.asarray(tail),
                            key)
        losses.append(ls)
        if len(steps) < plan.length:
            break
    return state, [float(x) for ls in losses for x in np.asarray(ls)]


# ---------------------------------------------------------------------------
# fleet-scale worker axis (DESIGN.md §8)
# ---------------------------------------------------------------------------


def shard_worker_axis(state: EngineState, mesh, axis: str = "data"
                      ) -> EngineState:
    """Shard the state's leading worker axis over a mesh axis.

    The engine keeps the whole fleet on-device (one vmapped worker
    axis); past one device's memory, place the per-worker fields
    (local/memory/inner/master_view/down_memory) ``P(axis)`` and
    replicate the master and scalars — under jit the partitioner then
    runs the vmapped local phase worker-parallel and inserts one
    cross-device reduction for the sync-phase Σ over workers.  R must
    divide by the axis size.  Reduction order may differ from the
    single-device layout (same math, float-rounding level); for
    bit-pinned comparisons keep R on one device.
    """
    from jax.sharding import NamedSharding, PartitionSpec as P
    rep = NamedSharding(mesh, P())
    wrk = NamedSharding(mesh, P(axis))

    def put(tree, sh):
        if tree is None:
            return None
        return jax.tree_util.tree_map(
            lambda x: jax.device_put(x, sh), tree)

    return state._replace(
        master=put(state.master, rep),
        master_view=put(state.master_view, wrk),
        local=put(state.local, wrk),
        memory=put(state.memory, wrk),
        inner=put(state.inner, wrk),
        down_memory=put(state.down_memory, wrk),
    )


# ---------------------------------------------------------------------------
# diagnostics (Lemma 4/5/7/8 empirical quantities)
# ---------------------------------------------------------------------------


def memory_sq_norms(state) -> jnp.ndarray:
    """||m_t^{(r)}||_2^2 per worker (flattened over the whole pytree)."""
    leaves = jax.tree_util.tree_leaves(state.memory)
    return sum(
        jnp.sum(jnp.square(l.astype(jnp.float32)),
                axis=tuple(range(1, l.ndim)))
        for l in leaves
    )


def local_deviation_sq(state) -> jnp.ndarray:
    """(1/R) Σ_r ||x̄ - x̂^{(r)}||^2 (Lemma 7/8 quantity)."""
    def dev(leaf):
        mean = jnp.mean(leaf.astype(jnp.float32), axis=0, keepdims=True)
        return jnp.sum(jnp.square(leaf.astype(jnp.float32) - mean))

    total = sum(dev(l) for l in jax.tree_util.tree_leaves(state.local))
    R = jax.tree_util.tree_leaves(state.local)[0].shape[0]
    return total / R

"""Unified Qsparse-local-SGD engine (paper Algorithms 1 and 2 as one
state machine; see DESIGN.md §1).

The paper presents a synchronous algorithm (one shared sync index set
I_T) and an asynchronous one (per-worker I_T^{(r)}); the repo used to
implement them twice.  This engine keeps ONE step function over the
generalized per-worker sync mask

    s ∈ {0,1}^R,   s_r = [t+1 ∈ I_T^{(r)}],

with per-worker master *views* x_t^{(r)} (the last broadcast worker r
received).  Algorithm 1 is the special case where all s_r agree — then
every view equals the true master at all times and the masked update
reduces exactly to the shared-I_T math.  Algorithm 2 is the general
case.  Per step t:

  x̂_{t+1/2}^{(r)} = x̂_t^{(r)} - eta_t d_t^{(r)}            (local phase)
  r with s_r = 0:  keep (x^{(r)}, m^{(r)});  x̂_{t+1}^{(r)} = x̂_{t+1/2}^{(r)}
  r with s_r = 1:  g_t^{(r)} = QComp_k(m_t^{(r)} + x_t^{(r)} - x̂_{t+1/2}^{(r)})
                   m_{t+1}^{(r)} = m_t^{(r)} + x_t^{(r)} - x̂_{t+1/2}^{(r)} - g
  master:          x̄_{t+1} = x̄_t - (1/R) Σ_{r: s_r} g_t^{(r)}
  r with s_r = 1:  x_{t+1}^{(r)} = x̂_{t+1}^{(r)} = x̄_{t+1}       (broadcast)

Both directions of the wire are first-class *channels* (DESIGN.md §5,
``core/channel.py``): the uplink above, and an optional **compressed
downlink** — instead of broadcasting x̄_{t+1} dense, the server
compresses the per-worker master delta with its own error memory
md^{(r)} (Double Quantization / error-compensated broadcast):

  r with s_r = 1:  q_t^{(r)}  = DComp(md_t^{(r)} + x̄_{t+1} - x_t^{(r)})
                   md_{t+1}^{(r)} = md_t^{(r)} + x̄_{t+1} - x_t^{(r)} - q
                   x_{t+1}^{(r)} = x̂_{t+1}^{(r)} = x_t^{(r)} + q_t^{(r)}

With ``downlink=None`` (or Identity) the broadcast stays the exact
assignment above — bit-for-bit the historical trajectories — and the
downlink ledger charges the dense broadcast cost the uplink-only
ledger used to omit.  ``state.bits`` stays uplink-only; the downlink
accumulates in ``state.bits_down`` (``channel.wire_ledger`` totals).

Compression routes through ``kernels.dispatch``: eligible (operator,
leaf) pairs execute the fused Pallas kernels — megabuffer-packed so a
sync round costs one kernel launch per operator family *per
direction*, not one per leaf (DESIGN.md §3.4) — everything else the
dense reference operators; same outputs, same wire-bit ledger either
way.

When no worker syncs (any(s) == False) the whole sync phase is skipped
via ``lax.cond``, so pure-local steps never pay for compression.

``core/qsparse.py`` and ``core/async_qsparse.py`` are thin wrappers
over this engine preserving their historical APIs; ``train/trainer.py``
drives it directly with a [T, R] mask.
"""

from __future__ import annotations

import warnings
from typing import Any, Callable, NamedTuple, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import channel as chn
from repro.core.operators import CompressionOp
from repro.kernels import dispatch as dsp
from repro.optim.transforms import GradientTransform, apply_updates



class EngineState(NamedTuple):
    master: Any           # x̄_t — the true master parameters
    master_view: Any      # x_t^{(r)}: last master copy worker r received [R]
    local: Any            # x̂_t^{(r)} [R]
    memory: Any           # m_t^{(r)} uplink error-feedback memory [R]
    inner: Any            # inner-optimizer state per worker [R]
    step: jnp.ndarray     # int32 global clock t
    bits: jnp.ndarray     # float32 cumulative UPLINK wire bits (Σ workers)
    rounds: jnp.ndarray   # int32 — see ``global_rounds`` in make_step
    # downlink channel state (DESIGN.md §5); down_memory is the
    # server-side per-worker error memory md^{(r)} [R] — None unless a
    # compressed downlink is configured (init(..., downlink=op))
    down_memory: Any = None
    bits_down: Any = None  # float32 cumulative DOWNLINK wire bits
    # per-leaf-group ledger (DESIGN.md §6): cumulative wire bits per
    # top-level parameter group, [G] f32 per direction — None unless
    # init/make_step were built with leaf_ledger=True.  Group names
    # come from ``leaf_group_names(params)``.
    leaf_bits: Any = None
    leaf_bits_down: Any = None
    # staleness-first fault runtime (DESIGN.md §9): the in-flight
    # payload queue — a per-worker ring of ``queue_depth`` slots holding
    # *decompressed* payload values g (f32, zeros in empty slots),
    # tagged with their global arrival step (-1 = empty) and staleness
    # τ.  A payload computed at t sits in slot ``t % depth`` until its
    # arrival step; depth = max_delay + 1 guarantees a slot is free
    # again before its next producer comes around.  None unless
    # init(..., queue_depth=) allocated it.
    inflight: Any = None       # payload values, [R, depth, ...] leaves
    arrive_at: Any = None      # int32 [R, depth], global step; -1 empty
    inflight_tau: Any = None   # int32 [R, depth], payload staleness


def replicate(tree, R: int):
    """Broadcast a pytree to a leading worker axis of size R."""
    return jax.tree_util.tree_map(
        lambda x: jnp.broadcast_to(x[None], (R,) + x.shape), tree
    )


def leaf_group_names(params) -> tuple:
    """Top-level parameter-group names of the per-leaf ledger, in the
    order ``state.leaf_bits``/``leaf_bits_down`` index them."""
    from repro.core.policy import leaf_groups
    return leaf_groups(params)[0]


def init(params, inner_opt: GradientTransform, R: int,
         downlink=None, leaf_ledger: bool = False,
         queue_depth: Optional[int] = None) -> EngineState:
    """``downlink``: the server→worker compression operator (or
    Channel) this state will be stepped with — needed here only to
    allocate the server-side error memory; None/Identity allocates
    nothing (the exact-broadcast path is memoryless).

    ``leaf_ledger``: allocate the optional per-top-level-leaf-group
    wire-bit ledgers ([G] f32 per direction, G = number of top-level
    parameter groups) — pass the same flag to :func:`make_step`.

    ``queue_depth``: allocate the in-flight payload queue of the fault
    runtime (``FaultSpec.depth`` slots per worker; pass the same value
    to :func:`make_fault_step`).  None = fault-free state (the queue
    fields stay None).
    """
    local = replicate(params, R)
    down = chn.as_channel(downlink, "downlink")
    G = len(leaf_group_names(params)) if leaf_ledger else 0
    if queue_depth is not None and queue_depth < 1:
        raise ValueError(f"queue_depth must be >= 1, got {queue_depth}")
    return EngineState(
        # own copies: the state is donated by engine.run/run_rounds, so
        # master may not alias the caller's params and master_view may
        # not alias local (one buffer cannot fill two donated slots)
        master=jax.tree_util.tree_map(jnp.copy, params),
        master_view=jax.tree_util.tree_map(jnp.copy, local),
        local=local,
        memory=jax.tree_util.tree_map(jnp.zeros_like, local),
        inner=jax.vmap(inner_opt.init)(local),
        step=jnp.zeros((), jnp.int32),
        bits=jnp.zeros((), jnp.float32),
        rounds=jnp.zeros((), jnp.int32),
        down_memory=(None if down.is_identity()
                     else down.init_memory(local)),
        bits_down=jnp.zeros((), jnp.float32),
        leaf_bits=jnp.zeros((G,), jnp.float32) if leaf_ledger else None,
        leaf_bits_down=(jnp.zeros((G,), jnp.float32) if leaf_ledger
                        else None),
        inflight=(None if queue_depth is None else jax.tree_util.tree_map(
            lambda x: jnp.zeros((R, queue_depth) + x.shape, jnp.float32),
            params)),
        arrive_at=(None if queue_depth is None
                   else jnp.full((R, queue_depth), -1, jnp.int32)),
        inflight_tau=(None if queue_depth is None
                      else jnp.zeros((R, queue_depth), jnp.int32)),
    )


def _make_local_phase(grad_fn: Callable, inner_opt: GradientTransform,
                      lr_schedule: Callable):
    """The per-step local phase (Algorithm 1/2 lines 5-7), shared by the
    per-step ``make_step`` and the scanned ``make_superstep``."""

    def local_phase(state: EngineState, batch):
        lr = lr_schedule(state.step)

        def one(params, inner, data):
            loss, grads = grad_fn(params, data)
            updates, inner = inner_opt.update(grads, inner, params, lr)
            return apply_updates(params, updates), inner, loss

        return jax.vmap(one)(state.local, state.inner, batch)

    return local_phase


def make_step(
    grad_fn: Callable,               # (params, batch) -> (loss, grads)
    inner_opt: GradientTransform,
    operator: CompressionOp | Any,   # op or tree-of-ops (Corollary 1)
    lr_schedule: Callable,
    R: int,
    *,
    dispatch: Optional[dsp.DispatchConfig] = None,
    global_rounds: bool = False,
    downlink=None,
    leaf_ledger: bool = False,
    aggregate: str = "mean_R",
):
    """Build the jittable unified step.

    grad_fn must accept per-worker params and a per-worker batch and
    return (loss, grads) — it is vmapped over the R axis.

    The built step takes ``(state, batch, sync_mask, key)`` where
    ``sync_mask`` is bool[R] (a scalar broadcasts): which workers hit a
    sync index at t+1.

    global_rounds: what ``state.rounds`` counts — True: master rounds
    (+1 whenever any worker syncs; Algorithm-1 bookkeeping), False:
    worker sync events (+Σ s_r; Algorithm-2 bookkeeping).

    aggregate: how the master divides the syncing subset's payload sum
    (DESIGN.md §8) — "mean_R" is the paper's Σ/R (bit-for-bit the
    historical trajectories; under partial participation it scales
    updates down by |S|/R — see ``scenarios.warn_if_biased``),
    "mean_S" divides by the syncing-subset size |S| (≡ mean_R when all
    R workers sync), "support_weighted" divides each coordinate by its
    survivor count — the number of syncing workers whose compressed
    payload carried that coordinate — so sparse payloads don't dilute
    each other; zero-support coordinates keep the master value (the
    payload sum is exactly 0 there and the ``max(count, 1)`` guard
    makes the quotient 0).  With Identity compression every syncing
    worker supports every coordinate, so support_weighted ≡ mean_S.

    downlink: server→worker compression — an operator (or tree, or
    ``channel.Channel``) applied to the per-worker master delta with a
    server-side error memory (state.down_memory; pass the same
    ``downlink`` to :func:`init`).  None/Identity keeps the exact
    dense broadcast (bit-for-bit historical trajectories) and charges
    its dense cost to ``state.bits_down``.

    leaf_ledger: accumulate the per-top-level-leaf-group wire-bit
    ledgers (``state.leaf_bits`` / ``state.leaf_bits_down``, indexed by
    ``leaf_group_names``) so heterogeneous policies can be compared on
    the paper's bits x-axis per layer group, not just in aggregate.
    Pure accounting: trajectories are unchanged.
    """
    from repro.core.scenarios import validate_aggregate
    validate_aggregate(aggregate)
    up_ch = (operator if isinstance(operator, chn.Channel)
             else chn.Channel(operator, "uplink", dispatch))
    down_ch = chn.as_channel(downlink, "downlink", dispatch)
    compressed_down = not down_ch.is_identity()

    local_phase = _make_local_phase(grad_fn, inner_opt, lr_schedule)

    def sync_phase(state: EngineState, half, inner, sync_mask, key):
        """Masked compress-and-aggregate (Algorithm 1/2 lines 8-20)."""
        if leaf_ledger:
            from repro.core.policy import leaf_groups
            _gnames, gidx = leaf_groups(state.master)
            seg = jnp.asarray(gidx, jnp.int32)
            G = len(_gnames)

        def group_bits(per_leaf_bits, s_r):
            """Per-leaf bits (flatten order) → masked [G] group vector."""
            vec = jax.ops.segment_sum(
                jnp.stack([jnp.asarray(b, jnp.float32)
                           for b in per_leaf_bits]),
                seg, num_segments=G)
            return jnp.where(s_r, vec, jnp.zeros_like(vec))

        def worker_update(m_r, view_r, half_r, key_r, s_r):
            acc = jax.tree_util.tree_map(
                lambda m, x, h: m + x.astype(jnp.float32)
                - h.astype(jnp.float32),
                m_r, view_r, half_r,
            )
            if leaf_ledger:
                g, m_out, bits, lb = up_ch.apply(key_r, acc, per_leaf=True)
                gvec = group_bits(lb, s_r)
            else:
                g, m_out, bits = up_ch.apply(key_r, acc)
                gvec = jnp.zeros((0,), jnp.float32)
            # masked: non-syncing workers transmit nothing and keep state
            g = jax.tree_util.tree_map(
                lambda gg: jnp.where(s_r, gg, jnp.zeros_like(gg)), g
            )
            new_m = jax.tree_util.tree_map(
                lambda m, mm: jnp.where(s_r, mm, m), m_r, m_out
            )
            return g, new_m, jnp.where(s_r, bits, 0.0), gvec

        keys = jax.random.split(key, R)
        g_all, new_mem, bits_all, gvec_all = jax.vmap(worker_update)(
            state.memory, state.master_view, half, keys, sync_mask
        )
        new_leaf_bits = (state.leaf_bits + jnp.sum(gvec_all, axis=0)
                         if leaf_ledger else state.leaf_bits)
        # master divides the syncing subset's payload sum per
        # ``aggregate`` (module docstring / DESIGN.md §8)
        if aggregate == "mean_R":
            # the paper's (1/R) Σ over S — the exact historical
            # expression, kept verbatim for bit-for-bit trajectories
            g_sum = jax.tree_util.tree_map(
                lambda g: jnp.sum(g, axis=0) / R, g_all
            )
        elif aggregate == "mean_S":
            # |S| ≥ 1 here: the sync phase only runs when any(s)
            n_sync = jnp.maximum(
                jnp.sum(sync_mask.astype(jnp.float32)), 1.0)
            g_sum = jax.tree_util.tree_map(
                lambda g: jnp.sum(g, axis=0) / n_sync, g_all
            )
        else:  # support_weighted: per-coordinate survivor count
            # (g is already zero-masked for non-syncing workers, so the
            # count only sees syncing payloads; where it is 0 the
            # numerator is exactly 0 too — master keeps its value)
            g_sum = jax.tree_util.tree_map(
                lambda g: jnp.sum(g, axis=0) / jnp.maximum(
                    jnp.sum((g != 0).astype(jnp.float32), axis=0), 1.0),
                g_all
            )
        new_master = jax.tree_util.tree_map(
            lambda x, g: (x.astype(jnp.float32) - g).astype(x.dtype),
            state.master, g_sum,
        )

        def sel(new, old):
            shape = (R,) + (1,) * (new.ndim - 1)
            return jnp.where(sync_mask.reshape(shape), new, old)

        if compressed_down:
            # downlink channel: the server compresses each syncing
            # worker's master delta against its per-worker error memory
            # md^{(r)}; only q crosses the wire, so the worker's view
            # (and local iterate) advances by the *decompressed* delta
            def down_update(dm_r, view_r, half_r, key_r, s_r):
                acc = jax.tree_util.tree_map(
                    lambda dm, v, nm: dm + nm.astype(jnp.float32)
                    - v.astype(jnp.float32),
                    dm_r, view_r, new_master,
                )
                if leaf_ledger:
                    q, dm_out, dbits, dlb = down_ch.apply(
                        key_r, acc, per_leaf=True)
                    dgvec = group_bits(dlb, s_r)
                else:
                    q, dm_out, dbits = down_ch.apply(key_r, acc)
                    dgvec = jnp.zeros((0,), jnp.float32)
                new_v = jax.tree_util.tree_map(
                    lambda v, qq: jnp.where(
                        s_r, (v.astype(jnp.float32) + qq).astype(v.dtype),
                        v),
                    view_r, q,
                )
                new_dm = jax.tree_util.tree_map(
                    lambda dm, mm: jnp.where(s_r, mm, dm), dm_r, dm_out
                )
                new_l = jax.tree_util.tree_map(
                    lambda nv, h: jnp.where(s_r, nv.astype(h.dtype), h),
                    new_v, half_r,
                )
                return (new_v, new_dm, new_l, jnp.where(s_r, dbits, 0.0),
                        dgvec)

            # uplink keys stay exactly jax.random.split(key, R) (bit
            # compat); downlink draws an independent stream per worker
            down_keys = jax.vmap(
                lambda kk: jax.random.fold_in(kk, 0x0d0b))(keys)
            (new_view, new_down_mem, new_local, dbits_all,
             dgvec_all) = jax.vmap(down_update)(
                state.down_memory, state.master_view, half, down_keys,
                sync_mask)
            down_bits = state.bits_down + jnp.sum(dbits_all)
            new_leaf_down = (
                state.leaf_bits_down + jnp.sum(dgvec_all, axis=0)
                if leaf_ledger else state.leaf_bits_down)
        else:
            # exact broadcast (historical path, bit-for-bit): workers in
            # S receive x̄_{t+1} verbatim; the ledger still charges the
            # dense per-receiver cost the wire would carry
            bcast = replicate(new_master, R)
            new_view = jax.tree_util.tree_map(sel, bcast,
                                              state.master_view)
            new_local = jax.tree_util.tree_map(sel, bcast, half)
            new_down_mem = state.down_memory
            n_sync = jnp.sum(sync_mask.astype(jnp.float32))
            down_bits = state.bits_down + (
                n_sync * down_ch.dense_bits(state.master))
            if leaf_ledger:
                # static per-group dense broadcast cost (per receiver)
                dense_vec = jnp.zeros((G,), jnp.float32).at[seg].add(
                    jnp.asarray(
                        [32.0 * l.size for l in
                         jax.tree_util.tree_leaves(state.master)],
                        jnp.float32))
                new_leaf_down = state.leaf_bits_down + n_sync * dense_vec
            else:
                new_leaf_down = state.leaf_bits_down

        inc = (jnp.any(sync_mask).astype(jnp.int32) if global_rounds
               else jnp.sum(sync_mask.astype(jnp.int32)))
        return EngineState(
            master=new_master,
            master_view=new_view,
            local=new_local,
            memory=new_mem,
            inner=inner,
            step=state.step + 1,
            bits=state.bits + jnp.sum(bits_all),
            rounds=state.rounds + inc,
            down_memory=new_down_mem,
            bits_down=down_bits,
            leaf_bits=new_leaf_bits,
            leaf_bits_down=new_leaf_down,
        )

    def step_fn(state: EngineState, batch, sync_mask, key):
        if compressed_down and state.down_memory is None:
            raise ValueError(
                "compressed downlink needs server-side error memory: "
                "initialize with engine.init(..., downlink=<op>)")
        if not compressed_down and state.down_memory is not None:
            raise ValueError(
                "state carries downlink error memory but this step was "
                "built without downlink=: pass the same downlink to "
                "make_step and init (or re-init without one)")
        if state.bits_down is None:  # states minted before the ledger split
            state = state._replace(bits_down=jnp.zeros((), jnp.float32))
        if leaf_ledger and state.leaf_bits is None:
            raise ValueError(
                "per-leaf ledger needs state fields: initialize with "
                "engine.init(..., leaf_ledger=True)")
        sync_mask = jnp.broadcast_to(
            jnp.asarray(sync_mask, bool).reshape(-1), (R,)
        )
        half, inner, losses = local_phase(state, batch)

        def no_sync(_):
            return EngineState(
                master=state.master,
                master_view=state.master_view,
                local=half,
                memory=state.memory,
                inner=inner,
                step=state.step + 1,
                bits=state.bits,
                rounds=state.rounds,
                down_memory=state.down_memory,
                bits_down=state.bits_down,
                leaf_bits=state.leaf_bits,
                leaf_bits_down=state.leaf_bits_down,
            )

        new_state = jax.lax.cond(
            jnp.any(sync_mask),
            lambda _: sync_phase(state, half, inner, sync_mask, key),
            no_sync,
            operand=None,
        )
        return new_state, jnp.mean(losses)

    return step_fn


def make_superstep(
    grad_fn: Callable,               # (params, batch) -> (loss, grads)
    inner_opt: GradientTransform,
    operator: CompressionOp | Any,
    lr_schedule: Callable,
    R: int,
    *,
    dispatch: Optional[dsp.DispatchConfig] = None,
    global_rounds: bool = False,
    downlink=None,
    leaf_ledger: bool = False,
    aggregate: str = "mean_R",
):
    """Build the round program (DESIGN.md §7): one compiled function per
    sync round — ``lax.scan`` over the local phase with the round's
    batch block as xs, the sync phase once at the tail.

    The built superstep takes ``(state, batch_block, tail_mask, key)``
    where ``batch_block`` stacks the round's L per-step batches on a new
    leading axis ([L, R, ...] leaves) and ``tail_mask`` is the tail
    step's sync row (bool[R]; a scalar broadcasts; all-False for a
    trailing partial round — the sync phase is then skipped by the same
    ``lax.cond`` the per-step path uses).  It returns
    ``(new_state, losses, key)`` with ``losses`` the [L] per-step mean
    losses (one device→host fetch per round) and ``key`` the advanced
    PRNG key.

    Bit-for-bit contract: the key is split *inside* the program with
    exactly the per-step host loop's sequence (one split per step, the
    subkey consumed only by the sync phase), and the scanned local body
    is the no-sync branch of the per-step ``lax.cond`` verbatim — so
    superstep trajectories equal per-step trajectories on every state
    leaf and every ledger, for any schedule.  Jit with the state
    donated (``donate_argnums=0``) to update the EngineState buffers in
    place; :func:`run_rounds` does both.
    """
    step_fn = make_step(
        grad_fn, inner_opt, operator, lr_schedule, R, dispatch=dispatch,
        global_rounds=global_rounds, downlink=downlink,
        leaf_ledger=leaf_ledger, aggregate=aggregate)
    local_phase = _make_local_phase(grad_fn, inner_opt, lr_schedule)

    def superstep(state: EngineState, batch_block, tail_mask, key):
        if state.bits_down is None:  # states minted before the ledger split
            state = state._replace(bits_down=jnp.zeros((), jnp.float32))

        def body(carry, batch):
            state, key = carry
            # same stream as the host loop: split per step, subkey
            # unused on pure-local steps (the sync phase is the only
            # consumer), carried key advances identically
            key, _sub = jax.random.split(key)
            half, inner, losses = local_phase(state, batch)
            state = state._replace(local=half, inner=inner,
                                   step=state.step + 1)
            return (state, key), jnp.mean(losses)

        head = jax.tree_util.tree_map(lambda x: x[:-1], batch_block)
        tail = jax.tree_util.tree_map(lambda x: x[-1], batch_block)
        (state, key), head_losses = jax.lax.scan(body, (state, key), head)
        key, sub = jax.random.split(key)
        state, tail_loss = step_fn(state, tail, tail_mask, sub)
        return state, jnp.concatenate([head_losses, tail_loss[None]]), key

    return superstep


def donated_jit(fn):
    """``jax.jit`` with the first argument (the state) donated.

    On backends without buffer aliasing, donation degrades to copies
    and jax warns per executable; the suppression here is scoped to
    *these* calls (not a process-global filter), so unrelated donated
    jits elsewhere keep their diagnostic.  The raw jitted function is
    exposed as ``.jitted``.
    """
    jfn = jax.jit(fn, donate_argnums=(0,))
    if _donation_supported():
        try:
            jfn.jitted = jfn  # uniform surface with the filtered wrapper
            return jfn
        except AttributeError:
            pass  # non-writable jit object: fall through to the wrapper

    def call(*args, **kwargs):
        with warnings.catch_warnings():
            warnings.filterwarnings(
                "ignore", message="Some donated buffers were not usable")
            return jfn(*args, **kwargs)

    call.jitted = jfn
    return call


_DONATION_OK: Optional[bool] = None


def _donation_supported() -> bool:
    """Does this backend alias donated buffers (no per-compile 'not
    usable' warning)?  Probed once per process with a scalar jit, so
    the steady-state donated dispatch path carries no warnings-context
    overhead when — as on TPU and current CPU jaxlibs — donation
    simply works."""
    global _DONATION_OK
    if _DONATION_OK is None:
        with warnings.catch_warnings(record=True) as wlog:
            warnings.simplefilter("always")
            jax.jit(lambda x: x + 1, donate_argnums=(0,))(
                jnp.zeros(())).block_until_ready()
        _DONATION_OK = not any(
            "donated buffers were not usable" in str(w.message)
            for w in wlog)
    return _DONATION_OK


def _donated(fn, attr: str = "_donated_jit"):
    """One :func:`donated_jit` per step function, cached on the
    function itself so repeated ``run``/``run_rounds`` calls over the
    same step reuse one executable instead of re-tracing (and
    re-allocating) every call."""
    cached = getattr(fn, attr, None)
    if cached is None:
        cached = donated_jit(fn)
        try:
            setattr(fn, attr, cached)
        except AttributeError:  # non-writable callables: still jitted
            pass
    return cached


def run(
    state: EngineState,
    step_fn,
    batches,                      # iterable of [R, ...] batches
    sync_mask,                    # bool[T] (all-agree) or bool[T, R]
    key,
    jit: bool = True,
) -> tuple[EngineState, list[float]]:
    """Drive T steps (per-step host loop).

    The step is jitted once per ``step_fn`` with the EngineState
    donated — buffers update in place across steps on backends with
    aliasing — and per-step losses stay on device until the loop ends
    (one deferred fetch, not T synchronizing transfers).  ``jit=False``
    runs the identical loop and loss accounting eagerly.  The state
    argument is consumed: don't reuse the passed-in buffers afterwards.
    """
    fn = _donated(step_fn) if jit else step_fn
    losses = []
    for t, batch in enumerate(batches):
        key, sub = jax.random.split(key)
        state, loss = fn(state, batch, jnp.asarray(sync_mask[t]), sub)
        losses.append(loss)
    return state, [float(l) for l in losses]


def stack_block(step_batches):
    """Stack a round's per-step batches into one [L, ...] block."""
    return jax.tree_util.tree_map(
        lambda *xs: jnp.stack([jnp.asarray(x) for x in xs]), *step_batches)


def run_rounds(
    state: EngineState,
    superstep,                    # from make_superstep
    batches,                      # iterable of [R, ...] batches
    sync_mask,                    # bool[T] (all-agree) or bool[T, R]
    key,
    jit: bool = True,
) -> tuple[EngineState, list[float]]:
    """Drive a whole schedule as compiled round programs (DESIGN.md §7).

    Segments ``sync_mask`` into round plans (``core/rounds.py``), stacks
    each round's batches into one block, and runs each round as a single
    donated program.  Rounds of equal length share one executable; the
    per-step losses come back as one array per round and are fetched
    once at the end, and block assembly for round i+1 overlaps round i's
    device execution (async dispatch = free host-side prefetch).
    Trajectories are bit-for-bit the per-step path's (see
    :func:`make_superstep`).  The state argument is consumed.
    """
    from repro.core import rounds as rnd
    plans = rnd.compile_rounds(sync_mask)
    fn = _donated(superstep) if jit else superstep
    losses = []
    it = iter(batches)
    for plan in plans:
        steps = []
        for _ in range(plan.length):
            try:
                steps.append(next(it))
            except StopIteration:
                break
        if not steps:
            break
        # a truncated block (batch stream shorter than the schedule,
        # matching run()'s graceful stop) never reaches the plan's tail
        # step — the last step it does reach is mid-round, i.e. no-sync
        tail = (plan.mask if len(steps) == plan.length
                else np.zeros_like(plan.mask))
        state, ls, key = fn(state, stack_block(steps), jnp.asarray(tail),
                            key)
        losses.append(ls)
        if len(steps) < plan.length:
            break
    return state, [float(x) for ls in losses for x in np.asarray(ls)]


def make_multiround(superstep):
    """Scan a round program over a *window* of equal-length rounds — the
    overlapped round driver's compiled unit (DESIGN.md §10).

    The returned function takes ``(state, blocks, tail_masks, key)``
    with ``blocks`` stacking W round blocks ([W, L, R, ...] leaves) and
    ``tail_masks`` the W tail sync rows ([W] scalars or [W, R]), and
    returns ``(state, losses [W, L], leds, key)``.

    Bit-for-bit contract: the scan body IS the superstep, so the key
    stream, every state leaf and both bits ledgers evolve exactly as W
    back-to-back superstep calls — the only change is scheduling: the
    device queue holds round w+1's scanned local phase before round w's
    sync collective is consumed, and the host pays one dispatch per
    window.  ``leds`` carries the per-round ledger scalars (bits,
    bits_down, rounds, and the per-leaf vectors when the ledger is on)
    stacked [W, ...], so a driver can reconstruct every mid-window
    round boundary's ledger without materializing mid-window states —
    that is what keeps the trainer's per-step History identical.
    """
    def multiround(state: EngineState, blocks, tail_masks, key):
        if state.bits_down is None:  # states minted before the ledger split
            state = state._replace(bits_down=jnp.zeros((), jnp.float32))

        def body(carry, xs):
            st, kk = carry
            block, mask = xs
            st, ls, kk = superstep(st, block, mask, kk)
            led = {"bits": st.bits, "bits_down": st.bits_down,
                   "rounds": st.rounds}
            if st.leaf_bits is not None:
                led["leaf_bits"] = st.leaf_bits
            if st.leaf_bits_down is not None:
                led["leaf_bits_down"] = st.leaf_bits_down
            return (st, kk), (ls, led)

        (state, key), (losses, leds) = jax.lax.scan(
            body, (state, key), (blocks, tail_masks))
        return state, losses, leds, key

    return multiround


def _multiround_for(superstep):
    """One :func:`make_multiround` per superstep, cached on the
    superstep itself (same idiom as :func:`_donated`)."""
    cached = getattr(superstep, "_multiround", None)
    if cached is None:
        cached = make_multiround(superstep)
        try:
            superstep._multiround = cached
        except AttributeError:
            pass
    return cached


def stack_window(steps, W: int, L: int):
    """Stack W·L per-step batches into one [W, L, ...] window block."""
    flat = stack_block(steps)
    return jax.tree_util.tree_map(
        lambda x: x.reshape((W, L) + x.shape[1:]), flat)


def run_rounds_overlap(
    state: EngineState,
    superstep,                    # from make_superstep
    batches,                      # iterable of [R, ...] batches
    sync_mask,                    # bool[T] (all-agree) or bool[T, R]
    key,
    jit: bool = True,
    window: int = 8,
) -> tuple[EngineState, list[float]]:
    """Overlapped counterpart of :func:`run_rounds`: consecutive
    equal-length rounds dispatch as scanned multi-round windows
    (``rounds.window_rounds`` → :func:`make_multiround`), so round r+1's
    local phase is already in the device queue while round r's sync
    collective completes and the host's per-round dispatch cost is paid
    once per window.  Trajectories — states, both bits ledgers, losses,
    the key stream — are bit-for-bit :func:`run_rounds`'s (the scan
    body is the same superstep; see make_multiround).  The state
    argument is consumed.
    """
    from repro.core import rounds as rnd
    plans = rnd.compile_rounds(sync_mask)
    windows = rnd.window_rounds(plans, max_window=window)
    serial = _donated(superstep) if jit else superstep
    multi = _multiround_for(superstep)
    mfn = _donated(multi, attr="_multiround_jit") if jit else multi
    losses = []
    it = iter(batches)
    stop = False
    for win in windows:
        W, L = len(win), win[0].length
        steps = []
        for _ in range(W * L):
            try:
                steps.append(next(it))
            except StopIteration:
                break
        if W == 1 or len(steps) < W * L:
            # singleton window, or the batch stream ran dry mid-window:
            # fall back to the serialized per-round path (identical
            # trajectories; handles the truncated tail like run_rounds)
            for wi, plan in enumerate(win):
                seg = steps[wi * L:(wi + 1) * L]
                if not seg:
                    stop = True
                    break
                tail = (plan.mask if len(seg) == plan.length
                        else np.zeros_like(plan.mask))
                state, ls, key = serial(state, stack_block(seg),
                                        jnp.asarray(tail), key)
                losses.append(ls)
                if len(seg) < plan.length:
                    stop = True
                    break
            if stop:
                break
            continue
        blocks = stack_window(steps, W, L)
        masks = jnp.asarray(np.stack([np.asarray(p.mask) for p in win]))
        state, ls, _leds, key = mfn(state, blocks, masks, key)
        losses.append(ls)
    return state, [float(x) for ls in losses
                   for x in np.asarray(ls).reshape(-1)]


# ---------------------------------------------------------------------------
# staleness-first fault runtime (DESIGN.md §9)
# ---------------------------------------------------------------------------


class FaultRow(NamedTuple):
    """One step's fault data over the worker axis (all leading-R arrays;
    the [T, R]-stacked numpy form from :func:`fault_rows` drives the
    per-step loop and the scanned fault superstep)."""

    sync: Any      # bool[R]  — scheduled sync fires at this step
    delay: Any     # int32[R] — staleness τ of a payload computed now
    alive: Any     # bool[R]  — worker is up this step
    drop: Any      # bool[R]  — a payload computed now is lost in flight
    recover: Any   # bool[R]  — first alive step after an outage


def fault_rows(mask, tables, R: int) -> FaultRow:
    """Stack a [T]/[T, R] sync mask and expanded
    :class:`~repro.core.scenarios.FaultTables` into one [T, R] FaultRow
    (numpy).  Slice step t with :func:`index_rows`."""
    m = np.asarray(mask, bool)
    if m.ndim == 1:
        m = np.broadcast_to(m[:, None], (m.shape[0], R)).copy()
    T = m.shape[0]
    if tables.delay.shape[0] < T or tables.delay.shape[1] != R:
        raise ValueError(
            f"fault tables of shape {tables.delay.shape} don't cover the "
            f"[{T}, {R}] mask — expand the spec with tables(T, R)")
    return FaultRow(sync=m,
                    delay=np.asarray(tables.delay[:T], np.int32),
                    alive=np.asarray(tables.alive[:T], bool),
                    drop=np.asarray(tables.drop[:T], bool),
                    recover=np.asarray(tables.recover[:T], bool))


def index_rows(rows: FaultRow, sl) -> FaultRow:
    """Slice stacked [T, R] fault rows along the step axis."""
    return FaultRow(*(np.asarray(x)[sl] for x in rows))


def make_fault_step(
    grad_fn: Callable,               # (params, batch) -> (loss, grads)
    inner_opt: GradientTransform,
    operator: CompressionOp | Any,
    lr_schedule: Callable,
    R: int,
    *,
    queue_depth: int,
    dispatch: Optional[dsp.DispatchConfig] = None,
    global_rounds: bool = False,
    downlink=None,
    leaf_ledger: bool = False,
    aggregate: str = "mean_R",
    staleness_weight: str = "uniform",
):
    """Build the jittable fault/staleness step (DESIGN.md §9).

    Same algebra as :func:`make_step` with the sync event split into a
    *compute* time and an *apply* time.  Per step t, given the step's
    :class:`FaultRow`:

    1. **recover** — workers on their first alive step after an outage
       re-initialize from the current master: local/view ← x̄_t, error
       memory ← 0, inner-opt state ← fresh.  (The crash lost them.)
    2. **local phase** — alive workers take the usual local step; dead
       workers' state is frozen.
    3. **compute** (scheduled sync AND alive): the exact
       error-compensated payload g of ``make_step`` — uplink error
       memory updated *now*, wire bits charged *now* — then g is
       *enqueued* with arrival step t+τ (τ = the row's delay) instead
       of being applied.  Dropped payloads are charged and compensated
       but never enqueued: error feedback absorbs the loss.
    4. **apply** — every in-flight payload whose arrival step is t
       (from any compute step ≤ t) joins this step's aggregation,
       weighted per ``staleness_weight``: "uniform" applies payloads
       exactly as computed (bit-for-bit the fault-free math when τ≡0),
       "damped" scales each by 1/(1+τ).  The aggregate rule then
       divides as in ``make_step`` ("mean_S" counts *arriving
       payloads*; "support_weighted" counts arriving support).
    5. **broadcast** — workers contributing an arrival this step (and
       alive) receive the new master (exact or compressed downlink,
       as in ``make_step``); applied queue slots are zeroed.

    With trivial fault rows (τ≡0, all alive, no drops) every phase
    reduces bit-for-bit to ``make_step``'s — enqueue and apply collapse
    into the same step and the queue holds only zeros — which
    ``tests/test_faults.py`` pins.

    The built step takes ``(state, batch, row, key)`` with ``row`` a
    :class:`FaultRow`; the state must have been allocated with
    ``init(..., queue_depth=queue_depth)``.
    """
    from repro.core.scenarios import (validate_aggregate,
                                      validate_staleness_weight)
    validate_aggregate(aggregate)
    validate_staleness_weight(staleness_weight)
    if queue_depth < 1:
        raise ValueError(f"queue_depth must be >= 1, got {queue_depth}")
    up_ch = (operator if isinstance(operator, chn.Channel)
             else chn.Channel(operator, "uplink", dispatch))
    down_ch = chn.as_channel(downlink, "downlink", dispatch)
    compressed_down = not down_ch.is_identity()
    local_phase = _make_local_phase(grad_fn, inner_opt, lr_schedule)
    Dq = int(queue_depth)
    RD = R * Dq

    def wsel(mask_r, new, old):
        """Per-worker select over leading-R trees."""
        def one(n, o):
            shape = (R,) + (1,) * (n.ndim - 1)
            return jnp.where(mask_r.reshape(shape), n, o)
        return jax.tree_util.tree_map(one, new, old)

    def recover_phase(state: EngineState, rec):
        bcast = replicate(state.master, R)
        fresh_local = jax.tree_util.tree_map(
            lambda b, l: b.astype(l.dtype), bcast, state.local)
        return state._replace(
            local=wsel(rec, fresh_local, state.local),
            master_view=wsel(rec, jax.tree_util.tree_map(
                lambda b, v: b.astype(v.dtype), bcast, state.master_view),
                state.master_view),
            memory=wsel(rec, jax.tree_util.tree_map(
                jnp.zeros_like, state.memory), state.memory),
            inner=wsel(rec, jax.vmap(inner_opt.init)(fresh_local),
                       state.inner),
        )

    def step_fn(state: EngineState, batch, row: FaultRow, key):
        if state.inflight is None or state.arrive_at is None:
            raise ValueError(
                "fault step needs the in-flight queue: initialize with "
                f"engine.init(..., queue_depth={Dq})")
        if state.arrive_at.shape != (R, Dq):
            raise ValueError(
                f"state queue depth {state.arrive_at.shape} != "
                f"({R}, {Dq}) this step was built for")
        if compressed_down and state.down_memory is None:
            raise ValueError(
                "compressed downlink needs server-side error memory: "
                "initialize with engine.init(..., downlink=<op>)")
        if leaf_ledger and state.leaf_bits is None:
            raise ValueError(
                "per-leaf ledger needs state fields: initialize with "
                "engine.init(..., leaf_ledger=True)")
        if state.bits_down is None:
            state = state._replace(bits_down=jnp.zeros((), jnp.float32))
        as_r = lambda x, dt: jnp.broadcast_to(  # noqa: E731
            jnp.asarray(x, dt).reshape(-1), (R,))
        row = FaultRow(sync=as_r(row.sync, bool),
                       delay=as_r(row.delay, jnp.int32),
                       alive=as_r(row.alive, bool),
                       drop=as_r(row.drop, bool),
                       recover=as_r(row.recover, bool))

        state = jax.lax.cond(jnp.any(row.recover),
                             lambda s: recover_phase(s, row.recover),
                             lambda s: s, state)

        half_raw, inner_raw, losses = local_phase(state, batch)
        # dead workers take no local step: their iterate and inner
        # state stay frozen (the gradient is computed and discarded —
        # masking beats ragged shapes under vmap)
        half = wsel(row.alive, half_raw, state.local)
        inner = wsel(row.alive, inner_raw, state.inner)

        compute = row.sync & row.alive
        pending = state.arrive_at == state.step            # [R, Dq]
        any_event = (jnp.any(compute) | jnp.any(pending))

        if leaf_ledger:
            from repro.core.policy import leaf_groups
            _gnames, gidx = leaf_groups(state.master)
            seg = jnp.asarray(gidx, jnp.int32)
            G = len(_gnames)

        def group_bits(per_leaf_bits, s_r):
            vec = jax.ops.segment_sum(
                jnp.stack([jnp.asarray(b, jnp.float32)
                           for b in per_leaf_bits]),
                seg, num_segments=G)
            return jnp.where(s_r, vec, jnp.zeros_like(vec))

        def worker_update(m_r, view_r, half_r, key_r, s_r):
            # identical to make_step's: compute-time error feedback
            acc = jax.tree_util.tree_map(
                lambda m, x, h: m + x.astype(jnp.float32)
                - h.astype(jnp.float32),
                m_r, view_r, half_r,
            )
            if leaf_ledger:
                g, m_out, bits, lb = up_ch.apply(key_r, acc, per_leaf=True)
                gvec = group_bits(lb, s_r)
            else:
                g, m_out, bits = up_ch.apply(key_r, acc)
                gvec = jnp.zeros((0,), jnp.float32)
            g = jax.tree_util.tree_map(
                lambda gg: jnp.where(s_r, gg, jnp.zeros_like(gg)), g
            )
            new_m = jax.tree_util.tree_map(
                lambda m, mm: jnp.where(s_r, mm, m), m_r, m_out
            )
            return g, new_m, jnp.where(s_r, bits, 0.0), gvec

        def event_phase(_):
            keys = jax.random.split(key, R)
            g_all, new_mem, bits_all, gvec_all = jax.vmap(worker_update)(
                state.memory, state.master_view, half, keys, compute
            )
            new_leaf_bits = (state.leaf_bits + jnp.sum(gvec_all, axis=0)
                             if leaf_ledger else state.leaf_bits)
            # ---- enqueue: slot t % depth, arrival at t + τ ----------
            slot = jnp.mod(state.step, Dq)
            keep = compute & ~row.drop
            q = jax.tree_util.tree_map(
                lambda qq, gg: qq.at[:, slot].set(
                    jnp.where(keep.reshape((R,) + (1,) * (gg.ndim - 1)),
                              gg, qq[:, slot])),
                state.inflight, g_all)
            arrive = state.arrive_at.at[:, slot].set(
                jnp.where(keep, state.step + row.delay,
                          state.arrive_at[:, slot]))
            tau = state.inflight_tau.at[:, slot].set(
                jnp.where(keep, row.delay, state.inflight_tau[:, slot]))
            # ---- apply: every payload whose arrival step is t -------
            arr = arrive == state.step                     # [R, Dq]
            arr_flat = arr.reshape(RD)

            def arriving(qq):
                flat = qq.reshape((RD,) + qq.shape[2:])
                shape = (RD,) + (1,) * (flat.ndim - 1)
                pay = jnp.where(arr_flat.reshape(shape), flat,
                                jnp.zeros_like(flat))
                if staleness_weight == "damped":
                    w = 1.0 / (1.0 + tau.reshape(RD).astype(jnp.float32))
                    pay = pay * w.reshape(shape)
                return pay

            pay_all = jax.tree_util.tree_map(arriving, q)
            if aggregate == "mean_R":
                g_sum = jax.tree_util.tree_map(
                    lambda p: jnp.sum(p, axis=0) / R, pay_all)
            elif aggregate == "mean_S":
                n_arr = jnp.maximum(
                    jnp.sum(arr_flat.astype(jnp.float32)), 1.0)
                g_sum = jax.tree_util.tree_map(
                    lambda p: jnp.sum(p, axis=0) / n_arr, pay_all)
            else:  # support_weighted: per-coordinate arriving support
                g_sum = jax.tree_util.tree_map(
                    lambda p: jnp.sum(p, axis=0) / jnp.maximum(
                        jnp.sum((p != 0).astype(jnp.float32), axis=0),
                        1.0),
                    pay_all)
            new_master = jax.tree_util.tree_map(
                lambda x, g: (x.astype(jnp.float32) - g).astype(x.dtype),
                state.master, g_sum,
            )
            # ---- dequeue applied slots (empty slots stay zero) ------
            new_q = jax.tree_util.tree_map(
                lambda qq: jnp.where(
                    arr.reshape((R, Dq) + (1,) * (qq.ndim - 2)),
                    jnp.zeros_like(qq), qq),
                q)
            new_arrive = jnp.where(arr, -1, arrive)
            new_tau = jnp.where(arr, 0, tau)
            # ---- broadcast to workers whose payload landed ----------
            b = jnp.any(arr, axis=1) & row.alive

            if compressed_down:
                def down_update(dm_r, view_r, half_r, key_r, s_r):
                    acc = jax.tree_util.tree_map(
                        lambda dm, v, nm: dm + nm.astype(jnp.float32)
                        - v.astype(jnp.float32),
                        dm_r, view_r, new_master,
                    )
                    if leaf_ledger:
                        qd, dm_out, dbits, dlb = down_ch.apply(
                            key_r, acc, per_leaf=True)
                        dgvec = group_bits(dlb, s_r)
                    else:
                        qd, dm_out, dbits = down_ch.apply(key_r, acc)
                        dgvec = jnp.zeros((0,), jnp.float32)
                    new_v = jax.tree_util.tree_map(
                        lambda v, qq: jnp.where(
                            s_r,
                            (v.astype(jnp.float32) + qq).astype(v.dtype),
                            v),
                        view_r, qd,
                    )
                    new_dm = jax.tree_util.tree_map(
                        lambda dm, mm: jnp.where(s_r, mm, dm), dm_r,
                        dm_out)
                    new_l = jax.tree_util.tree_map(
                        lambda nv, h: jnp.where(s_r, nv.astype(h.dtype),
                                                h),
                        new_v, half_r,
                    )
                    return (new_v, new_dm, new_l,
                            jnp.where(s_r, dbits, 0.0), dgvec)

                down_keys = jax.vmap(
                    lambda kk: jax.random.fold_in(kk, 0x0d0b))(keys)
                (new_view, new_down_mem, new_local, dbits_all,
                 dgvec_all) = jax.vmap(down_update)(
                    state.down_memory, state.master_view, half, down_keys,
                    b)
                down_bits = state.bits_down + jnp.sum(dbits_all)
                new_leaf_down = (
                    state.leaf_bits_down + jnp.sum(dgvec_all, axis=0)
                    if leaf_ledger else state.leaf_bits_down)
            else:
                bcast = replicate(new_master, R)
                new_view = wsel(b, bcast, state.master_view)
                new_local = wsel(b, bcast, half)
                new_down_mem = state.down_memory
                n_recv = jnp.sum(b.astype(jnp.float32))
                down_bits = state.bits_down + (
                    n_recv * down_ch.dense_bits(state.master))
                if leaf_ledger:
                    dense_vec = jnp.zeros((G,), jnp.float32).at[seg].add(
                        jnp.asarray(
                            [32.0 * l.size for l in
                             jax.tree_util.tree_leaves(state.master)],
                            jnp.float32))
                    new_leaf_down = (state.leaf_bits_down
                                     + n_recv * dense_vec)
                else:
                    new_leaf_down = state.leaf_bits_down

            inc = (jnp.any(arr).astype(jnp.int32) if global_rounds
                   else jnp.sum(compute.astype(jnp.int32)))
            return state._replace(
                master=new_master,
                master_view=new_view,
                local=new_local,
                memory=new_mem,
                inner=inner,
                step=state.step + 1,
                bits=state.bits + jnp.sum(bits_all),
                rounds=state.rounds + inc,
                down_memory=new_down_mem,
                bits_down=down_bits,
                leaf_bits=new_leaf_bits,
                leaf_bits_down=new_leaf_down,
                inflight=new_q,
                arrive_at=new_arrive,
                inflight_tau=new_tau,
            )

        def no_event(_):
            return state._replace(local=half, inner=inner,
                                  step=state.step + 1)

        new_state = jax.lax.cond(any_event, event_phase, no_event,
                                 operand=None)
        return new_state, jnp.mean(losses)

    return step_fn


def make_fault_superstep(
    grad_fn: Callable,
    inner_opt: GradientTransform,
    operator: CompressionOp | Any,
    lr_schedule: Callable,
    R: int,
    *,
    queue_depth: int,
    dispatch: Optional[dsp.DispatchConfig] = None,
    global_rounds: bool = False,
    downlink=None,
    leaf_ledger: bool = False,
    aggregate: str = "mean_R",
    staleness_weight: str = "uniform",
):
    """Round program for the fault runtime: one ``lax.scan`` of the full
    fault step over the round's steps, with the [L, R]-stacked fault
    rows as xs beside the batch block.

    Unlike :func:`make_superstep` (pure-local body + sync tail), every
    scanned step here is the *complete* fault step — payload arrivals
    can only land at round tails (``rounds.compile_fault_rounds`` closes
    rounds at every event step), but crash/recover transitions happen
    anywhere, and the per-step ``lax.cond`` skips the event phase on
    event-free steps.  Parity with the per-step loop is therefore by
    construction: both execute the same step function with the same
    per-step key-split sequence, which the differential tests pin
    bit-for-bit.  Signature ``(state, batch_block, rows, key) ->
    (state, losses[L], key)``.
    """
    step_fn = make_fault_step(
        grad_fn, inner_opt, operator, lr_schedule, R,
        queue_depth=queue_depth, dispatch=dispatch,
        global_rounds=global_rounds, downlink=downlink,
        leaf_ledger=leaf_ledger, aggregate=aggregate,
        staleness_weight=staleness_weight)

    def superstep(state: EngineState, batch_block, rows: FaultRow, key):
        if state.bits_down is None:
            state = state._replace(bits_down=jnp.zeros((), jnp.float32))

        def body(carry, xs):
            state, key = carry
            batch, row = xs
            # same stream as the host loop: one split per step, the
            # subkey consumed only by the event phase
            key, sub = jax.random.split(key)
            state, loss = step_fn(state, batch, row, sub)
            return (state, key), loss

        rows = FaultRow(*(jnp.asarray(x) for x in rows))
        (state, key), losses = jax.lax.scan(
            body, (state, key), (batch_block, rows))
        return state, losses, key

    return superstep


def run_faults(
    state: EngineState,
    step_fn,                      # from make_fault_step
    batches,                      # iterable of [R, ...] batches
    mask,                         # bool[T] or bool[T, R] sync schedule
    tables,                       # scenarios.FaultTables
    key,
    jit: bool = True,
) -> tuple[EngineState, list[float]]:
    """Drive T fault steps (per-step host loop; the oracle path the
    round driver is differentially tested against)."""
    if state.arrive_at is None:
        raise ValueError("fault drivers need a queue-bearing state: "
                         "initialize with engine.init(..., queue_depth=)")
    R = state.arrive_at.shape[0]
    rows = fault_rows(mask, tables, R)
    fn = _donated(step_fn) if jit else step_fn
    losses = []
    for t, batch in enumerate(batches):
        key, sub = jax.random.split(key)
        state, loss = fn(state, batch, index_rows(rows, t), sub)
        losses.append(loss)
    return state, [float(l) for l in losses]


def run_fault_rounds(
    state: EngineState,
    superstep,                    # from make_fault_superstep
    batches,
    mask,                         # bool[T] or bool[T, R] sync schedule
    tables,                       # scenarios.FaultTables
    key,
    jit: bool = True,
) -> tuple[EngineState, list[float]]:
    """Drive the schedule as compiled fault-round programs.

    Rounds close at *event* steps (scheduled syncs and payload
    arrivals, ``rounds.compile_fault_rounds``), so master and ledger
    state only change at round tails — the trainer's per-round ledger
    snapshots stay exact.  Rounds of equal length share one executable
    (fault rows are data).  The state argument is consumed.
    """
    from repro.core import rounds as rnd
    if state.arrive_at is None:
        raise ValueError("fault drivers need a queue-bearing state: "
                         "initialize with engine.init(..., queue_depth=)")
    R = state.arrive_at.shape[0]
    rows = fault_rows(mask, tables, R)
    plans = rnd.compile_fault_rounds(rows.sync, tables)
    fn = _donated(superstep) if jit else superstep
    losses = []
    it = iter(batches)
    for plan in plans:
        steps = []
        for _ in range(plan.length):
            try:
                steps.append(next(it))
            except StopIteration:
                break
        if not steps:
            break
        block_rows = index_rows(rows, slice(plan.start,
                                            plan.start + len(steps)))
        if len(steps) < plan.length:
            # truncated block (batch stream ended mid-round): the steps
            # actually reached are all event-free by construction
            block_rows = block_rows._replace(
                sync=np.zeros_like(block_rows.sync))
        state, ls, key = fn(state, stack_block(steps), block_rows, key)
        losses.append(ls)
        if len(steps) < plan.length:
            break
    return state, [float(x) for ls in losses for x in np.asarray(ls)]


# ---------------------------------------------------------------------------
# fleet-scale worker axis (DESIGN.md §8)
# ---------------------------------------------------------------------------


def shard_worker_axis(state: EngineState, mesh, axis: str = "data"
                      ) -> EngineState:
    """Shard the state's leading worker axis over a mesh axis.

    The engine keeps the whole fleet on-device (one vmapped worker
    axis); past one device's memory, place the per-worker fields
    (local/memory/inner/master_view/down_memory) ``P(axis)`` and
    replicate the master and scalars — under jit the partitioner then
    runs the vmapped local phase worker-parallel and inserts one
    cross-device reduction for the sync-phase Σ over workers.  R must
    divide by the axis size.  Reduction order may differ from the
    single-device layout (same math, float-rounding level); for
    bit-pinned comparisons keep R on one device.
    """
    from jax.sharding import NamedSharding, PartitionSpec as P
    rep = NamedSharding(mesh, P())
    wrk = NamedSharding(mesh, P(axis))

    def put(tree, sh):
        if tree is None:
            return None
        return jax.tree_util.tree_map(
            lambda x: jax.device_put(x, sh), tree)

    return state._replace(
        master=put(state.master, rep),
        master_view=put(state.master_view, wrk),
        local=put(state.local, wrk),
        memory=put(state.memory, wrk),
        inner=put(state.inner, wrk),
        down_memory=put(state.down_memory, wrk),
        inflight=put(state.inflight, wrk),
        arrive_at=put(state.arrive_at, wrk),
        inflight_tau=put(state.inflight_tau, wrk),
    )


# ---------------------------------------------------------------------------
# diagnostics (Lemma 4/5/7/8 empirical quantities)
# ---------------------------------------------------------------------------


def memory_sq_norms(state) -> jnp.ndarray:
    """||m_t^{(r)}||_2^2 per worker (flattened over the whole pytree)."""
    leaves = jax.tree_util.tree_leaves(state.memory)
    return sum(
        jnp.sum(jnp.square(l.astype(jnp.float32)),
                axis=tuple(range(1, l.ndim)))
        for l in leaves
    )


def local_deviation_sq(state) -> jnp.ndarray:
    """(1/R) Σ_r ||x̄ - x̂^{(r)}||^2 (Lemma 7/8 quantity)."""
    def dev(leaf):
        mean = jnp.mean(leaf.astype(jnp.float32), axis=0, keepdims=True)
        return jnp.sum(jnp.square(leaf.astype(jnp.float32) - mean))

    total = sum(dev(l) for l in jax.tree_util.tree_leaves(state.local))
    R = jax.tree_util.tree_leaves(state.local)[0].shape[0]
    return total / R

"""The compression *channel*: one abstraction for everything that
crosses the wire, in either direction (DESIGN.md §5).

The paper compresses the worker→server updates; Double Quantization
[Yu et al., 2019] and Error-Compensated QSGD [Wu et al., 2018] show the
server→worker broadcast can be compressed the same way, with its own
error memory on the server side.  This module packages the shared
structure — a compression operator (or tree of operators, Corollary 1),
a kernel-dispatch policy, a direction tag for the per-direction bits
ledger — so the engine instantiates it twice:

  * **uplink**  (worker → server): compresses the error-compensated
    difference ``m^{(r)} + x^{(r)} − x̂^{(r)}`` per worker;
  * **downlink** (server → worker): compresses the master *delta*
    ``x̄_{t+1} − x^{(r)}`` against the server-side per-worker error
    memory before updating worker r's master view.

The error memory itself is traced engine state (per worker, owned by
``EngineState`` / ``DistQsparseState``); a Channel holds only the
static policy plus the error-feedback algebra

    q = C(acc),   memory' = acc − q,   bits = counted wire cost,

routed through ``kernels.dispatch.channel_compress_tree`` so eligible
leaves run the fused Pallas kernels (megabuffer-packed: one launch per
operator family per direction per sync round) and the kernel's fused
error memory is consumed directly.

An Identity channel (``is_identity()``) means "no compression": the
engine takes the exact-broadcast fast path (bit-for-bit today's
trajectories) and the ledger charges the dense wire cost — the honest
accounting the uplink-only ledger used to omit.
"""

from __future__ import annotations

import dataclasses
from typing import Any, NamedTuple, Optional

import jax
import jax.numpy as jnp

from repro.core import bits as bitlib
from repro.core.operators import CompressionOp, Identity, ops_for_leaves


class WireLedger(NamedTuple):
    """Per-direction cumulative wire bits (the paper's x-axis, §1.4)."""

    up: Any    # worker → server
    down: Any  # server → worker

    @property
    def total(self):
        return self.up + self.down


def wire_ledger(state) -> WireLedger:
    """Per-direction ledger of any engine state carrying ``bits`` /
    ``bits_down`` fields (EngineState, QsparseState, DistQsparseState)."""
    down = getattr(state, "bits_down", None)
    if down is None:
        down = jnp.zeros((), jnp.float32)
    return WireLedger(up=state.bits, down=down)


def _all_identity(op_tree) -> bool:
    if isinstance(op_tree, CompressionOp):
        return isinstance(op_tree, Identity)
    leaves = jax.tree_util.tree_leaves(
        op_tree, is_leaf=lambda o: isinstance(o, CompressionOp))
    return all(isinstance(o, Identity) for o in leaves)


@dataclasses.dataclass(frozen=True)
class Channel:
    """Engine-level channel: operator tree + dispatch policy + direction.

    ``operator`` is a ``CompressionOp`` or a pytree of them (broadcast
    over leaves like ``operators.compress_tree``); ``dispatch`` the
    kernel routing policy (None = dispatch defaults); ``direction`` a
    tag ("uplink" | "downlink") for ledgers and launch accounting.
    """

    operator: Any
    direction: str = "uplink"
    dispatch: Optional[Any] = None  # kernels.dispatch.DispatchConfig

    def is_identity(self) -> bool:
        """True when the channel transmits exactly (no compression)."""
        return self.operator is None or _all_identity(self.operator)

    def init_memory(self, tree):
        """Fresh (zero) error memory in ``tree``'s layout, f32."""
        return jax.tree_util.tree_map(
            lambda x: jnp.zeros(x.shape, jnp.float32), tree)

    def apply(self, key, acc, *, per_leaf: bool = False):
        """Error-compensated compression of the accumulator ``acc``
        (caller adds the memory in: acc = memory + payload).

        Returns ``(q, new_memory, bits)`` with ``q + new_memory == acc``
        exactly (the kernels fuse the memory update; the reference path
        computes ``acc − q``) and counted wire bits.  With ``per_leaf``
        a fourth element carries the per-leaf bits (flatten order) for
        the per-leaf-group ledger (DESIGN.md §6).
        """
        from repro.kernels import dispatch as dsp
        return dsp.channel_compress_tree(
            self.operator, key, acc, self.dispatch,
            want_leaf_bits=per_leaf)

    def dense_bits(self, tree, value_bits: int = 32):
        """Exact-transmission wire cost of one broadcast of ``tree``
        (the Identity channel's per-worker ledger charge)."""
        return bitlib.bits_dense_tree(tree, value_bits)

    def ops_for(self, n_leaves: int):
        return ops_for_leaves(self.operator, n_leaves)


def as_channel(op_or_channel, direction: str, dispatch=None
               ) -> Optional[Channel]:
    """Normalize a make_step-style argument into a Channel (or None).

    ``None`` and Identity operators normalize to an Identity channel —
    the exact-broadcast path with dense ledger accounting.
    """
    if op_or_channel is None:
        return Channel(operator=None, direction=direction, dispatch=dispatch)
    if isinstance(op_or_channel, Channel):
        return op_or_channel
    return Channel(operator=op_or_channel, direction=direction,
                   dispatch=dispatch)


@dataclasses.dataclass(frozen=True)
class ShardChannel:
    """Mesh-level channel for the distributed engine: wraps a
    ``core.distributed.ShardCompressor`` (shard-local, spec-aware
    compression) with the same error-feedback algebra and direction
    tag.  Kept duck-typed to avoid a channel ↔ distributed import
    cycle; ``compressor`` is a ShardCompressor (or None = Identity).
    """

    compressor: Any
    direction: str = "uplink"

    def is_identity(self) -> bool:
        return self.compressor is None or self.compressor.is_identity()

    def init_memory(self, tree):
        return jax.tree_util.tree_map(
            lambda x: jnp.zeros(x.shape, jnp.float32), tree)

    def apply(self, acc, param_specs, key=None):
        """Dense-form error-compensated compression of ``acc``:
        ``(q, new_memory, bits)`` with q + new_memory == acc.
        ``key`` feeds stochastic per-leaf operators of a heterogeneous
        policy (deterministic compressors ignore it)."""
        q, bits = self.compressor(acc, param_specs, key=key)
        new_mem = jax.tree_util.tree_map(lambda a, g: a - g, acc, q)
        return q, new_mem, bits

    def compact(self, acc, param_specs, key=None):
        """Compact-wire-form counterpart (DESIGN.md §3.3): defers to
        ``ShardCompressor.compact`` — (payloads, treedef, bits, mem)."""
        return self.compressor.compact(acc, param_specs, key=key)

    def dense_bits(self, tree, value_bits: int = 32):
        return bitlib.bits_dense_tree(tree, value_bits)

"""Qsparse-local-SGD core: compression operators, error-feedback
memory, the unified sync/async engine (core/engine.py) with its
Algorithm-1/2 wrappers, bit accounting, distributed production
engine."""

from repro.core import bits, engine, operators, policy, schedule
from repro.core.engine import EngineState
from repro.core.policy import ChannelSpec, OpSpec, PolicySpec
from repro.core.operators import (
    CompressionOp,
    Identity,
    QSGDQuantizer,
    QuantizedSparsifier,
    RandK,
    RowSignTopK,
    RowTopK,
    Sign,
    SignSparsifier,
    StochasticKLevel,
    TopK,
    compress_tree,
    make_operator,
    tree_gamma,
)

__all__ = [
    "bits",
    "engine",
    "EngineState",
    "operators",
    "policy",
    "schedule",
    "ChannelSpec",
    "OpSpec",
    "PolicySpec",
    "CompressionOp",
    "Identity",
    "QSGDQuantizer",
    "QuantizedSparsifier",
    "RandK",
    "RowSignTopK",
    "RowTopK",
    "Sign",
    "SignSparsifier",
    "StochasticKLevel",
    "TopK",
    "compress_tree",
    "make_operator",
    "tree_gamma",
]

"""Qsparse-local-SGD core: compression operators, error-feedback
memory, sync/async engines, bit accounting, distributed production
engine."""

from repro.core import bits, operators, schedule
from repro.core.operators import (
    CompressionOp,
    Identity,
    QSGDQuantizer,
    QuantizedSparsifier,
    RandK,
    RowSignTopK,
    RowTopK,
    Sign,
    SignSparsifier,
    StochasticKLevel,
    TopK,
    compress_tree,
    make_operator,
    tree_gamma,
)

__all__ = [
    "bits",
    "operators",
    "schedule",
    "CompressionOp",
    "Identity",
    "QSGDQuantizer",
    "QuantizedSparsifier",
    "RandK",
    "RowSignTopK",
    "RowTopK",
    "Sign",
    "SignSparsifier",
    "StochasticKLevel",
    "TopK",
    "compress_tree",
    "make_operator",
    "tree_gamma",
]

"""Qsparse-local-SGD core: compression operators, error-feedback
memory, the unified sync/async engine (core/engine.py) with its
Algorithm-1/2 wrappers, bit accounting, distributed production
engine."""

from repro.core import bits, engine, operators, schedule
from repro.core.engine import EngineState
from repro.core.operators import (
    CompressionOp,
    Identity,
    QSGDQuantizer,
    QuantizedSparsifier,
    RandK,
    RowSignTopK,
    RowTopK,
    Sign,
    SignSparsifier,
    StochasticKLevel,
    TopK,
    compress_tree,
    make_operator,
    tree_gamma,
)

__all__ = [
    "bits",
    "engine",
    "EngineState",
    "operators",
    "schedule",
    "CompressionOp",
    "Identity",
    "QSGDQuantizer",
    "QuantizedSparsifier",
    "RandK",
    "RowSignTopK",
    "RowTopK",
    "Sign",
    "SignSparsifier",
    "StochasticKLevel",
    "TopK",
    "compress_tree",
    "make_operator",
    "tree_gamma",
]

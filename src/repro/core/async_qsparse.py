"""Qsparse-local-SGD with asynchronous updates (paper Algorithm 2).

Thin wrapper over the unified engine (``core/engine.py``), which
implements the per-worker sync mask natively — Algorithm 2 *is* the
engine's general case, so this module only preserves the historical
state/API names.

Faithful to the paper's asynchrony model: all workers advance local
iterates on a common global clock, but synchronize with the master at
*per-worker* times I_T^{(r)} with gap(I_T^{(r)}) <= H.  The additional
state vs Algorithm 1 is each worker's *view* of the master, x_t^{(r)}
(the last broadcast it received), which can lag behind the true master
x̄̄_t because other workers may have synced in between.

Per step t (Algorithm 2 lines 4-20), with s_r = [t+1 in I_T^{(r)}]:

  x̂_{t+1/2}^{(r)} = x̂_t^{(r)} - eta_t d_t^{(r)}
  if not s_r:  x_{t+1}^{(r)} = x_t^{(r)};  m_{t+1}^{(r)} = m_t^{(r)};
               x̂_{t+1}^{(r)} = x̂_{t+1/2}^{(r)}
  else:        g_t^{(r)} = QComp_k(m_t^{(r)} + x_t^{(r)} - x̂_{t+1/2}^{(r)})
               m_{t+1}^{(r)} = m_t^{(r)} + x_t^{(r)} - x̂_{t+1/2}^{(r)} - g
  master:      x̄̄_{t+1} = x̄̄_t - (1/R) sum_{r in S} g_t^{(r)}
  workers in S: x_{t+1}^{(r)} = x̂_{t+1}^{(r)} = x̄̄_{t+1}

The *executed* staleness regime — a payload computed at t applied to
the master at t+τ, with crash/recover and in-flight loss — is the
engine's fault runtime (``engine.make_fault_step``, DESIGN.md §9);
:func:`make_fault_step` / :func:`run_faults` below expose it under the
historical state shape.  ``scenarios.defer_sync`` (moving the whole
sync event) is only the modelled approximation of this.
"""

from __future__ import annotations

from typing import Any, Callable, NamedTuple, Optional

import jax.numpy as jnp

from repro.core import engine
from repro.kernels.dispatch import DispatchConfig
from repro.optim.transforms import GradientTransform


class AsyncQsparseState(NamedTuple):
    master: Any           # x̄̄_t (true master)
    master_view: Any      # x_t^{(r)}: last master copy each worker received [R]
    local: Any            # x̂_t^{(r)} [R]
    memory: Any           # m_t^{(r)} [R]
    inner: Any            # [R]
    step: jnp.ndarray
    bits: jnp.ndarray     # uplink wire bits
    rounds: jnp.ndarray   # total worker-sync events
    # downlink channel state (DESIGN.md §5): server-side per-worker
    # error memory + downlink bits ledger (field order mirrors
    # EngineState so the splat conversions below stay valid)
    down_memory: Any = None
    bits_down: Any = None
    # optional per-leaf-group ledgers (engine leaf_ledger=True)
    leaf_bits: Any = None
    leaf_bits_down: Any = None
    # in-flight payload queue of the fault runtime (engine DESIGN.md §9)
    # — None unless init(..., queue_depth=) allocated it
    inflight: Any = None
    arrive_at: Any = None
    inflight_tau: Any = None


def _replicate(tree, R: int):
    return engine.replicate(tree, R)


def init(params, inner_opt: GradientTransform, R: int,
         downlink=None, leaf_ledger: bool = False,
         queue_depth: Optional[int] = None) -> AsyncQsparseState:
    return AsyncQsparseState(*engine.init(params, inner_opt, R,
                                          downlink=downlink,
                                          leaf_ledger=leaf_ledger,
                                          queue_depth=queue_depth))


def make_step(
    grad_fn: Callable,
    inner_opt: GradientTransform,
    operator,
    lr_schedule: Callable,
    R: int,
    *,
    dispatch: Optional[DispatchConfig] = None,
    downlink=None,
    leaf_ledger: bool = False,
    aggregate: str = "mean_R",
):
    """sync_flags: bool[R] — which workers hit a sync index at t+1.

    The engine computes the update with per-worker masks (masked-out
    workers contribute zero to the master sum and keep their state) —
    exactly the shape the production shard_map engine uses.  Steps
    where no worker syncs skip the compression phase entirely.

    downlink: server→worker compression operator applied to each
    syncing worker's master delta x̄_{t+1} − x_t^{(r)} with a
    server-side error memory (None/Identity = exact broadcast).  Pass
    the same value to :func:`init`.

    aggregate: the master's division rule over the syncing subset
    (engine.make_step / DESIGN.md §8) — "mean_R" (the paper's Σ/R,
    default), "mean_S", or "support_weighted".
    """
    engine_step = engine.make_step(
        grad_fn, inner_opt, operator, lr_schedule, R,
        dispatch=dispatch, global_rounds=False, downlink=downlink,
        leaf_ledger=leaf_ledger, aggregate=aggregate,
    )

    def step_fn(state: AsyncQsparseState, batch, sync_flags, key):
        new, loss = engine_step(
            engine.EngineState(*state), batch, sync_flags, key)
        return AsyncQsparseState(*new), loss

    return step_fn


def make_superstep(
    grad_fn: Callable,
    inner_opt: GradientTransform,
    operator,
    lr_schedule: Callable,
    R: int,
    *,
    dispatch: Optional[DispatchConfig] = None,
    downlink=None,
    leaf_ledger: bool = False,
    aggregate: str = "mean_R",
):
    """Round program for Algorithm 2 (DESIGN.md §7): rounds close at
    every step where *any* worker syncs, so the scanned local phase
    covers the strictly-uncommunicated steps and the tail carries the
    per-worker sync row.  Signature ``(state, batch_block, tail_flags,
    key) -> (state, losses[L], key)``; bit-for-bit the per-step
    trajectories.  Drive with :func:`run_rounds`."""
    engine_super = engine.make_superstep(
        grad_fn, inner_opt, operator, lr_schedule, R,
        dispatch=dispatch, global_rounds=False, downlink=downlink,
        leaf_ledger=leaf_ledger, aggregate=aggregate,
    )

    def superstep(state: AsyncQsparseState, batch_block, tail_flags, key):
        new, losses, key = engine_super(
            engine.EngineState(*state), batch_block, tail_flags, key)
        return AsyncQsparseState(*new), losses, key

    return superstep


def make_fault_step(
    grad_fn: Callable,
    inner_opt: GradientTransform,
    operator,
    lr_schedule: Callable,
    R: int,
    *,
    queue_depth: int,
    dispatch: Optional[DispatchConfig] = None,
    downlink=None,
    leaf_ledger: bool = False,
    aggregate: str = "mean_R",
    staleness_weight: str = "uniform",
):
    """The *executed* Algorithm-2 staleness regime (engine fault
    runtime, DESIGN.md §9): a payload computed at t is applied to the
    master at t+τ out of a per-worker in-flight queue, with worker
    crash/recover and payload drop injectable via
    ``scenarios.FaultSpec``.  The built step takes ``(state, batch,
    fault_row, key)`` with ``fault_row`` an ``engine.FaultRow``;
    allocate the state with ``init(..., queue_depth=queue_depth)``
    (= the fault spec's ``depth``).  Drive with :func:`run_faults`."""
    engine_step = engine.make_fault_step(
        grad_fn, inner_opt, operator, lr_schedule, R,
        queue_depth=queue_depth, dispatch=dispatch, global_rounds=False,
        downlink=downlink, leaf_ledger=leaf_ledger, aggregate=aggregate,
        staleness_weight=staleness_weight,
    )

    def step_fn(state: AsyncQsparseState, batch, fault_row, key):
        new, loss = engine_step(
            engine.EngineState(*state), batch, fault_row, key)
        return AsyncQsparseState(*new), loss

    return step_fn


def run_faults(state, step_fn, batches, sync_mask, tables, key,
               jit: bool = True):
    """Drive the executed-staleness regime: sync_mask bool[T, R] plus
    the FaultSpec's expanded ``tables(T, R)``.  The step keeps the
    historical state shape end to end, so the engine driver threads it
    through unchanged."""
    new, losses = engine.run_faults(state, step_fn, batches, sync_mask,
                                    tables, key, jit=jit)
    return AsyncQsparseState(*new), losses


def run(state, step_fn, batches, sync_mask, key, jit: bool = True):
    """sync_mask: bool[T, R] from schedule.async_schedule."""
    return engine.run(state, step_fn, batches, sync_mask, key, jit=jit)


def run_rounds(state, superstep, batches, sync_mask, key, jit: bool = True):
    """Round-program driver: sync_mask bool[T, R] is segmented into
    rounds at the any-worker-syncs steps (core/rounds.py)."""
    return engine.run_rounds(state, superstep, batches, sync_mask, key,
                             jit=jit)

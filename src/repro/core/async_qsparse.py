"""Qsparse-local-SGD with asynchronous updates (paper Algorithm 2).

Thin wrapper over the unified engine (``core/engine.py``), which
implements the per-worker sync mask natively — Algorithm 2 *is* the
engine's general case, so this module only preserves the historical
state/API names.

Faithful to the paper's asynchrony model: all workers advance local
iterates on a common global clock, but synchronize with the master at
*per-worker* times I_T^{(r)} with gap(I_T^{(r)}) <= H.  The additional
state vs Algorithm 1 is each worker's *view* of the master, x_t^{(r)}
(the last broadcast it received), which can lag behind the true master
x̄̄_t because other workers may have synced in between.

Per step t (Algorithm 2 lines 4-20), with s_r = [t+1 in I_T^{(r)}]:

  x̂_{t+1/2}^{(r)} = x̂_t^{(r)} - eta_t d_t^{(r)}
  if not s_r:  x_{t+1}^{(r)} = x_t^{(r)};  m_{t+1}^{(r)} = m_t^{(r)};
               x̂_{t+1}^{(r)} = x̂_{t+1/2}^{(r)}
  else:        g_t^{(r)} = QComp_k(m_t^{(r)} + x_t^{(r)} - x̂_{t+1/2}^{(r)})
               m_{t+1}^{(r)} = m_t^{(r)} + x_t^{(r)} - x̂_{t+1/2}^{(r)} - g
  master:      x̄̄_{t+1} = x̄̄_t - (1/R) sum_{r in S} g_t^{(r)}
  workers in S: x_{t+1}^{(r)} = x̂_{t+1}^{(r)} = x̄̄_{t+1}
"""

from __future__ import annotations

from typing import Any, Callable, NamedTuple, Optional

import jax.numpy as jnp

from repro.core import engine
from repro.kernels.dispatch import DispatchConfig
from repro.optim.transforms import GradientTransform


class AsyncQsparseState(NamedTuple):
    master: Any           # x̄̄_t (true master)
    master_view: Any      # x_t^{(r)}: last master copy each worker received [R]
    local: Any            # x̂_t^{(r)} [R]
    memory: Any           # m_t^{(r)} [R]
    inner: Any            # [R]
    step: jnp.ndarray
    bits: jnp.ndarray     # uplink wire bits
    rounds: jnp.ndarray   # total worker-sync events
    # downlink channel state (DESIGN.md §5): server-side per-worker
    # error memory + downlink bits ledger (field order mirrors
    # EngineState so the splat conversions below stay valid)
    down_memory: Any = None
    bits_down: Any = None
    # optional per-leaf-group ledgers (engine leaf_ledger=True)
    leaf_bits: Any = None
    leaf_bits_down: Any = None


def _replicate(tree, R: int):
    return engine.replicate(tree, R)


def init(params, inner_opt: GradientTransform, R: int,
         downlink=None, leaf_ledger: bool = False) -> AsyncQsparseState:
    return AsyncQsparseState(*engine.init(params, inner_opt, R,
                                          downlink=downlink,
                                          leaf_ledger=leaf_ledger))


def make_step(
    grad_fn: Callable,
    inner_opt: GradientTransform,
    operator,
    lr_schedule: Callable,
    R: int,
    *,
    dispatch: Optional[DispatchConfig] = None,
    downlink=None,
    leaf_ledger: bool = False,
    aggregate: str = "mean_R",
):
    """sync_flags: bool[R] — which workers hit a sync index at t+1.

    The engine computes the update with per-worker masks (masked-out
    workers contribute zero to the master sum and keep their state) —
    exactly the shape the production shard_map engine uses.  Steps
    where no worker syncs skip the compression phase entirely.

    downlink: server→worker compression operator applied to each
    syncing worker's master delta x̄_{t+1} − x_t^{(r)} with a
    server-side error memory (None/Identity = exact broadcast).  Pass
    the same value to :func:`init`.

    aggregate: the master's division rule over the syncing subset
    (engine.make_step / DESIGN.md §8) — "mean_R" (the paper's Σ/R,
    default), "mean_S", or "support_weighted".
    """
    engine_step = engine.make_step(
        grad_fn, inner_opt, operator, lr_schedule, R,
        dispatch=dispatch, global_rounds=False, downlink=downlink,
        leaf_ledger=leaf_ledger, aggregate=aggregate,
    )

    def step_fn(state: AsyncQsparseState, batch, sync_flags, key):
        new, loss = engine_step(
            engine.EngineState(*state), batch, sync_flags, key)
        return AsyncQsparseState(*new), loss

    return step_fn


def make_superstep(
    grad_fn: Callable,
    inner_opt: GradientTransform,
    operator,
    lr_schedule: Callable,
    R: int,
    *,
    dispatch: Optional[DispatchConfig] = None,
    downlink=None,
    leaf_ledger: bool = False,
    aggregate: str = "mean_R",
):
    """Round program for Algorithm 2 (DESIGN.md §7): rounds close at
    every step where *any* worker syncs, so the scanned local phase
    covers the strictly-uncommunicated steps and the tail carries the
    per-worker sync row.  Signature ``(state, batch_block, tail_flags,
    key) -> (state, losses[L], key)``; bit-for-bit the per-step
    trajectories.  Drive with :func:`run_rounds`."""
    engine_super = engine.make_superstep(
        grad_fn, inner_opt, operator, lr_schedule, R,
        dispatch=dispatch, global_rounds=False, downlink=downlink,
        leaf_ledger=leaf_ledger, aggregate=aggregate,
    )

    def superstep(state: AsyncQsparseState, batch_block, tail_flags, key):
        new, losses, key = engine_super(
            engine.EngineState(*state), batch_block, tail_flags, key)
        return AsyncQsparseState(*new), losses, key

    return superstep


def run(state, step_fn, batches, sync_mask, key, jit: bool = True):
    """sync_mask: bool[T, R] from schedule.async_schedule."""
    return engine.run(state, step_fn, batches, sync_mask, key, jit=jit)


def run_rounds(state, superstep, batches, sync_mask, key, jit: bool = True):
    """Round-program driver: sync_mask bool[T, R] is segmented into
    rounds at the any-worker-syncs steps (core/rounds.py)."""
    return engine.run_rounds(state, superstep, batches, sync_mask, key,
                             jit=jit)

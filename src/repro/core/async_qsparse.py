"""Qsparse-local-SGD with asynchronous updates (paper Algorithm 2).

Faithful to the paper's asynchrony model: all workers advance local
iterates on a common global clock, but synchronize with the master at
*per-worker* times I_T^{(r)} with gap(I_T^{(r)}) <= H.  The additional
state vs Algorithm 1 is each worker's *view* of the master, x_t^{(r)}
(the last broadcast it received), which can lag behind the true master
x̄̄_t because other workers may have synced in between.

Per step t (Algorithm 2 lines 4-20), with s_r = [t+1 in I_T^{(r)}]:

  x̂_{t+1/2}^{(r)} = x̂_t^{(r)} - eta_t d_t^{(r)}
  if not s_r:  x_{t+1}^{(r)} = x_t^{(r)};  m_{t+1}^{(r)} = m_t^{(r)};
               x̂_{t+1}^{(r)} = x̂_{t+1/2}^{(r)}
  else:        g_t^{(r)} = QComp_k(m_t^{(r)} + x_t^{(r)} - x̂_{t+1/2}^{(r)})
               m_{t+1}^{(r)} = m_t^{(r)} + x_t^{(r)} - x̂_{t+1/2}^{(r)} - g
  master:      x̄̄_{t+1} = x̄̄_t - (1/R) sum_{r in S} g_t^{(r)}
  workers in S: x_{t+1}^{(r)} = x̂_{t+1}^{(r)} = x̄̄_{t+1}
"""

from __future__ import annotations

from typing import Any, Callable, NamedTuple

import jax
import jax.numpy as jnp

from repro.core.operators import compress_tree
from repro.optim.transforms import GradientTransform, apply_updates


class AsyncQsparseState(NamedTuple):
    master: Any           # x̄̄_t (true master)
    master_view: Any      # x_t^{(r)}: last master copy each worker received [R]
    local: Any            # x̂_t^{(r)} [R]
    memory: Any           # m_t^{(r)} [R]
    inner: Any            # [R]
    step: jnp.ndarray
    bits: jnp.ndarray
    rounds: jnp.ndarray   # total worker-sync events


def _replicate(tree, R: int):
    return jax.tree_util.tree_map(
        lambda x: jnp.broadcast_to(x[None], (R,) + x.shape), tree
    )


def init(params, inner_opt: GradientTransform, R: int) -> AsyncQsparseState:
    local = _replicate(params, R)
    return AsyncQsparseState(
        master=params,
        master_view=local,
        local=local,
        memory=jax.tree_util.tree_map(jnp.zeros_like, local),
        inner=jax.vmap(inner_opt.init)(local),
        step=jnp.zeros((), jnp.int32),
        bits=jnp.zeros((), jnp.float32),
        rounds=jnp.zeros((), jnp.int32),
    )


def make_step(
    grad_fn: Callable,
    inner_opt: GradientTransform,
    operator,
    lr_schedule: Callable,
    R: int,
):
    """sync_flags: bool[R] — which workers hit a sync index at t+1.

    Unlike the synchronous engine we cannot lax.cond the whole sync away
    (different workers branch differently), so the update is computed
    with per-worker masks; masked-out workers contribute zero to the
    master psum and keep their state.  This is also exactly the shape the
    production shard_map engine uses.
    """

    def step_fn(state: AsyncQsparseState, batch, sync_flags, key):
        lr = lr_schedule(state.step)

        def one(params, inner, data):
            loss, grads = grad_fn(params, data)
            updates, inner = inner_opt.update(grads, inner, params, lr)
            return apply_updates(params, updates), inner, loss

        half, inner, losses = jax.vmap(one)(state.local, state.inner, batch)

        def worker_update(m_r, view_r, half_r, key_r, s_r):
            delta = jax.tree_util.tree_map(
                lambda m, x, h: m + x.astype(jnp.float32) - h.astype(jnp.float32),
                m_r, view_r, half_r,
            )
            g, bits = compress_tree(operator, key_r, delta)
            # masked: non-syncing workers transmit nothing
            g = jax.tree_util.tree_map(
                lambda gg: jnp.where(s_r, gg, jnp.zeros_like(gg)), g
            )
            new_m = jax.tree_util.tree_map(
                lambda m, d, gg: jnp.where(s_r, d - gg, m), m_r, delta, g
            )
            bits = jnp.where(s_r, bits, 0.0)
            return g, new_m, bits

        keys = jax.random.split(key, R)
        g_all, new_mem, bits_all = jax.vmap(worker_update)(
            state.memory, state.master_view, half, keys, sync_flags
        )
        # master applies 1/R * sum over the syncing subset S
        g_sum = jax.tree_util.tree_map(lambda g: jnp.sum(g, axis=0) / R, g_all)
        new_master = jax.tree_util.tree_map(
            lambda x, g: (x.astype(jnp.float32) - g).astype(x.dtype),
            state.master, g_sum,
        )
        # only workers in S receive the broadcast
        bcast = _replicate(new_master, R)

        def select(s):  # per-leaf worker select on axis 0
            def f(new, old):
                shape = (R,) + (1,) * (new.ndim - 1)
                return jnp.where(s.reshape(shape), new, old)
            return f

        sel = select(sync_flags)
        new_view = jax.tree_util.tree_map(sel, bcast, state.master_view)
        new_local = jax.tree_util.tree_map(sel, bcast, half)

        new_state = AsyncQsparseState(
            master=new_master,
            master_view=new_view,
            local=new_local,
            memory=new_mem,
            inner=inner,
            step=state.step + 1,
            bits=state.bits + jnp.sum(bits_all),
            rounds=state.rounds + jnp.sum(sync_flags.astype(jnp.int32)),
        )
        return new_state, jnp.mean(losses)

    return step_fn


def run(state, step_fn, batches, sync_mask, key, jit: bool = True):
    """sync_mask: bool[T, R] from schedule.async_schedule."""
    fn = jax.jit(step_fn) if jit else step_fn
    losses = []
    for t, batch in enumerate(batches):
        key, sub = jax.random.split(key)
        state, loss = fn(state, batch, jnp.asarray(sync_mask[t]), sub)
        losses.append(float(loss))
    return state, losses

"""Qsparse-local-SGD, synchronous (paper Algorithm 1) — reference engine.

This engine is *structurally faithful* to Algorithm 1: R workers are an
explicit leading axis (vmapped), each holding its own local parameters
``x̂_t^{(r)}``, error memory ``m_t^{(r)}`` and inner-optimizer state.
The master parameter ``x_t`` is a single shared pytree.

Per step t (Algorithm 1 lines 4-20):

  x̂_{t+1/2}^{(r)} = x̂_t^{(r)} - eta_t * d_t^{(r)}          (local step;
        d includes momentum when the inner optimizer has it, matching
        the paper's experiments)

  if t+1 not in I_T:
      x_{t+1} = x_t ;  m_{t+1} = m_t ;  x̂_{t+1} = x̂_{t+1/2}
  else:
      g_t^{(r)} = QComp_k(m_t^{(r)} + x_t - x̂_{t+1/2}^{(r)})
      m_{t+1}^{(r)} = m_t^{(r)} + x_t - x̂_{t+1/2}^{(r)} - g_t^{(r)}
      x_{t+1} = x_t - (1/R) sum_r g_t^{(r)}
      x̂_{t+1}^{(r)} = x_{t+1}

The same engine doubles as every baseline in the paper:
  * vanilla distributed SGD:  operator=Identity, H=1
  * local SGD [Sti19,YYZ19]:  operator=Identity, H>1
  * TopK-SGD  [SCJ18,AHJ+18]: operator=TopK,    H=1
  * EF-SignSGD [KRSJ19]:      operator=Sign,    H=1
  * EF-QSGD  [WHHZ18]:        operator=QSGDQuantizer, H=1
  * QTopK / SignTopK (+ local): composed operators, any H.

This engine runs on a single device (tests, benchmarks, examples) or
under pjit with the worker axis sharded.  The production multi-pod
engine with the identical math lives in ``core/distributed.py``.
"""

from __future__ import annotations

from typing import Any, Callable, NamedTuple, Optional

import jax
import jax.numpy as jnp

from repro.core.operators import CompressionOp, compress_tree
from repro.optim.transforms import GradientTransform, apply_updates


class QsparseState(NamedTuple):
    master: Any          # x_t
    local: Any           # x̂_t^{(r)}, leading axis R
    memory: Any          # m_t^{(r)}, leading axis R
    inner: Any           # inner-opt state per worker, leading axis R
    step: jnp.ndarray    # int32
    bits: jnp.ndarray    # float32 cumulative wire bits (sum over workers)
    rounds: jnp.ndarray  # int32 number of sync rounds so far


def _replicate(tree, R: int):
    return jax.tree_util.tree_map(
        lambda x: jnp.broadcast_to(x[None], (R,) + x.shape), tree
    )


def init(params, inner_opt: GradientTransform, R: int) -> QsparseState:
    local = _replicate(params, R)
    memory = jax.tree_util.tree_map(jnp.zeros_like, local)
    inner = jax.vmap(inner_opt.init)(local)
    return QsparseState(
        master=params,
        local=local,
        memory=memory,
        inner=inner,
        step=jnp.zeros((), jnp.int32),
        bits=jnp.zeros((), jnp.float32),
        rounds=jnp.zeros((), jnp.int32),
    )


def make_step(
    grad_fn: Callable,              # (params, batch) -> (loss, grads)
    inner_opt: GradientTransform,
    operator: CompressionOp | Any,  # op or tree-of-ops (Corollary 1)
    lr_schedule: Callable,
    R: int,
):
    """Build the jittable Algorithm-1 step.

    grad_fn must accept per-worker params and a per-worker batch and
    return (loss, grads) — it is vmapped over the R axis.
    ``sync`` is a traced bool: whether t+1 ∈ I_T.
    """

    def local_phase(state: QsparseState, batch):
        lr = lr_schedule(state.step)

        def one(params, inner, data):
            loss, grads = grad_fn(params, data)
            updates, inner = inner_opt.update(grads, inner, params, lr)
            return apply_updates(params, updates), inner, loss

        half, inner, losses = jax.vmap(one)(state.local, state.inner, batch)
        return half, inner, losses

    def step_fn(state: QsparseState, batch, sync, key):
        half, inner, losses = local_phase(state, batch)

        def no_sync(_):
            return QsparseState(
                master=state.master,
                local=half,
                memory=state.memory,
                inner=inner,
                step=state.step + 1,
                bits=state.bits,
                rounds=state.rounds,
            )

        def do_sync(_):
            def worker_update(m_r, half_r, key_r):
                delta = jax.tree_util.tree_map(
                    lambda m, x, h: m + x.astype(jnp.float32) - h.astype(jnp.float32),
                    m_r, state.master, half_r,
                )
                g, bits = compress_tree(operator, key_r, delta)
                new_m = jax.tree_util.tree_map(lambda d, gg: d - gg, delta, g)
                return g, new_m, bits

            keys = jax.random.split(key, R)
            g_all, new_mem, bits_all = jax.vmap(worker_update)(
                state.memory, half, keys
            )
            g_mean = jax.tree_util.tree_map(
                lambda g: jnp.mean(g, axis=0), g_all
            )
            new_master = jax.tree_util.tree_map(
                lambda x, g: (x.astype(jnp.float32) - g).astype(x.dtype),
                state.master, g_mean,
            )
            new_local = _replicate(new_master, R)
            return QsparseState(
                master=new_master,
                local=new_local,
                memory=new_mem,
                inner=inner,
                step=state.step + 1,
                bits=state.bits + jnp.sum(bits_all),
                rounds=state.rounds + 1,
            )

        new_state = jax.lax.cond(sync, do_sync, no_sync, operand=None)
        return new_state, jnp.mean(losses)

    return step_fn


def run(
    state: QsparseState,
    step_fn,
    batches,                      # iterable of [R, ...] batches
    sync_mask,                    # bool[T]
    key,
    jit: bool = True,
) -> tuple[QsparseState, list[float]]:
    """Drive T steps (host loop; step_fn jitted once)."""
    fn = jax.jit(step_fn) if jit else step_fn
    losses = []
    for t, batch in enumerate(batches):
        key, sub = jax.random.split(key)
        state, loss = fn(state, batch, bool(sync_mask[t]), sub)
        losses.append(float(loss))
    return state, losses


# ---------------------------------------------------------------------------
# convenience: average memory norm (for Lemma 4/5 empirical checks)
# ---------------------------------------------------------------------------


def memory_sq_norms(state: QsparseState) -> jnp.ndarray:
    """||m_t^{(r)}||_2^2 per worker (flattened over the whole pytree)."""
    leaves = jax.tree_util.tree_leaves(state.memory)
    per_worker = sum(
        jnp.sum(jnp.square(l.astype(jnp.float32)), axis=tuple(range(1, l.ndim)))
        for l in leaves
    )
    return per_worker


def local_deviation_sq(state: QsparseState) -> jnp.ndarray:
    """(1/R) sum_r ||x̄ - x̂^{(r)}||^2 (Lemma 7/8 quantity)."""
    def dev(leaf):
        mean = jnp.mean(leaf.astype(jnp.float32), axis=0, keepdims=True)
        return jnp.sum(jnp.square(leaf.astype(jnp.float32) - mean))

    total = sum(dev(l) for l in jax.tree_util.tree_leaves(state.local))
    R = jax.tree_util.tree_leaves(state.local)[0].shape[0]
    return total / R

"""Qsparse-local-SGD, synchronous (paper Algorithm 1) — reference API.

Thin wrapper over the unified engine (``core/engine.py``): Algorithm 1
is the engine's special case where every worker shares one sync index
set I_T, i.e. the per-worker sync mask is ``s_r = sync`` for all r and
every worker's master view equals the true master at all times.  All
sync-phase math lives in the engine; this module only adapts the
historical state/API shape:

Per step t (Algorithm 1 lines 4-20):

  x̂_{t+1/2}^{(r)} = x̂_t^{(r)} - eta_t * d_t^{(r)}          (local step;
        d includes momentum when the inner optimizer has it, matching
        the paper's experiments)

  if t+1 not in I_T:
      x_{t+1} = x_t ;  m_{t+1} = m_t ;  x̂_{t+1} = x̂_{t+1/2}
  else:
      g_t^{(r)} = QComp_k(m_t^{(r)} + x_t - x̂_{t+1/2}^{(r)})
      m_{t+1}^{(r)} = m_t^{(r)} + x_t - x̂_{t+1/2}^{(r)} - g_t^{(r)}
      x_{t+1} = x_t - (1/R) sum_r g_t^{(r)}
      x̂_{t+1}^{(r)} = x_{t+1}

The same engine doubles as every baseline in the paper:
  * vanilla distributed SGD:  operator=Identity, H=1
  * local SGD [Sti19,YYZ19]:  operator=Identity, H>1
  * TopK-SGD  [SCJ18,AHJ+18]: operator=TopK,    H=1
  * EF-SignSGD [KRSJ19]:      operator=Sign,    H=1
  * EF-QSGD  [WHHZ18]:        operator=QSGDQuantizer, H=1
  * QTopK / SignTopK (+ local): composed operators, any H.

This wrapper runs on a single device (tests, benchmarks, examples) or
under pjit with the worker axis sharded.  The production multi-pod
engine with the identical math lives in ``core/distributed.py``.
"""

from __future__ import annotations

from typing import Any, Callable, NamedTuple, Optional

import jax.numpy as jnp

from repro.core import channel as chn, engine
from repro.core.operators import CompressionOp
from repro.kernels.dispatch import DispatchConfig
from repro.optim.transforms import GradientTransform


class QsparseState(NamedTuple):
    master: Any          # x_t
    local: Any           # x̂_t^{(r)}, leading axis R
    memory: Any          # m_t^{(r)}, leading axis R
    inner: Any           # inner-opt state per worker, leading axis R
    step: jnp.ndarray    # int32
    bits: jnp.ndarray    # float32 cumulative uplink bits (sum over workers)
    rounds: jnp.ndarray  # int32 number of sync rounds so far
    # downlink channel state (DESIGN.md §5) — populated only with a
    # compressed ``downlink=`` op; with the default exact broadcast the
    # views equal the master and are reconstructed as a free broadcast
    master_view: Any = None
    down_memory: Any = None
    bits_down: Any = None
    # optional per-leaf-group ledgers (engine leaf_ledger=True)
    leaf_bits: Any = None
    leaf_bits_down: Any = None


def _replicate(tree, R: int):
    return engine.replicate(tree, R)


def _from_engine(e: engine.EngineState, keep_view: bool) -> QsparseState:
    return QsparseState(
        master=e.master, local=e.local, memory=e.memory, inner=e.inner,
        step=e.step, bits=e.bits, rounds=e.rounds,
        master_view=e.master_view if keep_view else None,
        down_memory=e.down_memory, bits_down=e.bits_down,
        leaf_bits=e.leaf_bits, leaf_bits_down=e.leaf_bits_down,
    )


def _to_engine(state: QsparseState, R: int) -> engine.EngineState:
    # with the exact broadcast, all-agree masks keep every view equal to
    # the master, so the view axis is a (free) broadcast; a compressed
    # downlink makes views genuinely lag and they are carried in state
    view = (state.master_view if state.master_view is not None
            else _replicate(state.master, R))
    return engine.EngineState(
        master=state.master,
        master_view=view,
        local=state.local, memory=state.memory, inner=state.inner,
        step=state.step, bits=state.bits, rounds=state.rounds,
        down_memory=state.down_memory, bits_down=state.bits_down,
        leaf_bits=state.leaf_bits, leaf_bits_down=state.leaf_bits_down,
    )


def init(params, inner_opt: GradientTransform, R: int,
         downlink=None, leaf_ledger: bool = False) -> QsparseState:
    keep_view = not chn.as_channel(downlink, "downlink").is_identity()
    return _from_engine(
        engine.init(params, inner_opt, R, downlink=downlink,
                    leaf_ledger=leaf_ledger), keep_view)


def make_step(
    grad_fn: Callable,              # (params, batch) -> (loss, grads)
    inner_opt: GradientTransform,
    operator: CompressionOp | Any,  # op or tree-of-ops (Corollary 1)
    lr_schedule: Callable,
    R: int,
    *,
    dispatch: Optional[DispatchConfig] = None,
    downlink=None,
    leaf_ledger: bool = False,
    aggregate: str = "mean_R",
):
    """Build the jittable Algorithm-1 step (engine with an all-equal mask).

    grad_fn must accept per-worker params and a per-worker batch and
    return (loss, grads) — it is vmapped over the R axis.
    ``sync`` is a traced bool: whether t+1 ∈ I_T.

    downlink: server→worker compression operator (None/Identity =
    exact dense broadcast, today's trajectories bit-for-bit; see
    DESIGN.md §5).  Pass the same value to :func:`init` so the
    server-side error memory is allocated.

    leaf_ledger: per-top-level-leaf-group wire-bit accounting (pass
    the same flag to :func:`init`).

    aggregate: the master's division rule (engine.make_step /
    DESIGN.md §8) — with Algorithm 1's all-agree masks "mean_S" equals
    the default "mean_R" bit-for-bit.
    """
    engine_step = engine.make_step(
        grad_fn, inner_opt, operator, lr_schedule, R,
        dispatch=dispatch, global_rounds=True, downlink=downlink,
        leaf_ledger=leaf_ledger, aggregate=aggregate,
    )
    keep_view = not chn.as_channel(downlink, "downlink").is_identity()

    def step_fn(state: QsparseState, batch, sync, key):
        mask = jnp.broadcast_to(jnp.asarray(sync, bool), (R,))
        new, loss = engine_step(_to_engine(state, R), batch, mask, key)
        return _from_engine(new, keep_view), loss

    return step_fn


def make_superstep(
    grad_fn: Callable,
    inner_opt: GradientTransform,
    operator: CompressionOp | Any,
    lr_schedule: Callable,
    R: int,
    *,
    dispatch: Optional[DispatchConfig] = None,
    downlink=None,
    leaf_ledger: bool = False,
    aggregate: str = "mean_R",
):
    """Round program for Algorithm 1 (DESIGN.md §7): one compiled
    function per sync round — ``lax.scan`` over the local steps with
    the batch block as xs, the sync phase once at the tail.  Signature
    ``(state, batch_block, tail_sync, key) -> (state, losses[L], key)``
    with ``tail_sync`` the scalar "is t+1 in I_T" of the round's last
    step.  Bit-for-bit the per-step trajectories (see
    ``engine.make_superstep``); drive with :func:`run_rounds`."""
    engine_super = engine.make_superstep(
        grad_fn, inner_opt, operator, lr_schedule, R,
        dispatch=dispatch, global_rounds=True, downlink=downlink,
        leaf_ledger=leaf_ledger, aggregate=aggregate,
    )
    keep_view = not chn.as_channel(downlink, "downlink").is_identity()

    def superstep(state: QsparseState, batch_block, tail_sync, key):
        mask = jnp.broadcast_to(jnp.asarray(tail_sync, bool).reshape(-1),
                                (R,))
        new, losses, key = engine_super(_to_engine(state, R), batch_block,
                                        mask, key)
        return _from_engine(new, keep_view), losses, key

    return superstep


def run(
    state: QsparseState,
    step_fn,
    batches,                      # iterable of [R, ...] batches
    sync_mask,                    # bool[T]
    key,
    jit: bool = True,
) -> tuple[QsparseState, list[float]]:
    """Drive T steps (host loop; step_fn jitted once, state donated)."""
    return engine.run(state, step_fn, batches, sync_mask, key, jit=jit)


def run_rounds(
    state: QsparseState,
    superstep,                    # from make_superstep
    batches,
    sync_mask,                    # bool[T]
    key,
    jit: bool = True,
) -> tuple[QsparseState, list[float]]:
    """Drive the schedule as compiled round programs (DESIGN.md §7)."""
    return engine.run_rounds(state, superstep, batches, sync_mask, key,
                             jit=jit)


# ---------------------------------------------------------------------------
# convenience: average memory norm (for Lemma 4/5 empirical checks)
# ---------------------------------------------------------------------------


def memory_sq_norms(state: QsparseState) -> jnp.ndarray:
    """||m_t^{(r)}||_2^2 per worker (flattened over the whole pytree)."""
    return engine.memory_sq_norms(state)


def local_deviation_sq(state: QsparseState) -> jnp.ndarray:
    """(1/R) sum_r ||x̄ - x̂^{(r)}||^2 (Lemma 7/8 quantity)."""
    return engine.local_deviation_sq(state)

"""One compression-policy API (DESIGN.md §6).

The paper describes a *family* of algorithms — Top_k, Rand_k, QSGD,
Sign, composed quantized sparsifiers, local steps — and its ResNet-50
experiments apply Top_k layer-wise; Wangni et al. show *where* the
sparsity budget lands across the model matters as much as the total.
This module is the single configuration surface for all of it:

  * :class:`OpSpec` — a serializable handle on one registered operator
    (``parse("topk:k=0.01")`` ↔ ``to_dict()``/``from_dict()`` ↔
    ``build()``), validated against ``core.operators.OP_REGISTRY`` so
    unknown names or kwargs fail loudly instead of silently becoming
    Identity;
  * :class:`PolicySpec` — ordered ``(path-regex → OpSpec)`` rules with
    first-match-wins semantics plus an optional *global budget*
    allocator that splits one total survivor count across the matched
    leaves proportional to leaf size;
  * :class:`ChannelSpec` — an uplink/downlink pair of policies (the
    two wire directions of DESIGN.md §5);
  * :func:`resolve` — turns any of the above (or a plain operator, or
    a DSL string) into the per-leaf operator tree that
    ``kernels.dispatch.compress_tree`` / ``channel_compress_tree`` and
    the engines already accept.  Because the result is an ordinary
    tree of ``CompressionOp`` leaves, heterogeneous policies compose
    with megabuffer packing for free: dispatch buckets leaves by
    operator family, one kernel launch per family per direction.

DSL grammar (round-trips through ``to_string``)::

    policy   := side ( ">>" side )?          # uplink >> downlink
    side     := item ( ";" item )*
    item     := "budget=" number             # global-budget directive
              | [ pattern "->" ] opspec      # no pattern = catch-all
    opspec   := name ( ":" kv ( "," kv )* )?
    kv       := key "=" value                # int | float | bool | str

Patterns are Python regexes matched with ``re.search`` against the
leaf's ``/``-joined path (e.g. ``layers/attn/wq``); ``|`` alternation
is available since the direction separator is ``>>``.  Examples::

    topk:k=0.01                              # catch-all Top_k, 1%
    norm|bias|ln->identity; embed|head->qsgd:s=15; .*->topk:k=0.01
    budget=0.01; mlp|attn->topk; .*->identity
    topk:k=0.01 >> topk:k=0.05               # compressed downlink
"""

from __future__ import annotations

import dataclasses
import json
import re
import warnings
from typing import Any, Optional, Tuple, Union

import jax

from repro.core.operators import (
    OP_REGISTRY,
    CompressionOp,
    make_operator,
    spec_name_of,
)

#: DSL separators (see module docstring)
DIRECTION_SEP = ">>"
RULE_SEP = ";"
PATTERN_SEP = "->"

#: registry field name the budget allocator assigns
BUDGET_FIELD = "k"


# ---------------------------------------------------------------------------
# deprecation plumbing (shared by every policy-migration surface)
# ---------------------------------------------------------------------------


_WARNED_KEYS: set = set()


def warn_once(key: str, message: str, stacklevel: int = 3) -> None:
    """One-time (per process) DeprecationWarning — the RunConfig shims
    and the CLI legacy flags share this so warn-once semantics and
    formatting stay consistent across surfaces."""
    if key not in _WARNED_KEYS:
        warnings.warn(message, DeprecationWarning, stacklevel=stacklevel)
        _WARNED_KEYS.add(key)


# ---------------------------------------------------------------------------
# value (de)serialization
# ---------------------------------------------------------------------------


def _parse_value(text: str):
    t = text.strip()
    low = t.lower()
    if low in ("true", "false"):
        return low == "true"
    try:
        return int(t)
    except ValueError:
        pass
    try:
        return float(t)
    except ValueError:
        pass
    return t


def _format_value(v) -> str:
    if isinstance(v, bool):
        return "true" if v else "false"
    return repr(v) if isinstance(v, float) else str(v)


# ---------------------------------------------------------------------------
# OpSpec
# ---------------------------------------------------------------------------


@dataclasses.dataclass(frozen=True)
class OpSpec:
    """A registered operator name + its configurable kwargs.

    Hashable and order-normalized, so two specs describing the same
    operator compare equal; ``build()`` constructs the operator through
    ``operators.make_operator`` (registry-validated).
    """

    name: str
    kwargs: Tuple[Tuple[str, Any], ...] = ()

    def __post_init__(self):
        if self.name not in OP_REGISTRY:
            raise KeyError(
                f"unknown operator {self.name!r}; registered: "
                f"{sorted(OP_REGISTRY)}")
        object.__setattr__(self, "kwargs", tuple(sorted(self.kwargs)))
        entry = OP_REGISTRY[self.name]
        valid = entry.fields()
        for k, _ in self.kwargs:
            if k not in valid:
                raise TypeError(
                    f"operator {self.name!r} has no parameter {k!r}; "
                    f"valid: {sorted(valid)}")

    # -- construction ------------------------------------------------------
    @classmethod
    def parse(cls, text: str) -> "OpSpec":
        """``"topk:k=0.01,value_bits=32"`` → OpSpec."""
        t = text.strip()
        if not t:
            raise ValueError("empty operator spec")
        name, _, rest = t.partition(":")
        kw = {}
        if rest:
            for part in rest.split(","):
                k, sep, v = part.partition("=")
                if not sep:
                    raise ValueError(
                        f"malformed operator spec {text!r}: expected "
                        f"key=value, got {part!r}")
                kw[k.strip()] = _parse_value(v)
        return cls(name.strip(), tuple(kw.items()))

    @classmethod
    def of(cls, op: CompressionOp) -> "OpSpec":
        """The spec serializing an existing operator instance (only its
        non-default, non-pinned fields are recorded)."""
        name = spec_name_of(op)
        entry = OP_REGISTRY[name]
        kw = {k: getattr(op, k) for k, default in entry.fields().items()
              if getattr(op, k) != default}
        return cls(name, tuple(kw.items()))

    # -- serialization -----------------------------------------------------
    def to_string(self) -> str:
        if not self.kwargs:
            return self.name
        kv = ",".join(f"{k}={_format_value(v)}" for k, v in self.kwargs)
        return f"{self.name}:{kv}"

    def to_dict(self) -> dict:
        return {"op": self.name, **dict(self.kwargs)}

    @classmethod
    def from_dict(cls, d: dict) -> "OpSpec":
        d = dict(d)
        name = d.pop("op")
        return cls(name, tuple(d.items()))

    # -- resolution --------------------------------------------------------
    def takes(self, field: str) -> bool:
        return field in OP_REGISTRY[self.name].fields()

    def sets(self, field: str) -> bool:
        return any(k == field for k, _ in self.kwargs)

    def build(self, **extra) -> CompressionOp:
        return make_operator(self.name, **dict(self.kwargs), **extra)


# ---------------------------------------------------------------------------
# PolicySpec
# ---------------------------------------------------------------------------


@dataclasses.dataclass(frozen=True)
class PolicyRule:
    """One ordered rule: leaves whose path matches ``pattern`` (regex,
    ``re.search`` semantics) get ``op``.  First match wins."""

    pattern: str
    op: OpSpec

    def __post_init__(self):
        re.compile(self.pattern)  # fail at spec time, not resolve time

    def matches(self, path: str) -> bool:
        return re.search(self.pattern, path) is not None


@dataclasses.dataclass(frozen=True)
class PolicySpec:
    """Ordered path-regex rules + optional global-budget allocator.

    ``budget``: a total Top_k survivor budget shared by every leaf whose
    matching rule *takes* ``k`` but does not set it — an int is an
    absolute total count, a float in (0, 1) a fraction of the summed
    size of those leaves.  Each participating leaf i of size d_i gets
    ``k_i = max(1, round(K * d_i / Σ_j d_j))`` — the sparsity budget is
    spent proportional to leaf size (Wangni et al.).  Rules that set
    ``k`` explicitly are untouched by the allocator.
    """

    rules: Tuple[PolicyRule, ...]
    budget: Optional[Union[int, float]] = None

    def __post_init__(self):
        if not self.rules:
            raise ValueError("PolicySpec needs at least one rule")
        if self.budget is not None and not (
                isinstance(self.budget, int) and self.budget >= 1
                or isinstance(self.budget, float) and 0.0 < self.budget < 1.0):
            raise ValueError(
                f"budget must be an int count >= 1 or a fraction in "
                f"(0, 1); got {self.budget!r}")

    # -- construction ------------------------------------------------------
    @classmethod
    def parse(cls, text: str) -> "PolicySpec":
        """One DSL *side* (no ``>>``): ``item (";" item)*``."""
        rules, budget = [], None
        for raw in text.split(RULE_SEP):
            item = raw.strip()
            if not item:
                continue
            if item.startswith("budget="):
                if budget is not None:
                    raise ValueError(f"duplicate budget directive in {text!r}")
                budget = _parse_value(item[len("budget="):])
                continue
            if PATTERN_SEP in item:
                pat, _, spec = item.partition(PATTERN_SEP)
                rules.append(PolicyRule(pat.strip(), OpSpec.parse(spec)))
            else:
                rules.append(PolicyRule(".*", OpSpec.parse(item)))
        return cls(tuple(rules), budget)

    @classmethod
    def catch_all(cls, op: Union[OpSpec, str, CompressionOp]) -> "PolicySpec":
        if isinstance(op, CompressionOp):
            op = OpSpec.of(op)
        elif isinstance(op, str):
            op = OpSpec.parse(op)
        return cls((PolicyRule(".*", op),))

    # -- serialization -----------------------------------------------------
    def to_string(self) -> str:
        items = []
        if self.budget is not None:
            items.append(f"budget={_format_value(self.budget)}")
        for r in self.rules:
            items.append(r.op.to_string() if r.pattern == ".*"
                         else f"{r.pattern}{PATTERN_SEP}{r.op.to_string()}")
        return RULE_SEP.join(items)

    def to_dict(self) -> dict:
        d = {"rules": [{"match": r.pattern, **r.op.to_dict()}
                       for r in self.rules]}
        if self.budget is not None:
            d["budget"] = self.budget
        return d

    @classmethod
    def from_dict(cls, d: dict) -> "PolicySpec":
        rules = []
        for rd in d["rules"]:
            rd = dict(rd)
            pat = rd.pop("match", ".*")
            rules.append(PolicyRule(pat, OpSpec.from_dict(rd)))
        return cls(tuple(rules), d.get("budget"))

    # -- resolution --------------------------------------------------------
    def match(self, path: str) -> Optional[PolicyRule]:
        for r in self.rules:
            if r.matches(path):
                return r
        return None

    def resolve(self, params) -> Any:
        """Per-leaf operator tree in ``params``' structure — the form
        ``compress_tree``/``channel_compress_tree``/``engine.make_step``
        accept.  Every leaf must match a rule; end the policy with a
        catch-all (``.*->identity``) rather than relying on a silent
        default."""
        paths, leaves, treedef = tree_paths(params)
        matched = [self.match(p) for p in paths]
        missing = [p for p, m in zip(paths, matched) if m is None]
        if missing:
            raise ValueError(
                f"policy matches no rule for leaves {missing}; add a "
                f"final catch-all rule (e.g. '.*->identity')")
        # global-budget allocation (proportional to leaf size)
        budgeted = [i for i, m in enumerate(matched)
                    if self.budget is not None
                    and m.op.takes(BUDGET_FIELD)
                    and not m.op.sets(BUDGET_FIELD)]
        k_of = {}
        if budgeted:
            sizes = [int(leaves[i].size) for i in budgeted]
            total_d = sum(sizes)
            K = (int(self.budget) if isinstance(self.budget, int)
                 else max(1, round(self.budget * total_d)))
            for i, d_i in zip(budgeted, sizes):
                k_of[i] = max(1, min(d_i, round(K * d_i / total_d)))
        ops = []
        for i, m in enumerate(matched):
            extra = {BUDGET_FIELD: k_of[i]} if i in k_of else {}
            ops.append(m.op.build(**extra))
        return jax.tree_util.tree_unflatten(treedef, ops)


# ---------------------------------------------------------------------------
# ChannelSpec
# ---------------------------------------------------------------------------


@dataclasses.dataclass(frozen=True)
class ChannelSpec:
    """The two wire directions (DESIGN.md §5) as one spec: an uplink
    policy and an optional downlink policy (None = exact broadcast)."""

    uplink: PolicySpec
    downlink: Optional[PolicySpec] = None

    @classmethod
    def parse(cls, text: str) -> "ChannelSpec":
        parts = text.split(DIRECTION_SEP)
        if len(parts) > 2:
            raise ValueError(
                f"at most one {DIRECTION_SEP!r} (uplink >> downlink) "
                f"allowed; got {text!r}")
        up = PolicySpec.parse(parts[0])
        down = PolicySpec.parse(parts[1]) if len(parts) == 2 else None
        return cls(up, down)

    def to_string(self) -> str:
        if self.downlink is None:
            return self.uplink.to_string()
        return (f"{self.uplink.to_string()} {DIRECTION_SEP} "
                f"{self.downlink.to_string()}")

    def to_dict(self) -> dict:
        return {
            "uplink": self.uplink.to_dict(),
            "downlink": (None if self.downlink is None
                         else self.downlink.to_dict()),
        }

    @classmethod
    def from_dict(cls, d: dict) -> "ChannelSpec":
        down = d.get("downlink")
        return cls(PolicySpec.from_dict(d["uplink"]),
                   None if down is None else PolicySpec.from_dict(down))

    def resolve(self, params) -> Tuple[Any, Optional[Any]]:
        """(uplink_op_tree, downlink_op_tree | None)."""
        up = self.uplink.resolve(params)
        down = (None if self.downlink is None
                else self.downlink.resolve(params))
        return up, down


# ---------------------------------------------------------------------------
# top-level entries
# ---------------------------------------------------------------------------


PolicyLike = Union[str, OpSpec, PolicySpec, ChannelSpec, CompressionOp]


def parse(text: str) -> Union[PolicySpec, ChannelSpec]:
    """Parse a DSL string: a ChannelSpec when it carries a downlink
    side (``>>``), else a PolicySpec."""
    if DIRECTION_SEP in text:
        return ChannelSpec.parse(text)
    return PolicySpec.parse(text)


def from_dict(d: dict) -> Union[OpSpec, PolicySpec, ChannelSpec]:
    """Dispatch on the dict shape: {"uplink": ...} → ChannelSpec,
    {"rules": ...} → PolicySpec, {"op": ...} → OpSpec."""
    if "uplink" in d:
        return ChannelSpec.from_dict(d)
    if "rules" in d:
        return PolicySpec.from_dict(d)
    if "op" in d:
        return OpSpec.from_dict(d)
    raise ValueError(
        f"unrecognized policy dict (expected 'uplink', 'rules' or 'op' "
        f"key): {sorted(d)}")


def load(text: str) -> Union[PolicySpec, ChannelSpec]:
    """CLI argument form: an inline DSL string, or ``@file.json`` whose
    contents are a ``to_dict()`` serialization."""
    if text.startswith("@"):
        with open(text[1:]) as f:
            spec = from_dict(json.load(f))
        if isinstance(spec, OpSpec):
            return PolicySpec.catch_all(spec)
        return spec
    return parse(text)


def as_channel_spec(policy: PolicyLike) -> ChannelSpec:
    """Normalize any policy-like value to a ChannelSpec."""
    if isinstance(policy, str):
        policy = parse(policy)
    if isinstance(policy, CompressionOp):
        policy = PolicySpec.catch_all(policy)
    if isinstance(policy, OpSpec):
        policy = PolicySpec.catch_all(policy)
    if isinstance(policy, PolicySpec):
        policy = ChannelSpec(policy)
    if not isinstance(policy, ChannelSpec):
        raise TypeError(f"not a policy: {policy!r}")
    return policy


def resolve(policy: PolicyLike, params) -> Any:
    """One-direction resolution: any policy-like value → the per-leaf
    operator tree the engines/dispatch accept.  Plain operators (and
    operator trees) pass through untouched, so existing call sites keep
    their exact semantics."""
    if isinstance(policy, str):
        policy = parse(policy)
        if isinstance(policy, ChannelSpec):
            raise ValueError(
                "this surface takes a single direction; the '>>' downlink "
                "side belongs in a ChannelSpec-aware caller")
    if isinstance(policy, OpSpec):
        policy = PolicySpec.catch_all(policy)
    if isinstance(policy, PolicySpec):
        return policy.resolve(params)
    return policy  # CompressionOp or pre-resolved tree: pass through


# ---------------------------------------------------------------------------
# path / leaf-group helpers (shared with the per-leaf bits ledger)
# ---------------------------------------------------------------------------


def _path_str(path) -> str:
    return "/".join(str(getattr(p, "key", getattr(p, "idx", p)))
                    for p in path)


def tree_paths(tree):
    """(paths, leaves, treedef): '/'-joined key paths per leaf, in
    flatten order (the order every compression path iterates)."""
    flat, treedef = jax.tree_util.tree_flatten_with_path(tree)
    paths = [_path_str(p) for p, _ in flat]
    leaves = [l for _, l in flat]
    return paths, leaves, treedef


def leaf_groups(tree) -> Tuple[Tuple[str, ...], Tuple[int, ...]]:
    """Top-level leaf grouping for the per-leaf bits ledger: group names
    (first path component, sorted) and each leaf's group index, in
    flatten order."""
    paths, _, _ = tree_paths(tree)
    tops = [p.split("/")[0] if p else "<root>" for p in paths]
    names = tuple(sorted(set(tops)))
    index = {n: i for i, n in enumerate(names)}
    return names, tuple(index[t] for t in tops)

"""Production multi-pod engine for Qsparse-local-SGD.

Mapping onto the TPU mesh (see DESIGN.md §4):

  * Qsparse worker r  <->  one (pod, data) mesh row.  ``R = pod * data``.
  * tensor parallelism lives on the 'model' axis and is left to XLA SPMD:
    we shard_map with ``axis_names={'pod','data'}`` (manual) only.
  * the compressed aggregation  x_{t+1} = x_t - (1/R) sum_r g_r  is an
    explicit ``psum`` over the manual axes — the only cross-worker
    communication the algorithm performs.
  * compression is applied **per model shard** (each worker compresses
    the slice of each leaf it owns together with its TP group): we pick
    the top-k axis per leaf to be an *unsharded* axis so XLA keeps
    lax.top_k shard-local — this is Corollary 1 (piecewise compression)
    across shards; no gather enters the compression path.

Two statically-specialized step functions are built:

  * ``local_step``  — Algorithm-1 lines 5-7 (no communication beyond TP)
  * ``sync_step``   — lines 8-11 + master update (compressed psum)

The host trainer drives the schedule (``I_T``), which also keeps
collectives out of lax.cond and makes the dry-run/roofline artifacts
cleanly separable per step kind.

State layout (leading axes refer to the *global* array view):

  master : params pytree; replicated over ('pod','data') by default, or
           ZeRO-1-sharded over ('pod','data') on axis 0 when zero1=True
           (beyond-paper optimization, §Perf).
  local / memory / inner : one leading worker axis of size R, sharded
           P(('pod','data')) — physically one replica per worker.
  view / down_memory : same worker layout; only with a compressed
           ``downlink=`` channel (DESIGN.md §5) — each worker's lagging
           master view and the server-side downlink error memory.
"""

from __future__ import annotations

import dataclasses
import warnings
from typing import Any, Callable, NamedTuple, Optional, Sequence

import jax
import jax.numpy as jnp
from jax.sharding import NamedSharding, PartitionSpec as P

from repro.compat import (MODERN, axis_size, round_scan_supported,
                          shard_map, sharding_constraints_usable)
from repro.core import bits as bitlib
from repro.core import channel as chn
from repro.core.operators import (
    CompressionOp,
    Identity,
    RowSignTopK,
    RowTopK,
    SignSparsifier,
    TopK,
    ops_for_leaves,
    resolve_k,
)
from repro.optim.transforms import GradientTransform, apply_updates


class DistQsparseState(NamedTuple):
    master: Any
    local: Any            # leading worker axis R
    memory: Any           # leading worker axis R
    inner: Any            # leading worker axis R
    step: jnp.ndarray
    bits: jnp.ndarray     # uplink wire bits (worker → server)
    rounds: jnp.ndarray
    # downlink channel state (DESIGN.md §5) — populated only with a
    # compressed ``downlink=`` ShardCompressor in make_dist_steps:
    # view is x_t^{(r)} (each worker's lagging copy of the master),
    # down_memory the server-side per-worker error memory md^{(r)}
    view: Any = None          # leading worker axis R
    down_memory: Any = None   # leading worker axis R
    bits_down: Any = None     # downlink wire bits (server → worker)
    # staleness-first fault runtime (DESIGN.md §9) — populated only by
    # make_dist_fault_steps: the bounded per-worker in-flight payload
    # queue.  Dense wire: a master-shaped pytree of [R, depth, ...]
    # buffers; sparse wire: the compact (idx, val) wire buffers per
    # leaf, [R, depth, ..., kcap].  arrive_at[r, s] is the global step
    # at which slot s lands on the master (-1 = empty), inflight_tau
    # its staleness τ.
    inflight: Any = None
    arrive_at: Any = None     # int32 [R, depth]
    inflight_tau: Any = None  # int32 [R, depth]


# ---------------------------------------------------------------------------
# shard-local compression
# ---------------------------------------------------------------------------


def _pick_axis(shape: tuple[int, ...], spec: Optional[P]) -> int:
    """First axis not sharded by 'model' (prefer the last one)."""
    if spec is None:
        return len(shape) - 1
    entries = list(spec) + [None] * (len(shape) - len(spec))

    def uses_model(e):
        if e is None:
            return False
        if isinstance(e, (tuple, list)):
            return "model" in e
        return e == "model"

    for ax in range(len(shape) - 1, -1, -1):
        if not uses_model(entries[ax]) and shape[ax] > 1:
            return ax
    return len(shape) - 1


def axis_topk_compact(x: jnp.ndarray, k_frac: float, axis: int,
                      sign_bits: bool = False, dispatch_cfg=None):
    """Top-k along ``axis`` in *compact* form (DESIGN.md §3.3).

    Returns (idx [..., kcap] int32, val [..., kcap] f32, mem — the
    fused error memory in ``x``'s layout (f32) —, wire_bits,
    moved_shape) where idx/val live on the moved-to-last layout, with
    per-row indices relative to that last axis and empty slots holding
    the out-of-row sentinel (idx = n, val = 0).  Shard-local by
    construction when ``axis`` is unsharded.

    Sort-free on both routes: the compact Pallas kernel when the row
    is eligible (``dispatch_cfg``), else the scatter-free jnp oracle —
    either traces without ``lax.top_k``, which the 0.4.x SPMD
    partitioner cannot partition inside partial-manual regions, so the
    sparse-allgather aggregation runs on this container too.

    Wire bits are *counted* from the actual survivors (exact zeros
    excluded), matching the dense path's ledger convention.
    """
    from repro.kernels import dispatch as dsp
    n = x.shape[axis]
    k = resolve_k(k_frac, n)
    kcap = dsp.capacity(k, n)
    xm = jnp.moveaxis(x.astype(jnp.float32), axis, -1)
    rows = xm.reshape(-1, n)
    idx, val, mem, cnt = dsp.compact_rows(
        rows, k, kcap, sign=sign_bits, cfg=dispatch_cfg, leaf_size=x.size)
    nrows = rows.shape[0]
    counted = (bitlib.bits_signtopk_counted if sign_bits
               else bitlib.bits_topk_counted)
    bits = (jnp.float32(32 * nrows) + counted(n, jnp.sum(cnt))
            - jnp.float32(32))
    idx = idx.reshape(xm.shape[:-1] + (kcap,))
    val = val.reshape(xm.shape[:-1] + (kcap,))
    mem = jnp.moveaxis(mem.reshape(xm.shape), -1, axis)
    return idx, val, mem, bits, xm.shape


def _densify(idx, sel, moved_shape, axis):
    """Dense decode of compact (idx, sel) buffers on the moved layout —
    dispatch.decode_rows per compression row (sentinel slots drop, so
    fixed-capacity buffers decode without a length field)."""
    from repro.kernels.dispatch import decode_rows
    kcap = idx.shape[-1]
    out = decode_rows(idx.reshape(-1, kcap), sel.reshape(-1, kcap),
                      moved_shape[-1])
    return jnp.moveaxis(out.reshape(moved_shape), -1, axis)


def _threshold_axis_topk(x: jnp.ndarray, k_frac: float, axis: int,
                         sign_bits: bool, select):
    """Shared dense Top_k-along-axis plumbing: move ``axis`` last, shape
    [rows, n], run ``select(rows2d, k, sign) -> (sel, mem, cnt)`` (the
    Pallas kernel or its jnp oracle), move back, charge counted bits."""
    n = x.shape[axis]
    k = resolve_k(k_frac, n)
    xm = jnp.moveaxis(x.astype(jnp.float32), axis, -1)
    rows = xm.reshape(-1, n)
    sel, _mem, cnt = select(rows, k, sign_bits)
    out = jnp.moveaxis(sel.reshape(xm.shape), -1, axis)
    nrows = rows.shape[0]
    counted = (bitlib.bits_signtopk_counted if sign_bits
               else bitlib.bits_topk_counted)
    bits = (jnp.float32(32 * nrows) + counted(n, jnp.sum(cnt))
            - jnp.float32(32))
    return out, bits


def axis_topk(x: jnp.ndarray, k_frac: float, axis: int,
              sign_bits: bool = False) -> tuple[jnp.ndarray, jnp.ndarray]:
    """Dense Top_k along ``axis`` via the bisection *threshold select*
    (kernels/ref.py; exact-k generically — DESIGN.md §3.1).

    Sort- and scatter-free on purpose: ``lax.top_k`` hard-crashes the
    0.4.x SPMD partitioner inside a partial-manual shard_map region,
    and on TPU the threshold form is the fast path anyway (§3)."""
    from repro.kernels.ref import topk_compress_ref
    return _threshold_axis_topk(
        x, k_frac, axis, sign_bits,
        lambda rows, k, sign: topk_compress_ref(rows, k, sign=sign))


@dataclasses.dataclass(frozen=True)
class ShardCompressor:
    """Leafwise shard-local compressor for the distributed engine.

    mode: 'topk' (full-precision survivors) | 'signtopk' (1-bit survivors)
          | 'none' (Identity — vanilla/local-SGD baselines)
          | 'policy' (heterogeneous per-leaf operators, DESIGN.md §6:
          ``ops`` carries the resolved operator tree — build through
          :meth:`from_spec`)
    k_frac: survivor fraction along the chosen axis per leaf
          (homogeneous modes only; policy mode reads each op's own k).
    dispatch: kernel routing policy (see kernels/dispatch.py) — 'auto'
          runs the fused Pallas Top_k kernels on TPU for lane-aligned
          compression rows, 'kernel' forces them (interpret off-TPU),
          'reference' keeps the pure-jnp threshold path.  Both the
          dense form (``__call__``) and the compact wire form
          (``compact``) dispatch: the compact-emitting kernel writes
          (idx, val) survivor buffers plus the fused error memory
          directly (DESIGN.md §3.3), with the scatter-free jnp oracle
          as its transparent fallback.
    ops:  policy mode only — a ``CompressionOp`` tree (or single op) in
          the grads' structure, as produced by ``core.policy.resolve``.
          Per leaf: Top_k-family ops run the shard-local axis-Top_k
          paths (sparse wire form available; op.k is the survivor
          fraction/count along the chosen axis), Identity transmits
          dense, and every other operator (QSGD, k-level, Rand_k, the
          composed sparsifiers) runs its reference form shard-locally
          on the leaf and travels as a dense payload — Corollary 1
          piecewise compression across shards either way.
    """

    mode: str = "topk"
    k_frac: float = 0.01
    dispatch: str = "auto"
    ops: Any = None

    @classmethod
    def from_spec(cls, spec, params,
                  dispatch: str = "auto") -> "Optional[ShardCompressor]":
        """Build from any ``core.policy`` spec (PolicySpec/OpSpec/DSL
        string/operator tree), resolved per leaf against ``params``.
        Returns None for an all-Identity policy (= no compression).

        The shard paths select Top_k per compression *row* (the chosen
        unsharded axis), so a global-Top_k op with an **absolute** k —
        a whole-leaf survivor count, e.g. from the budget allocator —
        is normalized here to the equivalent leaf fraction ``k / d``
        (the per-row counts then sum back to ~k across the leaf's rows
        instead of selecting k per row, §6.4).  Fractional k and the
        per-row ops (RowTopK/RowSignTopK, whose k is per-row by
        definition) pass through untouched.
        """
        from repro.core import policy as pol
        op_tree = pol.resolve(spec, params)
        leaves = jax.tree_util.tree_leaves(params)
        ops_list = ops_for_leaves(op_tree, len(leaves))
        norm = [cls._normalize_leaf_op(op, int(leaf.size))
                for op, leaf in zip(ops_list, leaves)]
        op_tree = jax.tree_util.tree_unflatten(
            jax.tree_util.tree_structure(params), norm)
        comp = cls(mode="policy", dispatch=dispatch, ops=op_tree)
        return None if comp.is_identity() else comp

    @staticmethod
    def _normalize_leaf_op(op: CompressionOp, d: int) -> CompressionOp:
        """Absolute whole-leaf k → leaf fraction for global-Top_k ops
        (see :meth:`from_spec`).  ``1 − 1e-9`` keeps an everything-
        survives k inside resolve_k's fraction regime."""
        if not (isinstance(op, TopK) or (isinstance(op, SignSparsifier)
                                         and op.sparsifier == "top")):
            return op
        if isinstance(op.k, float) and 0.0 < op.k < 1.0:
            return op
        frac = min(1.0 - 1e-9, float(op.k) / max(d, 1))
        return dataclasses.replace(op, k=frac)

    def is_identity(self) -> bool:
        if self.mode == "none":
            return True
        if self.mode != "policy":
            return False
        leaves = jax.tree_util.tree_leaves(
            self.ops, is_leaf=lambda o: isinstance(o, CompressionOp))
        return all(isinstance(o, Identity) for o in leaves)

    def _dispatch_cfg(self):
        from repro.kernels.dispatch import DispatchConfig
        return DispatchConfig(mode=self.dispatch)

    def _plans(self, n_leaves: int):
        """Per-leaf execution plan: ("skip",), ("axis", k, sign_bits)
        or ("ref", op) — shared by the dense path, the compact path and
        the payload-kind metadata so all three always agree."""
        if self.mode == "policy":
            plans = []
            for op in ops_for_leaves(self.ops, n_leaves):
                if isinstance(op, Identity):
                    plans.append(("skip",))
                elif isinstance(op, (TopK, RowTopK)):
                    plans.append(("axis", op.k, False))
                elif isinstance(op, RowSignTopK) or (
                        isinstance(op, SignSparsifier)
                        and op.sparsifier == "top"):
                    plans.append(("axis", op.k, True))
                else:
                    plans.append(("ref", op))
            return plans
        if self.mode == "none":
            return [("skip",)] * n_leaves
        if self.mode not in ("topk", "signtopk"):
            raise ValueError(
                f"unknown ShardCompressor mode {self.mode!r}; expected "
                f"'topk' | 'signtopk' | 'none' | 'policy'")
        return [("axis", self.k_frac, self.mode == "signtopk")] * n_leaves

    @staticmethod
    def _skip(g) -> bool:
        """Tiny/scalar leaves transmit dense regardless of plan."""
        return g.ndim == 0 or g.size <= 8

    def _ref_leaf(self, op: CompressionOp, g, key, i: int):
        """Reference-operator leaf (dense payload): shard-local
        ``op(key_i, g)``.  Stochastic ops draw from ``key`` folded with
        the leaf index; the key is replicated over the worker axes, so
        the draw is shared across workers (the accumulators differ, so
        per-worker unbiasedness is unaffected)."""
        if op.stochastic and key is None:
            raise ValueError(
                f"stochastic operator {type(op).__name__} in a "
                f"ShardCompressor policy needs a key (thread key= "
                f"through apply/compact)")
        k_i = jax.random.fold_in(key, i) if op.stochastic else None
        out, b = op(k_i, g)
        return out.astype(jnp.float32), jnp.asarray(b, jnp.float32)

    def _kernel_leaf(self, g, k_frac, ax, sign):
        """Fused-kernel variant of ``axis_topk`` (dense survivors)."""
        from repro.kernels import dispatch as dsp
        cfg = self._dispatch_cfg()
        return _threshold_axis_topk(
            g, k_frac, ax, sign,
            lambda rows, k, sign_: dsp.topk_rows(rows, k, sign=sign_,
                                                 cfg=cfg))

    def __call__(self, grads, param_specs, key=None):
        from repro.kernels import dispatch as dsp
        dcfg = self._dispatch_cfg()
        leaves, treedef = jax.tree_util.tree_flatten(grads)
        specs = self._leaf_specs(param_specs, len(leaves))
        plans = self._plans(len(leaves))
        outs, bit_terms = [], []
        for i, (g, spec, plan) in enumerate(zip(leaves, specs, plans)):
            if plan[0] == "skip" or self._skip(g):
                outs.append(g.astype(jnp.float32))
                bit_terms.append(jnp.asarray(bitlib.bits_dense(g.size), jnp.float32))
                continue
            if plan[0] == "ref":
                o, b = self._ref_leaf(plan[1], g, key, i)
                outs.append(o)
                bit_terms.append(b)
                continue
            _, k_frac, sign = plan
            ax = _pick_axis(g.shape, spec)
            if dsp.rows_eligible(g.shape[ax], dcfg, leaf_size=g.size):
                o, b = self._kernel_leaf(g, k_frac, ax, sign)
            else:
                o, b = axis_topk(g, k_frac, ax, sign_bits=sign)
            if spec is not None and sharding_constraints_usable():
                # pin the densified update to the leaf's TP sharding: the
                # top_k/scatter pair otherwise makes XLA re-shard (an
                # all-gather per leaf — §Perf iteration 2 finding).  A
                # constraint naming auto axes inside a partial-manual
                # region crashes the 0.4.x SPMD partitioner, so the pin
                # is modern-jax only (pure perf, not correctness).
                entries = list(spec) + [None] * (g.ndim - len(tuple(spec)))
                o = jax.lax.with_sharding_constraint(o, P(*entries))
            outs.append(o)
            bit_terms.append(b)
        bits = jnp.sum(jnp.stack(bit_terms))
        return jax.tree_util.tree_unflatten(treedef, outs), bits

    def _leaf_specs(self, param_specs, n):
        if param_specs is None:
            return [None] * n
        return jax.tree_util.tree_leaves(
            param_specs, is_leaf=lambda z: isinstance(z, P) or z is None
        )

    def compact(self, grads, param_specs, key=None):
        """Compress to the compact wire form (§Perf beyond-paper
        aggregation): per leaf either ("dense", g) for skipped /
        reference-operator leaves (the latter carry the *compressed*
        dense payload) or ("sparse", idx, val, axis, moved_shape), with
        indices row-local to the moved-to-last compression axis
        (shard-local offsets — the model-sharded axes never enter the
        index space) and empty slots carrying the out-of-row sentinel.
        The fused error memories ride along so the sync body never
        densifies.

        Returns (list_of_leaf_payloads, treedef, wire_bits, mem_tree).
        """
        dcfg = self._dispatch_cfg()
        leaves, treedef = jax.tree_util.tree_flatten(grads)
        specs = self._leaf_specs(param_specs, len(leaves))
        plans = self._plans(len(leaves))
        payloads, bit_terms, mems = [], [], []
        for i, (g, spec, plan) in enumerate(zip(leaves, specs, plans)):
            if plan[0] == "skip" or self._skip(g):
                g32 = g.astype(jnp.float32)
                payloads.append(("dense", g32))
                mems.append(jnp.zeros_like(g32))
                bit_terms.append(
                    jnp.asarray(bitlib.bits_dense(g.size), jnp.float32))
                continue
            if plan[0] == "ref":
                q, b = self._ref_leaf(plan[1], g, key, i)
                payloads.append(("dense", q))
                mems.append(g.astype(jnp.float32) - q)
                bit_terms.append(b)
                continue
            _, k_frac, sign = plan
            ax = _pick_axis(g.shape, spec)
            idx, val, mem, b, moved = axis_topk_compact(
                g, k_frac, ax, sign_bits=sign, dispatch_cfg=dcfg)
            payloads.append(("sparse", idx, val, ax, moved))
            mems.append(mem)
            bit_terms.append(b)
        bits = jnp.sum(jnp.stack(bit_terms))
        mem_tree = jax.tree_util.tree_unflatten(treedef, mems)
        return payloads, treedef, bits, mem_tree

    def leaf_meta(self, master_tree, param_specs):
        """Payload-kind metadata per leaf, mirroring :meth:`compact`'s
        decisions on the *global* leaf shapes: ("sparse", axis,
        moved_shape) for axis-Top_k leaves, ("dense", None, None) for
        everything else.  The sparse sync bodies size their out_specs
        from this, so it must stay in lockstep with compact()."""
        leaves = jax.tree_util.tree_flatten(master_tree)[0]
        specs = self._leaf_specs(param_specs, len(leaves))
        plans = self._plans(len(leaves))
        meta = []
        for g, spec, plan in zip(leaves, specs, plans):
            if plan[0] != "axis" or self._skip(g):
                meta.append(("dense", None, None))
                continue
            ax = _pick_axis(g.shape, spec)
            moved = jnp.moveaxis(
                jnp.empty(g.shape, jnp.float32), ax, -1).shape
            meta.append(("sparse", ax, moved))
        return meta

    def would_kernel_dispatch(self) -> bool:
        """Could this compressor launch Pallas kernels as configured?
        (the 0.4.x TP>1 dense-psum guard's probe)"""
        if self.is_identity() or self.dispatch == "reference":
            return False
        return self.dispatch == "kernel" or (
            self.dispatch == "auto" and jax.default_backend() == "tpu")

    def gamma(self) -> float:
        if self.mode == "policy":
            gs = []
            for op in jax.tree_util.tree_leaves(
                    self.ops, is_leaf=lambda o: isinstance(o, CompressionOp)):
                if isinstance(op, Identity):
                    gs.append(1.0)
                elif hasattr(op, "k") and isinstance(op.k, float) \
                        and 0.0 < op.k < 1.0:
                    gs.append(op.k)
                else:
                    gs.append(0.0)  # unknown/absolute-k: conservative
            return min(gs) if gs else 1.0
        return 1.0 if self.mode == "none" else self.k_frac


# ---------------------------------------------------------------------------
# engine
# ---------------------------------------------------------------------------


_TP_KERNEL_WARNED = set()


def _legacy_tp_kernel_guard(compressor: Optional[ShardCompressor], mesh,
                            daxes, wire: str,
                            direction: str = "uplink"):
    """0.4.x partial-manual guard (ROADMAP known issue): on TP>1 legacy
    meshes the ``dense_psum`` sync body cannot host Pallas kernels —
    the uplink output feeds an in-body ``pmean`` over an auto-axis-
    sharded operand, and even the downlink's collective-free kernel
    launches trip the same ``IsManualSubgroup`` CHECK inside that
    region (reproduced; only the compact sparse path, whose buffers
    leave via out_specs, lowers with kernels there).  Auto-downgrade
    the affected channel to reference dispatch with a one-time warning
    per direction instead of hard-crashing — outputs and ledger are
    identical, only speed differs.
    """
    if MODERN or wire != "dense_psum" or compressor is None:
        return compressor
    tp = any(mesh.shape[a] > 1 for a in mesh.axis_names if a not in daxes)
    if not (tp and compressor.would_kernel_dispatch()):
        return compressor
    if direction not in _TP_KERNEL_WARNED:
        warnings.warn(
            "ShardCompressor(dispatch=%r) with dense psum aggregation "
            "cannot run the Pallas kernels inside a 0.4.x partial-manual "
            "region with a >1 tensor-parallel axis (XLA IsManualSubgroup); "
            "downgrading the %s to reference dispatch. Use "
            "aggregate='sparse_allgather' (kernel-capable there) or a "
            "modern jax to keep the kernel path."
            % (compressor.dispatch, direction),
            stacklevel=3)
        _TP_KERNEL_WARNED.add(direction)
    return dataclasses.replace(compressor, dispatch="reference")


def worker_count(mesh, data_axes: Sequence[str]) -> int:
    out = 1
    for a in data_axes:
        out *= mesh.shape[a]
    return out


def state_shardings(mesh, data_axes: Sequence[str], param_specs, state_tree):
    """NamedShardings for DistQsparseState (for jit in_shardings / init)."""
    daxes = tuple(data_axes)

    def master_spec(spec):
        return spec if spec is not None else P()

    def worker_spec(spec):
        inner = tuple(spec) if spec is not None else ()
        return P(daxes, *inner)

    master = jax.tree_util.tree_map(
        lambda s: NamedSharding(mesh, master_spec(s)), param_specs,
        is_leaf=lambda z: isinstance(z, P) or z is None,
    )
    worker = jax.tree_util.tree_map(
        lambda s: NamedSharding(mesh, worker_spec(s)), param_specs,
        is_leaf=lambda z: isinstance(z, P) or z is None,
    )
    return master, worker


def make_dist_steps(
    grad_fn: Callable,                 # (params, batch) -> (loss, grads)
    inner_opt: GradientTransform,
    compressor: ShardCompressor,
    lr_schedule: Callable,
    mesh,
    data_axes: Sequence[str] = ("data",),
    param_specs=None,                  # pytree of P for leaves (model axis)
    zero1: bool = False,
    aggregate: str = "mean_R",         # master division rule (DESIGN.md §8)
    downlink: Optional[ShardCompressor] = None,
    wire: str = "dense_psum",          # "dense_psum" | "sparse_allgather"
    partial: bool = False,
):
    """Returns (init_fn, local_step, sync_step).

    ``batch`` leaves carry a leading worker axis R sharded over
    data_axes.  Inside the manual region every worker sees leading dim 1.

    ``downlink``: server→worker compression channel (DESIGN.md §5) — a
    second ShardCompressor applied to each worker's master delta
    ``x̄_{t+1} − x_t^{(r)}`` against a server-side per-worker error
    memory before the broadcast; the worker's view (= its post-sync
    local iterate) then advances by the decompressed delta only, and
    the uplink compresses against that lagging view.  None (or mode
    "none") keeps the exact dense broadcast — bit-for-bit today's
    trajectories — while charging its dense cost to ``bits_down``.

    ``wire``: the sync round's wire format — "dense_psum" (in-body
    pmean ring all-reduce) or "sparse_allgather" (compact (idx, val)
    survivor buffers leave the manual region, dense decode in the auto
    region; DESIGN.md §3.3).  Identical math and ledger either way.

    ``aggregate``: the master's division rule over the syncing subset
    (DESIGN.md §8) — "mean_R" (the paper's Σ/R, bit-for-bit historical),
    "mean_S" (divide by |S|), or "support_weighted" (per-coordinate
    survivor counts with the zero-support guard).  For backward
    compatibility a wire-format value passed here is remapped onto
    ``wire=`` with a one-time warning.

    ``partial``: accept per-step participation masks (fleet scenarios,
    ``core/scenarios.py``) — ``sync_step``/``round_fn`` then take a
    trailing ``sync_mask`` bool[R] argument; workers with a False bit
    contribute nothing, keep their error memory, and continue from
    their own half-step iterate against a lagging master *view* (the
    state carries ``view`` even without a downlink).  With
    ``partial=False`` nothing changes: no extra state, bit-for-bit
    today's trajectories.
    """
    from repro.core import policy as pol
    from repro.core.scenarios import validate_aggregate
    if aggregate in ("dense_psum", "sparse_allgather"):
        pol.warn_once(
            "dist-aggregate-wire",
            "aggregate= now names the aggregation rule ('mean_R' | "
            "'mean_S' | 'support_weighted'); wire formats moved to "
            f"wire=. Mapping aggregate={aggregate!r} to wire= with "
            "aggregate='mean_R' (the historical behaviour).")
        wire, aggregate = aggregate, "mean_R"
    validate_aggregate(aggregate)
    if wire not in ("dense_psum", "sparse_allgather"):
        raise ValueError(f"unknown wire {wire!r}; expected 'dense_psum' "
                         f"| 'sparse_allgather'")
    daxes = tuple(data_axes)
    R = worker_count(mesh, daxes)
    manual = set(daxes)
    compressor = _legacy_tp_kernel_guard(compressor, mesh, daxes, wire)
    downlink = _legacy_tp_kernel_guard(downlink, mesh, daxes, wire,
                                       direction="downlink")
    up = chn.ShardChannel(compressor, "uplink")
    down = chn.ShardChannel(downlink, "downlink")
    down_active = not down.is_identity()
    # partial participation needs each worker's lagging master view even
    # without a compressed downlink (non-syncers fall behind the master)
    carry_view = down_active or partial

    def _spec_leaves_for(tree):
        is_spec = lambda z: isinstance(z, P) or z is None
        if param_specs is None:
            return None
        flat = jax.tree_util.tree_leaves(param_specs, is_leaf=is_spec)
        n = len(jax.tree_util.tree_leaves(tree))
        if len(flat) != n:
            reps = max(1, n // len(flat))
            flat = flat * reps
        return flat

    def _z1mask(master):
        """Per-leaf ZeRO-1 shard axis (int; -1 = replicated)."""
        leaves, td = jax.tree_util.tree_flatten(master)
        specs = _spec_leaves_for(master) or [None] * len(leaves)
        mask = []
        for x, sp in zip(leaves, specs):
            ax = _zero1_axis(x.shape, sp, R) if zero1 else None
            mask.append(-1 if ax is None else ax)
        return jax.tree_util.tree_unflatten(td, mask)

    def _gather_master(master, z1):
        return jax.tree_util.tree_map(
            lambda x, m: _allgather_axis(x, daxes, m) if m >= 0 else x,
            master, z1)

    def _scatter_master(master, z1):
        return jax.tree_util.tree_map(
            lambda x, m: _shard_axis(x, daxes, m) if m >= 0 else x,
            master, z1)

    def _master_in_specs(z1):
        if not zero1:
            return P()
        return jax.tree_util.tree_map(
            lambda m: P(*([None] * m), tuple(daxes)) if m >= 0 else P(), z1)

    def _squeeze(tree):
        return jax.tree_util.tree_map(lambda x: x[0], tree)

    def _expand(tree):
        return jax.tree_util.tree_map(lambda x: x[None], tree)

    # ---- local phase (shared) ------------------------------------------
    def _local(master, local, memory, inner, step, batch, lr):
        params = _squeeze(local)
        data = _squeeze(batch)
        loss, grads = grad_fn(params, data)
        updates, inner_new = inner_opt.update(grads, _squeeze(inner), params, lr)
        half = apply_updates(params, updates)
        return half, inner_new, loss

    # ---- local step -----------------------------------------------------
    def local_body(master, local, memory, inner, step, batch, key):
        lr = lr_schedule(step)
        half, inner_new, loss = _local(master, local, memory, inner, step, batch, lr)
        loss = jax.lax.pmean(loss, daxes)
        return _expand(half), _expand(inner_new), loss

    # ---- aggregation rules (DESIGN.md §8) -------------------------------
    def _aggregate_psum(g, s_f):
        """Masked payload tree → the master's per-coordinate divisor.
        ``s_f`` is this worker's participation as f32 (1.0 when the
        step was built without masks).  mean_R keeps the historical
        ``pmean`` lowering verbatim."""
        if aggregate == "mean_R":
            return jax.tree_util.tree_map(
                lambda gg: jax.lax.pmean(gg, daxes), g)
        if aggregate == "mean_S":
            n_sync = (jnp.maximum(jax.lax.psum(s_f, daxes), 1.0)
                      if partial else jnp.float32(R))
            return jax.tree_util.tree_map(
                lambda gg: jax.lax.psum(gg, daxes) / n_sync, g)
        # support_weighted: per-coordinate survivor count over the
        # syncing workers' payloads (masked workers' g is exactly 0, so
        # they support nothing); zero-support coords have a zero
        # numerator too — the max(cnt, 1) guard leaves the master alone
        return jax.tree_util.tree_map(
            lambda gg: jax.lax.psum(gg, daxes) / jnp.maximum(
                jax.lax.psum((gg != 0).astype(jnp.float32), daxes), 1.0),
            g)

    # ---- sync step ------------------------------------------------------
    def make_sync_body(z1, pregathered: bool = False,
                       with_down: bool = False):
      """Dense sync body.  With ``with_down`` (compressed downlink
      channel, DESIGN.md §5) the signature gains (view, down_mem): the
      uplink delta is taken against the worker's lagging *view*
      x_t^{(r)}, and after the master update the server compresses each
      worker's master delta against its error memory md^{(r)} — all
      shard-local threshold selection, sort- and collective-free, so
      the body stays partition-safe on 0.4.x partial-manual meshes.

      With ``partial`` (closure) the signature additionally gains a
      worker-sharded sync_mask and carries the view even without a
      downlink: masked-out workers transmit zeros (their payload is
      zeroed *before* the psum), keep their error memory and their
      half-step local iterate, and their view stays on the master copy
      they last received."""
      def sync_body(master, local, memory, inner, *rest):
        rest = list(rest)
        view = rest.pop(0) if carry_view else None
        down_mem = rest.pop(0) if with_down else None
        smask = rest.pop(0) if partial else None
        step, batch, key = rest
        lr = lr_schedule(step)
        half, inner_new, loss = _local(master, local, memory, inner, step,
                                       batch, lr)
        mem = _squeeze(memory)
        # zero1 masters are sharded on axis 0 over the worker axes:
        # materialize the full master for the delta via all_gather —
        # unless the caller already replicated it in the auto region
        # (0.4.x cannot partition all_gather inside partial-manual).
        full_master = master if pregathered else _gather_master(master, z1)
        ref = _squeeze(view) if carry_view else full_master
        delta = jax.tree_util.tree_map(
            lambda m, x, h: m + x.astype(jnp.float32) - h.astype(jnp.float32),
            mem, ref, half,
        )
        g, new_mem, wire_bits = up.apply(
            delta, param_specs, key=jax.random.fold_in(key, 1))
        if partial:
            s = smask[0]
            s_f = s.astype(jnp.float32)
            g = jax.tree_util.tree_map(
                lambda gg: jnp.where(s, gg, jnp.zeros_like(gg)), g)
            new_mem = jax.tree_util.tree_map(
                lambda old, nm: jnp.where(s, nm, old), mem, new_mem)
            wire_bits = jnp.where(s, wire_bits, 0.0)
        else:
            s, s_f = None, jnp.float32(1.0)
        g_mean = _aggregate_psum(g, s_f)
        new_full_master = jax.tree_util.tree_map(
            lambda x, gg: (x.astype(jnp.float32) - gg).astype(x.dtype),
            full_master, g_mean,
        )
        new_master = _scatter_master(new_full_master, z1)
        total_bits = jax.lax.psum(wire_bits, daxes)
        loss = jax.lax.pmean(loss, daxes)

        def picked(new, old):
            """Per-worker select: the new value only where s_r."""
            if not partial:
                return new
            return jax.tree_util.tree_map(
                lambda n, o: jnp.where(s, n.astype(o.dtype), o), new, old)

        if not with_down:
            new_local = picked(new_full_master, half)
            out = (
                new_master,
                _expand(new_local),   # exact broadcast (syncers only)
                _expand(new_mem),
                _expand(inner_new),
            )
            if carry_view:
                out = out + (_expand(picked(new_full_master, ref)),)
            return out + (total_bits, loss)
        # downlink: error-compensated compression of the master delta
        dm = _squeeze(down_mem)
        dacc = jax.tree_util.tree_map(
            lambda d, nm, vv: d + nm.astype(jnp.float32)
            - vv.astype(jnp.float32),
            dm, new_full_master, ref,
        )
        q, new_dm, dbits = down.apply(
            dacc, param_specs, key=jax.random.fold_in(key, 2))
        new_view = jax.tree_util.tree_map(
            lambda vv, qq: (vv.astype(jnp.float32) + qq).astype(vv.dtype),
            ref, q,
        )
        if partial:
            new_view = picked(new_view, ref)
            new_dm = jax.tree_util.tree_map(
                lambda old, nm: jnp.where(s, nm, old), dm, new_dm)
            dbits = jnp.where(s, dbits, 0.0)
        new_local = picked(new_view, half)
        total_down = jax.lax.psum(dbits, daxes)
        return (
            new_master,
            _expand(new_local),  # x̂_{t+1} = x_{t+1} = view (syncers)
            _expand(new_mem),
            _expand(inner_new),
            _expand(new_view),
            _expand(new_dm),
            total_bits,
            total_down,
            loss,
        )
      return sync_body

    # ---- spec plumbing ---------------------------------------------------
    # shard_map in_specs/out_specs may only reference the *manual* axes;
    # 'model' sharding of the arrays is carried by XLA-auto untouched.
    # Master specs are built lazily per-leaf (zero1 only shards leaves
    # whose axis 0 divides by the worker count).
    worker_specs = P(daxes)
    batch_spec = P(daxes)

    def _shmap(body, master_specs, out_specs, extra_worker: int = 0):
        """``extra_worker`` counts additional worker-sharded operands
        threaded between the core state and (step, batch, key): the
        downlink channel state (view, down_memory) and/or the per-step
        sync mask of a partial-participation run."""
        return shard_map(
            body,
            mesh=mesh,
            in_specs=(
                (master_specs, worker_specs, worker_specs, worker_specs)
                + (worker_specs,) * extra_worker
                + (P(), batch_spec, P())
            ),
            out_specs=out_specs,
            axis_names=manual,
            check_vma=True,
        )

    def _bits_down_of(state):
        return (state.bits_down if state.bits_down is not None
                else jnp.zeros((), jnp.float32))

    # dense broadcast cost of one exact sync (per-receiver, Σ workers);
    # leaf sizes are static so this is a trace-time python float
    def _exact_down_bits(master):
        return jnp.float32(R * down.dense_bits(master))

    def local_step(state: DistQsparseState, batch, key):
        z1 = _z1mask(state.master)
        local_mapped = _shmap(local_body, _master_in_specs(z1),
                              (worker_specs, worker_specs, P()))
        half, inner_new, loss = local_mapped(
            state.master, state.local, state.memory, state.inner,
            state.step, batch, key,
        )
        return (
            DistQsparseState(
                master=state.master, local=half, memory=state.memory,
                inner=inner_new, step=state.step + 1, bits=state.bits,
                rounds=state.rounds, view=state.view,
                down_memory=state.down_memory,
                bits_down=state.bits_down,
            ),
            loss,
        )

    def _prep_mask(sync_mask):
        if sync_mask is None:
            raise ValueError(
                "this step was built with partial=True: pass the bool[R] "
                "sync_mask of the step (which workers sync now)")
        return jnp.asarray(sync_mask).reshape((R,)).astype(bool)

    def sync_step_dense(state: DistQsparseState, batch, key,
                        sync_mask=None):
        m = _prep_mask(sync_mask) if partial else None
        z1 = _z1mask(state.master)
        mspecs = _master_in_specs(z1)
        master_in = state.master
        in_mspecs = mspecs
        pregather = zero1 and not MODERN
        if pregather:
            # replicate the zero1 master in the auto region (XLA inserts
            # the all-gather there); the body then skips its own gather
            master_in = jax.tree_util.tree_map(
                lambda x: jax.lax.with_sharding_constraint(
                    x, NamedSharding(mesh, P())), state.master)
            in_mspecs = P()
        extra_in = ()
        if carry_view:
            extra_in += (state.view,)
        if down_active:
            extra_in += (state.down_memory,)
        if partial:
            extra_in += (m,)
        rounds_inc = jnp.any(m).astype(jnp.int32) if partial else 1
        if down_active:
            sync_mapped = _shmap(
                make_sync_body(z1, pregather, with_down=True), in_mspecs,
                (mspecs, worker_specs, worker_specs, worker_specs,
                 worker_specs, worker_specs, P(), P(), P()),
                extra_worker=len(extra_in))
            (master, local, memory, inner_new, view, down_mem, wire_bits,
             down_bits, loss) = sync_mapped(
                master_in, state.local, state.memory, state.inner,
                *extra_in, state.step, batch, key,
            )
            return (
                DistQsparseState(
                    master=master, local=local, memory=memory,
                    inner=inner_new, step=state.step + 1,
                    bits=state.bits + wire_bits,
                    rounds=state.rounds + rounds_inc, view=view,
                    down_memory=down_mem,
                    bits_down=_bits_down_of(state) + down_bits,
                ),
                loss,
            )
        out_specs = (mspecs, worker_specs, worker_specs, worker_specs)
        if carry_view:
            out_specs = out_specs + (worker_specs,)
        sync_mapped = _shmap(
            make_sync_body(z1, pregather), in_mspecs,
            out_specs + (P(), P()), extra_worker=len(extra_in))
        out = sync_mapped(
            master_in, state.local, state.memory, state.inner,
            *extra_in, state.step, batch, key,
        )
        if carry_view:
            master, local, memory, inner_new, view, wire_bits, loss = out
        else:
            master, local, memory, inner_new, wire_bits, loss = out
            view = state.view
        # exact broadcast cost: only the syncing workers receive x_{t+1}
        down_cost = (jnp.sum(m.astype(jnp.float32))
                     * jnp.float32(down.dense_bits(state.master))
                     if partial else _exact_down_bits(state.master))
        return (
            DistQsparseState(
                master=master, local=local, memory=memory, inner=inner_new,
                step=state.step + 1, bits=state.bits + wire_bits,
                rounds=state.rounds + rounds_inc, view=view,
                down_memory=state.down_memory,
                bits_down=_bits_down_of(state) + down_cost,
            ),
            loss,
        )

    # ---- sparse-allgather sync (§Perf beyond-paper aggregation) ---------
    # The manual region emits each worker's *compact* (idx, val) survivor
    # buffers with a leading worker axis — written directly by the
    # compact Pallas kernel (DESIGN.md §3.3), which also hands back the
    # fused error memory, so no densify/scatter runs inside the manual
    # region.  The dense mean is reconstructed in the auto region, so
    # the wire carries W*kcap entries per row instead of a dense-f32
    # ring all-reduce.  Sort-free end to end: the traced step contains
    # no lax.top_k, so it partitions under 0.4.x too.
    def _leaf_meta(master_tree, comp: Optional[ShardCompressor] = None):
        comp = compressor if comp is None else comp
        return comp.leaf_meta(master_tree, param_specs)

    def _compact_arrays(payloads):
        arrays = []
        for pl in payloads:
            if pl[0] == "dense":
                arrays.append(pl[1])
            else:
                _, idx, sel, _ax, _moved = pl
                arrays.append(idx)
                arrays.append(sel)
        return arrays

    def make_sparse_sync_body(z1):
      def sparse_sync_body(master, local, memory, inner, *rest):
        rest = list(rest)
        view = rest.pop(0) if carry_view else None
        smask = rest.pop(0) if partial else None
        step, batch, key = rest
        lr = lr_schedule(step)
        half, inner_new, loss = _local(master, local, memory, inner, step,
                                       batch, lr)
        mem = _squeeze(memory)
        # with a compressed downlink (or a partial-participation run)
        # the uplink reference point is the worker's lagging view, not
        # the true master
        ref = _squeeze(view) if carry_view else _gather_master(master, z1)
        delta = jax.tree_util.tree_map(
            lambda m, x, h: m + x.astype(jnp.float32) - h.astype(jnp.float32),
            mem, ref, half,
        )
        payloads, _treedef, wire_bits, new_mem = compressor.compact(
            delta, param_specs, key=jax.random.fold_in(key, 1))
        if partial:
            # masked-out workers transmit nothing: zero their payload
            # values (sentinel-style — the auto-region scatter-add and
            # the support counts both see zeros), keep their memory
            s = smask[0]
            new_mem = jax.tree_util.tree_map(
                lambda old, nm: jnp.where(s, nm, old), mem, new_mem)
            wire_bits = jnp.where(s, wire_bits, 0.0)
            arrays = []
            for pl in payloads:
                if pl[0] == "dense":
                    arrays.append(
                        jnp.where(s, pl[1], jnp.zeros_like(pl[1])))
                else:
                    _, idx, sel, _ax, _moved = pl
                    arrays.append(idx)
                    arrays.append(jnp.where(s, sel, jnp.zeros_like(sel)))
        else:
            arrays = _compact_arrays(payloads)
        total_bits = jax.lax.psum(wire_bits, daxes)
        loss = jax.lax.pmean(loss, daxes)
        out = (_expand(new_mem), _expand(inner_new))
        if partial:
            out = out + (_expand(half),)
        return out + ([a[None] for a in arrays], total_bits, loss)
      return sparse_sync_body

    def make_sparse_down_body():
      """Second manual region of the sparse downlink: the server-side
      error-compensated compression of each worker's master delta,
      emitted in the compact (idx, val) wire form (DESIGN.md §3.3) so
      the buffers leave via out_specs and the dense decode happens in
      the auto region — sort-free, collective-free (bar the scalar
      bits psum), partition-safe on 0.4.x."""
      def down_body(new_master, view, down_mem, *rest):
        rest = list(rest)
        smask = rest.pop(0) if partial else None
        (key,) = rest
        v = _squeeze(view)
        dm = _squeeze(down_mem)
        dacc = jax.tree_util.tree_map(
            lambda d, nm, vv: d + nm.astype(jnp.float32)
            - vv.astype(jnp.float32),
            dm, new_master, v,
        )
        payloads, _treedef, dbits, new_dm = down.compact(
            dacc, param_specs, key=jax.random.fold_in(key, 2))
        if partial:
            # dropped workers receive nothing: server memory and bits
            # freeze; their q is discarded in the auto-region select
            s = smask[0]
            new_dm = jax.tree_util.tree_map(
                lambda old, nm: jnp.where(s, nm, old), dm, new_dm)
            dbits = jnp.where(s, dbits, 0.0)
        arrays = _compact_arrays(payloads)
        total_down = jax.lax.psum(dbits, daxes)
        return (_expand(new_dm), [a[None] for a in arrays], total_down)
      return down_body

    def sync_step_sparse(state: DistQsparseState, batch, key,
                         sync_mask=None):
        m = _prep_mask(sync_mask) if partial else None
        z1 = _z1mask(state.master)
        meta = _leaf_meta(state.master)
        n_arrays = sum(1 if mt[0] == "dense" else 2 for mt in meta)
        extra_in, extra_specs = (), ()
        if carry_view:
            extra_in += (state.view,)
            extra_specs += (worker_specs,)
        if partial:
            extra_in += (m,)
            extra_specs += (worker_specs,)
        half_specs = (worker_specs,) if partial else ()
        mapped = shard_map(
            make_sparse_sync_body(z1), mesh=mesh,
            in_specs=(_master_in_specs(z1), worker_specs, worker_specs,
                      worker_specs) + extra_specs + (P(), batch_spec, P()),
            out_specs=(worker_specs, worker_specs) + half_specs
            + ([P(tuple(daxes))] * n_arrays, P(), P()),
            axis_names=manual, check_vma=True,
        )
        out = mapped(
            state.master, state.local, state.memory, state.inner,
            *extra_in, state.step, batch, key)
        if partial:
            memory, inner_new, half_all, arrays, wire_bits, loss = out
        else:
            memory, inner_new, arrays, wire_bits, loss = out
            half_all = None
        n_sync = (jnp.maximum(jnp.sum(m.astype(jnp.float32)), 1.0)
                  if partial else None)
        # auto-region combine: dense mean per leaf, constrained to the
        # master's own sharding so the dense tree is never replicated
        # (zero1 leaves: sharded over the worker axes; each chip
        # reconstructs only its master shard from the gathered compacts).
        it = iter(arrays)
        master_leaves, mtd = jax.tree_util.tree_flatten(state.master)
        z1_leaves = jax.tree_util.tree_leaves(z1)
        means = []
        for (kind, ax, moved), mleaf, z1m in zip(meta, master_leaves,
                                                 z1_leaves):
            if kind == "dense":
                arr = next(it)
                if aggregate == "mean_R":
                    means.append(jnp.mean(arr, axis=0))
                elif aggregate == "mean_S":
                    d = n_sync if partial else jnp.float32(arr.shape[0])
                    means.append(jnp.sum(arr, axis=0) / d)
                else:  # support_weighted
                    cnt = jnp.sum((arr != 0).astype(jnp.float32), axis=0)
                    means.append(jnp.sum(arr, axis=0)
                                 / jnp.maximum(cnt, 1.0))
                continue
            idx_all = next(it)      # [W, ..., kcap]
            sel_all = next(it)
            W_ = idx_all.shape[0]
            # all W workers' buffers for a row decode in one scatter-add
            # (row-local indices are worker-independent; sentinels drop)
            from repro.kernels.dispatch import decode_rows
            ii = jnp.moveaxis(idx_all, 0, -2).reshape(
                (-1, W_ * idx_all.shape[-1]))
            ss = jnp.moveaxis(sel_all, 0, -2).reshape(
                (-1, W_ * sel_all.shape[-1]))
            dense = decode_rows(ii, ss, moved[-1])
            dense = jnp.moveaxis(dense.reshape(moved), -1, ax)
            z1spec = NamedSharding(mesh, P(*([None] * z1m), tuple(daxes))) \
                if z1m >= 0 else None
            if z1spec is not None:
                dense = jax.lax.with_sharding_constraint(dense, z1spec)
            if aggregate == "mean_R":
                means.append(dense / W_)
            elif aggregate == "mean_S":
                means.append(dense / (n_sync if partial
                                      else jnp.float32(W_)))
            else:  # support_weighted: survivor count per coordinate
                cnt = decode_rows(ii, (ss != 0).astype(jnp.float32),
                                  moved[-1])
                cnt = jnp.moveaxis(cnt.reshape(moved), -1, ax)
                if z1spec is not None:
                    cnt = jax.lax.with_sharding_constraint(cnt, z1spec)
                means.append(dense / jnp.maximum(cnt, 1.0))
        # zero1 masters keep their global shape (only the sharding
        # differs), so the update is uniform across both layouts.
        g_mean = jax.tree_util.tree_unflatten(mtd, means)
        new_master = jax.tree_util.tree_map(
            lambda x, gg: (x.astype(jnp.float32) - gg).astype(x.dtype),
            state.master, g_mean)
        rounds_inc = jnp.any(m).astype(jnp.int32) if partial else 1

        def _select(old_all):
            """Broadcast the new master to the (syncing) workers; the
            dropped workers keep ``old_all`` (their half-step iterate
            or stale view)."""
            def leaf(x, o):
                b = jnp.broadcast_to(x[None], o.shape).astype(o.dtype)
                if partial:
                    b = jnp.where(
                        m.reshape((-1,) + (1,) * (o.ndim - 1)), b, o)
                return jax.lax.with_sharding_constraint(
                    b, NamedSharding(mesh, P(tuple(daxes))))
            return jax.tree_util.tree_map(leaf, new_master, old_all)

        if down_active:
            new_local, view, down_mem, down_bits = _sparse_downlink(
                state, new_master, key, m, half_all)
            return (
                DistQsparseState(
                    master=new_master, local=new_local, memory=memory,
                    inner=inner_new, step=state.step + 1,
                    bits=state.bits + wire_bits,
                    rounds=state.rounds + rounds_inc,
                    view=view, down_memory=down_mem,
                    bits_down=_bits_down_of(state) + down_bits,
                ),
                loss,
            )
        new_local = _select(half_all if partial else state.local)
        new_view = _select(state.view) if carry_view else state.view
        down_cost = (jnp.sum(m.astype(jnp.float32))
                     * jnp.float32(down.dense_bits(state.master))
                     if partial else _exact_down_bits(state.master))
        return (
            DistQsparseState(
                master=new_master, local=new_local, memory=memory,
                inner=inner_new, step=state.step + 1,
                bits=state.bits + wire_bits,
                rounds=state.rounds + rounds_inc,
                view=new_view, down_memory=state.down_memory,
                bits_down=_bits_down_of(state) + down_cost,
            ),
            loss,
        )

    def _sparse_downlink(state, new_master, key, smask=None,
                         half_all=None):
        """Sparse-path downlink: a second manual region emits each
        worker's compact (idx, val) downlink buffers + updated server
        memory; the per-worker dense decode (scatter-add, sentinel
        slots drop) runs in the auto region, exactly like the uplink
        combine — no mean: each worker applies only its own q.  With
        ``partial`` the dropped workers (smask False) keep their view,
        server memory and half-step local iterate ``half_all``."""
        dmeta = _leaf_meta(state.master, downlink)
        n_down = sum(1 if mt[0] == "dense" else 2 for mt in dmeta)
        master_in = new_master
        if zero1:
            # replicate the (z1-sharded) new master in the auto region
            # before entry: 0.4.x partial-manual cannot gather in-body
            master_in = jax.tree_util.tree_map(
                lambda x: jax.lax.with_sharding_constraint(
                    x, NamedSharding(mesh, P())), new_master)
        mask_in = (smask,) if partial else ()
        mask_specs = (worker_specs,) if partial else ()
        down_mapped = shard_map(
            make_sparse_down_body(), mesh=mesh,
            in_specs=(P(), worker_specs, worker_specs)
            + mask_specs + (P(),),
            out_specs=(worker_specs, [P(tuple(daxes))] * n_down, P()),
            axis_names=manual, check_vma=True,
        )
        down_mem, darrays, down_bits = down_mapped(
            master_in, state.view, state.down_memory, *mask_in, key)
        it = iter(darrays)
        view_leaves, vtd = jax.tree_util.tree_flatten(state.view)
        new_view_leaves = []
        from repro.kernels.dispatch import decode_rows
        for (kind, ax, moved), vleaf in zip(dmeta, view_leaves):
            if kind == "dense":
                q = next(it)                    # [W, ...] exact payload
            else:
                idx_all = next(it)              # [W, ..., kcap]
                sel_all = next(it)
                W_ = idx_all.shape[0]
                kcap = idx_all.shape[-1]
                dense = decode_rows(idx_all.reshape(-1, kcap),
                                    sel_all.reshape(-1, kcap), moved[-1])
                dense = dense.reshape((W_,) + tuple(moved))
                q = jnp.moveaxis(dense, -1, ax + 1)
            new_view_leaves.append(
                (vleaf.astype(jnp.float32) + q).astype(vleaf.dtype))
        new_view = jax.tree_util.tree_unflatten(vtd, new_view_leaves)
        if partial:
            mb = lambda o: smask.reshape((-1,) + (1,) * (o.ndim - 1))
            new_view = jax.tree_util.tree_map(
                lambda nv, v: jnp.where(mb(v), nv, v),
                new_view, state.view)
        new_view = jax.tree_util.tree_map(
            lambda x: jax.lax.with_sharding_constraint(
                x, NamedSharding(mesh, P(tuple(daxes)))), new_view)
        if partial:
            new_local = jax.tree_util.tree_map(
                lambda nv, h: jax.lax.with_sharding_constraint(
                    jnp.where(mb(h), nv.astype(h.dtype), h),
                    NamedSharding(mesh, P(tuple(daxes)))),
                new_view, half_all)
            return new_local, new_view, down_mem, down_bits
        return new_view, new_view, down_mem, down_bits

    sync_step = (sync_step_sparse if wire == "sparse_allgather"
                 else sync_step_dense)

    # ---- init ------------------------------------------------------------
    def init_fn(params):
        """``params`` enter fully replicated over the worker axes."""
        z1 = _z1mask(params)

        def body(p):
            local = _expand(p)
            memory = jax.tree_util.tree_map(
                lambda x: jnp.zeros(x.shape, jnp.float32), local
            )
            inner = _expand(inner_opt.init(p))
            master = _scatter_master(p, z1)
            out = [master, local, memory, inner]
            if carry_view:
                # every worker's initial view is the initial master
                out.append(local)
            if down_active:
                # server-side downlink error memory starts at zero
                out.append(down.init_memory(local))
            return tuple(out)

        out_specs = (_master_in_specs(z1), worker_specs, worker_specs,
                     worker_specs)
        out_specs += (worker_specs,) * (int(carry_view) + int(down_active))
        mapped = shard_map(
            body, mesh=mesh, in_specs=(P(),),
            out_specs=out_specs,
            axis_names=manual, check_vma=True,
        )
        # eager shard_map with auto (non-manual) axes is unimplemented on
        # older jax; under jit it lowers fine on every version
        out = jax.jit(mapped)(params)
        master, local, memory, inner = out[:4]
        view = out[4] if carry_view else None
        down_mem = out[4 + int(carry_view)] if down_active else None
        return DistQsparseState(
            master=master, local=local, memory=memory, inner=inner,
            step=jnp.zeros((), jnp.int32),
            bits=jnp.zeros((), jnp.float32),
            rounds=jnp.zeros((), jnp.int32),
            view=view, down_memory=down_mem,
            bits_down=jnp.zeros((), jnp.float32),
        )

    return init_fn, local_step, sync_step


_ROUND_FALLBACK_WARNED = set()


def _make_round_core(local_step, sync_step):
    """One sync round as a traced program: lax.scan of the shard_mapped
    local step over the head, the sync step once at the tail, key split
    in-program with the host loop's sequence.  Shared by the fused
    round program and the windowed multi-round program."""
    def round_core(state, batch_block, key, *tail_mask):
        def body(carry, batch):
            state, key = carry
            key, sub = jax.random.split(key)
            state, loss = local_step(state, batch, sub)
            return (state, key), loss

        head = jax.tree_util.tree_map(lambda x: x[:-1], batch_block)
        tail = jax.tree_util.tree_map(lambda x: x[-1], batch_block)
        (state, key), head_losses = jax.lax.scan(
            body, (state, key), head)
        key, sub = jax.random.split(key)
        state, tail_loss = sync_step(state, tail, sub, *tail_mask)
        return (state, jnp.concatenate([head_losses, tail_loss[None]]),
                key)

    return round_core


def make_dist_round(
    grad_fn: Callable,
    inner_opt: GradientTransform,
    compressor: ShardCompressor,
    lr_schedule: Callable,
    mesh,
    data_axes: Sequence[str] = ("data",),
    param_specs=None,
    zero1: bool = False,
    aggregate: str = "mean_R",
    downlink: Optional[ShardCompressor] = None,
    wire: str = "dense_psum",
    partial: bool = False,
):
    """Round-program runtime for the mesh engine (DESIGN.md §7).

    Returns ``(init_fn, round_fn, fused)``.  ``round_fn(state,
    batch_block, key) -> (state, losses[L], key)`` executes one sync
    round — L−1 local steps then the sync step at the tail, where L is
    the block's leading dim (the host schedule guarantees the tail is
    the round's sync step; use L=1 blocks for back-to-back syncs).

    With ``partial=True`` (scenario runs, core/scenarios.py) the round
    signature gains the tail's per-worker mask: ``round_fn(state,
    batch_block, tail_mask, key)`` with ``tail_mask`` bool[R] — which
    workers contribute to the round's sync.  ``aggregate`` names the
    master's division rule (mean_R | mean_S | support_weighted) and
    ``wire`` the transport (dense_psum | sparse_allgather); legacy
    callers passing a wire format as ``aggregate=`` are shimmed with a
    one-time warning (see make_dist_steps).

    With ``fused`` (modern jax, or a legacy mesh whose tensor-parallel
    axes are all size 1 — ``compat.round_scan_supported``) the whole
    round is ONE donated jitted program: ``lax.scan`` over the
    shard_mapped local step with the batch block as xs, the shard_mapped
    sync step once at the tail, per-step losses accumulated on device
    and the PRNG key split in-program with the host loop's sequence —
    bit-for-bit the per-step trajectories.  On 0.4.x TP>1 meshes the
    legacy SPMD partitioner cannot partition scan-with-xs around the
    partial-manual steps (ROADMAP known issue), so ``round_fn``
    degrades to the per-step host composition (identical math and key
    stream, only dispatch overhead differs) with a one-time warning.
    """
    init_fn, local_step, sync_step = make_dist_steps(
        grad_fn, inner_opt, compressor, lr_schedule, mesh, data_axes,
        param_specs, zero1=zero1, aggregate=aggregate, downlink=downlink,
        wire=wire, partial=partial)
    fused = round_scan_supported(mesh, data_axes)

    if fused:
        round_core = _make_round_core(local_step, sync_step)

        if partial:
            def round_program(state, batch_block, tail_mask, key):
                return round_core(state, batch_block, key, tail_mask)
        else:
            round_program = round_core

        from repro.core.engine import donated_jit
        return init_fn, donated_jit(round_program), True

    if "round" not in _ROUND_FALLBACK_WARNED:
        warnings.warn(
            "the fused round program (lax.scan over the shard_mapped "
            "local step) cannot be partitioned on a 0.4.x jax mesh with "
            "a >1 tensor-parallel axis; falling back to per-step "
            "dispatch — identical trajectories, only host overhead "
            "differs. Use a TP=1 mesh or a modern jax for the fused "
            "path.", stacklevel=2)
        _ROUND_FALLBACK_WARNED.add("round")
    from repro.core.engine import donated_jit
    ls = donated_jit(local_step)
    ss = donated_jit(sync_step)

    def fallback_core(state, batch_block, key, *tail_mask):
        L = jax.tree_util.tree_leaves(batch_block)[0].shape[0]
        losses = []
        for i in range(L):
            batch = jax.tree_util.tree_map(lambda x, i=i: x[i], batch_block)
            key, sub = jax.random.split(key)
            if i == L - 1:
                state, loss = ss(state, batch, sub, *tail_mask)
            else:
                state, loss = ls(state, batch, sub)
            losses.append(loss)
        return state, jnp.stack(losses), key

    if partial:
        def round_fallback(state, batch_block, tail_mask, key):
            return fallback_core(state, batch_block, key, tail_mask)
    else:
        round_fallback = fallback_core

    return init_fn, round_fallback, False


def make_dist_multiround(
    grad_fn: Callable,
    inner_opt: GradientTransform,
    compressor: ShardCompressor,
    lr_schedule: Callable,
    mesh,
    data_axes: Sequence[str] = ("data",),
    param_specs=None,
    zero1: bool = False,
    aggregate: str = "mean_R",
    downlink: Optional[ShardCompressor] = None,
    wire: str = "dense_psum",
    partial: bool = False,
):
    """Windowed round program for the mesh engine — the overlapped
    driver's compiled unit (DESIGN.md §10, the mesh twin of
    ``engine.make_multiround``).

    Returns ``(init_fn, multiround_fn, fused)``.  ``multiround_fn``
    takes ``(state, blocks, key)`` — or ``(state, blocks, tail_masks,
    key)`` with ``partial=True`` — where ``blocks`` stacks W
    equal-length round blocks ([W, L, ...] leaves) and ``tail_masks``
    is bool[W, R]; it returns ``(state, losses [W, L], key)``.  The W
    rounds execute as ONE donated program: an outer ``lax.scan`` whose
    body is exactly the fused round core, so round w+1's scanned local
    phase sits in the device queue while round w's sync collective
    (psum / allgather) completes — the collective pipelines against the
    next round's compute instead of serializing the dispatch chain.

    Bit-for-bit contract: the scan body is the same round core the
    serialized ``make_dist_round`` program jits, threading the same key
    stream, so states, losses and both wire ledgers match the per-round
    driver exactly.

    On a 0.4.x mesh with a >1 tensor-parallel axis the round core
    itself cannot be partitioned (``compat.round_scan_supported``;
    ROADMAP known issue), so windows degrade to a host loop over the
    per-round fallback — identical trajectories, no overlap — with a
    one-time warning, and ``fused`` is False.
    """
    init_fn, local_step, sync_step = make_dist_steps(
        grad_fn, inner_opt, compressor, lr_schedule, mesh, data_axes,
        param_specs, zero1=zero1, aggregate=aggregate, downlink=downlink,
        wire=wire, partial=partial)
    fused = round_scan_supported(mesh, data_axes)
    from repro.core.engine import donated_jit

    if fused:
        round_core = _make_round_core(local_step, sync_step)

        def multi_core(state, blocks, key, *tail_masks):
            def body(carry, xs):
                st, kk = carry
                if tail_masks:
                    block, mask = xs
                    st, ls, kk = round_core(st, block, kk, mask)
                else:
                    st, ls, kk = round_core(st, xs, kk)
                return (st, kk), ls

            xs = (blocks, tail_masks[0]) if tail_masks else blocks
            (state, key), losses = jax.lax.scan(body, (state, key), xs)
            return state, losses, key

        if partial:
            def multiround(state, blocks, tail_masks, key):
                return multi_core(state, blocks, key, tail_masks)
        else:
            multiround = multi_core
        return init_fn, donated_jit(multiround), True

    if "multiround" not in _ROUND_FALLBACK_WARNED:
        warnings.warn(
            "the windowed multi-round program cannot be partitioned on "
            "a 0.4.x jax mesh with a >1 tensor-parallel axis; windows "
            "fall back to per-round dispatch — identical trajectories, "
            "no compute/comm overlap. Use a TP=1 mesh or a modern jax.",
            stacklevel=2)
        _ROUND_FALLBACK_WARNED.add("multiround")
    ls_fb = donated_jit(local_step)
    ss_fb = donated_jit(sync_step)

    def window_fallback(state, blocks, key, *tail_masks):
        W = jax.tree_util.tree_leaves(blocks)[0].shape[0]
        all_losses = []
        for w in range(W):
            block = jax.tree_util.tree_map(lambda x, w=w: x[w], blocks)
            L = jax.tree_util.tree_leaves(block)[0].shape[0]
            losses = []
            for i in range(L):
                batch = jax.tree_util.tree_map(
                    lambda x, i=i: x[i], block)
                key, sub = jax.random.split(key)
                if i == L - 1:
                    tm = ((tail_masks[0][w],) if tail_masks else ())
                    state, loss = ss_fb(state, batch, sub, *tm)
                else:
                    state, loss = ls_fb(state, batch, sub)
                losses.append(loss)
            all_losses.append(jnp.stack(losses))
        return state, jnp.stack(all_losses), key

    if partial:
        def multiround_fb(state, blocks, tail_masks, key):
            return window_fallback(state, blocks, key, tail_masks)
    else:
        multiround_fb = window_fallback
    return init_fn, multiround_fb, False


# ---------------------------------------------------------------------------
# staleness-first fault runtime (DESIGN.md §9)
# ---------------------------------------------------------------------------


def make_dist_fault_steps(
    grad_fn: Callable,
    inner_opt: GradientTransform,
    compressor: ShardCompressor,
    lr_schedule: Callable,
    mesh,
    data_axes: Sequence[str] = ("data",),
    param_specs=None,
    *,
    queue_depth: int,
    aggregate: str = "mean_R",
    wire: str = "dense_psum",
    staleness_weight: str = "uniform",
    downlink: Optional[ShardCompressor] = None,
    zero1: bool = False,
):
    """Mesh-engine counterpart of ``engine.make_fault_step``: the
    *executed* staleness regime on both transports.  A payload computed
    at step t (uplink error memory updated, wire bits charged *then*)
    is enqueued into a bounded per-worker in-flight buffer and applied
    to the master at t+τ; workers crash (state frozen), recover
    (re-initialized from the current master, error memory lost), and
    payloads drop in flight per the step's ``engine.FaultRow``.

    Returns ``(init_fn, fault_local_step, fault_sync_step)``; both
    steps take ``(state, batch, row, key)``.  The host drives the
    dispatch from the deterministic fault tables
    (``scenarios.fault_replay``): event steps — any scheduled sync row
    or any arrival — go through ``fault_sync_step``, the rest through
    ``fault_local_step`` (recover + alive-masked local phase only).

    Wire semantics:

    * ``dense_psum`` — the queue holds each worker's *decompressed*
      payload per shard ([R, depth, ...] master-shaped buffers); the
      arriving slots are summed per worker inside the manual region and
      the cross-worker reduce is one psum, exactly the non-fault body's
      pattern.
    * ``sparse_allgather`` — delayed shards ride the existing compact
      wire format: the queue holds the (idx, val) survivor buffers
      themselves ([R, depth, ..., kcap]); at arrival the masked vals of
      *all* queued buffers decode in the auto region via the same
      scatter-add combine as the non-fault path (sentinel and zeroed
      slots contribute nothing).

    Both wires produce the same trajectories (states allclose, both
    bits ledgers exact — the compact bits counting is the dense
    channel's).  ``zero1`` and a compressed ``downlink`` are not
    supported under faults on the mesh engine (the single-host engine
    carries the compressed-downlink fault path); pass ``downlink`` only
    as None/identity.
    """
    from repro.core.scenarios import (validate_aggregate,
                                      validate_staleness_weight)
    validate_aggregate(aggregate)
    validate_staleness_weight(staleness_weight)
    if wire not in ("dense_psum", "sparse_allgather"):
        raise ValueError(f"unknown wire {wire!r}; expected 'dense_psum' "
                         f"| 'sparse_allgather'")
    if queue_depth < 1:
        raise ValueError(f"queue_depth must be >= 1, got {queue_depth}")
    if zero1:
        raise ValueError(
            "zero1 master sharding is not supported under faults: the "
            "recover phase re-initializes workers from the full master "
            "inside the manual region (gather-free); run faults with "
            "zero1=False")
    if downlink is not None and not chn.ShardChannel(
            downlink, "downlink").is_identity():
        raise ValueError(
            "a compressed downlink is not supported under faults on the "
            "mesh engine; use the single-host engine "
            "(engine.make_fault_step) for compressed-downlink fault "
            "studies, or downlink=None here")
    daxes = tuple(data_axes)
    R = worker_count(mesh, daxes)
    manual = set(daxes)
    compressor = _legacy_tp_kernel_guard(compressor, mesh, daxes, wire)
    up = chn.ShardChannel(compressor, "uplink")
    down = chn.ShardChannel(None, "downlink")
    Dq = int(queue_depth)
    damped = staleness_weight == "damped"
    worker_specs = P(daxes)
    batch_spec = P(daxes)

    def _squeeze(tree):
        return jax.tree_util.tree_map(lambda x: x[0], tree)

    def _expand(tree):
        return jax.tree_util.tree_map(lambda x: x[None], tree)

    def _wsel(flag, new, old):
        """Scalar-flag select over same-structure trees (in-body, one
        worker): the new value only where ``flag``."""
        return jax.tree_util.tree_map(
            lambda n, o: jnp.where(flag, n.astype(o.dtype), o), new, old)

    def _row_arrays(row):
        """FaultRow → five [R] device arrays (worker-shardable)."""
        as_r = lambda x, dt: jnp.asarray(x, dt).reshape((R,))  # noqa: E731
        return (as_r(row.sync, bool), as_r(row.delay, jnp.int32),
                as_r(row.alive, bool), as_r(row.drop, bool),
                as_r(row.recover, bool))

    def _check_queue(state):
        if state.inflight is None or state.arrive_at is None:
            raise ValueError(
                "fault steps need the in-flight queue: build the state "
                "with this factory's init_fn")

    # ---- shared in-body phases ------------------------------------------
    def _recover_and_local(master, local, memory, inner, view,
                           alive, recover, step, batch):
        """Recover phase + alive-masked local phase for one worker
        (squeezed trees).  Returns (half, memory, inner, view, loss) —
        the crashed workers' iterate/inner stay frozen, recovered
        workers restart from the master with fresh memory/inner."""
        l0, v0 = _squeeze(local), _squeeze(view)
        m0, i0 = _squeeze(memory), _squeeze(inner)
        fresh = jax.tree_util.tree_map(
            lambda x, l: x.astype(l.dtype), master, l0)
        l0 = _wsel(recover, fresh, l0)
        v0 = _wsel(recover, jax.tree_util.tree_map(
            lambda x, v: x.astype(v.dtype), master, v0), v0)
        m0 = _wsel(recover, jax.tree_util.tree_map(jnp.zeros_like, m0), m0)
        i0 = _wsel(recover, inner_opt.init(fresh), i0)
        loss, grads = grad_fn(l0, _squeeze(batch))
        updates, i1 = inner_opt.update(grads, i0, l0, lr_schedule(step))
        half = apply_updates(l0, updates)
        half = _wsel(alive, half, l0)
        i1 = _wsel(alive, i1, i0)
        return half, m0, i1, v0, loss

    def _uplink_payload(m0, v0, half, compute, key, compact: bool):
        """Compute-time error feedback, masked to the computing workers
        (scheduled sync AND alive): memory and bits advance *now*, the
        payload is handed to the queue.  Dense form returns the
        decompressed g tree; compact form the wire arrays."""
        delta = jax.tree_util.tree_map(
            lambda m, x, h: m + x.astype(jnp.float32)
            - h.astype(jnp.float32),
            m0, v0, half,
        )
        sub = jax.random.fold_in(key, 1)
        if compact:
            payloads, _td, wire_bits, new_mem = compressor.compact(
                delta, param_specs, key=sub)
        else:
            g, new_mem, wire_bits = up.apply(delta, param_specs, key=sub)
        new_mem = jax.tree_util.tree_map(
            lambda old, nm: jnp.where(compute, nm, old), m0, new_mem)
        wire_bits = jnp.where(compute, wire_bits, 0.0)
        if compact:
            arrays = []
            for pl in payloads:
                if pl[0] == "dense":
                    arrays.append(jnp.where(compute, pl[1],
                                            jnp.zeros_like(pl[1])))
                else:
                    _, idx, sel, _ax, _moved = pl
                    arrays.append(idx)
                    arrays.append(jnp.where(compute, sel,
                                            jnp.zeros_like(sel)))
            return arrays, new_mem, wire_bits
        g = jax.tree_util.tree_map(
            lambda gg: jnp.where(compute, gg, jnp.zeros_like(gg)), g)
        return g, new_mem, wire_bits

    # ---- local fault step (no event this step) --------------------------
    def local_fault_body(master, local, memory, inner, view,
                         sync, delay, alive, drop, recover,
                         step, batch, key):
        half, m0, i1, v0, loss = _recover_and_local(
            master, local, memory, inner, view, alive[0], recover[0],
            step, batch)
        loss = jax.lax.pmean(loss, daxes)
        return (_expand(half), _expand(m0), _expand(i1), _expand(v0),
                loss)

    def fault_local_step(state: DistQsparseState, batch, row, key):
        _check_queue(state)
        rows = _row_arrays(row)
        mapped = shard_map(
            local_fault_body, mesh=mesh,
            in_specs=(P(), worker_specs, worker_specs, worker_specs,
                      worker_specs) + (worker_specs,) * 5
            + (P(), batch_spec, P()),
            out_specs=(worker_specs,) * 4 + (P(),),
            axis_names=manual, check_vma=True,
        )
        local, memory, inner, view, loss = mapped(
            state.master, state.local, state.memory, state.inner,
            state.view, *rows, state.step, batch, key)
        return state._replace(local=local, memory=memory, inner=inner,
                              view=view, step=state.step + 1), loss

    # ---- dense wire: queue + arrivals inside the manual region ----------
    def dense_fault_body(master, local, memory, inner, view,
                         q, arrive, tau,
                         sync, delay, alive, drop, recover,
                         step, batch, key):
        alv, rec = alive[0], recover[0]
        half, m0, i1, v0, loss = _recover_and_local(
            master, local, memory, inner, view, alv, rec, step, batch)
        compute = sync[0] & alv
        g, new_mem, wire_bits = _uplink_payload(
            m0, v0, half, compute, key, compact=False)
        # enqueue: slot t % depth, arrival at t + τ (dropped payloads
        # were charged and compensated but never travel)
        slot = jnp.mod(step, Dq)
        keep = compute & ~drop[0]
        qs = _squeeze(q)                    # [Dq, ...] this worker
        arr_q, tau_q = arrive[0], tau[0]    # [Dq]
        qs = jax.tree_util.tree_map(
            lambda qq, gg: qq.at[slot].set(jnp.where(keep, gg, qq[slot])),
            qs, g)
        arr_q = arr_q.at[slot].set(
            jnp.where(keep, step + delay[0], arr_q[slot]))
        tau_q = tau_q.at[slot].set(jnp.where(keep, delay[0], tau_q[slot]))
        # apply: every in-flight payload landing this step
        landing = arr_q == step             # [Dq]

        def pay_of(qq):
            shape = (Dq,) + (1,) * (qq.ndim - 1)
            p = jnp.where(landing.reshape(shape), qq, jnp.zeros_like(qq))
            if damped:
                w = 1.0 / (1.0 + tau_q.astype(jnp.float32))
                p = p * w.reshape(shape)
            return p

        pays = jax.tree_util.tree_map(pay_of, qs)
        pay_sum = jax.tree_util.tree_map(
            lambda p: jnp.sum(p, axis=0), pays)
        if aggregate == "mean_R":
            g_agg = jax.tree_util.tree_map(
                lambda p: jax.lax.psum(p, daxes) / R, pay_sum)
        elif aggregate == "mean_S":
            n_arr = jnp.maximum(jax.lax.psum(
                jnp.sum(landing.astype(jnp.float32)), daxes), 1.0)
            g_agg = jax.tree_util.tree_map(
                lambda p: jax.lax.psum(p, daxes) / n_arr, pay_sum)
        else:  # support_weighted: arriving per-coordinate support
            g_agg = jax.tree_util.tree_map(
                lambda p, c: jax.lax.psum(jnp.sum(p, axis=0), daxes)
                / jnp.maximum(jax.lax.psum(jnp.sum(
                    (c != 0).astype(jnp.float32), axis=0), daxes), 1.0),
                pays, pays)
        new_master = jax.tree_util.tree_map(
            lambda x, gg: (x.astype(jnp.float32) - gg).astype(x.dtype),
            master, g_agg)
        # dequeue applied slots
        qs = jax.tree_util.tree_map(
            lambda qq: jnp.where(
                landing.reshape((Dq,) + (1,) * (qq.ndim - 1)),
                jnp.zeros_like(qq), qq),
            qs)
        arr_q = jnp.where(landing, -1, arr_q)
        tau_q = jnp.where(landing, 0, tau_q)
        # broadcast to workers whose payload landed (and are alive)
        arr_any = jnp.any(landing)
        b = arr_any & alv
        new_local = _wsel(b, new_master, half)
        new_view = _wsel(b, new_master, v0)
        total_bits = jax.lax.psum(wire_bits, daxes)
        loss = jax.lax.pmean(loss, daxes)
        return (new_master, _expand(new_local), _expand(new_mem),
                _expand(i1), _expand(new_view), _expand(qs),
                arr_q[None], tau_q[None], arr_any[None], total_bits,
                loss)

    def fault_sync_step_dense(state: DistQsparseState, batch, row, key):
        _check_queue(state)
        rows = _row_arrays(row)
        mapped = shard_map(
            dense_fault_body, mesh=mesh,
            in_specs=(P(), worker_specs, worker_specs, worker_specs,
                      worker_specs, worker_specs, worker_specs,
                      worker_specs) + (worker_specs,) * 5
            + (P(), batch_spec, P()),
            out_specs=(P(),) + (worker_specs,) * 8 + (P(), P()),
            axis_names=manual, check_vma=True,
        )
        (master, local, memory, inner, view, q, arrive, tau, arr_any,
         wire_bits, loss) = mapped(
            state.master, state.local, state.memory, state.inner,
            state.view, state.inflight, state.arrive_at,
            state.inflight_tau, *rows, state.step, batch, key)
        alive_r = rows[2]
        n_recv = jnp.sum((arr_any & alive_r).astype(jnp.float32))
        down_cost = n_recv * jnp.float32(down.dense_bits(state.master))
        return state._replace(
            master=master, local=local, memory=memory, inner=inner,
            view=view, step=state.step + 1,
            bits=state.bits + wire_bits,
            rounds=state.rounds + jnp.any(arr_any).astype(jnp.int32),
            bits_down=state.bits_down + down_cost,
            inflight=q, arrive_at=arrive, inflight_tau=tau,
        ), loss

    # ---- sparse wire: compact buffers queue in the auto region ----------
    def sparse_fault_body(master, local, memory, inner, view,
                          sync, delay, alive, drop, recover,
                          step, batch, key):
        alv, rec = alive[0], recover[0]
        half, m0, i1, v0, loss = _recover_and_local(
            master, local, memory, inner, view, alv, rec, step, batch)
        compute = sync[0] & alv
        arrays, new_mem, wire_bits = _uplink_payload(
            m0, v0, half, compute, key, compact=True)
        total_bits = jax.lax.psum(wire_bits, daxes)
        loss = jax.lax.pmean(loss, daxes)
        return (_expand(half), _expand(new_mem), _expand(i1),
                _expand(v0), [a[None] for a in arrays], total_bits, loss)

    def fault_sync_step_sparse(state: DistQsparseState, batch, row, key):
        _check_queue(state)
        rows = _row_arrays(row)
        sync_r, delay_r, alive_r, drop_r, _rec = rows
        meta = compressor.leaf_meta(state.master, param_specs)
        n_arrays = sum(1 if mt[0] == "dense" else 2 for mt in meta)
        mapped = shard_map(
            sparse_fault_body, mesh=mesh,
            in_specs=(P(), worker_specs, worker_specs, worker_specs,
                      worker_specs) + (worker_specs,) * 5
            + (P(), batch_spec, P()),
            out_specs=(worker_specs,) * 4
            + ([P(tuple(daxes))] * n_arrays, P(), P()),
            axis_names=manual, check_vma=True,
        )
        half_all, memory, inner, view, arrays, wire_bits, loss = mapped(
            state.master, state.local, state.memory, state.inner,
            state.view, *rows, state.step, batch, key)
        # ---- enqueue into the compact queue (auto region) --------------
        compute = sync_r & alive_r
        keep = compute & ~drop_r
        slot = jnp.mod(state.step, Dq)

        def put(buf, payload):
            kmask = keep.reshape((R,) + (1,) * (payload.ndim - 1))
            return buf.at[:, slot].set(
                jnp.where(kmask, payload, buf[:, slot]))

        bufs = list(state.inflight)
        it = iter(arrays)
        new_bufs = []
        bi = 0
        for kind, _ax, _moved in meta:
            if kind == "dense":
                new_bufs.append(put(bufs[bi], next(it)))
                bi += 1
            else:
                new_bufs.append(put(bufs[bi], next(it)))      # idx
                new_bufs.append(put(bufs[bi + 1], next(it)))  # val
                bi += 2
        arrive = state.arrive_at.at[:, slot].set(
            jnp.where(keep, state.step + delay_r,
                      state.arrive_at[:, slot]))
        tau = state.inflight_tau.at[:, slot].set(
            jnp.where(keep, delay_r, state.inflight_tau[:, slot]))
        # ---- apply: decode every landing buffer, scatter-add combine ---
        landing = arrive == state.step                      # [R, Dq]
        w = (1.0 / (1.0 + tau.astype(jnp.float32))) if damped else None
        n_arr = jnp.maximum(jnp.sum(landing.astype(jnp.float32)), 1.0)
        from repro.kernels.dispatch import decode_rows
        master_leaves, mtd = jax.tree_util.tree_flatten(state.master)
        it = iter(new_bufs)
        means = []
        for (kind, ax, moved), mleaf in zip(meta, master_leaves):
            if kind == "dense":
                buf = next(it)                              # [R, Dq, ...]
                lm = landing.reshape((R, Dq) + (1,) * (buf.ndim - 2))
                p = jnp.where(lm, buf, jnp.zeros_like(buf))
                if damped:
                    p = p * w.reshape((R, Dq) + (1,) * (buf.ndim - 2))
                s = jnp.sum(p, axis=(0, 1))
                if aggregate == "mean_R":
                    means.append(s / R)
                elif aggregate == "mean_S":
                    means.append(s / n_arr)
                else:
                    cnt = jnp.sum((p != 0).astype(jnp.float32),
                                  axis=(0, 1))
                    means.append(s / jnp.maximum(cnt, 1.0))
                continue
            idx_buf = next(it)                  # [R, Dq, ..., kcap]
            val_buf = next(it)
            lm = landing.reshape((R, Dq) + (1,) * (val_buf.ndim - 2))
            vals = jnp.where(lm, val_buf, jnp.zeros_like(val_buf))
            if damped:
                vals = vals * w.reshape((R, Dq) + (1,) * (val_buf.ndim - 2))
            kcap = idx_buf.shape[-1]
            ii = idx_buf.reshape(-1, kcap)
            ss = vals.reshape(-1, kcap)
            dense = decode_rows(ii, ss, moved[-1])
            dense = dense.reshape((R, Dq) + tuple(moved))
            s = jnp.moveaxis(jnp.sum(dense, axis=(0, 1)), -1, ax)
            if aggregate == "mean_R":
                means.append(s / R)
            elif aggregate == "mean_S":
                means.append(s / n_arr)
            else:
                cntd = decode_rows(ii, (ss != 0).astype(jnp.float32),
                                   moved[-1])
                cnt = jnp.moveaxis(
                    jnp.sum(cntd.reshape((R, Dq) + tuple(moved)),
                            axis=(0, 1)), -1, ax)
                means.append(s / jnp.maximum(cnt, 1.0))
        g_agg = jax.tree_util.tree_unflatten(mtd, means)
        new_master = jax.tree_util.tree_map(
            lambda x, gg: (x.astype(jnp.float32) - gg).astype(x.dtype),
            state.master, g_agg)
        # ---- dequeue: zero applied vals, reset sentinels ---------------
        it = iter(new_bufs)
        deq = []
        for kind, _ax, moved in meta:
            if kind == "dense":
                buf = next(it)
                lm = landing.reshape((R, Dq) + (1,) * (buf.ndim - 2))
                deq.append(jnp.where(lm, jnp.zeros_like(buf), buf))
                continue
            idx_buf = next(it)
            val_buf = next(it)
            lm = landing.reshape((R, Dq) + (1,) * (val_buf.ndim - 2))
            deq.append(jnp.where(lm, jnp.full_like(idx_buf, moved[-1]),
                                 idx_buf))
            deq.append(jnp.where(lm, jnp.zeros_like(val_buf), val_buf))
        arrive = jnp.where(landing, -1, arrive)
        tau = jnp.where(landing, 0, tau)
        # ---- broadcast to workers whose payload landed -----------------
        b = jnp.any(landing, axis=1) & alive_r

        def pick(x, o):
            bb = jnp.broadcast_to(x[None], o.shape).astype(o.dtype)
            sel = jnp.where(b.reshape((-1,) + (1,) * (o.ndim - 1)), bb, o)
            return jax.lax.with_sharding_constraint(
                sel, NamedSharding(mesh, P(tuple(daxes))))

        new_local = jax.tree_util.tree_map(pick, new_master, half_all)
        new_view = jax.tree_util.tree_map(pick, new_master, view)
        n_recv = jnp.sum(b.astype(jnp.float32))
        down_cost = n_recv * jnp.float32(down.dense_bits(state.master))
        return state._replace(
            master=new_master, local=new_local, memory=memory,
            inner=inner, view=new_view, step=state.step + 1,
            bits=state.bits + wire_bits,
            rounds=state.rounds + jnp.any(landing).astype(jnp.int32),
            bits_down=state.bits_down + down_cost,
            inflight=tuple(deq), arrive_at=arrive, inflight_tau=tau,
        ), loss

    fault_sync_step = (fault_sync_step_sparse if wire == "sparse_allgather"
                       else fault_sync_step_dense)

    # ---- init ------------------------------------------------------------
    def init_fn(params):
        def body(p):
            local = _expand(p)
            memory = jax.tree_util.tree_map(
                lambda x: jnp.zeros(x.shape, jnp.float32), local)
            inner = _expand(inner_opt.init(p))
            out = [p, local, memory, inner, local]
            if wire == "dense_psum":
                out.append(jax.tree_util.tree_map(
                    lambda x: jnp.zeros((1, Dq) + x.shape, jnp.float32),
                    p))
            out.append(jnp.full((1, Dq), -1, jnp.int32))
            out.append(jnp.zeros((1, Dq), jnp.int32))
            return tuple(out)

        nq = 1 if wire == "dense_psum" else 0
        mapped = shard_map(
            body, mesh=mesh, in_specs=(P(),),
            out_specs=(P(),) + (worker_specs,) * (4 + nq + 2),
            axis_names=manual, check_vma=True,
        )
        out = jax.jit(mapped)(params)
        master, local, memory, inner, view = out[:5]
        if wire == "dense_psum":
            inflight, arrive, tau = out[5], out[6], out[7]
        else:
            arrive, tau = out[5], out[6]
            # compact wire buffers: [R, depth, ..., kcap] per sparse
            # leaf (idx at the out-of-row sentinel, vals zero), a dense
            # [R, depth, leaf] buffer per dense-payload leaf — sized
            # exactly like axis_topk_compact's emissions
            from repro.kernels import dispatch as dsp
            leaves = jax.tree_util.tree_leaves(master)
            meta = compressor.leaf_meta(master, param_specs)
            plans = compressor._plans(len(leaves))
            bufs = []
            for leaf, (kind, ax, moved), plan in zip(leaves, meta, plans):
                if kind == "dense":
                    bufs.append(jnp.zeros((R, Dq) + leaf.shape,
                                          jnp.float32))
                    continue
                n = moved[-1]
                kcap = dsp.capacity(resolve_k(plan[1], n), n)
                shape = (R, Dq) + tuple(moved[:-1]) + (kcap,)
                bufs.append(jnp.full(shape, n, jnp.int32))
                bufs.append(jnp.zeros(shape, jnp.float32))
            inflight = tuple(bufs)
        return DistQsparseState(
            master=master, local=local, memory=memory, inner=inner,
            step=jnp.zeros((), jnp.int32),
            bits=jnp.zeros((), jnp.float32),
            rounds=jnp.zeros((), jnp.int32),
            view=view, down_memory=None,
            bits_down=jnp.zeros((), jnp.float32),
            inflight=inflight, arrive_at=arrive, inflight_tau=tau,
        )

    return init_fn, fault_local_step, fault_sync_step


def make_dist_fault_round(
    grad_fn: Callable,
    inner_opt: GradientTransform,
    compressor: ShardCompressor,
    lr_schedule: Callable,
    mesh,
    data_axes: Sequence[str] = ("data",),
    param_specs=None,
    *,
    queue_depth: int,
    aggregate: str = "mean_R",
    wire: str = "dense_psum",
    staleness_weight: str = "uniform",
):
    """Round program for the mesh fault runtime: rounds close at every
    *event* step (``rounds.compile_fault_rounds``), so the scanned head
    is pure fault-local steps and the tail one fault-sync step.

    Returns ``(init_fn, round_fn, fused)`` with ``round_fn(state,
    batch_block, row_block, key) -> (state, losses[L], key)`` —
    ``row_block`` an ``engine.FaultRow`` of [L, R] arrays (stacked per
    step, ``engine.index_rows(rows, slice(start, stop))``).  Bit-for-bit
    the per-step fault trajectories; on 0.4.x TP>1 meshes degrades to
    the per-step host composition like ``make_dist_round``.
    """
    init_fn, fls, fss = make_dist_fault_steps(
        grad_fn, inner_opt, compressor, lr_schedule, mesh, data_axes,
        param_specs, queue_depth=queue_depth, aggregate=aggregate,
        wire=wire, staleness_weight=staleness_weight)
    from repro.core.engine import FaultRow, donated_jit
    fused = round_scan_supported(mesh, data_axes)

    def _tail(rows):
        return FaultRow(*(jnp.asarray(x)[-1] for x in rows))

    if fused:
        def round_program(state, batch_block, row_block, key):
            rows = FaultRow(*(jnp.asarray(x) for x in row_block))

            def body(carry, xs):
                state, key = carry
                batch, row = xs
                key, sub = jax.random.split(key)
                state, loss = fls(state, batch, row, sub)
                return (state, key), loss

            head_b = jax.tree_util.tree_map(lambda x: x[:-1], batch_block)
            head_r = FaultRow(*(x[:-1] for x in rows))
            tail_b = jax.tree_util.tree_map(lambda x: x[-1], batch_block)
            (state, key), head_losses = jax.lax.scan(
                body, (state, key), (head_b, head_r))
            key, sub = jax.random.split(key)
            state, tail_loss = fss(state, tail_b, _tail(rows), sub)
            return (state, jnp.concatenate([head_losses,
                                            tail_loss[None]]), key)

        return init_fn, donated_jit(round_program), True

    if "fault_round" not in _ROUND_FALLBACK_WARNED:
        warnings.warn(
            "the fused fault round program cannot be partitioned on a "
            "0.4.x jax mesh with a >1 tensor-parallel axis; falling "
            "back to per-step dispatch — identical trajectories, only "
            "host overhead differs.", stacklevel=2)
        _ROUND_FALLBACK_WARNED.add("fault_round")
    jls = donated_jit(fls)
    jss = donated_jit(fss)

    def round_fallback(state, batch_block, row_block, key):
        rows = FaultRow(*(jnp.asarray(x) for x in row_block))
        L = jax.tree_util.tree_leaves(batch_block)[0].shape[0]
        losses = []
        for i in range(L):
            batch = jax.tree_util.tree_map(lambda x, i=i: x[i], batch_block)
            row = FaultRow(*(x[i] for x in rows))
            key, sub = jax.random.split(key)
            fn = jss if i == L - 1 else jls
            state, loss = fn(state, batch, row, sub)
            losses.append(loss)
        return state, jnp.stack(losses), key

    return init_fn, round_fallback, False


def _zero1_axis(shape, spec, W: int):
    """ZeRO-1 shard axis for a leaf: the first axis that divides by the
    worker count and is unsharded in the TP spec; None when no axis
    qualifies (leaf stays replicated).  Layer-stacked leaves [L, ...]
    with L !% W fall through to their (usually large) inner dims."""
    entries = (list(spec) + [None] * len(shape)) if spec is not None \
        else [None] * len(shape)
    for ax, n in enumerate(shape):
        if entries[ax] is None and n % W == 0 and n >= W:
            return ax
    return None


def _allgather_axis(x, daxes, axis):
    """ZeRO-1: gather the shards spread over the worker axes."""
    g = x
    for a in reversed(daxes):
        g = jax.lax.all_gather(g, a, axis=axis, tiled=True)
    return g


def _shard_axis(x, daxes, axis):
    """Keep only this worker's slice along ``axis`` (inverse gather)."""
    if MODERN:
        n = 1
        idx = 0
        for a in daxes:
            size = axis_size(a)
            idx = idx * size + jax.lax.axis_index(a)
            n *= size
        shard = x.shape[axis] // n
        return jax.lax.dynamic_slice_in_dim(x, idx * shard, shard, axis=axis)
    # 0.4.x partial-manual regions cannot lower axis_index (PartitionId
    # is unsupported under SPMD).  The operand is replicated over the
    # worker axes here, so psum_scatter per axis (summing `size`
    # identical copies) then one division recovers this worker's slice.
    # Exact for power-of-two axis sizes; otherwise the single division
    # costs at most 1 ulp per element per axis.
    g = x
    for a in daxes:
        size = axis_size(a)
        g = jax.lax.psum_scatter(
            g, a, scatter_dimension=axis, tiled=True) / size
    return g

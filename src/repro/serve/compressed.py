"""Compressed serving weights (DESIGN.md §11.1–§11.2).

A trained Qsparse checkpoint carries its compression policy
(``PolicySpec``), and serving reuses it: every 2-D weight whose rule
lands in the Top_k family becomes a compact ``(idx, val)`` sparse
tensor, every QSGD-ruled weight becomes per-row int8 levels plus an
f32 scale column, and everything else (norm gains, biases, 1-D leaves)
stays dense.  The compressed form is the *resident* form: forward
passes contract activations against it directly through the
``kernels/sparse_gemm.py`` Pallas GEMMs (dispatch-routed, reference
fallback off-TPU), and the dense weight is never materialized on the
load path — :data:`STATS` counts ``densify`` calls so tests and the
launcher can assert exactly that.

Storage orientation: compact rows always enumerate the GEMM *output*
dimension.  A regular ``[n_in, n_out]`` weight is stored as rows of
``W.T`` (``out_axis=1``, ``row_len = n_in``) so ``matmul(x) = x @ W``;
the ``[V, d]`` embedding is stored row-major (``out_axis=0``) so the
same buffers serve both token gather (``take_rows``) and the tied
output head (``h @ W.T``).  One layout, three consumers.
"""

from __future__ import annotations

from typing import Any, Optional

import jax
import jax.numpy as jnp

from repro.core import policy as pol
from repro.core.operators import (
    CompressionOp,
    QSGDQuantizer,
    RowSignTopK,
    RowTopK,
    SignSparsifier,
    TopK,
    ops_for_leaves,
    resolve_k,
)
from repro.kernels import dispatch as dsp

#: trace-time serving-path counters.  ``densify`` is the load-path
#: counter the zero-densify guarantee is asserted on: the engine's
#: forward never calls it; only explicit round-trip checks do.
STATS = {"densify": 0, "sparse_matmul": 0, "quant_matmul": 0,
         "take_rows": 0}

#: QSGD serving levels are stored as int8 sign*xi
_MAX_LEVELS = 127

_dispatch_cfg: Optional[dsp.DispatchConfig] = None


def reset_stats() -> None:
    for k in STATS:
        STATS[k] = 0


def set_dispatch(cfg: Optional[dsp.DispatchConfig]) -> None:
    """Pin the DispatchConfig every CompressedTensor matmul routes
    through (None = the dispatch module default)."""
    global _dispatch_cfg
    _dispatch_cfg = cfg


def get_dispatch() -> Optional[dsp.DispatchConfig]:
    return _dispatch_cfg


# ---------------------------------------------------------------------------
# the compressed-leaf pytree
# ---------------------------------------------------------------------------


@jax.tree_util.register_pytree_node_class
class CompressedTensor:
    """One compressed weight leaf in serving orientation.

    kind='sparse': ``a`` = int32 indices ``[R, kcap]`` (row-local,
    ascending, out-of-row sentinel ``idx = row_len``), ``b`` = f32
    values ``[R, kcap]``.  kind='quant': ``a`` = int8 levels
    ``[R, row_len]``, ``b`` = f32 scale ``[R, 1]``.  A leading stack
    axis (``a.ndim == 3``) carries scan-stacked layers; scan/vmap slice
    the children and rebuild per-layer 2-D tensors through the pytree
    protocol, so ``matmul`` only ever sees 2-D buffers.
    """

    def __init__(self, kind: str, a, b, row_len: int, shape: tuple,
                 out_axis: int, dtype: str, op: str):
        self.kind = kind
        self.a = a
        self.b = b
        self.row_len = int(row_len)
        self.shape = tuple(shape)
        self.out_axis = int(out_axis)
        self.dtype = str(dtype)
        self.op = op

    # -- pytree protocol (children traced, layout static) ------------------
    def tree_flatten(self):
        return ((self.a, self.b), (self.kind, self.row_len, self.shape,
                                   self.out_axis, self.dtype, self.op))

    @classmethod
    def tree_unflatten(cls, aux, children):
        kind, row_len, shape, out_axis, dtype, op = aux
        a, b = children
        return cls(kind, a, b, row_len, shape, out_axis, dtype, op)

    def __repr__(self):
        return (f"CompressedTensor({self.kind}, shape={self.shape}, "
                f"row_len={self.row_len}, out_axis={self.out_axis}, "
                f"op={self.op!r})")

    # -- serving consumers -------------------------------------------------
    @property
    def ndim(self) -> int:
        return len(self.shape)

    @property
    def compressed_bytes(self) -> int:
        return int(self.a.size * self.a.dtype.itemsize
                   + self.b.size * self.b.dtype.itemsize)

    @property
    def dense_bytes(self) -> int:
        n = 1
        for s in self.shape:
            n *= s
        return int(n * jnp.dtype(self.dtype).itemsize)

    def matmul(self, x: jnp.ndarray) -> jnp.ndarray:
        """``x @ W`` (regular weights) / ``x @ W.T`` (tied embedding
        head) without densifying: ``x[..., row_len] -> [..., R]``."""
        if self.a.ndim != 2:
            raise ValueError(
                "stacked CompressedTensor must be sliced (scan/vmap) "
                f"before matmul; got children of ndim {self.a.ndim}")
        cfg = get_dispatch()
        lead = x.shape[:-1]
        x2 = x.reshape(-1, x.shape[-1])
        if self.kind == "sparse":
            STATS["sparse_matmul"] += 1
            y = dsp.sparse_gemm(x2, self.a, self.b, self.row_len, cfg)
        else:
            STATS["quant_matmul"] += 1
            y = dsp.qdq_gemm(x2, self.a, self.b, cfg)
        return y.reshape(*lead, y.shape[-1]).astype(self.dtype)

    def take_rows(self, tokens: jnp.ndarray) -> jnp.ndarray:
        """Embedding gather: decode only the gathered rows
        (``tokens[...] -> [..., row_len]``); the full table is never
        built."""
        if self.out_axis != 0:
            raise ValueError("take_rows needs out_axis=0 storage "
                             f"(got out_axis={self.out_axis})")
        STATS["take_rows"] += 1
        flat = tokens.reshape(-1)
        a = jnp.take(self.a, flat, axis=0)
        b = jnp.take(self.b, flat, axis=0)
        if self.kind == "sparse":
            w = dsp.decode_rows(a, b, self.row_len)
        else:
            w = a.astype(jnp.float32) * b
        return w.reshape(*tokens.shape, self.row_len).astype(self.dtype)

    def densify(self) -> jnp.ndarray:
        """Reconstruct the dense weight in its original shape/dtype.
        Bumps ``STATS['densify']`` — the zero-densify serving guarantee
        is that the forward path never lands here."""
        STATS["densify"] += 1
        if self.kind == "sparse":
            def dec(a, b):
                return dsp.decode_rows(a, b, self.row_len)
        else:
            def dec(a, b):
                return a.astype(jnp.float32) * b
        if self.a.ndim == 3:
            w = jax.vmap(dec)(self.a, self.b)
            if self.out_axis == 1:
                w = jnp.swapaxes(w, 1, 2)
        else:
            w = dec(self.a, self.b)
            if self.out_axis == 1:
                w = w.T
        return w.reshape(self.shape).astype(self.dtype)


# ---------------------------------------------------------------------------
# policy-guided tree compression
# ---------------------------------------------------------------------------


def _is_matrix(leaf, path: str) -> bool:
    """Is this leaf a GEMM weight in serving terms?  3-D leaves are
    scan-stacked ``[L, a, b]`` matrices; 2-D leaves are matrices UNLESS
    they sit in a scan-stacked layer dict (path under ``layers/`` with
    no numeric component), where ``[L, d]`` is a stacked *vector* (norm
    gain) that the forward never feeds through a matmul."""
    if leaf.ndim == 3:
        return True
    if leaf.ndim != 2:
        return False
    parts = path.split("/")
    if parts[0] == "layers" and not any(p.isdigit() for p in parts):
        return False   # scan-stacked per-layer 1-D param
    return True


def _plan(op: CompressionOp, leaf) -> Optional[tuple]:
    """(kind, frac_or_s, sign_m) serving scheme for one (op, leaf) pair,
    or None for dense passthrough.  ``frac`` is the survivor fraction
    normalized out of the op's native domain (whole tensor for
    TopK/SignTopK, op.row_len for the row variants) so it transfers to
    the serving row length."""
    if isinstance(op, TopK):
        d = int(leaf.size) if leaf.ndim == 2 else int(leaf[0].size)
        return ("sparse", resolve_k(op.k, d) / d, 0)
    if isinstance(op, RowTopK):
        row = min(op.row_len, int(leaf.size))
        return ("sparse", resolve_k(op.k, row) / row, 0)
    if isinstance(op, SignSparsifier):
        if op.sparsifier != "top":
            return None
        d = int(leaf.size) if leaf.ndim == 2 else int(leaf[0].size)
        return ("sparse", resolve_k(op.k, d) / d, op.m)
    if isinstance(op, RowSignTopK):
        row = min(op.row_len, int(leaf.size))
        return ("sparse", resolve_k(op.k, row) / row, op.m)
    if isinstance(op, QSGDQuantizer):
        return ("quant", min(int(op.s), _MAX_LEVELS), 0)
    return None


def _sparse_rows(m: jnp.ndarray, k_row: int, kcap: int, sign_m: int):
    """Per-row magnitude top-k of ``m [R, n]`` into compact ``(idx,
    val)`` buffers of capacity ``kcap`` (ascending indices, sentinel
    ``(n, 0)`` padding).  ``sign_m`` > 0 applies the SignComp_k value
    coding: sign times ||sel||_m / k."""
    n = m.shape[1]
    _, idx = jax.lax.top_k(jnp.abs(m), k_row)
    idx = jnp.sort(idx, axis=1)
    vals = jnp.take_along_axis(m, idx, axis=1)
    if sign_m == 1:
        norm = jnp.sum(jnp.abs(vals), axis=1, keepdims=True)
        vals = jnp.where(vals >= 0, 1.0, -1.0) * (norm / k_row)
    elif sign_m == 2:
        norm = jnp.sqrt(jnp.sum(vals * vals, axis=1, keepdims=True))
        vals = jnp.where(vals >= 0, 1.0, -1.0) * (norm / k_row)
    idx = jnp.pad(idx, ((0, 0), (0, kcap - k_row)),
                  constant_values=n).astype(jnp.int32)
    vals = jnp.pad(vals, ((0, 0), (0, kcap - k_row)))
    return idx, vals.astype(jnp.float32)


def _quant_rows(m: jnp.ndarray, s: int):
    """Deterministic per-row QSGD snapshot of ``m [R, n]``: int8 levels
    ``sign * round(s|x|/||row||)`` plus the ``[R, 1]`` f32 scale
    ``||row||/s``.  Round-to-nearest, not stochastic: the dither in the
    training quantizer unbiases *gradients across steps*; a one-shot
    weight snapshot just wants minimum distortion."""
    mf = m.astype(jnp.float32)
    norm = jnp.sqrt(jnp.sum(mf * mf, axis=1, keepdims=True))
    safe = jnp.where(norm > 0, norm, 1.0)
    level = jnp.clip(jnp.round(jnp.abs(mf) / safe * s), 0, s)
    lv = (jnp.sign(mf) * level).astype(jnp.int8)
    return lv, (norm / s).astype(jnp.float32)


def _compress_leaf(leaf, op: CompressionOp, path: str
                   ) -> Any:
    if not _is_matrix(leaf, path):
        return leaf
    plan = _plan(op, leaf)
    if plan is None:
        return leaf
    kind, param, sign_m = plan
    out_axis = 0 if path.split("/")[-1] == "embed" else 1
    stacked = leaf.ndim == 3
    rows = leaf if out_axis == 0 else jnp.swapaxes(leaf, -1, -2)
    rows = rows.astype(jnp.float32)
    n = rows.shape[-1]
    try:
        op_str = pol.OpSpec.of(op).to_string()
    except Exception:
        op_str = type(op).__name__
    if kind == "sparse":
        k_row = max(1, min(n, round(param * n)))
        kcap = dsp.capacity(k_row, n)
        fn = lambda m: _sparse_rows(m, k_row, kcap, sign_m)  # noqa: E731
    else:
        fn = lambda m: _quant_rows(m, param)                 # noqa: E731
    a, b = (jax.vmap(fn)(rows) if stacked else fn(rows))
    return CompressedTensor(kind, a, b, n, leaf.shape, out_axis,
                            jnp.dtype(leaf.dtype).name, op_str)


def compress_tree(params, policy) -> Any:
    """Policy-guided one-shot compression of a dense param tree into
    serving form.  ``policy`` is anything ``core.policy`` accepts (DSL
    string, PolicySpec, ChannelSpec — uplink side — or a plain
    operator/op-tree); rules select per-leaf schemes via :func:`_plan`.
    Returns the params tree with eligible leaves replaced by
    :class:`CompressedTensor` (all other leaves untouched)."""
    try:
        op_tree = pol.as_channel_spec(policy).uplink.resolve(params)
    except TypeError:
        op_tree = pol.resolve(policy, params)
    paths, leaves, treedef = pol.tree_paths(params)
    ops = ops_for_leaves(op_tree, len(leaves))
    out = [_compress_leaf(leaf, op, path)
           for leaf, op, path in zip(leaves, ops, paths)]
    return jax.tree_util.tree_unflatten(treedef, out)


def tree_bytes(params) -> dict:
    """{'compressed': int, 'dense': int, 'leaves': int} resident-bytes
    summary of a (possibly compressed) param tree."""
    comp = dense = n = 0
    for leaf in jax.tree_util.tree_leaves(
            params, is_leaf=lambda x: isinstance(x, CompressedTensor)):
        n += 1
        if isinstance(leaf, CompressedTensor):
            comp += leaf.compressed_bytes
            dense += leaf.dense_bytes
        else:
            comp += int(leaf.size * leaf.dtype.itemsize)
            dense += int(leaf.size * leaf.dtype.itemsize)
    return {"compressed": comp, "dense": dense, "leaves": n}

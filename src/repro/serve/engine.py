"""Continuous-batching request runtime (DESIGN.md §11.3).

The engine owns ``max_batch`` decode *slots*.  Each slot holds one
in-flight request's KV cache (a B=1 cache stacked on a leading slot
axis, so per-slot position state stays independent); every engine
iteration admits queued requests into free slots (prefill-insert) and
then advances **all** active slots by one token with a single vmapped,
jitted decode step.  Completion frees the slot for the next queued
request immediately — prefill and decode interleave, nothing waits for
a batch to drain.  ``scheduler='static'`` keeps the same machinery but
only admits when every slot is free (the classic static-batching
baseline the benchmarks compare against).

Slot admission (``_admit``): the prompt is right-padded to the engine's
static ``prompt_pad`` (one prefill compilation), the B=1 prefilled
cache has its pad positions invalidated (``pos >= true_len -> -1``) and
is written into the slot axis with a ``dynamic_update_slice``.  The
first decode step then re-feeds the last prompt token at position
``true_len - 1`` — an idempotent rewrite of that token's k/v — so
sampling starts from logits conditioned on the true prompt, not on pad
garbage.

Everything model-facing goes through ``models.transformer`` entry
points; compressed parameter trees (``serve.compressed``) drop in
unchanged because the model's matmuls are duck-typed on the leaves.
"""

from __future__ import annotations

import dataclasses
import time
from collections import deque
from typing import Any, List, Optional, Sequence

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import ModelConfig
from repro.models import transformer as tf
from repro.models.layers import KVCache


@dataclasses.dataclass
class Request:
    """One generation request: token prompt + decode budget."""

    prompt: Sequence[int]
    max_new_tokens: int = 16
    rid: int = -1
    submit_s: float = 0.0


@dataclasses.dataclass
class RequestMetrics:
    """Per-request serving metrics (all host wall-clock)."""

    rid: int
    prompt_len: int
    new_tokens: int
    queue_wait_s: float     # submit -> slot admission
    ttft_s: float           # submit -> first generated token
    decode_s: float         # first token -> completion
    tokens_per_s: float     # new_tokens / (admission -> completion)


@dataclasses.dataclass
class _Slot:
    rid: int = -1
    tokens: Optional[List[int]] = None   # generated so far
    next_token: int = 0
    pos: int = 0                         # position of next_token
    remaining: int = 0
    admit_s: float = 0.0
    submit_s: float = 0.0
    ttft_s: float = -1.0

    @property
    def free(self) -> bool:
        return self.rid < 0


def _sanitize(cache, true_len):
    """Invalidate prefill pad positions so decode masks them."""
    def fix(c: KVCache) -> KVCache:
        pos = jnp.where((c.pos >= 0) & (c.pos < true_len), c.pos, -1)
        return c._replace(pos=pos)
    if isinstance(cache, KVCache):
        return fix(cache)
    return [fix(c) for c in cache]


class ServeEngine:
    """Continuous-batching serving runtime over a (possibly compressed)
    parameter tree.

    model forward entry points come from ``models.transformer``;
    ``scheduler`` is 'continuous' (slot reuse on completion) or
    'static' (admit only into an all-free batch).  Greedy decoding;
    ``eos_id`` stops a request early.
    """

    def __init__(self, params, cfg: ModelConfig, *, max_batch: int = 8,
                 max_len: int = 64, prompt_pad: int = 16,
                 scheduler: str = "continuous",
                 eos_id: Optional[int] = None):
        if scheduler not in ("continuous", "static"):
            raise ValueError(f"unknown scheduler {scheduler!r}")
        if prompt_pad >= max_len:
            raise ValueError("prompt_pad must leave room to decode "
                             f"(prompt_pad={prompt_pad}, max_len={max_len})")
        self.params = params
        self.cfg = cfg
        self.max_batch = int(max_batch)
        self.max_len = int(max_len)
        self.prompt_pad = int(prompt_pad)
        self.scheduler = scheduler
        self.eos_id = eos_id
        self._queue: deque = deque()
        self._slots = [_Slot() for _ in range(self.max_batch)]
        self._next_rid = 0
        self._outputs: dict = {}
        self._metrics: dict = {}
        #: per-iteration active-slot counts (scheduler-invariant tests)
        self.occupancy: List[int] = []
        self.steps = 0

        one = tf.init_cache(cfg, 1, self.max_len)
        self._caches = jax.tree_util.tree_map(
            lambda x: jnp.stack([x] * self.max_batch), one)

        cfg_ = cfg
        maxlen = self.max_len

        def _admit_fn(params, caches, toks, true_len, slot):
            # toks: [prompt_pad] int32; true_len, slot: traced scalars
            _, cache, _ = tf.prefill(params, {"tokens": toks[None]}, cfg_,
                                     max_len=maxlen)
            cache = _sanitize(cache, true_len)

            def ins(big, small):
                return jax.lax.dynamic_update_slice(
                    big, small[None].astype(big.dtype),
                    (slot,) + (0,) * small.ndim)
            return jax.tree_util.tree_map(ins, caches, cache)

        def _step_fn(params, caches, toks, poss):
            # toks, poss: [max_batch] int32 (per-slot token + position)
            def one(cache, tok, pos):
                logits, new_c = tf.decode_step(params, cache, tok[None],
                                               pos, cfg_)
                return jnp.argmax(logits[0], axis=-1).astype(jnp.int32), new_c
            return jax.vmap(one, in_axes=(0, 0, 0))(caches, toks, poss)

        self._admit_jit = jax.jit(_admit_fn)
        self._step_jit = jax.jit(_step_fn)

    # -- request intake ----------------------------------------------------
    def submit(self, prompt: Sequence[int], max_new_tokens: int = 16) -> int:
        """Enqueue one request; returns its request id."""
        prompt = list(int(t) for t in prompt)
        if not prompt:
            raise ValueError("empty prompt")
        if len(prompt) > self.prompt_pad:
            raise ValueError(f"prompt length {len(prompt)} exceeds "
                             f"prompt_pad={self.prompt_pad}")
        budget = self.max_len - len(prompt)
        rid = self._next_rid
        self._next_rid += 1
        self._queue.append(Request(prompt, min(max_new_tokens, budget),
                                   rid, time.perf_counter()))
        return rid

    # -- scheduling --------------------------------------------------------
    def _free_slots(self) -> List[int]:
        return [i for i, s in enumerate(self._slots) if s.free]

    def _admit(self) -> int:
        """Move queued requests into free slots (FIFO).  The static
        scheduler admits only when *every* slot is free."""
        free = self._free_slots()
        if self.scheduler == "static" and len(free) < self.max_batch:
            return 0
        admitted = 0
        for slot_id in free:
            if not self._queue:
                break
            req = self._queue.popleft()
            toks = np.zeros(self.prompt_pad, np.int32)
            toks[:len(req.prompt)] = req.prompt
            true_len = len(req.prompt)
            self._caches = self._admit_jit(
                self.params, self._caches, jnp.asarray(toks),
                jnp.asarray(true_len, jnp.int32),
                jnp.asarray(slot_id, jnp.int32))
            self._slots[slot_id] = _Slot(
                rid=req.rid, tokens=[], next_token=req.prompt[-1],
                pos=true_len - 1, remaining=req.max_new_tokens,
                admit_s=time.perf_counter(), submit_s=req.submit_s)
            admitted += 1
        return admitted

    def step(self) -> int:
        """One engine iteration: admit, then advance every active slot
        one token.  Returns the number of requests completed."""
        self._admit()
        active = [i for i, s in enumerate(self._slots) if not s.free]
        if not active:
            return 0
        self.occupancy.append(len(active))
        toks = np.zeros(self.max_batch, np.int32)
        poss = np.zeros(self.max_batch, np.int32)
        for i, s in enumerate(self._slots):
            if not s.free:
                toks[i] = s.next_token
                poss[i] = s.pos
        nxt, self._caches = self._step_jit(
            self.params, self._caches, jnp.asarray(toks), jnp.asarray(poss))
        nxt = np.asarray(nxt)
        now = time.perf_counter()
        done = 0
        for i in active:
            s = self._slots[i]
            tok = int(nxt[i])
            s.tokens.append(tok)
            if s.ttft_s < 0:
                s.ttft_s = now - s.submit_s
            s.pos += 1
            s.next_token = tok
            s.remaining -= 1
            if s.remaining <= 0 or (self.eos_id is not None
                                    and tok == self.eos_id):
                self._finish(i, now)
                done += 1
        self.steps += 1
        return done

    def _finish(self, slot_id: int, now: float) -> None:
        s = self._slots[slot_id]
        n = len(s.tokens)
        span = max(now - s.admit_s, 1e-9)
        self._outputs[s.rid] = list(s.tokens)
        self._metrics[s.rid] = RequestMetrics(
            rid=s.rid, prompt_len=s.pos + 1 - n, new_tokens=n,
            queue_wait_s=s.admit_s - s.submit_s, ttft_s=s.ttft_s,
            decode_s=max(now - (s.submit_s + s.ttft_s), 0.0),
            tokens_per_s=n / span)
        self._slots[slot_id] = _Slot()

    @property
    def pending(self) -> int:
        return len(self._queue) + sum(1 for s in self._slots if not s.free)

    def run(self, requests: Optional[Sequence[Request]] = None,
            max_steps: int = 100_000) -> dict:
        """Drive the engine until every queued request completes.
        Returns {'outputs': {rid: tokens}, 'metrics': {rid: ...},
        'requests_per_s': float, 'tokens_per_s': float, 'steps': int}.
        """
        for req in requests or ():
            self.submit(req.prompt, req.max_new_tokens)
        t0 = time.perf_counter()
        steps = 0
        while self.pending and steps < max_steps:
            self.step()
            steps += 1
        wall = max(time.perf_counter() - t0, 1e-9)
        mets = dict(self._metrics)
        total_tokens = sum(m.new_tokens for m in mets.values())
        return {
            "outputs": dict(self._outputs),
            "metrics": mets,
            "requests_per_s": len(mets) / wall,
            "tokens_per_s": total_tokens / wall,
            "steps": steps,
            "wall_s": wall,
        }

"""Continuous-batching request runtime (DESIGN.md §11.3, §12).

The engine owns ``max_batch`` decode *slots*.  Each slot holds one
in-flight request's KV state; every engine iteration admits queued
requests into free slots (prefill-insert) and then advances **all**
active slots by one token with a single jitted decode step.  Completion
frees the slot for the next queued request immediately — prefill and
decode interleave, nothing waits for a batch to drain.
``scheduler='static'`` keeps the same machinery but only admits when
every slot is free (the classic static-batching baseline the benchmarks
compare against).

Two KV layouts (DESIGN.md §12):

- **contiguous** (default): a B=1 ``max_len`` ring cache per slot,
  stacked on a leading slot axis; HBM is ``max_batch × max_len``
  regardless of the tokens actually in flight.
- **paged** (``paged=True``): one shared page pool
  (``models.layers.PagedKVCache``, [L, n_pages, page_size, KV, hd]) +
  per-request block tables.  Admission allocates ``ceil(true_len /
  page_size)`` pages from a host-side free list (``serve.paging``),
  decode grows a request's table page-by-page, and occupancy is
  bounded by *tokens in flight*: requests are admitted while pages
  remain, stall in the queue when the pool can't hold their prompt
  (``admission_stalls``), and — when an active request needs a growth
  page the pool can't supply — the newest-admitted other request is
  preempted (pages freed, original request requeued at the *front* for
  recompute-from-start; ``preemptions``).  ``n_pages >=
  max_pages_per_req`` is enforced at construction, so a lone request
  can always finish and the preemption loop terminates.

Slot admission (``_admit``): the prompt is right-padded to the engine's
static ``prompt_pad`` (one prefill compilation).  Contiguous: the B=1
prefilled cache has its pad positions invalidated (``pos >= true_len ->
-1``) and is written into the slot axis with a ``dynamic_update_slice``.
Paged: the prefilled KV is scattered into the allocated pages
(pad-token garbage beyond ``true_len`` lands inside owned pages, is
masked by the per-slot length until decode overwrites it, and never
crosses request boundaries).  Either way the first decode step re-feeds
the last prompt token at position ``true_len - 1`` — an idempotent
rewrite of that token's k/v (int8 page quantization is deterministic,
so requantization is idempotent too) — so sampling starts from logits
conditioned on the true prompt, not on pad garbage.

Per-step host↔device traffic is download-only: slot tokens, positions
and liveness live in device buffers that the jitted step advances
(``poss + 1``) and that admission/finish *events* patch pointwise —
the per-step ``jnp.asarray`` uploads of the original engine are gone,
and tests pin ``_step_jit._cache_size() == 1`` across a whole run.

Everything model-facing goes through ``models.transformer`` entry
points; compressed parameter trees (``serve.compressed``) drop in
unchanged because the model's matmuls are duck-typed on the leaves.
"""

from __future__ import annotations

import dataclasses
import time
from collections import deque
from typing import Any, List, Optional, Sequence

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import ModelConfig
from repro.kernels.launch_stats import PAGE_POOL
from repro.models import layers as mlayers
from repro.models import transformer as tf
from repro.models.layers import KVCache
from repro.serve.paging import PagePool


@dataclasses.dataclass
class Request:
    """One generation request: token prompt + decode budget."""

    prompt: Sequence[int]
    max_new_tokens: int = 16
    rid: int = -1
    submit_s: float = 0.0


@dataclasses.dataclass
class RequestMetrics:
    """Per-request serving metrics (all host wall-clock)."""

    rid: int
    prompt_len: int
    new_tokens: int
    queue_wait_s: float     # submit -> slot admission
    ttft_s: float           # submit -> first generated token
    decode_s: float         # first token -> completion
    tokens_per_s: float     # new_tokens / (admission -> completion)


@dataclasses.dataclass
class _Slot:
    rid: int = -1
    tokens: Optional[List[int]] = None   # generated so far
    next_token: int = 0
    pos: int = 0                         # position of next_token
    remaining: int = 0
    admit_s: float = 0.0
    submit_s: float = 0.0
    ttft_s: float = -1.0
    # paged bookkeeping: owned physical pages (logical order), the
    # original request (for recompute-from-start preemption), and the
    # admission sequence number (preemption victims = newest first)
    pages: Optional[List[int]] = None
    prompt: Optional[List[int]] = None
    budget: int = 0
    admit_seq: int = -1

    @property
    def free(self) -> bool:
        return self.rid < 0


def _sanitize(cache, true_len):
    """Invalidate prefill pad positions so decode masks them."""
    def fix(c: KVCache) -> KVCache:
        pos = jnp.where((c.pos >= 0) & (c.pos < true_len), c.pos, -1)
        return c._replace(pos=pos)
    if isinstance(cache, KVCache):
        return fix(cache)
    return [fix(c) for c in cache]


class ServeEngine:
    """Continuous-batching serving runtime over a (possibly compressed)
    parameter tree.

    model forward entry points come from ``models.transformer``;
    ``scheduler`` is 'continuous' (slot reuse on completion) or
    'static' (admit only into an all-free batch).  Greedy decoding;
    ``eos_id`` stops a request early.  ``paged=True`` switches the KV
    state to the shared page pool (``page_size`` tokens per page,
    ``kv_pool_pages`` total — default ``max_batch * ceil(max_len /
    page_size)``, the contiguous layout's HBM equivalent);
    ``kv_quant=True`` stores pages as int8 levels + per-token-slot f32
    scales (4x KV HBM at f32 activations).
    """

    def __init__(self, params, cfg: ModelConfig, *, max_batch: int = 8,
                 max_len: int = 64, prompt_pad: int = 16,
                 scheduler: str = "continuous",
                 eos_id: Optional[int] = None,
                 paged: bool = False, page_size: int = 16,
                 kv_quant: bool = False,
                 kv_pool_pages: Optional[int] = None):
        if scheduler not in ("continuous", "static"):
            raise ValueError(f"unknown scheduler {scheduler!r}")
        if prompt_pad >= max_len:
            raise ValueError("prompt_pad must leave room to decode "
                             f"(prompt_pad={prompt_pad}, max_len={max_len})")
        if kv_quant and not paged:
            raise ValueError("kv_quant requires paged=True (the contiguous "
                             "layout has no quantized variant)")
        self.params = params
        self.cfg = cfg
        self.max_batch = int(max_batch)
        self.max_len = int(max_len)
        self.prompt_pad = int(prompt_pad)
        self.scheduler = scheduler
        self.eos_id = eos_id
        self.paged = bool(paged)
        self.kv_quant = bool(kv_quant)
        self._queue: deque = deque()
        self._slots = [_Slot() for _ in range(self.max_batch)]
        self._next_rid = 0
        self._admit_seq = 0
        self._outputs: dict = {}
        self._metrics: dict = {}
        #: per-iteration active-slot counts (scheduler-invariant tests)
        self.occupancy: List[int] = []
        self.steps = 0
        #: paged-runtime counters (mirrored into launch_stats.PAGE_POOL)
        self.preemptions = 0
        self.admission_stalls = 0
        self._peak_pages = 0

        cfg_ = cfg
        maxlen = self.max_len

        # per-slot decode state lives on device; the jitted step advances
        # positions, admission/finish events patch entries pointwise —
        # no per-step host->device uploads (tests pin the jit cache size)
        self._toks = jnp.zeros(self.max_batch, jnp.int32)
        self._poss = jnp.zeros(self.max_batch, jnp.int32)
        self._active = jnp.zeros(self.max_batch, bool)

        if self.paged:
            wins = cfg.layer_windows()
            if not (tf.uniform_windows(cfg) and cfg.scan_layers
                    and wins[0] <= 0):
                raise ValueError(
                    "paged KV serving requires uniform full-attention "
                    f"windows and scanned layers (windows={wins}, "
                    f"scan_layers={cfg.scan_layers})")
            if page_size <= 0:
                raise ValueError(f"page_size must be positive: {page_size}")
            self.page_size = int(page_size)
            self.max_pages_per_req = -(-self.max_len // self.page_size)
            default_pages = self.max_batch * self.max_pages_per_req
            self.n_pages = int(kv_pool_pages or default_pages)
            if self.n_pages < self.max_pages_per_req:
                raise ValueError(
                    f"kv_pool_pages={self.n_pages} cannot hold one "
                    f"max_len={self.max_len} request "
                    f"({self.max_pages_per_req} pages of {self.page_size})")
            self.pool_alloc = PagePool(self.n_pages, self.page_size)
            self._adm_pages = -(-self.prompt_pad // self.page_size)
            adm_cp = self._adm_pages * self.page_size
            self._pool = mlayers.init_paged_pool(
                cfg, self.n_pages, self.page_size, stacked=cfg.n_layers,
                quant=self.kv_quant)
            self._tables_np = np.full(
                (self.max_batch, self.max_pages_per_req), -1, np.int32)
            self._tables = jnp.asarray(self._tables_np)
            self._tables_dirty = False

            def _paged_admit_fn(params, pool, toks, page_ids):
                # toks: [prompt_pad]; page_ids: [adm_pages] physical page
                # destinations (n_pages sentinel = unallocated, dropped)
                _, cache, _ = tf.prefill(params, {"tokens": toks[None]},
                                         cfg_, max_len=adm_cp)
                return mlayers.paged_prefill_insert(
                    pool, cache.k[:, 0], cache.v[:, 0], page_ids)

            def _paged_step_fn(params, pool, tables, toks, poss, active):
                logits, new_pool = tf.decode_step_paged(
                    params, pool, tables, toks, poss, active, cfg_)
                nxt = jnp.argmax(logits, axis=-1).astype(jnp.int32)
                return nxt, new_pool, poss + 1

            self._admit_jit = jax.jit(_paged_admit_fn)
            self._step_jit = jax.jit(_paged_step_fn)
        else:
            one = tf.init_cache(cfg, 1, self.max_len)
            self._caches = jax.tree_util.tree_map(
                lambda x: jnp.stack([x] * self.max_batch), one)

            def _admit_fn(params, caches, toks, true_len, slot):
                # toks: [prompt_pad] int32; true_len, slot: traced scalars
                _, cache, _ = tf.prefill(params, {"tokens": toks[None]},
                                         cfg_, max_len=maxlen)
                cache = _sanitize(cache, true_len)

                def ins(big, small):
                    return jax.lax.dynamic_update_slice(
                        big, small[None].astype(big.dtype),
                        (slot,) + (0,) * small.ndim)
                return jax.tree_util.tree_map(ins, caches, cache)

            def _step_fn(params, caches, toks, poss):
                # toks, poss: [max_batch] int32 (per-slot token + position)
                def one(cache, tok, pos):
                    logits, new_c = tf.decode_step(params, cache, tok[None],
                                                   pos, cfg_)
                    return (jnp.argmax(logits[0], axis=-1).astype(jnp.int32),
                            new_c)
                nxt, new_caches = jax.vmap(one, in_axes=(0, 0, 0))(
                    caches, toks, poss)
                return nxt, new_caches, poss + 1

            self._admit_jit = jax.jit(_admit_fn)
            self._step_jit = jax.jit(_step_fn)

    # -- request intake ----------------------------------------------------
    def submit(self, prompt: Sequence[int], max_new_tokens: int = 16) -> int:
        """Enqueue one request; returns its request id."""
        prompt = list(int(t) for t in prompt)
        if not prompt:
            raise ValueError("empty prompt")
        if len(prompt) > self.prompt_pad:
            raise ValueError(f"prompt length {len(prompt)} exceeds "
                             f"prompt_pad={self.prompt_pad}")
        budget = self.max_len - len(prompt)
        rid = self._next_rid
        self._next_rid += 1
        self._queue.append(Request(prompt, min(max_new_tokens, budget),
                                   rid, time.perf_counter()))
        return rid

    # -- device slot state -------------------------------------------------
    def _set_slot_state(self, slot: int, tok: int, pos: int,
                        active: bool) -> None:
        """Point-patch one slot's device decode state (admission and
        finish events only — never per step)."""
        self._toks = self._toks.at[slot].set(tok)
        self._poss = self._poss.at[slot].set(pos)
        self._active = self._active.at[slot].set(active)

    # -- scheduling --------------------------------------------------------
    def _free_slots(self) -> List[int]:
        return [i for i, s in enumerate(self._slots) if s.free]

    def _admit(self) -> int:
        """Move queued requests into free slots (FIFO).  The static
        scheduler admits only when *every* slot is free.  Paged: the
        queue head additionally needs ``ceil(true_len / page_size)``
        free pages — token-budget admission; a blocked head counts an
        admission stall and keeps FIFO order (no head-of-line bypass)."""
        free = self._free_slots()
        if self.scheduler == "static" and len(free) < self.max_batch:
            return 0
        admitted = 0
        for slot_id in free:
            if not self._queue:
                break
            req = self._queue[0]
            true_len = len(req.prompt)
            pages: List[int] = []
            if self.paged:
                need = self.pool_alloc.pages_for(true_len)
                if not self.pool_alloc.can_alloc(need):
                    self.admission_stalls += 1
                    break
                pages = self.pool_alloc.alloc(need, req.rid)
            self._queue.popleft()
            toks = np.zeros(self.prompt_pad, np.int32)
            toks[:true_len] = req.prompt
            if self.paged:
                page_ids = np.full(self._adm_pages, self.n_pages, np.int32)
                page_ids[:len(pages)] = pages
                self._pool = self._admit_jit(
                    self.params, self._pool, jnp.asarray(toks),
                    jnp.asarray(page_ids))
                self._tables_np[slot_id, :] = -1
                self._tables_np[slot_id, :len(pages)] = pages
                self._tables_dirty = True
            else:
                self._caches = self._admit_jit(
                    self.params, self._caches, jnp.asarray(toks),
                    jnp.asarray(true_len, jnp.int32),
                    jnp.asarray(slot_id, jnp.int32))
            self._slots[slot_id] = _Slot(
                rid=req.rid, tokens=[], next_token=req.prompt[-1],
                pos=true_len - 1, remaining=req.max_new_tokens,
                admit_s=time.perf_counter(), submit_s=req.submit_s,
                pages=pages, prompt=list(req.prompt),
                budget=req.max_new_tokens, admit_seq=self._admit_seq)
            self._admit_seq += 1
            self._set_slot_state(slot_id, req.prompt[-1], true_len - 1, True)
            admitted += 1
        return admitted

    # -- paged page management ---------------------------------------------
    def _pick_victim(self, exclude: int) -> Optional[int]:
        cands = [(s.admit_seq, i) for i, s in enumerate(self._slots)
                 if not s.free and i != exclude]
        return max(cands)[1] if cands else None

    def _preempt(self, slot_id: int) -> None:
        """Evict one active request (recompute-from-start): free its
        pages, drop its generated tokens, and requeue the *original*
        request at the queue front so FIFO completion order survives."""
        s = self._slots[slot_id]
        self.pool_alloc.release(s.pages, s.rid)
        self._queue.appendleft(Request(s.prompt, s.budget, s.rid,
                                       s.submit_s))
        self._tables_np[slot_id, :] = -1
        self._tables_dirty = True
        self._slots[slot_id] = _Slot()
        self._set_slot_state(slot_id, 0, 0, False)
        self.preemptions += 1

    def _grow_pages(self) -> None:
        """Before a decode step, make sure every active slot owns the
        page its next write lands in, oldest admission first; preempt
        newest-admitted requests when the pool runs dry.  Terminates:
        ``n_pages >= max_pages_per_req`` guarantees the oldest survivor
        can always grow once every other slot is evicted."""
        order = sorted((i for i, s in enumerate(self._slots) if not s.free),
                       key=lambda i: self._slots[i].admit_seq)
        for i in order:
            s = self._slots[i]
            if s.free:           # preempted earlier in this pass
                continue
            while s.pos // self.page_size >= len(s.pages):
                if self.pool_alloc.can_alloc(1):
                    page = self.pool_alloc.alloc(1, s.rid)[0]
                    self._tables_np[i, len(s.pages)] = page
                    s.pages.append(page)
                    self._tables_dirty = True
                else:
                    victim = self._pick_victim(exclude=i)
                    if victim is None:
                        raise RuntimeError(
                            "page pool deadlock: lone request cannot grow "
                            "(kv_pool_pages misconfigured?)")
                    self._preempt(victim)

    def _refresh_gauges(self) -> None:
        used = self.pool_alloc.used_pages
        live = sum(s.pos + 1 for s in self._slots if not s.free)
        self._peak_pages = max(self._peak_pages, used)
        PAGE_POOL["pages_used"] = used
        PAGE_POOL["pages_free"] = self.pool_alloc.free_pages
        PAGE_POOL["peak_pages_used"] = self._peak_pages
        PAGE_POOL["fragmentation"] = (
            round(1.0 - live / (used * self.page_size), 4) if used else 0.0)
        PAGE_POOL["preemptions"] = self.preemptions
        PAGE_POOL["admission_stalls"] = self.admission_stalls

    def pool_metrics(self) -> dict:
        """Current page-pool gauges (paged engines only)."""
        if not self.paged:
            return {}
        self._refresh_gauges()
        return {"n_pages": self.n_pages, "page_size": self.page_size,
                "kv_quant": self.kv_quant, **{k: PAGE_POOL[k]
                                              for k in PAGE_POOL}}

    def step(self) -> int:
        """One engine iteration: admit, grow block tables (paged), then
        advance every active slot one token.  Returns the number of
        requests completed."""
        self._admit()
        if self.paged:
            self._grow_pages()
        active = [i for i, s in enumerate(self._slots) if not s.free]
        if not active:
            if self.paged:
                self._refresh_gauges()
            return 0
        self.occupancy.append(len(active))
        if self.paged:
            if self._tables_dirty:
                self._tables = jnp.asarray(self._tables_np)
                self._tables_dirty = False
            nxt, self._pool, self._poss = self._step_jit(
                self.params, self._pool, self._tables, self._toks,
                self._poss, self._active)
        else:
            nxt, self._caches, self._poss = self._step_jit(
                self.params, self._caches, self._toks, self._poss)
        self._toks = nxt
        nxt = np.asarray(nxt)
        now = time.perf_counter()
        done = 0
        for i in active:
            s = self._slots[i]
            tok = int(nxt[i])
            s.tokens.append(tok)
            if s.ttft_s < 0:
                s.ttft_s = now - s.submit_s
            s.pos += 1
            s.next_token = tok
            s.remaining -= 1
            if s.remaining <= 0 or (self.eos_id is not None
                                    and tok == self.eos_id):
                self._finish(i, now)
                done += 1
        self.steps += 1
        if self.paged:
            self._refresh_gauges()
        return done

    def _finish(self, slot_id: int, now: float) -> None:
        s = self._slots[slot_id]
        n = len(s.tokens)
        span = max(now - s.admit_s, 1e-9)
        self._outputs[s.rid] = list(s.tokens)
        self._metrics[s.rid] = RequestMetrics(
            rid=s.rid, prompt_len=s.pos + 1 - n, new_tokens=n,
            queue_wait_s=s.admit_s - s.submit_s, ttft_s=s.ttft_s,
            decode_s=max(now - (s.submit_s + s.ttft_s), 0.0),
            tokens_per_s=n / span)
        if self.paged and s.pages:
            self.pool_alloc.release(s.pages, s.rid)
            self._tables_np[slot_id, :] = -1
            self._tables_dirty = True
        self._slots[slot_id] = _Slot()
        self._set_slot_state(slot_id, 0, 0, False)

    @property
    def pending(self) -> int:
        return len(self._queue) + sum(1 for s in self._slots if not s.free)

    def run(self, requests: Optional[Sequence[Request]] = None,
            max_steps: int = 100_000) -> dict:
        """Drive the engine until every queued request completes.
        Returns {'outputs': {rid: tokens}, 'metrics': {rid: ...},
        'requests_per_s': float, 'tokens_per_s': float, 'steps': int}
        (plus 'pool': page-pool gauges when paged).
        """
        for req in requests or ():
            self.submit(req.prompt, req.max_new_tokens)
        t0 = time.perf_counter()
        steps = 0
        while self.pending and steps < max_steps:
            self.step()
            steps += 1
        wall = max(time.perf_counter() - t0, 1e-9)
        mets = dict(self._metrics)
        total_tokens = sum(m.new_tokens for m in mets.values())
        out: dict[str, Any] = {
            "outputs": dict(self._outputs),
            "metrics": mets,
            "requests_per_s": len(mets) / wall,
            "tokens_per_s": total_tokens / wall,
            "steps": steps,
            "wall_s": wall,
        }
        if self.paged:
            out["pool"] = self.pool_metrics()
        return out

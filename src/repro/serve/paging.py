"""Host-side free-list allocator for the shared KV page pool.

Pure python bookkeeping — the device arena (``models.layers.
PagedKVCache``) never moves; this module only decides which physical
page ids a request's block table points at.  Ownership is tracked per
page so double-frees and foreign-page releases fail loudly instead of
silently corrupting another request's KV state.

Kept deliberately standalone (no jax imports) so the allocator
invariants — conservation, no double allocation, exact-coverage block
tables — are property-testable without touching a device.
"""

from __future__ import annotations


class PagePool:
    """Fixed arena of ``n_pages`` pages of ``page_size`` token slots.

    ``alloc``/``release`` move page ids between the free list and the
    per-request ownership map; lowest-numbered free pages are handed out
    first (keeps smoke-test tables deterministic and dense)."""

    __slots__ = ("n_pages", "page_size", "_free", "_owner")

    def __init__(self, n_pages: int, page_size: int):
        if n_pages <= 0:
            raise ValueError(f"n_pages must be positive, got {n_pages}")
        if page_size <= 0:
            raise ValueError(f"page_size must be positive, got {page_size}")
        self.n_pages = int(n_pages)
        self.page_size = int(page_size)
        self._free = list(range(self.n_pages - 1, -1, -1))  # pop() -> lowest
        self._owner: dict[int, int] = {}

    @property
    def free_pages(self) -> int:
        return len(self._free)

    @property
    def used_pages(self) -> int:
        return self.n_pages - len(self._free)

    def pages_for(self, tokens: int) -> int:
        """Pages needed to hold ``tokens`` token slots (at least one —
        every admitted request owns a page for its first decode write)."""
        return max(1, -(-int(tokens) // self.page_size))

    def can_alloc(self, n: int) -> bool:
        return n <= len(self._free)

    def alloc(self, n: int, rid: int) -> list[int]:
        """Take ``n`` pages for request ``rid``; raises ``MemoryError``
        when the pool can't satisfy it (callers preempt or stall)."""
        if n > len(self._free):
            raise MemoryError(
                f"page pool exhausted: want {n}, free {len(self._free)}"
                f"/{self.n_pages}")
        pages = [self._free.pop() for _ in range(n)]
        for p in pages:
            self._owner[p] = rid
        return pages

    def release(self, pages, rid: int) -> None:
        """Return ``pages`` (owned by ``rid``) to the free list.
        Ownership is validated for the whole batch *before* any page is
        freed, so a rejected release leaves the pool untouched."""
        for p in pages:
            owner = self._owner.get(p)
            if owner != rid:
                raise ValueError(
                    f"release of page {p} by rid {rid}: owned by {owner}")
        for p in pages:
            del self._owner[p]
            self._free.append(p)

    def owner(self, page: int):
        return self._owner.get(page)

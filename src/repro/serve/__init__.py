"""Compressed-weight serving engine (DESIGN.md §11).

``serve.compressed`` turns a trained Qsparse checkpoint into
zero-densify serving weights — per-leaf compact ``(idx, val)`` sparse
buffers or int8-level quantized buffers chosen by the training policy —
and ``serve.engine`` runs a continuous-batching request runtime over
the model's prefill/decode entry points.
"""

from repro.serve.compressed import (   # noqa: F401
    STATS,
    CompressedTensor,
    compress_tree,
    get_dispatch,
    reset_stats,
    set_dispatch,
    tree_bytes,
)
from repro.serve.engine import (       # noqa: F401
    Request,
    RequestMetrics,
    ServeEngine,
)

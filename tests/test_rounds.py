"""Round-program runtime (DESIGN.md §7): schedule→round-plan
segmentation properties, and superstep-vs-per-step bit-for-bit parity
on states and every bits ledger across sync / async / downlink /
heterogeneous-policy configurations — engine, wrappers, and trainer."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest
import strategies
from hypothesis import given, settings

from repro.core import (async_qsparse, engine, operators as ops,
                        policy as pol, qsparse, rounds as rnd, schedule)
from repro.optim import constant, sgd
from repro.train.trainer import RunConfig, train

R, D = 4, 48


# ---------------------------------------------------------------------------
# segmentation: concatenated plans reproduce the original mask exactly
# ---------------------------------------------------------------------------


def _check_plans(mask):
    plans = rnd.compile_rounds(mask)
    m = np.asarray(mask, bool)
    # exact reconstruction (the runtime's correctness precondition)
    np.testing.assert_array_equal(rnd.expand_rounds(plans), m)
    # structural invariants: contiguity, ≥1-step rounds, all-local heads
    pos = 0
    rows = m if m.ndim == 2 else m[:, None]
    for p in plans:
        assert p.start == pos and p.length >= 1
        assert not rows[p.start:p.stop - 1].any(), "head step syncs"
        pos = p.stop
    assert pos == m.shape[0]
    # every sync row closes a round: plan count is #sync steps (+1 for a
    # trailing partial round)
    n_sync = int(rows.any(axis=1).sum())
    trailing = rows.shape[0] > 0 and not rows[-1].any()
    assert len(plans) == n_sync + int(trailing)
    if trailing:
        assert not plans[-1].syncs
    return plans


@settings(max_examples=40, deadline=None)
@given(case=strategies.fixed_schedule_cases(max_T=120, max_H=13))
def test_plans_reproduce_fixed_schedule(case):
    T, H = case
    mask = schedule.fixed_schedule(T, H)
    plans = _check_plans(mask)
    # fixed schedules compile to at most two distinct round lengths
    assert len(rnd.round_lengths(plans)) <= 2


@settings(max_examples=40, deadline=None)
@given(case=strategies.schedule_cases(max_T=120, max_R=8, max_H=9))
def test_plans_reproduce_async_schedule(case):
    T, Rr, H, seed = case
    _check_plans(schedule.async_schedule(T, Rr, H, seed=seed))


@settings(max_examples=40, deadline=None)
@given(case=strategies.schedule_cases(max_T=80, max_R=6, max_H=8))
def test_plans_reproduce_staggered_round_robin(case):
    """Worker r syncs at steps t+1 ≡ r (mod H): every step syncs some
    worker once R ≥ H, so rounds collapse to single steps."""
    T, Rr, H, _ = case
    H = max(H, 2)
    mask = np.zeros((T, Rr), bool)
    for r in range(Rr):
        for t in range(T):
            if (t + 1) % H == r % H:
                mask[t, r] = True
    plans = _check_plans(mask)
    if Rr >= H:
        assert all(p.length == 1 for p in plans)


@settings(max_examples=40, deadline=None)
@given(mask=strategies.sync_masks(max_T=64, max_R=5))
def test_plans_reproduce_random_mask(mask):
    """Arbitrary [T, R] masks — including all-False (one trailing
    partial round) and dense ones — reconstruct exactly."""
    _check_plans(mask)


@settings(max_examples=40, deadline=None)
@given(mask=strategies.scheduled_masks())
def test_plans_reproduce_scheduled_masks(mask):
    """Masks from every real schedule family — fixed broadcast, async,
    and fleet scenarios — segment and reconstruct exactly."""
    _check_plans(mask)


def test_trailing_partial_round():
    mask = np.zeros(7, bool)
    mask[2] = True  # last sync at step 3; steps 4-7 never sync
    plans = _check_plans(mask)
    assert [(p.start, p.length, p.syncs) for p in plans] == [
        (0, 3, True), (3, 4, False)]


def test_empty_and_shape_errors():
    assert rnd.compile_rounds(np.zeros((0, 3), bool)) == []
    assert rnd.expand_rounds([], R=3).shape == (0, 3)
    with pytest.raises(ValueError):
        rnd.compile_rounds(np.zeros((2, 3, 4), bool))


# ---------------------------------------------------------------------------
# superstep ≡ per-step, bit for bit (states + all ledgers)
# ---------------------------------------------------------------------------


def _problem(T, seed=2):
    cs = jax.random.normal(jax.random.PRNGKey(1), (R, D))

    def grad_fn(params, data):
        c, noise = data
        g = params["w"] - c + 0.01 * noise
        return 0.5 * jnp.sum((params["w"] - c) ** 2), {"w": g}

    k = jax.random.PRNGKey(seed)
    bs = []
    for _ in range(T):
        k, s = jax.random.split(k)
        bs.append((cs, jax.random.normal(s, (R, D))))
    return grad_fn, bs


def _assert_state_equal(s1, s2):
    for f in s1._fields:
        a, b = getattr(s1, f), getattr(s2, f)
        if a is None:
            assert b is None, f
            continue
        for x, y in zip(jax.tree_util.tree_leaves(a),
                        jax.tree_util.tree_leaves(b)):
            np.testing.assert_array_equal(np.asarray(x), np.asarray(y),
                                          err_msg=f)


def _engine_parity(operator, mask, *, downlink=None, leaf_ledger=False,
                   global_rounds=False, T=14):
    grad_fn, bs = _problem(T)
    params = {"w": jnp.zeros(D), "v": {"a": jnp.ones(D) * 0.1}}

    def grad2(p, data):
        loss, g = grad_fn({"w": p["w"]}, data)
        return loss, {"w": g["w"], "v": {"a": p["v"]["a"] * 0.01}}

    inner = sgd()
    kw = dict(downlink=downlink, leaf_ledger=leaf_ledger)
    s1 = engine.init(params, inner, R, **kw)
    step = engine.make_step(grad2, inner, operator, constant(0.05), R,
                            global_rounds=global_rounds, **kw)
    s1, l1 = engine.run(s1, step, bs, mask, jax.random.PRNGKey(3))
    s2 = engine.init(params, inner, R, **kw)
    sstep = engine.make_superstep(grad2, inner, operator, constant(0.05), R,
                                  global_rounds=global_rounds, **kw)
    s2, l2 = engine.run_rounds(s2, sstep, bs, mask, jax.random.PRNGKey(3))
    _assert_state_equal(s1, s2)
    np.testing.assert_array_equal(np.asarray(l1), np.asarray(l2))


def test_superstep_parity_sync():
    _engine_parity(ops.TopK(k=8), schedule.fixed_schedule(14, 4),
                   global_rounds=True)


def test_superstep_parity_async():
    _engine_parity(ops.TopK(k=8), schedule.async_schedule(14, R, 5, seed=3))


def test_superstep_parity_downlink():
    _engine_parity(ops.TopK(k=8), schedule.async_schedule(14, R, 4, seed=1),
                   downlink=ops.TopK(k=16))


def test_superstep_parity_hetero_policy_leaf_ledger():
    params = {"w": jnp.zeros(D), "v": {"a": jnp.ones(D) * 0.1}}
    op_tree = pol.resolve("v->qsgd:s=15;.*->topk:k=8", params)
    _engine_parity(op_tree, schedule.fixed_schedule(14, 4),
                   leaf_ledger=True, global_rounds=True)


def test_superstep_parity_trailing_partial():
    mask = schedule.fixed_schedule(14, 4).copy()
    mask[-1] = False  # steps 13-14 never sync: trailing partial round
    mask[-2] = False
    _engine_parity(ops.TopK(k=8), mask, global_rounds=True)


def test_run_jit_false_same_accounting():
    """jit=False exercises the identical loop and ledger accounting
    (compiled-vs-eager float rounding aside — ledgers count survivors,
    which exact-k selection pins)."""
    grad_fn, bs = _problem(10)
    params = {"w": jnp.zeros(D)}
    inner = sgd()
    mask = schedule.fixed_schedule(10, 3)
    step = qsparse.make_step(grad_fn, inner, ops.TopK(k=8), constant(0.05),
                             R)
    s1 = qsparse.init(params, inner, R)
    s1, l1 = qsparse.run(s1, step, bs, mask, jax.random.PRNGKey(3))
    s2 = qsparse.init(params, inner, R)
    s2, l2 = qsparse.run(s2, step, bs, mask, jax.random.PRNGKey(3),
                         jit=False)
    assert float(s1.bits) == float(s2.bits)
    assert float(s1.bits_down) == float(s2.bits_down)
    assert int(s1.rounds) == int(s2.rounds)
    np.testing.assert_allclose(np.asarray(l1), np.asarray(l2),
                               rtol=1e-5, atol=1e-6)
    np.testing.assert_allclose(np.asarray(s1.master["w"]),
                               np.asarray(s2.master["w"]),
                               rtol=1e-5, atol=1e-6)


def test_run_reuses_one_donated_executable():
    """run()/run_rounds() jit each step/superstep ONCE (cached on the
    function, state donated) — repeated drives reuse the executable."""
    grad_fn, bs = _problem(6)
    params = {"w": jnp.zeros(D)}
    inner = sgd()
    mask = schedule.fixed_schedule(6, 3)
    step = qsparse.make_step(grad_fn, inner, ops.TopK(k=8), constant(0.05),
                             R)
    s, _ = qsparse.run(qsparse.init(params, inner, R), step, bs, mask,
                       jax.random.PRNGKey(0))
    jitted = step._donated_jit
    s, _ = qsparse.run(qsparse.init(params, inner, R), step, bs, mask,
                       jax.random.PRNGKey(0))
    assert step._donated_jit is jitted
    # one executable, not one per run()
    assert jitted.jitted._cache_size() == 1


def test_run_rounds_short_batch_stream():
    """A batch iterable shorter than the schedule stops gracefully at
    the same prefix run() executes — the truncated round's tail is a
    mid-round (no-sync) step, exactly the per-step path's masks."""
    grad_fn, bs = _problem(13)
    params = {"w": jnp.zeros(D)}
    inner = sgd()
    mask = schedule.fixed_schedule(13, 4)
    bs = bs[:6]  # last full step is t=5, mid-round (sync is at t=7)
    step = qsparse.make_step(grad_fn, inner, ops.TopK(k=8), constant(0.05),
                             R)
    s1 = qsparse.init(params, inner, R)
    s1, l1 = qsparse.run(s1, step, bs, mask, jax.random.PRNGKey(3))
    sstep = qsparse.make_superstep(grad_fn, inner, ops.TopK(k=8),
                                   constant(0.05), R)
    s2 = qsparse.init(params, inner, R)
    s2, l2 = qsparse.run_rounds(s2, sstep, bs, mask, jax.random.PRNGKey(3))
    assert len(l1) == len(l2) == 6
    _assert_state_equal(s1, s2)
    np.testing.assert_array_equal(np.asarray(l1), np.asarray(l2))


# ---------------------------------------------------------------------------
# wrappers + trainer
# ---------------------------------------------------------------------------


def test_qsparse_superstep_parity():
    grad_fn, bs = _problem(13)
    params = {"w": jnp.zeros(D)}
    inner = sgd()
    mask = schedule.fixed_schedule(13, 4)
    step = qsparse.make_step(grad_fn, inner, ops.TopK(k=8), constant(0.05),
                             R)
    s1 = qsparse.init(params, inner, R)
    s1, l1 = qsparse.run(s1, step, bs, mask, jax.random.PRNGKey(3))
    sstep = qsparse.make_superstep(grad_fn, inner, ops.TopK(k=8),
                                   constant(0.05), R)
    s2 = qsparse.init(params, inner, R)
    s2, l2 = qsparse.run_rounds(s2, sstep, bs, mask, jax.random.PRNGKey(3))
    _assert_state_equal(s1, s2)
    np.testing.assert_array_equal(np.asarray(l1), np.asarray(l2))


def test_async_superstep_parity():
    grad_fn, bs = _problem(13)
    params = {"w": jnp.zeros(D)}
    inner = sgd()
    mask = schedule.async_schedule(13, R, 4, seed=5)
    step = async_qsparse.make_step(grad_fn, inner, ops.TopK(k=8),
                                   constant(0.05), R)
    s1 = async_qsparse.init(params, inner, R)
    s1, l1 = async_qsparse.run(s1, step, bs, mask, jax.random.PRNGKey(3))
    sstep = async_qsparse.make_superstep(grad_fn, inner, ops.TopK(k=8),
                                         constant(0.05), R)
    s2 = async_qsparse.init(params, inner, R)
    s2, l2 = async_qsparse.run_rounds(s2, sstep, bs, mask,
                                      jax.random.PRNGKey(3))
    _assert_state_equal(s1, s2)
    np.testing.assert_array_equal(np.asarray(l1), np.asarray(l2))


@pytest.mark.parametrize("asynchronous", [False, True])
@pytest.mark.parametrize("policy", ["topk:k=8", "topk:k=8 >> topk:k=16"])
def test_trainer_runtime_parity(asynchronous, policy):
    """RunConfig.runtime='round' vs 'step': identical History — the
    per-round loss blocks flatten to the same per-step view, mid-round
    log points read the same (previous-sync) ledger and master."""
    T = 17
    grad_fn, bs = _problem(T)
    params = {"w": jnp.zeros(D)}

    def eval_fn(m):
        return {"n": jnp.linalg.norm(m["w"])}

    results = {}
    for runtime in ("step", "round"):
        run = RunConfig(total_steps=T, R=R, H=4, asynchronous=asynchronous,
                        log_every=3, eval_every=5, leaf_ledger=True,
                        policy=policy, runtime=runtime, target_loss=200.0)
        results[runtime] = train(grad_fn, params, sgd(), None,
                                 constant(0.05), bs, run, eval_fn=eval_fn,
                                 smooth=4)
    (s1, h1), (s2, h2) = results["step"], results["round"]
    np.testing.assert_array_equal(np.asarray(s1.master["w"]),
                                  np.asarray(s2.master["w"]))
    for f in ("steps", "loss", "bits", "bits_down", "rounds", "leaf_bits",
              "leaf_bits_down", "eval_steps", "eval_metrics",
              "bits_to_target", "steps_to_target"):
        assert getattr(h1, f) == getattr(h2, f), f
    # the per-round blocks tile the schedule exactly
    assert h2.round_blocks and not h1.round_blocks
    assert sum(b[1] for b in h2.round_blocks) == T
    starts = [b[0] for b in h2.round_blocks]
    assert starts == sorted(starts) and starts[0] == 0


def test_trainer_runtime_validation():
    run = RunConfig(total_steps=2, R=R, runtime="warp")
    with pytest.raises(ValueError, match="runtime"):
        train(lambda p, b: (0.0, p), {"w": jnp.zeros(D)}, sgd(),
              ops.TopK(k=8), constant(0.1), [], run)

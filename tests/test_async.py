"""Algorithm 2 (asynchronous) behaviour tests."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from repro.core import async_qsparse, operators as ops, qsparse, schedule
from repro.optim import constant, inverse_time, sgd

R, D = 4, 40


@pytest.fixture(scope="module")
def problem():
    cs = jax.random.normal(jax.random.PRNGKey(1), (R, D))

    def grad_fn(params, data):
        c, noise = data
        g = params["w"] - c + 0.01 * noise
        return 0.5 * jnp.sum((params["w"] - c) ** 2), {"w": g}

    def batches(T, seed=2):
        k = jax.random.PRNGKey(seed)
        out = []
        for _ in range(T):
            k, s = jax.random.split(k)
            out.append((cs, jax.random.normal(s, (R, D))))
        return out

    return cs, grad_fn, batches


@settings(max_examples=20, deadline=None)
@given(T=st.integers(10, 200), Rr=st.integers(1, 12), H=st.integers(1, 9),
       seed=st.integers(0, 999))
def test_async_schedule_respects_gap(T, Rr, H, seed):
    mask = schedule.async_schedule(T, Rr, H, seed=seed)
    for g in schedule.worker_gaps(mask):
        assert 0 < g <= H


@settings(max_examples=20, deadline=None)
@given(T=st.integers(2, 300), H=st.integers(1, 16))
def test_fixed_schedule_gap(T, H):
    mask = schedule.fixed_schedule(T, H)
    idx = [t + 1 for t in range(T) if mask[t]]
    assert schedule.gap(idx) <= H
    assert T in idx  # paper requires T in I_T


def test_async_all_sync_equals_sync(problem):
    """When every worker syncs every step, Algorithm 2 == Algorithm 1."""
    cs, grad_fn, batches = problem
    T = 30
    bs = batches(T)
    op = ops.TopK(k=8)
    params = {"w": jnp.zeros(D)}
    inner = sgd()
    lr = constant(0.05)

    s1 = qsparse.init(params, inner, R)
    f1 = jax.jit(qsparse.make_step(grad_fn, inner, op, lr, R),
                 static_argnames=("sync",))
    s2 = async_qsparse.init(params, inner, R)
    f2 = jax.jit(async_qsparse.make_step(grad_fn, inner, op, lr, R))
    key = jax.random.PRNGKey(0)
    all_on = jnp.ones((R,), bool)
    for b in bs:
        key, k1 = jax.random.split(key)
        s1, _ = f1(s1, b, sync=True, key=k1)
        s2, _ = f2(s2, b, all_on, k1)
    np.testing.assert_allclose(np.asarray(s1.master["w"]),
                               np.asarray(s2.master["w"]),
                               rtol=1e-5, atol=1e-5)
    np.testing.assert_allclose(float(s1.bits), float(s2.bits))


def test_async_converges(problem):
    cs, grad_fn, batches = problem
    opt_pt = jnp.mean(cs, 0)
    T, H = 1200, 4
    op = ops.QuantizedSparsifier(k=8, s=15)
    params = {"w": jnp.zeros(D)}
    inner = sgd()
    lr = inverse_time(30.0, 200.0)
    state = async_qsparse.init(params, inner, R)
    step = async_qsparse.make_step(grad_fn, inner, op, lr, R)
    mask = schedule.async_schedule(T, R, H, seed=0)
    state, _ = async_qsparse.run(state, step, batches(T), mask,
                                 jax.random.PRNGKey(4))
    err = float(jnp.linalg.norm(state.master["w"] - opt_pt))
    assert err < 0.6, err


def test_async_nonsync_workers_keep_state(problem):
    cs, grad_fn, batches = problem
    op = ops.TopK(k=8)
    params = {"w": jnp.zeros(D)}
    inner = sgd()
    state = async_qsparse.init(params, inner, R)
    step = jax.jit(async_qsparse.make_step(grad_fn, inner, op,
                                           constant(0.05), R))
    b = batches(1)[0]
    flags = jnp.array([True] + [False] * (R - 1))
    state, _ = step(state, b, flags, jax.random.PRNGKey(0))
    # worker 0 synced: its view matches the new master; others still x0
    np.testing.assert_allclose(np.asarray(state.master_view["w"][0]),
                               np.asarray(state.master["w"]))
    np.testing.assert_allclose(np.asarray(state.master_view["w"][1]),
                               np.zeros(D))
    # memory only updated for worker 0
    assert float(jnp.sum(state.memory["w"][1] ** 2)) == 0.0
    assert float(jnp.sum(state.memory["w"][0] ** 2)) >= 0.0
    assert int(state.rounds) == 1

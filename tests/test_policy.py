"""Compression-policy API tests (DESIGN.md §6).

Pins the acceptance contract of the policy redesign:
 * every registered operator survives the full spec round trip
   (parse → to_dict → from_dict → to_string → parse) with identical
   resolved operators;
 * a catch-all single-rule policy is bit-for-bit identical to the
   pre-redesign single-operator trajectories (regression pin), through
   the raw engine and through the trainer surface;
 * rule order / first-match semantics are property-tested;
 * the global-budget allocator splits k proportional to leaf size;
 * a heterogeneous policy trains end-to-end with kernel dispatch and
   megabuffer packing (one launch per operator family per direction)
   and an exact per-leaf-group bits ledger;
 * the deprecated RunConfig/CLI surfaces keep working behind one-time
   warnings, and unknown names fail loudly everywhere.
"""

import argparse
import re
import warnings

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from repro.core import engine, operators as ops, policy as pol, qsparse
from repro.kernels import dispatch as dsp
from repro.optim import constant, sgd
from repro.train import checkpoint as ckpt
from repro.train import trainer


# ---------------------------------------------------------------------------
# spec round trips
# ---------------------------------------------------------------------------


def _example_spec(name: str) -> pol.OpSpec:
    """A non-trivial spec per registered op (sets k when it exists)."""
    entry = ops.OP_REGISTRY[name]
    kw = {}
    if "k" in entry.fields():
        kw["k"] = 0.25
    if "s" in entry.fields():
        kw["s"] = 7
    return pol.OpSpec(name, tuple(kw.items()))


@pytest.mark.parametrize("name", sorted(ops.OP_REGISTRY))
def test_opspec_roundtrip_every_registered_op(name):
    """parse → to_dict → from_dict → to_string → parse: identical
    resolved operators at every hop."""
    spec = _example_spec(name)
    op0 = spec.build()
    hops = [
        pol.OpSpec.parse(spec.to_string()),
        pol.OpSpec.from_dict(spec.to_dict()),
        pol.OpSpec.parse(
            pol.OpSpec.from_dict(
                pol.OpSpec.parse(spec.to_string()).to_dict()).to_string()),
    ]
    for h in hops:
        assert h == spec
        assert h.build() == op0


@pytest.mark.parametrize("name", sorted(ops.OP_REGISTRY))
def test_opspec_of_inverts_construction(name):
    op = ops.make_operator(name)
    spec = pol.OpSpec.of(op)
    assert spec.name == name or spec.build() == op
    assert spec.build() == op


def test_unknown_names_and_kwargs_fail_loudly():
    with pytest.raises(KeyError, match="registered"):
        pol.OpSpec.parse("nope")
    with pytest.raises(TypeError, match="no parameter"):
        pol.OpSpec.parse("topk:frac=0.5")
    with pytest.raises(KeyError, match="registered"):
        ops.make_operator("nope")
    with pytest.raises(TypeError, match="pins"):
        ops.make_operator("qtopk", sparsifier="rand")
    with pytest.raises(ValueError, match="key=value"):
        pol.OpSpec.parse("topk:k")


def test_policy_and_channel_roundtrip():
    text = ("budget=0.25;ln|bias->identity;embed->qsgd:s=15;"
            "topk:value_bits=16 >> signtopk:k=0.05")
    spec = pol.parse(text)
    assert isinstance(spec, pol.ChannelSpec)
    assert pol.parse(spec.to_string()) == spec
    assert pol.from_dict(spec.to_dict()) == spec
    # single side round trips as a PolicySpec
    side = pol.parse("a->topk:k=3;.*->identity")
    assert isinstance(side, pol.PolicySpec)
    assert pol.parse(side.to_string()) == side
    assert pol.from_dict(side.to_dict()) == side


def test_load_json_file(tmp_path):
    import json
    spec = pol.parse("embed->qsgd:s=15;.*->topk:k=0.01 >> topk:k=0.05")
    f = tmp_path / "policy.json"
    f.write_text(json.dumps(spec.to_dict()))
    assert pol.load(f"@{f}") == spec


# ---------------------------------------------------------------------------
# resolution: first-match rule order (property), budget, errors
# ---------------------------------------------------------------------------


_PATTERNS = ["^a", "a$", "ab", "b", r"\d", ".*"]
_TREE = {"ab": jnp.zeros(16), "ba": jnp.zeros(16),
         "nested": {"a1": jnp.zeros(16), "bb2": jnp.zeros(16)}}


@settings(max_examples=40, deadline=None)
@given(order=st.permutations(list(range(len(_PATTERNS)))),
       n_rules=st.integers(1, len(_PATTERNS)))
def test_first_match_rule_order_property(order, n_rules):
    """The resolved leaf operator is exactly the op of the first rule
    (in spec order) whose regex search-matches the leaf path — rule
    order is semantic, later matches never win."""
    chosen = [_PATTERNS[i] for i in order[:n_rules]]
    rules = tuple(
        pol.PolicyRule(pat, pol.OpSpec("topk", (("k", i + 2),)))
        for i, pat in enumerate(chosen))
    spec = pol.PolicySpec(rules)
    paths, _, _ = pol.tree_paths(_TREE)
    expected = {}
    for p in paths:
        m = next((i for i, pat in enumerate(chosen) if re.search(pat, p)),
                 None)
        expected[p] = m
    if any(v is None for v in expected.values()):
        with pytest.raises(ValueError, match="catch-all"):
            spec.resolve(_TREE)
        return
    tree = spec.resolve(_TREE)
    leaves = jax.tree_util.tree_leaves(
        tree, is_leaf=lambda z: isinstance(z, ops.CompressionOp))
    for p, op in zip(paths, leaves):
        assert op.k == expected[p] + 2, (p, op, chosen)


def test_budget_allocator_proportional_to_leaf_size():
    params = {"big": jnp.zeros((100, 10)), "small": jnp.zeros((50, 5)),
              "ln": jnp.zeros(7), "pinned": jnp.zeros(400)}
    spec = pol.parse(
        "budget=0.1;pinned->topk:k=5;big|small->topk;.*->identity")
    tree = spec.resolve(params)
    flat = dict(zip(pol.tree_paths(params)[0],
                    jax.tree_util.tree_leaves(
                        tree,
                        is_leaf=lambda z: isinstance(z, ops.CompressionOp))))
    # K = 0.1 * (1000 + 250) = 125, split 1000:250
    assert flat["big"].k == 100
    assert flat["small"].k == 25
    assert flat["pinned"].k == 5          # explicit k untouched
    assert isinstance(flat["ln"], ops.Identity)
    # absolute count form
    spec2 = pol.parse("budget=500;big|small->topk;.*->identity")
    tree2 = spec2.resolve(params)
    flat2 = dict(zip(pol.tree_paths(params)[0],
                     jax.tree_util.tree_leaves(
                         tree2,
                         is_leaf=lambda z: isinstance(z, ops.CompressionOp))))
    assert flat2["big"].k == 400 and flat2["small"].k == 100


def test_unmatched_leaf_is_an_error_not_identity():
    params = {"w": jnp.zeros(8), "unmatched": jnp.zeros(8)}
    spec = pol.parse("w->topk:k=2")
    with pytest.raises(ValueError, match="unmatched"):
        spec.resolve(params)


# ---------------------------------------------------------------------------
# regression pin: catch-all policy == historical single-op trajectories
# ---------------------------------------------------------------------------

R, D = 4, 48


def _problem():
    cs = jax.random.normal(jax.random.PRNGKey(1), (R, D))

    def grad_fn(p, data):
        c, noise = data
        return (0.5 * jnp.sum((p["w"] - c) ** 2),
                {"w": p["w"] - c + 0.01 * noise, "b": 0.1 * p["b"] + 0.01})

    def batches(T, seed=2):
        k = jax.random.PRNGKey(seed)
        out = []
        for _ in range(T):
            k, s = jax.random.split(k)
            out.append((cs, jax.random.normal(s, (R, D))))
        return out

    params = {"w": jnp.zeros(D), "b": jnp.zeros(12)}
    return params, grad_fn, batches


def _run(params, grad_fn, batches, operator, T=16, H=4, **cfg):
    from repro.core import schedule
    inner = sgd()
    state = qsparse.init(params, inner, R, **cfg)
    step = qsparse.make_step(grad_fn, inner, operator, constant(0.05), R,
                             **cfg)
    mask = schedule.fixed_schedule(T, H)
    return qsparse.run(state, step, batches(T), mask, jax.random.PRNGKey(3))


def test_catch_all_policy_bit_identical_to_single_op():
    """Acceptance pin: resolve('topk:k=10') reproduces the historical
    broadcast-operator trajectories bit-for-bit — same masters, locals,
    memories, losses and ledger."""
    params, grad_fn, batches = _problem()
    s0, l0 = _run(params, grad_fn, batches, ops.TopK(k=10))
    op_tree = pol.resolve("topk:k=10", params)
    s1, l1 = _run(params, grad_fn, batches, op_tree)
    for k in params:
        np.testing.assert_array_equal(np.asarray(s0.master[k]),
                                      np.asarray(s1.master[k]))
        np.testing.assert_array_equal(np.asarray(s0.local[k]),
                                      np.asarray(s1.local[k]))
        np.testing.assert_array_equal(np.asarray(s0.memory[k]),
                                      np.asarray(s1.memory[k]))
    assert l0 == l1
    assert float(s0.bits) == float(s1.bits)
    assert float(s0.bits_down) == float(s1.bits_down)


def test_trainer_policy_matches_operator_surface():
    """RunConfig.policy and the legacy operator argument produce
    bit-identical runs (the spec path adds no math)."""
    params, grad_fn, batches = _problem()
    T = 12
    st0, h0 = trainer.train(grad_fn, params, sgd(), ops.TopK(k=0.2),
                            constant(0.05), batches(T),
                            trainer.RunConfig(total_steps=T, R=R, H=4,
                                              log_every=4,
                                              dispatch="reference"))
    st1, h1 = trainer.train(grad_fn, params, sgd(), None, constant(0.05),
                            batches(T),
                            trainer.RunConfig(total_steps=T, R=R, H=4,
                                              log_every=4,
                                              dispatch="reference",
                                              policy="topk:k=0.2"))
    np.testing.assert_array_equal(np.asarray(st0.master["w"]),
                                  np.asarray(st1.master["w"]))
    assert h0.bits == h1.bits and h0.loss == h1.loss


# ---------------------------------------------------------------------------
# heterogeneous policy end to end (engine, kernels, packing, ledger)
# ---------------------------------------------------------------------------


def test_hetero_policy_trains_with_packing_and_leaf_ledger():
    """TopK on matmul kernels, QSGD on the embedding, dense on norms —
    through the engine with kernel dispatch and pack=True: per-family
    launch counts stay one per operator family per direction, and the
    per-leaf-group bits ledger is exact."""
    Rr = 2
    params = {
        "embed": 0.1 * jax.random.normal(jax.random.PRNGKey(0), (24, 128)),
        "layers": {
            "w1": 0.1 * jax.random.normal(jax.random.PRNGKey(1), (4, 256)),
            "w2": 0.1 * jax.random.normal(jax.random.PRNGKey(2), (4, 256)),
        },
        "ln": jnp.ones(16),
    }
    policy = pol.parse(
        "ln->identity;embed->qsgd:s=15;layers->topk:k=0.05"
        " >> ln->identity;.*->topk:k=0.1")
    up, down = pol.as_channel_spec(policy).resolve(params)

    def grad_fn(p, data):
        loss = sum(jnp.sum(l.astype(jnp.float32) ** 2)
                   for l in jax.tree_util.tree_leaves(p))
        return loss, jax.tree_util.tree_map(
            lambda l: 2.0 * l.astype(jnp.float32) + 0.01 * data, p)

    inner = sgd()
    cfg = dsp.DispatchConfig(mode="kernel", pack=True, min_size=1)
    state = engine.init(params, inner, Rr, downlink=down, leaf_ledger=True)
    step = engine.make_step(grad_fn, inner, up, constant(0.05), Rr,
                            dispatch=cfg, downlink=down, leaf_ledger=True)
    # one launch per operator family per direction per sync round
    dsp.reset_launches()
    jax.jit(step).lower(state, jnp.zeros((Rr,)), jnp.ones((Rr,), bool),
                        jax.random.PRNGKey(0))
    # uplink: one topk bucket (w1+w2 share (row,k,sign)) + one qsgd;
    # downlink: embed/w1/w2 all global-TopK rows but two row lengths
    # (embed 3072 vs layers 1024) -> two topk launches
    assert dsp.LAUNCHES["qsgd"] == 1
    assert dsp.LAUNCHES["topk_compress"] == 3
    fn = jax.jit(step)
    key = jax.random.PRNGKey(4)
    for t in range(6):
        key, sub = jax.random.split(key)
        state, loss = fn(state, jnp.zeros((Rr,)),
                         jnp.asarray((t + 1) % 2 == 0), sub)
    assert np.isfinite(float(loss))
    groups = engine.leaf_group_names(params)
    assert groups == ("embed", "layers", "ln")
    # the per-group ledgers sum exactly to the aggregate ledgers
    np.testing.assert_allclose(float(jnp.sum(state.leaf_bits)),
                               float(state.bits), rtol=1e-6)
    np.testing.assert_allclose(float(jnp.sum(state.leaf_bits_down)),
                               float(state.bits_down), rtol=1e-6)
    # identity group: uplink charges exactly the dense cost per worker
    # per round (Identity transmits dense); downlink Identity rule too
    rounds = 3
    i_ln = groups.index("ln")
    assert float(state.leaf_bits[i_ln]) == rounds * Rr * 32 * 16
    assert float(state.leaf_bits_down[i_ln]) == rounds * Rr * 32 * 16
    # every group transmitted something in both directions
    assert all(float(b) > 0 for b in state.leaf_bits)
    assert all(float(b) > 0 for b in state.leaf_bits_down)


# ---------------------------------------------------------------------------
# deprecation shims + loud errors on the config surfaces
# ---------------------------------------------------------------------------


def test_runconfig_downlink_op_shim_warns_and_works():
    params, grad_fn, batches = _problem()
    pol._WARNED_KEYS.clear()
    cfg = trainer.RunConfig(total_steps=4, R=R, H=2,
                            dispatch="reference",
                            downlink_op=ops.TopK(k=5))
    with warnings.catch_warnings(record=True) as w:
        warnings.simplefilter("always")
        up, down, spec = trainer.resolve_run_channels(
            ops.TopK(k=10), cfg, params)
    assert any("deprecated" in str(x.message) for x in w)
    assert isinstance(down, ops.TopK) and spec is None
    # one-time: a second resolve does not warn again
    with warnings.catch_warnings(record=True) as w2:
        warnings.simplefilter("always")
        trainer.resolve_run_channels(ops.TopK(k=10), cfg, params)
    assert not any("deprecated" in str(x.message) for x in w2)


def test_runconfig_policy_conflicts_and_registry_errors():
    params, grad_fn, batches = _problem()
    cfg = trainer.RunConfig(total_steps=4, R=R, policy="topk:k=2")
    with pytest.raises(ValueError, match="not both"):
        trainer.resolve_run_channels(ops.TopK(k=2), cfg, params)
    cfg2 = trainer.RunConfig(total_steps=4, R=R, policy="topk:k=2",
                             downlink_op=ops.TopK(k=2))
    with pytest.raises(ValueError, match="downlink"):
        trainer.resolve_run_channels(None, cfg2, params)
    with pytest.raises(ValueError, match="no compression"):
        trainer.resolve_run_channels(
            None, trainer.RunConfig(total_steps=4, R=R), params)
    # unknown downlink names go through the registry: loud KeyError,
    # never a silent identity (the old --downlink-k-frac=None path)
    cfg3 = trainer.RunConfig(total_steps=4, R=R, downlink_op="nope")
    with pytest.raises(KeyError, match="registered"):
        trainer.resolve_run_channels(ops.TopK(k=2), cfg3, params)


def test_launcher_legacy_flags_map_to_policy():
    from repro.launch import train as lt

    def ns(**kw):
        base = dict(policy=None, compressor=None, downlink=None,
                    downlink_k_frac=None, k_frac=0.02, arch="yi-6b")
        base.update(kw)
        return argparse.Namespace(**base)

    pol._WARNED_KEYS.clear()
    with warnings.catch_warnings(record=True) as w:
        warnings.simplefilter("always")
        spec = lt.resolve_policy_arg(
            ns(compressor="topk", downlink="topk"))
    assert any("deprecated" in str(x.message) for x in w)
    assert spec.uplink.rules[0].op == pol.OpSpec("topk", (("k", 0.02),))
    assert spec.downlink.rules[0].op == pol.OpSpec("topk", (("k", 0.02),))
    # --downlink-k-frac overrides; fallback to --k-frac otherwise
    spec2 = lt.resolve_policy_arg(
        ns(compressor="topk", downlink="signtopk", downlink_k_frac=0.5))
    assert spec2.downlink.rules[0].op == pol.OpSpec(
        "signtopk", (("k", 0.5),))
    # one-time warning
    with warnings.catch_warnings(record=True) as w2:
        warnings.simplefilter("always")
        lt.resolve_policy_arg(ns(compressor="topk"))
    assert not any("deprecated" in str(x.message) for x in w2)
    # unknown downlink name: loud registry error, not silent identity
    with pytest.raises(KeyError, match="registered"):
        lt.resolve_policy_arg(ns(downlink="nope"))
    # --policy + legacy flags conflict
    with pytest.raises(SystemExit):
        lt.resolve_policy_arg(ns(policy="topk:k=0.01", compressor="topk"))
    # no flags at all: the historical default (catch-all topk @ k-frac)
    spec3 = lt.resolve_policy_arg(ns())
    assert spec3.uplink.rules[0].op == pol.OpSpec("topk", (("k", 0.02),))
    assert spec3.downlink is None


def test_checkpoint_persists_policy(tmp_path):
    spec = pol.as_channel_spec(pol.parse(
        "embed->qsgd:s=15;.*->topk:k=0.01 >> topk:k=0.05"))
    tree = {"w": jnp.arange(4.0)}
    path = str(tmp_path / "ck")
    ckpt.save(path, tree, step=3, policy=spec.to_dict())
    assert ckpt.load_policy(path) == spec
    # pre-policy checkpoints read back as None
    path2 = str(tmp_path / "old")
    ckpt.save(path2, tree, step=1)
    assert ckpt.load_policy(path2) is None


def test_shard_compressor_normalizes_absolute_k_to_leaf_fraction():
    """The shard paths select per compression *row*: an absolute
    whole-leaf k (e.g. from the budget allocator) must become the
    equivalent leaf fraction in from_spec, not a per-row count — else
    a budget of 164 survivors on a (64, 256) leaf would transmit
    164 *per row* (~64x over budget, silently near-dense)."""
    from repro.core.distributed import ShardCompressor

    params = {"w": jnp.zeros((64, 256)), "ln": jnp.zeros(16)}
    comp = ShardCompressor.from_spec(
        "budget=164;w->topk;.*->identity", params, dispatch="reference")
    flat = dict(zip(pol.tree_paths(params)[0],
                    jax.tree_util.tree_leaves(
                        comp.ops,
                        is_leaf=lambda z: isinstance(z, ops.CompressionOp))))
    w_op = flat["w"]
    assert isinstance(w_op.k, float) and 0.0 < w_op.k < 1.0
    np.testing.assert_allclose(w_op.k, 164 / (64 * 256), rtol=1e-6)
    # end to end: survivors stay near the budget, not nrows * budget
    g = {"w": jax.random.normal(jax.random.PRNGKey(0), (64, 256)),
         "ln": jnp.zeros(16)}
    out, _bits = comp(g, None)
    nnz = int(jnp.sum(out["w"] != 0.0))
    assert nnz <= 3 * 64, nnz          # <= round-up of 164/64 per row
    assert nnz < 164 * 8               # nowhere near the per-row blowup
    # fractional and per-row ops pass through untouched
    comp2 = ShardCompressor.from_spec(
        "w->topk:k=0.05;.*->row_topk:k=7,row_len=8", params,
        dispatch="reference")
    flat2 = dict(zip(pol.tree_paths(params)[0],
                     jax.tree_util.tree_leaves(
                         comp2.ops,
                         is_leaf=lambda z: isinstance(z, ops.CompressionOp))))
    assert flat2["w"].k == 0.05
    assert flat2["ln"].k == 7


def test_trainer_leaf_ledger_history():
    params, grad_fn, batches = _problem()
    T = 8
    cfg = trainer.RunConfig(total_steps=T, R=R, H=4, log_every=4,
                            dispatch="reference", leaf_ledger=True,
                            policy="w->topk:k=10;.*->identity")
    state, hist = trainer.train(grad_fn, params, sgd(), None,
                                constant(0.05), batches(T), cfg)
    assert hist.leaf_groups == ["b", "w"]
    assert hist.leaf_bits and len(hist.leaf_bits[-1]) == 2
    np.testing.assert_allclose(sum(hist.leaf_bits[-1]), hist.bits[-1],
                               rtol=1e-6)
    np.testing.assert_allclose(sum(hist.leaf_bits_down[-1]),
                               hist.bits_down[-1], rtol=1e-6)
    assert "leaf_bits" in hist.summary()

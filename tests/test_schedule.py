"""core/schedule.py coverage: Definition-4 gap bounds on sampled async
schedules (property-tested) and fixed_schedule edge cases."""

import numpy as np
from hypothesis import given, settings

import strategies

from repro.core import schedule


# ---------------------------------------------------------------------------
# Definition 4: gap(I_T^{(r)}) <= H for every sampled worker schedule
# ---------------------------------------------------------------------------


@settings(max_examples=30, deadline=None)
@given(case=strategies.schedule_cases(max_T=250, max_R=10, max_H=12))
def test_async_schedule_gap_bounded(case):
    T, Rr, H, seed = case
    mask = schedule.async_schedule(T, Rr, H, seed=seed)
    assert mask.shape == (T, Rr)
    for g in schedule.worker_gaps(mask):
        assert 0 < g <= max(H, 1)
    # the paper requires T in I_T^{(r)} for every worker
    assert mask[T - 1].all()


@settings(max_examples=30, deadline=None)
@given(case=strategies.fixed_schedule_cases(max_T=250, max_H=16))
def test_fixed_schedule_gap_and_terminal(case):
    T, H = case
    mask = schedule.fixed_schedule(T, H)
    idx = [t + 1 for t in range(T) if mask[t]]
    # gap can reach H; the final partial window never exceeds it by
    # construction (T is appended, closing the last interval early)
    assert schedule.gap(idx) <= max(H, 1) or idx == [T]
    assert T in idx


# ---------------------------------------------------------------------------
# fixed_schedule edge cases
# ---------------------------------------------------------------------------


def test_fixed_schedule_T_smaller_than_H():
    """T < H: no interior multiple of H fits — only the mandatory
    terminal sync survives."""
    mask = schedule.fixed_schedule(3, 10)
    np.testing.assert_array_equal(mask, [False, False, True])


def test_fixed_schedule_H1_is_every_step():
    assert schedule.fixed_schedule(7, 1).all()


def test_fixed_schedule_T_multiple_of_H():
    mask = schedule.fixed_schedule(8, 4)
    np.testing.assert_array_equal(
        mask, [False] * 3 + [True] + [False] * 3 + [True])


def test_fixed_schedule_single_step():
    np.testing.assert_array_equal(schedule.fixed_schedule(1, 5), [True])


def test_schedule_from_indices_clamps_and_terminates():
    mask = schedule.schedule_from_indices(6, [2, 9, -1, 4])
    # out-of-range indices drop; T is always appended
    np.testing.assert_array_equal(
        mask, [False, True, False, True, False, True])


def test_gap_conventions():
    assert schedule.gap([]) == 0
    assert schedule.gap([5]) == 5          # measured from t = 0
    assert schedule.gap([2, 4, 9]) == 5

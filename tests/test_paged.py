"""Paged + quantized KV-cache serving (DESIGN.md §12): paged
flash-decode kernel vs gather oracle (fp + int8, geometry sweep),
paged-vs-contiguous logits pins at the transformer level, page
allocator properties (hypothesis + deterministic twins), and
``ServeEngine`` paged-runtime invariants — token parity with the
contiguous path, preemption recompute-from-start, admission stalls,
pool drain after ``run()``, block-table coverage, and the no-per-step-
recompilation jit cache pin (satellite fix).
"""

import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from repro.configs import get_config
from repro.kernels import dispatch as dsp
from repro.kernels.launch_stats import LAUNCHES, PAGE_POOL
from repro.kernels.paged_attention import paged_decode_fwd
from repro.kernels.ref import paged_decode_ref
from repro.models import layers as mlayers
from repro.models import transformer as tfm
from repro.serve.engine import ServeEngine
from repro.serve.paging import PagePool


@pytest.fixture(scope="module")
def smoke_model():
    cfg = get_config("yi-6b", smoke=True)
    params = tfm.init_params(jax.random.PRNGKey(0), cfg)
    return cfg, params


# ---------------------------------------------------------------------------
# kernel vs oracle
# ---------------------------------------------------------------------------


def _rand_pool(rng, n_pages, ps, KV, hd, quant):
    if quant:
        kp = rng.randint(-127, 128, (n_pages, ps, KV, hd)).astype(np.int8)
        vp = rng.randint(-127, 128, (n_pages, ps, KV, hd)).astype(np.int8)
        ks = (rng.rand(n_pages, ps) * 0.1).astype(np.float32)
        vs = (rng.rand(n_pages, ps) * 0.1).astype(np.float32)
    else:
        kp = rng.randn(n_pages, ps, KV, hd).astype(np.float32)
        vp = rng.randn(n_pages, ps, KV, hd).astype(np.float32)
        ks = np.zeros((n_pages, ps), np.float32)
        vs = np.zeros((n_pages, ps), np.float32)
    return map(jnp.asarray, (kp, vp, ks, vs))


def _rand_tables(rng, B, P, ps, n_pages, lengths):
    """Distinct physical pages per request, -1 beyond each row's need."""
    perm = rng.permutation(n_pages)[:B * P].reshape(B, P)
    tables = np.full((B, P), -1, np.int32)
    for b in range(B):
        need = max(1, -(-int(lengths[b]) // ps))
        tables[b, :need] = perm[b, :need]
    return jnp.asarray(tables)


@pytest.mark.parametrize("quant", [False, True])
@pytest.mark.parametrize("pb", [1, 2, 3, 4, 8])
def test_paged_kernel_matches_ref(quant, pb):
    rng = np.random.RandomState(0)
    B, H, KV, hd, ps, P, n_pages = 3, 8, 2, 32, 8, 5, 16
    q = jnp.asarray(rng.randn(B, 1, H, hd).astype(np.float32))
    kp, vp, ks, vs = _rand_pool(rng, n_pages, ps, KV, hd, quant)
    lengths = np.array([0, 7, P * ps], np.int32)   # free slot / partial / full
    tables = _rand_tables(rng, B, P, ps, n_pages, lengths)
    lens = jnp.asarray(lengths)
    ref = paged_decode_ref(q, kp, vp, ks, vs, tables, lens)
    out = paged_decode_fwd(q, kp, vp, ks, vs, tables, lens,
                           pages_per_block=pb, interpret=True)
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref), atol=1e-5)
    # a length-0 row is a free engine slot: exact zeros, not garbage
    assert float(jnp.max(jnp.abs(out[0]))) == 0.0


def test_paged_kernel_gqa_single_kv_head():
    # the smoke-model geometry: KV=1, every query head shares one page
    rng = np.random.RandomState(1)
    B, H, KV, hd, ps, P, n_pages = 2, 8, 1, 32, 4, 3, 8
    q = jnp.asarray(rng.randn(B, 1, H, hd).astype(np.float32))
    kp, vp, ks, vs = _rand_pool(rng, n_pages, ps, KV, hd, False)
    lengths = np.array([5, 12], np.int32)
    tables = _rand_tables(rng, B, P, ps, n_pages, lengths)
    ref = paged_decode_ref(q, kp, vp, ks, vs, tables, jnp.asarray(lengths))
    out = paged_decode_fwd(q, kp, vp, ks, vs, tables, jnp.asarray(lengths),
                           pages_per_block=2, interpret=True)
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref), atol=1e-5)


def test_dispatch_paged_decode_parity_and_counter():
    rng = np.random.RandomState(2)
    B, H, KV, hd, ps, P, n_pages = 2, 4, 2, 16, 4, 4, 12
    q = jnp.asarray(rng.randn(B, 1, H, hd).astype(np.float32))
    kp, vp, ks, vs = _rand_pool(rng, n_pages, ps, KV, hd, True)
    lengths = np.array([3, 16], np.int32)
    tables = _rand_tables(rng, B, P, ps, n_pages, lengths)
    lens = jnp.asarray(lengths)
    ref = dsp.paged_decode(q, kp, vp, ks, vs, tables, lens,
                           dsp.DispatchConfig(mode="reference"))
    before = LAUNCHES["paged_decode"]
    out = dsp.paged_decode(q, kp, vp, ks, vs, tables, lens,
                           dsp.DispatchConfig(mode="kernel"))
    assert LAUNCHES["paged_decode"] == before + 1
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref), atol=1e-5)


def test_paged_geometry_resolution():
    # explicit block_rows wins and is clamped to the table width
    assert dsp.paged_geometry(
        dsp.DispatchConfig(block_rows=64), 5, 8, 32, False) == 5
    # auto falls back to the default (clamped) when the table has no entry
    pb = dsp.paged_geometry(None, 3, 8, 32, False)
    assert 1 <= pb <= 3


# ---------------------------------------------------------------------------
# transformer-level logits pins (paged vs contiguous, fp + int8)
# ---------------------------------------------------------------------------


def _paged_logits(cfg, params, toks, quant, use_pallas=False):
    """Prefill -> page-pool insert -> one paged decode step."""
    if use_pallas:
        cfg = dataclasses.replace(cfg, use_pallas=True)
    S = toks.shape[1]
    ps = 4
    n_adm = -(-S // ps)
    cp = n_adm * ps
    logits_p, cache, _ = tfm.prefill(params, {"tokens": toks}, cfg,
                                     max_len=cp)
    pool = mlayers.init_paged_pool(cfg, 8, ps, stacked=cfg.n_layers,
                                   quant=quant)
    page_ids = jnp.arange(n_adm, dtype=jnp.int32)
    pool = mlayers.paged_prefill_insert(pool, cache.k[:, 0], cache.v[:, 0],
                                        page_ids)
    tables = np.full((1, 5), -1, np.int32)
    tables[0, :n_adm] = np.arange(n_adm)
    tok = jnp.argmax(logits_p[0, -1]).astype(jnp.int32)[None]
    lp, _ = tfm.decode_step_paged(
        params, pool, jnp.asarray(tables), tok,
        jnp.asarray([S], jnp.int32), jnp.asarray([True]), cfg)
    return lp


@pytest.mark.parametrize("prompt_len", [1, 3, 6])
def test_paged_logits_match_contiguous(smoke_model, prompt_len):
    cfg, params = smoke_model
    toks = jnp.asarray(np.random.RandomState(3).randint(
        0, cfg.vocab, (1, prompt_len)))
    logits_c, cache, _ = tfm.prefill(params, {"tokens": toks}, cfg,
                                     max_len=20)
    tok = jnp.argmax(logits_c[0, -1]).astype(jnp.int32)[None]
    lc, _ = tfm.decode_step(params, cache, tok, prompt_len, cfg)
    lp = _paged_logits(cfg, params, toks, quant=False)
    np.testing.assert_allclose(np.asarray(lp), np.asarray(lc), atol=1e-4)
    # int8 pages: within quantization tolerance, same greedy token
    lq = _paged_logits(cfg, params, toks, quant=True)
    np.testing.assert_allclose(np.asarray(lq), np.asarray(lc), atol=0.1)
    assert int(jnp.argmax(lq[0])) == int(jnp.argmax(lc[0]))


def test_paged_logits_kernel_matches_jnp(smoke_model):
    cfg, params = smoke_model
    toks = jnp.asarray(np.random.RandomState(4).randint(0, cfg.vocab,
                                                        (1, 5)))
    before = LAUNCHES["paged_decode"]
    lk = _paged_logits(cfg, params, toks, quant=False, use_pallas=True)
    assert LAUNCHES["paged_decode"] > before
    lj = _paged_logits(cfg, params, toks, quant=False)
    np.testing.assert_allclose(np.asarray(lk), np.asarray(lj), atol=1e-4)


def test_decode_step_paged_rejects_unscanned(smoke_model):
    cfg, params = smoke_model
    bad = dataclasses.replace(cfg, scan_layers=False)
    with pytest.raises(ValueError, match="paged decode"):
        tfm.decode_step_paged(params, None, None,
                              jnp.zeros(1, jnp.int32),
                              jnp.zeros(1, jnp.int32),
                              jnp.zeros(1, bool), bad)


# ---------------------------------------------------------------------------
# page allocator (hypothesis properties + deterministic twin)
# ---------------------------------------------------------------------------


def check_allocator_trace(n_pages, page_size, ops):
    """Replays (want_pages, release_idx) ops; checks conservation, no
    double allocation, and ownership-validated release throughout."""
    pool = PagePool(n_pages, page_size)
    held = {}          # rid -> pages
    rid = 0
    for want, release_idx in ops:
        want = 1 + want % n_pages
        if pool.can_alloc(want):
            pages = pool.alloc(want, rid)
            assert len(pages) == len(set(pages))
            for other, theirs in held.items():
                assert not set(pages) & set(theirs), "double allocation"
            held[rid] = pages
            rid += 1
        else:
            with pytest.raises(MemoryError):
                pool.alloc(want, rid)
        if held and release_idx is not None:
            victim = sorted(held)[release_idx % len(held)]
            pool.release(held.pop(victim), victim)
        live = sum(len(p) for p in held.values())
        assert pool.used_pages == live
        assert pool.free_pages == n_pages - live
    for r in sorted(held):
        pool.release(held.pop(r), r)
    assert pool.used_pages == 0 and pool.free_pages == n_pages


@settings(max_examples=50, deadline=None)
@given(n_pages=st.integers(1, 24), page_size=st.integers(1, 16),
       ops=st.lists(st.tuples(st.integers(0, 30),
                              st.one_of(st.none(), st.integers(0, 30))),
                    max_size=40))
def test_allocator_properties(n_pages, page_size, ops):
    check_allocator_trace(n_pages, page_size, ops)


def test_allocator_trace_deterministic():
    rng = np.random.RandomState(7)
    for n_pages in (1, 5, 16):
        ops = [(int(rng.randint(0, 30)),
                None if rng.rand() < 0.4 else int(rng.randint(0, 30)))
               for _ in range(60)]
        check_allocator_trace(n_pages, 4, ops)


def test_allocator_rejects_foreign_release():
    pool = PagePool(4, 2)
    pages = pool.alloc(2, rid=0)
    with pytest.raises(ValueError, match="owned by"):
        pool.release(pages, rid=1)
    pool.release(pages, rid=0)
    with pytest.raises(ValueError):       # double free
        pool.release(pages, rid=0)


def test_allocator_pages_for():
    pool = PagePool(8, 4)
    assert pool.pages_for(0) == 1
    assert pool.pages_for(1) == 1
    assert pool.pages_for(4) == 1
    assert pool.pages_for(5) == 2
    assert pool.pages_for(16) == 4


# ---------------------------------------------------------------------------
# engine: paged runtime invariants
# ---------------------------------------------------------------------------


REQS = [([5, 6, 7], 6), ([1], 4), ([9, 8, 7, 6, 5], 8), ([3, 3], 5),
        ([11, 2, 4], 7), ([8], 3)]


def _engine(smoke_model, **kw):
    cfg, params = smoke_model
    if kw.pop("use_pallas", False):
        cfg = dataclasses.replace(cfg, use_pallas=True)
    return ServeEngine(params, cfg, max_batch=2, max_len=20, prompt_pad=6,
                       **kw)


def _run(eng, reqs=REQS):
    for p, n in reqs:
        eng.submit(p, n)
    return eng.run()


@pytest.mark.parametrize("scheduler", ["continuous", "static"])
def test_paged_engine_matches_contiguous_tokens(smoke_model, scheduler):
    res_c = _run(_engine(smoke_model, scheduler=scheduler))
    res_p = _run(_engine(smoke_model, scheduler=scheduler, paged=True,
                         page_size=4))
    assert res_p["outputs"] == res_c["outputs"]


def test_paged_engine_int8_within_tolerance(smoke_model):
    # int8 pages change logits by ~1e-2 — greedy tokens may legitimately
    # diverge on near-ties, but every request must complete its budget
    # and stay in-vocab; on this smoke model they match exactly
    cfg, _ = smoke_model
    res_c = _run(_engine(smoke_model))
    res_q = _run(_engine(smoke_model, paged=True, page_size=4,
                         kv_quant=True))
    assert sorted(res_q["outputs"]) == sorted(res_c["outputs"])
    for rid, toks in res_q["outputs"].items():
        assert len(toks) == len(res_c["outputs"][rid])
        assert all(0 <= t < cfg.vocab for t in toks)
    exact = sum(res_q["outputs"][r] == res_c["outputs"][r]
                for r in res_c["outputs"])
    assert exact >= len(res_c["outputs"]) // 2


def test_paged_engine_kernel_path_matches_jnp(smoke_model):
    before = LAUNCHES["paged_decode"]
    res_k = _run(_engine(smoke_model, paged=True, page_size=4,
                         use_pallas=True))
    assert LAUNCHES["paged_decode"] > before
    res_j = _run(_engine(smoke_model, paged=True, page_size=4))
    assert res_k["outputs"] == res_j["outputs"]


def test_no_per_step_recompilation(smoke_model):
    # satellite fix: slot tokens/positions live in device buffers the
    # step advances — one compilation for the whole mixed-length run
    for kw in ({}, {"paged": True, "page_size": 4}):
        eng = _engine(smoke_model, **kw)
        res = _run(eng)
        assert res["steps"] > 5
        assert eng._step_jit._cache_size() == 1, kw


def test_paged_pool_drains_after_run(smoke_model):
    eng = _engine(smoke_model, paged=True, page_size=4)
    res = _run(eng)
    assert sorted(res["outputs"]) == list(range(len(REQS)))
    assert eng.pool_alloc.used_pages == 0
    assert eng.pool_alloc.free_pages == eng.n_pages
    assert (eng._tables_np == -1).all()
    assert res["pool"]["pages_used"] == 0
    assert res["pool"]["peak_pages_used"] > 0


def test_block_tables_cover_exactly_true_len(smoke_model):
    # after every step, an active slot owns exactly
    # ceil(tokens_written / page_size) pages and its table rows match
    eng = _engine(smoke_model, paged=True, page_size=4)
    for p, n in REQS:
        eng.submit(p, n)
    while eng.pending:
        eng.step()
        for i, s in enumerate(eng._slots):
            if s.free:
                assert (eng._tables_np[i] == -1).all()
                continue
            written = s.pos            # post-step: positions [0, pos)
            want = max(1, -(-written // eng.page_size))
            assert len(s.pages) == want
            assert list(eng._tables_np[i, :want]) == s.pages
            assert (eng._tables_np[i, want:] == -1).all()


def test_preemption_recomputes_identically(smoke_model):
    reqs = [([5, 6, 7], 12), ([1, 2, 3, 4], 12), ([9, 8], 12)]
    tiny = _engine(smoke_model, paged=True, page_size=4, kv_pool_pages=6)
    res_t = _run(tiny, reqs)
    assert tiny.preemptions > 0
    assert tiny.pool_alloc.used_pages == 0
    assert res_t["pool"]["preemptions"] == tiny.preemptions
    # the module-level gauge tracks the engine that refreshed it last
    assert PAGE_POOL["preemptions"] == tiny.preemptions
    ample = _engine(smoke_model, paged=True, page_size=4)
    res_a = _run(ample, reqs)
    assert ample.preemptions == 0
    # recompute-from-start: evicted requests regenerate the same tokens
    assert res_t["outputs"] == res_a["outputs"]


def test_admission_stalls_counted(smoke_model):
    # a 3-page pool: the running request holds 2-3 pages the whole
    # time, so the queued second prompt (2 pages) stalls every step
    # despite the free slot, then admits and completes once the first
    # request finishes and drains its pages
    cfg, params = smoke_model
    eng = ServeEngine(params, cfg, max_batch=2, max_len=12, prompt_pad=6,
                      paged=True, page_size=4, kv_pool_pages=3)
    reqs = [([1, 2, 3, 4, 5, 6], 6), ([7, 8, 9, 10, 11, 12], 6)]
    res = _run(eng, reqs)
    assert sorted(res["outputs"]) == [0, 1]
    assert eng.preemptions == 0        # stall, not eviction
    assert eng.admission_stalls > 0
    assert res["pool"]["admission_stalls"] == eng.admission_stalls


def test_paged_engine_rejects_bad_config(smoke_model):
    cfg, params = smoke_model
    with pytest.raises(ValueError, match="kv_quant requires paged"):
        ServeEngine(params, cfg, kv_quant=True)
    with pytest.raises(ValueError, match="cannot hold one"):
        ServeEngine(params, cfg, max_len=64, page_size=4, paged=True,
                    kv_pool_pages=2)
    bad = dataclasses.replace(cfg, scan_layers=False)
    with pytest.raises(ValueError, match="paged KV serving requires"):
        ServeEngine(params, bad, paged=True)


def test_paged_admission_is_token_budget_not_slots(smoke_model):
    # 8 slots x max_len 20 would need 40 pages contiguously; a 10-page
    # pool still admits as many *short* requests as fit by tokens
    cfg, params = smoke_model
    eng = ServeEngine(params, cfg, max_batch=8, max_len=20, prompt_pad=6,
                      paged=True, page_size=4, kv_pool_pages=10)
    for _ in range(8):
        eng.submit([1, 2, 3], 2)       # 1 page each at admit
    eng.step()
    assert eng.occupancy[-1] == 8      # all 8 admitted on 10 pages
    while eng.pending:
        eng.step()
    assert len(eng._outputs) == 8

"""Property tests for the compression operators (paper Section 2).

The load-bearing invariant is Definition 3:
    E_C ||x - C(x)||^2 <= (1 - gamma) ||x||^2
with the gamma values proved in Lemmas 1-3 and Corollary 1.
"""

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from repro.core import bits as bitlib
from repro.core import operators as ops

ATOL = 1e-4


def vec_strategy(max_d=400):
    return st.integers(1, 10_000).map(
        lambda seed: jax.random.normal(
            jax.random.PRNGKey(seed),
            (int(jax.random.randint(jax.random.PRNGKey(seed + 1), (), 8,
                                    max_d)),),
        )
    )


def check_def3(op, x, trials=12, slack=1.02):
    d = int(x.size)
    errs = []
    for i in range(trials):
        out, _ = op(jax.random.PRNGKey(i), x)
        errs.append(float(jnp.sum((x - out.astype(x.dtype)) ** 2)))
    lhs = np.mean(errs)
    rhs = (1.0 - op.gamma(d)) * float(jnp.sum(x ** 2))
    assert lhs <= rhs * slack + ATOL, (lhs, rhs, type(op).__name__)


@settings(max_examples=15, deadline=None)
@given(seed=st.integers(0, 10_000), kfrac=st.floats(0.01, 0.9))
def test_topk_def3(seed, kfrac):
    x = jax.random.normal(jax.random.PRNGKey(seed), (200,))
    check_def3(ops.TopK(k=kfrac), x, trials=1)


@settings(max_examples=15, deadline=None)
@given(seed=st.integers(0, 10_000), kfrac=st.floats(0.05, 0.9))
def test_randk_def3(seed, kfrac):
    x = jax.random.normal(jax.random.PRNGKey(seed), (150,))
    check_def3(ops.RandK(k=kfrac), x, trials=30, slack=1.25)


@settings(max_examples=10, deadline=None)
@given(seed=st.integers(0, 10_000), s=st.integers(16, 128))
def test_qsgd_def3_and_unbiased(seed, s):
    x = jax.random.normal(jax.random.PRNGKey(seed), (100,))
    op = ops.QSGDQuantizer(s=s)
    check_def3(op, x, trials=30, slack=1.3)
    outs = [op(jax.random.PRNGKey(i), x)[0] for i in range(200)]
    mean = jnp.mean(jnp.stack(outs), 0)
    # Definition 1(i): unbiasedness
    np.testing.assert_allclose(np.asarray(mean), np.asarray(x),
                               atol=4 * float(jnp.max(jnp.abs(x))) / np.sqrt(200))


@settings(max_examples=10, deadline=None)
@given(seed=st.integers(0, 10_000))
def test_qsgd_second_moment(seed):
    """Definition 1(ii): E||Q(x)||^2 <= (1 + beta)||x||^2."""
    x = jax.random.normal(jax.random.PRNGKey(seed), (64,))
    s = 8
    op = ops.QSGDQuantizer(s=s)
    sq = [float(jnp.sum(op(jax.random.PRNGKey(i), x)[0] ** 2))
          for i in range(100)]
    beta = op.beta(64)
    assert np.mean(sq) <= (1 + beta) * float(jnp.sum(x ** 2)) * 1.1


@settings(max_examples=10, deadline=None)
@given(seed=st.integers(0, 10_000), k=st.integers(4, 64),
       scaled=st.booleans())
def test_qtopk_composition_lemma(seed, k, scaled):
    """Lemma 1 (unscaled, beta < 1 regime) / Lemma 2 (scaled, always)."""
    x = jax.random.normal(jax.random.PRNGKey(seed), (128,))
    s = 32  # beta_{k,s} = k/s^2 < 1 for k <= 64
    op = ops.QuantizedSparsifier(k=k, s=s, scaled=scaled)
    check_def3(op, x, trials=25, slack=1.2)


@settings(max_examples=10, deadline=None)
@given(seed=st.integers(0, 10_000), k=st.integers(1, 100),
       m=st.sampled_from([1, 2]))
def test_signtopk_lemma3(seed, k, m):
    x = jax.random.normal(jax.random.PRNGKey(seed), (128,))
    op = ops.SignSparsifier(k=k, m=m)
    check_def3(op, x, trials=1)


def test_sign_def3():
    x = jax.random.normal(jax.random.PRNGKey(0), (256,))
    check_def3(ops.Sign(), x, trials=1)


def test_scaled_better_gamma_when_beta_lt_1():
    """Remark 2: gamma_scaled > gamma_unscaled whenever beta < 1."""
    d = 1000
    for k in (10, 100, 500):
        u = ops.QuantizedSparsifier(k=k, s=40, scaled=False)
        s = ops.QuantizedSparsifier(k=k, s=40, scaled=True)
        assert u.beta(d) < 1
        assert s.gamma(d) > u.gamma(d)


def test_piecewise_corollary1():
    """Corollary 1: leafwise composition has gamma = min_i gamma_i."""
    tree = {
        "a": jax.random.normal(jax.random.PRNGKey(0), (64,)),
        "b": jax.random.normal(jax.random.PRNGKey(1), (32, 8)),
    }
    op_tree = {"a": ops.TopK(k=16), "b": ops.TopK(k=0.5)}
    g = ops.tree_gamma(op_tree, tree)
    assert abs(g - min(16 / 64, 0.5)) < 1e-9
    out, total_bits = ops.compress_tree(op_tree, jax.random.PRNGKey(2), tree)
    err = sum(float(jnp.sum((x - y) ** 2))
              for x, y in zip(jax.tree_util.tree_leaves(tree),
                              jax.tree_util.tree_leaves(out)))
    norm = sum(float(jnp.sum(x ** 2)) for x in jax.tree_util.tree_leaves(tree))
    assert err <= (1 - g) * norm * 1.01
    assert float(total_bits) > 0


def test_row_ops_match_gamma():
    x = jax.random.normal(jax.random.PRNGKey(3), (1000,))
    for op in (ops.RowTopK(k=0.1, row_len=100),
               ops.RowSignTopK(k=0.1, row_len=100, m=2)):
        check_def3(op, x, trials=1)


def test_randk_threshold_selection_parity():
    """The keyed threshold Rand_k (PR 8 — replaces the O(d log d)
    per-call permutation): exact-k support, values pass through
    untouched, and the wire-bit accounting is unchanged (seeded
    indices: 64 + 32k bits)."""
    for d, kfrac in ((64, 0.25), (331, 0.1), (1024, 0.03)):
        op = ops.RandK(k=kfrac)
        k = ops.resolve_k(kfrac, d)
        x = jax.random.normal(jax.random.PRNGKey(0), (d,))
        out, bits = op(jax.random.PRNGKey(1), x)
        assert int(jnp.sum(out != 0)) == k, (d, kfrac)
        assert float(bits) == bitlib.bits_randk(d, k)
        sel = np.nonzero(np.asarray(out))[0]
        np.testing.assert_array_equal(np.asarray(out)[sel],
                                      np.asarray(x)[sel])
    # the subset is keyed: deterministic per key, distinct across keys,
    # always exactly k distinct coordinates
    a = ops._rand_subset(jax.random.PRNGKey(0), 100, 10)
    b = ops._rand_subset(jax.random.PRNGKey(0), 100, 10)
    c = ops._rand_subset(jax.random.PRNGKey(1), 100, 10)
    np.testing.assert_array_equal(np.asarray(a), np.asarray(b))
    assert len(set(np.asarray(a).tolist())) == 10
    assert not np.array_equal(np.sort(np.asarray(a)), np.sort(np.asarray(c)))
    # k >= d keeps every coordinate
    np.testing.assert_array_equal(
        np.asarray(ops._rand_subset(jax.random.PRNGKey(0), 5, 7)),
        np.arange(5))
    # coverage: over many keys every coordinate gets selected
    hits = np.zeros(40)
    for i in range(60):
        hits[np.asarray(ops._rand_subset(jax.random.PRNGKey(i), 40, 8))] += 1
    assert (hits > 0).all()


def test_bits_accounting_exact():
    d, k = 1024, 32
    assert bitlib.bits_dense(d) == d * 32
    assert bitlib.bits_topk(d, k) == 32 + k * (10 + 32)
    assert bitlib.bits_signtopk(d, k) == 32 + k * 11
    assert bitlib.bits_randk(d, k) == 64 + 32 * k
    # composed operator beats TopK beats dense
    assert (bitlib.bits_signtopk(d, k) < bitlib.bits_topk(d, k)
            < bitlib.bits_dense(d))


def test_operator_registry():
    for name in ops.OPERATORS:
        op = ops.make_operator(name)
        x = jax.random.normal(jax.random.PRNGKey(0), (64,))
        out, bits = op(jax.random.PRNGKey(1), x)
        assert out.shape == x.shape
        assert np.isfinite(float(bits))
    with pytest.raises(KeyError):
        ops.make_operator("nope")

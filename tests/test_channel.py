"""Channel model tests (DESIGN.md §5): error-compensated downlink
alongside the uplink, per-direction ledgers, exact backward compat.

Pins the acceptance contract of the channelization refactor:
 * ``downlink=None`` and ``downlink=Identity`` reproduce identical
   trajectories and an identical uplink ledger (the exact-broadcast
   fast path), while the new downlink ledger charges the dense
   broadcast cost the old uplink-only ledger omitted;
 * a compressed downlink converges to the same neighborhood, its
   ledger uses the counted-survivor forms, and non-syncing workers
   keep their view/server-memory untouched (Algorithm-2 semantics).
"""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import (async_qsparse, bits as bitlib, channel as chn,
                        engine, operators as ops, qsparse, schedule)
from repro.kernels import dispatch as dsp
from repro.optim import constant, inverse_time, sgd

R, D = 4, 50


@pytest.fixture(scope="module")
def problem():
    cs = jax.random.normal(jax.random.PRNGKey(1), (R, D))

    def grad_fn(params, data):
        c, noise = data
        g = params["w"] - c + 0.01 * noise
        return 0.5 * jnp.sum((params["w"] - c) ** 2), {"w": g}

    def batches(T, seed=2):
        k = jax.random.PRNGKey(seed)
        out = []
        for _ in range(T):
            k, s = jax.random.split(k)
            out.append((cs, jax.random.normal(s, (R, D))))
        return out

    return cs, grad_fn, batches


def run_sync(grad_fn, batches, op, T, H, lr, downlink=None, seed=3):
    params = {"w": jnp.zeros(D)}
    inner = sgd()
    state = qsparse.init(params, inner, R, downlink=downlink)
    step = qsparse.make_step(grad_fn, inner, op, lr, R, downlink=downlink)
    mask = schedule.fixed_schedule(T, H)
    state, losses = qsparse.run(state, step, batches, mask,
                                jax.random.PRNGKey(seed))
    return state, losses


# ---------------------------------------------------------------------------
# channel algebra
# ---------------------------------------------------------------------------


def test_channel_apply_error_feedback_identity():
    """q + memory' == acc exactly, on both dispatch routes."""
    tree = {"a": jax.random.normal(jax.random.PRNGKey(0), (64, 256)),
            "b": jax.random.normal(jax.random.PRNGKey(1), (37,))}
    for mode in ("reference", "kernel"):
        ch = chn.Channel(ops.TopK(k=0.1), "downlink",
                         dsp.DispatchConfig(mode=mode))
        q, mem, bits = ch.apply(jax.random.PRNGKey(2), tree)
        for k in tree:
            np.testing.assert_array_equal(
                np.asarray(q[k] + mem[k]), np.asarray(tree[k]))
        assert float(bits) > 0


def test_channel_compress_tree_matches_compress_tree():
    """The channel entry is the same compression as compress_tree —
    same outputs, same counted bits — plus the memory."""
    tree = {"a": jax.random.normal(jax.random.PRNGKey(0), (64, 256))}
    key = jax.random.PRNGKey(2)
    for mode in ("reference", "kernel"):
        cfg = dsp.DispatchConfig(mode=mode)
        op = ops.TopK(k=0.05)
        q0, b0 = dsp.compress_tree(op, key, tree, cfg)
        q1, mem, b1 = dsp.channel_compress_tree(op, key, tree, cfg)
        np.testing.assert_array_equal(np.asarray(q0["a"]),
                                      np.asarray(q1["a"]))
        np.testing.assert_allclose(float(b0), float(b1))
        np.testing.assert_array_equal(
            np.asarray(q1["a"] + mem["a"]), np.asarray(tree["a"]))


def test_channel_identity_detection():
    assert chn.as_channel(None, "downlink").is_identity()
    assert chn.as_channel(ops.Identity(), "downlink").is_identity()
    assert chn.as_channel({"w": ops.Identity(), "b": ops.Identity()},
                          "downlink").is_identity()
    assert not chn.as_channel(ops.TopK(k=2), "downlink").is_identity()
    assert not chn.as_channel({"w": ops.Identity(), "b": ops.TopK(k=2)},
                              "downlink").is_identity()


# ---------------------------------------------------------------------------
# exact backward compat (acceptance: bit-identical with Identity)
# ---------------------------------------------------------------------------


def test_identity_downlink_bit_identical(problem):
    """downlink=None and downlink=Identity: identical trajectories,
    identical uplink ledger; the downlink ledger charges exactly the
    dense broadcast cost per syncing worker."""
    cs, grad_fn, batches = problem
    T, H = 24, 4
    bs = batches(T)
    op = ops.TopK(k=10)
    s0, l0 = run_sync(grad_fn, bs, op, T, H, constant(0.05), downlink=None)
    s1, l1 = run_sync(grad_fn, bs, op, T, H, constant(0.05),
                      downlink=ops.Identity())
    np.testing.assert_array_equal(np.asarray(s0.master["w"]),
                                  np.asarray(s1.master["w"]))
    np.testing.assert_array_equal(np.asarray(s0.local["w"]),
                                  np.asarray(s1.local["w"]))
    np.testing.assert_array_equal(np.asarray(s0.memory["w"]),
                                  np.asarray(s1.memory["w"]))
    assert float(s0.bits) == float(s1.bits)
    assert l0 == l1
    rounds = int(s0.rounds)
    expected_down = rounds * R * bitlib.bits_dense(D)
    assert float(s0.bits_down) == expected_down
    assert float(s1.bits_down) == expected_down
    # the combined ledger is up + down
    led = chn.wire_ledger(s0)
    np.testing.assert_allclose(float(led.total),
                               float(s0.bits) + expected_down)


def test_identity_downlink_views_equal_master(problem):
    """Exact broadcast: at a sync step every synced view IS the master
    (no float drift — the assignment path, not view + (x̄ − view))."""
    cs, grad_fn, batches = problem
    T, H = 8, 4
    state, _ = run_sync(grad_fn, batches(T), ops.TopK(k=10), T, H,
                        constant(0.05), downlink=ops.Identity())
    np.testing.assert_array_equal(np.asarray(state.local["w"][0]),
                                  np.asarray(state.master["w"]))


# ---------------------------------------------------------------------------
# compressed downlink
# ---------------------------------------------------------------------------


def test_compressed_downlink_ledger_counted(problem):
    """Downlink Top_k charges the counted-survivor wire cost per
    syncing worker per round (exact-k on tie-free data)."""
    cs, grad_fn, batches = problem
    T, H, kd = 24, 4, 20
    state, _ = run_sync(grad_fn, batches(T), ops.TopK(k=10), T, H,
                        constant(0.05), downlink=ops.TopK(k=kd))
    rounds = int(state.rounds)
    np.testing.assert_allclose(
        float(state.bits_down), rounds * R * bitlib.bits_topk(D, kd))
    # uplink ledger is untouched by the downlink choice
    s0, _ = run_sync(grad_fn, batches(T), ops.TopK(k=10), T, H,
                     constant(0.05))
    assert float(state.bits) == float(s0.bits)


def test_compressed_downlink_error_feedback_state(problem):
    """Views lag the master (compression is lossy) but the server-side
    memory absorbs exactly the undelivered part: after every sync,
    view' + md' == x̄' + md (the channel's error-feedback identity
    q + md' == md + (x̄' − view) rearranged)."""
    cs, grad_fn, batches = problem
    T, H = 24, 4
    bs = batches(T)
    dl = ops.TopK(k=15)
    params = {"w": jnp.zeros(D)}
    inner = sgd()
    state = qsparse.init(params, inner, R, downlink=dl)
    step = jax.jit(
        qsparse.make_step(grad_fn, inner, ops.TopK(k=10), constant(0.05),
                          R, downlink=dl),
        static_argnames=("sync",))
    mask = schedule.fixed_schedule(T, H)
    key = jax.random.PRNGKey(3)
    for t in range(T):
        key, sub = jax.random.split(key)
        prev_md = np.asarray(state.down_memory["w"])
        state, _ = step(state, bs[t], sync=bool(mask[t]), key=sub)
        if mask[t]:
            views = np.asarray(state.master_view["w"])
            md = np.asarray(state.down_memory["w"])
            master = np.asarray(state.master["w"])
            np.testing.assert_allclose(views + md, master[None] + prev_md,
                                       rtol=1e-5, atol=1e-6)
    # and the compression is genuinely lossy: views lag the master
    assert np.max(np.abs(np.asarray(state.master_view["w"])
                         - np.asarray(state.master["w"])[None])) > 0


def test_compressed_downlink_converges(problem):
    """Bidirectional compression converges to the same neighborhood.

    Note the downlink has its own stability condition (double
    compression, cf. Double Squeeze / DORE): the view lag feeds the
    uplink through the local restarts, so aggressive downlink
    compression needs a commensurately small effective step
    (~eta*H*(1-gamma_d)/gamma_d < 1).  gamma_d = 0.5 here keeps the
    paper's LR schedule stable."""
    cs, grad_fn, batches = problem
    opt_pt = jnp.mean(cs, 0)
    T, H = 1200, 4
    lr = inverse_time(30.0, 200.0)
    state, _ = run_sync(grad_fn, batches(T), ops.TopK(k=10), T, H, lr,
                        downlink=ops.TopK(k=25))
    err = float(jnp.linalg.norm(state.master["w"] - opt_pt))
    assert err < 0.6, err


def test_async_downlink_nonsync_workers_keep_channel_state(problem):
    cs, grad_fn, batches = problem
    dl = ops.TopK(k=8)
    params = {"w": jnp.zeros(D)}
    inner = sgd()
    state = async_qsparse.init(params, inner, R, downlink=dl)
    step = jax.jit(async_qsparse.make_step(
        grad_fn, inner, ops.TopK(k=8), constant(0.05), R, downlink=dl))
    b = batches(1)[0]
    flags = jnp.array([True] + [False] * (R - 1))
    state, _ = step(state, b, flags, jax.random.PRNGKey(0))
    # worker 0 synced: its view moved and its server memory may be
    # nonzero; the others' channel state is untouched
    assert float(jnp.sum(jnp.abs(state.master_view["w"][0]))) > 0.0
    np.testing.assert_array_equal(np.asarray(state.master_view["w"][1]),
                                  np.zeros(D))
    np.testing.assert_array_equal(np.asarray(state.down_memory["w"][1]),
                                  np.zeros(D))
    # downlink ledger charged for exactly one worker
    np.testing.assert_allclose(float(state.bits_down),
                               bitlib.bits_topk(D, 8))


def test_engine_requires_down_memory():
    """Stepping a compressed downlink over a state initialized without
    one fails loudly at trace time."""
    params = {"w": jnp.zeros(D)}
    inner = sgd()

    def grad_fn(p, data):
        return 0.5 * jnp.sum(p["w"] ** 2), {"w": p["w"]}

    state = engine.init(params, inner, R)  # no downlink memory
    step = engine.make_step(grad_fn, inner, ops.TopK(k=5), constant(0.1),
                            R, downlink=ops.TopK(k=5))
    with pytest.raises(ValueError, match="down"):
        step(state, {"w": jnp.zeros((R, D))}, jnp.ones((R,), bool),
             jax.random.PRNGKey(0))
    # ... and the converse: a downlink-initialized state stepped by a
    # downlink-less step must not silently fall back to exact broadcast
    state_dl = engine.init(params, inner, R, downlink=ops.TopK(k=5))
    step_plain = engine.make_step(grad_fn, inner, ops.TopK(k=5),
                                  constant(0.1), R)
    with pytest.raises(ValueError, match="without downlink"):
        step_plain(state_dl, {"w": jnp.zeros((R, D))},
                   jnp.ones((R,), bool), jax.random.PRNGKey(0))

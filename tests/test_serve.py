"""Compressed-weight serving engine tests (DESIGN.md §11).

Covers the four contract layers: (1) the serving GEMM kernels against
the densify-then-matmul oracle (per dtype, per operator family); (2)
flash decode against the jnp decode-attention path; (3) compact
checkpoint round-trips (buffers, structure, zero-densify load); (4)
scheduler invariants of the continuous-batching engine (FIFO no
starvation, slot conservation under mixed prefill/decode, static vs
continuous admission).
"""

import dataclasses
import os

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import yi_6b
from repro.kernels import ops, ref
from repro.kernels.dispatch import DispatchConfig, capacity, decode_rows
from repro.models import transformer as tfm
from repro.serve import compressed as sc
from repro.serve.engine import ServeEngine
from repro.train import checkpoint as ckpt


def _compact_rows(rng, R, n, kcap):
    idx = np.full((R, kcap), n, np.int32)
    val = np.zeros((R, kcap), np.float32)
    for r in range(R):
        kk = rng.randint(1, kcap + 1)
        cols = np.sort(rng.choice(n, kk, replace=False))
        idx[r, :kk] = cols
        val[r, :kk] = rng.randn(kk)
    return jnp.asarray(idx), jnp.asarray(val)


# ---------------------------------------------------------------------------
# serving GEMMs vs densify-then-matmul
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("M,R,n,kcap", [
    (4, 256, 688, 16), (1, 8, 256, 8), (17, 100, 300, 12), (2, 33, 129, 5),
])
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_sparse_gemm_matches_densify_matmul(M, R, n, kcap, dtype):
    rng = np.random.RandomState(M * R)
    x = jnp.asarray(rng.randn(M, n).astype(np.float32)).astype(dtype)
    idx, val = _compact_rows(rng, R, n, kcap)
    y = ops.sparse_gemm(x, idx, val, n)
    # oracle: decode to dense then matmul
    w = decode_rows(idx, val, n)
    want = x.astype(jnp.float32) @ w.T
    np.testing.assert_allclose(np.asarray(y), np.asarray(want),
                               rtol=1e-4, atol=1e-4)
    np.testing.assert_allclose(np.asarray(ref.sparse_gemm_ref(x, idx, val, n)),
                               np.asarray(want), rtol=1e-5, atol=1e-5)


@pytest.mark.parametrize("M,R,n", [(4, 256, 688), (1, 8, 128), (9, 33, 300)])
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_qdq_gemm_matches_dequant_matmul(M, R, n, dtype):
    rng = np.random.RandomState(M + R + n)
    x = jnp.asarray(rng.randn(M, n).astype(np.float32)).astype(dtype)
    lv = jnp.asarray(rng.randint(-15, 16, (R, n)).astype(np.int8))
    scl = jnp.asarray(rng.rand(R, 1).astype(np.float32))
    y = ops.qdq_gemm(x, lv, scl)
    w = lv.astype(jnp.float32) * scl
    want = x.astype(jnp.float32) @ w.T
    np.testing.assert_allclose(np.asarray(y), np.asarray(want),
                               rtol=1e-4, atol=1e-4)


def test_dispatch_kernel_and_reference_agree():
    rng = np.random.RandomState(3)
    from repro.kernels import dispatch as dsp
    x = jnp.asarray(rng.randn(5, 384).astype(np.float32))
    idx, val = _compact_rows(rng, 64, 384, 24)
    ker = dsp.sparse_gemm(x, idx, val, 384,
                          DispatchConfig(mode="kernel", interpret=True))
    rf = dsp.sparse_gemm(x, idx, val, 384, DispatchConfig(mode="reference"))
    np.testing.assert_allclose(np.asarray(ker), np.asarray(rf),
                               rtol=1e-4, atol=1e-4)
    lv = jnp.asarray(rng.randint(-7, 8, (64, 384)).astype(np.int8))
    scl = jnp.asarray(rng.rand(64, 1).astype(np.float32))
    ker = dsp.qdq_gemm(x, lv, scl,
                       DispatchConfig(mode="kernel", interpret=True))
    rf = dsp.qdq_gemm(x, lv, scl, DispatchConfig(mode="reference"))
    np.testing.assert_allclose(np.asarray(ker), np.asarray(rf),
                               rtol=1e-4, atol=1e-4)


# ---------------------------------------------------------------------------
# flash decode vs the jnp decode-attention path
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("KV", [1, 4])
def test_flash_decode_matches_ref(KV):
    rng = np.random.RandomState(KV)
    B, H, hd, C = 2, 8, 32, 24
    q = jnp.asarray(rng.randn(B, 1, H, hd).astype(np.float32))
    k = jnp.asarray(rng.randn(B, C, KV, hd).astype(np.float32))
    v = jnp.asarray(rng.randn(B, C, KV, hd).astype(np.float32))
    valid = jnp.asarray(rng.rand(C) > 0.4).at[0].set(True)
    y = ops.flash_decode(q, k, v, valid)
    np.testing.assert_allclose(np.asarray(y),
                               np.asarray(ref.flash_decode_ref(q, k, v,
                                                               valid)),
                               rtol=1e-5, atol=1e-5)


def test_decode_attention_flash_parity_in_model():
    """cfg.use_pallas routes model decode through the flash kernel; the
    logits must match the jnp path."""
    cfg = yi_6b.smoke()
    params = tfm.init_params(jax.random.PRNGKey(0), cfg)
    toks = jnp.asarray(np.random.RandomState(0).randint(
        0, cfg.vocab, (2, 6)))
    logits, cache, S = tfm.prefill(params, {"tokens": toks}, cfg,
                                   max_len=16)
    tok = jnp.argmax(logits[:, -1], -1).astype(jnp.int32)
    from repro.kernels.launch_stats import LAUNCHES
    before = LAUNCHES["flash_decode"]
    lg_jnp, _ = tfm.decode_step(params, cache, tok, S, cfg)
    assert LAUNCHES["flash_decode"] == before
    cfgp = dataclasses.replace(cfg, use_pallas=True)
    lg_fl, _ = tfm.decode_step(params, cache, tok, S, cfgp)
    assert LAUNCHES["flash_decode"] > before   # kernel actually dispatched
    np.testing.assert_allclose(np.asarray(lg_fl), np.asarray(lg_jnp),
                               rtol=1e-4, atol=1e-4)


# ---------------------------------------------------------------------------
# policy-guided compression + compact checkpoints
# ---------------------------------------------------------------------------


def _smoke_compressed(policy="ln|norm->identity;embed|head->qsgd:s=15;"
                             ".*->topk:k=0.05"):
    cfg = yi_6b.smoke()
    params = tfm.init_params(jax.random.PRNGKey(1), cfg)
    return cfg, params, sc.compress_tree(params, policy)


def test_compress_tree_schemes_and_shapes():
    cfg, params, comp = _smoke_compressed()
    assert comp["embed"].kind == "quant" and comp["embed"].out_axis == 0
    assert comp["head"].kind == "quant" and comp["head"].out_axis == 1
    w1 = comp["layers"]["mlp"]["w1"]
    assert w1.kind == "sparse" and w1.a.ndim == 3   # scan-stacked
    # stacked [L, d] norm gains must never be treated as matrices,
    # whatever the policy says
    assert not isinstance(comp["layers"]["ln1"], sc.CompressedTensor)
    assert not isinstance(comp["final_norm"], sc.CompressedTensor)
    # capacity honors the survivor fraction: k = 5% of d_model, lane
    # aligned
    k_row = max(1, round(0.05 * cfg.d_model))
    assert w1.a.shape[-1] == capacity(k_row, cfg.d_model)
    # densify restores the original geometry
    assert w1.densify().shape == params["layers"]["mlp"]["w1"].shape


def test_compressed_matmul_matches_densify_matmul():
    _, params, comp = _smoke_compressed()
    w1 = comp["layers"]["mlp"]["w1"]
    one = jax.tree_util.tree_map(lambda x: x[0], w1)
    x = jnp.asarray(np.random.RandomState(2).randn(3, 256)
                    .astype(np.float32))
    got = one.matmul(x)
    dense_slice = np.asarray(w1.densify())[0]
    want = x @ dense_slice
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               rtol=1e-4, atol=1e-4)


def test_take_rows_matches_densify_gather():
    _, params, comp = _smoke_compressed()
    emb = comp["embed"]
    toks = jnp.asarray([[1, 5, 9], [0, 2, 4]])
    got = emb.take_rows(toks)
    want = jnp.take(emb.densify(), toks, axis=0)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               rtol=1e-5, atol=1e-5)


def test_compact_checkpoint_roundtrip(tmp_path):
    cfg, params, comp = _smoke_compressed()
    path = os.path.join(str(tmp_path), "ck")
    ckpt.save_compact(path, comp, step=3, policy={"op": "topk", "k": 0.05})
    assert ckpt.is_compact(path)
    assert not ckpt.is_compact(str(tmp_path))
    sc.reset_stats()
    back = ckpt.load_compact(path)
    assert sc.STATS["densify"] == 0   # loading never densifies
    flat_a = jax.tree_util.tree_flatten_with_path(
        comp, is_leaf=lambda x: isinstance(x, sc.CompressedTensor))[0]
    flat_b = jax.tree_util.tree_flatten_with_path(
        back, is_leaf=lambda x: isinstance(x, sc.CompressedTensor))[0]
    assert len(flat_a) == len(flat_b)
    for (pa, la), (pb, lb) in zip(flat_a, flat_b):
        assert pa == pb
        if isinstance(la, sc.CompressedTensor):
            assert (la.kind, la.row_len, la.shape, la.out_axis) == \
                   (lb.kind, lb.row_len, lb.shape, lb.out_axis)
            assert jnp.array_equal(la.a, lb.a) and jnp.array_equal(la.b,
                                                                   lb.b)
        else:
            assert jnp.array_equal(la, lb)
    # bit-identical forward
    toks = jnp.asarray([[3, 1, 4, 1, 5]])
    np.testing.assert_array_equal(
        np.asarray(tfm.forward(comp, {"tokens": toks}, cfg)),
        np.asarray(tfm.forward(back, {"tokens": toks}, cfg)))


def test_dense_checkpoint_compress_at_load(tmp_path):
    """The launcher path: dense checkpoint + persisted policy spec →
    one-shot compression identical to compressing the live tree."""
    from repro.core import policy as pol
    cfg = yi_6b.smoke()
    params = tfm.init_params(jax.random.PRNGKey(4), cfg)
    spec = pol.parse("embed|head->qsgd:s=15;.*->topk:k=0.05")
    path = os.path.join(str(tmp_path), "dense_ck")
    ckpt.save(path, params, step=2, policy=spec.to_dict())
    like = tfm.init_params(jax.random.PRNGKey(5), cfg)
    restored = ckpt.restore(path, like)
    loaded_spec = ckpt.load_policy(path)
    comp_a = sc.compress_tree(restored, loaded_spec)
    comp_b = sc.compress_tree(params, spec)
    for la, lb in zip(
            jax.tree_util.tree_leaves(
                comp_a, is_leaf=lambda x: isinstance(x, sc.CompressedTensor)),
            jax.tree_util.tree_leaves(
                comp_b, is_leaf=lambda x: isinstance(x, sc.CompressedTensor))):
        if isinstance(la, sc.CompressedTensor):
            assert jnp.array_equal(la.a, lb.a)
            assert jnp.array_equal(la.b, lb.b)


# ---------------------------------------------------------------------------
# end-to-end zero-densify serving
# ---------------------------------------------------------------------------


def test_end_to_end_compressed_serving_zero_densify(tmp_path):
    cfg, params, comp = _smoke_compressed()
    path = os.path.join(str(tmp_path), "ck")
    ckpt.save_compact(path, comp)
    served = ckpt.load_compact(path)
    sc.reset_stats()
    eng = ServeEngine(served, cfg, max_batch=2, max_len=20, prompt_pad=6)
    rng = np.random.RandomState(0)
    for _ in range(3):
        eng.submit(rng.randint(0, cfg.vocab, 4).tolist(), max_new_tokens=3)
    res = eng.run()
    assert len(res["outputs"]) == 3
    for toks in res["outputs"].values():
        assert len(toks) == 3
        assert all(0 <= t < cfg.vocab for t in toks)
    assert sc.STATS["densify"] == 0
    assert sc.STATS["sparse_matmul"] > 0 and sc.STATS["take_rows"] > 0
    for m in res["metrics"].values():
        assert m.queue_wait_s >= 0 and m.ttft_s >= m.queue_wait_s
        assert m.tokens_per_s > 0


def test_compressed_decode_tracks_dense_decode():
    """Greedy decode from the compressed model should mostly agree with
    the dense model at 5% sparsity on the tiny config — and must stay
    finite/in-vocab everywhere."""
    cfg, params, comp = _smoke_compressed(
        policy="ln|norm->identity;.*->topk:k=0.97")   # near-lossless
    toks = jnp.asarray(np.random.RandomState(1).randint(
        0, cfg.vocab, (1, 8)))
    ld = tfm.forward(params, {"tokens": toks}, cfg)
    lc = tfm.forward(comp, {"tokens": toks}, cfg)
    assert bool(jnp.all(jnp.isfinite(lc)))
    # at 97% density the logits track the dense model closely
    a = np.asarray(ld).ravel() - float(jnp.mean(ld))
    b = np.asarray(lc).ravel() - float(jnp.mean(lc))
    corr = float(np.dot(a, b) / (np.linalg.norm(a) * np.linalg.norm(b)))
    assert corr > 0.95


# ---------------------------------------------------------------------------
# scheduler invariants
# ---------------------------------------------------------------------------


def _engine(scheduler, max_batch=2, **kw):
    cfg = yi_6b.smoke()
    params = tfm.init_params(jax.random.PRNGKey(0), cfg)
    return ServeEngine(params, cfg, max_batch=max_batch, max_len=20,
                       prompt_pad=6, scheduler=scheduler, **kw), cfg


def test_slot_conservation_and_no_starvation():
    eng, cfg = _engine("continuous", max_batch=2)
    rng = np.random.RandomState(0)
    rids = [eng.submit(rng.randint(0, cfg.vocab,
                                   int(rng.randint(2, 6))).tolist(),
                       max_new_tokens=int(rng.randint(2, 5)))
            for _ in range(7)]
    res = eng.run()
    # every request completes (no starvation), occupancy never exceeds
    # the slot count, and slots were actually reused across the run
    assert sorted(res["outputs"]) == sorted(rids)
    assert max(eng.occupancy) <= 2
    assert res["steps"] < sum(2 + 5 for _ in rids)   # batching happened
    # FIFO admission: request admission order follows rid order
    admits = sorted(res["metrics"].values(),
                    key=lambda m: m.queue_wait_s)
    # queue_wait is monotone in rid for same-time submissions
    assert [m.rid for m in admits] == sorted(m.rid for m in admits)


def test_continuous_interleaves_prefill_and_decode():
    """A slot freed mid-run is refilled while other slots keep
    decoding: occupancy recovers without draining to zero."""
    eng, cfg = _engine("continuous", max_batch=2)
    eng.submit([1, 2], max_new_tokens=2)    # finishes early
    eng.submit([3, 4, 5], max_new_tokens=8)
    eng.submit([5, 6], max_new_tokens=2)    # waits for the free slot
    res = eng.run()
    assert len(res["outputs"]) == 3
    occ = eng.occupancy
    assert occ[0] == 2
    # after the short request completes the queued one is admitted next
    # iteration while the long request is still decoding
    assert 2 in occ[2:]


def test_static_scheduler_drains_batches():
    eng, cfg = _engine("static", max_batch=2)
    for i in range(4):
        eng.submit([1 + i, 2 + i], max_new_tokens=3)
    res = eng.run()
    assert len(res["outputs"]) == 4
    # static admission: the second pair waits for a full drain, so
    # occupancy returns to a fresh batch boundary (2,2,2, 2,2,2)
    assert eng.occupancy == [2, 2, 2, 2, 2, 2]


def test_engine_rejects_bad_requests():
    eng, cfg = _engine("continuous")
    with pytest.raises(ValueError):
        eng.submit([])
    with pytest.raises(ValueError):
        eng.submit(list(range(99)))
    with pytest.raises(ValueError):
        ServeEngine(tfm.init_params(jax.random.PRNGKey(0), cfg), cfg,
                    max_batch=1, max_len=8, prompt_pad=8)
    with pytest.raises(ValueError):
        ServeEngine(tfm.init_params(jax.random.PRNGKey(0), cfg), cfg,
                    scheduler="mystery")

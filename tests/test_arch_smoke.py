"""Per-architecture smoke tests (deliverable f): a REDUCED variant of
each assigned architecture runs one forward/train step (and one serve
step for decoder archs) on CPU, asserting output shapes and no NaNs."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import ARCHS, get_config
from repro.models import get_model
from repro.optim import apply_updates, sgd


def make_batch(cfg, B=2, S=16, seed=0):
    ks = jax.random.split(jax.random.PRNGKey(seed), 2)
    batch = {"tokens": jax.random.randint(ks[0], (B, S + 1), 0, cfg.vocab)}
    if cfg.modality:
        batch["prefix_embeds"] = jax.random.normal(
            ks[1], (B, cfg.n_frontend_tokens, cfg.d_model)) * 0.02
    return batch


@pytest.mark.parametrize("arch", sorted(ARCHS))
def test_smoke_train_step(arch):
    cfg = get_config(arch, smoke=True)
    assert cfg.n_layers <= 4 and cfg.d_model <= 512
    if cfg.family == "moe":
        assert cfg.n_experts <= 4
    model = get_model(cfg)
    params = model.init_params(jax.random.PRNGKey(0), cfg)
    batch = make_batch(cfg)

    def loss(p):
        l, _ = model.loss_fn(p, batch, cfg)
        return l

    l0, grads = jax.value_and_grad(loss)(params)
    assert np.isfinite(float(l0)), arch
    # one SGD step decreases loss on the same batch (lr small)
    inner = sgd()
    upd, _ = inner.update(grads, inner.init(params), params,
                          jnp.float32(0.05))
    new_params = apply_updates(params, upd)
    l1 = float(loss(new_params))
    assert np.isfinite(l1)
    assert l1 < float(l0) + 1e-3, (arch, float(l0), l1)
    for leaf in jax.tree_util.tree_leaves(grads):
        assert np.isfinite(np.asarray(leaf)).all(), arch


@pytest.mark.parametrize("arch", sorted(ARCHS))
def test_smoke_serve_step(arch):
    cfg = get_config(arch, smoke=True)
    model = get_model(cfg)
    params = model.init_params(jax.random.PRNGKey(0), cfg)
    B, S = 2, 12
    batch = make_batch(cfg, B=B, S=S)
    prompt = {k: (v[:, :8] if k == "tokens" else v) for k, v in batch.items()}
    logits, cache, n = model.prefill(params, prompt, cfg, max_len=64)
    assert logits.shape[-1] == cfg.vocab
    assert np.isfinite(np.asarray(logits)).all(), arch
    tok = jnp.argmax(logits.reshape(B, -1)[:, :cfg.vocab], -1).astype(jnp.int32)
    pos = 8 + (cfg.n_frontend_tokens if cfg.modality else 0)
    lg, cache = model.decode_step(params, cache, tok, pos, cfg)
    assert lg.shape == (B, cfg.vocab)
    assert np.isfinite(np.asarray(lg)).all(), arch


def test_paper_models_smoke():
    """The paper's own experiment models (ResNet + convex softmax)."""
    from repro.models import resnet, softmax
    rcfg = resnet.resnet8_config()
    rp = resnet.init_params(jax.random.PRNGKey(0), rcfg)
    imgs = jax.random.normal(jax.random.PRNGKey(1), (4, 16, 16, 3))
    lbl = jnp.array([0, 1, 2, 3])
    loss, aux = resnet.loss_fn(rp, {"images": imgs, "labels": lbl}, rcfg)
    assert np.isfinite(float(loss))
    scfg = softmax.SoftmaxConfig()
    sp = softmax.init_params(jax.random.PRNGKey(0), scfg)
    assert sum(x.size for x in jax.tree_util.tree_leaves(sp)) == 7850
    feats = jax.random.normal(jax.random.PRNGKey(2), (8, 784))
    sl, _ = softmax.loss_fn(sp, {"features": feats,
                                 "labels": jnp.arange(8) % 10}, scfg)
    assert np.isfinite(float(sl))


def test_param_counts_match_published():
    expected = {
        "yi-6b": (6.0e9, 0.1),
        "stablelm-3b": (2.8e9, 0.15),
        "llama4-maverick-400b-a17b": (400e9, 0.05),
        "gemma3-1b": (1.0e9, 0.1),
        "rwkv6-3b": (2.7e9, 0.25),
        "musicgen-medium": (1.8e9, 0.3),
        "qwen3-moe-30b-a3b": (30.5e9, 0.05),
        "yi-34b": (34.4e9, 0.05),
        "zamba2-7b": (7.0e9, 0.15),
        "internvl2-26b": (20e9, 0.1),   # LLM backbone (ViT is stubbed)
    }
    for arch, (target, tol) in expected.items():
        n = get_config(arch).param_count()
        assert abs(n - target) / target < tol, (arch, n, target)


def test_moe_active_params():
    q = get_config("qwen3-moe-30b-a3b")
    assert 2.5e9 < q.active_param_count() < 4e9
    l4 = get_config("llama4-maverick-400b-a17b")
    assert 14e9 < l4.active_param_count() < 23e9

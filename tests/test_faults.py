"""Staleness-first fault runtime tests (DESIGN.md §9).

The acceptance pins for the fault runtime, in one suite:

* fault tables are deterministic in the dedicated fault seed and obey
  the spec invariants (delay windows, crash/recover structure);
* with trivial tables the fault runtime is **bit-for-bit** the
  fault-free runtime — enabling the queue machinery (or flipping the
  fault seed) never perturbs the jax data/model key stream (S1);
* a payload computed at t is executed at t+τ — differential test of
  the engine against a plain-numpy oracle that replays the documented
  semantics step by step;
* the compiled fault-round programs match the per-step loop exactly,
  fault-free and under chaos;
* crash → recover re-initializes from the master and zeroes the error
  memory; dead workers are frozen;
* an all-crashed round is a no-op sync: master untouched, zero bits,
  an empty History round (S2);
* the trainer surface: ``faults="preset:none"`` bit-exact, step/round
  runtime parity, crash-consistent resume restoring the in-flight
  queue exactly;
* both distributed transports execute the same faults (slow/subprocess
  twins live at the bottom).
"""

import os
import shutil

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings

from repro.core import engine, operators as ops, rounds as rnd, \
    scenarios as scn, schedule as sched
from repro.optim import constant, sgd
from tests.strategies import FAULT_GRID, fault_schedules, fault_specs

R, D, T, H = 4, 24, 20, 4


# ---------------------------------------------------------------------------
# fault tables: determinism + invariants
# ---------------------------------------------------------------------------


@settings(max_examples=30, deadline=None)
@given(spec=fault_specs())
def test_tables_deterministic_and_invariant(spec):
    t1 = spec.tables(T, R)
    t2 = spec.tables(T, R)
    for a, b in zip(t1, t2):
        np.testing.assert_array_equal(a, b)
    assert t1.delay.shape == (T, R) and t1.delay.dtype == np.int32
    assert (t1.delay >= spec.min_delay).all()
    assert (t1.delay <= spec.max_delay).all()
    assert t1.depth <= spec.depth
    # recover fires exactly on the first alive step after an outage
    assert not t1.recover[0].any()
    np.testing.assert_array_equal(
        t1.recover[1:], t1.alive[1:] & ~t1.alive[:-1])


@pytest.mark.parametrize("spec", FAULT_GRID)
def test_grid_tables_cover_crash_windows(spec):
    tables = spec.tables(T, R)
    for w, c, rec in spec.crash:
        if w < R:
            assert not tables.alive[min(c, T):min(rec, T), w].any()
    if spec == scn.FaultSpec():
        assert tables.trivial


def test_trivial_tables_ignore_seed():
    """The fault seed feeds only the fault PRNG: a no-fault spec yields
    identical (trivial) tables whatever the seed (S1)."""
    for seed in (0, 1, 123):
        t = scn.FaultSpec(seed=seed).tables(T, R)
        assert t.trivial
        np.testing.assert_array_equal(t.delay,
                                      np.zeros((T, R), np.int32))


def test_parse_roundtrip_and_presets():
    for spec in FAULT_GRID:
        assert scn.parse_faults(spec.to_string()) == spec
    for name in scn.FAULT_PRESETS:
        assert scn.parse_faults(f"preset:{name}") == scn.FAULT_PRESETS[name]
    with pytest.raises(KeyError):
        scn.parse_faults("preset:nope")
    with pytest.raises(KeyError):
        scn.parse_faults("bogus_knob=1")
    with pytest.raises(ValueError):
        scn.FaultSpec(min_delay=3, max_delay=1)
    with pytest.raises(ValueError):
        scn.FaultSpec(crash=((0, 5, 2),))


# ---------------------------------------------------------------------------
# host-side replay + round segmentation under faults
# ---------------------------------------------------------------------------


@settings(max_examples=25, deadline=None)
@given(case=fault_schedules())
def test_fault_replay_conserves_payloads(case):
    mask, tables = case
    Tt, Rr = mask.shape
    computed, arrivals, events = scn.fault_replay(mask, tables)
    np.testing.assert_array_equal(computed, mask & tables.alive)
    # every computed, undropped payload either lands within the window
    # or is still in flight past T-1 — none is duplicated or invented
    src = computed & ~tables.drop
    landed = sum(1 for t, r in zip(*np.nonzero(src))
                 if t + int(tables.delay[t, r]) < Tt)
    assert int(arrivals.sum()) == landed
    np.testing.assert_array_equal(
        events, mask.any(axis=1) | (arrivals > 0).any(axis=1))


@settings(max_examples=25, deadline=None)
@given(case=fault_schedules())
def test_fault_rounds_close_at_events(case):
    mask, tables = case
    _, _, events = scn.fault_replay(mask, tables)
    plans = rnd.compile_fault_rounds(mask, tables)
    pos = 0
    for p in plans:
        assert p.start == pos
        # heads are event-free; tails are events (or the trailing
        # partial round, which has no event at all)
        assert not events[p.start:p.stop - 1].any()
        pos = p.stop
    assert pos == mask.shape[0]
    np.testing.assert_array_equal(rnd.expand_rounds(plans), mask)
    if tables.trivial:
        base = rnd.compile_rounds(mask)
        assert [(p.start, p.length) for p in plans] == \
            [(p.start, p.length) for p in base]


def test_fault_rounds_extra_events_split():
    mask = sched.fixed_schedule(12, 4)
    tables = scn.FaultSpec().tables(12, 1)
    plans = rnd.compile_fault_rounds(mask, tables, extra_events=[1])
    assert plans[0].length == 2 and not plans[0].syncs
    np.testing.assert_array_equal(rnd.expand_rounds(plans), mask)


# ---------------------------------------------------------------------------
# engine: problem fixture
# ---------------------------------------------------------------------------


@pytest.fixture(scope="module")
def problem():
    rng = np.random.RandomState(0)
    A = jnp.asarray(rng.randn(64, D).astype(np.float32))
    y = jnp.asarray(rng.randn(64).astype(np.float32))

    def grad_fn(params, batch):
        Ab, yb = A[batch], y[batch]

        def loss_fn(w):
            r = Ab @ w - yb
            return 0.5 * jnp.mean(r * r)

        l, g = jax.value_and_grad(loss_fn)(params["w"])
        return l, {"w": g}

    params = {"w": jnp.zeros((D,), jnp.float32)}
    batches = [jnp.asarray(rng.randint(0, 64, size=(R, 8)))
               for _ in range(T)]
    mask = sched.async_schedule(T, R, H, seed=3)
    return grad_fn, params, batches, mask


def _run_faulty(problem, spec, op, *, rounds=False, **kw):
    grad_fn, params, batches, mask = problem
    tables = spec.tables(T, R)
    state = engine.init(params, sgd(), R, queue_depth=spec.depth)
    key = jax.random.PRNGKey(42)
    if rounds:
        sup = engine.make_fault_superstep(
            grad_fn, sgd(), op, constant(0.05), R,
            queue_depth=spec.depth, **kw)
        return engine.run_fault_rounds(state, sup, batches, mask, tables,
                                       key)
    step = engine.make_fault_step(
        grad_fn, sgd(), op, constant(0.05), R,
        queue_depth=spec.depth, **kw)
    return engine.run_faults(state, step, batches, mask, tables, key)


# ---------------------------------------------------------------------------
# S1: trivial tables are bit-for-bit the fault-free runtime
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("op", [
    ops.TopK(k=6),
    # randomized quantizer: pins that the fault machinery consumes the
    # exact same key-split sequence as the baseline step
    ops.QuantizedSparsifier(k=6, s=15),
], ids=["topk", "qtopk"])
def test_trivial_faults_bit_exact(problem, op):
    grad_fn, params, batches, mask = problem
    key = jax.random.PRNGKey(42)
    base_state = engine.init(params, sgd(), R)
    base_step = engine.make_step(grad_fn, sgd(), op, constant(0.05), R)
    base, base_losses = engine.run(base_state, base_step, batches, mask,
                                   key)
    # any fault seed: trivial tables are seed-independent
    faulty, fl = _run_faulty(problem, scn.FaultSpec(seed=7), op)
    for field in ("master", "local", "memory"):
        np.testing.assert_array_equal(
            np.asarray(getattr(base, field)["w"]),
            np.asarray(getattr(faulty, field)["w"]))
    np.testing.assert_array_equal(np.asarray(base.bits),
                                  np.asarray(faulty.bits))
    np.testing.assert_array_equal(np.asarray(base.rounds),
                                  np.asarray(faulty.rounds))
    np.testing.assert_array_equal(np.asarray(base_losses), np.asarray(fl))
    # τ ≡ 0: enqueue and apply collapse — the queue never holds state
    assert not np.asarray(faulty.inflight["w"]).any()
    assert (np.asarray(faulty.arrive_at) == -1).all()


# ---------------------------------------------------------------------------
# tentpole: executed delayed payloads vs a plain-numpy oracle
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("spec", FAULT_GRID,
                         ids=[f"spec{i}" for i in range(len(FAULT_GRID))])
def test_engine_matches_numpy_oracle(problem, spec):
    """Identity compression makes the error-feedback algebra exact
    (memory stays zero), so a hand-rolled numpy replay of the §9
    semantics — compute at t, enqueue, execute at t+τ, broadcast on
    arrival — must reproduce the engine's master trajectory."""
    grad_fn, params, batches, mask = problem
    tables = spec.tables(T, R)
    lr = np.float32(0.05)

    # ---- oracle ---------------------------------------------------
    Dq = spec.depth
    master = np.zeros(D, np.float32)
    local = np.zeros((R, D), np.float32)
    view = np.zeros((R, D), np.float32)
    q = np.zeros((R, Dq, D), np.float32)
    arrive = np.full((R, Dq), -1, np.int64)
    for t in range(T):
        for r in range(R):
            if tables.recover[t, r]:
                local[r] = master
                view[r] = master
        alive = tables.alive[t]
        half = local.copy()
        for r in range(R):
            if alive[r]:
                _, g = grad_fn({"w": jnp.asarray(local[r])},
                               np.asarray(batches[t][r]))
                half[r] = local[r] - lr * np.asarray(g["w"], np.float32)
        compute = mask[t] & alive
        if not (compute.any() or (arrive == t).any()):
            local = half
            continue
        slot = t % Dq
        for r in range(R):
            if compute[r] and not tables.drop[t, r]:
                q[r, slot] = view[r] - half[r]    # memory ≡ 0 (Identity)
                arrive[r, slot] = t + int(tables.delay[t, r])
        arr = arrive == t
        master = master - (q * arr[..., None]).sum(axis=(0, 1)) / R
        q[arr] = 0.0
        arrive[arr] = -1
        received = arr.any(axis=1) & alive
        local = half
        for r in range(R):
            if received[r]:
                view[r] = master
                local[r] = master

    # ---- engine ---------------------------------------------------
    state, _ = _run_faulty(problem, spec, ops.Identity())
    np.testing.assert_allclose(np.asarray(state.master["w"]), master,
                               rtol=1e-5, atol=1e-6)
    np.testing.assert_allclose(np.asarray(state.local["w"]), local,
                               rtol=1e-5, atol=1e-6)
    # Identity keeps the uplink error memory exactly zero throughout
    assert not np.asarray(state.memory["w"]).any()
    np.testing.assert_array_equal(np.asarray(state.arrive_at), arrive)


# ---------------------------------------------------------------------------
# round program parity under faults
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("spec", FAULT_GRID,
                         ids=[f"spec{i}" for i in range(len(FAULT_GRID))])
def test_fault_round_matches_per_step(problem, spec):
    op = ops.TopK(k=6)
    s1, l1 = _run_faulty(problem, spec, op)
    s2, l2 = _run_faulty(problem, spec, op, rounds=True)
    for field in ("master", "local", "memory", "inflight"):
        np.testing.assert_array_equal(
            np.asarray(getattr(s1, field)["w"]),
            np.asarray(getattr(s2, field)["w"]))
    np.testing.assert_array_equal(np.asarray(s1.arrive_at),
                                  np.asarray(s2.arrive_at))
    np.testing.assert_array_equal(np.asarray(s1.bits),
                                  np.asarray(s2.bits))
    np.testing.assert_array_equal(np.asarray(l1), np.asarray(l2))


def test_staleness_weight_damped_differs(problem):
    spec = scn.FaultSpec(max_delay=3, seed=1)
    su, _ = _run_faulty(problem, spec, ops.TopK(k=6))
    sd, _ = _run_faulty(problem, spec, ops.TopK(k=6),
                        staleness_weight="damped")
    assert np.isfinite(np.asarray(sd.master["w"])).all()
    # delayed payloads are scaled by 1/(1+τ): trajectories must differ
    assert not np.array_equal(np.asarray(su.master["w"]),
                              np.asarray(sd.master["w"]))


# ---------------------------------------------------------------------------
# crash → recover semantics
# ---------------------------------------------------------------------------


def test_crash_freezes_and_recover_reinitializes(problem):
    grad_fn, params, batches, mask_ = problem
    crash_t, rec_t, w = 5, 11, 1
    spec = scn.FaultSpec(crash=((w, crash_t, rec_t),))
    tables = spec.tables(T, R)
    mask = np.asarray(mask_, bool).copy()
    mask[rec_t, :] = False            # recover step takes no sync
    rows = engine.fault_rows(mask, tables, R)
    state = engine.init(params, sgd(), R, queue_depth=spec.depth)
    step = engine._donated(engine.make_fault_step(
        grad_fn, sgd(), ops.TopK(k=6), constant(0.05), R,
        queue_depth=spec.depth))
    key = jax.random.PRNGKey(42)
    snap = None
    for t in range(rec_t + 1):
        if t == crash_t:
            snap = jax.tree.map(np.asarray,
                                {"local": state.local["w"][w],
                                 "memory": state.memory["w"][w],
                                 "view": state.master_view["w"][w]})
        key, sub = jax.random.split(key)
        state, _ = step(state, batches[t], engine.index_rows(rows, t), sub)
        if crash_t <= t < rec_t:
            # dead: iterate, memory and view frozen at pre-crash values
            np.testing.assert_array_equal(
                np.asarray(state.local["w"][w]), snap["local"])
            np.testing.assert_array_equal(
                np.asarray(state.memory["w"][w]), snap["memory"])
            np.testing.assert_array_equal(
                np.asarray(state.master_view["w"][w]), snap["view"])
    # the recover step ran: memory lost, view = master, local = master
    # plus exactly one local sgd step taken from the master
    assert not np.asarray(state.memory["w"][w]).any()
    master_before = np.asarray(state.master["w"])   # untouched at rec_t
    np.testing.assert_array_equal(
        np.asarray(state.master_view["w"][w]), master_before)
    _, g = grad_fn({"w": jnp.asarray(master_before)},
                   np.asarray(batches[rec_t][w]))
    np.testing.assert_allclose(
        np.asarray(state.local["w"][w]),
        master_before - 0.05 * np.asarray(g["w"]), rtol=1e-6, atol=1e-7)


def test_all_crashed_round_is_noop(problem):
    """S2: a fleet that is entirely dead across the whole schedule
    produces no payloads — the master never moves, both bits ledgers
    stay zero, and no round is counted."""
    spec = scn.FaultSpec(crash=tuple((r, 0, T + 1) for r in range(R)))
    for rounds in (False, True):
        state, losses = _run_faulty(problem, spec, ops.TopK(k=6),
                                    rounds=rounds)
        np.testing.assert_array_equal(np.asarray(state.master["w"]),
                                      np.zeros(D, np.float32))
        np.testing.assert_array_equal(np.asarray(state.local["w"]),
                                      np.zeros((R, D), np.float32))
        assert float(state.bits) == 0.0
        assert float(state.bits_down) == 0.0
        assert int(state.rounds) == 0
        assert len(losses) == T and np.isfinite(losses).all()


# ---------------------------------------------------------------------------
# trainer surface: preset:none pin, runtime parity, resume, S2 History
# ---------------------------------------------------------------------------


@pytest.fixture(scope="module")
def trainer_problem():
    from repro.data import mnist_like, worker_batches
    from repro.models import softmax
    from repro.optim import inverse_time

    x, y = mnist_like(800, seed=0)
    cfg = softmax.SoftmaxConfig(l2=1.0 / len(x))
    params = softmax.init_params(jax.random.PRNGKey(0), cfg)

    def grad_fn(p, batch):
        return jax.value_and_grad(
            lambda pp: softmax.loss_fn(pp, batch, cfg)[0])(p)

    lr = inverse_time(xi=60.0, a=100.0)

    def mk_batches(Tt=48, seed=0):
        return worker_batches(x, y, R, 16, Tt, seed=seed)

    return grad_fn, params, lr, mk_batches


def _train(trainer_problem, **kw):
    from repro.train import RunConfig, train
    grad_fn, params, lr, mk_batches = trainer_problem
    run = RunConfig(total_steps=48, R=R, H=4, log_every=8, seed=0, **kw)
    return train(grad_fn, params, sgd(), ops.TopK(k=0.05), lr,
                 mk_batches(), run)


def test_trainer_preset_none_bit_exact(trainer_problem):
    st0, h0 = _train(trainer_problem)
    st1, h1 = _train(trainer_problem, faults="preset:none", fault_seed=3)
    np.testing.assert_array_equal(np.asarray(st0.master["x"]),
                                  np.asarray(st1.master["x"]))
    assert h0.loss == h1.loss
    assert h0.bits == h1.bits
    assert h0.rounds == h1.rounds


def test_trainer_fault_step_round_parity(trainer_problem):
    sts, hs = _train(trainer_problem, faults="preset:chaos",
                     runtime="step")
    str_, hr = _train(trainer_problem, faults="preset:chaos",
                      runtime="round")
    np.testing.assert_array_equal(np.asarray(sts.master["x"]),
                                  np.asarray(str_.master["x"]))
    np.testing.assert_array_equal(np.asarray(sts.inflight["x"]),
                                  np.asarray(str_.inflight["x"]))
    np.testing.assert_array_equal(np.asarray(sts.arrive_at),
                                  np.asarray(str_.arrive_at))
    assert hs.loss == hr.loss
    assert hs.bits == hr.bits


def test_trainer_crash_consistent_resume(tmp_path, trainer_problem):
    d = str(tmp_path / "ckpt")
    sta, _ = _train(trainer_problem, faults="preset:chaos", ckpt_dir=d,
                    ckpt_every=16)
    from repro.train import checkpoint as ckpt
    # wipe later snapshots so the resume starts mid-trajectory, with
    # payloads still in flight in the restored queue
    for dd in os.listdir(d):
        if dd.startswith("full_step_") and int(dd.rsplit("_", 1)[1]) > 16:
            shutil.rmtree(os.path.join(d, dd))
    full = ckpt.latest_full(d)
    assert full is not None and 0 < full < 48
    stb, _ = _train(trainer_problem, faults="preset:chaos", ckpt_dir=d,
                    ckpt_every=0, resume=True)
    for field in ("master", "memory", "inflight"):
        np.testing.assert_array_equal(
            np.asarray(getattr(sta, field)["x"]),
            np.asarray(getattr(stb, field)["x"]))
    np.testing.assert_array_equal(np.asarray(sta.arrive_at),
                                  np.asarray(stb.arrive_at))
    np.testing.assert_array_equal(np.asarray(sta.inflight_tau),
                                  np.asarray(stb.inflight_tau))


def test_trainer_dead_fleet_records_empty_rounds(trainer_problem):
    """S2 at the History level: scheduled rounds still close (and are
    recorded) when every worker is crashed — with zero payloads
    applied, zero bits, and the master untouched."""
    grad_fn, params, lr, mk_batches = trainer_problem
    dead = "crash=" + "+".join(f"{r}@0-64" for r in range(R))
    st, h = _train(trainer_problem, faults=dead)
    np.testing.assert_array_equal(np.asarray(st.master["x"]),
                                  np.asarray(params["x"]))
    assert h.bits[-1] == 0.0
    assert h.rounds[-1] == 0
    assert h.round_blocks, "scheduled rounds must still be recorded"
    assert all(n == 0 for (_, _, n) in h.round_blocks)


# ---------------------------------------------------------------------------
# distributed transports under faults (8 forced host devices)
# ---------------------------------------------------------------------------

DIST_COMMON = r"""
import jax, jax.numpy as jnp, numpy as np
from jax.sharding import PartitionSpec as P
from repro.compat import set_mesh
from repro.core.distributed import (make_dist_steps, make_dist_fault_steps,
                                    make_dist_fault_round, ShardCompressor)
from repro.core import engine, scenarios as scn, rounds as rnd, \
    schedule as sched
from repro.core.engine import stack_block
from repro.optim import sgd, constant

mesh = jax.make_mesh((8,), ("data",))
R, d_in, d_out = 8, 16, 8
params = {"w": jnp.zeros((d_in, d_out)), "b": jnp.zeros((d_out,))}
specs = {"w": P(), "b": P()}
Wtrue = jax.random.normal(jax.random.PRNGKey(0), (d_in, d_out))

def grad_fn(p, batch):
    x, y = batch
    f = lambda pp: jnp.mean((x @ pp["w"] + pp["b"] - y) ** 2)
    return jax.value_and_grad(f)(p)

inner = sgd()
comp = ShardCompressor(mode="topk", k_frac=0.25)
T, H = 24, 3
mask = sched.async_schedule(T, R, H, seed=7)

def batches(seed=5):
    key = jax.random.PRNGKey(seed)
    out = []
    for t in range(T):
        key, s1 = jax.random.split(key)
        x = jax.random.normal(s1, (R, 8, d_in))
        out.append((x, jnp.einsum("rbi,io->rbo", x, Wtrue)))
    return out

def run_fault(wire, spec, sw="uniform"):
    tables = spec.tables(T, R)
    rows = engine.fault_rows(mask, tables, R)
    _, _, events = scn.fault_replay(mask, tables)
    init_fn, fls, fss = make_dist_fault_steps(
        grad_fn, inner, comp, constant(0.1), mesh, ("data",), specs,
        queue_depth=spec.depth, wire=wire, staleness_weight=sw)
    with set_mesh(mesh):
        state = init_fn(params)
        jls, jss = jax.jit(fls), jax.jit(fss)
        key = jax.random.PRNGKey(1)
        for t, b in enumerate(batches()):
            key, sub = jax.random.split(key)
            row = engine.index_rows(rows, t)
            state, loss = (jss if events[t] else jls)(state, b, row, sub)
    return state

chaos = scn.FaultSpec(max_delay=3, drop=0.15, crash=((1, 4, 9),), seed=5)
"""

DIST_FAULT_PARITY = DIST_COMMON + r"""
# dense == sparse under chaos: states allclose, both bits ledgers exact
sd = run_fault("dense_psum", chaos)
ss = run_fault("sparse_allgather", chaos)
for f in ("master", "local", "memory"):
    np.testing.assert_allclose(
        np.asarray(getattr(sd, f)["w"]), np.asarray(getattr(ss, f)["w"]),
        rtol=1e-5, atol=1e-6)
np.testing.assert_array_equal(np.asarray(sd.bits), np.asarray(ss.bits))
np.testing.assert_array_equal(np.asarray(sd.bits_down),
                              np.asarray(ss.bits_down))
assert int(sd.rounds) == int(ss.rounds)

# trivial faults == the partial non-fault path (dense wire)
st = run_fault("dense_psum", scn.FaultSpec())
init_fn, lsn, ssn = make_dist_steps(
    grad_fn, inner, comp, constant(0.1), mesh, ("data",), specs,
    partial=True)
with set_mesh(mesh):
    state = init_fn(params)
    jl, js = jax.jit(lsn), jax.jit(ssn)
    key = jax.random.PRNGKey(1)
    for t, b in enumerate(batches()):
        key, sub = jax.random.split(key)
        if mask[t].any():
            state, _ = js(state, b, sub, jnp.asarray(mask[t]))
        else:
            state, _ = jl(state, b, sub)
np.testing.assert_allclose(np.asarray(st.master["w"]),
                           np.asarray(state.master["w"]),
                           rtol=1e-6, atol=1e-7)
np.testing.assert_array_equal(np.asarray(st.bits), np.asarray(state.bits))

# damped weighting: finite, and the two wires still agree
sdw = run_fault("dense_psum", chaos, sw="damped")
ssw = run_fault("sparse_allgather", chaos, sw="damped")
assert np.isfinite(np.asarray(sdw.master["w"])).all()
np.testing.assert_allclose(np.asarray(sdw.master["w"]),
                           np.asarray(ssw.master["w"]),
                           rtol=1e-5, atol=1e-6)
print("OK")
"""

DIST_FAULT_ROUNDS_AND_S2 = DIST_COMMON + r"""
def run_fault_rounds(wire, spec):
    tables = spec.tables(T, R)
    rows = engine.fault_rows(mask, tables, R)
    init_fn, round_fn, fused = make_dist_fault_round(
        grad_fn, inner, comp, constant(0.1), mesh, ("data",), specs,
        queue_depth=spec.depth, wire=wire, staleness_weight="uniform")
    plans = rnd.compile_fault_rounds(mask, tables)
    bs = batches()
    with set_mesh(mesh):
        state = init_fn(params)
        key = jax.random.PRNGKey(1)
        for p in plans:
            block = stack_block(bs[p.start:p.stop])
            rblock = engine.index_rows(rows, slice(p.start, p.stop))
            state, losses, key = round_fn(state, block, rblock, key)
    return state, fused

for wire in ("dense_psum", "sparse_allgather"):
    sr, fused = run_fault_rounds(wire, chaos)
    sp = run_fault(wire, chaos)
    np.testing.assert_array_equal(np.asarray(sr.master["w"]),
                                  np.asarray(sp.master["w"]))
    np.testing.assert_array_equal(np.asarray(sr.bits), np.asarray(sp.bits))
    assert int(sr.rounds) == int(sp.rounds)

# S2: an all-crashed fleet is a no-op on both transports
dead = scn.FaultSpec(crash=tuple((r, 0, T + 1) for r in range(R)))
for wire in ("dense_psum", "sparse_allgather"):
    s2 = run_fault(wire, dead)
    np.testing.assert_array_equal(np.asarray(s2.master["w"]),
                                  np.asarray(params["w"]))
    assert float(s2.bits) == 0.0 and float(s2.bits_down) == 0.0
    assert int(s2.rounds) == 0
print("OK")
"""


@pytest.mark.slow
def test_dist_fault_wire_parity(subproc):
    assert "OK" in subproc(DIST_FAULT_PARITY)


@pytest.mark.slow
def test_dist_fault_rounds_and_zero_support(subproc):
    assert "OK" in subproc(DIST_FAULT_ROUNDS_AND_S2)

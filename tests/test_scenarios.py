"""Fleet scenario simulator (DESIGN.md §8): mask-compilation
properties, aggregation-mode algebra (mean_R / mean_S /
support_weighted), dropped-worker state invariants, the
inject_dropout-vs-defer_sync differential failure-injection net, and
runtime x wire pinning — engine step/round on-process, the distributed
mesh paths in subprocesses.

Every property has a hypothesis version (skipped when hypothesis is
absent) AND a deterministic twin over ``strategies.SCENARIO_GRID`` /
``strategies.mask_grid()`` that runs everywhere.
"""

import warnings

import jax
import jax.numpy as jnp
import numpy as np
import pytest
import strategies
from hypothesis import given, settings

from repro.core import engine, operators as ops, policy as pol, \
    scenarios as scn, schedule as sched
from repro.optim import constant, sgd
from repro.train.trainer import RunConfig, train

R, D, LR = 4, 32, 0.05


# ---------------------------------------------------------------------------
# scenario -> mask compilation
# ---------------------------------------------------------------------------


def check_lossless_is_fixed_schedule(T, Rr, H):
    mask = scn.Scenario().mask(T, Rr, H=H)
    fixed = sched.fixed_schedule(T, H)
    np.testing.assert_array_equal(
        mask, np.broadcast_to(fixed[:, None], (T, Rr)))


@settings(max_examples=40, deadline=None)
@given(case=strategies.schedule_cases(max_T=120, max_R=8, max_H=10))
def test_lossless_scenario_is_fixed_schedule(case):
    T, Rr, H, _ = case
    check_lossless_is_fixed_schedule(T, Rr, H)


@pytest.mark.parametrize("T,Rr,H", [(1, 1, 1), (7, 3, 3), (24, 8, 5)])
def test_lossless_scenario_is_fixed_schedule_grid(T, Rr, H):
    check_lossless_is_fixed_schedule(T, Rr, H)


@settings(max_examples=40, deadline=None)
@given(sc=strategies.scenario_specs())
def test_scenario_mask_deterministic_and_bounded(sc):
    m1, m2 = sc.mask(30, 6, H=4), sc.mask(30, 6, H=4)
    np.testing.assert_array_equal(m1, m2)
    assert m1.shape == (30, 6) and m1.dtype == bool
    # every sync event survives thinning only: scenario masks are a
    # subset of the union of all per-worker base schedules
    assert m1.sum() <= 30 * 6


@pytest.mark.parametrize("i", range(len(strategies.SCENARIO_GRID)))
def test_scenario_grid_masks_deterministic(i):
    sc = strategies.SCENARIO_GRID[i]
    np.testing.assert_array_equal(sc.mask(24, 4, H=3), sc.mask(24, 4, H=3))


def test_scenario_thinning_is_monotone():
    """Each knob only removes sync events from the lossless schedule
    (for shared H): scenario masks are subsets of the base mask."""
    T, Rr, H = 36, 8, 4
    base = scn.Scenario().mask(T, Rr, H=H)
    for sc in [scn.Scenario(participation=0.5, seed=2),
               scn.Scenario(dropout_mid_round=0.4, seed=3),
               scn.Scenario(straggler_frac=0.5, seed=4),
               scn.Scenario(participation=0.7, dropout_mid_round=0.2,
                            straggler_frac=0.25, seed=5)]:
        m = sc.mask(T, Rr, H=H)
        assert not (m & ~base).any(), sc


def test_straggler_cadence():
    """A 100%-straggler fleet keeps exactly every k-th scheduled sync."""
    sc = scn.Scenario(straggler_frac=1.0, straggler_stale_rounds=3)
    m = sc.mask(36, 2, H=3)
    events = np.flatnonzero(sched.fixed_schedule(36, 3))
    kept = events[2::3]  # every 3rd of the 1-indexed event sequence
    for r in range(2):
        np.testing.assert_array_equal(np.flatnonzero(m[:, r]), kept)


def test_parse_roundtrip_and_presets():
    for sc in strategies.SCENARIO_GRID:
        assert scn.parse(sc.to_string() or "participation=1.0") == sc
    assert scn.parse("preset:flaky_fleet") is scn.PRESETS["flaky_fleet"]
    assert scn.parse(scn.PRESETS["dropout"]) is scn.PRESETS["dropout"]
    with pytest.raises(KeyError):
        scn.parse("preset:nope")
    with pytest.raises(KeyError):
        scn.parse("participaton=0.5")  # typo'd key
    with pytest.raises(ValueError):
        scn.parse("participation")
    with pytest.raises(ValueError):
        scn.Scenario(participation=1.5)


def test_mask_diagnostics():
    full = np.ones((8, 4), bool)
    assert not scn.is_partial(full)
    assert scn.participation_of(full) == 1.0
    part = full.copy()
    part[3, 2] = False
    assert scn.is_partial(part)
    assert 0.0 < scn.participation_of(part) < 1.0
    assert scn.participation_of(np.zeros((8, 4), bool)) == 0.0
    assert not scn.is_partial(sched.fixed_schedule(8, 2))  # [T] broadcasts


def test_warn_if_biased_once():
    part = np.ones((8, 4), bool)
    part[3, 2] = False
    pol._WARNED_KEYS.discard("scenario-mean_R-partial")
    with warnings.catch_warnings(record=True) as wlog:
        warnings.simplefilter("always")
        assert scn.warn_if_biased(part, "mean_R")
        assert scn.warn_if_biased(part, "mean_R")  # second time: silent
        assert not scn.warn_if_biased(part, "mean_S")
        assert not scn.warn_if_biased(np.ones((8, 4), bool), "mean_R")
    msgs = [w for w in wlog if "mean_R" in str(w.message)]
    assert len(msgs) == 1


# ---------------------------------------------------------------------------
# engine runs: shared harness
# ---------------------------------------------------------------------------


def _problem(T, Rr=R, seed=2, bounded=False):
    cs = jax.random.normal(jax.random.PRNGKey(1), (Rr, D))

    def grad_fn(params, data):
        c, noise = data
        err = params["w"] - c
        g = jnp.tanh(err) if bounded else err + 0.01 * noise
        return 0.5 * jnp.sum(err ** 2), {"w": g}

    k = jax.random.PRNGKey(seed)
    bs = []
    for _ in range(T):
        k, s = jax.random.split(k)
        bs.append((cs, jax.random.normal(s, (Rr, D))))
    return grad_fn, bs


def _run(mask, aggregate, operator=None, runtime="step", Rr=R, T=None,
         bounded=False, prefix=None):
    T = T if T is not None else np.asarray(mask).shape[0]
    operator = operator if operator is not None else ops.TopK(k=8)
    grad_fn, bs = _problem(T, Rr=Rr, bounded=bounded)
    if prefix is not None:
        bs, mask = bs[:prefix], np.asarray(mask)[:prefix]
    params = {"w": jnp.zeros(D)}
    inner = sgd()
    state = engine.init(params, inner, Rr)
    key = jax.random.PRNGKey(3)
    if runtime == "round":
        sstep = engine.make_superstep(grad_fn, inner, operator, constant(LR),
                                      Rr, global_rounds=True,
                                      aggregate=aggregate)
        return engine.run_rounds(state, sstep, bs, mask, key)
    step = engine.make_step(grad_fn, inner, operator, constant(LR), Rr,
                            global_rounds=True, aggregate=aggregate)
    return engine.run(state, step, bs, mask, key)


def _assert_state_equal(s1, s2):
    for f in s1._fields:
        a, b = getattr(s1, f), getattr(s2, f)
        if a is None:
            assert b is None, f
            continue
        for x, y in zip(jax.tree_util.tree_leaves(a),
                        jax.tree_util.tree_leaves(b)):
            np.testing.assert_array_equal(np.asarray(x), np.asarray(y),
                                          err_msg=f)


# ---------------------------------------------------------------------------
# aggregation-mode algebra
# ---------------------------------------------------------------------------


def check_mean_S_equals_mean_R_at_full_participation(mask):
    """With every scheduled sync an all-agree row, |S| = R: the two
    division rules are the same operation, bit for bit."""
    s1, l1 = _run(mask, "mean_R")
    s2, l2 = _run(mask, "mean_S")
    _assert_state_equal(s1, s2)
    np.testing.assert_array_equal(np.asarray(l1), np.asarray(l2))


def check_support_weighted_identity_equals_mean_S(mask):
    """Identity compression: every syncing worker supports every
    coordinate, so the per-coordinate survivor count is exactly |S|."""
    s1, l1 = _run(mask, "mean_S", operator=ops.Identity())
    s2, l2 = _run(mask, "support_weighted", operator=ops.Identity())
    _assert_state_equal(s1, s2)
    np.testing.assert_array_equal(np.asarray(l1), np.asarray(l2))


@settings(max_examples=10, deadline=None)
@given(case=strategies.fixed_schedule_cases(max_T=20, max_H=6))
def test_mean_S_equals_mean_R_full_participation(case):
    T, H = case
    check_mean_S_equals_mean_R_at_full_participation(
        sched.fixed_schedule(T, H))


@settings(max_examples=10, deadline=None)
@given(mask=strategies.sync_masks(max_T=16, max_R=R))
def test_support_weighted_identity_equals_mean_S(mask):
    if mask.shape[1] != R:
        mask = np.broadcast_to(mask.any(axis=1)[:, None],
                               (mask.shape[0], R)).copy()
    check_support_weighted_identity_equals_mean_S(mask)


@pytest.mark.parametrize("name,mask", strategies.mask_grid(T=16, R=R, H=4))
def test_aggregate_algebra_grid(name, mask):
    if not scn.is_partial(mask):
        check_mean_S_equals_mean_R_at_full_participation(mask)
    check_support_weighted_identity_equals_mean_S(mask)


def test_support_weighted_zero_support_keeps_master():
    """When every syncing worker's top-k payload misses a coordinate,
    the numerator is exactly 0 and max(count, 1) keeps the master
    value there — no NaN, no drift."""
    T = 4
    mask = np.zeros((T, R), bool)
    mask[-1] = True

    def grad_fn(params, data):
        # only coordinate 0 carries signal: k=1 topk payloads all pick
        # it, so coordinates 1..D-1 have zero support at the sync
        g = jnp.zeros(D).at[0].set(1.0)
        return jnp.sum(params["w"] ** 2), {"w": g}

    inner = sgd()
    state = engine.init({"w": jnp.ones(D)}, inner, R)
    step = engine.make_step(grad_fn, inner, ops.TopK(k=1), constant(LR), R,
                            global_rounds=True,
                            aggregate="support_weighted")
    bs = [(jnp.zeros(R),)] * T
    state, _ = engine.run(state, step, bs, mask, jax.random.PRNGKey(0))
    w = np.asarray(state.master["w"])
    assert np.isfinite(w).all()
    np.testing.assert_array_equal(w[1:], np.ones(D - 1))  # untouched
    assert w[0] < 1.0                                     # updated


# ---------------------------------------------------------------------------
# dropped-worker state invariants
# ---------------------------------------------------------------------------


def check_never_syncing_worker(mask, worker):
    """A worker whose column is all-False never touches the master and
    is never touched by it: its view stays the initial master, its
    error memory never activates, its local iterate free-runs."""
    mask = np.array(mask, bool, copy=True)
    mask[:, worker] = False
    state, _ = _run(mask, "mean_S")
    view = np.asarray(state.master_view["w"][worker])
    np.testing.assert_array_equal(view, np.zeros(D, np.float32))
    np.testing.assert_array_equal(
        np.asarray(state.memory["w"][worker]), np.zeros(D, np.float32))
    if mask.any():
        other = int(np.flatnonzero(mask.any(axis=0))[0])
        assert not np.array_equal(np.asarray(state.local["w"][worker]),
                                  np.asarray(state.local["w"][other]))


def test_never_syncing_worker_grid():
    base = np.broadcast_to(
        sched.fixed_schedule(16, 4)[:, None], (16, R)).copy()
    check_never_syncing_worker(base, worker=2)


def test_all_false_mask_master_untouched():
    state, _ = _run(np.zeros((10, R), bool), "mean_S")
    np.testing.assert_array_equal(np.asarray(state.master["w"]),
                                  np.zeros(D, np.float32))
    assert float(state.bits) == 0.0 and int(state.rounds) == 0


def check_memory_growth_linear(k_stale, H=2, T=None):
    """Straggler error memory is at most linear in missed rounds: with
    per-coordinate gradients bounded by 1 (tanh) and lr fixed, the
    half-vector a straggler accumulates over a gap of g steps has norm
    A <= lr * g * sqrt(D).  Top-k (delta = k/D) contracts each banked
    residual by c = sqrt(1 - delta), so the memory recursion
    ||M'|| <= c (||M|| + A) stays below cA/(1-c) — linear in the gap,
    for any number of syncs (Lemma 4's bounded-memory argument)."""
    T = T if T is not None else 8 * k_stale * H
    sc = scn.Scenario(straggler_frac=1.0, straggler_stale_rounds=k_stale)
    mask = sc.mask(T, R, H=H)
    state, _ = _run(mask, "mean_S", bounded=True, T=T)
    gaps = sched.worker_gaps(mask) or [T]
    g_max = max(gaps)
    c = np.sqrt(1.0 - 8 / D)  # _run compresses with TopK(k=8)
    bound = (c / (1.0 - c)) * LR * g_max * np.sqrt(D) * (1.0 + 1e-6)
    norms = np.linalg.norm(np.asarray(state.memory["w"]), axis=-1)
    assert (norms <= bound).all(), (norms, bound)
    return float(norms.max())


@pytest.mark.parametrize("k_stale", [1, 2, 4])
def test_straggler_memory_linear_in_staleness(k_stale):
    check_memory_growth_linear(k_stale)


# ---------------------------------------------------------------------------
# failure-injection differential: inject_dropout vs defer_sync
# ---------------------------------------------------------------------------


def test_inject_vs_defer_divergence_confined():
    """The same failure injected at two layers — payload lost
    (inject_dropout) vs payload arrives stale (defer_sync) — produces
    trajectories that are bit-identical until the stale arrival; after
    it, divergence is confined to the master and the deferred worker's
    state until the other workers' next sync round.  This is the
    regression net for the async/stale-sync regime of
    core/async_qsparse.py."""
    T, H, w = 16, 4, 1
    base = np.broadcast_to(
        sched.fixed_schedule(T, H)[:, None], (T, R)).copy()
    t0, later = H - 1, H + 1          # sync at t=4; stale arrival at t=6
    next_sync = 2 * H - 1             # the fleet's next round at t=8
    m_drop = scn.inject_dropout(base, w, t0)
    m_defer = scn.defer_sync(base, w, t0, later)
    np.testing.assert_array_equal(m_drop[:later], m_defer[:later])

    # bit-identical through every step before the stale arrival
    s1, l1 = _run(m_drop, "mean_S", prefix=later)
    s2, l2 = _run(m_defer, "mean_S", prefix=later)
    _assert_state_equal(s1, s2)
    np.testing.assert_array_equal(np.asarray(l1), np.asarray(l2))

    # after the arrival, before the fleet's next round: only the master
    # and worker w's state may differ — nobody else has read the master
    s1, _ = _run(m_drop, "mean_S", prefix=next_sync)
    s2, _ = _run(m_defer, "mean_S", prefix=next_sync)
    assert not np.array_equal(np.asarray(s1.master["w"]),
                              np.asarray(s2.master["w"]))
    for f in ("local", "memory", "master_view"):
        a = np.asarray(getattr(s1, f)["w"])
        b = np.asarray(getattr(s2, f)["w"])
        for r in range(R):
            if r == w:
                continue
            np.testing.assert_array_equal(a[r], b[r], err_msg=f"{f}[{r}]")
    # worker w's state does differ (it banked/spent its payload)
    assert not np.array_equal(np.asarray(s1.local["w"][w]),
                              np.asarray(s2.local["w"][w])) or \
        not np.array_equal(np.asarray(s1.memory["w"][w]),
                           np.asarray(s2.memory["w"][w]))

    # at the fleet's next sync the master difference propagates to all
    s1, _ = _run(m_drop, "mean_S", prefix=next_sync + 1)
    s2, _ = _run(m_defer, "mean_S", prefix=next_sync + 1)
    for r in range(R):
        assert not np.array_equal(
            np.asarray(s1.master_view["w"][r]),
            np.asarray(s2.master_view["w"][r])), r


def test_injection_helpers_validate():
    base = np.broadcast_to(
        sched.fixed_schedule(8, 4)[:, None], (8, R)).copy()
    with pytest.raises(ValueError):
        scn.inject_dropout(base, 0, 0)   # no sync scheduled at t=0
    with pytest.raises(ValueError):
        scn.defer_sync(base, 0, 3, 2)    # later must follow step
    m = scn.defer_sync(base, 0, 3, 5)
    assert not m[3, 0] and m[5, 0] and base[3, 0] and not base[5, 0]


# ---------------------------------------------------------------------------
# runtime x path pinning
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("aggregate", list(scn.AGGREGATES))
@pytest.mark.parametrize("name,mask", strategies.mask_grid(T=16, R=R, H=4))
def test_step_round_parity_scenarios(aggregate, name, mask):
    """Round-program runtime == per-step runtime, bit for bit, on every
    scenario mask family x aggregation mode."""
    s1, l1 = _run(mask, aggregate, runtime="step")
    s2, l2 = _run(mask, aggregate, runtime="round")
    _assert_state_equal(s1, s2)
    np.testing.assert_array_equal(np.asarray(l1), np.asarray(l2))


@pytest.mark.slow
@pytest.mark.parametrize("aggregate", ["mean_S", "support_weighted"])
def test_dist_wire_parity_partial(subproc, aggregate):
    """dense_psum and sparse_allgather agree on partial masks (states,
    exact bit ledgers, round counts), and the partial round program
    matches the per-step path bit-for-bit — on a real 8-way mesh."""
    subproc(_DIST_CODE.format(aggregate=aggregate), devices=8)


_DIST_CODE = """
import jax, jax.numpy as jnp, numpy as np
from jax.sharding import PartitionSpec as P, NamedSharding
from repro.compat import set_mesh
from repro.core.distributed import make_dist_steps, make_dist_round, \
    ShardCompressor
from repro.optim import sgd, constant

mesh = jax.make_mesh((8,), ("data",))
R, d_in, d_out, T, H = 8, 12, 6, 12, 4
params = {{"w": jnp.zeros((d_in, d_out)), "b": jnp.zeros((d_out,))}}
specs = {{"w": P(None, None), "b": P(None)}}
params = jax.device_put(params, jax.tree.map(
    lambda s: NamedSharding(mesh, s), specs,
    is_leaf=lambda z: isinstance(z, P)))
Wt = jax.random.normal(jax.random.PRNGKey(0), (d_in, d_out))

def grad_fn(p, batch):
    x, y = batch
    f = lambda pp: jnp.mean((x @ pp["w"] + pp["b"] - y) ** 2)
    return jax.value_and_grad(f)(p)

bs = []
key = jax.random.PRNGKey(7)
for _ in range(T):
    key, s = jax.random.split(key)
    x = jax.random.normal(s, (R, 8, d_in))
    bs.append((x, jnp.einsum("rbi,io->rbo", x, Wt)))

mask = np.ones((T, R), bool)
mask[3, 2] = False
mask[7, :] = False
mask[7, 0] = True

def run(wire):
    comp = ShardCompressor("topk", 0.25)
    init_fn, ls_, ss_ = make_dist_steps(
        grad_fn, sgd(), comp, constant(0.1), mesh, ("data",), specs,
        wire=wire, aggregate="{aggregate}", partial=True)
    with set_mesh(mesh):
        st = init_fn(params)
        ls, ss = jax.jit(ls_), jax.jit(ss_)
        k = jax.random.PRNGKey(1)
        for t in range(T):
            k, sub = jax.random.split(k)
            if (t + 1) % H == 0:
                st, _ = ss(st, bs[t], sub, mask[t])
            else:
                st, _ = ls(st, bs[t], sub)
    return jax.device_get(st)

sd, sp = run("dense_psum"), run("sparse_allgather")
for f in ("master", "local", "memory", "view"):
    a, b = getattr(sd, f), getattr(sp, f)
    np.testing.assert_allclose(np.asarray(a["w"]), np.asarray(b["w"]),
                               rtol=1e-5, atol=1e-6, err_msg=f)
assert float(sd.bits) == float(sp.bits)
assert int(sd.rounds) == int(sp.rounds) == T // H

comp = ShardCompressor("topk", 0.25)
init_fn, round_fn, fused = make_dist_round(
    grad_fn, sgd(), comp, constant(0.1), mesh, ("data",), specs,
    wire="dense_psum", aggregate="{aggregate}", partial=True)
assert fused
with set_mesh(mesh):
    st2 = init_fn(params)
    k = jax.random.PRNGKey(1)
    for r0 in range(0, T, H):
        blk = jax.tree_util.tree_map(
            lambda *xs: jnp.stack(xs), *bs[r0:r0 + H])
        st2, _, k = round_fn(st2, blk, mask[r0 + H - 1], k)
st2 = jax.device_get(st2)
np.testing.assert_array_equal(np.asarray(sd.master["w"]),
                              np.asarray(st2.master["w"]))
assert float(sd.bits) == float(st2.bits)
assert int(sd.rounds) == int(st2.rounds)
print("DIST SCENARIO PARITY OK")
"""


# ---------------------------------------------------------------------------
# trainer wiring
# ---------------------------------------------------------------------------


def test_trainer_scenario_run():
    T = 12
    grad_fn, bs = _problem(T)
    run = RunConfig(total_steps=T, R=R, H=4, policy="topk:k=8",
                    scenario="participation=0.6,seed=3",
                    aggregate="mean_S", log_every=4)
    state, hist = train(grad_fn, {"w": jnp.zeros(D)}, sgd(), None,
                        constant(LR), bs, run)
    assert np.isfinite(np.asarray(state.master["w"])).all()
    assert hist.loss


def test_trainer_scenario_rejects_async():
    run = RunConfig(total_steps=4, R=R, scenario="preset:dropout",
                    asynchronous=True)
    with pytest.raises(ValueError, match="scenario"):
        train(lambda p, b: (0.0, p), {"w": jnp.zeros(D)}, sgd(),
              ops.TopK(k=8), constant(LR), [], run)


def test_trainer_scenario_mean_R_warns():
    T = 8
    grad_fn, bs = _problem(T)
    pol._WARNED_KEYS.discard("scenario-mean_R-partial")
    run = RunConfig(total_steps=T, R=R, H=4, policy="topk:k=8",
                    scenario="participation=0.4,seed=5")
    with warnings.catch_warnings(record=True) as wlog:
        warnings.simplefilter("always")
        train(grad_fn, {"w": jnp.zeros(D)}, sgd(), None, constant(LR),
              bs, run)
    assert any("mean_R" in str(w.message) for w in wlog)


# ---------------------------------------------------------------------------
# fleet scale (pytest -m scenarios lane)
# ---------------------------------------------------------------------------


@pytest.mark.scenarios
def test_fleet_scale_mask_statistics():
    """R = 1024: the realized participation rate concentrates near the
    spec's survival probability p * (1 - dropout)."""
    sc = scn.Scenario(participation=0.8, dropout_mid_round=0.1, seed=9)
    mask = sc.mask(40, 1024, H=4)
    p_hat = scn.participation_of(mask)
    assert abs(p_hat - 0.8 * 0.9) < 0.03
    assert scn.is_partial(mask)


@pytest.mark.scenarios
def test_fleet_scale_engine_run():
    """R = 256 through the vmapped engine on a flaky fleet: finite
    state, loss decreased, ledgers consistent with the mask."""
    Rr, T, H = 256, 8, 2
    sc = scn.PRESETS["flaky_fleet"]
    mask = sc.mask(T, Rr, H=H)
    state, losses = _run(mask, "support_weighted", Rr=Rr, T=T)
    assert np.isfinite(np.asarray(state.master["w"])).all()
    assert float(losses[-1]) < float(losses[0])
    assert int(state.rounds) == int(mask.any(axis=1).sum())


@pytest.mark.scenarios
def test_fleet_scale_sharded_worker_axis(subproc):
    """R = 1024 sharded over an 8-way mesh via shard_worker_axis: the
    partitioned run stays finite and syncs the fleet."""
    subproc("""
import jax, jax.numpy as jnp, numpy as np
from repro.core import engine, operators as ops, scenarios as scn
from repro.optim import constant, sgd

mesh = jax.make_mesh((8,), ("data",))
Rr, D, T, H = 1024, 16, 4, 2
mask = scn.PRESETS["flaky_fleet"].mask(T, Rr, H=H)

def grad_fn(p, data):
    err = p["w"] - data
    return 0.5 * jnp.sum(err ** 2), {"w": err}

inner = sgd()
state = engine.init({"w": jnp.zeros(D)}, inner, Rr)
state = engine.shard_worker_axis(state, mesh)
step = engine.make_step(grad_fn, inner, ops.TopK(k=4), constant(0.05),
                        Rr, global_rounds=True, aggregate="mean_S")
bs = [jnp.ones((Rr, D)) for _ in range(T)]
state, losses = engine.run(state, step, bs, mask, jax.random.PRNGKey(0))
assert np.isfinite(np.asarray(state.master["w"])).all()
assert int(state.rounds) == int(mask.any(axis=1).sum())
print("FLEET SHARDED OK", float(losses[-1]))
""", devices=8)

"""benchmarks/check_regression.py gate semantics: new rows are
reported and skipped, removed rows fail, regressions fail."""

import json
import subprocess
import sys
import os

import pytest

REPO = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
GATE = os.path.join(REPO, "benchmarks", "check_regression.py")


def _write(path, rows):
    with open(path, "w") as f:
        json.dump({"suite": "operators",
                   "rows": [{"name": n, "us_per_call": us,
                             "wire_bits": wb} for n, us, wb in rows]}, f)


def _gate(baseline, current, *extra):
    out = subprocess.run(
        [sys.executable, GATE, "--baseline", baseline,
         "--current", current, *extra],
        capture_output=True, text=True)
    return out.returncode, out.stdout


@pytest.fixture
def paths(tmp_path):
    return str(tmp_path / "base.json"), str(tmp_path / "cur.json")


def test_new_rows_reported_and_skipped(paths):
    base, cur = paths
    _write(base, [("op/a", 1000.0, 64.0)])
    # the new row is wildly "slow" — must still pass: no baseline to
    # judge it against until the committed baseline is regenerated
    _write(cur, [("op/a", 1000.0, 64.0), ("channel/new", 99000.0, 1.0)])
    rc, out = _gate(base, cur)
    assert rc == 0, out
    assert "NEW channel/new" in out
    assert "skipped" in out


def test_removed_rows_fail(paths):
    base, cur = paths
    _write(base, [("op/a", 1000.0, 64.0), ("op/gone", 1000.0, 64.0)])
    _write(cur, [("op/a", 1000.0, 64.0)])
    rc, out = _gate(base, cur)
    assert rc == 1
    assert "missing" in out


def test_relative_regression_fails_uniform_slowdown_passes(paths):
    base, cur = paths
    _write(base, [(f"op/{i}", 1000.0, 64.0) for i in range(5)])
    # uniform 2x slowdown (cold runner): calibrated away, passes
    _write(cur, [(f"op/{i}", 2000.0, 64.0) for i in range(5)])
    rc, out = _gate(base, cur)
    assert rc == 0, out
    # one row 4x slower than its peers: fails
    rows = [(f"op/{i}", 2000.0, 64.0) for i in range(4)]
    rows.append(("op/4", 8000.0, 64.0))
    _write(cur, rows)
    rc, out = _gate(base, cur)
    assert rc == 1
    assert "REGRESSION" in out


def test_wire_bit_change_fails(paths):
    base, cur = paths
    _write(base, [("op/a", 1000.0, 64.0), ("op/b", 1000.0, 100.0)])
    _write(cur, [("op/a", 1000.0, 64.0), ("op/b", 1000.0, 150.0)])
    rc, out = _gate(base, cur)
    assert rc == 1
    assert "LEDGER CHANGE" in out

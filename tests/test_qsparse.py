"""Algorithm 1 (sync) behaviour tests: vanilla-SGD equivalence,
convergence, memory lemmas, error-compensation identity (Lemma 6)."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import operators as ops, qsparse, schedule
from repro.optim import constant, inverse_time, sgd

R, D = 4, 50


@pytest.fixture(scope="module")
def problem():
    cs = jax.random.normal(jax.random.PRNGKey(1), (R, D))

    def grad_fn(params, data):
        c, noise = data
        g = params["w"] - c + 0.01 * noise
        return 0.5 * jnp.sum((params["w"] - c) ** 2), {"w": g}

    def batches(T, seed=2):
        k = jax.random.PRNGKey(seed)
        out = []
        for _ in range(T):
            k, s = jax.random.split(k)
            out.append((cs, jax.random.normal(s, (R, D))))
        return out

    return cs, grad_fn, batches


def run_alg1(grad_fn, batches, op, T, H, lr, seed=3):
    params = {"w": jnp.zeros(D)}
    inner = sgd()
    state = qsparse.init(params, inner, R)
    step = qsparse.make_step(grad_fn, inner, op, lr, R)
    mask = schedule.fixed_schedule(T, H)
    state, losses = qsparse.run(state, step, batches, mask,
                                jax.random.PRNGKey(seed))
    return state, losses


def test_identity_h1_equals_vanilla_sgd(problem):
    """gamma=1, H=1 must reproduce distributed vanilla SGD exactly."""
    cs, grad_fn, batches = problem
    T, eta = 40, 0.05
    bs = batches(T)
    state, _ = run_alg1(grad_fn, bs, ops.Identity(), T, 1, constant(eta))
    # manual vanilla distributed SGD
    w = jnp.zeros(D)
    for c, noise in bs:
        g = jnp.mean(w[None] - c + 0.01 * noise, axis=0)
        w = w - eta * g
    np.testing.assert_allclose(np.asarray(state.master["w"]), np.asarray(w),
                               rtol=1e-5, atol=1e-5)


def test_identity_local_sgd_equals_manual(problem):
    """gamma=1, H>1 == local SGD with parameter averaging at sync."""
    cs, grad_fn, batches = problem
    T, H, eta = 12, 3, 0.05
    bs = batches(T)
    state, _ = run_alg1(grad_fn, bs, ops.Identity(), T, H, constant(eta))
    ws = jnp.zeros((R, D))
    for t, (c, noise) in enumerate(bs):
        g = ws - c + 0.01 * noise
        ws = ws - eta * g
        if (t + 1) % H == 0 or t == T - 1:
            ws = jnp.broadcast_to(jnp.mean(ws, 0), ws.shape)
    np.testing.assert_allclose(np.asarray(state.master["w"]),
                               np.asarray(ws[0]), rtol=1e-5, atol=1e-5)


def test_compressed_converges_to_neighborhood(problem):
    cs, grad_fn, batches = problem
    opt_pt = jnp.mean(cs, 0)
    T, H = 1200, 4
    lr = inverse_time(30.0, 200.0)
    state, _ = run_alg1(grad_fn, batches(T), ops.TopK(k=10), T, H, lr)
    err = float(jnp.linalg.norm(state.master["w"] - opt_pt))
    assert err < 0.35, err
    # uncompressed reference is better but same order
    state0, _ = run_alg1(grad_fn, batches(T), ops.Identity(), T, H, lr)
    err0 = float(jnp.linalg.norm(state0.master["w"] - opt_pt))
    assert err0 < err


def test_memory_lemma5_bound(problem):
    """Lemma 5: E||m||^2 <= 4 eta^2 (1-gamma^2)/gamma^2 H^2 G^2."""
    cs, grad_fn, batches = problem
    T, H, eta = 200, 4, 0.02
    op = ops.TopK(k=10)
    gamma = op.gamma(D)
    state, _ = run_alg1(grad_fn, batches(T), op, T, H, constant(eta))
    mem = float(jnp.mean(qsparse.memory_sq_norms(state)))
    # G^2: bound gradient norm along the trajectory (generous estimate)
    G2 = float(jnp.max(jnp.sum(cs ** 2, axis=1))) * 4 + 1.0
    bound = 4 * eta ** 2 * (1 - gamma ** 2) / gamma ** 2 * H ** 2 * G2
    assert mem <= bound, (mem, bound)


def test_memory_contracts_with_decaying_lr(problem):
    """Lemma 4: memory ~ O(eta_t^2) for eta_t = xi/(a+t)."""
    cs, grad_fn, batches = problem
    op = ops.TopK(k=10)
    mems = []
    for T in (200, 800):
        lr = inverse_time(20.0, 400.0)
        state, _ = run_alg1(grad_fn, batches(T), op, T, 4, lr)
        mems.append(float(jnp.mean(qsparse.memory_sq_norms(state))))
    # eta ratio: ((400+200)/(400+800))^2 = 0.25 => memory should shrink
    assert mems[1] < mems[0] * 0.6, mems


def test_bits_ledger_matches_schedule(problem):
    cs, grad_fn, batches = problem
    T, H = 40, 4
    op = ops.TopK(k=10)
    state, _ = run_alg1(grad_fn, batches(T), op, T, H, constant(0.05))
    rounds = int(state.rounds)
    assert rounds == len([t for t in range(T)
                          if (t + 1) % H == 0 or t == T - 1])
    from repro.core import bits as bitlib
    expected = rounds * R * bitlib.bits_topk(D, 10)
    np.testing.assert_allclose(float(state.bits), expected)


def test_lemma6_virtual_sequence_identity(problem):
    """Lemma 6: x̂_t − x̃_t == (1/R) Σ_r m_t^{(r)}.  The virtual sequence
    x̃ applies the *uncompressed* local updates evaluated at the real
    local iterates; we replay it exactly alongside Algorithm 1."""
    cs, grad_fn, batches = problem
    T, H, eta = 12, 3, 0.05
    bs = batches(T)
    op = ops.TopK(k=5)
    params = {"w": jnp.zeros(D)}
    inner = sgd()
    state = qsparse.init(params, inner, R)
    step = jax.jit(qsparse.make_step(grad_fn, inner, op, constant(eta), R),
                   static_argnames=("sync",))
    mask = schedule.fixed_schedule(T, H)
    key = jax.random.PRNGKey(3)
    virtual = jnp.zeros((R, D))  # x̃^{(r)}
    for t, (c, noise) in enumerate(bs):
        # virtual update uses gradients at the REAL local iterates x̂_t
        g = state.local["w"] - c + 0.01 * noise
        virtual = virtual - eta * g
        key, sub = jax.random.split(key)
        state, _ = step(state, (c, noise), sync=bool(mask[t]), key=sub)
        xhat_bar = jnp.mean(state.local["w"], 0)
        xtilde_bar = jnp.mean(virtual, 0)
        mean_mem = jnp.mean(state.memory["w"], 0)
        np.testing.assert_allclose(
            np.asarray(xhat_bar - xtilde_bar), np.asarray(mean_mem),
            rtol=1e-4, atol=1e-5)
    # at a sync step, locals == master exactly
    np.testing.assert_allclose(
        np.asarray(state.local["w"][0]), np.asarray(state.master["w"]),
        rtol=1e-6, atol=1e-6)

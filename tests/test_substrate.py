"""Substrate tests: optimizers, schedules, data pipelines, checkpointing."""

import os
import tempfile

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from repro.data import LMTokenStream, make_image_data, mnist_like, worker_batches
from repro.optim import (
    adam,
    apply_updates,
    inverse_time,
    momentum_sgd,
    paper_convex_lr,
    piecewise_decay,
    sgd,
    warmup_piecewise,
)
from repro.train import checkpoint


def rosenbrockish(p):
    return jnp.sum((p["a"] - 1.0) ** 2) + 0.5 * jnp.sum(p["b"] ** 2)


@pytest.mark.parametrize("opt,lr", [
    (sgd(), 0.1),
    (momentum_sgd(0.9), 0.02),
    (momentum_sgd(0.9, nesterov=True), 0.02),
    (adam(), 0.05),
    (sgd(weight_decay=1e-4), 0.1),
])
def test_optimizers_minimize(opt, lr):
    p = {"a": jnp.zeros(5), "b": jnp.ones(3)}
    state = opt.init(p)
    for _ in range(200):
        g = jax.grad(rosenbrockish)(p)
        upd, state = opt.update(g, state, p, jnp.float32(lr))
        p = apply_updates(p, upd)
    assert float(rosenbrockish(p)) < 1e-2


def test_schedules():
    s = inverse_time(10.0, 100.0)
    assert float(s(jnp.asarray(0))) == pytest.approx(0.1)
    assert float(s(jnp.asarray(900))) == pytest.approx(0.01)
    pw = piecewise_decay(1.0, [10, 20])
    assert float(pw(jnp.asarray(5))) == 1.0
    assert float(pw(jnp.asarray(15))) == pytest.approx(0.1)
    assert float(pw(jnp.asarray(25))) == pytest.approx(0.01)
    w = warmup_piecewise(1.0, 10, [100])
    assert float(w(jnp.asarray(0))) == pytest.approx(0.1)
    assert float(w(jnp.asarray(50))) == 1.0
    pc = paper_convex_lr(c=1.0, lam=0.1, d=7850, H=4, k=40)
    assert float(pc(jnp.asarray(0))) == pytest.approx(1.0 / 0.1 / 785.0)


@settings(max_examples=10, deadline=None)
@given(R=st.integers(1, 8), batch=st.integers(1, 16), steps=st.integers(1, 5),
       non_iid=st.booleans())
def test_worker_batches_shapes(R, batch, steps, non_iid):
    x, y = mnist_like(600, seed=1)
    got = list(worker_batches(x, y, R, batch, steps, non_iid=non_iid))
    assert len(got) == steps
    for b in got:
        assert b["features"].shape == (R, batch, 784)
        assert b["labels"].shape == (R, batch)


def test_worker_batches_deterministic():
    x, y = mnist_like(600, seed=1)
    a = list(worker_batches(x, y, 4, 8, 3, seed=7))
    b = list(worker_batches(x, y, 4, 8, 3, seed=7))
    for ba, bb in zip(a, b):
        np.testing.assert_array_equal(ba["features"], bb["features"])


def test_non_iid_skews_classes():
    x, y = mnist_like(6000, seed=1)
    b = next(worker_batches(x, y, 4, 200, 1, seed=0, non_iid=True))
    # worker r biased to class r
    for r in range(4):
        frac = float(np.mean(b["labels"][r] == r))
        assert frac > 0.4, (r, frac)


def test_lm_stream_learnable_structure():
    """Markov tokens must beat uniform entropy — i.e. the pipeline emits
    learnable data, not noise."""
    stream = LMTokenStream(vocab=64, R=1, order=8, seed=0)
    batch = next(stream.batches(8, 256, 1))
    toks = batch["tokens"][0]
    # bigram statistics concentrate
    trans = np.zeros((64, 64))
    for row in toks:
        for a, b in zip(row[:-1], row[1:]):
            trans[a, b] += 1
    row_sums = trans.sum(1, keepdims=True)
    probs = trans / np.maximum(row_sums, 1)
    ent = -(probs * np.log(probs + 1e-12)).sum(1)
    used = (row_sums[:, 0] > 50)
    assert ent[used].mean() < np.log(64) * 0.8


def test_image_data():
    x, y = make_image_data(100, hw=8)
    assert x.shape == (100, 8, 8, 3) and y.shape == (100,)


def test_checkpoint_roundtrip_nested():
    tree = {
        "a": jnp.arange(6, dtype=jnp.float32).reshape(2, 3),
        "nested": {"b": jnp.ones((4,), jnp.bfloat16),
                   "c": [jnp.zeros((2, 2)), jnp.full((1,), 7)]},
    }
    with tempfile.TemporaryDirectory() as d:
        checkpoint.save(os.path.join(d, "c"), tree, step=3)
        back = checkpoint.restore(os.path.join(d, "c"), tree)
        for x, yv in zip(jax.tree_util.tree_leaves(tree),
                         jax.tree_util.tree_leaves(back)):
            np.testing.assert_array_equal(np.asarray(x), np.asarray(yv))
        assert checkpoint.latest_step(d) is None
        checkpoint.save(os.path.join(d, "step_10"), tree)
        checkpoint.save(os.path.join(d, "step_20"), tree)
        assert checkpoint.latest_step(d) == 20


def test_checkpoint_structure_mismatch():
    tree = {"a": jnp.zeros((2,))}
    with tempfile.TemporaryDirectory() as d:
        checkpoint.save(os.path.join(d, "c"), tree)
        with pytest.raises(ValueError):
            checkpoint.restore(os.path.join(d, "c"),
                               {"a": jnp.zeros((2,)), "b": jnp.zeros((1,))})

"""Shared fixtures.  NOTE: no XLA_FLAGS device-count forcing here —
smoke tests must see the real single CPU device; multi-device tests
spawn subprocesses that set the flag before importing jax.

When ``hypothesis`` is not installed, a minimal stub is injected into
``sys.modules`` so the property-test modules still import; every
``@given``-decorated test is then collected as a single skipped test
with an explicit reason instead of erroring at collection time.
"""

import os
import subprocess
import sys
import types

import pytest

REPO = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
SRC = os.path.join(REPO, "src")

try:
    import hypothesis  # noqa: F401

    HAVE_HYPOTHESIS = True
except ImportError:
    HAVE_HYPOTHESIS = False

    class _Strategy:
        """Inert stand-in: absorbs .map/.filter/.flatmap chains and —
        for ``@st.composite``-built strategies, which the stub turns
        into _Strategy instances — calls."""

        def map(self, _fn):
            return self

        def filter(self, _fn):
            return self

        def flatmap(self, _fn):
            return self

        def __call__(self, *_a, **_k):
            return self

    class _Strategies(types.ModuleType):
        def __getattr__(self, _name):
            return lambda *a, **k: _Strategy()

    def _given(*_a, **_k):
        def deco(fn):
            @pytest.mark.skip(
                reason="hypothesis not installed; property test skipped"
            )
            def shim():
                pass

            shim.__name__ = fn.__name__
            shim.__qualname__ = getattr(fn, "__qualname__", fn.__name__)
            shim.__doc__ = fn.__doc__
            shim.__module__ = fn.__module__
            return shim

        return deco

    def _settings(*_a, **_k):
        # usable both as @settings(...) and as settings(...)(fn)
        return lambda fn: fn

    _st = _Strategies("hypothesis.strategies")
    _hyp = types.ModuleType("hypothesis")
    _hyp.given = _given
    _hyp.settings = _settings
    _hyp.strategies = _st
    _hyp.assume = lambda *_a, **_k: True
    sys.modules["hypothesis"] = _hyp
    sys.modules["hypothesis.strategies"] = _st

if HAVE_HYPOTHESIS:
    # example budgets: "fleet" keeps the R>=256 scenario lane cheap
    # (pytest -m scenarios in CI); select with HYPOTHESIS_PROFILE=
    from hypothesis import settings as _hs

    _hs.register_profile("fleet", max_examples=5, deadline=None)
    _hs.register_profile("ci", max_examples=25, deadline=None)
    _hs.load_profile(os.environ.get("HYPOTHESIS_PROFILE", "default"))


def run_subprocess(code: str, devices: int = 8, timeout: int = 900):
    """Run python code in a subprocess with a forced device count."""
    env = dict(os.environ)
    env["XLA_FLAGS"] = f"--xla_force_host_platform_device_count={devices}"
    env["PYTHONPATH"] = SRC
    out = subprocess.run(
        [sys.executable, "-c", code], env=env, capture_output=True,
        text=True, timeout=timeout,
    )
    if out.returncode != 0:
        raise AssertionError(
            f"subprocess failed\nSTDOUT:\n{out.stdout[-4000:]}\n"
            f"STDERR:\n{out.stderr[-4000:]}"
        )
    return out.stdout


@pytest.fixture(scope="session")
def subproc():
    return run_subprocess

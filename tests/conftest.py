"""Shared fixtures.  NOTE: no XLA_FLAGS device-count forcing here —
smoke tests must see the real single CPU device; multi-device tests
spawn subprocesses that set the flag before importing jax."""

import os
import subprocess
import sys

import pytest

REPO = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
SRC = os.path.join(REPO, "src")


def run_subprocess(code: str, devices: int = 8, timeout: int = 900):
    """Run python code in a subprocess with a forced device count."""
    env = dict(os.environ)
    env["XLA_FLAGS"] = f"--xla_force_host_platform_device_count={devices}"
    env["PYTHONPATH"] = SRC
    out = subprocess.run(
        [sys.executable, "-c", code], env=env, capture_output=True,
        text=True, timeout=timeout,
    )
    if out.returncode != 0:
        raise AssertionError(
            f"subprocess failed\nSTDOUT:\n{out.stdout[-4000:]}\n"
            f"STDERR:\n{out.stderr[-4000:]}"
        )
    return out.stdout


@pytest.fixture(scope="session")
def subproc():
    return run_subprocess

"""Tests for the overlapped round driver (DESIGN.md §10).

The feature's whole contract is *scheduling only*: windows of
consecutive equal-length rounds execute as one scanned multi-round
program (``rounds.window_rounds`` → ``engine.make_multiround`` /
``distributed.make_dist_multiround``), and every trajectory — state
leaves, both wire-bit ledgers, per-step losses, trainer History — is
bit-for-bit the serialized round runtime's.  These tests pin that
across sync/async/scenario masks, compressed downlinks, the per-leaf
ledger, truncated batch streams, eval/ckpt boundaries, and the mesh
engine.
"""

import dataclasses
import tempfile

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import engine as eng
from repro.core import operators as ops
from repro.core import rounds as rnd
from repro.core import schedule as sched
from repro.optim import transforms as tfm
from tests.strategies import mask_grid

# ---------------------------------------------------------------------------
# window_rounds (pure host)
# ---------------------------------------------------------------------------


def _plans_of(mask):
    return rnd.compile_rounds(mask)


@pytest.mark.parametrize("name,mask", mask_grid())
def test_window_rounds_partition(name, mask):
    plans = _plans_of(mask)
    for w in (1, 2, 4, 8):
        windows = rnd.window_rounds(plans, max_window=w)
        flat = [p for win in windows for p in win]
        assert flat == plans, name
        for win in windows:
            assert len(win) <= w
            assert len(win) & (len(win) - 1) == 0, "power-of-two sizes"
            assert len({p.length for p in win}) == 1, \
                "windows are rectangular"


def test_window_rounds_boundary_singletons():
    mask = sched.fixed_schedule(32, 4)
    plans = _plans_of(mask)
    # steps 11 and 23 (0-based) are eval points: their rounds must be
    # singleton windows so the driver can materialize the state there
    windows = rnd.window_rounds(plans, max_window=8,
                                boundary_steps=(11, 23))
    for win in windows:
        for p in win:
            if any(p.start <= b < p.stop for b in (11, 23)):
                assert len(win) == 1
    assert [p for w in windows for p in w] == plans


def test_window_rounds_rejects_bad_window():
    with pytest.raises(ValueError):
        rnd.window_rounds([], max_window=0)


# ---------------------------------------------------------------------------
# engine: run_rounds_overlap ≡ run_rounds
# ---------------------------------------------------------------------------

R, D, T = 4, 96, 24


def _problem():
    rng = np.random.default_rng(0)
    A = jnp.asarray(rng.normal(size=(64, D)).astype(np.float32))
    y = jnp.asarray(rng.normal(size=(64,)).astype(np.float32))
    params = {"w": jnp.zeros((D,), jnp.float32),
              "b": jnp.zeros((3, 8), jnp.float32)}

    def loss(p, batch):
        xb, yb = batch
        pred = xb @ p["w"] + p["b"].sum()
        return jnp.mean((pred - yb) ** 2)

    def batches(n=T):
        r = np.random.default_rng(5)
        for _ in range(n):
            idx = r.integers(0, 64, size=(R, 16))
            yield (A[jnp.asarray(idx)], y[jnp.asarray(idx)])

    return jax.value_and_grad(loss), params, batches


def _assert_same(state_a, state_b, losses_a, losses_b, ctx=""):
    la, lb = np.asarray(losses_a), np.asarray(losses_b)
    assert la.shape == lb.shape and np.array_equal(la, lb), (ctx, "losses")
    fa = jax.tree_util.tree_leaves(state_a)
    fb = jax.tree_util.tree_leaves(state_b)
    assert len(fa) == len(fb)
    for xa, xb in zip(fa, fb):
        np.testing.assert_array_equal(np.asarray(xa), np.asarray(xb),
                                      err_msg=str(ctx))


@pytest.mark.parametrize("name,mask", mask_grid(T=T, R=R, H=3))
def test_overlap_matches_serial(name, mask):
    grad_fn, params, batches = _problem()
    inner = tfm.sgd(0.05)
    sup = eng.make_superstep(grad_fn, inner, ops.TopK(0.25),
                             lambda t: 0.05, R)
    s_a, l_a = eng.run_rounds(eng.init(params, inner, R), sup, batches(),
                              mask, jax.random.PRNGKey(7))
    s_b, l_b = eng.run_rounds_overlap(
        eng.init(params, inner, R), sup, batches(), mask,
        jax.random.PRNGKey(7), window=4)
    _assert_same(s_a, s_b, l_a, l_b, name)


@pytest.mark.parametrize("downlink,leaf", [
    (None, True),
    (ops.QSGDQuantizer(4), False),
    (ops.QSGDQuantizer(4), True),
])
def test_overlap_matches_serial_channels(downlink, leaf):
    """Both bits ledgers (and the per-leaf split) survive windowing,
    with and without a compressed downlink."""
    grad_fn, params, batches = _problem()
    inner = tfm.sgd(0.05)
    mask = sched.async_schedule(T, R, 3, seed=11)
    sup = eng.make_superstep(grad_fn, inner, ops.TopK(0.25),
                             lambda t: 0.05, R, downlink=downlink,
                             leaf_ledger=leaf)
    s_a, l_a = eng.run_rounds(
        eng.init(params, inner, R, downlink=downlink, leaf_ledger=leaf),
        sup, batches(), mask, jax.random.PRNGKey(7))
    s_b, l_b = eng.run_rounds_overlap(
        eng.init(params, inner, R, downlink=downlink, leaf_ledger=leaf),
        sup, batches(), mask, jax.random.PRNGKey(7), window=8)
    _assert_same(s_a, s_b, l_a, l_b, (downlink, leaf))
    assert float(s_a.bits) == float(s_b.bits) > 0
    assert float(np.asarray(s_a.bits_down)) == float(
        np.asarray(s_b.bits_down))


def test_overlap_truncated_stream():
    """A batch stream that dries up mid-window serializes the leftover
    rounds exactly like run_rounds (zeros tail on the partial round)."""
    grad_fn, params, batches = _problem()
    inner = tfm.sgd(0.05)
    fixed = sched.fixed_schedule(T, 3)
    mask = np.broadcast_to(fixed[:, None], (T, R)).copy()
    for cut in (T - 5, 7, 2):
        sup = eng.make_superstep(grad_fn, inner, ops.TopK(0.25),
                                 lambda t: 0.05, R)
        s_a, l_a = eng.run_rounds(eng.init(params, inner, R), sup,
                                  batches(cut), mask, jax.random.PRNGKey(7))
        s_b, l_b = eng.run_rounds_overlap(
            eng.init(params, inner, R), sup, batches(cut), mask,
            jax.random.PRNGKey(7), window=4)
        _assert_same(s_a, s_b, l_a, l_b, f"cut={cut}")


def test_multiround_emits_per_round_ledgers():
    """The scanned window reports each interior round boundary's ledger
    — what keeps the trainer's History exact without materializing
    mid-window states."""
    grad_fn, params, batches = _problem()
    inner = tfm.sgd(0.05)
    fixed = sched.fixed_schedule(T, 3)
    mask = np.broadcast_to(fixed[:, None], (T, R)).copy()
    plans = rnd.compile_rounds(mask)[:4]
    sup = eng.make_superstep(grad_fn, inner, ops.TopK(0.25),
                             lambda t: 0.05, R)
    # serial reference ledgers at each round boundary
    state = eng.init(params, inner, R)
    key = jax.random.PRNGKey(7)
    it = iter(batches())
    ref = []
    for p in plans:
        block = eng.stack_block([next(it) for _ in range(p.length)])
        state, _, key = sup(state, block, jnp.asarray(p.mask), key)
        ref.append((float(state.bits), int(state.rounds)))
    multi = eng.make_multiround(sup)
    state2 = eng.init(params, inner, R)
    it = iter(batches())
    steps = [next(it) for _ in range(sum(p.length for p in plans))]
    blocks = eng.stack_window(steps, len(plans), plans[0].length)
    masks = jnp.asarray(np.stack([np.asarray(p.mask) for p in plans]))
    _, _, leds, _ = multi(state2, blocks, masks, jax.random.PRNGKey(7))
    got = [(float(b), int(r)) for b, r in
           zip(np.asarray(leds["bits"]), np.asarray(leds["rounds"]))]
    assert got == ref


# ---------------------------------------------------------------------------
# trainer: History parity + guards
# ---------------------------------------------------------------------------


def _train_pair(run_kw, policy="topk:k=0.25"):
    from repro.train import trainer as tr
    grad_fn, params, batches = _problem()

    def eval_fn(master):
        return {"norm": float(jnp.sum(master["w"] ** 2))}

    out = {}
    for overlap in (False, True):
        with tempfile.TemporaryDirectory() as td:
            run = tr.RunConfig(total_steps=T, R=R, seed=3, log_every=5,
                               eval_every=10, ckpt_dir=td, ckpt_every=12,
                               policy=policy, overlap=overlap,
                               overlap_window=4, **run_kw)
            st, h = tr.train(grad_fn, params, tfm.sgd(0.05),
                             lr_schedule=lambda t: 0.05,
                             batches=batches(), run=run, eval_fn=eval_fn)
        d = dataclasses.asdict(h)
        d.pop("wall_time")
        out[overlap] = (np.asarray(st.master["w"]), d, float(st.bits))
    return out


@pytest.mark.parametrize("run_kw,policy", [
    (dict(H=3), "topk:k=0.25"),
    (dict(H=3, asynchronous=True), "topk:k=0.25"),
    (dict(H=2, leaf_ledger=True), "topk:k=0.25 >> qsgd:s=4"),
    (dict(H=4, scenario="participation=0.7,seed=2"), "topk:k=0.25"),
])
def test_trainer_overlap_history_identical(run_kw, policy):
    pair = _train_pair(run_kw, policy)
    wa, ha, ba = pair[False]
    wb, hb, bb = pair[True]
    np.testing.assert_array_equal(wa, wb)
    assert ba == bb
    assert ha == hb, {k: (ha[k], hb[k]) for k in ha if ha[k] != hb[k]}


def test_trainer_overlap_guards():
    from repro.train import trainer as tr
    grad_fn, params, batches = _problem()
    for bad in (dict(runtime="step"), dict(faults="preset:none")):
        run = tr.RunConfig(total_steps=4, R=R, policy="topk:k=0.25",
                           overlap=True, **bad)
        with pytest.raises(ValueError):
            tr.train(grad_fn, params, tfm.sgd(0.05),
                     lr_schedule=lambda t: 0.05, batches=batches(4),
                     run=run)


# ---------------------------------------------------------------------------
# mesh engine: make_dist_multiround ≡ make_dist_round
# ---------------------------------------------------------------------------

DIST_MULTIROUND = r"""
import jax, jax.numpy as jnp, numpy as np
from jax.sharding import PartitionSpec as P, NamedSharding
from repro.compat import set_mesh
from repro.core.distributed import (make_dist_round, make_dist_multiround,
                                    ShardCompressor)
from repro.optim import sgd, constant

mesh = jax.make_mesh((8, 1), ("data", "model"))
R, d_in, d_out = 8, 16, 8
params = {"w": jnp.zeros((d_in, d_out)), "b": jnp.zeros((d_out,))}
specs = {"w": P(None, "model"), "b": P("model")}
params = jax.device_put(params, jax.tree.map(
    lambda s: NamedSharding(mesh, s), specs,
    is_leaf=lambda z: isinstance(z, P)))
Wtrue = jax.random.normal(jax.random.PRNGKey(0), (d_in, d_out))

def grad_fn(p, batch):
    x, y = batch
    f = lambda pp: jnp.mean((x @ pp["w"] + pp["b"] - y) ** 2)
    return jax.value_and_grad(f)(p)

key0 = jax.random.PRNGKey(7)
bs = []
for _ in range(16):
    key0, s = jax.random.split(key0)
    x = jax.random.normal(s, (R, 16, d_in))
    bs.append((x, jnp.einsum("rbi,io->rbo", x, Wtrue)))

H, T = 4, 16
comp = ShardCompressor("topk", 0.25)
for dl in (None, ShardCompressor("topk", 0.5)):
    init_fn, round_fn, fused = make_dist_round(
        grad_fn, sgd(), comp, constant(0.1), mesh, ("data",), specs,
        downlink=dl)
    init2, multi_fn, fused2 = make_dist_multiround(
        grad_fn, sgd(), comp, constant(0.1), mesh, ("data",), specs,
        downlink=dl)
    assert fused and fused2
    with set_mesh(mesh):
        st = init_fn(params)
        key = jax.random.PRNGKey(1)
        ref_losses = []
        for r0 in range(0, T, H):
            block = jax.tree_util.tree_map(
                lambda *xs: jnp.stack(xs), *bs[r0:r0 + H])
            st, larr, key = round_fn(st, block, key)
            ref_losses.append(np.asarray(larr))
        st2 = init2(params)
        blocks = jax.tree_util.tree_map(
            lambda *xs: jnp.stack(xs).reshape((T // H, H) + xs[0].shape),
            *bs)
        st2, larr2, _ = multi_fn(st2, blocks, jax.random.PRNGKey(1))
    np.testing.assert_array_equal(np.stack(ref_losses), np.asarray(larr2))
    np.testing.assert_array_equal(np.asarray(st.master["w"]),
                                  np.asarray(st2.master["w"]))
    np.testing.assert_array_equal(np.asarray(st.memory["w"]),
                                  np.asarray(st2.memory["w"]))
    assert float(st.bits) == float(st2.bits)
    assert float(st.bits_down) == float(st2.bits_down)
    assert int(st.rounds) == int(st2.rounds)
    print("DIST MULTIROUND OK", "downlink" if dl else "nodl")

# partial=True: per-round tail masks stack to [W, R]
init_fn, round_fn, _ = make_dist_round(
    grad_fn, sgd(), comp, constant(0.1), mesh, ("data",), specs,
    partial=True)
init2, multi_fn, _ = make_dist_multiround(
    grad_fn, sgd(), comp, constant(0.1), mesh, ("data",), specs,
    partial=True)
rngm = np.random.default_rng(3)
masks = jnp.asarray(rngm.random((T // H, R)) < 0.6)
with set_mesh(mesh):
    st = init_fn(params)
    key = jax.random.PRNGKey(1)
    ref_losses = []
    for w, r0 in enumerate(range(0, T, H)):
        block = jax.tree_util.tree_map(
            lambda *xs: jnp.stack(xs), *bs[r0:r0 + H])
        st, larr, key = round_fn(st, block, masks[w], key)
        ref_losses.append(np.asarray(larr))
    st2 = init2(params)
    blocks = jax.tree_util.tree_map(
        lambda *xs: jnp.stack(xs).reshape((T // H, H) + xs[0].shape), *bs)
    st2, larr2, _ = multi_fn(st2, blocks, masks, jax.random.PRNGKey(1))
np.testing.assert_array_equal(np.stack(ref_losses), np.asarray(larr2))
np.testing.assert_array_equal(np.asarray(st.master["w"]),
                              np.asarray(st2.master["w"]))
assert float(st.bits) == float(st2.bits)
print("DIST MULTIROUND PARTIAL OK")
"""


def test_dist_multiround_parity(subproc):
    out = subproc(DIST_MULTIROUND, devices=8, timeout=1500)
    assert out.count("DIST MULTIROUND OK") == 2
    assert "DIST MULTIROUND PARTIAL OK" in out

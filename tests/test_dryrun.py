"""Dry-run plumbing test: the exact lowering/compile/analysis path used
for the production matrix, on reduced configs + an 8-device mesh (full
configs x 256/512 devices run via `python -m repro.launch.dryrun`)."""

import pytest

from repro import compat

pytestmark = pytest.mark.slow

# The legacy-jax skip is per-test, not module-wide: the sparse
# aggregation path is sort-free since the compact kernels landed, so
# its dry-run compiles run on 0.4.x too (test below).  Only the TP>1
# production-matrix compiles stay modern-jax-only.
TP_GT1_SKIP = pytest.mark.skipif(
    not compat.MODERN,
    reason="the TP>1 production-matrix train compiles scan layer stacks "
           "inside a partial-manual shard_map with a >1 tensor-parallel "
           "auto axis; 0.4.x XLA hard-crashes (CHECK IsManualSubgroup) "
           "partitioning scan-with-xs there — unrelated to the sparse "
           "wire path, which is sort-free since the compact kernels "
           "(kernels/topk_compress.py) replaced lax.top_k and is "
           "covered on 0.4.x below and in tests/test_distributed.py.  "
           "TP=1 meshes are unaffected (see repro/compat.py).")

CODE = r"""
import os
from repro.launch import dryrun as dr
import jax
from repro.configs.base import InputShape

mesh = jax.make_mesh((4, 2), ("data", "model"))
shapes = {
    "train_4k": InputShape("train_4k", 64, 8, "train"),
    "prefill_32k": InputShape("prefill_32k", 64, 4, "prefill"),
    "decode_32k": InputShape("decode_32k", 64, 8, "decode"),
    "long_500k": InputShape("long_500k", 128, 1, "decode"),
}
combos = [
    ("yi-6b", "train_4k"), ("yi-6b", "decode_32k"),
    ("gemma3-1b", "long_500k"),
    ("qwen3-moe-30b-a3b", "train_4k"),
    ("rwkv6-3b", "long_500k"),
    ("zamba2-7b", "train_4k"),
    ("musicgen-medium", "prefill_32k"),
    ("yi-34b", "long_500k"),          # must be skipped
]
for arch, shp in combos:
    rec = dr.run_one(arch, shp, smoke=True, mesh=mesh,
                     shape_override=shapes[shp])
    expect_skip = (arch == "yi-34b" and shp == "long_500k")
    if expect_skip:
        assert rec["status"] == "skipped", rec
        continue
    assert rec["status"] == "ok", (arch, shp, rec.get("error"))
    step = next(iter(rec["steps"].values()))
    assert step["flops"] > 0
    assert step["memory"]["temp_bytes"] >= 0
    assert "collectives" in step
print("DRYRUN SMOKE OK")
"""


@TP_GT1_SKIP
def test_dryrun_smoke_path(subproc):
    out = subproc(CODE, devices=8, timeout=1500)
    assert "DRYRUN SMOKE OK" in out


CODE_SPARSE = r"""
import jax
from repro.launch import dryrun as dr
from repro.configs.base import InputShape

mesh = jax.make_mesh((8, 1), ("data", "model"))
shp = InputShape("train_4k", 64, 8, "train")
rec = dr.run_one("yi-6b", "train_4k", smoke=True, mesh=mesh,
                 shape_override=shp, aggregate="sparse_allgather")
assert rec["status"] == "ok", rec.get("error")
assert rec["aggregate"] == "sparse_allgather"
sync = rec["steps"]["sync_step"]
assert sync["flops"] > 0
assert "collectives" in sync
print("DRYRUN SPARSE OK")
"""


def test_dryrun_sparse_allgather(subproc):
    """The sparse-allgather train compile runs on every supported jax —
    the compact wire path traces without lax.top_k, so 0.4.x lowers and
    compiles it (previously this whole module was legacy-skipped)."""
    out = subproc(CODE_SPARSE, devices=8, timeout=1500)
    assert "DRYRUN SPARSE OK" in out


def test_collective_parser():
    from repro.launch import roofline_parse
    hlo = """
  %ag = bf16[8,128]{1,0} all-gather(bf16[1,128]{1,0} %x), dimensions={0}
  %ar = f32[256]{0} all-reduce(f32[256]{0} %y), to_apply=%sum
  %rs = f32[2,4]{1,0} reduce-scatter(f32[16,4]{1,0} %z), dimensions={0}
  %a2a = bf16[4,16]{1,0} all-to-all(bf16[4,16]{1,0} %w), dimensions={0}
  %cp = f32[10]{0} collective-permute(f32[10]{0} %v), source_target_pairs={{0,1}}
"""
    out = roofline_parse.collective_bytes(hlo)
    assert out["all-gather"] == 8 * 128 * 2
    assert out["all-reduce"] == 256 * 4
    assert out["reduce-scatter"] == 2 * 4 * 4
    assert out["all-to-all"] == 4 * 16 * 2
    assert out["collective-permute"] == 40
    assert out["total"] == sum(v for k, v in out.items() if k != "total")

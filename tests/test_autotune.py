"""Tests for the kernel block-geometry autotuner (kernels/autotune.py,
DESIGN.md §10).

The load-bearing invariants:

* resolution order — explicit ``DispatchConfig(block_rows=...)`` beats
  the tuning table beats the historical default, so untuned shapes and
  off-TPU runs behave exactly as before the autotuner existed;
* robustness — corrupt, stale-schema or foreign-device table files
  load as empty with a once-per-reason warning, never an exception;
* the geometry-transparency contract the whole feature rests on: the
  kernels are row-independent, so ANY tuned geometry produces
  bit-for-bit the default geometry's outputs (pinned across TUNE_GRID).
"""

import json
import warnings

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.kernels import autotune as at
from repro.kernels import dispatch as dsp
from repro.kernels import qsgd as qk
from repro.kernels.launch_stats import TUNE_CACHE, reset_tune_cache
from tests.strategies import TUNE_GRID

KCFG = dict(mode="kernel")   # force the kernel path (interpret on CPU)


@pytest.fixture(autouse=True)
def isolated_table(tmp_path):
    """Point the autotuner at a throwaway table dir for every test and
    restore the default afterwards."""
    at.configure(str(tmp_path))
    reset_tune_cache()
    yield
    at.configure(at.DEFAULT_TABLE_DIR)


def _entry(br, chunk=None, us=12.5):
    return at.TunedEntry(br, chunk, us)


def test_table_roundtrip():
    k1 = at.ShapeKey("topk_compress", 4, 512, 16, False)
    k2 = at.ShapeKey("topk_compact", 2, 256, 8, True)
    path = at.save_table({k1.as_str(): _entry(4)})
    assert at.load_table(path)[k1.as_str()].block_rows == 4
    # second save merges instead of clobbering
    at.save_table({k2.as_str(): _entry(2, chunk=128)})
    loaded = at.load_table(path)
    assert set(loaded) == {k1.as_str(), k2.as_str()}
    assert loaded[k2.as_str()].chunk == 128
    # the persisted table feeds lookup after a cache drop
    at.clear_cache()
    ent = at.lookup("topk_compact", 2, 256, 8, True)
    assert ent == loaded[k2.as_str()]


def test_missing_table_is_empty_and_silent():
    with warnings.catch_warnings():
        warnings.simplefilter("error")
        assert at.load_table() == {}
        assert at.lookup("topk_compress", 1, 256, 8, False) is None


@pytest.mark.parametrize("payload,reason", [
    ("{not json", "corrupt"),
    (json.dumps({"version": 999, "entries": {}}), "stale"),
    (json.dumps([1, 2, 3]), "stale"),
    (None, "foreign"),   # filled in below with a wrong device_kind
])
def test_bad_tables_load_safe(payload, reason):
    path = at.table_path()
    import os
    os.makedirs(os.path.dirname(path), exist_ok=True)
    if payload is None:
        payload = json.dumps({
            "version": at.TABLE_VERSION, "device_kind": "tpu_v9000",
            "entries": {"topk_compress|f32|1|256|8|0": {"block_rows": 2}},
        })
    with open(path, "w") as f:
        f.write(payload)
    with warnings.catch_warnings(record=True) as wlog:
        warnings.simplefilter("always")
        assert at.load_table() == {}
        assert at.load_table() == {}    # warn-once: no second warning
    assert len(wlog) == 1, [str(w.message) for w in wlog]
    assert reason in str(wlog[0].message) or "ignoring" in str(
        wlog[0].message)
    # dispatch still resolves (to the default) instead of raising
    assert at.lookup("topk_compress", 1, 256, 8, False) is None


def test_malformed_entries_skipped_individually():
    good = at.ShapeKey("topk_compress", 4, 512, 16, False)
    path = at.table_path()
    import os
    os.makedirs(os.path.dirname(path), exist_ok=True)
    with open(path, "w") as f:
        json.dump({
            "version": at.TABLE_VERSION, "device_kind": at.device_kind(),
            "entries": {
                good.as_str(): {"block_rows": 4, "chunk": None, "us": 1.0},
                "nonsense-key": {"block_rows": 4},
                "topk_compress|f32|1|256|8|0": {"block_rows": "eight"},
                # chunk must divide row_len
                "topk_compact|f32|1|256|8|0": {"block_rows": 1,
                                               "chunk": 100},
            },
        }, f)
    with warnings.catch_warnings(record=True) as wlog:
        warnings.simplefilter("always")
        loaded = at.load_table()
    assert set(loaded) == {good.as_str()}
    assert len(wlog) == 1   # once per file, not per entry


def test_lookup_lru_counters():
    key = at.ShapeKey("topk_compress", 4, 512, 16, False)
    at.save_table({key.as_str(): _entry(4)})
    at.clear_cache()
    reset_tune_cache()
    assert at.lookup(*key[:5]).block_rows == 4
    assert TUNE_CACHE == {"hit": 0, "miss": 1}
    assert at.lookup(*key[:5]).block_rows == 4
    assert TUNE_CACHE == {"hit": 1, "miss": 1}
    # negative result is cached too: one miss, then hits
    assert at.lookup("qsgd", 1, 256, 7, False) is None
    assert at.lookup("qsgd", 1, 256, 7, False) is None
    assert TUNE_CACHE == {"hit": 2, "miss": 2}


def test_resolution_order():
    key = at.ShapeKey("topk_compress", 4, 512, 16, False)
    at.save_table({key.as_str(): _entry(2)})
    at.clear_cache()
    tuned = dsp.DispatchConfig(**KCFG)                 # auto: table wins
    explicit = dsp.DispatchConfig(block_rows=3, **KCFG)
    assert dsp._block_rows(tuned, *key[:5]) == 2
    assert dsp._block_rows(explicit, *key[:5]) == 3    # explicit beats table
    # untuned shape falls back to the historical heuristic
    assert dsp._block_rows(tuned, "topk_compress", 9, 640, 5,
                           False) == dsp.DEFAULT_BLOCK_ROWS
    assert dsp._compact_geometry(tuned, 9, 640, 5, False) == (
        dsp.DEFAULT_BLOCK_ROWS, dsp.DEFAULT_CHUNK)


def _synthetic_geometry(kernel, rows, row_len):
    """A deliberately non-default (but valid) geometry per signature."""
    br = max(1, min(rows, 3))
    chunk = None
    if kernel == "topk_compact":
        chunk = 256 if row_len % 256 == 0 else 128
    return br, chunk


@pytest.mark.parametrize("kernel,rows,row_len,k,sign", TUNE_GRID)
def test_tuned_equals_untuned_bit_for_bit(kernel, rows, row_len, k, sign):
    """The contract that makes geometry tunable at all: block_rows /
    chunk change timing only — outputs are bit-for-bit identical for
    any table entry, across the whole signature grid."""
    br, chunk = _synthetic_geometry(kernel, rows, row_len)
    key = at.ShapeKey(kernel, rows, row_len, k, sign)
    at.save_table({key.as_str(): _entry(br, chunk)})
    at.clear_cache()
    tuned = dsp.DispatchConfig(**KCFG)
    default = dsp.DispatchConfig(block_rows=dsp.DEFAULT_BLOCK_ROWS, **KCFG)
    rng = np.random.RandomState(7)
    x = jnp.asarray(rng.randn(rows, row_len).astype(np.float32))
    if kernel == "topk_compress":
        out_t = dsp.topk_rows(x, k, sign=sign, cfg=tuned)
        out_d = dsp.topk_rows(x, k, sign=sign, cfg=default)
    elif kernel == "topk_compact":
        kcap = dsp.capacity(k, row_len)
        out_t = dsp.compact_rows(x, k, kcap, sign=sign, cfg=tuned)
        out_d = dsp.compact_rows(x, k, kcap, sign=sign, cfg=default)
    else:   # qsgd — geometry resolved through the same table
        u = jnp.asarray(rng.rand(rows, row_len).astype(np.float32))
        ent = at.lookup(*key[:5])
        assert ent is not None and ent.block_rows == br
        out_t = qk.qsgd_quantize(x, u, k, block_rows=ent.block_rows,
                                 interpret=True)
        out_d = qk.qsgd_quantize(x, u, k,
                                 block_rows=dsp.DEFAULT_BLOCK_ROWS,
                                 interpret=True)
    for a, b in zip(jax.tree_util.tree_leaves(out_t),
                    jax.tree_util.tree_leaves(out_d)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


def test_tune_measures_and_caches():
    key = at.ShapeKey("topk_compress", 2, 256, 8, False)
    fresh = at.tune([key], iters=1, interpret=True)
    assert key.as_str() in fresh
    assert fresh[key.as_str()].block_rows in (1, 2)
    assert np.isfinite(fresh[key.as_str()].us)
    import os
    assert os.path.exists(at.table_path())
    # second run: everything cache-hits, nothing re-measured
    again = at.tune([key], iters=1, interpret=True)
    assert again == {} and at.tune.last_cached == 1
    # retune forces a re-measure
    forced = at.tune([key], iters=1, retune=True, interpret=True)
    assert key.as_str() in forced


def test_tune_for_run_covers_launch_plans():
    from repro.core import policy as pol
    params = {"w": jnp.zeros((256, 128)), "b": jnp.zeros((128,))}
    up, down = pol.as_channel_spec("topk:k=0.05").resolve(params)
    cfg = dsp.DispatchConfig(**KCFG)
    want = {k.as_str() for k in dsp.launch_plans(up, params, cfg)}
    assert want, "grid premise: the policy must dispatch kernels"
    fresh = at.tune_for_run(up, params, cfg, iters=1)
    assert set(fresh) == want
    # the table now feeds dispatch for exactly those signatures
    at.clear_cache()
    for ks in want:
        key = at._parse_key(ks)
        assert at.lookup(*key[:5]) is not None


def test_cli_smoke_twice(capsys):
    assert at.main(["--smoke", "--iters", "1"]) == 0
    out1 = capsys.readouterr().out
    assert f"tuned {len(at.SMOKE_KEYS)}" in out1
    import os
    assert os.path.exists(at.table_path())
    assert at.main(["--smoke", "--iters", "1"]) == 0
    out2 = capsys.readouterr().out
    assert f"tuned 0, cached {len(at.SMOKE_KEYS)}" in out2

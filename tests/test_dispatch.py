"""Kernel-dispatch parity: compression routed through the Pallas
kernels (kernels/dispatch.py, interpret mode on CPU) must match the
dense reference operators in core/operators.py — selected values,
error-memory update and wire-bit counts — and fall back transparently
where kernels don't apply.

Top_k inputs are tie-free by construction: threshold selection keeps
*all* coordinates tied at the k-th magnitude while lax.top_k breaks
ties by index, so parity is only exact on distinct magnitudes (see
DESIGN.md §3.1).
"""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import engine, operators as ops, qsparse
from repro.core.distributed import ShardCompressor
from repro.kernels import dispatch as dsp
from repro.optim import constant, sgd

KERNEL = dsp.DispatchConfig(mode="kernel")
REFERENCE = dsp.DispatchConfig(mode="reference")


def tie_free(key, shape, lo=0.05, hi=4.0):
    """Random-looking tensor with strictly distinct |values|."""
    d = int(np.prod(shape))
    mags = jnp.linspace(lo, hi, d)
    ks, kp = jax.random.split(key)
    signs = jnp.where(jax.random.bernoulli(ks, 0.5, (d,)), 1.0, -1.0)
    return (mags * signs)[jax.random.permutation(kp, d)].reshape(shape)


def assert_leaf_parity(op, x, *, atol=1e-5):
    """Dispatched output == reference output: values, memory, bits."""
    key = jax.random.PRNGKey(3)
    out_k, bits_k, used = dsp.compress_leaf(op, key, x, KERNEL)
    assert used, f"{type(op).__name__} did not take the kernel path"
    out_r, bits_r = op(key, x)
    np.testing.assert_allclose(np.asarray(out_k, np.float32),
                               np.asarray(out_r, np.float32),
                               rtol=1e-5, atol=atol)
    # fused error-memory update m' = acc - selected
    np.testing.assert_allclose(np.asarray(x - out_k, np.float32),
                               np.asarray(x - out_r, np.float32),
                               rtol=1e-5, atol=atol)
    np.testing.assert_allclose(float(bits_k), float(bits_r))


def test_topk_kernel_parity():
    x = tie_free(jax.random.PRNGKey(0), (96, 1024))
    assert dsp.would_dispatch(ops.TopK(k=0.01), x.shape, cfg=KERNEL)
    assert_leaf_parity(ops.TopK(k=0.01), x)


def test_signtopk_kernel_parity():
    x = tie_free(jax.random.PRNGKey(1), (96, 1024))
    assert_leaf_parity(ops.SignSparsifier(k=0.01, m=2), x)


def test_row_topk_kernel_parity():
    x = tie_free(jax.random.PRNGKey(2), (64, 512))
    assert_leaf_parity(ops.RowTopK(k=0.05, row_len=512), x)


def test_row_signtopk_kernel_parity():
    x = tie_free(jax.random.PRNGKey(3), (64, 512))
    assert_leaf_parity(ops.RowSignTopK(k=0.05, row_len=512, m=2), x)


def test_qsgd_kernel_parity():
    # same key => same uniforms => identical stochastic rounding
    x = jax.random.normal(jax.random.PRNGKey(4), (300, 128))
    assert_leaf_parity(ops.QSGDQuantizer(s=15), x, atol=1e-4)


def test_fallback_paths():
    """Unsupported (op, shape) pairs run the reference — bit-identical."""
    key = jax.random.PRNGKey(5)
    cases = [
        # auto mode off-TPU: platform rule keeps everything on reference
        (ops.TopK(k=0.2), jax.random.normal(key, (4096,)),
         dsp.DispatchConfig(mode="auto")),
        # tiny leaf in auto mode on any platform: below min_size
        (ops.TopK(k=0.2), jax.random.normal(key, (50,)),
         dsp.DispatchConfig(mode="auto", interpret=True)),
        # L1-scaled SignTopK has no kernel (kernel normalizes by L2)
        (ops.SignSparsifier(k=0.01, m=1), tie_free(key, (96, 1024)), KERNEL),
        # non-lane-aligned compression row
        (ops.RowTopK(k=0.1, row_len=100), jax.random.normal(key, (2000,)),
         KERNEL),
        # a row too long for the VMEM budget
        (ops.TopK(k=0.01), jax.random.normal(key, (1 << 20,)), KERNEL),
        # reference mode disables dispatch outright
        (ops.TopK(k=0.01), tie_free(key, (96, 1024)), REFERENCE),
    ]
    for op, x, cfg in cases:
        assert not dsp.would_dispatch(op, x.shape, cfg=cfg)
        out, bits, used = dsp.compress_leaf(op, key, x, cfg)
        assert not used
        out_r, bits_r = op(key, x)
        np.testing.assert_array_equal(np.asarray(out), np.asarray(out_r))
        np.testing.assert_allclose(float(bits), float(bits_r))


def test_compress_tree_mixed_dispatch():
    """Leafwise routing: eligible leaves take the kernel, the rest fall
    back, totals add up."""
    grads = {
        "big": tie_free(jax.random.PRNGKey(6), (96, 1024)),
        "small": jax.random.normal(jax.random.PRNGKey(7), (50,)),
    }
    op = ops.TopK(k=0.02)
    assert dsp.would_dispatch(op, grads["big"].shape, cfg=KERNEL)
    key = jax.random.PRNGKey(8)
    tree_k, bits_k = dsp.compress_tree(op, key, grads, KERNEL)
    tree_r, bits_r = ops.compress_tree(op, key, grads)
    for name in grads:
        np.testing.assert_allclose(np.asarray(tree_k[name]),
                                   np.asarray(tree_r[name]),
                                   rtol=1e-5, atol=1e-5)
    np.testing.assert_allclose(float(bits_k), float(bits_r))


# ---------------------------------------------------------------------------
# engine through the kernel path
# ---------------------------------------------------------------------------


def _engine_problem(shape=(96, 1024), R=2):
    c = tie_free(jax.random.PRNGKey(9), (R,) + shape, lo=0.05, hi=4.0)

    def grad_fn(params, data):
        g = params["w"] - data
        return 0.5 * jnp.sum(g ** 2), {"w": g}

    return c, grad_fn


def _run_one_sync(dispatch_cfg):
    R = 2
    c, grad_fn = _engine_problem(R=R)
    params = {"w": jnp.zeros(c.shape[1:])}
    state = engine.init(params, sgd(), R)
    step = jax.jit(engine.make_step(
        grad_fn, sgd(), ops.TopK(k=0.01), constant(0.1), R,
        dispatch=dispatch_cfg, global_rounds=True))
    return step(state, c, jnp.ones((R,), bool), jax.random.PRNGKey(0))


def test_engine_sync_step_kernel_vs_reference():
    """Acceptance: a TopK compression executes through the Pallas kernel
    inside the jitted engine step with output parity vs the dense
    reference — master update, error memory and bits ledger."""
    op = ops.TopK(k=0.01)
    assert dsp.would_dispatch(op, (96, 1024), cfg=KERNEL)
    ks, loss_k = _run_one_sync(KERNEL)
    rs, loss_r = _run_one_sync(REFERENCE)
    np.testing.assert_allclose(np.asarray(ks.master["w"]),
                               np.asarray(rs.master["w"]),
                               rtol=1e-5, atol=1e-6)
    np.testing.assert_allclose(np.asarray(ks.memory["w"]),
                               np.asarray(rs.memory["w"]),
                               rtol=1e-5, atol=1e-6)
    np.testing.assert_allclose(float(ks.bits), float(rs.bits))
    np.testing.assert_allclose(float(loss_k), float(loss_r))
    assert int(ks.rounds) == 1


def test_qsparse_wrapper_matches_engine():
    """The Algorithm-1 wrapper is the engine under an all-equal mask."""
    R, D = 4, 64
    c = jax.random.normal(jax.random.PRNGKey(10), (R, D))

    def grad_fn(params, data):
        g = params["w"] - data
        return 0.5 * jnp.sum(g ** 2), {"w": g}

    params = {"w": jnp.zeros(D)}
    op = ops.TopK(k=8)
    w_state = qsparse.init(params, sgd(), R)
    w_step = jax.jit(qsparse.make_step(grad_fn, sgd(), op, constant(0.1), R),
                     static_argnames=("sync",))
    e_state = engine.init(params, sgd(), R)
    e_step = jax.jit(engine.make_step(grad_fn, sgd(), op, constant(0.1), R,
                                      global_rounds=True))
    key = jax.random.PRNGKey(11)
    for t in range(6):
        key, sub = jax.random.split(key)
        sync = t % 3 == 2
        w_state, _ = w_step(w_state, c, sync=sync, key=sub)
        e_state, _ = e_step(e_state, c, jnp.full((R,), sync), sub)
    np.testing.assert_array_equal(np.asarray(w_state.master["w"]),
                                  np.asarray(e_state.master["w"]))
    np.testing.assert_array_equal(np.asarray(w_state.memory["w"]),
                                  np.asarray(e_state.memory["w"]))
    assert float(w_state.bits) == float(e_state.bits)
    assert int(w_state.rounds) == int(e_state.rounds)


def test_shard_compressor_kernel_parity():
    """The distributed engine's shard-local compressor takes the same
    kernel path with identical outputs and wire bits."""
    g = {"w": tie_free(jax.random.PRNGKey(12), (256, 512))}
    for mode in ("topk", "signtopk"):
        ck = ShardCompressor(mode=mode, k_frac=0.05, dispatch="kernel")
        cr = ShardCompressor(mode=mode, k_frac=0.05, dispatch="reference")
        out_k, bits_k = ck(g, None)
        out_r, bits_r = cr(g, None)
        np.testing.assert_allclose(np.asarray(out_k["w"]),
                                   np.asarray(out_r["w"]),
                                   rtol=1e-5, atol=1e-5)
        np.testing.assert_allclose(float(bits_k), float(bits_r))

"""Kernel-dispatch parity: compression routed through the Pallas
kernels (kernels/dispatch.py, interpret mode on CPU) must match the
dense reference operators in core/operators.py — selected values,
error-memory update and wire-bit counts — and fall back transparently
where kernels don't apply.

Top_k inputs are tie-free by construction: threshold selection keeps
*all* coordinates tied at the k-th magnitude while lax.top_k breaks
ties by index, so parity is only exact on distinct magnitudes (see
DESIGN.md §3.1).
"""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import engine, operators as ops, qsparse
from repro.core.distributed import ShardCompressor
from repro.kernels import dispatch as dsp
from repro.optim import constant, sgd

KERNEL = dsp.DispatchConfig(mode="kernel")
REFERENCE = dsp.DispatchConfig(mode="reference")


def tie_free(key, shape, lo=0.05, hi=4.0):
    """Random-looking tensor with strictly distinct |values|."""
    d = int(np.prod(shape))
    mags = jnp.linspace(lo, hi, d)
    ks, kp = jax.random.split(key)
    signs = jnp.where(jax.random.bernoulli(ks, 0.5, (d,)), 1.0, -1.0)
    return (mags * signs)[jax.random.permutation(kp, d)].reshape(shape)


def assert_leaf_parity(op, x, *, atol=1e-5):
    """Dispatched output == reference output: values, memory, bits."""
    key = jax.random.PRNGKey(3)
    out_k, bits_k, used = dsp.compress_leaf(op, key, x, KERNEL)
    assert used, f"{type(op).__name__} did not take the kernel path"
    out_r, bits_r = op(key, x)
    np.testing.assert_allclose(np.asarray(out_k, np.float32),
                               np.asarray(out_r, np.float32),
                               rtol=1e-5, atol=atol)
    # fused error-memory update m' = acc - selected
    np.testing.assert_allclose(np.asarray(x - out_k, np.float32),
                               np.asarray(x - out_r, np.float32),
                               rtol=1e-5, atol=atol)
    np.testing.assert_allclose(float(bits_k), float(bits_r))


def test_topk_kernel_parity():
    x = tie_free(jax.random.PRNGKey(0), (96, 1024))
    assert dsp.would_dispatch(ops.TopK(k=0.01), x.shape, cfg=KERNEL)
    assert_leaf_parity(ops.TopK(k=0.01), x)


def test_signtopk_kernel_parity():
    x = tie_free(jax.random.PRNGKey(1), (96, 1024))
    assert_leaf_parity(ops.SignSparsifier(k=0.01, m=2), x)


def test_row_topk_kernel_parity():
    x = tie_free(jax.random.PRNGKey(2), (64, 512))
    assert_leaf_parity(ops.RowTopK(k=0.05, row_len=512), x)


def test_row_signtopk_kernel_parity():
    x = tie_free(jax.random.PRNGKey(3), (64, 512))
    assert_leaf_parity(ops.RowSignTopK(k=0.05, row_len=512, m=2), x)


def test_qsgd_kernel_parity():
    # same key => same uniforms => identical stochastic rounding
    x = jax.random.normal(jax.random.PRNGKey(4), (300, 128))
    assert_leaf_parity(ops.QSGDQuantizer(s=15), x, atol=1e-4)


def test_fallback_paths():
    """Unsupported (op, shape) pairs run the reference — bit-identical."""
    key = jax.random.PRNGKey(5)
    cases = [
        # auto mode off-TPU: platform rule keeps everything on reference
        (ops.TopK(k=0.2), jax.random.normal(key, (4096,)),
         dsp.DispatchConfig(mode="auto")),
        # tiny leaf in auto mode on any platform: below min_size
        (ops.TopK(k=0.2), jax.random.normal(key, (50,)),
         dsp.DispatchConfig(mode="auto", interpret=True)),
        # L1-scaled SignTopK has no kernel (kernel normalizes by L2)
        (ops.SignSparsifier(k=0.01, m=1), tie_free(key, (96, 1024)), KERNEL),
        # non-lane-aligned compression row
        (ops.RowTopK(k=0.1, row_len=100), jax.random.normal(key, (2000,)),
         KERNEL),
        # a row too long for the VMEM budget
        (ops.TopK(k=0.01), jax.random.normal(key, (1 << 20,)), KERNEL),
        # reference mode disables dispatch outright
        (ops.TopK(k=0.01), tie_free(key, (96, 1024)), REFERENCE),
    ]
    for op, x, cfg in cases:
        assert not dsp.would_dispatch(op, x.shape, cfg=cfg)
        out, bits, used = dsp.compress_leaf(op, key, x, cfg)
        assert not used
        out_r, bits_r = op(key, x)
        np.testing.assert_array_equal(np.asarray(out), np.asarray(out_r))
        np.testing.assert_allclose(float(bits), float(bits_r))


def test_compress_tree_mixed_dispatch():
    """Leafwise routing: eligible leaves take the kernel, the rest fall
    back, totals add up."""
    grads = {
        "big": tie_free(jax.random.PRNGKey(6), (96, 1024)),
        "small": jax.random.normal(jax.random.PRNGKey(7), (50,)),
    }
    op = ops.TopK(k=0.02)
    assert dsp.would_dispatch(op, grads["big"].shape, cfg=KERNEL)
    key = jax.random.PRNGKey(8)
    tree_k, bits_k = dsp.compress_tree(op, key, grads, KERNEL)
    tree_r, bits_r = ops.compress_tree(op, key, grads)
    for name in grads:
        np.testing.assert_allclose(np.asarray(tree_k[name]),
                                   np.asarray(tree_r[name]),
                                   rtol=1e-5, atol=1e-5)
    np.testing.assert_allclose(float(bits_k), float(bits_r))


# ---------------------------------------------------------------------------
# engine through the kernel path
# ---------------------------------------------------------------------------


def _engine_problem(shape=(96, 1024), R=2):
    c = tie_free(jax.random.PRNGKey(9), (R,) + shape, lo=0.05, hi=4.0)

    def grad_fn(params, data):
        g = params["w"] - data
        return 0.5 * jnp.sum(g ** 2), {"w": g}

    return c, grad_fn


def _run_one_sync(dispatch_cfg):
    R = 2
    c, grad_fn = _engine_problem(R=R)
    params = {"w": jnp.zeros(c.shape[1:])}
    state = engine.init(params, sgd(), R)
    step = jax.jit(engine.make_step(
        grad_fn, sgd(), ops.TopK(k=0.01), constant(0.1), R,
        dispatch=dispatch_cfg, global_rounds=True))
    return step(state, c, jnp.ones((R,), bool), jax.random.PRNGKey(0))


def test_engine_sync_step_kernel_vs_reference():
    """Acceptance: a TopK compression executes through the Pallas kernel
    inside the jitted engine step with output parity vs the dense
    reference — master update, error memory and bits ledger."""
    op = ops.TopK(k=0.01)
    assert dsp.would_dispatch(op, (96, 1024), cfg=KERNEL)
    ks, loss_k = _run_one_sync(KERNEL)
    rs, loss_r = _run_one_sync(REFERENCE)
    np.testing.assert_allclose(np.asarray(ks.master["w"]),
                               np.asarray(rs.master["w"]),
                               rtol=1e-5, atol=1e-6)
    np.testing.assert_allclose(np.asarray(ks.memory["w"]),
                               np.asarray(rs.memory["w"]),
                               rtol=1e-5, atol=1e-6)
    np.testing.assert_allclose(float(ks.bits), float(rs.bits))
    np.testing.assert_allclose(float(loss_k), float(loss_r))
    assert int(ks.rounds) == 1


def test_qsparse_wrapper_matches_engine():
    """The Algorithm-1 wrapper is the engine under an all-equal mask."""
    R, D = 4, 64
    c = jax.random.normal(jax.random.PRNGKey(10), (R, D))

    def grad_fn(params, data):
        g = params["w"] - data
        return 0.5 * jnp.sum(g ** 2), {"w": g}

    params = {"w": jnp.zeros(D)}
    op = ops.TopK(k=8)
    w_state = qsparse.init(params, sgd(), R)
    w_step = jax.jit(qsparse.make_step(grad_fn, sgd(), op, constant(0.1), R),
                     static_argnames=("sync",))
    e_state = engine.init(params, sgd(), R)
    e_step = jax.jit(engine.make_step(grad_fn, sgd(), op, constant(0.1), R,
                                      global_rounds=True))
    key = jax.random.PRNGKey(11)
    for t in range(6):
        key, sub = jax.random.split(key)
        sync = t % 3 == 2
        w_state, _ = w_step(w_state, c, sync=sync, key=sub)
        e_state, _ = e_step(e_state, c, jnp.full((R,), sync), sub)
    np.testing.assert_array_equal(np.asarray(w_state.master["w"]),
                                  np.asarray(e_state.master["w"]))
    np.testing.assert_array_equal(np.asarray(w_state.memory["w"]),
                                  np.asarray(e_state.memory["w"]))
    assert float(w_state.bits) == float(e_state.bits)
    assert int(w_state.rounds) == int(e_state.rounds)


# ---------------------------------------------------------------------------
# compact wire path (kernel compact emission, DESIGN.md §3.3)
# ---------------------------------------------------------------------------


def test_compact_rows_matches_lax_topk():
    """Kernel compact survivors == lax.top_k selection on tie-free rows
    (same index set, same values; compact fills slots in ascending index
    order while lax.top_k sorts by magnitude, so compare as sets)."""
    x = tie_free(jax.random.PRNGKey(20), (8, 512))
    k, kcap = 32, dsp.capacity(32, 512)
    idx, val, mem, cnt = dsp.compact_rows(x, k, kcap, cfg=KERNEL)
    _tv, ti = jax.lax.top_k(jnp.abs(x), k)
    np.testing.assert_array_equal(np.asarray(cnt), k)
    for r in range(x.shape[0]):
        assert set(np.asarray(idx[r, :k])) == set(np.asarray(ti[r]))
        np.testing.assert_allclose(
            np.sort(np.asarray(val[r, :k])),
            np.sort(np.asarray(x[r, np.asarray(idx[r, :k])])), rtol=1e-6)
    # empty slots: out-of-row sentinel index, zero value
    np.testing.assert_array_equal(np.asarray(idx[:, k:]), x.shape[1])
    np.testing.assert_array_equal(np.asarray(val[:, k:]), 0.0)


@pytest.mark.parametrize("sign", [False, True])
def test_compact_densify_matches_dense_kernel(sign):
    """_densify(compact) == the dense kernel's output, and the fused
    error memories and survivor counts agree — compact emission is the
    same selection, different wire format."""
    from repro.core.distributed import _densify

    x = tie_free(jax.random.PRNGKey(21), (16, 384))
    k, kcap = 24, dsp.capacity(24, 384)
    idx, val, mem_c, cnt_c = dsp.compact_rows(x, k, kcap, sign=sign,
                                              cfg=KERNEL)
    sel_d, mem_d, cnt_d = dsp.topk_rows(x, k, sign=sign, cfg=KERNEL)
    dense = _densify(idx, val, x.shape, x.ndim - 1)
    np.testing.assert_allclose(np.asarray(dense), np.asarray(sel_d),
                               rtol=1e-5, atol=1e-6)
    np.testing.assert_allclose(np.asarray(mem_c), np.asarray(mem_d),
                               rtol=1e-5, atol=1e-6)
    np.testing.assert_array_equal(np.asarray(cnt_c), np.asarray(cnt_d))


@pytest.mark.parametrize("sign", [False, True])
def test_compact_kernel_matches_reference_oracle(sign):
    """Kernel compact == the scatter-free jnp oracle (the transparent
    fallback), including on rows the kernel would not accept."""
    from repro.kernels.ref import topk_compact_ref

    x = tie_free(jax.random.PRNGKey(22), (8, 256))
    k, kcap = 16, dsp.capacity(16, 256)
    got = dsp.compact_rows(x, k, kcap, sign=sign, cfg=KERNEL)
    want = topk_compact_ref(x, k, kcap, sign=sign)
    for g, w in zip(got, want):
        np.testing.assert_allclose(np.asarray(g, np.float32),
                                   np.asarray(w, np.float32),
                                   rtol=1e-5, atol=1e-6)
    # non-lane-aligned rows fall back to the oracle and still decode
    y = tie_free(jax.random.PRNGKey(23), (4, 100))
    idx, val, mem, cnt = dsp.compact_rows(y, 7, dsp.capacity(7, 100),
                                          sign=sign, cfg=KERNEL)
    dense = jax.vmap(lambda o, i, v: o.at[i].add(v, mode="drop"))(
        jnp.zeros((4, 100)), idx, val)
    np.testing.assert_allclose(np.asarray(y - dense), np.asarray(mem),
                               rtol=1e-5, atol=1e-6)


def test_compact_compress_leaf_parity_and_bits():
    """Operator-level compact form: densify == the reference operator's
    dense output and the counted bits equal the reference ledger on
    tie-free inputs (exactly k survivors, exact zeros excluded)."""
    cases = [
        (ops.TopK(k=0.01), (96, 1024)),
        (ops.SignSparsifier(k=0.01, m=2), (96, 1024)),
        (ops.RowTopK(k=0.05, row_len=512), (64, 512)),
        (ops.RowSignTopK(k=0.05, row_len=512, m=2), (64, 512)),
    ]
    for i, (op, shape) in enumerate(cases):
        x = tie_free(jax.random.PRNGKey(24 + i), shape)
        leaf, used = dsp.compact_compress(op, None, x, KERNEL)
        assert used, type(op).__name__
        dense = dsp.densify_compact(leaf, x.shape)
        out_r, bits_r = op(jax.random.PRNGKey(3), x)
        np.testing.assert_allclose(np.asarray(dense), np.asarray(out_r),
                                   rtol=1e-5, atol=1e-5)
        np.testing.assert_allclose(float(leaf.bits), float(bits_r))
        np.testing.assert_allclose(np.asarray(leaf.mem),
                                   np.asarray(x - dense),
                                   rtol=1e-5, atol=1e-5)
    with pytest.raises(TypeError):
        dsp.compact_compress(ops.QSGDQuantizer(s=15), None,
                             tie_free(jax.random.PRNGKey(30), (96, 1024)),
                             KERNEL)


def test_shard_compressor_compact_counted_bits():
    """axis_topk_compact charges counted bits (actual survivors, exact
    zeros excluded) — the compact ledger equals the dense compressor's
    on tie-free inputs, on both dispatch routes, and the fused error
    memory rides along."""
    from repro.core.distributed import _densify

    g = {"w": tie_free(jax.random.PRNGKey(25), (256, 512))}
    for mode in ("topk", "signtopk"):
        for disp in ("kernel", "reference"):
            c = ShardCompressor(mode=mode, k_frac=0.05, dispatch=disp)
            payloads, _td, bits, mems = c.compact(g, None)
            kind, idx, val, ax, moved = payloads[0]
            assert kind == "sparse"
            dense = _densify(idx, val, moved, ax)
            out_d, bits_d = c(g, None)
            np.testing.assert_allclose(np.asarray(dense),
                                       np.asarray(out_d["w"]),
                                       rtol=1e-5, atol=1e-5)
            np.testing.assert_allclose(float(bits), float(bits_d))
            np.testing.assert_allclose(np.asarray(mems["w"]),
                                       np.asarray(g["w"] - dense),
                                       rtol=1e-5, atol=1e-5)


def test_compact_bits_exclude_zero_rows():
    """All-zero compression rows transmit no survivors: counted bits
    charge only the per-row scale fields, matching the dense path."""
    x = jnp.zeros((4, 256))
    idx, val, mem, cnt = dsp.compact_rows(x, 16, 128, cfg=KERNEL)
    np.testing.assert_array_equal(np.asarray(cnt), 0)
    np.testing.assert_array_equal(np.asarray(idx), 256)
    np.testing.assert_array_equal(np.asarray(val), 0.0)


# ---------------------------------------------------------------------------
# megabuffer packing (one kernel launch per operator family, §3.4)
# ---------------------------------------------------------------------------


def test_megabuffer_pack_roundtrip():
    """Packed compress_tree == leaf-by-leaf compress_tree, per leaf
    dtype and shape, with identical bits — and strictly fewer kernel
    launches (>= 2x here: four same-bucket leaves share one launch)."""
    key = jax.random.PRNGKey(26)
    tree = {
        "w1": tie_free(jax.random.PRNGKey(27), (96, 1024)),
        "w2": tie_free(jax.random.PRNGKey(28), (96, 1024)),
        "w3": tie_free(jax.random.PRNGKey(29), (48, 2048)),
        "w4": tie_free(jax.random.PRNGKey(30), (1024, 96)),
        "half": tie_free(jax.random.PRNGKey(31),
                         (64, 512)).astype(jnp.bfloat16),
        "small": jax.random.normal(jax.random.PRNGKey(32), (50,)),
    }
    op = ops.TopK(k=0.02)
    packed_cfg = dsp.DispatchConfig(mode="kernel", pack=True)
    unpacked_cfg = dsp.DispatchConfig(mode="kernel", pack=False)
    dsp.reset_launches()
    tp, bp = dsp.compress_tree(op, key, tree, packed_cfg)
    packed_launches = dsp.total_launches()
    dsp.reset_launches()
    tu, bu = dsp.compress_tree(op, key, tree, unpacked_cfg)
    unpacked_launches = dsp.total_launches()
    for name, leaf in tree.items():
        assert tp[name].shape == leaf.shape
        assert tp[name].dtype == tu[name].dtype
        np.testing.assert_allclose(
            np.asarray(tp[name], np.float32),
            np.asarray(tu[name], np.float32), rtol=1e-5, atol=1e-5)
    np.testing.assert_allclose(float(bp), float(bu))
    # w1/w2/w3/w4 all flatten to a 98304-element row -> one bucket; half
    # (32768) and small (padded to 128 — mode="kernel" bypasses the
    # min_size floor) get their own.  6 launches -> 3.
    assert unpacked_launches >= 2 * packed_launches, (
        packed_launches, unpacked_launches)


def test_megabuffer_pack_mixed_families():
    """Buckets are per (family, row length, k, sign): RowTopK rows,
    sign variants and QSGD pack separately and correctly."""
    key = jax.random.PRNGKey(33)
    tree = {
        "a": tie_free(jax.random.PRNGKey(34), (16, 512)),
        "b": tie_free(jax.random.PRNGKey(35), (16, 512)),
    }
    for op in (ops.RowTopK(k=0.05, row_len=512),
               ops.RowSignTopK(k=0.05, row_len=512, m=2),
               ops.QSGDQuantizer(s=15)):
        dsp.reset_launches()
        tp, bp = dsp.compress_tree(
            op, key, tree, dsp.DispatchConfig(mode="kernel", pack=True))
        assert dsp.total_launches() == 1, type(op).__name__
        tu, bu = dsp.compress_tree(
            op, key, tree, dsp.DispatchConfig(mode="kernel", pack=False))
        for name in tree:
            np.testing.assert_allclose(np.asarray(tp[name]),
                                       np.asarray(tu[name]),
                                       rtol=1e-5, atol=1e-5)
        np.testing.assert_allclose(float(bp), float(bu))


def test_shard_compressor_kernel_parity():
    """The distributed engine's shard-local compressor takes the same
    kernel path with identical outputs and wire bits."""
    g = {"w": tie_free(jax.random.PRNGKey(12), (256, 512))}
    for mode in ("topk", "signtopk"):
        ck = ShardCompressor(mode=mode, k_frac=0.05, dispatch="kernel")
        cr = ShardCompressor(mode=mode, k_frac=0.05, dispatch="reference")
        out_k, bits_k = ck(g, None)
        out_r, bits_r = cr(g, None)
        np.testing.assert_allclose(np.asarray(out_k["w"]),
                                   np.asarray(out_r["w"]),
                                   rtol=1e-5, atol=1e-5)
        np.testing.assert_allclose(float(bits_k), float(bits_r))
